#!/usr/bin/env python
"""Tolerance-banded perf-regression gate over the committed BENCH_*.json
baselines — the enforcement half of the "measured perf trajectory"
standing item.

CI runs a fresh bench smoke (small sizes, shared runner, interpret-mode
Pallas) and compares it against the committed baseline with this script;
an out-of-band drift fails the leg.  Because the smoke sizes differ from
the committed run, every check is **scale-robust**: dimensionless ratios
measured within one run (fused-vs-loop speedup, achieved/offered load,
faulted-vs-clean throughput), rows matched on identical offered load, and
boolean invariants — never raw inst/s across different problem sizes.

Tolerance bands (deliberately loose — the gate exists to catch
order-of-magnitude regressions and broken invariants, not 10% noise on a
shared CI box):

  streaming
    - driver_posterior_max_abs_diff <= 1e-6     (fused == loop, exact)
    - speedup_inst_per_s >= max(1.0, 0.15 x baseline speedup)
      (the fused scan must stay a *speedup*; at 0.15x the committed
       ratio something structural broke, e.g. the scan fell back to
       per-batch dispatch)

  serve   (rows matched by driver + offered_qps; serve_single only —
           mesh timing is too noisy at smoke sizes)
    - p50_ms <= max(20 ms, 4 x baseline p50)
    - p99_ms <= max(30 ms, 4 x baseline p99)
    - achieved_qps / offered_qps >= max(0.5, baseline ratio - 0.3)
    - plan_cache_hit_rate >= baseline - 0.2    (payload-level)
    - hot_swap_zero_drop stays true

  resilience
    - quarantine_bit_identical / serve_zero_loss / resume_bit_identical
      stay true
    - streaming overhead_pct <= 50   (quarantine gate stays ~free)
    - faulted achieved_qps >= 0.5 x clean achieved_qps (within-run)
    - zero lost tickets, clean and faulted

Reading a failure: each line prints  CHECK  fresh-value  vs  band
(derived from the baseline value in parentheses).  A FAIL on a parity /
boolean check means a correctness regression — fix the code.  A FAIL on
a latency/throughput band means either a real perf regression (profile
the path the check names) or a genuinely slower runner — if the latter,
re-run; the bands already absorb ~4x machine variance, so a persistent
failure is a regression, not noise.

Usage:
  python scripts/bench_compare.py --bench streaming \
      --fresh /tmp/bench.json --baseline BENCH_streaming.json

Exits 0 when every check passes, 1 otherwise.  Pure stdlib — no repro /
jax imports — so it runs in any leg instantly.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List, NamedTuple, Optional


class Check(NamedTuple):
    name: str
    ok: bool
    fresh: Any
    band: str           # human-readable bound, baseline in parentheses

    def line(self) -> str:
        mark = "PASS" if self.ok else "FAIL"
        return f"  [{mark}] {self.name}: {self.fresh} vs {self.band}"


def _fmt(v: Any) -> str:
    return f"{v:.4g}" if isinstance(v, float) else str(v)


def compare_streaming(fresh: Dict, base: Dict) -> List[Check]:
    checks = []
    diff = fresh["driver_posterior_max_abs_diff"]
    checks.append(Check("fused-vs-loop posterior parity", diff <= 1e-6,
                        _fmt(diff), "<= 1e-06"))
    floor = max(1.0, 0.15 * base["speedup_inst_per_s"])
    sp = fresh["speedup_inst_per_s"]
    checks.append(Check(
        "stream_fit_scan speedup over stream_update_loop", sp >= floor,
        _fmt(sp),
        f">= {floor:.2f} (0.15 x baseline {base['speedup_inst_per_s']:.2f}, "
        f"floor 1.0)"))
    return checks


def _serve_rows(payload: Dict, driver: str) -> Dict[float, Dict]:
    return {r["offered_qps"]: r for r in payload["results"]
            if r.get("driver") == driver}


def compare_serve(fresh: Dict, base: Dict) -> List[Check]:
    checks = []
    fr = _serve_rows(fresh, "serve_single")
    br = _serve_rows(base, "serve_single")
    common = sorted(set(fr) & set(br))
    if not common:
        # no identical offered load: compare each fresh row against the
        # nearest baseline load (bands are wide enough to absorb it)
        pairs = [(q, min(br, key=lambda b: abs(b - q))) for q in sorted(fr)]
    else:
        pairs = [(q, q) for q in common]
    for fq, bq in pairs:
        f, b = fr[fq], br[bq]
        tag = (f"@{fq:g}qps" if fq == bq
               else f"@{fq:g}qps (nearest baseline {bq:g})")
        p50_cap = max(20.0, 4.0 * b["p50_ms"])
        checks.append(Check(
            f"serve_single p50_ms {tag}", f["p50_ms"] <= p50_cap,
            _fmt(f["p50_ms"]),
            f"<= {p50_cap:.1f} (max(20, 4 x baseline {b['p50_ms']:.2f}))"))
        p99_cap = max(30.0, 4.0 * b["p99_ms"])
        checks.append(Check(
            f"serve_single p99_ms {tag}", f["p99_ms"] <= p99_cap,
            _fmt(f["p99_ms"]),
            f"<= {p99_cap:.1f} (max(30, 4 x baseline {b['p99_ms']:.2f}))"))
        f_ratio = f["achieved_qps"] / f["offered_qps"]
        b_ratio = b["achieved_qps"] / b["offered_qps"]
        ratio_floor = max(0.5, b_ratio - 0.3)
        checks.append(Check(
            f"serve_single achieved/offered {tag}", f_ratio >= ratio_floor,
            _fmt(f_ratio),
            f">= {ratio_floor:.2f} (baseline ratio {b_ratio:.2f} - 0.3, "
            f"floor 0.5)"))
    hit_floor = base["plan_cache_hit_rate"] - 0.2
    hr = fresh["plan_cache_hit_rate"]
    checks.append(Check(
        "plan_cache_hit_rate", hr >= hit_floor, _fmt(hr),
        f">= {hit_floor:.2f} (baseline {base['plan_cache_hit_rate']:.2f} "
        f"- 0.2)"))
    checks.append(Check("hot_swap_zero_drop", bool(fresh["hot_swap_zero_drop"]),
                        fresh["hot_swap_zero_drop"], "== True"))
    return checks


def compare_resilience(fresh: Dict, base: Dict) -> List[Check]:
    checks = []
    for key in ("quarantine_bit_identical", "serve_zero_loss",
                "resume_bit_identical"):
        checks.append(Check(key, bool(fresh[key]), fresh[key], "== True"))
    ov = fresh["streaming"]["overhead_pct"]
    checks.append(Check("quarantine-gate streaming overhead_pct", ov <= 50.0,
                        _fmt(ov), "<= 50"))
    clean = fresh["serving"]["clean"]["achieved_qps"]
    faulted = fresh["serving"]["faulted"]["achieved_qps"]
    floor = 0.5 * clean
    checks.append(Check(
        "faulted achieved_qps vs clean (within-run)", faulted >= floor,
        _fmt(faulted), f">= {floor:.1f} (0.5 x clean {clean:.1f})"))
    for leg in ("clean", "faulted"):
        lost = fresh["serving"][leg]["lost_tickets"]
        checks.append(Check(f"{leg} lost_tickets", lost == 0, lost, "== 0"))
    return checks


COMPARATORS = {"streaming": compare_streaming, "serve": compare_serve,
               "resilience": compare_resilience}


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="tolerance-banded bench regression gate (see module "
                    "docstring for the bands)")
    ap.add_argument("--bench", required=True, choices=sorted(COMPARATORS))
    ap.add_argument("--fresh", required=True,
                    help="freshly produced bench JSON (the smoke run)")
    ap.add_argument("--baseline", required=True,
                    help="committed BENCH_*.json baseline")
    args = ap.parse_args(argv)

    with open(args.fresh) as fh:
        fresh = json.load(fh)
    with open(args.baseline) as fh:
        base = json.load(fh)
    for payload, path in ((fresh, args.fresh), (base, args.baseline)):
        if payload.get("bench") != args.bench:
            print(f"bench_compare: {path} is a "
                  f"{payload.get('bench')!r} payload, expected "
                  f"{args.bench!r}", file=sys.stderr)
            return 2

    checks = COMPARATORS[args.bench](fresh, base)
    failed = [c for c in checks if not c.ok]
    print(f"bench_compare[{args.bench}]: {args.fresh} vs {args.baseline}")
    for c in checks:
        print(c.line())
    if failed:
        print(f"bench_compare[{args.bench}]: {len(failed)}/{len(checks)} "
              f"checks FAILED — out-of-band drift vs the committed "
              f"baseline (see script docstring: parity/boolean failures "
              f"are correctness bugs; band failures are perf regressions "
              f"unless the runner is pathologically slow)")
        return 1
    print(f"bench_compare[{args.bench}]: all {len(checks)} checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
