#!/usr/bin/env bash
# Tier-1 smoke runner.  Two gates:
#   1. the full pytest suite with -x (any collection error — e.g. a jax
#      import that moved between versions — fails fast instead of landing),
#   2. an end-to-end 2-variable junction-tree query through the public API,
#      so the exact-inference path is exercised even under pytest -k filters.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q "$@"

python - <<'EOF'
import jax.numpy as jnp
from repro.core.dag import BayesianNetwork, DAG, MultinomialCPD, Variables
from repro.infer_exact import JunctionTreeEngine

vs = Variables()
a = vs.new_multinomial("A", 2)
b = vs.new_multinomial("B", 2)
dag = DAG(vs)
dag.add_parent(b, a)
bn = BayesianNetwork(dag, {
    "A": MultinomialCPD(jnp.array([0.6, 0.4])),
    "B": MultinomialCPD(jnp.array([[0.9, 0.1], [0.2, 0.8]])),
})
eng = JunctionTreeEngine(bn)
eng.set_evidence({"B": 1})
eng.run_inference()
post = eng.posterior_discrete(a)
expect = jnp.array([0.6 * 0.1, 0.4 * 0.8])
expect = expect / expect.sum()
assert jnp.allclose(post, expect, atol=1e-6), (post, expect)
print(f"ci smoke: P(A | B=1) = {post} OK")
EOF
