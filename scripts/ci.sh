#!/usr/bin/env bash
# Tier-1 CI runner (run by .github/workflows/ci.yml on every push/PR, and by
# hand via `bash scripts/ci.sh`).  Gates:
#   1. the full pytest suite with -x (any collection error — e.g. a jax
#      import that moved between versions — fails fast instead of landing),
#   2. kernel interpret-vs-policy parity: tests/test_kernels.py runs once
#      with REPRO_PALLAS_INTERPRET=1 forced and once under the default
#      policy, so on a TPU runner the compiled Mosaic path is checked
#      against the same oracles the CPU container verifies in interpret
#      mode (they may not silently diverge),
#   3. the streaming perf harness in --json mode on tiny sizes with schema
#      validation, so perf-trajectory breakage (BENCH_streaming.json) fails
#      tier-1 instead of silently rotting,
#   3b. the perf-regression gate: scripts/bench_compare.py diffs the fresh
#      streaming / serve / resilience smoke payloads against the committed
#      BENCH_*.json baselines using scale-robust tolerance bands
#      (dimensionless within-run ratios, load-matched rows, boolean
#      invariants — the bands are documented in the script docstring), so
#      an out-of-band perf drift fails tier-1 with a named check,
#   4. the d-VMP mesh-path harness (--json --dvmp) on a forced 4-device
#      host mesh with schema + shard-invariance validation,
#   4b. the latent-path harness (--json --latent) on tiny sizes: schema
#      validation PLUS the fused-kernel-vs-einsum and bucketed-vs-per-clique
#      parity gates baked into the validator (the latent-kernel interpret-
#      vs-policy parity itself rides the test_kernels legs of step 2),
#   4c. the structure-learning harness (--json --structure) on tiny sizes:
#      schema validation PLUS the family_counts-vs-einsum score parity and
#      the Chow-Liu / hill-climb recovery gates baked into the validator,
#   4d. the temporal harness (--json --temporal) on tiny sizes: schema
#      validation PLUS the fused-vs-host-loop posterior parity, the fHMM
#      pallas-vs-einsum suff-stats parity and the no-retrace program-cache
#      flag baked into the validator,
#   4e. the serving harness (--json --serve) on short offered-load windows
#      over a forced 4-device host: schema validation PLUS the single-device
#      and mesh-replica drivers, two load points each, and the
#      hot-swap-zero-drop gate baked into the validator,
#   4f. the resilience harness (--json --resilience) on tiny sizes: schema
#      validation PLUS the quarantine bit-identity, serve-zero-loss (worker
#      crash + compile failure under load) and bit-identical-resume gates
#      baked into the validator,
#   5. end-to-end junction-tree queries through the public API: a discrete
#      2-variable query AND a strong-junction-tree query on a CLG network
#      with an unobserved continuous INTERNAL node, so both exact-inference
#      pipelines are exercised even under pytest -k filters,
#   6. a structure-recovery smoke: Chow-Liu learns a ground-truth tree from
#      sampled data, recovers it exactly, and the learned network answers a
#      schema-batched query through PGMQueryEngine,
#   7. the observability leg: one fresh process under REPRO_OBS=trace runs a
#      drifting stream_fit plus schema-batched PGMQueryEngine flushes, then
#      validate_obs_events checks the emitted JSONL against the event schema
#      and asserts the run produced ELBO-per-batch metrics, drift events,
#      per-bucket serve latency spans and kernel-dispatch counts; the obs
#      test module also re-runs once with REPRO_OBS=trace ambient so the
#      instrumentation is exercised at a non-default level under pytest,
#   7b. the temporal obs leg: a fresh process fits a dynamic HMM (fused),
#      replays a sequence stream through seq_stream_fit and serves
#      filter/predict queries via PGMQueryEngine mode="temporal", then
#      validate_obs_events asserts temporal_fit, stream_batch and
#      temporal_plan events all made it to the JSONL,
#   7c. the serving obs leg: a fresh process drives AsyncPGMServer through
#      timeout-triggered micro-batch flushes and a mid-stream hot model
#      swap, then validate_obs_events asserts serve_deadline, serve_swap,
#      the per-bucket serve_bucket telemetry and the aggregation-tier
#      slo / serve_health events all validate,
#   7c2. the replica-health demo leg: a fresh 2-replica AsyncPGMServer with
#      an injected slow_flush pinned to replica 0 — the health score must
#      diverge (replica 0 degraded, replica 1 not), dispatch must bias away
#      from the sick replica (strictly fewer buckets flushed by replica 0),
#      no ticket may be lost, and the run's Prometheus snapshot
#      (serve_request_ms histogram + replica_score gauges) and Chrome-trace
#      export must both render; the JSONL is then schema-validated for
#      serve_health + slo,
#   7d. the chaos leg: a fresh process under REPRO_OBS=trace runs the whole
#      fault-injection suite in one go — a NaN-poisoned fused stream replay
#      (held-posterior bit-identity asserted inline), a mid-stream
#      checkpoint + crash-recovery resume (bit-identity asserted inline),
#      and an AsyncPGMServer run through load shedding, one worker crash
#      and one transient plan-compile failure with zero lost tickets —
#      then validate_obs_events asserts the quarantine, checkpoint,
#      serve_shed, serve_retry and serve_worker events all validate.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q "$@"

# Kernel parity: the tier-1 run above already executes tests/test_kernels.py
# under the DEFAULT interpret policy (compiled on TPU runners, interpret on
# CPU); when that default resolves to COMPILED, force interpret mode once
# more so the two paths cannot silently diverge.  On runners whose default
# is already interpret (this CPU container, the GitHub runner) the forced
# leg would be byte-identical to the tier-1 run, so it is skipped.  If the
# tier-1 run was filtered via "$@", re-run the default-policy leg so the
# pair stays complete.
if [ "$#" -gt 0 ]; then
    python -m pytest -x -q tests/test_kernels.py
fi
DEFAULT_INTERPRET="$(python -c 'from repro.kernels import ops; print(int(ops.INTERPRET))')"
if [ "$DEFAULT_INTERPRET" = "0" ]; then
    echo "ci: kernel parity leg (default policy compiles — forcing interpret)"
    REPRO_PALLAS_INTERPRET=1 python -m pytest -x -q tests/test_kernels.py
else
    echo "ci: kernel parity leg skipped (default policy is already interpret)"
fi

BENCH_OUT="$(mktemp -t bench_streaming_smoke.XXXXXX.json)"
DVMP_OUT="$(mktemp -t bench_dvmp_smoke.XXXXXX.json)"
LATENT_OUT="$(mktemp -t bench_latent_smoke.XXXXXX.json)"
STRUCT_OUT="$(mktemp -t bench_structure_smoke.XXXXXX.json)"
TEMPORAL_OUT="$(mktemp -t bench_temporal_smoke.XXXXXX.json)"
SERVE_OUT="$(mktemp -t bench_serve_smoke.XXXXXX.json)"
RESIL_OUT="$(mktemp -t bench_resilience_smoke.XXXXXX.json)"
OBS_OUT="$(mktemp -t obs_events_smoke.XXXXXX.jsonl)"
OBS_TEMPORAL_OUT="$(mktemp -t obs_temporal_smoke.XXXXXX.jsonl)"
OBS_SERVE_OUT="$(mktemp -t obs_serve_smoke.XXXXXX.jsonl)"
OBS_HEALTH_OUT="$(mktemp -t obs_health_smoke.XXXXXX.jsonl)"
OBS_CHAOS_OUT="$(mktemp -t obs_chaos_smoke.XXXXXX.jsonl)"
trap 'rm -f "$BENCH_OUT" "$DVMP_OUT" "$LATENT_OUT" "$STRUCT_OUT" "$TEMPORAL_OUT" "$SERVE_OUT" "$RESIL_OUT" "$OBS_OUT" "$OBS_TEMPORAL_OUT" "$OBS_SERVE_OUT" "$OBS_HEALTH_OUT" "$OBS_HEALTH_OUT.trace.json" "$OBS_CHAOS_OUT"' EXIT
python benchmarks/run.py --json --n 1000 --batch 250 --sweeps 2 \
    --window 2 --out "$BENCH_OUT"
python - "$BENCH_OUT" <<'EOF'
import json, sys
sys.path.insert(0, "benchmarks")
from run import validate_bench_streaming

with open(sys.argv[1]) as fh:
    payload = json.load(fh)
validate_bench_streaming(payload)
print("ci smoke: BENCH_streaming schema OK "
      f"(speedup {payload['speedup_inst_per_s']:.2f}x)")
EOF
python scripts/bench_compare.py --bench streaming \
    --fresh "$BENCH_OUT" --baseline BENCH_streaming.json

XLA_FLAGS="--xla_force_host_platform_device_count=4${XLA_FLAGS:+ $XLA_FLAGS}" \
python benchmarks/run.py --json --dvmp --n 2000 --sweeps 3 --out "$DVMP_OUT"
python - "$DVMP_OUT" <<'EOF'
import json, sys
sys.path.insert(0, "benchmarks")
from run import validate_bench_dvmp

with open(sys.argv[1]) as fh:
    payload = json.load(fh)
validate_bench_dvmp(payload)
print("ci smoke: BENCH_dvmp schema OK (mesh "
      f"{payload['config']['mesh_shape']}, posterior diff "
      f"{payload['posterior_max_abs_diff']:.2e})")
EOF

python benchmarks/run.py --json --latent --latent-n 512 --depth 6 \
    --out "$LATENT_OUT"
python - "$LATENT_OUT" <<'EOF'
import json, sys
sys.path.insert(0, "benchmarks")
from run import validate_bench_latent

with open(sys.argv[1]) as fh:
    payload = json.load(fh)
validate_bench_latent(payload)
print("ci smoke: BENCH_latent schema OK (kernel rel diff "
      f"{payload['latent_backend_max_rel_diff']:.2e}, strong-JT bucketed "
      f"{payload['jt_bucketed_speedup']:.2f}x, "
      f"diff {payload['jt_posterior_max_abs_diff']:.2e})")
EOF

python benchmarks/run.py --json --structure --structure-n 3000 \
    --structure-vars 6 --out "$STRUCT_OUT"
python - "$STRUCT_OUT" <<'EOF'
import json, sys
sys.path.insert(0, "benchmarks")
from run import validate_bench_structure

with open(sys.argv[1]) as fh:
    payload = json.load(fh)
validate_bench_structure(payload)
print("ci smoke: BENCH_structure schema OK (score diff "
      f"{payload['family_score_max_abs_diff']:.2e}, chowliu F1 "
      f"{payload['chowliu_edge_f1']:.2f}, hillclimb F1 "
      f"{payload['hillclimb_skeleton_f1']:.2f})")
EOF

python benchmarks/run.py --json --temporal --temporal-b 16 --temporal-t 8 \
    --sweeps 2 --out "$TEMPORAL_OUT"
python - "$TEMPORAL_OUT" <<'EOF'
import json, sys
sys.path.insert(0, "benchmarks")
from run import validate_bench_temporal

with open(sys.argv[1]) as fh:
    payload = json.load(fh)
validate_bench_temporal(payload)
print("ci smoke: BENCH_temporal schema OK (fused "
      f"{payload['speedup_seq_per_s']:.2f}x, posterior diff "
      f"{payload['fused_posterior_max_abs_diff']:.2e}, "
      f"retrace_free={payload['retrace_free']})")
EOF

XLA_FLAGS="--xla_force_host_platform_device_count=4${XLA_FLAGS:+ $XLA_FLAGS}" \
python benchmarks/run.py --json --serve --serve-duration 1.0 \
    --serve-loads 100 200 --out "$SERVE_OUT"
python - "$SERVE_OUT" <<'EOF'
import json, sys
sys.path.insert(0, "benchmarks")
from run import validate_bench_serve

with open(sys.argv[1]) as fh:
    payload = json.load(fh)
validate_bench_serve(payload)
single = [r for r in payload["results"] if r["driver"] == "serve_single"][0]
print("ci smoke: BENCH_serve schema OK "
      f"({single['achieved_qps']:.0f} q/s, p99 {single['p99_ms']:.1f}ms, "
      f"hit rate {payload['plan_cache_hit_rate']:.2f}, "
      f"zero_drop={payload['hot_swap_zero_drop']})")
EOF
python scripts/bench_compare.py --bench serve \
    --fresh "$SERVE_OUT" --baseline BENCH_serve.json

python benchmarks/run.py --json --resilience --n 4000 --batch 500 \
    --sweeps 2 --serve-duration 1.0 --out "$RESIL_OUT"
python - "$RESIL_OUT" <<'EOF'
import json, sys
sys.path.insert(0, "benchmarks")
from run import validate_bench_resilience

with open(sys.argv[1]) as fh:
    payload = json.load(fh)
validate_bench_resilience(payload)
s, f = payload["streaming"], payload["serving"]["faulted"]
print("ci smoke: BENCH_resilience schema OK "
      f"({s['quarantined']}/{s['n_batches']} batches quarantined, faulted "
      f"serve {f['achieved_qps']:.0f} q/s with {f['worker_restarts']} "
      f"restart(s), zero_loss={payload['serve_zero_loss']}, "
      f"resume_bit_identical={payload['resume_bit_identical']})")
EOF
python scripts/bench_compare.py --bench resilience \
    --fresh "$RESIL_OUT" --baseline BENCH_resilience.json

python - <<'EOF'
import jax.numpy as jnp
from repro.core.dag import BayesianNetwork, DAG, MultinomialCPD, Variables
from repro.infer_exact import JunctionTreeEngine

vs = Variables()
a = vs.new_multinomial("A", 2)
b = vs.new_multinomial("B", 2)
dag = DAG(vs)
dag.add_parent(b, a)
bn = BayesianNetwork(dag, {
    "A": MultinomialCPD(jnp.array([0.6, 0.4])),
    "B": MultinomialCPD(jnp.array([[0.9, 0.1], [0.2, 0.8]])),
})
eng = JunctionTreeEngine(bn)
eng.set_evidence({"B": 1})
eng.run_inference()
post = eng.posterior_discrete(a)
expect = jnp.array([0.6 * 0.1, 0.4 * 0.8])
expect = expect / expect.sum()
assert jnp.allclose(post, expect, atol=1e-6), (post, expect)
print(f"ci smoke: P(A | B=1) = {post} OK")
EOF

python - <<'EOF'
import numpy as np
import jax.numpy as jnp
from repro.core.dag import (BayesianNetwork, CLGCPD, DAG, MultinomialCPD,
                            Variables)
from repro.infer_exact import (JunctionTreeEngine, brute_posterior,
                               brute_posterior_mean_var)

# strong junction tree: Z -> X1 -> X2 -> X3 with X2 an unobserved
# continuous INTERNAL node (evidence on X1 and X3 only)
vs = Variables()
Z = vs.new_multinomial("Z", 2)
X1, X2, X3 = (vs.new_gaussian(n) for n in ("X1", "X2", "X3"))
dag = DAG(vs)
dag.add_parent(X1, Z)
dag.add_parent(X2, X1)
dag.add_parent(X3, X2)
bn = BayesianNetwork(dag, {
    "Z": MultinomialCPD(jnp.array([0.4, 0.6])),
    "X1": CLGCPD(jnp.array([0.0, 3.0]), jnp.zeros((2, 0)),
                 jnp.array([1.0, 0.5])),
    "X2": CLGCPD(jnp.asarray(1.0), jnp.asarray([0.8]), jnp.asarray(0.7)),
    "X3": CLGCPD(jnp.asarray(-0.5), jnp.asarray([1.2]), jnp.asarray(0.4)),
})
eng = JunctionTreeEngine(bn)
assert eng.strong
ev = {"X1": 0.9, "X3": 0.2}
eng.set_evidence(ev)
eng.run_inference()
pz = np.asarray(eng.posterior_discrete(Z))
assert np.allclose(pz, np.asarray(brute_posterior(bn, Z, ev)), atol=1e-5)
m, v = eng.posterior_mean_var(X2)
mb, vb = brute_posterior_mean_var(bn, X2, ev)
assert abs(float(m) - float(mb)) < 1e-5 and abs(float(v) - float(vb)) < 1e-5
print(f"ci smoke: strong JT P(Z | X1, X3) = {pz}, "
      f"E[X2 | e] = {float(m):.4f} OK")
EOF

python - <<'EOF'
import numpy as np
from repro.data import synthetic as syn
from repro.learn_structure import chow_liu, undirected_edges
from repro.serve.engine import PGMQueryEngine

# structure recovery: Chow-Liu must find a ground-truth tree exactly, and
# the learned network must serve schema-batched exact queries
bn = syn.random_discrete_bn(6, card=3, seed=3, tree=True)
stream = syn.bn_stream(bn, 4000, seed=4)
edges, learned = chow_liu(stream, stream.attributes)
true, got = undirected_edges(bn), undirected_edges(edges)
assert got == true, (sorted(map(tuple, true)), sorted(map(tuple, got)))
eng = PGMQueryEngine(learned, mode="exact")
qs = [eng.submit("D0", {"D2": k % 3, "D3": (k + 1) % 3}) for k in range(4)]
eng.flush()
for q in qs:
    assert q.done and abs(float(q.result.sum()) - 1.0) < 1e-5
print(f"ci smoke: Chow-Liu recovered the tree exactly "
      f"({len(edges)} edges), learned BN served {len(qs)} exact queries OK")
EOF

# obs leg: a FRESH process (kernel-dispatch counters fire at host-dispatch /
# trace time, so the run must own its jit caches) emits the full telemetry
# surface in one go, then the JSONL is schema-validated.
REPRO_OBS=trace REPRO_OBS_PATH="$OBS_OUT" python - <<'EOF'
import jax
import jax.numpy as jnp
from repro.core import streaming, vmp
from repro.core.dag import PlateSpec
from repro.data import synthetic as syn
from repro.serve.engine import PGMQueryEngine

# drifting stream -> stream_batch + drift events + kernel_dispatch snapshot
stream, _ = syn.drift_stream(1000, 3, seed=8)
cp = vmp.compile_plate(PlateSpec(n_features=3, latent_card=1))
prior = vmp.default_prior(cp)
init = vmp.symmetry_broken(prior, jax.random.PRNGKey(0))
batches = list(stream.batches(250))
state = streaming.stream_init(prior, init)
state, info = streaming.stream_fit(
    cp, prior, state,
    jnp.stack([b.xc for b in batches]), jnp.stack([b.xd for b in batches]),
    jnp.stack([b.mask for b in batches]), drift_threshold=3.0)
assert bool(info["drifted"].any()), "drift stream produced no drift event"

# schema-batched serving -> serve spans, bucket events, jt_plan
bn = syn.random_discrete_bn(5, card=3, seed=0, tree=True)
eng = PGMQueryEngine(bn, mode="exact")
for k in range(3):
    eng.submit("D0", {"D3": k % 3, "D4": (k + 1) % 3})
eng.submit("D0", {"D4": 1})
eng.flush()
for k in range(3):
    eng.submit("D0", {"D3": (k + 1) % 3, "D4": k % 3})   # cached schema
eng.flush()
EOF
python - "$OBS_OUT" <<'EOF'
import sys
from repro.obs import validate_obs_events

counts = validate_obs_events(sys.argv[1])
need = ("stream_batch", "drift", "span", "serve_flush", "serve_bucket",
        "jt_plan", "kernel_dispatch")
missing = [ev for ev in need if not counts.get(ev)]
assert not missing, f"obs leg missing event types: {missing} (got {counts})"
print(f"ci smoke: obs JSONL schema OK ({sum(counts.values())} events: "
      + ", ".join(f"{k}={counts[k]}" for k in sorted(counts)) + ")")
EOF

# temporal obs leg: fused dynamic-BN fit + sequence-batch streaming +
# temporal serving in a fresh process, validated against the event schema.
REPRO_OBS=basic REPRO_OBS_PATH="$OBS_TEMPORAL_OUT" python - <<'EOF'
import numpy as np
from repro.data import synthetic as syn
from repro.pgm_models import HiddenMarkovModel, seq_stream_fit
from repro.serve.engine import PGMQueryEngine

batches, attrs, switch_at = syn.hmm_stream(
    n_batches=4, s=16, t=10, states=2, f=2, shift=8.0, seed=0)
m = HiddenMarkovModel(attrs, n_states=2, seed=0)
m.update_model(batches[0], sweeps=3)              # temporal_fit event
info = seq_stream_fit(m, batches, sweeps=3, tol=0.0)   # stream_batch events
assert m.n_drifts >= 1, "temporal stream produced no drift event"
eng = PGMQueryEngine(m, mode="temporal")          # temporal_plan events
xc = np.asarray(batches[0].xc)
qs = [eng.submit("filter", {}, payload=xc[i]) for i in range(3)]
qs.append(eng.submit("predict", {"horizon": 2}, payload=xc[3]))
eng.flush()
assert all(q.done and np.isfinite(np.asarray(q.result)).all() for q in qs)
EOF
python - "$OBS_TEMPORAL_OUT" <<'EOF'
import sys
from repro.obs import validate_obs_events

counts = validate_obs_events(sys.argv[1])
need = ("temporal_fit", "stream_batch", "drift", "temporal_plan",
        "serve_bucket")
missing = [ev for ev in need if not counts.get(ev)]
assert not missing, f"temporal obs leg missing: {missing} (got {counts})"
print(f"ci smoke: temporal obs JSONL schema OK ("
      + ", ".join(f"{k}={counts[k]}" for k in sorted(counts)) + ")")
EOF

# serving obs leg: async micro-batching (timeout-triggered flushes) plus a
# mid-stream hot model swap in a fresh process; the swap and every flush
# decision must land in the JSONL and validate against the event schema.
REPRO_OBS=basic REPRO_OBS_PATH="$OBS_SERVE_OUT" python - <<'EOF'
import numpy as np
from repro.data import synthetic as syn
from repro.serve.queue import AsyncPGMServer

bn = syn.random_discrete_bn(5, card=2, max_parents=2, seed=0)
bn2 = syn.random_discrete_bn(5, card=2, max_parents=2, seed=1)
names = [v.name for v in bn.order]
server = AsyncPGMServer(bn, mode="exact", max_batch=64, max_delay_ms=20,
                        default_deadline_ms=60_000)
tickets = [server.submit(names[-1], {names[0]: float(k % 2)})
           for k in range(3)]
[t.result(timeout=120) for t in tickets]          # serve_deadline (timeout)
info = server.swap_model(bn2)                     # serve_swap
assert info["new_version"] == 1 and info["warmed_plans"] >= 1, info
tickets = [server.submit(names[-1], {names[0]: float(k % 2)})
           for k in range(3)]
out = [t.result(timeout=120) for t in tickets]    # served by the new network
server.stop()
assert server.stats()["pending"] == 0, server.stats()
assert all(np.isfinite(np.asarray(r)).all() for r in out)
EOF
python - "$OBS_SERVE_OUT" <<'EOF'
import sys
from repro.obs import validate_obs_events

counts = validate_obs_events(sys.argv[1])
need = ("serve_deadline", "serve_swap", "serve_bucket", "serve_flush",
        "slo", "serve_health")
missing = [ev for ev in need if not counts.get(ev)]
assert not missing, f"serve obs leg missing: {missing} (got {counts})"
print(f"ci smoke: serve obs JSONL schema OK ("
      + ", ".join(f"{k}={counts[k]}" for k in sorted(counts)) + ")")
EOF

# replica-health demo leg: one replica of a 2-replica server gets an
# injected slow_flush; the health score must diverge, dispatch must shift
# to the healthy replica, no ticket may be lost, and the run's Prometheus
# snapshot + Chrome-trace export must both render.
REPRO_OBS=trace REPRO_OBS_PATH="$OBS_HEALTH_OUT" python - <<'EOF'
import json
import os
import time

from repro.data import synthetic as syn
from repro.obs import default_prometheus_text, write_chrome_trace
from repro.resilience import FaultInjector
from repro.serve.queue import AsyncPGMServer

bn = syn.random_discrete_bn(5, card=2, max_parents=2, seed=0)
names = [v.name for v in bn.order]


def q(i=0):
    return names[-1], {names[0]: float(i % 2)}


srv = AsyncPGMServer(bn, mode="exact", max_batch=8, max_delay_ms=5,
                     default_deadline_ms=60_000, replicas=2,
                     supervise_interval_ms=5)
srv.submit(*q()).result(timeout=120)                  # warm the plan
FaultInjector(seed=0).slow_flush(srv, delay_s=0.08, n=1000, widx=0)
tickets = []
deadline = time.monotonic() + 30.0
i = 0
while time.monotonic() < deadline:                    # degrade replica 0
    tickets.append(srv.submit(*q(i)))
    i += 1
    time.sleep(0.006)
    if srv.health.snapshots()[0]["degraded"]:
        break
assert srv.health.snapshots()[0]["degraded"], \
    "slow replica never marked degraded"
for j in range(30):                                   # biased dispatch phase
    tickets.append(srv.submit(*q(j)))
    time.sleep(0.006)
h = srv.health.snapshots()   # before stop(): the drain disables deferral
srv.stop()
st = srv.stats()
assert st["pending"] == 0, st                         # zero lost tickets
assert all(t.done() and t.error is None for t in tickets)
assert h[0]["degraded"] and not h[1]["degraded"], h
assert h[0]["score"] < 0.5 * h[1]["score"], h
assert h[0]["flushes"] < h[1]["flushes"], h           # dispatch shifted away

prom = default_prometheus_text()
assert "serve_request_ms_bucket" in prom and "replica_score" in prom
jsonl = os.environ["REPRO_OBS_PATH"]
write_chrome_trace(jsonl, jsonl + ".trace.json")
with open(jsonl + ".trace.json") as fh:
    events = json.load(fh)["traceEvents"]
assert any(e["ph"] == "X" for e in events), "trace has no complete spans"
print(f"ci health demo: replica 0 score {h[0]['score']:.3f} "
      f"({h[0]['flushes']} flushes) vs replica 1 score {h[1]['score']:.3f} "
      f"({h[1]['flushes']} flushes), {len(tickets)} tickets all served, "
      f"prometheus {len(prom.splitlines())} lines, "
      f"chrome trace {len(events)} events")
EOF
python - "$OBS_HEALTH_OUT" <<'EOF'
import sys
from repro.obs import validate_obs_events

counts = validate_obs_events(sys.argv[1])
need = ("serve_health", "slo", "span")
missing = [ev for ev in need if not counts.get(ev)]
assert not missing, f"health demo leg missing: {missing} (got {counts})"
print(f"ci smoke: health obs JSONL schema OK ("
      + ", ".join(f"{k}={counts[k]}" for k in sorted(counts)) + ")")
EOF

# chaos leg: the fault-injection suite end to end in one fresh process —
# NaN quarantine (bit-identical to a never-poisoned replay), checkpoint +
# crash-recovery resume (bit-identical to the uninterrupted run), and a
# served workload through shedding, a worker crash and a transient compile
# failure with zero accepted tickets lost.
REPRO_OBS=trace REPRO_OBS_PATH="$OBS_CHAOS_OUT" python - <<'EOF'
import tempfile

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import streaming, vmp
from repro.core.dag import PlateSpec
from repro.data import synthetic as syn
from repro.resilience import (CheckpointManager, FaultInjector, ShedError,
                              resume_stream_fit)
from repro.serve.plan import PlanCache
from repro.serve.queue import AsyncPGMServer


def eq(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(la, lb))


# NaN quarantine: poisoned replay == replay that never saw those batches
stream, _, _ = syn.gmm_stream(2000, 2, 3, seed=0)
cp = vmp.compile_plate(PlateSpec(n_features=3, latent_card=2))
prior = vmp.default_prior(cp)
init = vmp.symmetry_broken(prior, jax.random.PRNGKey(0))
batches = list(stream.batches(250))
xcs = jnp.stack([b.xc for b in batches])
xds = jnp.stack([b.xd for b in batches])
inj = FaultInjector(seed=0)
bad, idx = inj.poison_nan(np.asarray(xcs), rate=0.15)
sp, _ = streaming.stream_fit(cp, prior, streaming.stream_init(prior, init),
                             jnp.asarray(bad), xds)       # quarantine events
keep = np.setdiff1d(np.arange(xcs.shape[0]), idx)
sc, _ = streaming.stream_fit(cp, prior, streaming.stream_init(prior, init),
                             xcs[keep], xds[keep])
assert int(sp.n_quarantined) == len(idx), (sp.n_quarantined, idx)
assert eq(sp.post, sc.post), "quarantined replay diverged"

# checkpoint + crash-recovery resume, bit-identical to the straight run
with tempfile.TemporaryDirectory() as ckdir:
    mgr = CheckpointManager(ckdir, every=0)
    head, _ = streaming.stream_fit(
        cp, prior, streaming.stream_init(prior, init), xcs[:4], xds[:4])
    mgr.save(4, head)                                     # checkpoint event
    resumed, _ = resume_stream_fit(
        cp, prior, streaming.stream_init(prior, init), xcs, xds, manager=mgr)
full, _ = streaming.stream_fit(cp, prior,
                               streaming.stream_init(prior, init), xcs, xds)
assert eq(resumed, full), "mid-stream resume diverged"

# serving chaos: bounded queue sheds, the drain crashes one worker (the
# supervisor respawns it and requeues the bucket) and the plan compile
# fails once transiently (retried) — every accepted ticket still resolves
bn = syn.random_discrete_bn(5, card=2, max_parents=2, seed=0)
names = [v.name for v in bn.order]
cache = PlanCache(compile_retries=2, retry_backoff_s=0.01)
inj.fail_compiles(cache, n=1)                             # serve_retry
srv = AsyncPGMServer(bn, mode="exact", max_batch=16, max_delay_ms=10_000,
                     default_deadline_ms=60_000, max_queue=2,
                     plan_cache=cache, supervise_interval_ms=5)
inj.crash_worker(srv)                                     # serve_worker
kept = [srv.submit(names[-1], {names[0]: float(k % 2)}) for k in range(2)]
shed = srv.submit(names[-1], {names[0]: 0.0})             # serve_shed
try:
    shed.result()
    raise SystemExit("over-max_queue submit was not shed")
except ShedError:
    pass
srv.stop()
st = srv.stats()
assert st["pending"] == 0, st                             # zero lost tickets
assert st["worker_restarts"] >= 1 and st["shed"] == 1, st
assert st["plans"]["retries"] >= 1, st
for t in kept:
    assert np.isfinite(np.asarray(t.result())).all()
print("ci chaos: quarantine bit-identical, resume bit-identical, "
      f"{st['worker_restarts']} worker restart(s), {st['shed']} shed, "
      f"{st['plans']['retries']} compile retry(s), zero lost tickets")
EOF
python - "$OBS_CHAOS_OUT" <<'EOF'
import sys
from repro.obs import validate_obs_events

counts = validate_obs_events(sys.argv[1])
need = ("quarantine", "checkpoint", "serve_shed", "serve_retry",
        "serve_worker")
missing = [ev for ev in need if not counts.get(ev)]
assert not missing, f"chaos obs leg missing: {missing} (got {counts})"
print(f"ci smoke: chaos obs JSONL schema OK ("
      + ", ".join(f"{k}={counts[k]}" for k in sorted(counts)) + ")")
EOF

echo "ci: obs-enabled pytest leg (REPRO_OBS=trace)"
OBS_PYTEST_OUT="$(mktemp -t obs_pytest.XXXXXX.jsonl)"
REPRO_OBS=trace REPRO_OBS_PATH="$OBS_PYTEST_OUT" \
    python -m pytest -x -q tests/test_obs.py
rm -f "$OBS_PYTEST_OUT"
