#!/usr/bin/env bash
# Tier-1 smoke runner.  Three gates:
#   1. the full pytest suite with -x (any collection error — e.g. a jax
#      import that moved between versions — fails fast instead of landing),
#   2. an end-to-end 2-variable junction-tree query through the public API,
#      so the exact-inference path is exercised even under pytest -k filters,
#   3. the streaming perf harness in --json mode on tiny sizes with schema
#      validation, so perf-trajectory breakage (BENCH_streaming.json) fails
#      tier-1 instead of silently rotting.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q "$@"

BENCH_OUT="$(mktemp -t bench_streaming_smoke.XXXXXX.json)"
trap 'rm -f "$BENCH_OUT"' EXIT
python benchmarks/run.py --json --n 1000 --batch 250 --sweeps 2 \
    --out "$BENCH_OUT"
python - "$BENCH_OUT" <<'EOF'
import json, sys
sys.path.insert(0, "benchmarks")
from run import validate_bench_streaming

with open(sys.argv[1]) as fh:
    payload = json.load(fh)
validate_bench_streaming(payload)
print("ci smoke: BENCH_streaming schema OK "
      f"(speedup {payload['speedup_inst_per_s']:.2f}x)")
EOF

python - <<'EOF'
import jax.numpy as jnp
from repro.core.dag import BayesianNetwork, DAG, MultinomialCPD, Variables
from repro.infer_exact import JunctionTreeEngine

vs = Variables()
a = vs.new_multinomial("A", 2)
b = vs.new_multinomial("B", 2)
dag = DAG(vs)
dag.add_parent(b, a)
bn = BayesianNetwork(dag, {
    "A": MultinomialCPD(jnp.array([0.6, 0.4])),
    "B": MultinomialCPD(jnp.array([[0.9, 0.1], [0.2, 0.8]])),
})
eng = JunctionTreeEngine(bn)
eng.set_evidence({"B": 1})
eng.run_inference()
post = eng.posterior_discrete(a)
expect = jnp.array([0.6 * 0.1, 0.4 * 0.8])
expect = expect / expect.sum()
assert jnp.allclose(post, expect, atol=1e-6), (post, expect)
print(f"ci smoke: P(A | B=1) = {post} OK")
EOF
