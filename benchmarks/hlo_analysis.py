"""Trip-count-aware cost analysis of post-SPMD HLO text.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE — but this
framework scans over layers (and over KV blocks), so the body runs L times.
This module parses the HLO text, builds the computation call graph (fusion
calls, while bodies with their ``known_trip_count`` backend config,
conditionals), and walks it from ENTRY accumulating:

  * flops               dot ops: 2 * prod(result dims) * prod(contracted)
  * hbm bytes           top-level op operand+result bytes via the def-use
                        map (fusion internals add flops only — a fusion
                        reads its operands and writes its result once)
  * collective bytes    result-shape bytes by collective kind

Everything is PER DEVICE (the input is the partitioned module text).
"""

from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "c64": 8, "f32": 4, "s32": 4,
                "u32": 4, "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1, "token": 0}

_SHAPE_RE = re.compile(
    r"\b(f64|s64|u64|c64|f32|s32|u32|bf16|f16|s16|u16|s8|u8|pred|token)"
    r"\[([0-9,]*)\]")

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->")
_RESULT = re.compile(r"^(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLEE = re.compile(r"(?:calls=|to_apply=|body=|condition=)%?([\w\.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_LHS_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERANDS = re.compile(r"%([\w\.\-]+)")
_OPCODE = re.compile(r"\b([a-z][a-z0-9\-]*)\(")

_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "after-all", "partition-id", "iota",
               # control ops: data movement is accounted by the ops inside
               # their bodies / consuming their elements
               "while", "conditional", "call", "optimization-barrier"}


def _dims_of(seg: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_RE.search(seg)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


def _bytes_of(sig: str) -> int:
    """Total bytes of ALL shape literals in a signature segment (tuples)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(sig):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


class Comp:
    def __init__(self, name: str, header: str):
        self.name = name
        self.header = header
        self.lines: List[str] = []
        self.is_fusion_body = False


def _split(text: str) -> Tuple[Dict[str, Comp], Optional[str]]:
    comps: Dict[str, Comp] = {}
    cur: Optional[Comp] = None
    entry = None
    for raw in text.splitlines():
        line = raw.strip()
        m = _COMP_HDR.match(line)
        if m and line.endswith("{"):
            cur = Comp(m.group(2), m.group(3))
            comps[cur.name] = cur
            if m.group(1):
                entry = cur.name
            continue
        if line == "}":
            cur = None
            continue
        if cur is not None and line:
            cur.lines.append(line)
    return comps, entry


def analyze(text: str, top_k: int = 0) -> Dict[str, float]:
    """top_k > 0: also return 'top_bytes'/'top_flops' contributor lists."""
    comps, entry = _split(text)
    if entry is None:
        raise ValueError("no ENTRY computation")

    # ---- pass 1: def-use map (name -> result-signature bytes / dims) -------
    defs_bytes: Dict[str, int] = {}
    defs_dims: Dict[str, List[int]] = {}
    for comp in comps.values():
        # parameters declared in the header: "p: f32[..], q: (f32[..],..)"
        for pm in re.finditer(r"([\w\.\-]+)\s*:\s*", comp.header):
            pass  # shapes resolved from 'parameter' result lines below
        for line in comp.lines:
            m = _RESULT.match(line)
            if not m:
                continue
            name, rhs = m.group(1), m.group(2)
            head = rhs.split("(", 1)[0] if "(" in rhs else rhs
            defs_bytes[name] = _bytes_of(head)
            dd = _dims_of(head)
            if dd:
                defs_dims[name] = dd[1]

    # ---- pass 1b: fusion-body per-parameter read sizes ----------------------
    # a fused dynamic-slice (scan-over-layers weight access) reads only the
    # slice, not the whole stacked [L, ...] operand — resolve per parameter.
    fusion_param_reads: Dict[str, Dict[int, int]] = {}
    for comp in comps.values():
        preads: Dict[int, int] = {}
        pnames: Dict[str, int] = {}
        for line in comp.lines:
            m = _RESULT.match(line)
            if not m:
                continue
            name, rhs = m.group(1), m.group(2)
            pm = re.search(r"parameter\((\d+)\)", rhs)
            if pm:
                pnames[name] = int(pm.group(1))
                preads[int(pm.group(1))] = _bytes_of(rhs.split("(", 1)[0])
        for pname, pidx in pnames.items():
            consumers = []
            for line in comp.lines:
                m = _RESULT.match(line)
                if not m or m.group(1) == pname:
                    continue
                if re.search(r"%" + re.escape(pname) + r"\b", m.group(2)):
                    opm = _OPCODE.search(m.group(2))
                    consumers.append(
                        (opm.group(1) if opm else "",
                         _bytes_of(m.group(2).split("(", 1)[0])))
            if consumers and all(op == "dynamic-slice" for op, _ in consumers):
                preads[pidx] = sum(bb for _, bb in consumers)
        fusion_param_reads[comp.name] = preads

    # ---- pass 2: per-computation costs + call edges -------------------------
    flops: Dict[str, float] = {}
    bts: Dict[str, float] = {}
    coll: Dict[str, Dict[str, float]] = {}
    edges: Dict[str, List[Tuple[str, float]]] = {}

    bmin: Dict[str, float] = {}

    contrib: Dict[str, List] = {}

    for comp in comps.values():
        f = b = b_min = 0.0
        c = {k: 0.0 for k in _COLL_KINDS}
        ed: List[Tuple[str, float]] = []
        items: List = []
        for line in comp.lines:
            m = _RESULT.match(line)
            if not m:
                continue
            name, rhs = m.group(1), m.group(2)
            paren = rhs.find("(")
            head = rhs[:paren] if paren >= 0 else rhs
            opm = _OPCODE.search(rhs)
            op = opm.group(1) if opm else ""
            args_seg = rhs[paren:rhs.find(")") + 1] if paren >= 0 else ""
            operand_names = _OPERANDS.findall(args_seg)

            if op == "dot":
                out = _dims_of(head)
                mc = _LHS_CONTRACT.search(rhs)
                k = 1
                if mc and operand_names:
                    lhs_dims = defs_dims.get(operand_names[0], [])
                    for idx in mc.group(1).split(","):
                        if idx and int(idx) < len(lhs_dims):
                            k *= lhs_dims[int(idx)]
                n_out = 1
                if out:
                    for d in out[1]:
                        n_out *= d
                f += 2.0 * n_out * k

            for kind in _COLL_KINDS:
                if op == kind or op == kind + "-start":
                    cb = _bytes_of(head)
                    # XLA CPU float-normalization promotes bf16 collectives
                    # to f32 ("to_apply=%add...promoted"); on the TPU target
                    # the payload is bf16 — count the true width.
                    if "promoted" in rhs and "f32[" in head:
                        cb //= 2
                    c[kind] += cb

            if op not in _SKIP_BYTES:
                if op in ("dynamic-slice", "gather"):
                    b += 2 * _bytes_of(head)        # read slice + write
                    b_min += 2 * _bytes_of(head)
                elif op in ("dynamic-update-slice", "scatter"):
                    upd = (defs_bytes.get(operand_names[1], 0)
                           if len(operand_names) > 1 else 0)
                    b += 3 * upd                    # read slice+upd, write
                    b_min += 3 * upd
                elif op == "fusion":
                    b += _bytes_of(head)
                    callee = _CALLEE.findall(rhs)
                    preads = fusion_param_reads.get(
                        callee[0] if callee else "", {})
                    for j, nm in enumerate(operand_names):
                        full = defs_bytes.get(nm, 0)
                        b += min(full, preads.get(j, full)) \
                            if j in preads else full
                else:
                    b += _bytes_of(head)  # result write
                    b += sum(defs_bytes.get(nm, 0) for nm in operand_names)
                # lower bound (perfect-fusion model): only matmul, conv and
                # collective payload traffic touches HBM
                if op in ("dot", "convolution"):
                    db = _bytes_of(head) + sum(defs_bytes.get(nm, 0)
                                               for nm in operand_names)
                    b_min += db
                    items.append((db, op, name, head.strip()[:48]))
                elif any(op == k or op == k + "-start"
                         for k in _COLL_KINDS):
                    b_min += 2 * _bytes_of(head)
                    items.append((2 * _bytes_of(head), op, name,
                                  head.strip()[:48]))
                elif op in ("dynamic-slice", "gather"):
                    items.append((2 * _bytes_of(head), op, name,
                                  head.strip()[:48]))

            # call edges
            trip = 1.0
            mt = _TRIP.search(rhs)
            if mt:
                trip = float(mt.group(1))
            if op == "while":
                for nm in _CALLEE.findall(rhs):
                    ed.append((nm, trip))
            else:
                for nm in _CALLEE.findall(rhs):
                    ed.append((nm, 1.0))
                    if op == "fusion" and nm in comps:
                        comps[nm].is_fusion_body = True
            mb = _BRANCHES.search(rhs)
            if mb:
                for nm in mb.group(1).split(","):
                    ed.append((nm.strip().lstrip("%"), 1.0))
        flops[comp.name] = f
        bts[comp.name] = b
        bmin[comp.name] = b_min
        coll[comp.name] = c
        edges[comp.name] = ed
        contrib[comp.name] = items

    # fusion internals: flops count, bytes don't (operands/result already
    # accounted at the fusion call site) — except b_min keeps fused dots
    for comp in comps.values():
        if comp.is_fusion_body:
            bts[comp.name] = 0.0

    memo: Dict[str, tuple] = {}

    def total(name: str, depth=0):
        if name in memo:
            return memo[name]
        if name not in comps or depth > 64:
            return 0.0, 0.0, 0.0, {k: 0.0 for k in _COLL_KINDS}
        f, b, bm = flops[name], bts[name], bmin[name]
        c = dict(coll[name])
        for callee, w in edges[name]:
            cf, cb, cbm, cc = total(callee, depth + 1)
            f += w * cf
            b += w * cb
            bm += w * cbm
            for k in _COLL_KINDS:
                c[k] += w * cc[k]
        memo[name] = (f, b, bm, c)
        return memo[name]

    f, b, bm, c = total(entry)
    out = {"flops": f, "hbm_bytes": b, "hbm_bytes_min": bm,
           "collective_bytes": sum(c.values())}
    for k in _COLL_KINDS:
        out[f"coll_{k}"] = c[k]

    if top_k:
        # weight each computation's contributors by its total multiplicity
        mult: Dict[str, float] = {entry: 1.0}

        def walk(name, w, depth=0):
            if depth > 64 or name not in comps:
                return
            for callee, ew in edges.get(name, []):
                mult[callee] = mult.get(callee, 0.0) + w * ew
                walk(callee, w * ew, depth + 1)

        walk(entry, 1.0)
        flat = []
        for cname, items in contrib.items():
            w = mult.get(cname, 0.0)
            if cname == entry:
                w = 1.0
            for db, op, nm, sig in items:
                flat.append((db * w, op, cname, nm, sig))
        flat.sort(reverse=True)
        out["top_bytes"] = flat[:top_k]
    return out
