"""Benchmark harness — one function per paper claim (DESIGN.md §7.5).

Prints ``name,us_per_call,derived`` CSV rows.  The paper is a toolbox paper
without numeric tables; the benchmarks instantiate its CLAIMS:

  (i)    parallel VMP scales with batched instances (multi-core -> vmap)
  (iii)  streaming VB is constant-memory and tracks the batch posterior
  (iv)   drift detection flags synthetic concept drift
  (v)    model zoo recovers ground truth (Table 2)
  (vi)   parallel importance sampling throughput + ESS
  (vii)  kernels (interpret mode — correctness-grade timing only)
  (viii) end-to-end LM training throughput (reduced configs)
  (ix)   exact (junction tree) vs approximate (IS, VMP) posterior accuracy
         and throughput — the paper's HUGIN link, replaced natively

(d-VMP shard invariance — claim (ii) — is exercised in
tests/test_distributed.py and at 256/512-chip scale by the dry-run.)
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from functools import partial

import numpy as np

# Emitted by --json mode; every PR appends a measured before/after point so
# the perf trajectory of ROADMAP's "as fast as the hardware allows" is a
# recorded artifact, not a claim.
BENCH_STREAMING_SCHEMA = {
    "bench": str, "schema_version": int, "created": str, "backend": str,
    "config": dict, "results": list, "speedup_inst_per_s": float,
}

# --json --dvmp mode: the distributed mesh path (shard_map + psum) vs the
# single-device fit on identical data — the d-VMP claim (ii) as a JSON
# artifact (ROADMAP open item "a JSON mode for the d-VMP mesh path").
BENCH_DVMP_SCHEMA = {
    "bench": str, "schema_version": int, "created": str, "backend": str,
    "config": dict, "results": list, "speedup_inst_per_s": float,
    "posterior_max_abs_diff": float,
}

# --json --latent mode: the latent-plate (FA/PPCA) E-step einsum vs the fused
# component-major Pallas kernel, plus strong-junction-tree query throughput
# with and without shape-bucketed clique propagation.
BENCH_LATENT_SCHEMA = {
    "bench": str, "schema_version": int, "created": str,
    "config": dict, "results": list,
    "latent_backend_max_rel_diff": float,
    "jt_posterior_max_abs_diff": float,
    "jt_bucketed_speedup": float,
}

# --json --structure mode: the structure-learning workload — batched family
# scoring throughput (family_counts kernel vs einsum), Chow-Liu edge
# recovery and hill-climbing wall-clock/skeleton-F1 on ground-truth
# synthetic networks.
BENCH_STRUCTURE_SCHEMA = {
    "bench": str, "schema_version": int, "created": str,
    "config": dict, "results": list,
    "family_score_max_abs_diff": float,
    "chowliu_edge_f1": float,
    "hillclimb_skeleton_f1": float,
}


# --json --temporal mode: the fused temporal hot path — the whole-fit
# lax.scan (dynamic HMM family) vs the seed-style host sweep loop at
# B=512/T=64, the chain-parallel fHMM suff-stats backends, fused/unfused
# posterior parity and the compiled-program (no-retrace) flag.
BENCH_TEMPORAL_SCHEMA = {
    "bench": str, "schema_version": int, "created": str,
    "config": dict, "results": list,
    "speedup_seq_per_s": float,
    "fused_posterior_max_abs_diff": float,
    "fhmm_backend_max_abs_diff": float,
    "retrace_free": bool,
}


# --json --serve mode: the async serving tier — sustained queries/s and
# request/bucket latency percentiles vs offered load, single-device vs
# mesh-replica, plan-cache hit rate and the hot-swap zero-drop flag.
BENCH_SERVE_SCHEMA = {
    "bench": str, "schema_version": int, "created": str,
    "config": dict, "results": list,
    "plan_cache_hit_rate": float,
    "hot_swap_zero_drop": bool,
}


# --json --resilience mode: the fault-tolerance layer under injected
# faults — streaming throughput with a 1%-NaN-poisoned stream vs clean
# (plus the quarantine bit-identity flag), serving qps/p99 through a
# worker crash + transient compile failure vs clean (plus the zero-loss
# flag), and checkpoint save/restore/recovery timings with the
# bit-identical-resume flag.
BENCH_RESILIENCE_SCHEMA = {
    "bench": str, "schema_version": int, "created": str,
    "config": dict, "streaming": dict, "serving": dict, "checkpoint": dict,
    "quarantine_bit_identical": bool,
    "serve_zero_loss": bool,
    "resume_bit_identical": bool,
}


def _bench_env_config() -> dict:
    """Environment fields stamped into every BENCH_*.json config block so
    the perf trajectory is comparable across jax versions / kernel policies."""
    import jax

    from repro.kernels import clg_stats

    return {
        "device": str(jax.devices()[0]).split(":")[0],
        "jax_version": jax.__version__,
        "pallas_policy": ("interpret" if clg_stats._resolve_interpret(None)
                          else "compiled"),
    }


def _t(fn, *args, reps=3, warmup=1, **kw):
    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6  # us


def bench_vmp_parallel():
    """(i) E-step throughput vs batch size — the parallelStream analog."""
    import jax
    import jax.numpy as jnp

    from repro.core import vmp
    from repro.core.dag import PlateSpec

    spec = PlateSpec(n_features=10, latent_card=4)
    cp = vmp.compile_plate(spec)
    prior = vmp.default_prior(cp)
    post = vmp.symmetry_broken(prior, jax.random.PRNGKey(0))
    step = jax.jit(lambda x, xd, m: vmp.local_step(cp, post, x, xd, m))
    for n in (1_000, 10_000, 100_000):
        x = jax.random.normal(jax.random.PRNGKey(1), (n, 10))
        xd = jnp.zeros((n, 0), jnp.int32)
        us = _t(step, x, xd, jnp.ones(n))
        print(f"vmp_estep_n{n},{us:.0f},{n / us * 1e6:.0f} inst/s")


def bench_streaming():
    """(iii) streaming VB: batches/sec at fixed memory."""
    import jax

    from repro.core import streaming, vmp
    from repro.core.dag import PlateSpec
    from repro.data.synthetic import gmm_stream

    stream, _, _ = gmm_stream(50_000, 3, 8, seed=0)
    spec = PlateSpec(n_features=8, latent_card=3)
    cp = vmp.compile_plate(spec)
    prior = vmp.default_prior(cp)
    ss = streaming.stream_init(
        prior, vmp.symmetry_broken(prior, jax.random.PRNGKey(0)))
    t0 = time.perf_counter()
    nb = 0
    for b in stream.batches(2_000):
        ss, info = streaming.stream_update(cp, prior, ss, b.xc, b.xd,
                                           sweeps=5)
        nb += 1
    dt = time.perf_counter() - t0
    print(f"streaming_vb_batch2000,{dt / nb * 1e6:.0f},"
          f"{50_000 / dt:.0f} inst/s elbo={float(info['elbo']):.1f}")


def register_estimators() -> None:
    """Register the bench-side obs estimators:

    * ``"hlo_cost"`` — the analytical HLO cost model
      (``hlo_analysis.analyze``, dormant since seed); estimates flow back
      into BENCH_* results via :func:`_program_analysis`.
    * ``"achieved_vs_peak"`` — ``roofline.achieved_vs_peak``: measured
      seconds + analytical FLOPs/bytes -> fraction-of-roof and
      compute/memory bound classification (the live half of the ROADMAP
      roofline gate; peaks tunable via ``REPRO_PEAK_*``).

    When obs is enabled every estimate is also a ``bench_estimate``
    JSONL event."""
    from repro import obs

    if not obs.registered("hlo_cost"):
        try:
            import hlo_analysis                  # script mode (sys.path[0])
        except ImportError:
            from benchmarks import hlo_analysis  # repo-root import

        def hlo_cost(hlo_text: str) -> dict:
            a = hlo_analysis.analyze(hlo_text)
            return {"flops": a.get("flops"),
                    "hbm_bytes": a.get("hbm_bytes"),
                    "hbm_bytes_min": a.get("hbm_bytes_min"),
                    "collective_bytes": a.get("collective_bytes")}

        obs.register("hlo_cost", hlo_cost)

    if not obs.registered("achieved_vs_peak"):
        try:
            import roofline                      # script mode (sys.path[0])
        except ImportError:
            from benchmarks import roofline      # repo-root import
        obs.register("achieved_vs_peak", roofline.achieved_vs_peak)


def _achieved_vs_peak_row(analytical, us_per_call: float):
    """achieved-vs-peak stamp for one bench row: analytical FLOP/byte
    counts + the measured per-call time -> fraction-of-roof dict (None
    when the cost model produced nothing to score)."""
    from repro import obs

    if not analytical or not analytical.get("flops"):
        return None
    if not obs.registered("achieved_vs_peak"):
        return None
    return obs.estimate("achieved_vs_peak", seconds=us_per_call / 1e6,
                        flops=analytical["flops"],
                        hbm_bytes=analytical.get("hbm_bytes_min"))


def _program_analysis(lowered):
    """(peak_mem_bytes, analytical) of a lowered program — ONE compile
    shared by the peak-memory proxy and the registered ``hlo_cost``
    analytical FLOP/byte model.  Either half degrades to None if the
    backend exposes no memory analysis / HLO text."""
    from repro import obs

    try:
        compiled = lowered.compile()
    except Exception:
        return None, None
    try:
        ma = compiled.memory_analysis()
        peak = float(ma.temp_size_in_bytes + ma.argument_size_in_bytes
                     + ma.output_size_in_bytes)
    except Exception:
        peak = None
    analytical = None
    try:
        if obs.registered("hlo_cost"):
            analytical = obs.estimate("hlo_cost", compiled.as_text())
    except Exception:
        analytical = None
    return peak, analytical


def _peak_mem_proxy(lowered):
    """Compiled-program peak-memory proxy in bytes (None if the backend
    exposes no memory analysis — e.g. some CPU jaxlibs)."""
    return _program_analysis(lowered)[0]


def bench_streaming_json(n: int = 50_000, batch: int = 2_000,
                         sweeps: int = 5, k: int = 3, f: int = 8,
                         backend: str = None, out: str = "BENCH_streaming.json",
                         window: int = 5) -> dict:
    """(iii, JSON mode) seed per-batch ``stream_update`` loop vs the fused,
    resident ``stream_fit`` scan (whole stream on device) vs the windowed
    scan (host-resident stream, ``window`` batches on device at a time) on
    the benchmark GMM stream.

    Writes ``out`` with inst/s, us/batch, a peak-memory proxy and the
    suff-stats backend for all three drivers — the perf-trajectory artifact
    this and every future PR updates.
    """
    import datetime

    import jax
    import jax.numpy as jnp

    from repro.core import streaming, vmp
    from repro.core.dag import PlateSpec
    from repro.data.synthetic import gmm_stream

    backend = backend or vmp.default_backend()
    spec = PlateSpec(n_features=f, latent_card=k)
    cp = vmp.compile_plate(spec)
    prior = vmp.default_prior(cp)
    init = vmp.symmetry_broken(prior, jax.random.PRNGKey(0))
    stream, _, _ = gmm_stream(n, k, f, seed=0)
    batches = list(stream.batches(batch))
    nb = len(batches)
    window = max(1, min(window, nb))

    def run_loop():
        ss = streaming.stream_init(prior, init)
        for b in batches:
            ss, info = streaming.stream_update(cp, prior, ss, b.xc, b.xd,
                                               sweeps=sweeps, mask=b.mask)
        jax.block_until_ready(ss.post.reg.m)
        return ss

    xcs = jnp.stack([b.xc for b in batches])
    xds = jnp.stack([b.xd for b in batches])
    masks = jnp.stack([b.mask for b in batches])
    # the windowed driver's stream stays host-resident (numpy)
    xcs_h, xds_h, masks_h = (np.asarray(xcs), np.asarray(xds),
                             np.asarray(masks))

    def run_scan():
        ss = streaming.stream_init(prior, init)
        ss, infos = streaming.stream_fit(cp, prior, ss, xcs, xds, masks,
                                         sweeps=sweeps, backend=backend)
        jax.block_until_ready(ss.post.reg.m)
        return ss

    def run_windowed():
        ss = streaming.stream_init(prior, init)
        ss, infos = streaming.stream_fit(cp, prior, ss, xcs_h, xds_h,
                                         masks_h, sweeps=sweeps,
                                         backend=backend, window=window)
        jax.block_until_ready(ss.post.reg.m)
        return ss

    results = []
    finals = {}
    for name, fn in (("stream_update_loop", run_loop),
                     ("stream_fit_scan", run_scan),
                     ("stream_fit_windowed", run_windowed)):
        fn()                          # warm the jit caches
        t0 = time.perf_counter()
        finals[name] = fn()
        dt = time.perf_counter() - t0
        results.append({
            "driver": name,
            "backend": backend if name != "stream_update_loop" else "einsum",
            "n_batches": nb,
            "window": window if name == "stream_fit_windowed" else None,
            "us_per_batch": dt / nb * 1e6,
            "inst_per_s": n / dt,
            "peak_mem_bytes": None,
        })

    # peak-mem proxies + analytical FLOP/byte estimates from the compiled
    # scan programs (one compile each — _program_analysis shares it); the
    # loop driver has no single program — proxy with its per-batch fit
    register_estimators()
    ss0 = streaming.stream_init(prior, init)
    results[1]["peak_mem_bytes"], results[1]["analytical"] = \
        _program_analysis(streaming._stream_fit_scan.lower(
            cp, prior, ss0, xcs, xds, masks, sweeps=sweeps, tol=1e-4,
            drift_threshold=5.0, forget=0.3, backend=backend, chunk=None))
    ss0 = streaming.stream_init(prior, init)
    results[2]["peak_mem_bytes"], results[2]["analytical"] = \
        _program_analysis(streaming._stream_fit_scan.lower(
            cp, prior, ss0, xcs[:window], xds[:window], masks[:window],
            sweeps=sweeps, tol=1e-4, drift_threshold=5.0, forget=0.3,
            backend=backend, chunk=None))
    results[0]["peak_mem_bytes"], results[0]["analytical"] = \
        _program_analysis(
            vmp.vmp_fit.lower(cp, prior, init, batches[0].xc, batches[0].xd,
                              sweeps, 1e-4, batches[0].mask, "einsum", None))

    # same posterior from all drivers (parity is also unit-tested)
    drift = max(float(np.abs(
        np.asarray(finals["stream_update_loop"].post.reg.m)
        - np.asarray(finals[d].post.reg.m)).max())
        for d in ("stream_fit_scan", "stream_fit_windowed"))

    payload = {
        "bench": "streaming",
        "schema_version": 2,
        "created": datetime.datetime.now(datetime.timezone.utc).isoformat(),
        "backend": backend,
        "config": {"n": n, "batch": batch, "sweeps": sweeps,
                   "features": f, "components": k, "window": window,
                   **_bench_env_config()},
        "results": results,
        "speedup_inst_per_s": results[1]["inst_per_s"] / results[0]["inst_per_s"],
        "driver_posterior_max_abs_diff": drift,
    }
    with open(out, "w") as fh:
        json.dump(payload, fh, indent=2)
        fh.write("\n")
    print(f"wrote {out}: stream_fit_scan {payload['speedup_inst_per_s']:.2f}x "
          f"inst/s vs stream_update_loop "
          f"({results[1]['inst_per_s']:.0f} vs {results[0]['inst_per_s']:.0f})")
    return payload


def validate_bench_streaming(payload: dict) -> None:
    """Schema gate used by scripts/ci.sh — raises on any malformed field."""
    for key, typ in BENCH_STREAMING_SCHEMA.items():
        if key not in payload:
            raise ValueError(f"BENCH_streaming.json missing key {key!r}")
        if typ is float and isinstance(payload[key], int):
            continue
        if not isinstance(payload[key], typ):
            raise ValueError(f"{key!r} must be {typ.__name__}, "
                             f"got {type(payload[key]).__name__}")
    drivers = {r["driver"] for r in payload["results"]}
    if drivers != {"stream_update_loop", "stream_fit_scan",
                   "stream_fit_windowed"}:
        raise ValueError(f"unexpected drivers {drivers}")
    for key in ("jax_version", "pallas_policy"):
        if key not in payload["config"]:
            raise ValueError(f"config missing {key!r}")
    for r in payload["results"]:
        for field in ("backend", "n_batches", "window", "us_per_batch",
                      "inst_per_s", "peak_mem_bytes"):
            if field not in r:
                raise ValueError(f"result {r['driver']} missing {field!r}")
        if not r["inst_per_s"] > 0:
            raise ValueError("inst_per_s must be positive")


def bench_dvmp_json(n: int = 50_000, sweeps: int = 5, k: int = 3, f: int = 8,
                    backend: str = None, n_devices: int = 0,
                    out: str = "BENCH_dvmp.json") -> dict:
    """(ii, JSON mode) d-VMP over the device mesh vs single-device VMP.

    Same data, same sweep count; the mesh driver is the `shard_map` body
    with one ``lax.psum`` of the suff-stats pytree per sweep.  Writes
    ``out`` with inst/s, us/fit and the replicated-posterior max-abs-diff
    (shard invariance — must stay at float-reduction-order noise).
    """
    import datetime

    import jax

    from repro.core import dvmp, vmp
    from repro.core.compat import make_mesh
    from repro.core.dag import PlateSpec
    from repro.data.synthetic import gmm_stream

    backend = backend or vmp.default_backend()
    ndev = n_devices or len(jax.devices())
    if n < ndev:
        raise ValueError(f"--n {n} must be >= the mesh size {ndev}")
    n = (n // ndev) * ndev                      # shardable leading dim
    spec = PlateSpec(n_features=f, latent_card=k)
    cp = vmp.compile_plate(spec)
    prior = vmp.default_prior(cp)
    init = vmp.symmetry_broken(prior, jax.random.PRNGKey(0))
    stream, _, _ = gmm_stream(n, k, f, seed=0)
    batch = stream.collect()
    xc, xd = batch.xc, batch.xd
    mesh = make_mesh((ndev,), ("data",))

    def run_single():
        st = vmp.vmp_fit(cp, prior, init, xc, xd, sweeps, 0.0,
                         None, backend, None)
        jax.block_until_ready(st.post.reg.m)
        return st

    def run_mesh():
        st = dvmp.dvmp_fit(cp, prior, init, xc, xd, mesh, ("data",),
                           sweeps, 0.0, backend=backend)
        jax.block_until_ready(st.post.reg.m)
        return st

    results = []
    finals = {}
    for name, fn in (("vmp_single_device", run_single),
                     ("dvmp_mesh", run_mesh)):
        fn()                                    # warm the jit caches
        t0 = time.perf_counter()
        finals[name] = fn()
        dt = time.perf_counter() - t0
        results.append({
            "driver": name,
            "backend": backend,
            "n_devices": 1 if name == "vmp_single_device" else ndev,
            "us_per_fit": dt * 1e6,
            "inst_per_s": n * sweeps / dt,
        })

    diff = float(np.abs(
        np.asarray(finals["vmp_single_device"].post.reg.m)
        - np.asarray(finals["dvmp_mesh"].post.reg.m)).max())
    # analytical FLOP/byte estimate of the compiled mesh-fit program
    register_estimators()
    prog = dvmp._fit_program(cp, mesh, ("data",), sweeps, 0.0, backend, None)
    _, analytical = _program_analysis(
        prog.lower(prior, init, xc, xd,
                   jax.numpy.ones(xc.shape[0], xc.dtype)))
    payload = {
        "bench": "dvmp",
        "schema_version": 1,
        "created": datetime.datetime.now(datetime.timezone.utc).isoformat(),
        "backend": backend,
        "config": {"n": n, "sweeps": sweeps, "features": f, "components": k,
                   "mesh_shape": [ndev], "analytical_mesh_fit": analytical,
                   **_bench_env_config()},
        "results": results,
        "speedup_inst_per_s": results[1]["inst_per_s"]
        / results[0]["inst_per_s"],
        "posterior_max_abs_diff": diff,
    }
    with open(out, "w") as fh:
        json.dump(payload, fh, indent=2)
        fh.write("\n")
    print(f"wrote {out}: dvmp_mesh x{ndev} {payload['speedup_inst_per_s']:.2f}x"
          f" inst/s vs single device (posterior diff {diff:.2e})")
    return payload


def validate_bench_dvmp(payload: dict) -> None:
    """Schema gate for BENCH_dvmp.json — used by scripts/ci.sh."""
    for key, typ in BENCH_DVMP_SCHEMA.items():
        if key not in payload:
            raise ValueError(f"BENCH_dvmp.json missing key {key!r}")
        if typ is float and isinstance(payload[key], int):
            continue
        if not isinstance(payload[key], typ):
            raise ValueError(f"{key!r} must be {typ.__name__}, "
                             f"got {type(payload[key]).__name__}")
    drivers = {r["driver"] for r in payload["results"]}
    if drivers != {"vmp_single_device", "dvmp_mesh"}:
        raise ValueError(f"unexpected drivers {drivers}")
    for key in ("jax_version", "pallas_policy"):
        if key not in payload["config"]:
            raise ValueError(f"config missing {key!r}")
    for r in payload["results"]:
        for field in ("backend", "n_devices", "us_per_fit", "inst_per_s"):
            if field not in r:
                raise ValueError(f"result {r['driver']} missing {field!r}")
        if not r["inst_per_s"] > 0:
            raise ValueError("inst_per_s must be positive")
    if not payload["posterior_max_abs_diff"] < 1e-2:
        raise ValueError(
            "d-VMP shard invariance violated: posterior_max_abs_diff="
            f"{payload['posterior_max_abs_diff']}")


def bench_latent_json(n: int = 8_192, f: int = 4, k: int = 3,
                      latent_dims: tuple = (2, 8), depth: int = 12,
                      b: int = 32, reps: int = 5,
                      out: str = "BENCH_latent.json") -> dict:
    """(i/ix, JSON mode) the latent-plate perf trail.

    Part 1 — FA/PPCA-mixture E-step (``local_step`` with L > 0): the einsum
    reference vs the fused component-major ``clg_suffstats_latent`` Pallas
    kernel, per latent dimension in ``latent_dims``; records inst/s for
    both backends and their max relative suff-stat difference (the fused
    path must match the reference wherever it runs).

    Part 2 — strong-junction-tree queries on a depth-``depth`` CLG chain
    (Z -> X0 -> ... -> X_{depth-1}, batched evidence on the last node):
    per-clique propagation vs shape-bucketed propagation, queries/s both
    ways plus the posterior max-abs-diff (must be ~0).
    """
    import datetime

    import jax
    import jax.numpy as jnp

    from repro.core import expfam as ef
    from repro.core import vmp
    from repro.core.dag import (BayesianNetwork, CLGCPD, DAG, MultinomialCPD,
                                PlateSpec, Variables)
    from repro.infer_exact import JunctionTreeEngine

    register_estimators()
    results = []

    # -- part 1: latent-plate E-step backends --------------------------------
    rel_diff = 0.0
    for L in latent_dims:
        spec = PlateSpec(n_features=f, latent_card=k, latent_dim=L)
        cp = vmp.compile_plate(spec)
        prior = vmp.default_prior(cp)
        post = vmp.symmetry_broken(prior, jax.random.PRNGKey(0))
        xc = jax.random.normal(jax.random.PRNGKey(1), (n, f))
        xd = jnp.zeros((n, 0), jnp.int32)
        mask = jnp.ones(n)
        stats = {}
        for backend in ("einsum", "pallas"):
            step = jax.jit(lambda x, d, m, be=backend: vmp.local_step(
                cp, post, x, d, m, backend=be))
            us = _t(step, xc, xd, mask, reps=reps)
            _, analytical = _program_analysis(step.lower(xc, xd, mask))
            row = {
                "driver": f"local_step_L{L}", "backend": backend, "L": L,
                "n": n, "us_per_call": us, "inst_per_s": n / us * 1e6,
            }
            avp = _achieved_vs_peak_row(analytical, us)
            if avp is not None:
                row["achieved_vs_peak"] = avp
            results.append(row)
            stats[backend] = step(xc, xd, mask)[0]
        de = np.asarray(ef.reg_dense(stats["einsum"].reg).sxx)
        dp = np.asarray(ef.reg_dense(stats["pallas"].reg).sxx)
        rel_diff = max(rel_diff,
                       float((np.abs(de - dp) / (1.0 + np.abs(de))).max()))

    # -- part 2: strong JT on a deep chain, bucketed vs per-clique -----------
    vs = Variables()
    Z = vs.new_multinomial("Z", 3)
    xs = [vs.new_gaussian(f"X{i:02d}") for i in range(depth)]
    dag = DAG(vs)
    dag.add_parent(xs[0], Z)
    for a_, b_ in zip(xs, xs[1:]):
        dag.add_parent(b_, a_)
    rng = np.random.RandomState(0)
    cpds = {"Z": MultinomialCPD(jnp.asarray(rng.dirichlet(np.ones(3)))),
            xs[0].name: CLGCPD(jnp.asarray(rng.randn(3)),
                               jnp.zeros((3, 0)), jnp.ones(3))}
    for a_, b_ in zip(xs, xs[1:]):
        cpds[b_.name] = CLGCPD(jnp.asarray(rng.randn()),
                               jnp.asarray(rng.randn(1) * 0.8),
                               jnp.asarray(0.3 + rng.rand()))
    bn = BayesianNetwork(dag, cpds)
    ev = {xs[-1].name: rng.randn(b).astype(np.float32)}
    post_z = {}
    for name, bucketed in (("strong_jt_per_clique", False),
                           ("strong_jt_bucketed", True)):
        eng = JunctionTreeEngine(bn, bucketed=bucketed)
        eng.set_evidence(ev)
        eng.run_inference()                   # compile + warm
        t0 = time.perf_counter()
        for _ in range(reps):
            eng.run_inference()
            pz = eng.posterior_discrete(Z)
        jax.block_until_ready(pz)
        dt = (time.perf_counter() - t0) / reps
        post_z[name] = np.asarray(pz)
        results.append({
            "driver": name, "depth": depth, "batch": b,
            "us_per_batch": dt * 1e6, "queries_per_s": b / dt,
        })
    jt_diff = float(np.abs(post_z["strong_jt_bucketed"]
                           - post_z["strong_jt_per_clique"]).max())
    jt_speedup = (results[-1]["queries_per_s"]
                  / results[-2]["queries_per_s"])

    payload = {
        "bench": "latent",
        "schema_version": 1,
        "created": datetime.datetime.now(datetime.timezone.utc).isoformat(),
        "config": {"n": n, "features": f, "components": k,
                   "latent_dims": list(latent_dims), "jt_depth": depth,
                   "jt_batch": b, **_bench_env_config()},
        "results": results,
        "latent_backend_max_rel_diff": rel_diff,
        "jt_posterior_max_abs_diff": jt_diff,
        "jt_bucketed_speedup": jt_speedup,
    }
    with open(out, "w") as fh:
        json.dump(payload, fh, indent=2)
        fh.write("\n")
    print(f"wrote {out}: latent backends rel diff {rel_diff:.2e}; "
          f"strong JT bucketed {jt_speedup:.2f}x "
          f"({results[-1]['queries_per_s']:.0f} vs "
          f"{results[-2]['queries_per_s']:.0f} q/s, diff {jt_diff:.2e})")
    return payload


def validate_bench_latent(payload: dict) -> None:
    """Schema gate for BENCH_latent.json — used by scripts/ci.sh."""
    for key, typ in BENCH_LATENT_SCHEMA.items():
        if key not in payload:
            raise ValueError(f"BENCH_latent.json missing key {key!r}")
        if typ is float and isinstance(payload[key], int):
            continue
        if not isinstance(payload[key], typ):
            raise ValueError(f"{key!r} must be {typ.__name__}, "
                             f"got {type(payload[key]).__name__}")
    for key in ("jax_version", "pallas_policy"):
        if key not in payload["config"]:
            raise ValueError(f"config missing {key!r}")
    drivers = {r["driver"] for r in payload["results"]}
    for need in ("strong_jt_per_clique", "strong_jt_bucketed"):
        if need not in drivers:
            raise ValueError(f"missing driver {need!r}")
    if not any(d.startswith("local_step_L") for d in drivers):
        raise ValueError("missing local_step latent drivers")
    backends = {r.get("backend") for r in payload["results"]
                if r["driver"].startswith("local_step_L")}
    if backends != {"einsum", "pallas"}:
        raise ValueError(f"latent drivers must cover both backends, "
                         f"got {backends}")
    if not payload["latent_backend_max_rel_diff"] < 1e-4:
        raise ValueError(
            "fused latent path diverged from the einsum reference: "
            f"rel diff {payload['latent_backend_max_rel_diff']}")
    if not payload["jt_posterior_max_abs_diff"] < 1e-5:
        raise ValueError(
            "bucketed strong JT diverged from per-clique propagation: "
            f"{payload['jt_posterior_max_abs_diff']}")


def bench_structure_json(n: int = 20_000, n_vars: int = 8,
                         max_parents: int = 2, card: int = 3, reps: int = 3,
                         out: str = "BENCH_structure.json") -> dict:
    """(JSON mode) the structure-learning perf trail (learn_structure).

    Part 1 — batched family scoring: EVERY candidate family of parent-set
    size <= ``max_parents`` over ``n_vars`` discrete columns, scored in one
    device call per backend (``family_counts`` Pallas kernel vs the einsum
    reference); records families/s both ways plus their max score diff
    (the kernel must match the reference wherever it runs).

    Part 2 — Chow-Liu on a ground-truth random tree: wall-clock + exact
    edge-recovery F1.

    Part 3 — hill-climbing on a bounded-fan-in random discrete BN:
    wall-clock, iterations, cache-miss families scored, skeleton F1.
    """
    import datetime
    import itertools

    from repro.data import synthetic as syn
    from repro.learn_structure import chow_liu, hill_climb, skeleton_f1
    from repro.learn_structure import scores as S

    results = []

    # -- part 1: family-score throughput, einsum vs pallas -------------------
    bn = syn.random_discrete_bn(n_vars, card=card,
                                max_parents=max_parents, seed=0)
    stream = syn.bn_stream(bn, n, seed=1)
    batch = stream.collect()
    cards = [card] * n_vars
    fams = []
    for ch in range(n_vars):
        rest = [v for v in range(n_vars) if v != ch]
        for k in range(max_parents + 1):
            fams.extend((ch, pa) for pa in
                        itertools.combinations(rest, k))
    register_estimators()
    # disc_family_scores mixes host numpy with device calls, so there is
    # no single lowered program to analyze; the closed-form count-kernel
    # model below covers the dominant contraction: one-hot accumulation
    # into each family's joint contingency table (2*n*J FMA per family
    # with J joint states) over an n x n_vars int32 read.
    joint_states = [int(np.prod([cards[ch]] + [cards[p] for p in pa]))
                    for ch, pa in fams]
    fam_flops = float(2 * n * sum(joint_states))
    fam_bytes = float(4 * n * n_vars + 4 * sum(joint_states))
    scores = {}
    for backend in ("einsum", "pallas"):
        def score(be=backend):
            scores[be] = S.disc_family_scores(
                batch.xd, fams, cards, mask=batch.mask, backend=be)
            return scores[be]

        t = _t(score, reps=reps)
        row = {
            "driver": "family_scores", "backend": backend,
            "n": n, "n_families": len(fams), "us_per_call": t,
            "families_per_s": len(fams) / t * 1e6,
        }
        avp = _achieved_vs_peak_row(
            {"flops": fam_flops, "hbm_bytes_min": fam_bytes}, t)
        if avp is not None:
            row["achieved_vs_peak"] = avp
        results.append(row)
    score_diff = float(np.abs(scores["einsum"] - scores["pallas"]).max())

    # -- part 2: Chow-Liu tree recovery --------------------------------------
    tree = syn.random_discrete_bn(n_vars, card=card, seed=3, tree=True)
    ts = syn.bn_stream(tree, n, seed=4)
    tb = ts.collect()
    chow_liu(tb, ts.attributes)                   # warm the jit caches
    t0 = time.perf_counter()
    for _ in range(reps):
        edges, _ = chow_liu(tb, ts.attributes)
    dt = (time.perf_counter() - t0) / reps
    cl_f1 = skeleton_f1(tree, edges)
    results.append({
        "driver": "chowliu", "backend": "einsum", "n": n,
        "n_vars": n_vars, "wallclock_s": dt, "edge_f1": cl_f1,
    })

    # -- part 3: hill-climbing recovery --------------------------------------
    hs = syn.bn_stream(bn, n, seed=5)
    hb = hs.collect()
    hill_climb(hb, hs.attributes, max_parents=max_parents)     # warm
    t0 = time.perf_counter()
    res = hill_climb(hb, hs.attributes, max_parents=max_parents)
    dt = time.perf_counter() - t0
    hc_f1 = skeleton_f1(bn, res.parents)
    results.append({
        "driver": "hillclimb", "backend": "einsum", "n": n,
        "n_vars": n_vars, "max_parents": max_parents, "wallclock_s": dt,
        "n_iters": res.n_iters, "n_families_scored": res.n_scored,
        "skeleton_f1": hc_f1,
    })

    payload = {
        "bench": "structure",
        "schema_version": 1,
        "created": datetime.datetime.now(datetime.timezone.utc).isoformat(),
        "config": {"n": n, "n_vars": n_vars, "max_parents": max_parents,
                   "card": card, **_bench_env_config()},
        "results": results,
        "family_score_max_abs_diff": score_diff,
        "chowliu_edge_f1": cl_f1,
        "hillclimb_skeleton_f1": hc_f1,
    }
    with open(out, "w") as fh:
        json.dump(payload, fh, indent=2)
        fh.write("\n")
    print(f"wrote {out}: {len(fams)} families "
          f"({results[0]['families_per_s']:.0f} fam/s einsum, "
          f"{results[1]['families_per_s']:.0f} pallas, "
          f"diff {score_diff:.2e}); chowliu F1={cl_f1:.2f}, "
          f"hillclimb F1={hc_f1:.2f} in {dt:.2f}s")
    return payload


def validate_bench_structure(payload: dict) -> None:
    """Schema gate for BENCH_structure.json — used by scripts/ci.sh."""
    for key, typ in BENCH_STRUCTURE_SCHEMA.items():
        if key not in payload:
            raise ValueError(f"BENCH_structure.json missing key {key!r}")
        if typ is float and isinstance(payload[key], int):
            continue
        if not isinstance(payload[key], typ):
            raise ValueError(f"{key!r} must be {typ.__name__}, "
                             f"got {type(payload[key]).__name__}")
    for key in ("jax_version", "pallas_policy"):
        if key not in payload["config"]:
            raise ValueError(f"config missing {key!r}")
    drivers = {r["driver"] for r in payload["results"]}
    for need in ("family_scores", "chowliu", "hillclimb"):
        if need not in drivers:
            raise ValueError(f"missing driver {need!r}")
    backends = {r["backend"] for r in payload["results"]
                if r["driver"] == "family_scores"}
    if backends != {"einsum", "pallas"}:
        raise ValueError(f"family_scores must cover both backends, "
                         f"got {backends}")
    if not payload["family_score_max_abs_diff"] < 1e-2:
        raise ValueError(
            "family_counts kernel diverged from the einsum reference: "
            f"{payload['family_score_max_abs_diff']}")
    if not payload["chowliu_edge_f1"] >= 0.99:
        raise ValueError(
            f"Chow-Liu tree recovery broke: F1={payload['chowliu_edge_f1']}")
    if not payload["hillclimb_skeleton_f1"] >= 0.7:
        raise ValueError("hill-climb skeleton recovery broke: "
                         f"F1={payload['hillclimb_skeleton_f1']}")


def bench_temporal_json(b: int = 512, t: int = 64, states: int = 3,
                        f: int = 2, sweeps: int = 5, chains: int = 2,
                        reps: int = 3, out: str = "BENCH_temporal.json"
                        ) -> dict:
    """(JSON mode) the temporal hot path (pgm_models.dynamic).

    Part 1 — HMM VB-EM at B=``b`` sequences x T=``t`` steps: the seed-style
    host sweep loop (one device dispatch per E/M step) vs the fused
    whole-fit ``lax.scan`` (``fused=True``), sequences/s both ways plus the
    posterior max-abs-diff between the two drivers (``tol=0`` so both run
    exactly ``sweeps`` sweeps).

    Part 2 — factorial HMM chain-parallel sweep: ``einsum`` vs ``pallas``
    suff-stats backends (the ``clg_seq_suffstats`` kernel), sequences/s and
    the learnt-means max-abs-diff.

    Part 3 — program caching: refitting a FRESH same-shape model must NOT
    retrace the fused program (``dynamic.trace_counts``) — recorded as the
    ``retrace_free`` flag the CI gate asserts.
    """
    import datetime

    from repro.data.synthetic import hmm_sequences
    from repro.pgm_models import FactorialHMMModel, HiddenMarkovModel
    from repro.pgm_models import dynamic as dyn

    stream = hmm_sequences(s=b, t=t, states=states, f=f, seed=0)[0]
    batch = stream.collect()
    results = []

    def make():
        m = HiddenMarkovModel(stream.attributes, n_states=states, seed=0)
        m._warm_start(batch.xc)     # identical init for every driver
        return m

    # -- part 1: fused scan vs host sweep loop -------------------------------
    mf, mu = make(), make()
    mf.update_model(batch, sweeps=sweeps, tol=0.0, fused=True)
    mu.update_model(batch, sweeps=sweeps, tol=0.0, fused=False)
    parity = float(np.abs(np.asarray(mf.posterior.emis.m)
                          - np.asarray(mu.posterior.emis.m)).max())
    for name, fused in (("hmm_update_host_loop", False),
                        ("hmm_fit_fused_scan", True)):
        m = make()
        m.update_model(batch, sweeps=sweeps, tol=0.0, fused=fused)  # warm
        t0 = time.perf_counter()
        for _ in range(reps):
            m.update_model(batch, sweeps=sweeps, tol=0.0, fused=fused)
        dt = (time.perf_counter() - t0) / reps
        results.append({
            "driver": name, "B": b, "T": t, "sweeps": sweeps,
            "us_per_fit": dt * 1e6, "seq_per_s": b / dt,
            "sweeps_per_s": sweeps / dt,
        })
    speedup = results[1]["seq_per_s"] / results[0]["seq_per_s"]

    # -- part 2: fHMM suff-stats backends ------------------------------------
    fmeans = {}
    for backend in ("einsum", "pallas"):
        fm = FactorialHMMModel(stream.attributes, n_chains=chains,
                               n_states=2, seed=0)
        fm.update_model(batch, sweeps=sweeps, tol=0.0, backend=backend)
        fmeans[backend] = np.asarray(fm.means)
        t0 = time.perf_counter()
        for _ in range(reps):
            fm.update_model(batch, sweeps=sweeps, tol=0.0, backend=backend)
        dt = (time.perf_counter() - t0) / reps
        results.append({
            "driver": "fhmm_fit_fused_scan", "backend": backend,
            "B": b, "T": t, "sweeps": sweeps, "us_per_fit": dt * 1e6,
            "seq_per_s": b / dt, "sweeps_per_s": sweeps / dt,
        })
    fhmm_diff = float(np.abs(fmeans["einsum"] - fmeans["pallas"]).max())

    # -- part 3: a fresh same-shape model reuses the compiled program --------
    before = dyn.trace_counts().get("hmm_fit", 0)
    make().update_model(batch, sweeps=sweeps, tol=0.0, fused=True)
    retrace_free = dyn.trace_counts().get("hmm_fit", 0) == before

    payload = {
        "bench": "temporal",
        "schema_version": 1,
        "created": datetime.datetime.now(datetime.timezone.utc).isoformat(),
        "config": {"B": b, "T": t, "states": states, "features": f,
                   "sweeps": sweeps, "chains": chains,
                   **_bench_env_config()},
        "results": results,
        "speedup_seq_per_s": speedup,
        "fused_posterior_max_abs_diff": parity,
        "fhmm_backend_max_abs_diff": fhmm_diff,
        "retrace_free": retrace_free,
    }
    with open(out, "w") as fh:
        json.dump(payload, fh, indent=2)
        fh.write("\n")
    print(f"wrote {out}: hmm_fit_fused_scan {speedup:.2f}x seq/s vs host "
          f"loop ({results[1]['seq_per_s']:.0f} vs "
          f"{results[0]['seq_per_s']:.0f}); posterior diff {parity:.2e}, "
          f"fhmm backend diff {fhmm_diff:.2e}, retrace_free={retrace_free}")
    return payload


def validate_bench_temporal(payload: dict) -> None:
    """Schema gate for BENCH_temporal.json — used by scripts/ci.sh."""
    for key, typ in BENCH_TEMPORAL_SCHEMA.items():
        if key not in payload:
            raise ValueError(f"BENCH_temporal.json missing key {key!r}")
        if typ is float and isinstance(payload[key], int):
            continue
        if not isinstance(payload[key], typ):
            raise ValueError(f"{key!r} must be {typ.__name__}, "
                             f"got {type(payload[key]).__name__}")
    for key in ("jax_version", "pallas_policy"):
        if key not in payload["config"]:
            raise ValueError(f"config missing {key!r}")
    drivers = {r["driver"] for r in payload["results"]}
    for need in ("hmm_update_host_loop", "hmm_fit_fused_scan",
                 "fhmm_fit_fused_scan"):
        if need not in drivers:
            raise ValueError(f"missing driver {need!r}")
    backends = {r.get("backend") for r in payload["results"]
                if r["driver"] == "fhmm_fit_fused_scan"}
    if backends != {"einsum", "pallas"}:
        raise ValueError(f"fhmm_fit_fused_scan must cover both backends, "
                         f"got {backends}")
    for r in payload["results"]:
        if not r["seq_per_s"] > 0:
            raise ValueError("seq_per_s must be positive")
    if not payload["speedup_seq_per_s"] > 1.0:
        raise ValueError("fused temporal fit must beat the host sweep loop: "
                         f"speedup {payload['speedup_seq_per_s']}")
    if not payload["fused_posterior_max_abs_diff"] < 1e-2:
        raise ValueError("fused/unfused posterior parity broke: "
                         f"{payload['fused_posterior_max_abs_diff']}")
    if not payload["fhmm_backend_max_abs_diff"] < 1e-2:
        raise ValueError("fHMM pallas backend diverged from einsum: "
                         f"{payload['fhmm_backend_max_abs_diff']}")
    if payload["retrace_free"] is not True:
        raise ValueError("same-shape refit retraced the fused program")


def _serve_offered_load(server, xs, load: float, duration: float,
                        deadline_ms: float, seed: int = 0,
                        swap_fn=None) -> dict:
    """Drive one offered-load window: Poisson arrivals at ``load`` q/s for
    ``duration`` s; optional hot swap at the halfway point.  Returns
    request-level latency stats (all tickets are awaited — a lost request
    would hang the bench, so completion IS the zero-drop check)."""
    rng = np.random.default_rng(seed)
    tickets = []
    swapped = swap_fn is None
    t0 = time.monotonic()
    end = t0 + duration
    F = xs.shape[1]
    while time.monotonic() < end:
        row = xs[rng.integers(len(xs))]
        tickets.append(server.submit(
            "Z", {f"X{i}": float(row[i]) for i in range(F)},
            deadline_ms=deadline_ms))
        if not swapped and time.monotonic() - t0 > duration / 2:
            swap_fn()
            swapped = True
        time.sleep(rng.exponential(1.0 / load))
    for t in tickets:
        t.result(timeout=120)
    dt = time.monotonic() - t0
    lat_ms = np.array([(t.done_s - t.submitted_s) * 1e3 for t in tickets])
    return {
        "offered_qps": load,
        "achieved_qps": len(tickets) / dt,
        "n_queries": len(tickets),
        "p50_ms": float(np.percentile(lat_ms, 50)),
        "p99_ms": float(np.percentile(lat_ms, 99)),
        "deadline_ms": deadline_ms,
        "deadline_misses": sum(t.deadline_miss for t in tickets),
        "swapped": swap_fn is not None,
    }


def bench_serve_json(duration: float = 3.0, loads: tuple = (200.0, 800.0),
                     deadline_ms: float = 50.0, max_batch: int = 32,
                     max_delay_ms: float = 5.0, n: int = 512, k: int = 3,
                     f: int = 4, out: str = "BENCH_serve.json") -> dict:
    """(JSON mode) the async serving tier (``repro.serve.queue``).

    A fitted GaussianMixture serves q(Z | x) queries (``mode="vmp"`` — the
    jitted ``posterior_z`` path) through :class:`AsyncPGMServer` under
    Poisson offered load, at each load in ``loads``, for two drivers:

    * ``serve_single`` — one engine replica, plain single-device dispatch;
      the FIRST load window includes a mid-stream hot model swap, and the
      bench blocks on every ticket — completion of all of them is the
      zero-drop check recorded as ``hot_swap_zero_drop``.
    * ``serve_mesh`` — the same buckets data-sharded across all visible
      devices via the ``dvmp`` ``shard_map`` path (run under
      ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` for a real
      mesh on CPU).

    Request-level p50/p99 come from ticket submit->done wall times;
    bucket-level p50/p99 are aggregated from the ``serve_bucket``
    ``latency_us`` telemetry (obs JSONL), per the ROADMAP serving item.
    """
    import datetime
    import os
    import tempfile

    import jax

    from repro import obs
    from repro.core.compat import make_mesh
    from repro.data.synthetic import gmm_stream
    from repro.pgm_models import GaussianMixture
    from repro.serve.queue import AsyncPGMServer

    stream, _, _ = gmm_stream(n, k, f, seed=0)
    model = GaussianMixture(stream.attributes, n_states=k)
    model.update_model(stream)
    xs = np.asarray(stream.collect().xc)
    ndev = len(jax.devices())
    mesh = make_mesh((ndev,), ("data",))

    results = []
    hit_rates = []
    zero_drop = False
    for driver in ("serve_single", "serve_mesh"):
        for li, load in enumerate(loads):
            tmp = tempfile.NamedTemporaryFile(
                suffix=".jsonl", delete=False).name
            server = AsyncPGMServer(
                model, mode="vmp", max_batch=max_batch,
                max_delay_ms=max_delay_ms, default_deadline_ms=deadline_ms,
                mesh=mesh if driver == "serve_mesh" else None)
            prev = None
            try:
                # warm the plan cache BEFORE enabling telemetry, so compile
                # latencies stay out of the measured bucket percentiles —
                # one plan per pow2 batch capacity the load will coalesce to
                cap = 1
                while cap <= 2 * max_batch:
                    warm = [server.submit(
                        "Z", {f"X{i}": float(xs[j % len(xs), i])
                              for i in range(f)})
                        for j in range(cap)]
                    for t in warm:
                        t.result(timeout=120)
                    cap *= 2
                prev = obs.configure(level="basic", path=tmp)

                swap_fn = None
                swap_thread = []
                if driver == "serve_single" and li == 0:
                    import threading

                    refit = GaussianMixture(stream.attributes, n_states=k,
                                            seed=1)
                    refit.update_model(stream)

                    def swap_fn():
                        # swap from a side thread: arrivals keep flowing
                        # while the new version warms in the background
                        th = threading.Thread(
                            target=server.swap_model, args=(refit,))
                        th.start()
                        swap_thread.append(th)

                row = _serve_offered_load(server, xs, load, duration,
                                          deadline_ms, seed=li,
                                          swap_fn=swap_fn)
                for th in swap_thread:
                    th.join()
                if swap_fn is not None:
                    # every ticket resolved across the swap -> zero dropped
                    zero_drop = (server.stats()["pending"] == 0)
            finally:
                server.stop()
                if prev is not None:
                    obs.configure(**prev)
            st = server.stats()
            hit_rates.append(st["plans"]["hit_rate"])
            bucket_us = [e["latency_us"] for e in
                         (json.loads(l) for l in open(tmp))
                         if e["event"] == "serve_bucket"]
            os.unlink(tmp)
            row.update({
                "driver": driver,
                "n_devices": ndev if driver == "serve_mesh" else 1,
                "bucket_p50_us": float(np.percentile(bucket_us, 50)),
                "bucket_p99_us": float(np.percentile(bucket_us, 99)),
                "n_buckets": len(bucket_us),
                "plan_cache_hit_rate": st["plans"]["hit_rate"],
                "flushes": st["flushes"],
            })
            results.append(row)

    payload = {
        "bench": "serve",
        "schema_version": 1,
        "created": datetime.datetime.now(datetime.timezone.utc).isoformat(),
        "config": {"duration_s": duration, "loads_qps": list(loads),
                   "deadline_ms": deadline_ms, "max_batch": max_batch,
                   "max_delay_ms": max_delay_ms, "n": n, "components": k,
                   "features": f, "mode": "vmp", "n_devices": ndev,
                   **_bench_env_config()},
        "results": results,
        "plan_cache_hit_rate": float(np.mean(hit_rates)),
        "hot_swap_zero_drop": zero_drop,
    }
    with open(out, "w") as fh:
        json.dump(payload, fh, indent=2)
        fh.write("\n")
    r0 = results[0]
    print(f"wrote {out}: serve_single {r0['achieved_qps']:.0f} q/s at "
          f"{r0['offered_qps']:.0f} offered (p50 {r0['p50_ms']:.1f}ms, "
          f"p99 {r0['p99_ms']:.1f}ms), mesh x{ndev}, plan hit-rate "
          f"{payload['plan_cache_hit_rate']:.2f}, "
          f"hot_swap_zero_drop={zero_drop}")
    return payload


def validate_bench_serve(payload: dict) -> None:
    """Schema gate for BENCH_serve.json — used by scripts/ci.sh."""
    for key, typ in BENCH_SERVE_SCHEMA.items():
        if key not in payload:
            raise ValueError(f"BENCH_serve.json missing key {key!r}")
        if typ is float and isinstance(payload[key], int):
            continue
        if not isinstance(payload[key], typ):
            raise ValueError(f"{key!r} must be {typ.__name__}, "
                             f"got {type(payload[key]).__name__}")
    for key in ("jax_version", "pallas_policy"):
        if key not in payload["config"]:
            raise ValueError(f"config missing {key!r}")
    drivers = {r["driver"] for r in payload["results"]}
    if drivers != {"serve_single", "serve_mesh"}:
        raise ValueError(f"unexpected drivers {drivers}")
    for need in drivers:
        loads = {r["offered_qps"] for r in payload["results"]
                 if r["driver"] == need}
        if len(loads) < 2:
            raise ValueError(f"driver {need!r} must cover >= 2 offered "
                             f"loads, got {sorted(loads)}")
    for r in payload["results"]:
        for field in ("offered_qps", "achieved_qps", "n_queries", "p50_ms",
                      "p99_ms", "bucket_p50_us", "bucket_p99_us",
                      "deadline_misses", "n_devices",
                      "plan_cache_hit_rate"):
            if field not in r:
                raise ValueError(f"result {r['driver']} missing {field!r}")
        if not r["achieved_qps"] > 0:
            raise ValueError("achieved_qps must be positive")
        if r["p99_ms"] < r["p50_ms"]:
            raise ValueError("p99 below p50 — latency aggregation broken")
    if not 0.0 <= payload["plan_cache_hit_rate"] <= 1.0:
        raise ValueError("plan_cache_hit_rate out of [0, 1]")
    if payload["hot_swap_zero_drop"] is not True:
        raise ValueError("hot swap dropped requests (or never ran)")


def _tree_bit_equal(a, b) -> bool:
    import jax

    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(la, lb))


def bench_resilience_json(n: int = 50_000, batch: int = 2_000,
                          sweeps: int = 5, k: int = 3, f: int = 8,
                          poison_rate: float = 0.01,
                          duration: float = 2.0, load: float = 300.0,
                          out: str = "BENCH_resilience.json") -> dict:
    """(JSON mode) the fault-tolerance layer under injected faults.

    Three legs, each comparing a clean run against the same run with
    seeded faults from :class:`repro.resilience.FaultInjector`:

    * **streaming** — the fused ``stream_fit`` scan over a clean stream vs
      the same stream with ``poison_rate`` of its batches NaN-poisoned.
      Records inst/s for both (quarantine is a held-state select inside
      the compiled scan, so the overhead should be noise) and asserts the
      quarantine bit-identity: the poisoned run's final posterior equals a
      run that never saw the poisoned batches.
    * **serving** — ``AsyncPGMServer`` (2 replicas, vmp mode) under
      Poisson offered load, clean vs a run with one worker crash and one
      transient plan-compile failure injected mid-stream.  Records
      achieved qps / p50 / p99 for both, the restart/retry counters, and
      the zero-loss flag (every accepted ticket resolves; pending == 0).
    * **checkpoint** — snapshot the full streaming state mid-stream, then
      time crash recovery: restore from disk + replay the tail, with the
      bit-identical-resume flag against the uninterrupted run.
    """
    import datetime
    import tempfile

    import jax
    import jax.numpy as jnp

    from repro.core import streaming, vmp
    from repro.core.dag import PlateSpec
    from repro.data.synthetic import gmm_stream
    from repro.pgm_models import GaussianMixture
    from repro.resilience import CheckpointManager, FaultInjector
    from repro.resilience import checkpoint as rckpt
    from repro.serve.plan import PlanCache
    from repro.serve.queue import AsyncPGMServer

    backend = vmp.default_backend()
    spec = PlateSpec(n_features=f, latent_card=k)
    cp = vmp.compile_plate(spec)
    prior = vmp.default_prior(cp)
    init = vmp.symmetry_broken(prior, jax.random.PRNGKey(0))
    stream, _, _ = gmm_stream(n, k, f, seed=0)
    batches = list(stream.batches(batch))
    nb = len(batches)
    xcs = jnp.stack([b.xc for b in batches])
    xds = jnp.stack([b.xd for b in batches])

    # -- streaming under NaN poison -------------------------------------------
    inj = FaultInjector(seed=0)
    bad, idx = inj.poison_nan(np.asarray(xcs), rate=poison_rate)
    bad = jnp.asarray(bad)

    def run(x, d):
        ss = streaming.stream_init(prior, init)
        ss, _ = streaming.stream_fit(cp, prior, ss, x, d, sweeps=sweeps,
                                     backend=backend)
        jax.block_until_ready(ss.post.reg.m)
        return ss

    run(xcs, xds)                                     # warm the scan
    t0 = time.perf_counter()
    clean_state = run(xcs, xds)
    clean_dt = time.perf_counter() - t0
    t0 = time.perf_counter()
    poisoned_state = run(bad, xds)
    poison_dt = time.perf_counter() - t0
    keep = np.setdiff1d(np.arange(nb), idx)
    never_state = run(xcs[keep], xds[keep])
    bit_identical = _tree_bit_equal(poisoned_state.post, never_state.post)
    streaming_leg = {
        "n_batches": nb, "n_poisoned": int(len(idx)),
        "quarantined": int(poisoned_state.n_quarantined),
        "clean_inst_per_s": n / clean_dt,
        "poisoned_inst_per_s": n / poison_dt,
        "overhead_pct": (poison_dt / clean_dt - 1.0) * 100.0,
    }

    # -- serving through a crash + compile failure ----------------------------
    model = GaussianMixture(stream.attributes, n_states=k)
    model.update_model(stream)
    xs = np.asarray(stream.collect().xc)

    def serve_leg(faults: bool) -> dict:
        cache = PlanCache(compile_retries=2, retry_backoff_s=0.01)
        inj = FaultInjector(seed=1)
        with AsyncPGMServer(model, mode="vmp", max_batch=32,
                            max_delay_ms=5.0, default_deadline_ms=60_000,
                            replicas=2, plan_cache=cache,
                            supervise_interval_ms=5) as srv:
            cap = 1                                   # warm pow2 plans
            while cap <= 64:
                if faults and cap == 64:
                    # the last warm compile hits the injected failure and
                    # must retry — deterministic, and it keeps the compile
                    # fault out of the measured load window
                    inj.fail_compiles(cache, n=1)
                warm = [srv.submit("Z", {f"X{i}": float(xs[j % len(xs), i])
                                         for i in range(f)})
                        for j in range(cap)]
                for t in warm:
                    t.result(timeout=120)
                cap *= 2
            if faults:
                inj.crash_worker(srv)                 # any worker, mid-load
            row = _serve_offered_load(srv, xs, load, duration,
                                      deadline_ms=60_000, seed=2)
            st = srv.stats()
        return {
            "achieved_qps": row["achieved_qps"], "p50_ms": row["p50_ms"],
            "p99_ms": row["p99_ms"], "n_queries": row["n_queries"],
            "worker_restarts": st["worker_restarts"],
            "compile_retries": st["plans"]["retries"], "shed": st["shed"],
            "lost_tickets": st["pending"],
        }

    clean_serve = serve_leg(faults=False)
    faulted_serve = serve_leg(faults=True)
    zero_loss = (faulted_serve["lost_tickets"] == 0
                 and faulted_serve["worker_restarts"] >= 1
                 and faulted_serve["compile_retries"] >= 1)

    # -- checkpoint save / restore / recovery ---------------------------------
    half = nb // 2
    with tempfile.TemporaryDirectory() as ckdir:
        mgr = CheckpointManager(ckdir, every=0, keep=2)
        head = run(xcs[:half], xds[:half])
        t0 = time.perf_counter()
        mgr.save(half, head)
        save_ms = (time.perf_counter() - t0) * 1e3
        t0 = time.perf_counter()
        restored, meta = rckpt.load(mgr.latest(),
                                    streaming.stream_init(prior, init))
        restore_ms = (time.perf_counter() - t0) * 1e3
        t0 = time.perf_counter()
        resumed, _ = rckpt.resume_stream_fit(
            cp, prior, streaming.stream_init(prior, init), xcs, xds,
            manager=mgr, sweeps=sweeps, backend=backend)
        recovery_s = time.perf_counter() - t0
    resume_ok = _tree_bit_equal(resumed, clean_state)
    checkpoint_leg = {
        "save_ms": save_ms, "restore_ms": restore_ms,
        "recovery_s": recovery_s, "resumed_batches": nb - half,
        "checkpoint_t": int(meta["t"]),
    }

    payload = {
        "bench": "resilience",
        "schema_version": 1,
        "created": datetime.datetime.now(datetime.timezone.utc).isoformat(),
        "config": {"n": n, "batch": batch, "sweeps": sweeps, "features": f,
                   "components": k, "poison_rate": poison_rate,
                   "duration_s": duration, "load_qps": load,
                   "backend": backend, **_bench_env_config()},
        "streaming": streaming_leg,
        "serving": {"clean": clean_serve, "faulted": faulted_serve},
        "checkpoint": checkpoint_leg,
        "quarantine_bit_identical": bit_identical,
        "serve_zero_loss": zero_loss,
        "resume_bit_identical": resume_ok,
    }
    with open(out, "w") as fh:
        json.dump(payload, fh, indent=2)
        fh.write("\n")
    print(f"wrote {out}: poisoned stream {streaming_leg['poisoned_inst_per_s']:.0f} "
          f"inst/s vs clean {streaming_leg['clean_inst_per_s']:.0f} "
          f"({streaming_leg['quarantined']} batches quarantined, "
          f"bit_identical={bit_identical}); faulted serve "
          f"{faulted_serve['achieved_qps']:.0f} q/s p99 "
          f"{faulted_serve['p99_ms']:.1f}ms vs clean "
          f"{clean_serve['achieved_qps']:.0f} q/s "
          f"(restarts={faulted_serve['worker_restarts']}, zero_loss="
          f"{zero_loss}); recovery {checkpoint_leg['recovery_s']:.2f}s "
          f"resume_bit_identical={resume_ok}")
    return payload


def validate_bench_resilience(payload: dict) -> None:
    """Schema + invariant gate for BENCH_resilience.json (scripts/ci.sh)."""
    for key, typ in BENCH_RESILIENCE_SCHEMA.items():
        if key not in payload:
            raise ValueError(f"BENCH_resilience.json missing key {key!r}")
        if typ is float and isinstance(payload[key], int):
            continue
        if not isinstance(payload[key], typ):
            raise ValueError(f"{key!r} must be {typ.__name__}, "
                             f"got {type(payload[key]).__name__}")
    for key in ("jax_version", "pallas_policy"):
        if key not in payload["config"]:
            raise ValueError(f"config missing {key!r}")
    s = payload["streaming"]
    if not (s["clean_inst_per_s"] > 0 and s["poisoned_inst_per_s"] > 0):
        raise ValueError("streaming throughput must be positive")
    if s["n_poisoned"] < 1 or s["quarantined"] != s["n_poisoned"]:
        raise ValueError(f"quarantine miscount: {s['quarantined']} flagged "
                         f"vs {s['n_poisoned']} poisoned")
    if payload["quarantine_bit_identical"] is not True:
        raise ValueError("poisoned-run posterior diverged from the "
                         "never-poisoned run")
    for leg in ("clean", "faulted"):
        r = payload["serving"][leg]
        if not r["achieved_qps"] > 0:
            raise ValueError(f"{leg} serving qps must be positive")
        if r["p99_ms"] < r["p50_ms"]:
            raise ValueError("p99 below p50 — latency aggregation broken")
    fr = payload["serving"]["faulted"]
    if fr["lost_tickets"] != 0:
        raise ValueError(f"faulted serve lost {fr['lost_tickets']} tickets")
    if fr["worker_restarts"] < 1 or fr["compile_retries"] < 1:
        raise ValueError("faults did not fire (no restart / no retry) — "
                         "the faulted leg measured nothing")
    if payload["serve_zero_loss"] is not True:
        raise ValueError("serve_zero_loss flag is false")
    c = payload["checkpoint"]
    if not (c["save_ms"] > 0 and c["restore_ms"] > 0
            and c["recovery_s"] > 0):
        raise ValueError("checkpoint timings must be positive")
    if payload["resume_bit_identical"] is not True:
        raise ValueError("mid-stream resume diverged from the "
                         "uninterrupted run")


def bench_drift():
    """(iv) drift detection latency (batches until flagged)."""
    import jax

    from repro.core import streaming, vmp
    from repro.core.dag import PlateSpec
    from repro.data.synthetic import drift_stream

    stream, _ = drift_stream(2_500, 4, seed=1)
    spec = PlateSpec(n_features=4, latent_card=1)
    cp = vmp.compile_plate(spec)
    prior = vmp.default_prior(cp)
    ss = streaming.stream_init(
        prior, vmp.symmetry_broken(prior, jax.random.PRNGKey(0)))
    fired = -1
    for i, b in enumerate(stream.batches(250)):
        ss, info = streaming.stream_update(cp, prior, ss, b.xc, b.xd,
                                           drift_threshold=3.0)
        if bool(info["drifted"]) and fired < 0:
            fired = i
    print(f"drift_detection,0,fired_at_batch={fired} (shift at 10)")


def bench_model_zoo():
    """(v) Table-2 recovery metrics."""
    import itertools

    from repro.data import synthetic as syn
    from repro.pgm_models import (GaussianMixture, HiddenMarkovModel, LDA,
                                  NaiveBayesClassifier)

    s, means, _ = syn.gmm_stream(2000, 3, 4, seed=1)
    m = GaussianMixture(s.attributes, n_states=3)
    t0 = time.perf_counter()
    m.update_model(s)
    gmm_t = time.perf_counter() - t0
    err = float(np.abs(np.sort(np.asarray(m.posterior.reg.m[:, :, 0]).T, 0)
                       - np.sort(means, 0)).max())
    print(f"zoo_gmm_fit,{gmm_t * 1e6:.0f},mean_err={err:.3f}")

    s, y = syn.nb_stream(1500, 3, 2, 2, seed=2)
    clf = NaiveBayesClassifier(s.attributes)
    clf.update_model(s)
    acc = float((np.asarray(clf.predict(s)) == y).mean())
    print(f"zoo_nbc,0,acc={acc:.3f}")

    ds, trans, hm_means, zs = syn.hmm_sequences(20, 60, 3, 2, seed=6)
    hm = HiddenMarkovModel(ds.attributes, n_states=3, seed=1)
    hm.update_model(ds)
    vit = hm.viterbi_states(ds.collect().xc)
    acc = max((np.asarray(vit) == np.array(p)[zs].reshape(vit.shape)).mean()
              for p in itertools.permutations(range(3)))
    print(f"zoo_hmm,0,decode_acc={acc:.3f}")

    counts, beta = syn.lda_corpus(120, 50, 4, seed=8)
    lda = LDA(4, 50, seed=0)
    lda.update_model(counts, sweeps=25)
    score = max(sum(float(lda.topics()[p[t]] @ beta[t]) for t in range(4))
                for p in itertools.permutations(range(4)))
    print(f"zoo_lda,0,topic_score={score:.2f} (perfect~0.80, random~0.08)")


def bench_importance_sampling():
    """(vi) parallel IS throughput and effective sample size."""
    import jax.numpy as jnp

    from repro.core.dag import (BayesianNetwork, CLGCPD, DAG, MultinomialCPD,
                                Variables)
    from repro.core.importance_sampling import ImportanceSampling

    vs = Variables()
    Z = vs.new_multinomial("Z", 2)
    X1 = vs.new_gaussian("X1")
    X2 = vs.new_gaussian("X2")
    dag = DAG(vs)
    dag.add_parent(X1, Z)
    dag.add_parent(X2, Z)
    bn = BayesianNetwork(dag, {
        "Z": MultinomialCPD(jnp.array([0.3, 0.7])),
        "X1": CLGCPD(jnp.array([0.0, 4.0]), jnp.zeros((2, 0)),
                     jnp.array([1.0, 1.0])),
        "X2": CLGCPD(jnp.array([-2.0, 2.0]), jnp.zeros((2, 0)),
                     jnp.array([1.0, 1.0]))})
    inf = ImportanceSampling(n_samples=100_000, seed=0)
    inf.set_model(bn)
    inf.set_evidence({"X1": 3.0, "X2": 1.0})
    t0 = time.perf_counter()
    inf.run_inference()
    dt = time.perf_counter() - t0
    print(f"importance_sampling_100k,{dt * 1e6:.0f},"
          f"ESS={float(inf.effective_sample_size()):.0f}")


def bench_kernels():
    """(vii) kernel calls (interpret mode: correctness-grade timing)."""
    import jax

    from repro.kernels import ops

    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 256, 4, 64))
    k = jax.random.normal(key, (1, 256, 2, 64))
    us = _t(ops.flash_attention, q, k, k, reps=2)
    print(f"kernel_flash_attn_256,{us:.0f},interpret-mode")
    x = jax.random.normal(key, (1, 128, 4, 32))
    dt = jax.nn.softplus(jax.random.normal(key, (1, 128, 4)))
    A = jax.numpy.ones((4,))
    B = jax.random.normal(key, (1, 128, 1, 32))
    us = _t(ops.ssd_scan, x, dt, A, B, B, chunk=32, reps=2)
    print(f"kernel_ssd_scan_128,{us:.0f},interpret-mode")
    d = jax.random.normal(key, (512, 2, 4))
    yv = jax.random.normal(key, (512, 2))
    r = jax.nn.softmax(jax.random.normal(key, (512, 3)), -1)
    us = _t(ops.clg_suffstats, d, yv, r, reps=2)
    print(f"kernel_clg_stats_512,{us:.0f},interpret-mode")


def bench_exact_vs_approx():
    """(ix) exact junction tree vs importance sampling vs VMP: marginal
    accuracy and query throughput (the infer_exact subsystem — the paper's
    HUGIN link, served natively)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.dag import (BayesianNetwork, CLGCPD, DAG,
                                MultinomialCPD, Variables)
    from repro.core.importance_sampling import ImportanceSampling
    from repro.data.synthetic import gmm_stream
    from repro.infer_exact import JunctionTreeEngine
    from repro.pgm_models import GaussianMixture

    # ground-truth CLG mixture Z -> X0..X3
    K, Fdim = 3, 4
    rng = np.random.RandomState(0)
    vs = Variables()
    Z = vs.new_multinomial("Z", K)
    xs = [vs.new_gaussian(f"X{f}") for f in range(Fdim)]
    dag = DAG(vs)
    for x in xs:
        dag.add_parent(x, Z)
    cpds = {"Z": MultinomialCPD(jnp.asarray(rng.dirichlet(np.ones(K))))}
    for f, x in enumerate(xs):
        cpds[x.name] = CLGCPD(jnp.asarray(rng.randn(K) * 3.0),
                              jnp.zeros((K, 0)),
                              jnp.ones(K))
    bn = BayesianNetwork(dag, cpds)
    B = 64
    sample = bn.sample(jax.random.PRNGKey(1), B)
    evidence = {x.name: sample[x.name] for x in xs}

    # junction tree: B queries, ONE batched device call
    jt = JunctionTreeEngine(bn)
    jt.set_evidence(evidence)
    jt.run_inference()  # compile
    t0 = time.perf_counter()
    for _ in range(3):
        jt.run_inference()
        exact = jt.posterior_discrete(Z)
    jax.block_until_ready(exact)
    dt = (time.perf_counter() - t0) / 3
    exact = np.asarray(exact)
    print(f"exact_vs_approx_jt,{dt / B * 1e6:.0f},{B / dt:.0f} q/s "
          f"(batched, err=0 oracle)")

    # importance sampling: one run per query instance
    n_is = 8
    t0 = time.perf_counter()
    is_err = 0.0
    for b in range(n_is):
        inf = ImportanceSampling(n_samples=20_000, seed=b)
        inf.set_model(bn)
        inf.set_evidence({x.name: float(sample[x.name][b]) for x in xs})
        inf.run_inference()
        is_err = max(is_err, float(np.abs(
            np.asarray(inf.posterior_discrete(Z)) - exact[b]).max()))
    dt = (time.perf_counter() - t0) / n_is
    print(f"exact_vs_approx_is20k,{dt * 1e6:.0f},{1 / dt:.1f} q/s "
          f"max_err={is_err:.4f}")

    # VMP: fit a GaussianMixture, compare its E-step posterior against the
    # junction tree run on the model's own BN export
    stream, _, _ = gmm_stream(2000, K, Fdim, seed=2)
    m = GaussianMixture(stream.attributes, n_states=K)
    m.update_model(stream)
    batch = stream.collect()
    t0 = time.perf_counter()
    rz = m.posterior_z(batch)
    jax.block_until_ready(rz)
    dt = time.perf_counter() - t0
    re = m.posterior_exact(batch)
    vmp_err = float(np.abs(np.asarray(rz) - np.asarray(re)).max())
    print(f"exact_vs_approx_vmp,{dt / batch.xc.shape[0] * 1e6:.2f},"
          f"{batch.xc.shape[0] / dt:.0f} q/s max_err={vmp_err:.2e} "
          f"(vs jt on exported BN)")


def bench_lm_training():
    """(viii) reduced-config LM training throughput."""
    import jax

    from repro.configs import get_config
    from repro.data.tokens import TokenStream, markov_sequence_fast
    from repro.nn import transformer as T
    from repro.train import optimizer as opt
    from repro.train import step as ts

    cfg = get_config("granite-3-2b").reduced()
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    state = ts.init_train_state(params)
    toks = markov_sequence_fast(20_000, cfg.vocab, seed=1)
    stream = TokenStream(toks, batch=8, seq=128)
    lr_fn = opt.cosine_schedule(1e-3, 10, 100)
    jstep = jax.jit(partial(ts.train_step, cfg=cfg, lr_fn=lr_fn))
    batches = list(stream.batches(12))
    state, _ = jstep(state, batches[0])  # compile
    t0 = time.perf_counter()
    for b in batches[1:]:
        state, m = jstep(state, b)
    jax.block_until_ready(m["loss"])
    dt = time.perf_counter() - t0
    tps = 11 * 8 * 128 / dt
    print(f"lm_train_step,{dt / 11 * 1e6:.0f},{tps:.0f} tok/s "
          f"loss={float(m['loss']):.3f}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", action="store_true",
                    help="run the streaming before/after comparison and "
                         "write BENCH_streaming.json instead of CSV rows")
    ap.add_argument("--dvmp", action="store_true",
                    help="with --json: run the d-VMP mesh-path driver and "
                         "write BENCH_dvmp.json instead")
    ap.add_argument("--latent", action="store_true",
                    help="with --json: run the latent-plate E-step + "
                         "bucketed strong-JT drivers and write "
                         "BENCH_latent.json instead")
    ap.add_argument("--structure", action="store_true",
                    help="with --json: run the structure-learning drivers "
                         "(family scoring, Chow-Liu, hill-climb) and write "
                         "BENCH_structure.json instead")
    ap.add_argument("--temporal", action="store_true",
                    help="with --json: run the fused temporal VB-EM drivers "
                         "(HMM scan vs host loop, fHMM backends) and write "
                         "BENCH_temporal.json instead")
    ap.add_argument("--serve", action="store_true",
                    help="with --json: drive the async serving tier under "
                         "Poisson offered load (single-device vs mesh "
                         "replicas) and write BENCH_serve.json instead")
    ap.add_argument("--resilience", action="store_true",
                    help="with --json: run the fault-injection drivers "
                         "(NaN-poisoned stream, worker crash + compile "
                         "failure under load, checkpoint recovery) and "
                         "write BENCH_resilience.json instead")
    ap.add_argument("--out", default=None)
    ap.add_argument("--n", type=int, default=50_000)
    ap.add_argument("--batch", type=int, default=2_000)
    ap.add_argument("--sweeps", type=int, default=5)
    ap.add_argument("--window", type=int, default=5,
                    help="stream_fit_windowed driver's device-resident "
                         "window (batches)")
    ap.add_argument("--devices", type=int, default=0,
                    help="mesh size for --dvmp (default: all jax devices)")
    ap.add_argument("--backend", default=None,
                    help="suff-stats backend for stream_fit "
                         "(einsum|pallas; default: auto)")
    ap.add_argument("--latent-n", type=int, default=8_192,
                    help="instances for the --latent E-step drivers")
    ap.add_argument("--depth", type=int, default=12,
                    help="CLG chain depth for the --latent strong-JT driver")
    ap.add_argument("--structure-n", type=int, default=20_000,
                    help="instances for the --structure drivers")
    ap.add_argument("--structure-vars", type=int, default=8,
                    help="variables for the --structure drivers")
    ap.add_argument("--temporal-b", type=int, default=512,
                    help="sequences per batch for the --temporal drivers")
    ap.add_argument("--temporal-t", type=int, default=64,
                    help="steps per sequence for the --temporal drivers")
    ap.add_argument("--serve-duration", type=float, default=3.0,
                    help="offered-load window per --serve config, seconds")
    ap.add_argument("--serve-loads", type=float, nargs="+",
                    default=[200.0, 800.0],
                    help="offered loads (queries/s) for the --serve drivers")
    ap.add_argument("--deadline-ms", type=float, default=50.0,
                    help="per-request deadline for the --serve drivers")
    ap.add_argument("--profile", default=None, metavar="DIR",
                    help="capture a jax.profiler trace of the benchmark "
                         "run into DIR (open with TensorBoard/Perfetto)")
    args = ap.parse_args(argv)

    if ((args.dvmp or args.latent or args.structure or args.temporal
         or args.serve or args.resilience) and not args.json):
        ap.error("--dvmp/--latent/--structure/--temporal/--serve/"
                 "--resilience require --json (they write BENCH_*.json)")

    from repro.obs.profile import profile

    with profile(args.profile):
        if args.json and args.dvmp:
            payload = bench_dvmp_json(
                n=args.n, sweeps=args.sweeps, backend=args.backend,
                n_devices=args.devices, out=args.out or "BENCH_dvmp.json")
            validate_bench_dvmp(payload)
            return
        if args.json and args.latent:
            payload = bench_latent_json(
                n=args.latent_n, depth=args.depth,
                out=args.out or "BENCH_latent.json")
            validate_bench_latent(payload)
            return
        if args.json and args.structure:
            payload = bench_structure_json(
                n=args.structure_n, n_vars=args.structure_vars,
                out=args.out or "BENCH_structure.json")
            validate_bench_structure(payload)
            return
        if args.json and args.temporal:
            payload = bench_temporal_json(
                b=args.temporal_b, t=args.temporal_t, sweeps=args.sweeps,
                out=args.out or "BENCH_temporal.json")
            validate_bench_temporal(payload)
            return
        if args.json and args.serve:
            payload = bench_serve_json(
                duration=args.serve_duration, loads=tuple(args.serve_loads),
                deadline_ms=args.deadline_ms,
                out=args.out or "BENCH_serve.json")
            validate_bench_serve(payload)
            return
        if args.json and args.resilience:
            payload = bench_resilience_json(
                n=args.n, batch=args.batch, sweeps=args.sweeps,
                duration=args.serve_duration,
                out=args.out or "BENCH_resilience.json")
            validate_bench_resilience(payload)
            return
        if args.json:
            payload = bench_streaming_json(
                n=args.n, batch=args.batch, sweeps=args.sweeps,
                backend=args.backend, window=args.window,
                out=args.out or "BENCH_streaming.json")
            validate_bench_streaming(payload)
            return

        print("name,us_per_call,derived")
        for fn in (bench_vmp_parallel, bench_streaming, bench_drift,
                   bench_model_zoo, bench_importance_sampling, bench_kernels,
                   bench_exact_vs_approx, bench_lm_training):
            fn()


if __name__ == "__main__":
    main()
