"""Roofline analysis (deliverable g) — derive the three terms per
(arch x shape) from the dry-run artifacts.

    compute_s    = HLO_FLOPs_per_device / 197 TFLOP/s      (bf16 MXU peak)
    memory_s     = HLO_bytes_per_device / 819 GB/s         (HBM)
    collective_s = link_bytes_per_device / 50 GB/s         (ICI per link)

FLOPs/bytes come from the trip-count-aware HLO analyzer (hlo_analysis.py)
over the post-SPMD module (xla's cost_analysis undercounts scan bodies).
Link-byte model: all-reduce costs 2x its payload (reduce-scatter +
all-gather halves of a ring), the others 1x.

MODEL_FLOPS = 6*N*D (train) or 2*N*D (inference) with N = active params;
the ratio MODEL_FLOPS / HLO_FLOPs exposes remat/attention/padding overhead.

Usage: PYTHONPATH=src python -m benchmarks.roofline \
           [--dryrun results/dryrun] [--hlo results/hlo] [--mesh 16x16]
Writes results/roofline.csv and results/roofline.md.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
CHIPS = 256  # single-pod table


def model_flops_per_device(rec: dict) -> float:
    """6*N_active*D (train) / 2*N_active*D (inference), per chip."""
    from repro.configs.base import INPUT_SHAPES

    shape = INPUT_SHAPES[rec["shape"]]
    n = rec["n_active"]
    if rec["kind"] == "train":
        tokens = shape.global_batch * shape.seq_len
        total = 6.0 * n * tokens
    elif rec["kind"] == "prefill":
        tokens = shape.global_batch * shape.seq_len
        total = 2.0 * n * tokens
    else:  # decode: ONE token per stream
        total = 2.0 * n * shape.global_batch
    return total / CHIPS


def analyze_record(rec: dict, hlo_dir: str) -> dict:
    from benchmarks.hlo_analysis import analyze

    tag = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}"
    path = os.path.join(hlo_dir, tag + ".hlo.txt")
    with open(path) as f:
        h = analyze(f.read())
    link_bytes = (2 * h["coll_all-reduce"] + h["coll_all-gather"]
                  + h["coll_reduce-scatter"] + h["coll_all-to-all"]
                  + h["coll_collective-permute"])
    compute_s = h["flops"] / PEAK_FLOPS
    # bytes: [min, max] — min assumes TPU-grade fusion (only matmul/conv/
    # collective/slice traffic hits HBM), max is the unfused CPU-HLO bound.
    memory_s_min = h["hbm_bytes_min"] / HBM_BW
    memory_s = h["hbm_bytes"] / HBM_BW
    coll_s = link_bytes / LINK_BW
    # dominance judged on the fused (TPU-realistic) memory bound
    terms = {"compute": compute_s, "memory": memory_s_min,
             "collective": coll_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_device(rec)
    rec = dict(rec)
    rec.update({
        "hlo_flops": h["flops"], "hlo_bytes": h["hbm_bytes"],
        "hlo_bytes_min": h["hbm_bytes_min"],
        "link_bytes": link_bytes,
        "compute_s": compute_s, "memory_s": memory_s,
        "memory_s_min": memory_s_min,
        "collective_s": coll_s, "dominant": dominant,
        "model_flops": mf,
        "useful_ratio": mf / max(h["flops"], 1.0),
        "coll_detail": {k: h[f"coll_{k}"] for k in
                        ("all-gather", "all-reduce", "reduce-scatter",
                         "all-to-all", "collective-permute")},
    })
    rec["note"] = _note(rec)
    return rec


def _note(r: dict) -> str:
    """One sentence: what would move the dominant term down."""
    if r["dominant"] == "memory":
        if r["kind"] == "decode":
            return ("decode is weight/KV-read bound: quantize weights or "
                    "batch more streams per chip to amortize reads")
        return ("fp32 activation traffic dominates: fuse residual chains / "
                "bf16 the saved remat activations")
    if r["dominant"] == "collective":
        return ("all-reduce bound: overlap grad reduce-scatter with bwd "
                "compute or shift sharding from TP toward FSDP")
    if r["useful_ratio"] < 0.5:
        return ("compute-bound with low useful ratio: cut remat recompute "
                "or attention waste (flash kernel)")
    return "compute-bound near the MXU roof: increase per-chip batch"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="results/dryrun")
    ap.add_argument("--hlo", default="results/hlo")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--out", default="results/roofline")
    args = ap.parse_args(argv)

    recs = []
    for path in sorted(glob.glob(os.path.join(args.dryrun, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("mesh") != args.mesh:
            continue
        if "skipped" in rec or "error" in rec:
            recs.append(rec)
            continue
        try:
            recs.append(analyze_record(rec, args.hlo))
        except FileNotFoundError:
            rec["note"] = "no HLO dump"
            recs.append(rec)

    # ---- csv ----
    cols = ["arch", "shape", "kind", "dominant", "compute_s",
            "memory_s_min", "memory_s", "collective_s", "hlo_flops",
            "hlo_bytes_min", "hlo_bytes", "link_bytes", "model_flops",
            "useful_ratio"]
    with open(args.out + ".csv", "w") as f:
        f.write(",".join(cols) + ",note\n")
        for r in recs:
            if "skipped" in r:
                f.write(f"{r['arch']},{r['shape']},skip,,,,,,,,,,"
                        f"\"{r['skipped']}\"\n")
                continue
            f.write(",".join(str(r.get(c, "")) for c in cols)
                    + f",\"{r.get('note', '')}\"\n")

    # ---- markdown ----
    with open(args.out + ".md", "w") as f:
        f.write("| arch | shape | compute_s | memory_s (fused..unfused) |"
                " collective_s | dominant | MODEL/HLO flops | note |\n"
                "|---|---|---|---|---|---|---|---|\n")
        for r in recs:
            if "skipped" in r:
                f.write(f"| {r['arch']} | {r['shape']} | — | — | — | skip |"
                        f" — | {r['skipped'][:60]} |\n")
                continue
            f.write(
                f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3g} |"
                f" {r['memory_s_min']:.3g}..{r['memory_s']:.3g} |"
                f" {r['collective_s']:.3g} |"
                f" **{r['dominant']}** | {r['useful_ratio']:.2f} |"
                f" {r['note'][:80]} |\n")
    print(f"[roofline] wrote {args.out}.csv / .md ({len(recs)} rows)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
