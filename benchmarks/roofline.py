"""Roofline analysis — hardware peaks, achieved-vs-peak scoring, and the
dry-run artifact report.

    compute_s    = HLO_FLOPs_per_device / peak FLOP/s      (bf16 MXU peak)
    memory_s     = HLO_bytes_per_device / HBM BW
    collective_s = link_bytes_per_device / link BW         (ICI per link)

FLOPs/bytes come from the trip-count-aware HLO analyzer (hlo_analysis.py)
over the post-SPMD module (xla's cost_analysis undercounts scan bodies).
Link-byte model: all-reduce costs 2x its payload (reduce-scatter +
all-gather halves of a ring), the others 1x.

The hardware peaks are parameters, not constants: :class:`HardwarePeaks`
defaults to a TPU v5e-class chip (197 TFLOP/s bf16, 819 GB/s HBM,
50 GB/s per ICI link) and can be overridden per run via the
``REPRO_PEAK_FLOPS`` / ``REPRO_PEAK_HBM_BW`` / ``REPRO_PEAK_LINK_BW`` /
``REPRO_PEAK_CHIPS`` environment knobs or the CLI flags below — the same
analysis answers "how far off the roof are we" on any accelerator.

:func:`achieved_vs_peak` is the live half (ROADMAP Pallas item):
``benchmarks/run.py`` registers it as the ``achieved_vs_peak`` obs
estimator, so the PGM kernel bench blocks (``--latent``, ``--structure``)
stamp measured-throughput-vs-roof fractions (and the compute/memory
bound classification) next to each row, from the analytical FLOP/byte
counts of the very program they timed.

MODEL_FLOPS = 6*N*D (train) or 2*N*D (inference) with N = active params;
the ratio MODEL_FLOPS / HLO_FLOPs exposes remat/attention/padding overhead.

Usage: PYTHONPATH=src python -m benchmarks.roofline \
           [--dryrun results/dryrun] [--hlo results/hlo] [--mesh 16x16] \
           [--peak-flops 1.97e14] [--hbm-bw 8.19e11] [--link-bw 5e10] \
           [--chips 256]
Writes results/roofline.csv and results/roofline.md.
"""

from __future__ import annotations

import argparse
import dataclasses
import glob
import json
import os
import sys
from typing import Optional


@dataclasses.dataclass(frozen=True)
class HardwarePeaks:
    """Peak rates of the accelerator the roofline is drawn against."""

    flops: float = 197e12       # bf16 MXU peak, FLOP/s per chip
    hbm_bw: float = 819e9       # HBM bandwidth, B/s per chip
    link_bw: float = 50e9       # ICI per-link bandwidth, B/s
    chips: int = 256            # pod size for per-device splits

    @classmethod
    def from_env(cls, **overrides: float) -> "HardwarePeaks":
        """Defaults <- REPRO_PEAK_* env vars <- explicit overrides."""
        vals = {}
        for field, env in (("flops", "REPRO_PEAK_FLOPS"),
                           ("hbm_bw", "REPRO_PEAK_HBM_BW"),
                           ("link_bw", "REPRO_PEAK_LINK_BW"),
                           ("chips", "REPRO_PEAK_CHIPS")):
            if env in os.environ:
                cast = int if field == "chips" else float
                vals[field] = cast(float(os.environ[env]))
        vals.update({k: v for k, v in overrides.items() if v is not None})
        return cls(**vals)


DEFAULT_PEAKS = HardwarePeaks()

# Back-compat aliases for the former module constants.
PEAK_FLOPS = DEFAULT_PEAKS.flops
HBM_BW = DEFAULT_PEAKS.hbm_bw
LINK_BW = DEFAULT_PEAKS.link_bw
CHIPS = DEFAULT_PEAKS.chips


def achieved_vs_peak(*, seconds: float, flops: Optional[float] = None,
                     hbm_bytes: Optional[float] = None,
                     peaks: Optional[HardwarePeaks] = None) -> dict:
    """Score a measured region against the hardware roof.

    ``flops`` / ``hbm_bytes`` are the work done in ``seconds`` (per
    device); returns achieved FLOP/s and B/s, their fractions of peak,
    and which roof the region sits under (``bound``: the resource whose
    peak-fraction is higher is the one limiting further speedup).
    Registered as the ``achieved_vs_peak`` obs estimator by
    ``benchmarks/run.py``.
    """
    p = peaks if peaks is not None else HardwarePeaks.from_env()
    out: dict = {"seconds": seconds,
                 "peak_flops": p.flops, "peak_hbm_bw": p.hbm_bw}
    frac_f = frac_b = None
    if flops is not None and seconds > 0:
        out["achieved_flops_per_s"] = flops / seconds
        frac_f = out["frac_peak_flops"] = flops / seconds / p.flops
    if hbm_bytes is not None and seconds > 0:
        out["achieved_bytes_per_s"] = hbm_bytes / seconds
        frac_b = out["frac_peak_hbm_bw"] = hbm_bytes / seconds / p.hbm_bw
    if frac_f is not None and frac_b is not None:
        out["bound"] = "compute" if frac_f >= frac_b else "memory"
    return out


def model_flops_per_device(rec: dict,
                           peaks: HardwarePeaks = DEFAULT_PEAKS) -> float:
    """6*N_active*D (train) / 2*N_active*D (inference), per chip."""
    from repro.configs.base import INPUT_SHAPES

    shape = INPUT_SHAPES[rec["shape"]]
    n = rec["n_active"]
    if rec["kind"] == "train":
        tokens = shape.global_batch * shape.seq_len
        total = 6.0 * n * tokens
    elif rec["kind"] == "prefill":
        tokens = shape.global_batch * shape.seq_len
        total = 2.0 * n * tokens
    else:  # decode: ONE token per stream
        total = 2.0 * n * shape.global_batch
    return total / peaks.chips


def analyze_record(rec: dict, hlo_dir: str,
                   peaks: HardwarePeaks = DEFAULT_PEAKS) -> dict:
    from benchmarks.hlo_analysis import analyze

    tag = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}"
    path = os.path.join(hlo_dir, tag + ".hlo.txt")
    with open(path) as f:
        h = analyze(f.read())
    link_bytes = (2 * h["coll_all-reduce"] + h["coll_all-gather"]
                  + h["coll_reduce-scatter"] + h["coll_all-to-all"]
                  + h["coll_collective-permute"])
    compute_s = h["flops"] / peaks.flops
    # bytes: [min, max] — min assumes TPU-grade fusion (only matmul/conv/
    # collective/slice traffic hits HBM), max is the unfused CPU-HLO bound.
    memory_s_min = h["hbm_bytes_min"] / peaks.hbm_bw
    memory_s = h["hbm_bytes"] / peaks.hbm_bw
    coll_s = link_bytes / peaks.link_bw
    # dominance judged on the fused (TPU-realistic) memory bound
    terms = {"compute": compute_s, "memory": memory_s_min,
             "collective": coll_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_device(rec, peaks)
    rec = dict(rec)
    rec.update({
        "hlo_flops": h["flops"], "hlo_bytes": h["hbm_bytes"],
        "hlo_bytes_min": h["hbm_bytes_min"],
        "link_bytes": link_bytes,
        "compute_s": compute_s, "memory_s": memory_s,
        "memory_s_min": memory_s_min,
        "collective_s": coll_s, "dominant": dominant,
        "model_flops": mf,
        "useful_ratio": mf / max(h["flops"], 1.0),
        "coll_detail": {k: h[f"coll_{k}"] for k in
                        ("all-gather", "all-reduce", "reduce-scatter",
                         "all-to-all", "collective-permute")},
    })
    rec["note"] = _note(rec)
    return rec


def _note(r: dict) -> str:
    """One sentence: what would move the dominant term down."""
    if r["dominant"] == "memory":
        if r["kind"] == "decode":
            return ("decode is weight/KV-read bound: quantize weights or "
                    "batch more streams per chip to amortize reads")
        return ("fp32 activation traffic dominates: fuse residual chains / "
                "bf16 the saved remat activations")
    if r["dominant"] == "collective":
        return ("all-reduce bound: overlap grad reduce-scatter with bwd "
                "compute or shift sharding from TP toward FSDP")
    if r["useful_ratio"] < 0.5:
        return ("compute-bound with low useful ratio: cut remat recompute "
                "or attention waste (flash kernel)")
    return "compute-bound near the MXU roof: increase per-chip batch"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="results/dryrun")
    ap.add_argument("--hlo", default="results/hlo")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--out", default="results/roofline")
    ap.add_argument("--peak-flops", type=float, default=None,
                    help="peak FLOP/s per chip (default: v5e-class 197e12; "
                         "env REPRO_PEAK_FLOPS)")
    ap.add_argument("--hbm-bw", type=float, default=None,
                    help="HBM B/s per chip (default 819e9; REPRO_PEAK_HBM_BW)")
    ap.add_argument("--link-bw", type=float, default=None,
                    help="ICI link B/s (default 50e9; REPRO_PEAK_LINK_BW)")
    ap.add_argument("--chips", type=int, default=None,
                    help="pod size (default 256; REPRO_PEAK_CHIPS)")
    args = ap.parse_args(argv)
    peaks = HardwarePeaks.from_env(flops=args.peak_flops, hbm_bw=args.hbm_bw,
                                   link_bw=args.link_bw, chips=args.chips)

    recs = []
    for path in sorted(glob.glob(os.path.join(args.dryrun, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("mesh") != args.mesh:
            continue
        if "skipped" in rec or "error" in rec:
            recs.append(rec)
            continue
        try:
            recs.append(analyze_record(rec, args.hlo, peaks))
        except FileNotFoundError:
            rec["note"] = "no HLO dump"
            recs.append(rec)

    # ---- csv ----
    cols = ["arch", "shape", "kind", "dominant", "compute_s",
            "memory_s_min", "memory_s", "collective_s", "hlo_flops",
            "hlo_bytes_min", "hlo_bytes", "link_bytes", "model_flops",
            "useful_ratio"]
    with open(args.out + ".csv", "w") as f:
        f.write(",".join(cols) + ",note\n")
        for r in recs:
            if "skipped" in r:
                f.write(f"{r['arch']},{r['shape']},skip,,,,,,,,,,"
                        f"\"{r['skipped']}\"\n")
                continue
            f.write(",".join(str(r.get(c, "")) for c in cols)
                    + f",\"{r.get('note', '')}\"\n")

    # ---- markdown ----
    with open(args.out + ".md", "w") as f:
        f.write("| arch | shape | compute_s | memory_s (fused..unfused) |"
                " collective_s | dominant | MODEL/HLO flops | note |\n"
                "|---|---|---|---|---|---|---|---|\n")
        for r in recs:
            if "skipped" in r:
                f.write(f"| {r['arch']} | {r['shape']} | — | — | — | skip |"
                        f" — | {r['skipped'][:60]} |\n")
                continue
            f.write(
                f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3g} |"
                f" {r['memory_s_min']:.3g}..{r['memory_s']:.3g} |"
                f" {r['collective_s']:.3g} |"
                f" **{r['dominant']}** | {r['useful_ratio']:.2f} |"
                f" {r['note'][:80]} |\n")
    print(f"[roofline] wrote {args.out}.csv / .md ({len(recs)} rows)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
