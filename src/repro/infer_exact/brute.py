"""Brute-force enumeration oracle for exact inference on tiny networks.

Independent of the factor algebra and junction tree: enumerates every joint
discrete configuration and scores it with ``BayesianNetwork._node_logp``
(the same density code the samplers use), so it cross-checks the whole
``infer_exact`` stack, not just the message passing.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax.numpy as jnp
import jax.scipy.special as jsp

from repro.core.dag import BayesianNetwork, Variable


def enumerate_log_joint(
    bn: BayesianNetwork,
    evidence: Optional[Dict[str, float]] = None,
) -> Tuple[Tuple[str, ...], Tuple[int, ...], jnp.ndarray]:
    """Unnormalized log p(x_discrete, e) over the full discrete grid.

    Returns (names, cards, table [*cards]).  Observed continuous nodes
    contribute their CLG likelihood; unobserved continuous nodes integrate
    to one (their continuous parents, if any, must be observed).
    """
    evidence = {k: jnp.asarray(v) for k, v in (evidence or {}).items()}
    dvars = [v for v in bn.order if v.is_discrete]
    names = tuple(v.name for v in dvars)
    cards = tuple(v.card for v in dvars)
    grids = jnp.meshgrid(*[jnp.arange(c) for c in cards], indexing="ij")
    asg: Dict[str, jnp.ndarray] = {
        v.name: g.reshape(-1) for v, g in zip(dvars, grids)}
    n_cfg = asg[names[0]].shape[0] if names else 1

    total = jnp.zeros(n_cfg)
    for v in bn.order:
        if not v.is_discrete:
            if v.name not in evidence:
                continue  # integrates to 1
            for p in bn.dag.get_parents(v):
                if not p.is_discrete and p.name not in evidence:
                    raise NotImplementedError(
                        f"unobserved continuous parent {p.name!r} of "
                        f"observed {v.name!r}")
            asg[v.name] = jnp.broadcast_to(evidence[v.name], (n_cfg,))
            total = total + bn._node_logp(v, asg)
        else:
            total = total + bn._node_logp(v, asg)
            if v.name in evidence:
                hit = asg[v.name] == evidence[v.name].astype(jnp.int32)
                total = jnp.where(hit, total, -jnp.inf)
    return names, cards, total.reshape(cards)


def brute_posterior(
    bn: BayesianNetwork,
    var: Variable,
    evidence: Optional[Dict[str, float]] = None,
) -> jnp.ndarray:
    """Normalized posterior table p(var | evidence) by full enumeration."""
    names, cards, table = enumerate_log_joint(bn, evidence)
    axis = names.index(var.name)
    other = tuple(i for i in range(len(names)) if i != axis)
    marg = jsp.logsumexp(table, axis=other) if other else table
    return jnp.exp(marg - jsp.logsumexp(marg))


def brute_log_evidence(
    bn: BayesianNetwork, evidence: Dict[str, float]
) -> jnp.ndarray:
    """log p(e) by full enumeration."""
    _, _, table = enumerate_log_joint(bn, evidence)
    return jsp.logsumexp(table)
