"""Brute-force enumeration oracle for exact inference on tiny networks.

Independent of the factor algebra and junction tree: enumerates every joint
discrete configuration and, per configuration, composes the EXACT joint
Gaussian over the continuous variables (the linear-Gaussian system
``x = A x + b + e`` solved in closed form), so it covers the full CLG class
— including unobserved continuous *internal* nodes with observed continuous
descendants, the case the strong junction tree exists for.  Discrete-only
scoring still goes through ``BayesianNetwork._node_logp`` (the same density
code the samplers use), so this cross-checks the whole ``infer_exact``
stack, not just the message passing.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np
import jax.numpy as jnp
import jax.scipy.special as jsp
import jax.scipy.stats as jst

from repro.core.dag import BayesianNetwork, Variable


def _discrete_grid(bn: BayesianNetwork):
    dvars = [v for v in bn.order if v.is_discrete]
    names = tuple(v.name for v in dvars)
    cards = tuple(v.card for v in dvars)
    grids = jnp.meshgrid(*[jnp.arange(c) for c in cards], indexing="ij")
    asg = {v.name: g.reshape(-1) for v, g in zip(dvars, grids)}
    n_cfg = asg[names[0]].shape[0] if names else 1
    return names, cards, asg, n_cfg


def _cont_joint(bn: BayesianNetwork, asg: Dict[str, jnp.ndarray],
                n_cfg: int) -> Tuple[Tuple[str, ...], jnp.ndarray,
                                     jnp.ndarray]:
    """Per-configuration joint Gaussian over ALL continuous variables.

    The CLG system is ``x = A(d) x + b(d) + e``, ``e ~ N(0, diag(s2(d)))``
    with A strictly lower-triangular in topological order, so
    ``mean = (I - A)^-1 b`` and ``cov = (I - A)^-1 diag(s2) (I - A)^-T``.
    Returns (names, mean [n_cfg, C], cov [n_cfg, C, C]).
    """
    cvars = [v for v in bn.order if not v.is_discrete]
    names = tuple(v.name for v in cvars)
    C = len(cvars)
    idx = {n: i for i, n in enumerate(names)}
    A = jnp.zeros((n_cfg, C, C))
    b = jnp.zeros((n_cfg, C))
    s2 = jnp.zeros((n_cfg, C))
    for v in cvars:
        i = idx[v.name]
        parents = bn.dag.get_parents(v)
        dpa = [p for p in parents if p.is_discrete]
        cpa = [p for p in parents if not p.is_discrete]
        didx = tuple(asg[p.name].astype(jnp.int32) for p in dpa)
        cpd = bn.cpds[v.name]
        alpha = jnp.broadcast_to(jnp.asarray(cpd.alpha)[didx], (n_cfg,))
        sig = jnp.broadcast_to(jnp.asarray(cpd.sigma2)[didx], (n_cfg,))
        b = b.at[:, i].set(alpha)
        s2 = s2.at[:, i].set(sig)
        if cpa:
            beta = jnp.broadcast_to(jnp.asarray(cpd.beta)[didx],
                                    (n_cfg, len(cpa)))
            for ci, p in enumerate(cpa):
                A = A.at[:, i, idx[p.name]].set(beta[:, ci])
    I_A = jnp.broadcast_to(jnp.eye(C), (n_cfg, C, C)) - A
    mean = jnp.linalg.solve(I_A, b[..., None])[..., 0]
    M = jnp.linalg.inv(I_A)
    cov = M @ (s2[..., None] * jnp.swapaxes(M, -1, -2))
    return names, mean, cov


def enumerate_log_joint(
    bn: BayesianNetwork,
    evidence: Optional[Dict[str, float]] = None,
) -> Tuple[Tuple[str, ...], Tuple[int, ...], jnp.ndarray]:
    """Unnormalized log p(x_discrete, e) over the full discrete grid.

    Returns (names, cards, table [*cards]).  Observed continuous nodes
    contribute the density of the per-configuration joint-Gaussian marginal
    over the observed set; unobserved continuous nodes (internal or leaf)
    integrate out exactly.
    """
    evidence = {k: jnp.asarray(v, jnp.float32) for k, v
                in (evidence or {}).items()}
    names, cards, asg, n_cfg = _discrete_grid(bn)
    total = jnp.zeros(n_cfg)
    for v in bn.order:
        if not v.is_discrete:
            continue
        total = total + bn._node_logp(v, asg)
        if v.name in evidence:
            hit = asg[v.name] == evidence[v.name].astype(jnp.int32)
            total = jnp.where(hit, total, -jnp.inf)
    cnames = [v.name for v in bn.order
              if not v.is_discrete and v.name in evidence]
    if cnames:
        all_names, mean, cov = _cont_joint(bn, asg, n_cfg)
        oi = np.asarray([all_names.index(n) for n in cnames], np.int32)
        x = jnp.stack([evidence[n].reshape(()) for n in cnames])
        total = total + jst.multivariate_normal.logpdf(
            x, mean[:, oi], cov[:, oi[:, None], oi[None, :]])
    return names, cards, total.reshape(cards)


def brute_posterior(
    bn: BayesianNetwork,
    var: Variable,
    evidence: Optional[Dict[str, float]] = None,
) -> jnp.ndarray:
    """Normalized posterior table p(var | evidence) by full enumeration."""
    names, cards, table = enumerate_log_joint(bn, evidence)
    axis = names.index(var.name)
    other = tuple(i for i in range(len(names)) if i != axis)
    marg = jsp.logsumexp(table, axis=other) if other else table
    return jnp.exp(marg - jsp.logsumexp(marg))


def brute_log_evidence(
    bn: BayesianNetwork, evidence: Dict[str, float]
) -> jnp.ndarray:
    """log p(e) by full enumeration."""
    _, _, table = enumerate_log_joint(bn, evidence)
    return jsp.logsumexp(table)


def brute_posterior_mean_var(
    bn: BayesianNetwork,
    var: Variable,
    evidence: Optional[Dict[str, float]] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Exact posterior mean and variance of an unobserved continuous node.

    Per discrete configuration, conditions the joint Gaussian on the
    observed continuous values, then mixes the conditional moments with the
    configuration posterior — the ground truth the strong junction tree's
    weak marginals must reproduce exactly.
    """
    evidence = {k: jnp.asarray(v, jnp.float32) for k, v
                in (evidence or {}).items()}
    name = var.name if isinstance(var, Variable) else str(var)
    if name in evidence:
        raise ValueError(f"{name!r} is observed")
    _, _, table = enumerate_log_joint(bn, evidence)
    logw = table.reshape(-1)
    w = jnp.exp(logw - jsp.logsumexp(logw))
    _, _, asg, n_cfg = _discrete_grid(bn)
    all_names, mean, cov = _cont_joint(bn, asg, n_cfg)
    vi = all_names.index(name)
    onames = [n for n in all_names if n in evidence]
    if onames:
        oi = np.asarray([all_names.index(n) for n in onames], np.int32)
        x = jnp.stack([evidence[n].reshape(()) for n in onames])
        coo = cov[:, oi[:, None], oi[None, :]]
        cvo = cov[:, vi, oi]                             # [n_cfg, o]
        sol = jnp.linalg.solve(coo, (x - mean[:, oi])[..., None])[..., 0]
        mu_c = mean[:, vi] + (cvo * sol).sum(-1)
        gain = jnp.linalg.solve(coo, cvo[..., None])[..., 0]
        s2_c = cov[:, vi, vi] - (cvo * gain).sum(-1)
    else:
        mu_c, s2_c = mean[:, vi], cov[:, vi, vi]
    m = (w * mu_c).sum()
    second = (w * (s2_c + mu_c ** 2)).sum()
    return m, second - m ** 2
