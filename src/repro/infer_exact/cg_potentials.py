"""Batched conditional-Gaussian (CG) potential algebra — the strong
junction tree's factor layer (Lauritzen 1992).

A CG potential has a *discrete* scope (named variables with cardinalities)
and a *continuous* scope (named heads).  Two dual representations:

* :class:`CGPotential` — **canonical** characteristics ``(g, h, K)``:
  ``phi(d, x) = exp(g(d) + h(d)^T x - x^T K(d) x / 2)``.  Closed under
  combination (add), division (subtract), continuous-evidence reduction and
  EXACT integration of continuous variables — everything the collect pass
  toward the strong root needs.  Crucially it represents CLG *conditionals*
  ``p(x | d, z)`` (K merely PSD), which moment form cannot.

* :class:`MomentPotential` — **moment** characteristics ``(p, mu, Sigma)``
  per discrete configuration: the weight table (log p), the mean vector and
  the covariance.  Marginalizing continuous variables is projection;
  marginalizing discrete variables is the *weak marginal* — the moment-
  matched single Gaussian per remaining configuration, which preserves the
  mixture's first and second moments exactly (Lauritzen's theorem: after a
  strong collect and a weak distribute, every clique holds the true weak
  marginal of the posterior, so queried means/variances are exact).

All tables carry a leading evidence-batch axis ``B``: one slice per
evidence instance, so the whole strong junction tree propagates B queries
in one jitted device call.  Scopes/cards are static Python; tables are jnp.

The moment-matching hot loop can dispatch to the Pallas kernel
``repro.kernels.factor_ops.cg_weak_marg`` (oracle:
``repro.kernels.ref.cg_weak_marg_ref``) via ``use_pallas=True``.
"""

from __future__ import annotations

import math
from typing import Dict, NamedTuple, Sequence, Tuple

import numpy as np
import jax.numpy as jnp
import jax.scipy.special as jsp

LOG_2PI = math.log(2.0 * math.pi)
NEG_INF = float("-inf")


class CGPotential(NamedTuple):
    """Canonical-form CG potential.  Shapes (B = evidence batch):

    g: [B, *cards]; h: [B, *cards, n]; K: [B, *cards, n, n], n = |cscope|.
    """

    dscope: Tuple[str, ...]
    cards: Tuple[int, ...]
    cscope: Tuple[str, ...]
    g: jnp.ndarray
    h: jnp.ndarray
    K: jnp.ndarray


class MomentPotential(NamedTuple):
    """Moment-form CG potential: logp [B, *cards]; mu [B, *cards, n];
    sigma [B, *cards, n, n]."""

    dscope: Tuple[str, ...]
    cards: Tuple[int, ...]
    cscope: Tuple[str, ...]
    logp: jnp.ndarray
    mu: jnp.ndarray
    sigma: jnp.ndarray


# -- constructors -------------------------------------------------------------


def zeros(dscope: Tuple[str, ...], cards: Tuple[int, ...],
          cscope: Tuple[str, ...], B: int) -> CGPotential:
    """Multiplicative-identity potential (g = 0, no Gaussian info)."""
    n = len(cscope)
    return CGPotential(dscope, cards, cscope,
                       jnp.zeros((B,) + cards),
                       jnp.zeros((B,) + cards + (n,)),
                       jnp.zeros((B,) + cards + (n, n)))


def from_discrete_table(dscope: Tuple[str, ...], cards: Tuple[int, ...],
                        logp: jnp.ndarray) -> CGPotential:
    """Purely discrete potential from a log table [*cards] (B=1 slice)."""
    return CGPotential(dscope, cards, (),
                       logp[None], jnp.zeros((1,) + cards + (0,)),
                       jnp.zeros((1,) + cards + (0, 0)))


def from_clg(alpha: jnp.ndarray, beta: jnp.ndarray, sigma2: jnp.ndarray,
             dscope: Tuple[str, ...], cards: Tuple[int, ...],
             cscope: Tuple[str, ...]) -> CGPotential:
    """Canonical form of a CLG CPD ``N(x; alpha(d) + beta(d)^T z, sigma2(d))``.

    ``cscope`` = (x, *z): the child variable first, then its continuous
    parents.  alpha/sigma2: [*cards]; beta: [*cards, C].
    """
    alpha = jnp.broadcast_to(jnp.asarray(alpha, jnp.float32), cards)
    sigma2 = jnp.broadcast_to(jnp.asarray(sigma2, jnp.float32), cards)
    C = len(cscope) - 1
    beta = jnp.broadcast_to(jnp.asarray(beta, jnp.float32), cards + (C,))
    prec = 1.0 / sigma2
    # w^T [x, z] = x - beta^T z;  exponent = -(w^T u - alpha)^2 / (2 s2) + c
    w = jnp.concatenate([jnp.ones(cards + (1,)), -beta], axis=-1)
    K = prec[..., None, None] * (w[..., :, None] * w[..., None, :])
    h = (alpha * prec)[..., None] * w
    g = -0.5 * (alpha ** 2 * prec + jnp.log(2.0 * jnp.pi * sigma2))
    return CGPotential(dscope, cards, cscope, g[None], h[None], K[None])


# -- scope plumbing -----------------------------------------------------------


def _expand_discrete(t: jnp.ndarray, old: Tuple[str, ...],
                     new: Tuple[str, ...], new_cards: Tuple[int, ...],
                     trailing: int) -> jnp.ndarray:
    """Broadcast a [B, *old_cards, *trail] table onto the discrete superset
    ``new`` (old ⊆ new), keeping ``trailing`` minor axes in place."""
    order = sorted(range(len(old)), key=lambda i: new.index(old[i]))
    nt = t.ndim - trailing
    perm = ((0,) + tuple(1 + i for i in order)
            + tuple(range(nt, t.ndim)))
    t = jnp.transpose(t, perm)
    for axis, v in enumerate(new):
        if v not in old:
            t = jnp.expand_dims(t, 1 + axis)
    target = (t.shape[0],) + tuple(new_cards) + t.shape[1 + len(new_cards):]
    return jnp.broadcast_to(t, target)


def _extend(p: CGPotential, dscope: Tuple[str, ...], cards: Tuple[int, ...],
            cscope: Tuple[str, ...]) -> CGPotential:
    """Embed ``p`` into the superset scopes (zero-pad the Gaussian part)."""
    g = _expand_discrete(p.g, p.dscope, dscope, cards, 0)
    n_new = len(cscope)
    cols = np.asarray([cscope.index(v) for v in p.cscope], np.int32)
    h_old = _expand_discrete(p.h, p.dscope, dscope, cards, 1)
    K_old = _expand_discrete(p.K, p.dscope, dscope, cards, 2)
    h = jnp.zeros(g.shape + (n_new,))
    K = jnp.zeros(g.shape + (n_new, n_new))
    if len(cols):
        h = h.at[..., cols].set(h_old)
        K = K.at[..., cols[:, None], cols[None, :]].set(K_old)
    return CGPotential(dscope, cards, cscope, g, h, K)


def _union_scopes(pots: Sequence[CGPotential]
                  ) -> Tuple[Tuple[str, ...], Tuple[int, ...],
                             Tuple[str, ...]]:
    card_of: Dict[str, int] = {}
    cvars: list = []
    for p in pots:
        for v, c in zip(p.dscope, p.cards):
            if v in card_of:
                if card_of[v] != c:
                    raise ValueError(f"cardinality clash for {v}")
            else:
                card_of[v] = c
        for v in p.cscope:
            if v not in cvars:
                cvars.append(v)
    dscope = tuple(sorted(card_of))
    return dscope, tuple(card_of[v] for v in dscope), tuple(sorted(cvars))


def combine(*pots: CGPotential) -> CGPotential:
    """Product of CG potentials: union scopes, add (g, h, K)."""
    dscope, cards, cscope = _union_scopes(pots)
    out = None
    for p in pots:
        q = _extend(p, dscope, cards, cscope)
        out = q if out is None else CGPotential(
            dscope, cards, cscope, out.g + q.g, out.h + q.h, out.K + q.K)
    return out


def divide(a: CGPotential, msg: CGPotential) -> CGPotential:
    """``a / msg`` (canonical subtraction); msg scopes ⊆ a scopes.

    Configurations dead in ``a`` (g = -inf) stay dead: -inf - (-inf) would
    be NaN, and a divisor can only be -inf where the dividend already is
    (the dividend belief carries strictly more evidence).
    """
    q = _extend(msg, a.dscope, a.cards, a.cscope)
    dead = jnp.isneginf(a.g)
    g = jnp.where(dead, NEG_INF, a.g - q.g)
    h = jnp.where(dead[..., None], 0.0, a.h - q.h)
    K = jnp.where(dead[..., None, None], 0.0, a.K - q.K)
    return CGPotential(a.dscope, a.cards, a.cscope, g, h, K)


# -- evidence -----------------------------------------------------------------


def reduce_evidence(p: CGPotential, values: Dict[str, jnp.ndarray]
                    ) -> CGPotential:
    """Instantiate observed continuous heads to per-instance values [B].

    Exact in canonical form; the observed axes disappear from the scope.
    """
    obs = tuple(v for v in p.cscope if v in values)
    if not obs:
        return p
    keep = tuple(v for v in p.cscope if v not in obs)
    oi = np.asarray([p.cscope.index(v) for v in obs], np.int32)
    ki = np.asarray([p.cscope.index(v) for v in keep], np.int32)
    nb = len(p.cards)
    x = jnp.stack([jnp.asarray(values[v], jnp.float32).reshape(-1)
                   for v in obs], axis=-1)                      # [B, do]
    x = x.reshape((x.shape[0],) + (1,) * nb + (len(obs),))
    h_o = p.h[..., oi]
    K_oo = p.K[..., oi[:, None], oi[None, :]]
    g = (p.g + (h_o * x).sum(-1)
         - 0.5 * (x[..., :, None] * K_oo * x[..., None, :]).sum((-2, -1)))
    if not keep:
        B = max(g.shape[0], x.shape[0])
        g = jnp.broadcast_to(g, (B,) + g.shape[1:])
        return CGPotential(p.dscope, p.cards, (), g,
                           jnp.zeros(g.shape + (0,)),
                           jnp.zeros(g.shape + (0, 0)))
    K_uo = p.K[..., ki[:, None], oi[None, :]]
    h = p.h[..., ki] - (K_uo * x[..., None, :]).sum(-1)
    K = p.K[..., ki[:, None], ki[None, :]]
    B = max(g.shape[0], h.shape[0])
    g = jnp.broadcast_to(g, (B,) + g.shape[1:])
    h = jnp.broadcast_to(h, (B,) + h.shape[1:])
    K = jnp.broadcast_to(K, (B,) + K.shape[1:])
    return CGPotential(p.dscope, p.cards, keep, g, h, K)


def add_discrete_log(p: CGPotential, dscope: Tuple[str, ...],
                     cards: Tuple[int, ...], logp: jnp.ndarray) -> CGPotential:
    """Multiply in a purely discrete (batched) log table [B, *cards]."""
    q = CGPotential(dscope, cards, (), logp,
                    jnp.zeros(logp.shape + (0,)),
                    jnp.zeros(logp.shape + (0, 0)))
    return combine(p, q)


# -- marginalization ----------------------------------------------------------


def marginalize_cont(p: CGPotential, drop: Sequence[str]) -> CGPotential:
    """EXACT Gaussian integral over ``drop`` ⊆ cscope (strong operation).

    Valid when K restricted to ``drop`` is positive definite — guaranteed
    during collect by the strong elimination order (each continuous
    variable is integrated at the topmost clique containing it, after its
    CPD's precision has been absorbed).
    """
    drop = tuple(v for v in p.cscope if v in set(drop))
    if not drop:
        return p
    keep = tuple(v for v in p.cscope if v not in drop)
    di = np.asarray([p.cscope.index(v) for v in drop], np.int32)
    ki = np.asarray([p.cscope.index(v) for v in keep], np.int32)
    # dead configurations (g = -inf, from discrete-evidence indicators) can
    # carry arbitrary (even singular) K blocks after distribute-pass
    # division — mask them so slogdet/solve garbage cannot leak out as NaN
    dead = jnp.isneginf(p.g)
    K_ii = p.K[..., di[:, None], di[None, :]]
    K_ii = jnp.where(dead[..., None, None], jnp.eye(len(drop)), K_ii)
    h_i = p.h[..., di]
    sign, logdet = jnp.linalg.slogdet(K_ii)
    del sign                                     # PD by construction
    sol_h = jnp.linalg.solve(K_ii, h_i[..., None])[..., 0]
    g = (p.g + 0.5 * (len(drop) * LOG_2PI - logdet)
         + 0.5 * (h_i * sol_h).sum(-1))
    g = jnp.where(dead, NEG_INF, g)
    if not keep:
        return CGPotential(p.dscope, p.cards, (), g,
                           jnp.zeros(g.shape + (0,)),
                           jnp.zeros(g.shape + (0, 0)))
    K_ji = p.K[..., ki[:, None], di[None, :]]
    sol_K = jnp.linalg.solve(K_ii, jnp.swapaxes(K_ji, -1, -2))  # K_ii^-1 K_ij
    h = p.h[..., ki] - (K_ji * sol_h[..., None, :]).sum(-1)
    K = p.K[..., ki[:, None], ki[None, :]] - K_ji @ sol_K
    K = 0.5 * (K + jnp.swapaxes(K, -1, -2))
    h = jnp.where(dead[..., None], 0.0, h)
    K = jnp.where(dead[..., None, None], jnp.eye(len(keep)), K)
    return CGPotential(p.dscope, p.cards, keep, g, h, K)


def marginalize_disc(p: CGPotential, drop: Sequence[str]) -> CGPotential:
    """logsumexp out discrete variables — STRONG only when the continuous
    scope is empty (guaranteed on the collect pass by strongness)."""
    drop = tuple(v for v in p.dscope if v in set(drop))
    if not drop:
        return p
    if p.cscope:
        raise ValueError(
            "strong discrete marginalization with live continuous scope "
            f"{p.cscope} — use weak_marginalize")
    keep = tuple(v for v in p.dscope if v not in drop)
    axes = tuple(1 + p.dscope.index(v) for v in drop)
    cards = tuple(p.cards[p.dscope.index(v)] for v in keep)
    # surviving axes keep their relative order == sorted scope order
    g = jsp.logsumexp(p.g, axis=axes)
    return CGPotential(keep, cards, (), g,
                       jnp.zeros(g.shape + (0,)), jnp.zeros(g.shape + (0, 0)))


# -- moment form --------------------------------------------------------------


def to_moment(p: CGPotential) -> MomentPotential:
    """Canonical -> moment.  Needs K positive definite per configuration
    (true for clique/sepset *beliefs*)."""
    n = len(p.cscope)
    if n == 0:
        return MomentPotential(p.dscope, p.cards, (), p.g,
                               p.h, p.K)
    dead = jnp.isneginf(p.g)
    K = jnp.where(dead[..., None, None], jnp.eye(n), p.K)
    sign, logdet = jnp.linalg.slogdet(K)
    del sign
    mu = jnp.linalg.solve(K, p.h[..., None])[..., 0]
    sigma = jnp.linalg.inv(K)
    sigma = 0.5 * (sigma + jnp.swapaxes(sigma, -1, -2))
    logp = p.g + 0.5 * (n * LOG_2PI - logdet + (p.h * mu).sum(-1))
    logp = jnp.where(dead, NEG_INF, logp)
    mu = jnp.where(dead[..., None], 0.0, mu)
    sigma = jnp.where(dead[..., None, None], jnp.eye(n), sigma)
    return MomentPotential(p.dscope, p.cards, p.cscope, logp, mu, sigma)


def to_canonical(m: MomentPotential) -> CGPotential:
    """Moment -> canonical.  Configurations with logp = -inf get an
    identity covariance stand-in (their weight keeps them inert)."""
    n = len(m.cscope)
    if n == 0:
        return CGPotential(m.dscope, m.cards, (), m.logp, m.mu, m.sigma)
    dead = jnp.isneginf(m.logp)[..., None, None]
    sigma = jnp.where(dead, jnp.eye(n), m.sigma)
    K = jnp.linalg.inv(sigma)
    K = 0.5 * (K + jnp.swapaxes(K, -1, -2))
    h = (K @ m.mu[..., None])[..., 0]
    sign, logdet_s = jnp.linalg.slogdet(sigma)
    del sign
    g = m.logp - 0.5 * (n * LOG_2PI + logdet_s + (h * m.mu).sum(-1))
    g = jnp.where(jnp.isneginf(m.logp), NEG_INF, g)
    return CGPotential(m.dscope, m.cards, m.cscope, g, h, K)


def moment_marginalize_cont(m: MomentPotential, drop: Sequence[str]
                            ) -> MomentPotential:
    """Drop continuous heads in moment form (exact: Gaussian projection)."""
    drop = tuple(v for v in m.cscope if v in set(drop))
    if not drop:
        return m
    keep = tuple(v for v in m.cscope if v not in drop)
    ki = np.asarray([m.cscope.index(v) for v in keep], np.int32)
    return MomentPotential(m.dscope, m.cards, keep, m.logp,
                           m.mu[..., ki],
                           m.sigma[..., ki[:, None], ki[None, :]])


def moment_match(logp: jnp.ndarray, mu: jnp.ndarray, sigma: jnp.ndarray,
                 axes: Tuple[int, ...]
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Collapse mixture axes to a single Gaussian with the same first and
    second moments (the weak marginal).  -inf weights contribute nothing;
    all-dead mixtures yield (logp=-inf, mu=0, sigma=I)."""
    n = mu.shape[-1]
    lse = jsp.logsumexp(logp, axis=axes, keepdims=True)
    safe = jnp.where(jnp.isneginf(lse), 0.0, lse)
    w = jnp.where(jnp.isneginf(logp), 0.0, jnp.exp(logp - safe))
    mu_hat = (w[..., None] * mu).sum(axes)
    second = (w[..., None, None]
              * (sigma + mu[..., :, None] * mu[..., None, :])).sum(axes)
    sigma_hat = second - mu_hat[..., :, None] * mu_hat[..., None, :]
    logp_hat = lse.squeeze(axes)
    dead = jnp.isneginf(logp_hat)
    sigma_hat = jnp.where(dead[..., None, None], jnp.eye(n), sigma_hat)
    mu_hat = jnp.where(dead[..., None], 0.0, mu_hat)
    return logp_hat, mu_hat, sigma_hat


def weak_marginalize(p: CGPotential, keep_disc: Sequence[str],
                     keep_cont: Sequence[str], *,
                     use_pallas: bool = False) -> CGPotential:
    """Weak (moment-matched) marginal of a *belief* onto a sepset.

    Continuous drops are exact projections; discrete drops moment-match.
    Returns canonical form (ready for division / combination).
    """
    keep_d = set(keep_disc)
    keep_c = set(keep_cont)
    drop_d = tuple(v for v in p.dscope if v not in keep_d)
    drop_c = tuple(v for v in p.cscope if v not in keep_c)
    if not drop_d:
        out = marginalize_cont(p, drop_c) if drop_c else p
        return out
    if not p.cscope:
        return marginalize_disc(p, drop_d)
    m = to_moment(p)
    m = moment_marginalize_cont(m, drop_c)
    if not m.cscope:
        can = CGPotential(m.dscope, m.cards, (), m.logp, m.mu, m.sigma)
        return marginalize_disc(can, drop_d)
    # permute kept discrete axes ahead of dropped ones, then moment-match
    keep_ds = tuple(v for v in m.dscope if v in keep_d)
    perm_scope = keep_ds + drop_d
    perm = (0,) + tuple(1 + m.dscope.index(v) for v in perm_scope)
    nb = 1 + len(m.dscope)
    logp = jnp.transpose(m.logp, perm)
    mu = jnp.transpose(m.mu, perm + (nb,))
    sigma = jnp.transpose(m.sigma, perm + (nb, nb + 1))
    axes = tuple(range(1 + len(keep_ds), 1 + len(m.dscope)))
    n = len(m.cscope)
    kcards = tuple(m.cards[m.dscope.index(v)] for v in keep_ds)
    if use_pallas and axes:
        from repro.kernels import ops

        B = logp.shape[0]
        M = int(np.prod(kcards)) if kcards else 1
        N = int(np.prod(logp.shape[1 + len(kcards):]))
        lp, muh, sigh = ops.cg_weak_marg(
            logp.reshape(B, M, N), mu.reshape(B, M, N, n),
            sigma.reshape(B, M, N, n, n))
        lp = lp.reshape((B,) + kcards)
        muh = muh.reshape((B,) + kcards + (n,))
        sigh = sigh.reshape((B,) + kcards + (n, n))
    else:
        lp, muh, sigh = moment_match(logp, mu, sigma, axes)
    out = MomentPotential(keep_ds, kcards, m.cscope, lp, muh, sigh)
    return to_canonical(out)


# -- shape-bucketed batching --------------------------------------------------
#
# Junction-tree propagation issues one solve/slogdet (marginalize_cont) or one
# moment-match chain (weak_marginalize) PER CLIQUE.  Cliques at the same tree
# level are independent, and cliques of equal shape signature —
# (n_cont, n_discrete_configs, batch) — can ride the SAME stacked linalg call:
# each member's tables are permuted to a canonical layout (kept continuous
# heads first, kept discrete axes major), flattened, stacked along a pseudo
# batch axis and pushed through the ordinary scalar operation once, then
# unstacked and relabeled.  Per-clique work becomes cheap gathers/transposes;
# the dispatch-heavy solve/slogdet/inv ops drop to one per bucket per level.


def _cfg(p: CGPotential) -> int:
    return int(np.prod(p.cards)) if p.cards else 1


def marginalize_cont_many(
    items: Sequence[Tuple[CGPotential, Sequence[str]]]
) -> list:
    """Batched :func:`marginalize_cont` over same-shaped potentials.

    ``items``: (potential, continuous names to drop) pairs.  Potentials
    bucketed by (|cscope|, |drop|, n_configs, B) run ONE stacked
    solve/slogdet; singletons fall through to the scalar op.  Output order
    matches input order and every entry equals its scalar counterpart.
    """
    out: list = [None] * len(items)
    buckets: Dict[Tuple[int, int, int, int], list] = {}
    for i, (p, drop) in enumerate(items):
        dropt = tuple(v for v in p.cscope if v in set(drop))
        if not dropt:
            out[i] = p
            continue
        key = (len(p.cscope), len(dropt), _cfg(p), p.g.shape[0])
        buckets.setdefault(key, []).append((i, p, dropt))
    for (n, nd, cfg, B), members in buckets.items():
        if len(members) == 1:
            i, p, dropt = members[0]
            out[i] = marginalize_cont(p, dropt)
            continue
        nk = n - nd
        gs, hs, Ks, keeps = [], [], [], []
        for i, p, dropt in members:
            keep = tuple(v for v in p.cscope if v not in dropt)
            keeps.append(keep)
            order = np.asarray([p.cscope.index(v) for v in keep + dropt],
                               np.int32)
            gs.append(p.g.reshape(B * cfg))
            hs.append(p.h[..., order].reshape(B * cfg, n))
            Ks.append(p.K[..., order[:, None], order[None, :]]
                      .reshape(B * cfg, n, n))
        names = tuple(f"_c{j}" for j in range(n))
        q = CGPotential((), (), names,
                        jnp.concatenate(gs), jnp.concatenate(hs),
                        jnp.concatenate(Ks))
        m = marginalize_cont(q, names[nk:])
        g = m.g.reshape(len(members), B * cfg)
        h = m.h.reshape(len(members), B * cfg, nk)
        K = m.K.reshape(len(members), B * cfg, nk, nk)
        for j, (i, p, dropt) in enumerate(members):
            shp = (B,) + p.cards
            out[i] = CGPotential(
                p.dscope, p.cards, keeps[j], g[j].reshape(shp),
                h[j].reshape(shp + (nk,)), K[j].reshape(shp + (nk, nk)))
    return out


def weak_marginalize_many(
    items: Sequence[Tuple[CGPotential, Sequence[str], Sequence[str]]], *,
    use_pallas: bool = False,
) -> list:
    """Batched :func:`weak_marginalize` over same-shaped beliefs.

    ``items``: (belief, keep_disc, keep_cont) triples.  Pure-continuous
    drops route through :func:`marginalize_cont_many`; table-only beliefs
    logsumexp per item (already one cheap op); the general moment-matching
    path buckets by (|cscope|, kept heads, kept configs M, dropped configs
    N, B) and runs the to_moment / moment_match / to_canonical chain ONCE
    per bucket on stacked [S*B, M, N, ...] tables.
    """
    out: list = [None] * len(items)
    cont_idx: list = []
    cont_items: list = []
    buckets: Dict[Tuple[int, int, int, int, int], list] = {}
    for i, (p, keep_disc, keep_cont) in enumerate(items):
        keep_d, keep_c = set(keep_disc), set(keep_cont)
        drop_d = tuple(v for v in p.dscope if v not in keep_d)
        drop_c = tuple(v for v in p.cscope if v not in keep_c)
        if not drop_d:
            cont_idx.append(i)
            cont_items.append((p, drop_c))
            continue
        if not p.cscope:
            out[i] = marginalize_disc(p, drop_d)
            continue
        keep_ds = tuple(v for v in p.dscope if v in keep_d)
        kcards = tuple(p.cards[p.dscope.index(v)] for v in keep_ds)
        M = int(np.prod(kcards)) if kcards else 1
        N = _cfg(p) // M
        n = len(p.cscope)
        nkc = n - len(drop_c)
        key = (n, nkc, M, N, p.g.shape[0])
        buckets.setdefault(key, []).append((i, p, keep_ds, drop_d, drop_c))
    for i, r in zip(cont_idx, marginalize_cont_many(cont_items)):
        out[i] = r
    for (n, nkc, M, N, B), members in buckets.items():
        if len(members) == 1:
            i, p, keep_ds, drop_d, drop_c = members[0]
            out[i] = weak_marginalize(p, keep_ds,
                                      tuple(v for v in p.cscope
                                            if v not in set(drop_c)),
                                      use_pallas=use_pallas)
            continue
        gs, hs, Ks, metas = [], [], [], []
        for i, p, keep_ds, drop_d, drop_c in members:
            keep_cs = tuple(v for v in p.cscope if v not in set(drop_c))
            nb = 1 + len(p.dscope)
            perm = (0,) + tuple(1 + p.dscope.index(v)
                                for v in keep_ds + drop_d)
            corder = np.asarray(
                [p.cscope.index(v)
                 for v in keep_cs + tuple(v for v in p.cscope
                                          if v in set(drop_c))], np.int32)
            gs.append(jnp.transpose(p.g, perm).reshape(B, M, N))
            hs.append(jnp.transpose(p.h, perm + (nb,))[..., corder]
                      .reshape(B, M, N, n))
            Ks.append(jnp.transpose(p.K, perm + (nb, nb + 1))
                      [..., corder[:, None], corder[None, :]]
                      .reshape(B, M, N, n, n))
            kcards = tuple(p.cards[p.dscope.index(v)] for v in keep_ds)
            metas.append((keep_ds, kcards, keep_cs))
        names = tuple(f"_c{j}" for j in range(n))
        q = CGPotential(("_keep", "_drop"), (M, N), names,
                        jnp.concatenate(gs), jnp.concatenate(hs),
                        jnp.concatenate(Ks))
        r = weak_marginalize(q, ("_keep",), names[:nkc],
                             use_pallas=use_pallas)
        g = r.g.reshape(len(members), B, M)
        h = r.h.reshape(len(members), B, M, nkc)
        K = r.K.reshape(len(members), B, M, nkc, nkc)
        for j, (i, p, keep_ds, drop_d, drop_c) in enumerate(members):
            keep_ds_j, kcards, keep_cs = metas[j]
            shp = (B,) + kcards
            out[i] = CGPotential(
                keep_ds_j, kcards, keep_cs, g[j].reshape(shp),
                h[j].reshape(shp + (nkc,)), K[j].reshape(shp + (nkc, nkc)))
    return out


# -- queries ------------------------------------------------------------------


def discrete_table(p: CGPotential) -> jnp.ndarray:
    """Exact discrete log-marginal table [B, *cards] of a belief: integrate
    every continuous head, keep the full discrete scope."""
    out = marginalize_cont(p, p.cscope)
    return out.g


def log_norm(p: CGPotential) -> jnp.ndarray:
    """log of the potential's total mass: integrate continuous, sum
    discrete -> [B]."""
    out = marginalize_cont(p, p.cscope)
    return jsp.logsumexp(out.g, axis=tuple(range(1, out.g.ndim)))
