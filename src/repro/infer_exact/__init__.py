"""Native exact inference — the HUGIN-link replacement (paper §2.2, §3).

The AMIDST toolbox obtains exact posteriors only by *interfacing out* to the
commercial HUGIN engine; this package is the in-repo replacement: a
junction-tree engine for the CLG ``BayesianNetwork`` of ``repro.core.dag``
whose factor algebra is batched over evidence instances and backed by Pallas
kernels (``repro.kernels.factor_ops``).

Modules:
  graph         moralization, min-fill triangulation, junction-tree
                construction with running-intersection verification; strong
                triangulation + strong-root directed trees for CLG networks
                with continuous-continuous edges (static Python over DAG)
  factors       batched log-space discrete factor algebra (product,
                marginalize, evidence reduction) with a Pallas fast path
  cg_potentials batched conditional-Gaussian potential algebra — canonical
                (g, h, K) and moment (p, mu, Sigma) forms with combine /
                strong-marginalize / weak-marginalize (moment matching) ops
  engine        JunctionTreeEngine — two-pass (collect/distribute) belief
                propagation; discrete pipeline for mixture-style networks,
                Lauritzen's strong junction tree for the full CLG class
                (unobserved continuous internal nodes included)
  brute         brute-force enumeration oracle for tests and tiny networks
                (full CLG: per-configuration joint Gaussians)
"""

from repro.infer_exact.brute import (brute_posterior,
                                     brute_posterior_mean_var,
                                     enumerate_log_joint)
from repro.infer_exact.cg_potentials import CGPotential, MomentPotential
from repro.infer_exact.engine import JunctionTreeEngine
from repro.infer_exact.factors import Factor
from repro.infer_exact.graph import (JunctionTree, compile_junction_tree,
                                     compile_strong_junction_tree)

__all__ = [
    "JunctionTreeEngine",
    "JunctionTree",
    "compile_junction_tree",
    "compile_strong_junction_tree",
    "Factor",
    "CGPotential",
    "MomentPotential",
    "brute_posterior",
    "brute_posterior_mean_var",
    "enumerate_log_joint",
]
