"""Native exact inference — the HUGIN-link replacement (paper §2.2, §3).

The AMIDST toolbox obtains exact posteriors only by *interfacing out* to the
commercial HUGIN engine; this package is the in-repo replacement: a
junction-tree engine for the CLG ``BayesianNetwork`` of ``repro.core.dag``
whose factor algebra is batched over evidence instances and backed by Pallas
kernels (``repro.kernels.factor_ops``).

Modules:
  graph      moralization, min-fill triangulation, junction-tree construction
             with running-intersection verification (static Python over DAG)
  factors    batched log-space discrete factor algebra (product, marginalize,
             evidence reduction) with a Pallas fast path
  engine     JunctionTreeEngine — two-pass (collect/distribute) belief
             propagation; continuous CLG leaves by analytic conditioning
  brute      brute-force enumeration oracle for tests and tiny networks
"""

from repro.infer_exact.brute import brute_posterior, enumerate_log_joint
from repro.infer_exact.engine import JunctionTreeEngine
from repro.infer_exact.factors import Factor
from repro.infer_exact.graph import JunctionTree, compile_junction_tree

__all__ = [
    "JunctionTreeEngine",
    "JunctionTree",
    "compile_junction_tree",
    "Factor",
    "brute_posterior",
    "enumerate_log_joint",
]
