"""Batched log-space factor algebra over discrete variables.

A :class:`Factor` is a named-scope log-probability table with an optional
leading batch axis (one slice per evidence instance — the whole junction
tree propagates B queries in one device call).  Scopes and cardinalities are
static Python; tables are jnp arrays, so every operation traces cleanly
under ``jax.jit`` / ``jax.vmap``.

The two hot loops of junction-tree propagation — sepset absorption (factor
product against a message) and marginalization onto a sepset — dispatch to
the Pallas kernels in ``repro.kernels.factor_ops`` when ``use_pallas`` is
on; the default is the pure-jnp path (identical semantics, and the kernels
are verified against it in tests/test_kernels.py).
"""

from __future__ import annotations

import math
import os
from typing import Dict, NamedTuple, Sequence, Tuple

import jax.numpy as jnp
import jax.scipy.special as jsp

# Flip on to route marginalize/absorb through the Pallas kernels
# (interpret-mode on CPU; compiled on TPU via REPRO_PALLAS_COMPILE=1).
USE_PALLAS = os.environ.get("REPRO_EXACT_PALLAS", "0") == "1"

NEG_INF = float("-inf")


class Factor(NamedTuple):
    """log p over ``scope``; table shape = batch_shape + cards."""

    scope: Tuple[str, ...]
    cards: Tuple[int, ...]
    logp: jnp.ndarray

    @property
    def batch_ndim(self) -> int:
        return self.logp.ndim - len(self.scope)


def _expand(f: Factor, scope: Tuple[str, ...], cards: Tuple[int, ...]
            ) -> jnp.ndarray:
    """Broadcast ``f.logp`` onto the superset ``scope`` (batch axes lead)."""
    nb = f.batch_ndim
    pos = {v: i for i, v in enumerate(f.scope)}
    order = sorted(range(len(f.scope)), key=lambda i: scope.index(f.scope[i]))
    t = jnp.transpose(f.logp, tuple(range(nb)) + tuple(nb + i for i in order))
    for axis, v in enumerate(scope):
        if v not in pos:
            t = jnp.expand_dims(t, nb + axis)
    return t


def product(factors: Sequence[Factor]) -> Factor:
    """Log-space factor product: union scope, broadcast add."""
    scope: Tuple[str, ...] = ()
    card_of: Dict[str, int] = {}
    for f in factors:
        for v, c in zip(f.scope, f.cards):
            if v not in card_of:
                scope = scope + (v,)
                card_of[v] = c
            elif card_of[v] != c:
                raise ValueError(f"cardinality clash for {v}")
    cards = tuple(card_of[v] for v in scope)
    t = _expand(factors[0], scope, cards)
    for f in factors[1:]:
        t = t + _expand(f, scope, cards)
    return Factor(scope, cards, t)


def absorb(f: Factor, msg: Factor, *, use_pallas: bool = False) -> Factor:
    """``f * msg`` where ``msg.scope`` is a subset of ``f.scope``.

    This is the sepset-absorption hot loop; with ``use_pallas`` the tables
    are flattened to [B, M, N] (sepset vars minor) and the add runs in the
    ``log_product`` kernel.
    """
    if not set(msg.scope) <= set(f.scope):
        return product([f, msg])
    if not use_pallas or f.batch_ndim != 1 or msg.batch_ndim != 1:
        return product([f, msg])
    from repro.kernels import ops

    sep = msg.scope
    keep = tuple(v for v in f.scope if v not in sep)
    perm_scope = keep + sep
    ft = _permute(f, perm_scope)
    B = ft.shape[0]
    m = math.prod(f.cards[f.scope.index(v)] for v in keep)
    n = math.prod(msg.cards)
    mt = _permute(msg, sep)
    out = ops.log_product(ft.reshape(B, m, n), mt.reshape(B, n))
    cards = tuple(f.cards[f.scope.index(v)] for v in perm_scope)
    return Factor(perm_scope, cards, out.reshape((B,) + cards))


def _permute(f: Factor, scope: Tuple[str, ...]) -> jnp.ndarray:
    """Reorder ``f``'s table axes to match ``scope`` (same variable set)."""
    nb = f.batch_ndim
    perm = tuple(nb + f.scope.index(v) for v in scope)
    return jnp.transpose(f.logp, tuple(range(nb)) + perm)


def marginalize(f: Factor, keep: Sequence[str], *,
                use_pallas: bool = False) -> Factor:
    """logsumexp out every variable not in ``keep``."""
    keep = tuple(v for v in f.scope if v in set(keep))
    drop = tuple(v for v in f.scope if v not in set(keep))
    if not drop:
        return Factor(keep, tuple(f.cards[f.scope.index(v)] for v in keep),
                      _permute(f, keep))
    cards_keep = tuple(f.cards[f.scope.index(v)] for v in keep)
    t = _permute(f, keep + drop)
    if use_pallas and f.batch_ndim == 1:
        from repro.kernels import ops

        B = t.shape[0]
        m = math.prod(cards_keep)
        n = math.prod(f.cards[f.scope.index(v)] for v in drop)
        out = ops.log_marginalize(t.reshape(B, m, n))
        return Factor(keep, cards_keep, out.reshape((B,) + cards_keep))
    nb = f.batch_ndim
    axes = tuple(range(nb + len(keep), nb + len(f.scope)))
    return Factor(keep, cards_keep, jsp.logsumexp(t, axis=axes))


def reduce_evidence(f: Factor, var: str, idx: jnp.ndarray, *,
                    use_pallas: bool = False) -> Factor:
    """Clamp ``var`` to per-instance values ``idx`` ([B] int), dropping it.

    Shrink-style evidence reduction: the observed axis disappears, so
    downstream messages are smaller.  ``JunctionTreeEngine`` folds evidence
    as :func:`indicator` factors instead (static clique shapes per evidence
    schema); this op is the algebra layer's alternative for callers that
    want the smaller tables.
    """
    keep = tuple(v for v in f.scope if v != var)
    cards_keep = tuple(f.cards[f.scope.index(v)] for v in keep)
    t = _permute(f, keep + (var,))
    nb = f.batch_ndim
    if nb == 0:
        t = t[None]
        idx = jnp.asarray(idx).reshape(1)
        nb = 1
    B = t.shape[0]
    n = f.cards[f.scope.index(var)]
    flat = t.reshape(B, math.prod(cards_keep), n)
    if use_pallas:
        from repro.kernels import ops

        out = ops.evidence_select(flat, idx)
    else:
        out = jnp.take_along_axis(
            flat, idx.astype(jnp.int32)[:, None, None], axis=-1)[..., 0]
    out = out.reshape((B,) + cards_keep)
    if f.batch_ndim == 0:
        out = out[0]
    return Factor(keep, cards_keep, out)


def indicator(var: str, card: int, idx: jnp.ndarray) -> Factor:
    """log 1[x_var == idx] as a batched factor ([B] -> [B, card])."""
    idx = jnp.asarray(idx, jnp.int32).reshape(-1)
    onehot = idx[:, None] == jnp.arange(card)[None, :]
    return Factor((var,), (card,), jnp.where(onehot, 0.0, NEG_INF))


def normalize(f: Factor) -> Factor:
    """Normalize over scope axes (per batch instance)."""
    nb = f.batch_ndim
    axes = tuple(range(nb, f.logp.ndim))
    z = jsp.logsumexp(f.logp, axis=axes, keepdims=True)
    return Factor(f.scope, f.cards, f.logp - z)
