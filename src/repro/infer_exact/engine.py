"""JunctionTreeEngine — native exact inference for CLG Bayesian networks.

Replaces the AMIDST paper's HUGIN link (§2.2): the same ``set_model /
set_evidence / run_inference / posterior_*`` surface as
``repro.core.importance_sampling.ImportanceSampling``, but exact.

Two-pass (collect/distribute) belief propagation on the compiled clique
tree.  All tables carry a leading evidence-batch axis, so ``set_evidence``
with ``[B]``-shaped value arrays propagates B query instances through the
tree in ONE jitted device call — the serving path batches requests that
share an evidence *schema* (set of observed names) onto this axis.

Two compilation pipelines, chosen statically from the network:

  * **discrete pipeline** — networks whose continuous nodes have no
    continuous parents (mixtures, naive Bayes, ...).  Continuous CLG nodes
    are handled by analytic conditioning on their discrete parents: an
    observed node's likelihood lambda(d_pa) enters the clique holding its
    (married) discrete parents; an unobserved one integrates to 1 during
    propagation and is queried as the analytic mixture of its per-
    configuration Gaussians.  Tables are plain discrete factors
    (``factors.py``) with Pallas fast paths.

  * **strong pipeline** (Lauritzen 1992) — any network with a continuous-
    continuous edge, including unobserved continuous INTERNAL nodes with
    observed continuous descendants (FA/PPCA-style structures).  The clique
    tree is strongly triangulated and rooted (``graph.py``); potentials are
    conditional-Gaussian ``(g, h, K)`` / ``(p, mu, Sigma)`` tables
    (``cg_potentials.py``).  The collect pass toward the strong root uses
    EXACT strong marginalization (Gaussian integrals, then table sums); the
    distribute pass uses weak (moment-matched) marginals, so every clique
    ends up holding the true weak marginal of the posterior — queried
    discrete marginals, means and variances are exact.
"""

from __future__ import annotations

import warnings
from functools import partial
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.dag import BayesianNetwork, Variable
from repro.infer_exact import cg_potentials as CG
from repro.infer_exact import factors as F
from repro.infer_exact.graph import (JunctionTree, compile_junction_tree,
                                     compile_strong_junction_tree)
from repro import obs
from repro.serve.plan import PlanCache, PlanKey


def _needs_strong(bn: BayesianNetwork) -> bool:
    """Strong pipeline iff some continuous node has a continuous parent."""
    for v in bn.order:
        if v.is_discrete:
            continue
        if any(not p.is_discrete for p in bn.dag.get_parents(v)):
            return True
    return False


class JunctionTreeEngine:
    """Paper §3.4 inference API, exact flavor."""

    def __init__(self, bn: Optional[BayesianNetwork] = None, *,
                 use_pallas: Optional[bool] = None,
                 bucketed: bool = True,
                 plan_cache: Optional[PlanCache] = None,
                 network_version: int = 0) -> None:
        self.use_pallas = F.USE_PALLAS if use_pallas is None else use_pallas
        # strong pipeline: batch per-clique solve/slogdet/weak-marginal calls
        # through shape buckets per tree level (False = one call per clique,
        # the reference schedule; results are identical — tested)
        self.bucketed = bucketed
        self.bn: Optional[BayesianNetwork] = None
        self.jt: Optional[JunctionTree] = None
        self.evidence: Dict[str, jnp.ndarray] = {}
        self._beliefs: Optional[Tuple] = None
        self._logz: Optional[jnp.ndarray] = None
        self._batched = False
        # AOT propagation programs live in a PlanCache keyed on
        # (network_version, pipeline, schema, batch, dtypes).  A shared
        # cache (the serving tier passes one) lets exact-JT plans coexist
        # with vmp/temporal plans under one LRU + one hit-rate counter.
        self.plans = plan_cache if plan_cache is not None else PlanCache()
        self.network_version = network_version
        self.last_run: Optional[Dict[str, object]] = None
        if bn is not None:
            self.set_model(bn, network_version=network_version)

    @property
    def _compiled(self) -> Dict[Tuple, object]:
        """Deprecated pre-plan-API cache view: ``{(schema, batch, dtypes):
        executable}`` for the CURRENT network version.  Use
        ``self.plans`` (:class:`~repro.serve.plan.PlanCache`) instead;
        this read-only shim is removed one release after the plan API."""
        warnings.warn(
            "JunctionTreeEngine._compiled is deprecated; use "
            "JunctionTreeEngine.plans (repro.serve.plan.PlanCache)",
            DeprecationWarning, stacklevel=2)
        return {(k.schema, k.batch_shape[0], k.dtypes): p._fn
                for k, p in ((k, self.plans.peek(k))
                             for k in self.plans.keys())
                if p is not None and k.network_version == self.network_version
                and k.mode.startswith("jt-")}

    # -- compilation ---------------------------------------------------------

    def set_model(self, bn: BayesianNetwork, *,
                  network_version: Optional[int] = None) -> None:
        """(Re)compile the junction tree for ``bn``.

        ``network_version`` stamps the plan keys of every propagation
        program compiled for this network; re-setting a model without an
        explicit version bumps it, so stale plans (which bake the old
        network's CPDs in as compiled constants) can never serve the new
        one.  They age out of the LRU rather than being dropped eagerly —
        the hot-swap drain calls ``plans.invalidate(old_version)``.
        """
        if network_version is not None:
            self.network_version = network_version
        elif self.bn is not None:
            self.network_version += 1
        self.bn = bn
        self.strong = _needs_strong(bn)
        self.jt = (compile_strong_junction_tree(bn) if self.strong
                   else compile_junction_tree(bn))
        self._card = {v.name: v.card for v in bn.order if v.is_discrete}
        self._cont = {v.name for v in bn.order if not v.is_discrete}
        # canonical (sorted) scopes per clique — the jitted propagation's
        # static output layout
        self._scopes: Tuple[Tuple[str, ...], ...] = tuple(
            tuple(sorted(c - self._cont)) for c in self.jt.cliques)
        self._cscopes: Tuple[Tuple[str, ...], ...] = tuple(
            tuple(sorted(c & self._cont)) for c in self.jt.cliques)
        # home clique of every CPD / lambda factor
        self._home: Dict[str, Optional[int]] = {}
        for v in bn.order:
            if self.strong:
                fam = {v.name} | {p.name for p in bn.dag.get_parents(v)}
                self._home[v.name] = self.jt.smallest_containing(fam)
                continue
            dpa = {p.name for p in bn.dag.get_parents(v) if p.is_discrete}
            if v.is_discrete:
                self._home[v.name] = self.jt.smallest_containing({v.name} | dpa)
            else:
                self._home[v.name] = (
                    self.jt.smallest_containing(dpa) if dpa else 0)
        # message schedule: DFS from the root, children -> root then back
        root = self.jt.root
        adj: Dict[int, List[Tuple[int, Tuple[str, ...]]]] = {
            i: [] for i in range(len(self.jt.cliques))}
        for (a, b), s in zip(self.jt.edges, self.jt.sepsets):
            sep = tuple(sorted(s))
            adj[a].append((b, sep))
            adj[b].append((a, sep))
        seen = {root}
        stack: List[Tuple[int, int, Tuple[str, ...]]] = [
            (c, root, s) for c, s in adj[root]]
        pre: List[Tuple[int, int, Tuple[str, ...]]] = []
        while stack:
            u, p, s = stack.pop()
            if u in seen:
                continue
            seen.add(u)
            pre.append((u, p, s))
            for w, sw in adj[u]:
                if w not in seen:
                    stack.append((w, u, sw))
        self._collect = tuple(reversed(pre))     # post-order: leaves first
        self._distribute = tuple(pre)            # root outward
        self._beliefs = None

    # -- evidence / propagation ----------------------------------------------

    def set_evidence(self, evidence: Dict[str, object]) -> None:
        ev = {k: jnp.asarray(v) for k, v in evidence.items()}
        if self.bn is not None:
            by_name = {v.name: v for v in self.bn.order}
            for k, a in ev.items():
                if k not in by_name:
                    raise ValueError(f"unknown evidence variable {k!r}")
                v = by_name[k]
                if v.is_discrete:
                    import numpy as np

                    vals = np.asarray(a)
                    if vals.size and ((vals < 0) | (vals >= v.card)).any():
                        raise ValueError(
                            f"evidence for {k!r} outside [0, {v.card})")
        self.evidence = ev
        self._beliefs = None

    def _plan_levels(self) -> List[int]:
        """Clique count per tree depth (root = level 0) — the propagation
        plan shape both pipelines schedule by."""
        depth = {self.jt.root: 0}
        for u, p, _ in self._distribute:     # preorder: parent before child
            depth[u] = depth[p] + 1
        levels = [0] * (max(depth.values()) + 1 if depth else 1)
        for d in depth.values():
            levels[d] += 1
        return levels

    def run_inference(self) -> None:
        """Propagate. One device call for the full (batched) tree.

        Zero-probability evidence is reported as ``log_evidence() == -inf``
        (posteriors are then 0/0 = NaN — check the evidence first).

        Propagation programs are compiled ahead-of-time per
        ``(schema, batch, dtypes)`` key, which splits compile from execute
        time; ``self.last_run`` always records
        ``{"cache_hit", "compile_us", "execute_us", "batch", "pipeline"}``
        (the serving tier's per-bucket split), and ``obs`` additionally gets
        ``jt.compile``/``jt.execute`` spans plus a ``jt_plan`` event (per-
        level clique counts) at trace level.
        """
        import time as _time

        names = tuple(sorted(self.evidence))
        vals = []
        B = 1
        for n in names:
            a = self.evidence[n].reshape(-1)
            B = max(B, a.shape[0])
            vals.append(a)
        sizes = {v.shape[0] for v in vals if v.shape[0] > 1}
        if len(sizes) > 1:
            raise ValueError(
                f"evidence batch lengths disagree: {sorted(sizes)}")
        self._batched = any(v.shape[0] > 1 for v in vals)
        vals = tuple(jnp.broadcast_to(v, (B,)) for v in vals)
        pipeline = "strong" if self.strong else "discrete"
        # AOT executables do not retrace on new shapes the way lazy jit
        # does, so the plan key carries everything shape-affecting (plus
        # the network version: the compiled program bakes the CPDs in)
        key = PlanKey(self.network_version, f"jt-{pipeline}", names, (B,),
                      tuple(str(v.dtype) for v in vals))
        cache_hit = self.plans.peek(key) is not None
        compile_us = 0.0
        if not cache_hit:
            prop = self._propagate_strong if self.strong else self._propagate

            def build():
                with obs.span("jt.compile", schema=",".join(names), batch=B,
                              pipeline=pipeline):
                    return jax.jit(partial(prop, names)).lower(vals).compile()

            plan = self.plans.get(key, build)
            compile_us = plan.compile_us
            if obs.enabled():
                obs.emit("jt_plan", pipeline=pipeline,
                         n_cliques=len(self.jt.cliques),
                         levels=self._plan_levels(),
                         bucketed=self.bucketed, batch=B,
                         schema=",".join(names))
        else:
            plan = self.plans.get(key)
        self._run_names = names
        t0 = _time.perf_counter_ns()
        with obs.span("jt.execute", schema=",".join(names), batch=B,
                      pipeline=pipeline, cache_hit=cache_hit):
            out = plan.run(vals)
            if obs.enabled(obs.TRACE):
                # only at trace level: force the async dispatch to finish so
                # the span measures device time, not enqueue time
                out = jax.block_until_ready(out)
        execute_us = (_time.perf_counter_ns() - t0) / 1e3
        self._beliefs, self._logz = out
        self.last_run = {"cache_hit": cache_hit, "compile_us": compile_us,
                         "execute_us": execute_us, "batch": B,
                         "pipeline": pipeline}

    # ======================= discrete pipeline ==============================

    def _cpd_factor(self, v: Variable) -> F.Factor:
        """log CPD table of a discrete node as a Factor (parents-major)."""
        dpa = [p.name for p in self.bn.dag.get_parents(v) if
               self._card.get(p.name) is not None]
        scope = tuple(dpa) + (v.name,)
        cards = tuple(self._card[n] for n in scope)
        return F.Factor(scope, cards,
                        jnp.log(jnp.asarray(self.bn.cpds[v.name].table)))

    def _lambda_factor(self, v: Variable, ev: Dict[str, jnp.ndarray],
                       B: int) -> F.Factor:
        """Evidence likelihood of an observed continuous node over its
        discrete parents (analytic CLG conditioning).  Continuous parents
        cannot occur here — those networks compile the strong pipeline."""
        parents = self.bn.dag.get_parents(v)
        dpa = [p for p in parents if p.is_discrete]
        cpd = self.bn.cpds[v.name]
        alpha = jnp.asarray(cpd.alpha)                 # [*dcards]
        sigma2 = jnp.asarray(cpd.sigma2)
        mean = jnp.broadcast_to(alpha, (B,) + alpha.shape)
        x = ev[v.name].reshape((B,) + (1,) * alpha.ndim)
        ll = -0.5 * (jnp.log(2 * jnp.pi * sigma2) + (x - mean) ** 2 / sigma2)
        scope = tuple(p.name for p in dpa)
        cards = tuple(self._card[n] for n in scope)
        return F.Factor(scope, cards, ll)

    def _potentials(self, names: Tuple[str, ...],
                    values: Tuple[jnp.ndarray, ...]) -> List[F.Factor]:
        """Batched clique log-potentials with evidence folded in."""
        B = values[0].shape[0] if values else 1
        ev = dict(zip(names, values))
        pots: List[F.Factor] = []
        for scope in self._scopes:
            cards = tuple(self._card[n] for n in scope)
            pots.append(F.Factor(scope, cards, jnp.zeros((B,) + cards)))

        def add(ci: int, f: F.Factor) -> None:
            pots[ci] = F.product([pots[ci], f])

        for v in self.bn.order:
            if v.is_discrete:
                add(self._home[v.name], self._cpd_factor(v))
                if v.name in ev:
                    idx = ev[v.name].astype(jnp.int32)
                    add(self.jt.smallest_containing({v.name}),
                        F.indicator(v.name, v.card, idx))
            elif v.name in ev:
                add(self._home[v.name], self._lambda_factor(v, ev, B))
        return pots

    def _propagate(self, names: Tuple[str, ...],
                   values: Tuple[jnp.ndarray, ...]
                   ) -> Tuple[Tuple[jnp.ndarray, ...], jnp.ndarray]:
        pots = self._potentials(names, values)
        up = self.use_pallas
        msgs: Dict[Tuple[int, int], F.Factor] = {}
        # collect: leaves -> root
        for u, p, sep in self._collect:
            f = pots[u]
            for w, _, _ in self._collect:
                if (w, u) in msgs:
                    f = F.absorb(f, msgs[(w, u)], use_pallas=up)
            msgs[(u, p)] = F.marginalize(f, sep, use_pallas=up)
        # distribute: root -> leaves
        for u, p, sep in self._distribute:
            f = pots[p]
            for (a, b), m in list(msgs.items()):
                if b == p and a != u:
                    f = F.absorb(f, m, use_pallas=up)
            msgs[(p, u)] = F.marginalize(f, sep, use_pallas=up)
        # beliefs
        beliefs: List[jnp.ndarray] = []
        logz = None
        for i, scope in enumerate(self._scopes):
            f = pots[i]
            for (a, b), m in msgs.items():
                if b == i:
                    f = F.absorb(f, m, use_pallas=up)
            table = F._permute(f, scope)
            beliefs.append(table)
            if i == self.jt.root:
                logz = F.marginalize(F.Factor(scope, f.cards, table), (),
                                     use_pallas=False).logp
        return tuple(beliefs), logz

    # ======================= strong pipeline ================================

    def _run_cscopes(self, names: Tuple[str, ...]
                     ) -> Tuple[Tuple[str, ...], ...]:
        """Per-clique continuous scope once observed heads are instantiated
        (static per evidence schema)."""
        obs = set(names)
        return tuple(tuple(v for v in cs if v not in obs)
                     for cs in self._cscopes)

    def _strong_potentials(self, names: Tuple[str, ...],
                           values: Tuple[jnp.ndarray, ...]
                           ) -> List[CG.CGPotential]:
        B = values[0].shape[0] if values else 1
        ev = dict(zip(names, values))
        cscopes = self._run_cscopes(names)
        pots = [CG.zeros(scope, tuple(self._card[n] for n in scope), cs, B)
                for scope, cs in zip(self._scopes, cscopes)]

        def add(ci: int, q: CG.CGPotential) -> None:
            pots[ci] = CG.combine(pots[ci], q)

        for v in self.bn.order:
            parents = self.bn.dag.get_parents(v)
            raw_dpa = tuple(p.name for p in parents if p.is_discrete)
            dpa = tuple(sorted(raw_dpa))
            dcards = tuple(self._card[n] for n in dpa)
            cpd = self.bn.cpds[v.name]
            if v.is_discrete:
                # CPD tables are laid out in RAW get_parents order; label the
                # factor accordingly and let _permute reorder to sorted scope
                raw_cards = tuple(self._card[n] for n in raw_dpa)
                f = F.Factor(raw_dpa + (v.name,), raw_cards + (v.card,),
                             jnp.log(jnp.asarray(cpd.table)))
                scope = tuple(sorted(f.scope))
                q = CG.from_discrete_table(
                    scope, tuple(self._card[n] for n in scope),
                    F._permute(f, scope))
                add(self._home[v.name], q)
                if v.name in ev:
                    ind = F.indicator(v.name, v.card, ev[v.name])
                    ci = self.jt.smallest_containing({v.name})
                    pots[ci] = CG.add_discrete_log(
                        pots[ci], (v.name,), (v.card,), ind.logp)
                continue
            # continuous CLG node: canonical CPD over (v, *cont parents),
            # permuted so discrete-parent axes follow the sorted convention
            cpa = [p.name for p in parents if not p.is_discrete]
            alpha = jnp.asarray(cpd.alpha, jnp.float32)
            beta = jnp.asarray(cpd.beta, jnp.float32)
            sigma2 = jnp.asarray(cpd.sigma2, jnp.float32)
            if raw_dpa != dpa:                   # permute table axes
                perm = tuple(raw_dpa.index(n) for n in dpa)
                alpha = jnp.transpose(alpha, perm)
                sigma2 = jnp.transpose(sigma2, perm)
                beta = jnp.transpose(beta, perm + (len(raw_dpa),))
            q = CG.from_clg(alpha, beta, sigma2, dpa, dcards,
                            (v.name,) + tuple(cpa))
            q = CG.reduce_evidence(q, {k: ev[k] for k in (v.name, *cpa)
                                       if k in ev})
            add(self._home[v.name], q)
        return pots

    def _propagate_strong(self, names: Tuple[str, ...],
                          values: Tuple[jnp.ndarray, ...]):
        """Level-ordered two-pass propagation.

        Cliques at the same tree depth are independent given the previous
        level, so their canonical-form linalg (the collect pass's exact
        Gaussian integrals, the distribute pass's weak marginals) is batched
        through shape buckets — one stacked solve/slogdet/moment-match per
        (n_cont, n_config) bucket per level instead of one per clique
        (``bucketed=False`` restores the per-clique reference schedule).
        """
        pots = self._strong_potentials(names, values)
        cscopes = self._run_cscopes(names)
        up = self.use_pallas
        root = self.jt.root
        children: Dict[int, List[int]] = {}
        for u, p, _ in self._collect:
            children.setdefault(p, []).append(u)
        depth = {root: 0}
        for u, p, _ in self._distribute:     # preorder: parent before child
            depth[u] = depth[p] + 1
        by_level: Dict[int, List[Tuple[int, int, Tuple[str, ...]]]] = {}
        for u, p, sep in self._collect:
            by_level.setdefault(depth[u], []).append((u, p, sep))
        nmsg: Dict[Tuple[int, int], CG.CGPotential] = {}
        absorbed: List[CG.CGPotential] = list(pots)
        # collect: deepest level -> root, EXACT strong marginals: integrate
        # the continuous residual, then sum the (now table-only) discrete one
        for lev in sorted(by_level, reverse=True):
            entries = by_level[lev]
            items = []
            for u, p, sep in entries:
                f = absorbed[u]
                for w in children.get(u, ()):
                    f = CG.combine(f, nmsg[(w, u)])
                absorbed[u] = f
                sep_c = {v for v in cscopes[u] if v in set(sep)}
                items.append(
                    (f, tuple(v for v in f.cscope if v not in sep_c)))
            ms = (CG.marginalize_cont_many(items) if self.bucketed
                  else [CG.marginalize_cont(f_, d_) for f_, d_ in items])
            for (u, p, sep), m in zip(entries, ms):
                sep_d = {v for v in self._scopes[u] if v in set(sep)}
                nmsg[(u, p)] = CG.marginalize_disc(
                    m, tuple(v for v in m.dscope if v not in sep_d))
        beliefs: List[Optional[CG.CGPotential]] = [None] * len(pots)
        f = absorbed[root]
        for w in children.get(root, ()):
            f = CG.combine(f, nmsg[(w, root)])
        beliefs[root] = f
        logz = CG.log_norm(f)
        # distribute: root -> leaves, WEAK (moment-matched) marginals; all
        # edges leaving one level share one bucketed weak-marginal pass
        by_plevel: Dict[int, List[Tuple[int, int, Tuple[str, ...]]]] = {}
        for u, p, sep in self._distribute:
            by_plevel.setdefault(depth[p], []).append((u, p, sep))
        for lev in sorted(by_plevel):
            entries = by_plevel[lev]
            items = []
            for u, p, sep in entries:
                sep_set = set(sep)
                sep_d = tuple(v for v in self._scopes[p] if v in sep_set)
                sep_c = tuple(v for v in cscopes[p] if v in sep_set)
                items.append((beliefs[p], sep_d, sep_c))
            stars = (CG.weak_marginalize_many(items, use_pallas=up)
                     if self.bucketed
                     else [CG.weak_marginalize(b_, d_, c_, use_pallas=up)
                           for b_, d_, c_ in items])
            for (u, p, sep), star in zip(entries, stars):
                down = CG.divide(star, nmsg[(u, p)])
                beliefs[u] = CG.combine(absorbed[u], down)
        flat = tuple((b.g, b.h, b.K) for b in beliefs)
        return flat, logz

    def _strong_belief(self, ci: int) -> CG.CGPotential:
        g, h, K = self._beliefs[ci]
        return CG.CGPotential(
            self._scopes[ci],
            tuple(self._card[n] for n in self._scopes[ci]),
            self._run_cscopes(self._run_names)[ci], g, h, K)

    # -- queries -------------------------------------------------------------

    def _require_run(self) -> None:
        if self._beliefs is None:
            raise RuntimeError("call run_inference() first")

    def _joint(self, names: Tuple[str, ...]) -> jnp.ndarray:
        """Normalized joint log-posterior over discrete ``names``."""
        ci = self.jt.smallest_containing(set(names))
        scope = self._scopes[ci]
        cards = tuple(self._card[n] for n in scope)
        if self.strong:
            table = CG.discrete_table(self._strong_belief(ci))
        else:
            table = self._beliefs[ci]
        f = F.Factor(scope, cards, table)
        f = F.normalize(F.marginalize(f, names))
        return F._permute(f, names)

    def _maybe_squeeze(self, a: jnp.ndarray) -> jnp.ndarray:
        return a if self._batched else a[0]

    def posterior_discrete(self, var: Variable) -> jnp.ndarray:
        """p(var | e): [card], or [B, card] under batched evidence."""
        self._require_run()
        name = var.name if isinstance(var, Variable) else str(var)
        return self._maybe_squeeze(jnp.exp(self._joint((name,))))

    def posterior_mean_var(self, var: Variable
                           ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Posterior mean/variance of an unobserved continuous node — the
        exact moments of its posterior mixture."""
        self._require_run()
        if var.name in self.evidence:
            raise ValueError(f"{var.name!r} is observed")
        if self.strong:
            return self._strong_mean_var(var)
        parents = self.bn.dag.get_parents(var)
        dpa = [p for p in parents if p.is_discrete]
        cpd = self.bn.cpds[var.name]
        alpha = jnp.asarray(cpd.alpha)
        sigma2 = jnp.asarray(cpd.sigma2)
        B = self._logz.shape[0]
        if dpa:
            w = jnp.exp(self._joint(tuple(p.name for p in dpa)))  # [B,*dcards]
        else:
            w = jnp.ones((B,) + (1,) * alpha.ndim)
        mu = jnp.broadcast_to(alpha, (B,) + alpha.shape)
        axes = tuple(range(1, mu.ndim))
        mean = (w * mu).sum(axes)
        second = (w * (sigma2 + mu ** 2)).sum(axes)
        return (self._maybe_squeeze(mean),
                self._maybe_squeeze(second - mean ** 2))

    def _strong_mean_var(self, var: Variable
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Exact mixture moments from the clique belief holding ``var``."""
        cscopes = self._run_cscopes(self._run_names)
        ci = None
        for i, cs in enumerate(cscopes):
            if var.name in cs:
                if ci is None or len(cs) + len(self._scopes[i]) < (
                        len(cscopes[ci]) + len(self._scopes[ci])):
                    ci = i
        if ci is None:
            raise ValueError(f"{var.name!r} not in any clique "
                             "(is it observed?)")
        m = CG.to_moment(self._strong_belief(ci))
        iv = m.cscope.index(var.name)
        axes = tuple(range(1, m.logp.ndim))
        # collapse the whole mixture onto the single head: one shared
        # moment-matching implementation (same -inf/dead-config semantics
        # as the distribute pass)
        _, mu, sg = CG.moment_match(
            m.logp, m.mu[..., iv:iv + 1],
            m.sigma[..., iv:iv + 1, iv:iv + 1], axes)
        return (self._maybe_squeeze(mu[..., 0]),
                self._maybe_squeeze(sg[..., 0, 0]))

    def log_evidence(self) -> jnp.ndarray:
        """log p(e) — exact model evidence of the observed values."""
        self._require_run()
        return self._maybe_squeeze(self._logz)
