"""JunctionTreeEngine — native exact inference for CLG Bayesian networks.

Replaces the AMIDST paper's HUGIN link (§2.2): the same ``set_model /
set_evidence / run_inference / posterior_*`` surface as
``repro.core.importance_sampling.ImportanceSampling``, but exact.

Two-pass (collect/distribute) belief propagation on the compiled clique
tree.  All tables carry a leading evidence-batch axis, so ``set_evidence``
with ``[B]``-shaped value arrays propagates B query instances through the
tree in ONE jitted device call — the serving path batches requests that
share an evidence *schema* (set of observed names) onto this axis.

Continuous CLG nodes are handled by analytic conditioning on their discrete
parents:

  * observed   — its likelihood lambda(d_pa) = N(x; alpha(d)+beta(d)^T c,
                 sigma2(d)) enters the clique holding its (married) discrete
                 parents; continuous co-parents must be observed too.
  * unobserved — contributes nothing during propagation (integrates to 1);
                 queried posteriors are the analytic mixture of its per-
                 configuration Gaussians under the joint of its discrete
                 parents.  Unobserved continuous *internal* nodes with
                 observed continuous children need the strong junction tree
                 (ROADMAP open item) and raise ``NotImplementedError``.
"""

from __future__ import annotations

from functools import partial
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.dag import BayesianNetwork, Variable
from repro.infer_exact import factors as F
from repro.infer_exact.graph import JunctionTree, compile_junction_tree


class JunctionTreeEngine:
    """Paper §3.4 inference API, exact flavor."""

    def __init__(self, bn: Optional[BayesianNetwork] = None, *,
                 use_pallas: Optional[bool] = None) -> None:
        self.use_pallas = F.USE_PALLAS if use_pallas is None else use_pallas
        self.bn: Optional[BayesianNetwork] = None
        self.jt: Optional[JunctionTree] = None
        self.evidence: Dict[str, jnp.ndarray] = {}
        self._beliefs: Optional[Tuple[jnp.ndarray, ...]] = None
        self._logz: Optional[jnp.ndarray] = None
        self._batched = False
        self._compiled: Dict[Tuple[str, ...], object] = {}
        if bn is not None:
            self.set_model(bn)

    # -- compilation ---------------------------------------------------------

    def set_model(self, bn: BayesianNetwork) -> None:
        self.bn = bn
        self.jt = compile_junction_tree(bn)
        self._card = {v.name: v.card for v in bn.order if v.is_discrete}
        # canonical (sorted) scope per clique — the jitted propagation's
        # static output layout
        self._scopes: Tuple[Tuple[str, ...], ...] = tuple(
            tuple(sorted(c)) for c in self.jt.cliques)
        # home clique of every CPD / lambda factor
        self._home: Dict[str, Optional[int]] = {}
        for v in bn.order:
            dpa = {p.name for p in bn.dag.get_parents(v) if p.is_discrete}
            if v.is_discrete:
                self._home[v.name] = self.jt.smallest_containing({v.name} | dpa)
            else:
                self._home[v.name] = (
                    self.jt.smallest_containing(dpa) if dpa else 0)
        # message schedule: DFS from clique 0, children -> root then back
        adj: Dict[int, List[Tuple[int, Tuple[str, ...]]]] = {
            i: [] for i in range(len(self.jt.cliques))}
        for (a, b), s in zip(self.jt.edges, self.jt.sepsets):
            sep = tuple(sorted(s))
            adj[a].append((b, sep))
            adj[b].append((a, sep))
        schedule: List[Tuple[int, int, Tuple[str, ...]]] = []  # (child, parent)
        seen = {0}
        stack: List[Tuple[int, int, Tuple[str, ...]]] = [
            (c, 0, s) for c, s in adj[0]]
        pre: List[Tuple[int, int, Tuple[str, ...]]] = []
        while stack:
            u, p, s = stack.pop()
            if u in seen:
                continue
            seen.add(u)
            pre.append((u, p, s))
            for w, sw in adj[u]:
                if w not in seen:
                    stack.append((w, u, sw))
        schedule = list(reversed(pre))           # post-order: leaves first
        self._collect = tuple(schedule)          # (child, parent, sepset)
        self._distribute = tuple(pre)            # root outward
        self._compiled = {}
        self._beliefs = None

    # -- evidence / propagation ----------------------------------------------

    def set_evidence(self, evidence: Dict[str, object]) -> None:
        ev = {k: jnp.asarray(v) for k, v in evidence.items()}
        if self.bn is not None:
            by_name = {v.name: v for v in self.bn.order}
            for k, a in ev.items():
                if k not in by_name:
                    raise ValueError(f"unknown evidence variable {k!r}")
                v = by_name[k]
                if v.is_discrete:
                    import numpy as np

                    vals = np.asarray(a)
                    if vals.size and ((vals < 0) | (vals >= v.card)).any():
                        raise ValueError(
                            f"evidence for {k!r} outside [0, {v.card})")
        self.evidence = ev
        self._beliefs = None

    def run_inference(self) -> None:
        """Propagate. One device call for the full (batched) tree.

        Zero-probability evidence is reported as ``log_evidence() == -inf``
        (posteriors are then 0/0 = NaN — check the evidence first).
        """
        names = tuple(sorted(self.evidence))
        vals = []
        B = 1
        for n in names:
            a = self.evidence[n].reshape(-1)
            B = max(B, a.shape[0])
            vals.append(a)
        sizes = {v.shape[0] for v in vals if v.shape[0] > 1}
        if len(sizes) > 1:
            raise ValueError(
                f"evidence batch lengths disagree: {sorted(sizes)}")
        self._batched = any(v.shape[0] > 1 for v in vals)
        vals = tuple(jnp.broadcast_to(v, (B,)) for v in vals)
        fn = self._compiled.get(names)
        if fn is None:
            fn = jax.jit(partial(self._propagate, names))
            self._compiled[names] = fn
        self._beliefs, self._logz = fn(vals)

    def _cpd_factor(self, v: Variable) -> F.Factor:
        """log CPD table of a discrete node as a Factor (parents-major)."""
        dpa = [p.name for p in self.bn.dag.get_parents(v) if
               self._card.get(p.name) is not None]
        scope = tuple(dpa) + (v.name,)
        cards = tuple(self._card[n] for n in scope)
        return F.Factor(scope, cards,
                        jnp.log(jnp.asarray(self.bn.cpds[v.name].table)))

    def _lambda_factor(self, v: Variable, ev: Dict[str, jnp.ndarray],
                       B: int) -> F.Factor:
        """Evidence likelihood of an observed continuous node over its
        discrete parents (analytic CLG conditioning)."""
        parents = self.bn.dag.get_parents(v)
        dpa = [p for p in parents if p.is_discrete]
        cpa = [p for p in parents if not p.is_discrete]
        for p in cpa:
            if p.name not in ev:
                raise NotImplementedError(
                    f"unobserved continuous parent {p.name!r} of observed "
                    f"{v.name!r}: needs the strong junction tree "
                    "(ROADMAP open item)")
        cpd = self.bn.cpds[v.name]
        alpha = jnp.asarray(cpd.alpha)                 # [*dcards]
        sigma2 = jnp.asarray(cpd.sigma2)
        mean = jnp.broadcast_to(alpha, (B,) + alpha.shape)
        if cpa:
            beta = jnp.asarray(cpd.beta)               # [*dcards, C]
            for ci, p in enumerate(cpa):
                val = ev[p.name].reshape((B,) + (1,) * alpha.ndim)
                mean = mean + beta[..., ci] * val
        x = ev[v.name].reshape((B,) + (1,) * alpha.ndim)
        ll = -0.5 * (jnp.log(2 * jnp.pi * sigma2) + (x - mean) ** 2 / sigma2)
        scope = tuple(p.name for p in dpa)
        cards = tuple(self._card[n] for n in scope)
        return F.Factor(scope, cards, ll)

    def _potentials(self, names: Tuple[str, ...],
                    values: Tuple[jnp.ndarray, ...]) -> List[F.Factor]:
        """Batched clique log-potentials with evidence folded in."""
        B = values[0].shape[0] if values else 1
        ev = dict(zip(names, values))
        pots: List[F.Factor] = []
        for scope in self._scopes:
            cards = tuple(self._card[n] for n in scope)
            pots.append(F.Factor(scope, cards, jnp.zeros((B,) + cards)))

        def add(ci: int, f: F.Factor) -> None:
            pots[ci] = F.product([pots[ci], f])

        for v in self.bn.order:
            if v.is_discrete:
                add(self._home[v.name], self._cpd_factor(v))
                if v.name in ev:
                    idx = ev[v.name].astype(jnp.int32)
                    add(self.jt.smallest_containing({v.name}),
                        F.indicator(v.name, v.card, idx))
            elif v.name in ev:
                add(self._home[v.name], self._lambda_factor(v, ev, B))
        return pots

    def _propagate(self, names: Tuple[str, ...],
                   values: Tuple[jnp.ndarray, ...]
                   ) -> Tuple[Tuple[jnp.ndarray, ...], jnp.ndarray]:
        pots = self._potentials(names, values)
        up = self.use_pallas
        msgs: Dict[Tuple[int, int], F.Factor] = {}
        # collect: leaves -> root
        for u, p, sep in self._collect:
            f = pots[u]
            for w, _, _ in self._collect:
                if (w, u) in msgs:
                    f = F.absorb(f, msgs[(w, u)], use_pallas=up)
            msgs[(u, p)] = F.marginalize(f, sep, use_pallas=up)
        # distribute: root -> leaves
        for u, p, sep in self._distribute:
            f = pots[p]
            for (a, b), m in list(msgs.items()):
                if b == p and a != u:
                    f = F.absorb(f, m, use_pallas=up)
            msgs[(p, u)] = F.marginalize(f, sep, use_pallas=up)
        # beliefs
        beliefs: List[jnp.ndarray] = []
        logz = None
        for i, scope in enumerate(self._scopes):
            f = pots[i]
            for (a, b), m in msgs.items():
                if b == i:
                    f = F.absorb(f, m, use_pallas=up)
            table = F._permute(f, scope)
            beliefs.append(table)
            if i == 0:
                logz = F.marginalize(F.Factor(scope, f.cards, table), (),
                                     use_pallas=False).logp
        return tuple(beliefs), logz

    # -- queries -------------------------------------------------------------

    def _require_run(self) -> None:
        if self._beliefs is None:
            raise RuntimeError("call run_inference() first")

    def _joint(self, names: Tuple[str, ...]) -> jnp.ndarray:
        """Normalized joint log-posterior over ``names`` (one clique)."""
        ci = self.jt.smallest_containing(set(names))
        scope = self._scopes[ci]
        cards = tuple(self._card[n] for n in scope)
        f = F.Factor(scope, cards, self._beliefs[ci])
        f = F.normalize(F.marginalize(f, names))
        return F._permute(f, names)

    def _maybe_squeeze(self, a: jnp.ndarray) -> jnp.ndarray:
        return a if self._batched else a[0]

    def posterior_discrete(self, var: Variable) -> jnp.ndarray:
        """p(var | e): [card], or [B, card] under batched evidence."""
        self._require_run()
        name = var.name if isinstance(var, Variable) else str(var)
        return self._maybe_squeeze(jnp.exp(self._joint((name,))))

    def posterior_mean_var(self, var: Variable
                           ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Mixture mean/variance of an unobserved continuous CLG node."""
        self._require_run()
        if var.name in self.evidence:
            raise ValueError(f"{var.name!r} is observed")
        parents = self.bn.dag.get_parents(var)
        dpa = [p for p in parents if p.is_discrete]
        cpa = [p for p in parents if not p.is_discrete]
        for p in cpa:
            if p.name not in self.evidence:
                raise NotImplementedError(
                    f"unobserved continuous parent {p.name!r}: needs the "
                    "strong junction tree (ROADMAP open item)")
        cpd = self.bn.cpds[var.name]
        alpha = jnp.asarray(cpd.alpha)
        sigma2 = jnp.asarray(cpd.sigma2)
        B = self._logz.shape[0]
        if dpa:
            w = jnp.exp(self._joint(tuple(p.name for p in dpa)))  # [B,*dcards]
        else:
            w = jnp.ones((B,) + (1,) * alpha.ndim)
        mu = jnp.broadcast_to(alpha, (B,) + alpha.shape)
        if cpa:
            beta = jnp.asarray(cpd.beta)
            for ci, p in enumerate(cpa):
                val = jnp.broadcast_to(
                    self.evidence[p.name].reshape(-1), (B,))
                mu = mu + beta[..., ci] * val.reshape((B,) + (1,) * alpha.ndim)
        axes = tuple(range(1, mu.ndim))
        mean = (w * mu).sum(axes)
        second = (w * (sigma2 + mu ** 2)).sum(axes)
        return (self._maybe_squeeze(mean),
                self._maybe_squeeze(second - mean ** 2))

    def log_evidence(self) -> jnp.ndarray:
        """log p(e) — exact model evidence of the observed values."""
        self._require_run()
        return self._maybe_squeeze(self._logz)
