"""Junction-tree compilation: moralize, triangulate, build, verify.

All static Python over the ``DAG`` of ``repro.core.dag`` — this runs once
per network at engine construction and produces the hashable structure the
jitted propagation closes over.

Pipeline (Lauritzen–Spiegelhalter):

  1. *Moralize* the discrete subgraph: connect every discrete node to its
     discrete parents and marry those parents pairwise.  The discrete-parent
     set of each **continuous** CLG node is married too, so the evidence
     likelihood lambda(d_pa) of an observed continuous leaf — and the joint
     needed to query an unobserved one — always fits inside one clique.
  2. *Triangulate* with the min-fill heuristic, collecting elimination
     cliques; keep the maximal ones.
  3. Build the tree as a maximum-weight spanning tree over pairwise sepset
     sizes (Kruskal; zero-weight edges permitted so disconnected moral
     graphs still yield a single tree — empty sepsets exchange only the
     subtree normalizer, which cancels on normalization).
  4. Verify the running-intersection property: for every variable the
     cliques containing it must induce a connected subtree.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, List, Sequence, Set, Tuple

from repro.core.dag import BayesianNetwork


@dataclasses.dataclass(frozen=True)
class JunctionTree:
    """Compiled clique-tree structure (no parameters, fully static)."""

    cliques: Tuple[FrozenSet[str], ...]
    edges: Tuple[Tuple[int, int], ...]          # tree edges (i < j)
    sepsets: Tuple[FrozenSet[str], ...]         # aligned with edges
    elimination_order: Tuple[str, ...]
    fill_in_count: int

    def neighbors(self, i: int) -> List[Tuple[int, FrozenSet[str]]]:
        out = []
        for (a, b), s in zip(self.edges, self.sepsets):
            if a == i:
                out.append((b, s))
            elif b == i:
                out.append((a, s))
        return out

    def smallest_containing(self, names: Set[str]) -> int:
        """Index of the smallest clique containing every name (error if none)."""
        best, best_size = -1, None
        for i, c in enumerate(self.cliques):
            if names <= c and (best_size is None or len(c) < best_size):
                best, best_size = i, len(c)
        if best < 0:
            raise ValueError(f"no clique contains {sorted(names)}")
        return best


def moral_scopes(bn: BayesianNetwork) -> List[Set[str]]:
    """One scope per factor that must land inside a clique."""
    scopes: List[Set[str]] = []
    for v in bn.order:
        dpa = {p.name for p in bn.dag.get_parents(v) if p.is_discrete}
        if v.is_discrete:
            scopes.append({v.name} | dpa)
        elif dpa:
            scopes.append(dpa)       # lambda(d_pa) of a continuous CLG node
    return scopes


def moralize(bn: BayesianNetwork) -> Dict[str, Set[str]]:
    """Undirected moral graph over the *discrete* variables."""
    adj: Dict[str, Set[str]] = {
        v.name: set() for v in bn.order if v.is_discrete}
    for scope in moral_scopes(bn):
        nodes = sorted(scope)
        for i, a in enumerate(nodes):
            for b in nodes[i + 1:]:
                adj[a].add(b)
                adj[b].add(a)
    return adj


def min_fill_triangulate(
    adj: Dict[str, Set[str]]
) -> Tuple[List[FrozenSet[str]], Tuple[str, ...], int]:
    """Min-fill elimination; returns (maximal cliques, order, #fill edges)."""
    g = {v: set(ns) for v, ns in adj.items()}
    order: List[str] = []
    cliques: List[FrozenSet[str]] = []
    fills = 0

    def fill_cost(v: str) -> int:
        ns = sorted(g[v])
        c = 0
        for i, a in enumerate(ns):
            for b in ns[i + 1:]:
                if b not in g[a]:
                    c += 1
        return c

    while g:
        v = min(sorted(g), key=fill_cost)     # sorted() makes ties stable
        ns = sorted(g[v])
        cliques.append(frozenset([v] + ns))
        for i, a in enumerate(ns):
            for b in ns[i + 1:]:
                if b not in g[a]:
                    g[a].add(b)
                    g[b].add(a)
                    fills += 1
        for a in ns:
            g[a].discard(v)
        del g[v]
        order.append(v)

    maximal = [c for c in cliques
               if not any(c < other for other in cliques)]
    # dedupe while preserving order
    seen: Set[FrozenSet[str]] = set()
    uniq = []
    for c in maximal:
        if c not in seen:
            seen.add(c)
            uniq.append(c)
    return uniq, tuple(order), fills


def spanning_tree(cliques: Sequence[FrozenSet[str]]
                  ) -> Tuple[Tuple[Tuple[int, int], ...],
                             Tuple[FrozenSet[str], ...]]:
    """Max-weight spanning tree over |C_i ∩ C_j| (Kruskal + union-find)."""
    n = len(cliques)
    if n == 1:
        return (), ()
    cand = sorted(
        ((len(cliques[i] & cliques[j]), i, j)
         for i in range(n) for j in range(i + 1, n)),
        key=lambda t: (-t[0], t[1], t[2]))
    parent = list(range(n))

    def find(x: int) -> int:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    edges: List[Tuple[int, int]] = []
    seps: List[FrozenSet[str]] = []
    for w, i, j in cand:
        ri, rj = find(i), find(j)
        if ri != rj:
            parent[ri] = rj
            edges.append((i, j))
            seps.append(cliques[i] & cliques[j])
            if len(edges) == n - 1:
                break
    return tuple(edges), tuple(seps)


def verify_running_intersection(
    cliques: Sequence[FrozenSet[str]],
    edges: Sequence[Tuple[int, int]],
) -> None:
    """Raise if some variable's cliques do not form a connected subtree."""
    names = set().union(*cliques) if cliques else set()
    adj: Dict[int, List[int]] = {i: [] for i in range(len(cliques))}
    for a, b in edges:
        adj[a].append(b)
        adj[b].append(a)
    for name in names:
        holders = [i for i, c in enumerate(cliques) if name in c]
        # BFS inside the induced subgraph
        seen = {holders[0]}
        stack = [holders[0]]
        while stack:
            u = stack.pop()
            for w in adj[u]:
                if w not in seen and name in cliques[w]:
                    seen.add(w)
                    stack.append(w)
        if seen != set(holders):
            raise AssertionError(
                f"running intersection violated for {name!r}: "
                f"cliques {holders} not connected")


def compile_junction_tree(bn: BayesianNetwork) -> JunctionTree:
    """Full pipeline: moralize -> min-fill -> spanning tree -> verify."""
    adj = moralize(bn)
    if not adj:
        raise ValueError("network has no discrete variables")
    cliques, order, fills = min_fill_triangulate(adj)
    edges, seps = spanning_tree(cliques)
    verify_running_intersection(cliques, edges)
    return JunctionTree(cliques=tuple(cliques), edges=edges, sepsets=seps,
                        elimination_order=order, fill_in_count=fills)
