"""Junction-tree compilation: moralize, triangulate, build, verify.

All static Python over the ``DAG`` of ``repro.core.dag`` — this runs once
per network at engine construction and produces the hashable structure the
jitted propagation closes over.

Pipeline (Lauritzen–Spiegelhalter):

  1. *Moralize* the discrete subgraph: connect every discrete node to its
     discrete parents and marry those parents pairwise.  The discrete-parent
     set of each **continuous** CLG node is married too, so the evidence
     likelihood lambda(d_pa) of an observed continuous leaf — and the joint
     needed to query an unobserved one — always fits inside one clique.
  2. *Triangulate* with the min-fill heuristic, collecting elimination
     cliques; keep the maximal ones.
  3. Build the tree as a maximum-weight spanning tree over pairwise sepset
     sizes (Kruskal; zero-weight edges permitted so disconnected moral
     graphs still yield a single tree — empty sepsets exchange only the
     subtree normalizer, which cancels on normalization).
  4. Verify the running-intersection property: for every variable the
     cliques containing it must induce a connected subtree.

For CLG networks with continuous-continuous edges the engine instead uses
:func:`compile_strong_junction_tree` (Lauritzen 1992): the FULL moral graph
(continuous nodes included), a *strong* elimination order that eliminates
every continuous variable before any discrete one, and a clique tree
directed toward a strong root — for every clique, either its residual
toward the root is all-continuous (an exact Gaussian integral) or its
sepset is all-discrete (a plain sum over a table).  That property is what
lets collect-phase messages stay exact and confines moment matching (weak
marginals) to the distribute pass.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, List, Sequence, Set, Tuple

from repro.core.dag import BayesianNetwork


@dataclasses.dataclass(frozen=True)
class JunctionTree:
    """Compiled clique-tree structure (no parameters, fully static).

    ``root`` is the propagation root (index 0 for the weak/discrete
    pipeline; the strong root for :func:`compile_strong_junction_tree`).
    ``continuous`` is empty for the discrete pipeline.
    """

    cliques: Tuple[FrozenSet[str], ...]
    # tree edges: (i < j) pairs for the discrete pipeline; DIRECTED
    # (child, parent) pairs toward ``root`` for strong trees — direction is
    # load-bearing (verify_strong, the engine's collect/distribute order)
    edges: Tuple[Tuple[int, int], ...]
    sepsets: Tuple[FrozenSet[str], ...]         # aligned with edges
    elimination_order: Tuple[str, ...]
    fill_in_count: int
    root: int = 0
    continuous: FrozenSet[str] = frozenset()

    def neighbors(self, i: int) -> List[Tuple[int, FrozenSet[str]]]:
        out = []
        for (a, b), s in zip(self.edges, self.sepsets):
            if a == i:
                out.append((b, s))
            elif b == i:
                out.append((a, s))
        return out

    def smallest_containing(self, names: Set[str]) -> int:
        """Index of the smallest clique containing every name (error if none)."""
        best, best_size = -1, None
        for i, c in enumerate(self.cliques):
            if names <= c and (best_size is None or len(c) < best_size):
                best, best_size = i, len(c)
        if best < 0:
            raise ValueError(f"no clique contains {sorted(names)}")
        return best


def moral_scopes(bn: BayesianNetwork) -> List[Set[str]]:
    """One scope per factor that must land inside a clique."""
    scopes: List[Set[str]] = []
    for v in bn.order:
        dpa = {p.name for p in bn.dag.get_parents(v) if p.is_discrete}
        if v.is_discrete:
            scopes.append({v.name} | dpa)
        elif dpa:
            scopes.append(dpa)       # lambda(d_pa) of a continuous CLG node
    return scopes


def moralize(bn: BayesianNetwork) -> Dict[str, Set[str]]:
    """Undirected moral graph over the *discrete* variables."""
    adj: Dict[str, Set[str]] = {
        v.name: set() for v in bn.order if v.is_discrete}
    for scope in moral_scopes(bn):
        nodes = sorted(scope)
        for i, a in enumerate(nodes):
            for b in nodes[i + 1:]:
                adj[a].add(b)
                adj[b].add(a)
    return adj


def _min_fill_eliminate(
    adj: Dict[str, Set[str]], priority: Set[str] = frozenset()
) -> Tuple[List[FrozenSet[str]], Tuple[str, ...], int]:
    """Min-fill elimination.  Vertices in ``priority`` are eliminated before
    all others (the strong-order constraint; empty = plain min-fill).
    Returns (per-vertex elimination cliques in CREATION order, elimination
    order, #fill edges); ``sorted()`` calls make tie-breaks stable."""
    g = {v: set(ns) for v, ns in adj.items()}
    order: List[str] = []
    cliques: List[FrozenSet[str]] = []
    fills = 0

    def fill_cost(v: str) -> int:
        ns = sorted(g[v])
        c = 0
        for i, a in enumerate(ns):
            for b in ns[i + 1:]:
                if b not in g[a]:
                    c += 1
        return c

    while g:
        cand = sorted(v for v in g if v in priority) or sorted(g)
        v = min(cand, key=fill_cost)
        ns = sorted(g[v])
        cliques.append(frozenset([v] + ns))
        for i, a in enumerate(ns):
            for b in ns[i + 1:]:
                if b not in g[a]:
                    g[a].add(b)
                    g[b].add(a)
                    fills += 1
        for a in ns:
            g[a].discard(v)
        del g[v]
        order.append(v)
    return cliques, tuple(order), fills


def min_fill_triangulate(
    adj: Dict[str, Set[str]]
) -> Tuple[List[FrozenSet[str]], Tuple[str, ...], int]:
    """Min-fill elimination; returns (maximal cliques, order, #fill edges)."""
    cliques, order, fills = _min_fill_eliminate(adj)
    maximal = [c for c in cliques
               if not any(c < other for other in cliques)]
    # dedupe while preserving order
    seen: Set[FrozenSet[str]] = set()
    uniq = []
    for c in maximal:
        if c not in seen:
            seen.add(c)
            uniq.append(c)
    return uniq, tuple(order), fills


def spanning_tree(cliques: Sequence[FrozenSet[str]]
                  ) -> Tuple[Tuple[Tuple[int, int], ...],
                             Tuple[FrozenSet[str], ...]]:
    """Max-weight spanning tree over |C_i ∩ C_j| (Kruskal + union-find)."""
    n = len(cliques)
    if n == 1:
        return (), ()
    cand = sorted(
        ((len(cliques[i] & cliques[j]), i, j)
         for i in range(n) for j in range(i + 1, n)),
        key=lambda t: (-t[0], t[1], t[2]))
    parent = list(range(n))

    def find(x: int) -> int:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    edges: List[Tuple[int, int]] = []
    seps: List[FrozenSet[str]] = []
    for w, i, j in cand:
        ri, rj = find(i), find(j)
        if ri != rj:
            parent[ri] = rj
            edges.append((i, j))
            seps.append(cliques[i] & cliques[j])
            if len(edges) == n - 1:
                break
    return tuple(edges), tuple(seps)


def verify_running_intersection(
    cliques: Sequence[FrozenSet[str]],
    edges: Sequence[Tuple[int, int]],
) -> None:
    """Raise if some variable's cliques do not form a connected subtree."""
    names = set().union(*cliques) if cliques else set()
    adj: Dict[int, List[int]] = {i: [] for i in range(len(cliques))}
    for a, b in edges:
        adj[a].append(b)
        adj[b].append(a)
    for name in names:
        holders = [i for i, c in enumerate(cliques) if name in c]
        # BFS inside the induced subgraph
        seen = {holders[0]}
        stack = [holders[0]]
        while stack:
            u = stack.pop()
            for w in adj[u]:
                if w not in seen and name in cliques[w]:
                    seen.add(w)
                    stack.append(w)
        if seen != set(holders):
            raise AssertionError(
                f"running intersection violated for {name!r}: "
                f"cliques {holders} not connected")


def compile_junction_tree(bn: BayesianNetwork) -> JunctionTree:
    """Full pipeline: moralize -> min-fill -> spanning tree -> verify."""
    adj = moralize(bn)
    if not adj:
        raise ValueError("network has no discrete variables")
    cliques, order, fills = min_fill_triangulate(adj)
    edges, seps = spanning_tree(cliques)
    verify_running_intersection(cliques, edges)
    return JunctionTree(cliques=tuple(cliques), edges=edges, sepsets=seps,
                        elimination_order=order, fill_in_count=fills)


# ---------------------------------------------------------------------------
# Strong junction tree (Lauritzen 1992) — CLG networks with cont-cont edges
# ---------------------------------------------------------------------------


def moralize_full(bn: BayesianNetwork) -> Dict[str, Set[str]]:
    """Undirected moral graph over ALL variables (discrete + continuous)."""
    adj: Dict[str, Set[str]] = {v.name: set() for v in bn.order}
    for v in bn.order:
        family = sorted({v.name} | {p.name for p in bn.dag.get_parents(v)})
        for i, a in enumerate(family):
            for b in family[i + 1:]:
                adj[a].add(b)
                adj[b].add(a)
    return adj


def strong_triangulate(
    adj: Dict[str, Set[str]], continuous: Set[str]
) -> Tuple[List[FrozenSet[str]], Tuple[str, ...], int]:
    """Min-fill elimination constrained to a STRONG order: every continuous
    variable is eliminated before any discrete one.  Returns EVERY
    elimination clique (one per vertex, birth order — the strong-root tree
    is built over all of them and subset cliques contracted away; pruning
    before building breaks the RIP attachment), the elimination order and
    the fill-in count."""
    return _min_fill_eliminate(adj, continuous)


def strong_root_tree(
    cliques: Sequence[FrozenSet[str]],
    order: Sequence[str],
) -> Tuple[List[FrozenSet[str]], Tuple[Tuple[int, int], ...],
           Tuple[FrozenSet[str], ...], int]:
    """Directed clique tree with a strong root, from the per-vertex
    elimination cliques (birth order, aligned with ``order``).

    Construction: clique ``K_i`` (formed when eliminating ``e_i``) attaches
    to the elimination clique of the FIRST-eliminated vertex of its sepset
    ``S_i = K_i \\ {e_i}`` — the classic Lauritzen–Spiegelhalter tree, for
    which ``S_i = K_i ∩ K_parent`` and the running intersection property
    hold by the perfect-elimination argument.  Cliques with empty sepsets
    (disconnected components) attach to the last-born clique.  Non-maximal
    cliques are then contracted into their superset neighbor; by the
    junction property the surviving sepsets are unchanged, so RIP and the
    strong-root property are preserved.  With a strong elimination order
    the surviving root is at the all-discrete end of the tree.

    Returns (maximal_cliques, edges (child, parent), sepsets, root_index).
    """
    n = len(cliques)
    pos = {v: i for i, v in enumerate(order)}
    parent: List[int] = [-1] * n
    root = n - 1
    for i in range(n):
        sep = cliques[i] - {order[i]}
        if i == root:
            parent[i] = -1
        elif sep:
            parent[i] = pos[min(sep, key=lambda v: pos[v])]
        else:
            parent[i] = root
    children: Dict[int, Set[int]] = {i: set() for i in range(n)}
    for i in range(n):
        if parent[i] >= 0:
            children[parent[i]].add(i)

    alive = set(range(n))

    def _drop(child: int, keeper: int) -> None:
        """Merge ``child`` into adjacent ``keeper`` (child ⊆ keeper)."""
        for c in children[child]:
            if c != keeper:
                parent[c] = keeper
                children[keeper].add(c)
        p = parent[child]
        if p == keeper:
            children[keeper].discard(child)
        elif p >= 0:                     # keeper was a child of `child`
            children[p].discard(child)
            children[p].add(keeper)
            parent[keeper] = p
        else:                            # `child` was the root
            parent[keeper] = -1
        alive.discard(child)

    changed = True
    while changed:
        changed = False
        for i in sorted(alive):
            p = parent[i]
            if p < 0:
                continue
            if cliques[i] <= cliques[p]:
                _drop(i, p)
                changed = True
                break
            if cliques[p] < cliques[i]:
                _drop(p, i)
                changed = True
                break

    idx = {old: new for new, old in enumerate(sorted(alive))}
    out_cliques = [cliques[i] for i in sorted(alive)]
    edges: List[Tuple[int, int]] = []
    seps: List[FrozenSet[str]] = []
    new_root = -1
    for i in sorted(alive):
        if parent[i] < 0:
            new_root = idx[i]
        else:
            edges.append((idx[i], idx[parent[i]]))
            seps.append(cliques[i] & cliques[parent[i]])
    return out_cliques, tuple(edges), tuple(seps), new_root


def verify_strong(
    cliques: Sequence[FrozenSet[str]],
    edges: Sequence[Tuple[int, int]],
    sepsets: Sequence[FrozenSet[str]],
    continuous: Set[str],
) -> None:
    """Raise unless every directed edge (child -> parent) has an
    all-continuous residual or an all-discrete sepset — the strong-root
    property that makes collect-phase marginalization exact."""
    for (child, _), sep in zip(edges, sepsets):
        residual = cliques[child] - sep
        if residual <= continuous:
            continue
        if not (sep & continuous):
            continue
        raise AssertionError(
            f"strong-root property violated at clique {sorted(cliques[child])}"
            f": residual {sorted(residual)} has discrete vars and sepset "
            f"{sorted(sep)} has continuous vars")


def compile_strong_junction_tree(bn: BayesianNetwork) -> JunctionTree:
    """Strong pipeline: full moral graph -> strong min-fill -> strong-root
    directed tree -> verify RIP + the strong-root property."""
    continuous = {v.name for v in bn.order if not v.is_discrete}
    adj = moralize_full(bn)
    if not adj:
        raise ValueError("empty network")
    elim_cliques, order, fills = strong_triangulate(adj, continuous)
    cliques, edges, seps, root = strong_root_tree(elim_cliques, order)
    verify_running_intersection(cliques, edges)
    verify_strong(cliques, edges, seps, continuous)
    return JunctionTree(cliques=tuple(cliques), edges=edges, sepsets=seps,
                        elimination_order=order, fill_in_count=fills,
                        root=root, continuous=frozenset(continuous))
