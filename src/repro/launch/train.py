"""Training driver: ``python -m repro.launch.train --arch <id> [options]``.

On this CPU container it runs REDUCED configs end-to-end (the e2e example
uses a ~100M-param model); on a real pod the same driver takes the full
config + production mesh.
"""

from __future__ import annotations

import argparse
import time
from functools import partial

import numpy as np


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--optimizer", choices=["adamw", "vb"], default="adamw")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false",
                    help="full config (requires a real pod)")
    ap.add_argument("--data-shards", type=int, default=1)
    ap.add_argument("--model-shards", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--corpus-size", type=int, default=200_000)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp

    from repro import obs
    from repro.configs import get_config
    from repro.data.tokens import TokenStream, markov_sequence_fast
    from repro.launch.mesh import make_host_mesh
    from repro.nn import transformer as T
    from repro.train import checkpoint as ck
    from repro.train import optimizer as opt
    from repro.train import step as ts
    from repro.bayes.drift import LossDriftMonitor

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    obs.log(f"[train] arch={cfg.name} params~{cfg.n_params()/1e6:.1f}M "
            f"optimizer={args.optimizer}",
            component="train", arch=cfg.name, n_params=cfg.n_params(),
            optimizer=args.optimizer)

    sh = T.NO_SHARD
    if args.data_shards * args.model_shards > 1:
        mesh = make_host_mesh(args.data_shards, args.model_shards)
        sh = T.Shardings(mesh=mesh, data_axes=("data",), model_axis="model")

    key = jax.random.PRNGKey(args.seed)
    ep = args.model_shards if cfg.moe else 1
    params = T.init_model(key, cfg, ep_shards=ep)

    corpus = markov_sequence_fast(args.corpus_size, cfg.vocab, seed=args.seed)
    enc_stub = ((cfg.encoder.enc_len, cfg.d_model) if cfg.is_encdec else None)
    stream = TokenStream(corpus, args.batch, args.seq, enc_stub=enc_stub)

    lr_fn = opt.cosine_schedule(args.lr, args.steps // 10, args.steps)
    monitor = LossDriftMonitor.create()

    if args.optimizer == "adamw":
        state = ts.init_train_state(params)
        jstep = jax.jit(partial(ts.train_step, cfg=cfg, sh=sh, lr_fn=lr_fn))
    else:
        state = ts.init_vb_state(params)
        jstep = jax.jit(partial(ts.vb_train_step, cfg=cfg, sh=sh,
                                n_total=float(args.corpus_size)))

    t0 = time.time()
    losses = []
    for i, batch in enumerate(stream.batches(args.steps)):
        state, metrics = jstep(state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        monitor, drifted = monitor.observe(jnp.asarray(loss))
        if i % args.log_every == 0 or i == args.steps - 1:
            tps = args.batch * args.seq * (i + 1) / (time.time() - t0)
            obs.log(f"[train] step={i:5d} loss={loss:.4f} tok/s={tps:,.0f}"
                    + (" DRIFT" if bool(drifted) else ""),
                    component="train", step=i, loss=loss, tok_s=tps,
                    drifted=bool(drifted))
    obs.log(f"[train] done: first={losses[0]:.3f} last={losses[-1]:.3f} "
            f"log(V)={np.log(cfg.vocab):.3f}",
            component="train", first_loss=losses[0], last_loss=losses[-1])
    if args.ckpt:
        p = state.params if args.optimizer == "adamw" else state.vb.mean
        ck.save(args.ckpt, p)
        obs.log(f"[train] checkpoint -> {args.ckpt}", component="train",
                ckpt=args.ckpt)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
