"""Production mesh — (2 pods x) 16 x 16 TPU v5e chips.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state; the dry-run sets XLA_FLAGS before first init.
"""

from __future__ import annotations

from typing import Tuple

import jax

from repro.core.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def data_axes_of(mesh) -> Tuple[str, ...]:
    names = mesh.axis_names
    return tuple(a for a in names if a in ("pod", "data"))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over host devices (tests / CPU examples)."""
    return make_mesh((data, model), ("data", "model"))
