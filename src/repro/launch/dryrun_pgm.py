"""Dry-run the PAPER'S OWN workload at production scale: d-VMP on 256 chips.

The d-VMP paper [11] reports models with >1e9 nodes (= instances x local
latents).  This driver lowers ``dvmp_fit`` for a plate model with N = 100M
instances sharded over the ('data',...) axes of the production mesh, proves
it compiles, and verifies the headline structural claim: the ONLY
cross-shard communication is ONE all-reduce of the sufficient-statistic
pytree per VMP sweep (all-reduce count in the while body == suff-stat leaf
count, independent of N).

Run: PYTHONPATH=src python -m repro.launch.dryrun_pgm [--n 100000000]
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

# ruff: noqa: E402
import argparse
import json
import re
import sys
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.amidst_pgm import PGM_WORKLOADS
from repro.core import dvmp, vmp
from repro.launch.mesh import data_axes_of, make_production_mesh


def run_one(name: str, n: int, multi_pod: bool, out_dir: str) -> dict:
    wl = PGM_WORKLOADS[name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    dp = data_axes_of(mesh)
    cp = vmp.compile_plate(wl.spec)
    prior = vmp.default_prior(cp)
    init = vmp.symmetry_broken(prior, jax.random.PRNGKey(0))
    lay = cp.layout

    xc = jax.ShapeDtypeStruct((n, max(lay.F, 1)), jnp.float32,
                              sharding=NamedSharding(mesh, P(dp, None)))
    xd = jax.ShapeDtypeStruct((n, max(lay.Fd, 0)), jnp.int32,
                              sharding=NamedSharding(mesh, P(dp, None)))
    mask = jax.ShapeDtypeStruct((n,), jnp.float32,
                                sharding=NamedSharding(mesh, P(dp)))

    def fit(prior_, init_, xc_, xd_, mask_):
        return dvmp.dvmp_fit(cp, prior_, init_, xc_, xd_, mesh, dp,
                             max_sweeps=50, tol=1e-5, mask=mask_)

    t0 = time.time()
    with mesh:
        lowered = jax.jit(fit).lower(prior, init, xc, xd, mask)
        compiled = lowered.compile()
    hlo = compiled.as_text()
    mem = compiled.memory_analysis()

    # structural claim: collectives per sweep == grouped suff-stat psum.
    # the sweep while-body is the only computation containing all-reduces;
    # count result-defining all-reduce ops module-wide (the body appears
    # ONCE regardless of sweep count or N).
    body = [ln for ln in hlo.splitlines()
            if re.search(r"=.*\ball-reduce(-start)?\(", ln)]
    n_leaves = len(jax.tree_util.tree_leaves(
        vmp.local_step(cp, init,
                       jnp.zeros((2, max(lay.F, 1))),
                       jnp.zeros((2, max(lay.Fd, 0)), jnp.int32),
                       jnp.ones(2))[0]))
    # XLA may fuse the pytree psum into fewer grouped all-reduces
    rec = {
        "workload": name, "n_instances": n,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "compile_s": round(time.time() - t0, 1),
        "all_reduces_in_sweep_body": len(body),
        "suffstat_leaves": n_leaves,
        "per_device_mem_gb": round(
            getattr(mem, "temp_size_in_bytes", 0) / 1e9, 3),
        "claim": "collective count is O(1) in N (grouped psum of the "
                 "suff-stat pytree once per sweep)",
    }
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, f"pgm_{name}_{rec['mesh']}.json"),
              "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--workload", default="gmm_large",
                    choices=list(PGM_WORKLOADS))
    ap.add_argument("--n", type=int, default=100_000_000)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--out", default="results/dryrun_pgm")
    args = ap.parse_args(argv)
    rec = run_one(args.workload, args.n, args.mesh == "multi", args.out)
    print(json.dumps(rec, indent=1))
    return 0


if __name__ == "__main__":
    sys.exit(main())
