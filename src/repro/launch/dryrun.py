"""Multi-pod dry-run: prove every (arch x shape x mesh) lowers + compiles.

MUST be the first import side effect: 512 placeholder host devices for the
production mesh (before ANY jax-touching import).
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

# ruff: noqa: E402
import argparse
import json
import re
import sys
import time
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

# obs is deliberately jax-free (safe even before the XLA_FLAGS line above)
from repro import obs
from repro.configs import ARCH_IDS, get_config
from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig
from repro.launch.mesh import data_axes_of, make_production_mesh
from repro.nn import transformer as T
from repro.sharding import decode_state_specs, param_specs, train_state_specs
from repro.train import optimizer as opt
from repro.train import step as ts

# ---------------------------------------------------------------------------
# skip table (DESIGN.md §decode coverage): long_500k needs sub-quadratic attn
# ---------------------------------------------------------------------------


def skip_reason(cfg: ModelConfig, shape: InputShape) -> Optional[str]:
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return ("full-attention arch: 524k dense KV decode is the quadratic "
                "regime this shape excludes (DESIGN.md)")
    if shape.name == "long_500k" and cfg.is_encdec:
        return "enc-dec audio arch: 30s/1500-frame context by construction"
    return None


# ---------------------------------------------------------------------------
# abstract inputs (ShapeDtypeStruct — no allocation)
# ---------------------------------------------------------------------------


def _axis_sizes(mesh):
    return {a: mesh.shape[a] for a in mesh.axis_names}


def _sds(shape, dtype, mesh, spec):
    from repro.sharding.specs import fix_spec

    spec = fix_spec(spec, tuple(shape), _axis_sizes(mesh))
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def _with_sharding(tree, spec_tree, mesh):
    return jax.tree_util.tree_map(
        lambda leaf, spec: jax.ShapeDtypeStruct(
            leaf.shape, leaf.dtype, sharding=NamedSharding(mesh, spec)),
        tree, spec_tree)


TRAIN_SHARDING = os.environ.get("REPRO_TRAIN_SHARDING", "tp_fsdp")


def shardings_for(cfg: ModelConfig, mesh, mode: str) -> T.Shardings:
    dp = data_axes_of(mesh)
    model_size = mesh.shape["model"]
    if mode == "train" and TRAIN_SHARDING == "fsdp":
        # pure FSDP (§Perf change C): every axis is a batch axis
        all_axes = tuple(mesh.axis_names)
        from repro.configs.base import INPUT_SHAPES  # batch divisibility
        return T.Shardings(mesh=mesh, data_axes=all_axes, model_axis="model",
                           shard_heads=False, moe_ep=False)
    seq_shard = bool(cfg.n_heads) and (cfg.n_heads % model_size != 0)
    if mode == "decode":
        # q/o stay head-sharded so the ctx-parallel shard_map boundary
        # gathers the TINY q activation, not the attention weights
        # (§Perf change D); small-head archs fall back to replication.
        return T.Shardings(mesh=mesh, data_axes=dp, model_axis="model",
                           shard_heads=not seq_shard, attn_seq_shard=False)
    return T.Shardings(
        mesh=mesh, data_axes=dp, model_axis="model",
        shard_heads=True, attn_seq_shard=seq_shard)


def abstract_params(cfg: ModelConfig, mesh, mode: str, dtype):
    fsdp = mode == "train" and TRAIN_SHARDING == "fsdp"
    ep = 1 if fsdp else (mesh.shape["model"] if cfg.moe else 1)
    shape_tree = jax.eval_shape(
        lambda: T.init_model(jax.random.PRNGKey(0), cfg, ep_shards=ep,
                             dtype=dtype))
    specs = param_specs(shape_tree, cfg,
                        "train_fsdp" if fsdp else mode,
                        data_axes=data_axes_of(mesh), model_axis="model",
                        axis_sizes=_axis_sizes(mesh))
    return _with_sharding(shape_tree, specs, mesh), specs


def input_specs(arch: str, shape_name: str, mesh, mode_override=None
                ) -> Tuple[str, tuple, Any]:
    """Returns (kind, args-as-ShapeDtypeStructs, step callable)."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    dp = data_axes_of(mesh)
    kind = mode_override or shape.kind
    B, S = shape.global_batch, shape.seq_len

    if kind == "train":
        sh = shardings_for(cfg, mesh, "train")
        params_sds, pspecs = abstract_params(cfg, mesh, "train", jnp.float32)
        state_shape = jax.eval_shape(
            lambda p: ts.init_train_state(p), params_sds)
        fsdp = TRAIN_SHARDING == "fsdp"
        sspecs = train_state_specs(
            state_shape, cfg, data_axes=dp, axis_sizes=_axis_sizes(mesh),
            mode="train_fsdp" if fsdp else "train")
        state_sds = _with_sharding(state_shape, sspecs, mesh)
        bdp = sh.data_axes if fsdp else dp
        batch_sds = ts.TrainBatch(
            tokens=_sds((B, S), jnp.int32, mesh, P(bdp, None)),
            labels=_sds((B, S), jnp.int32, mesh, P(bdp, None)),
            enc_input=(_sds((B, cfg.encoder.enc_len, cfg.d_model),
                            jnp.float32, mesh, P(dp, None, None))
                       if cfg.is_encdec else None),
        )
        lr_fn = opt.cosine_schedule(3e-4, 100, 10_000)

        def fn(state, batch):
            return ts.train_step(state, batch, cfg, sh, lr_fn=lr_fn)

        return kind, (state_sds, batch_sds), fn

    if kind == "prefill":
        sh = shardings_for(cfg, mesh, "prefill")
        params_sds, _ = abstract_params(cfg, mesh, "serve", jnp.bfloat16)
        toks = _sds((B, S), jnp.int32, mesh, P(dp, None))
        enc = (_sds((B, cfg.encoder.enc_len, cfg.d_model), jnp.bfloat16,
                    mesh, P(dp, None, None)) if cfg.is_encdec else None)

        def fn(params, tokens, enc_input):
            out = T.forward(params, tokens, cfg, sh, remat=False,
                            enc_input=enc_input)
            # serving prefill emits next-token logits (KV-write bytes are
            # accounted analytically in §Roofline notes)
            return out.logits[:, -1]

        return kind, (params_sds, toks, enc), fn

    # decode
    sh = shardings_for(cfg, mesh, "decode")
    params_sds, _ = abstract_params(cfg, mesh, "decode", jnp.bfloat16)
    capacity = S
    if cfg.sliding_window and shape.name == "long_500k":
        capacity = cfg.sliding_window       # ring buffer IS the window
    # cache capacity must divide the model axis for ctx-parallel sharding
    ms = mesh.shape["model"]
    capacity = max(ms, (capacity // ms) * ms)
    state_shape = jax.eval_shape(
        lambda p: T.init_decode_state(
            p, cfg, B, capacity, T.NO_SHARD,
            enc_input=(jnp.zeros((B, cfg.encoder.enc_len, cfg.d_model),
                                 jnp.bfloat16) if cfg.is_encdec else None)),
        params_sds)
    dspecs = decode_state_specs(state_shape, cfg, data_axes=dp,
                                axis_sizes=_axis_sizes(mesh))
    state_sds = jax.tree_util.tree_map(
        lambda leaf, spec: None if leaf is None else jax.ShapeDtypeStruct(
            leaf.shape, leaf.dtype, sharding=NamedSharding(mesh, spec)),
        state_shape, dspecs,
        is_leaf=lambda x: x is None)
    tok = _sds((B, 1), jnp.int32, mesh, P(dp, None))

    def fn(params, state, token):
        return ts.serve_step(params, state, token, cfg, sh)

    return kind, (params_sds, state_sds, tok), fn


# ---------------------------------------------------------------------------
# collective-bytes extraction from post-SPMD HLO
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "c64": 8, "f32": 4, "s32": 4,
                "u32": 4, "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1, "f8e4m3": 1, "f8e5m2": 1}

_SHAPE_RE = re.compile(r"(f64|s64|u64|c64|f32|s32|u32|bf16|f16|s16|u16|s8|u8|"
                       r"pred|f8e4m3|f8e5m2)\[([0-9,]*)\]")

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(segment: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(segment):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes of every collective op, by kind.

    Uses each op's RESULT shape (the payload that crosses/lands on links);
    bytes are whole-module (all devices); §Roofline divides by chips x link.
    """
    out = {k: 0 for k in _COLL_KINDS}
    out["count"] = 0
    for line in hlo_text.splitlines():
        ls = line.strip()
        # result-defining collective lines look like: %x = TYPE[...] all-reduce(
        m = re.search(r"=\s*([^=]*?)\s+(all-gather|all-reduce|reduce-scatter|"
                      r"all-to-all|collective-permute)", ls)
        if not m:
            continue
        kind = m.group(2)
        out[kind] += _shape_bytes(m.group(1))
        out["count"] += 1
    return out


# ---------------------------------------------------------------------------
# the dry run
# ---------------------------------------------------------------------------


def run_one(arch: str, shape_name: str, multi_pod: bool,
            save_hlo: Optional[str] = None) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    reason = skip_reason(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "n_params": cfg.n_params(), "n_active": cfg.n_active_params(),
    }
    if reason:
        rec["skipped"] = reason
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    kind, args, fn = input_specs(arch, shape_name, mesh)
    with mesh:
        lowered = jax.jit(fn).lower(*args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)
    rec.update({
        "kind": kind,
        "lower_s": round(t1 - t0, 1),
        "compile_s": round(t2 - t1, 1),
        "flops": cost.get("flops", 0.0),
        "bytes_accessed": cost.get("bytes accessed", 0.0),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", 0),
        },
        "collectives": coll,
    })
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all",
                    help=f"one of {ARCH_IDS} or 'all'")
    ap.add_argument("--shape", default="all",
                    help=f"one of {list(INPUT_SHAPES)} or 'all'")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--hlo-dir", default=None,
                    help="also dump post-SPMD HLO text here")
    args = ap.parse_args(argv)

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    if args.hlo_dir:
        os.makedirs(args.hlo_dir, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
                out_path = os.path.join(args.out, tag + ".json")
                hlo_path = (os.path.join(args.hlo_dir, tag + ".hlo.txt")
                            if args.hlo_dir else None)
                try:
                    rec = run_one(arch, shape, mp, save_hlo=hlo_path)
                    status = ("SKIP: " + rec["skipped"][:40]
                              if "skipped" in rec else
                              f"ok lower={rec['lower_s']}s "
                              f"compile={rec['compile_s']}s "
                              f"flops={rec['flops']:.3g}")
                except Exception as e:  # noqa: BLE001 — report and continue
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "error": f"{type(e).__name__}: {e}"}
                    status = "FAIL " + rec["error"][:120]
                    failures += 1
                with open(out_path, "w") as f:
                    json.dump(rec, f, indent=1)
                obs.log(f"[dryrun] {tag}: {status}", component="dryrun",
                        tag=tag, status=status)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
