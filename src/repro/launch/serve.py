"""Serving driver: ``python -m repro.launch.serve --arch <id>``.

Loads (or random-inits) a reduced model and serves a batch of synthetic
requests through the continuous-batching DecodeEngine.
"""

from __future__ import annotations

import argparse
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--capacity", type=int, default=256)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import jax
    import numpy as np

    from repro import obs
    from repro.configs import get_config
    from repro.nn import transformer as T
    from repro.serve.engine import DecodeEngine, Request
    from repro.train import checkpoint as ck

    cfg = get_config(args.arch).reduced()
    key = jax.random.PRNGKey(args.seed)
    params = T.init_model(key, cfg)
    if args.ckpt:
        params = ck.load(args.ckpt, params)

    engine = DecodeEngine(params, cfg, args.batch, args.capacity)
    rng = np.random.default_rng(args.seed)
    for rid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, rng.integers(4, 12)).tolist()
        engine.submit(Request(rid=rid, prompt=prompt, max_new=args.max_new))

    t0 = time.time()
    steps = 0
    while True:
        active = engine.step()
        steps += 1
        if active == 0 and not engine.queue:
            break
        if steps > 100_000:
            raise RuntimeError("serve loop did not drain")
    dt = time.time() - t0
    done = args.requests
    toks = done * args.max_new
    obs.log(f"[serve] {done} requests, {toks} tokens in {dt:.1f}s "
            f"({toks/dt:,.0f} tok/s, batch={args.batch})",
            component="serve", requests=done, tokens=toks, seconds=dt,
            tok_s=toks / dt, batch=args.batch)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
