"""Serving driver: ``python -m repro.launch.serve``.

Two paths:

* ``--arch <id>`` — the original LM demo: random-init a reduced model and
  drain a batch of synthetic requests through the continuous-batching
  DecodeEngine.

* default (no ``--arch``) — drive the async PGM serving tier
  (:class:`repro.serve.queue.AsyncPGMServer`) under Poisson offered load:
  a synthetic discrete network (or a vmp-served GaussianMixture with
  ``--mode vmp``), exponential inter-arrival times at ``--load`` queries/s,
  per-request deadlines from ``--deadline-ms``, optional mid-run hot model
  swap (``--swap``).  Progress and the final latency summary go through
  ``repro.obs`` (structured ``log`` events + the serving tier's own
  ``serve_*`` telemetry) instead of prints.
"""

from __future__ import annotations

import argparse
import time


def _serve_lm(args) -> int:
    import jax
    import numpy as np

    from repro import obs
    from repro.configs import get_config
    from repro.nn import transformer as T
    from repro.serve.engine import DecodeEngine, Request
    from repro.train import checkpoint as ck

    cfg = get_config(args.arch).reduced()
    key = jax.random.PRNGKey(args.seed)
    params = T.init_model(key, cfg)
    if args.ckpt:
        params = ck.load(args.ckpt, params)

    engine = DecodeEngine(params, cfg, args.batch, args.capacity)
    rng = np.random.default_rng(args.seed)
    for rid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, rng.integers(4, 12)).tolist()
        engine.submit(Request(rid=rid, prompt=prompt, max_new=args.max_new))

    t0 = time.time()
    steps = 0
    while True:
        active = engine.step()
        steps += 1
        if active == 0 and not engine.queue:
            break
        if steps > 100_000:
            raise RuntimeError("serve loop did not drain")
    dt = time.time() - t0
    done = args.requests
    toks = done * args.max_new
    obs.log(f"[serve] {done} requests, {toks} tokens in {dt:.1f}s "
            f"({toks/dt:,.0f} tok/s, batch={args.batch})",
            component="serve", requests=done, tokens=toks, seconds=dt,
            tok_s=toks / dt, batch=args.batch)
    return 0


def _serve_pgm(args) -> int:
    import numpy as np

    from repro import obs
    from repro.data import synthetic as syn
    from repro.serve.queue import AsyncPGMServer

    rng = np.random.default_rng(args.seed)
    if args.mode == "vmp":
        from repro.pgm_models import GaussianMixture

        s, _, _ = syn.gmm_stream(512, 3, 4, seed=args.seed)
        model = GaussianMixture(s.attributes, n_states=3)
        model.update_model(s)
        xs = np.asarray(s.collect().xc)

        def make_query():
            row = xs[rng.integers(len(xs))]
            return "Z", {f"X{i}": float(row[i]) for i in range(xs.shape[1])}
    else:
        bn = syn.random_discrete_bn(args.vars, card=2, max_parents=2,
                                    seed=args.seed)
        names = [v.name for v in bn.order]
        model = bn
        # a few evidence schemas so the bucket/coalescing path is exercised
        schemas = [names[:1], names[1:3], names[:2]]

        def make_query():
            sc = schemas[rng.integers(len(schemas))]
            return names[-1], {n: float(rng.integers(2)) for n in sc}

    server = AsyncPGMServer(model, mode=args.mode, max_batch=args.max_batch,
                            max_delay_ms=args.max_delay_ms,
                            default_deadline_ms=args.deadline_ms,
                            replicas=args.replicas)
    obs.log(f"[serve] async PGM tier up: mode={args.mode} "
            f"load={args.load}/s deadline={args.deadline_ms}ms "
            f"replicas={args.replicas}", component="serve")

    tickets = []
    swapped = False
    t0 = time.monotonic()
    end = t0 + args.duration
    while time.monotonic() < end:
        target, evidence = make_query()
        tickets.append(server.submit(target, evidence,
                                     deadline_ms=args.deadline_ms))
        if args.swap and not swapped and time.monotonic() - t0 > args.duration / 2:
            if args.mode == "exact":
                bn2 = syn.random_discrete_bn(args.vars, card=2, max_parents=2,
                                             seed=args.seed + 1)
                info = server.swap_model(bn2)
            else:
                model.update_model(xs[:256])
                info = server.swap_model(model)
            obs.log(f"[serve] hot swap v{info['old_version']}->"
                    f"v{info['new_version']} warmed={info['warmed_plans']} "
                    f"drained={info['drained']}", component="serve")
            swapped = True
        # Poisson arrivals at the offered load
        time.sleep(rng.exponential(1.0 / args.load))
    server.stop()

    for t in tickets:
        t.result(timeout=60)        # all served — stop() drained the queue
    lat_ms = np.array([(t.done_s - t.submitted_s) * 1e3 for t in tickets])
    st = server.stats()
    dt = time.monotonic() - t0
    n = len(tickets)
    obs.log(f"[serve] {n} queries in {dt:.1f}s "
            f"({n/dt:,.0f} q/s achieved vs {args.load}/s offered), "
            f"p50 {np.percentile(lat_ms, 50):.2f}ms "
            f"p99 {np.percentile(lat_ms, 99):.2f}ms, "
            f"deadline misses {st['deadline_misses']}/{n}, "
            f"flushes {st['flushes']}, "
            f"plan hit-rate {st['plans']['hit_rate']:.2f}",
            component="serve", queries=n, seconds=dt, qps=n / dt,
            offered=args.load, p50_ms=float(np.percentile(lat_ms, 50)),
            p99_ms=float(np.percentile(lat_ms, 99)),
            deadline_misses=st["deadline_misses"],
            flushes=st["flushes"], plan_stats=st["plans"])
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None,
                    help="LM decode demo arch id (omit for the PGM tier)")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--capacity", type=int, default=256)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--seed", type=int, default=0)
    # async PGM tier knobs
    ap.add_argument("--mode", default="exact", choices=["exact", "vmp"])
    ap.add_argument("--vars", type=int, default=6,
                    help="exact mode: synthetic network size")
    ap.add_argument("--load", type=float, default=200.0,
                    help="offered load, queries/s (Poisson)")
    ap.add_argument("--duration", type=float, default=3.0,
                    help="offered-load window, seconds")
    ap.add_argument("--deadline-ms", type=float, default=50.0,
                    help="per-request deadline")
    ap.add_argument("--max-delay-ms", type=float, default=5.0,
                    help="micro-batch coalescing window")
    ap.add_argument("--max-batch", type=int, default=32,
                    help="micro-batch size trigger")
    ap.add_argument("--replicas", type=int, default=1)
    ap.add_argument("--swap", action="store_true",
                    help="hot-swap the model mid-run")
    args = ap.parse_args(argv)
    if args.arch is not None:
        return _serve_lm(args)
    return _serve_pgm(args)


if __name__ == "__main__":
    raise SystemExit(main())
