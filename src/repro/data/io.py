"""ARFF-style text IO — the paper's DataStreamLoader/Writer (§3.1).

A minimal Weka-ARFF subset: ``@relation``, ``@attribute <name> REAL`` or
``@attribute <name> {v0,v1,...}``, ``@data`` CSV rows.  Dynamic streams use
the paper's convention of leading SEQUENCE_ID / TIME_ID REAL columns.
"""

from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from repro.data.stream import (Attribute, DataStream, DynamicDataStream,
                               FINITE, REAL)


def load_arff(path: str) -> DataStream:
    attrs: List[Attribute] = []
    rows: List[List[str]] = []
    in_data = False
    with open(path) as f:
        for raw in f:
            line = raw.strip()
            if not line or line.startswith("%"):
                continue
            low = line.lower()
            if low.startswith("@relation"):
                continue
            if low.startswith("@attribute"):
                _, name, kind = line.split(None, 2)
                kind = kind.strip()
                if kind.upper() == "REAL" or kind.upper() == "NUMERIC":
                    attrs.append(Attribute(name, REAL))
                elif kind.startswith("{"):
                    vals = [v.strip() for v in kind.strip("{}").split(",")]
                    attrs.append(Attribute(name, FINITE, len(vals)))
                else:
                    raise ValueError(f"unsupported attribute type {kind!r}")
                continue
            if low.startswith("@data"):
                in_data = True
                continue
            if in_data:
                rows.append(line.split(","))
    cont_idx = [i for i, a in enumerate(attrs) if a.kind == REAL]
    disc_idx = [i for i, a in enumerate(attrs) if a.kind == FINITE]
    n = len(rows)
    xc = np.zeros((n, len(cont_idx)), np.float32)
    xd = np.zeros((n, len(disc_idx)), np.int32)
    for r, row in enumerate(rows):
        for j, i in enumerate(cont_idx):
            xc[r, j] = float(row[i])
        for j, i in enumerate(disc_idx):
            xd[r, j] = int(float(row[i]))
    return DataStream.from_arrays(attrs, xc, xd)


def save_arff(path: str, stream: DataStream, relation: str = "repro") -> None:
    batch = stream.collect()
    with open(path, "w") as f:
        f.write(f"@relation {relation}\n\n")
        for a in stream.attributes:
            if a.kind == REAL:
                f.write(f"@attribute {a.name} REAL\n")
            else:
                vals = ",".join(str(v) for v in range(a.card))
                f.write(f"@attribute {a.name} {{{vals}}}\n")
        f.write("\n@data\n")
        xc = np.asarray(batch.xc)
        xd = np.asarray(batch.xd)
        ci = di = 0
        col_kind = [a.kind for a in stream.attributes]
        for r in range(xc.shape[0]):
            parts = []
            ci = di = 0
            for kind in col_kind:
                if kind == REAL:
                    parts.append(repr(float(xc[r, ci])))
                    ci += 1
                else:
                    parts.append(str(int(xd[r, di])))
                    di += 1
            f.write(",".join(parts) + "\n")


def load_dynamic_arff(path: str) -> DynamicDataStream:
    """Paper §3.1 dynamic format: SEQUENCE_ID, TIME_ID leading columns."""
    flat = load_arff(path)
    batch = flat.collect()
    xc = np.asarray(batch.xc)
    names = [a.name for a in flat.attributes if a.kind == REAL]
    if names[:2] != ["SEQUENCE_ID", "TIME_ID"]:
        raise ValueError("dynamic ARFF needs SEQUENCE_ID, TIME_ID columns")
    seq = xc[:, 0].astype(int)
    t = xc[:, 1].astype(int)
    vals = xc[:, 2:]
    S, T = seq.max() + 1, t.max() + 1
    out = np.zeros((S, T, vals.shape[1]), np.float32)
    mask = np.zeros((S, T), np.float32)
    out[seq, t] = vals
    mask[seq, t] = 1.0
    attrs = [a for a in flat.attributes
             if a.name not in ("SEQUENCE_ID", "TIME_ID")]
    return DynamicDataStream(attrs, out, mask=mask)
