"""Synthetic data generators for every experiment in EXPERIMENTS.md."""

from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.data.stream import Attribute, DataStream, DynamicDataStream, REAL, FINITE


def gmm_stream(n: int, k: int, f: int, seed: int = 0, sep: float = 4.0,
               noise: float = 0.7) -> Tuple[DataStream, np.ndarray, np.ndarray]:
    """K-component diagonal GMM; returns (stream, true_means, labels)."""
    rng = np.random.default_rng(seed)
    means = rng.uniform(-sep, sep, size=(k, f)).astype(np.float32)
    z = rng.integers(0, k, size=n)
    x = means[z] + noise * rng.standard_normal((n, f)).astype(np.float32)
    attrs = [Attribute(f"GaussianVar{i}", REAL) for i in range(f)]
    return DataStream.from_arrays(attrs, x), means, z


def drift_stream(n_per_phase: int, f: int, seed: int = 0
                 ) -> Tuple[DataStream, int]:
    """Two-phase stream with an abrupt mean shift (concept drift) halfway."""
    rng = np.random.default_rng(seed)
    mu1 = rng.uniform(-2, 2, f).astype(np.float32)
    mu2 = mu1 + 6.0
    x1 = mu1 + rng.standard_normal((n_per_phase, f)).astype(np.float32)
    x2 = mu2 + rng.standard_normal((n_per_phase, f)).astype(np.float32)
    attrs = [Attribute(f"GaussianVar{i}", REAL) for i in range(f)]
    x = np.concatenate([x1, x2])
    return DataStream.from_arrays(attrs, x), n_per_phase


def nb_stream(n: int, classes: int, f_cont: int, f_disc: int, card: int = 3,
              seed: int = 0) -> Tuple[DataStream, np.ndarray]:
    """Naive-Bayes data: class -> continuous + discrete children."""
    rng = np.random.default_rng(seed)
    y = rng.integers(0, classes, n)
    means = rng.uniform(-3, 3, (classes, f_cont)).astype(np.float32)
    xc = means[y] + 0.8 * rng.standard_normal((n, f_cont)).astype(np.float32)
    tables = rng.dirichlet(np.ones(card) * 0.5, size=(classes, f_disc))
    xd = np.stack(
        [[rng.choice(card, p=tables[y[i], j]) for j in range(f_disc)]
         for i in range(n)]
    ).astype(np.int32)
    attrs = ([Attribute(f"G{i}", REAL) for i in range(f_cont)]
             + [Attribute(f"D{i}", FINITE, card) for i in range(f_disc)]
             + [Attribute("Class", FINITE, classes)])
    xd_full = np.concatenate([xd, y[:, None].astype(np.int32)], axis=1)
    return DataStream.from_arrays(attrs, xc, xd_full), y


def regression_stream(n: int, d: int, seed: int = 0, noise: float = 0.5
                      ) -> Tuple[DataStream, np.ndarray]:
    """Bayesian-linear-regression data: y = w^T x + b + eps."""
    rng = np.random.default_rng(seed)
    w = rng.standard_normal(d).astype(np.float32)
    b = 0.7
    x = rng.standard_normal((n, d)).astype(np.float32)
    y = x @ w + b + noise * rng.standard_normal(n).astype(np.float32)
    attrs = ([Attribute(f"X{i}", REAL) for i in range(d)]
             + [Attribute("Y", REAL)])
    return (DataStream.from_arrays(attrs, np.concatenate([x, y[:, None]], 1)),
            np.concatenate([w, [b]]).astype(np.float32))


def fa_stream(n: int, f: int, l: int, seed: int = 0, noise: float = 0.3
              ) -> Tuple[DataStream, np.ndarray]:
    """Factor-analysis data: x = W h + mu + eps, h ~ N(0, I_l)."""
    rng = np.random.default_rng(seed)
    W = rng.standard_normal((f, l)).astype(np.float32)
    mu = rng.uniform(-1, 1, f).astype(np.float32)
    h = rng.standard_normal((n, l)).astype(np.float32)
    x = h @ W.T + mu + noise * rng.standard_normal((n, f)).astype(np.float32)
    attrs = [Attribute(f"X{i}", REAL) for i in range(f)]
    return DataStream.from_arrays(attrs, x), W


def hmm_sequences(s: int, t: int, states: int, f: int, seed: int = 0
                  ) -> Tuple[DynamicDataStream, np.ndarray, np.ndarray, np.ndarray]:
    """Gaussian-emission HMM sequences; returns stream + true params."""
    rng = np.random.default_rng(seed)
    trans = rng.dirichlet(np.ones(states) * 0.3, size=states)
    # make transitions sticky so states are identifiable
    trans = 0.2 * trans + 0.8 * np.eye(states)
    init = np.ones(states) / states
    means = (np.arange(states)[:, None] * 4.0
             + rng.uniform(-1, 1, (states, f))).astype(np.float32)
    xs = np.zeros((s, t, f), np.float32)
    zs = np.zeros((s, t), np.int64)
    for i in range(s):
        z = rng.choice(states, p=init)
        for j in range(t):
            zs[i, j] = z
            xs[i, j] = means[z] + 0.5 * rng.standard_normal(f)
            z = rng.choice(states, p=trans[z])
    attrs = [Attribute(f"G{i}", REAL) for i in range(f)]
    return DynamicDataStream(attrs, xs), trans.astype(np.float32), means, zs


def lds_sequences(s: int, t: int, dim_h: int, f: int, seed: int = 0
                  ) -> Tuple[DynamicDataStream, np.ndarray, np.ndarray]:
    """Linear dynamical system: h_t = A h_{t-1} + w, x_t = C h_t + v."""
    rng = np.random.default_rng(seed)
    # stable A
    A = rng.standard_normal((dim_h, dim_h)) * 0.3
    A = 0.9 * A / np.abs(np.linalg.eigvals(A)).max()  # spectral radius 0.9
    C = rng.standard_normal((f, dim_h)).astype(np.float32)
    xs = np.zeros((s, t, f), np.float32)
    for i in range(s):
        h = rng.standard_normal(dim_h)
        for j in range(t):
            h = A @ h + 0.3 * rng.standard_normal(dim_h)
            xs[i, j] = C @ h + 0.2 * rng.standard_normal(f)
    attrs = [Attribute(f"G{i}", REAL) for i in range(f)]
    return DynamicDataStream(attrs, xs), A.astype(np.float32), C


def lda_corpus(n_docs: int, vocab: int, topics: int, doc_len: int = 80,
               seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Bag-of-words corpus from an LDA generative model.

    Returns (counts [n_docs, vocab], true_topics [topics, vocab])."""
    rng = np.random.default_rng(seed)
    beta = rng.dirichlet(np.ones(vocab) * 0.1, size=topics)
    counts = np.zeros((n_docs, vocab), np.float32)
    for d in range(n_docs):
        theta = rng.dirichlet(np.ones(topics) * 0.3)
        zs = rng.choice(topics, size=doc_len, p=theta)
        for z in zs:
            w = rng.choice(vocab, p=beta[z])
            counts[d, w] += 1
    return counts, beta.astype(np.float32)
