"""Synthetic data generators for every experiment in EXPERIMENTS.md."""

from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from repro.data.stream import Attribute, DataStream, DynamicDataStream, REAL, FINITE


def poison_stream(stream: DataStream, rate: float, seed: int = 0
                  ) -> DataStream:
    """Wrap ``stream`` with seeded NaN corruption: each row of each chunk
    independently goes fully-NaN with probability ``rate``.

    The chaos-test / bench counterpart of ``DataStream(validate=True)``
    and the streaming scans' non-finite quarantine — feed a poisoned
    stream through either and the dropped/skipped counts must match the
    injected corruption.  Deterministic per (stream, rate, seed)."""
    if not 0.0 <= rate <= 1.0:
        raise ValueError(f"rate must be in [0, 1], got {rate}")
    rng = np.random.default_rng(seed)

    def src():
        for xc, xd in stream.chunks():
            xc = np.array(xc, np.float32)
            if xc.shape[1]:
                rows = rng.random(xc.shape[0]) < rate
                xc[rows] = np.nan
            yield xc, np.asarray(xd)

    return DataStream(stream.attributes, src,
                      n_instances=stream.n_instances)


def gmm_stream(n: int, k: int, f: int, seed: int = 0, sep: float = 4.0,
               noise: float = 0.7) -> Tuple[DataStream, np.ndarray, np.ndarray]:
    """K-component diagonal GMM; returns (stream, true_means, labels)."""
    rng = np.random.default_rng(seed)
    means = rng.uniform(-sep, sep, size=(k, f)).astype(np.float32)
    z = rng.integers(0, k, size=n)
    x = means[z] + noise * rng.standard_normal((n, f)).astype(np.float32)
    attrs = [Attribute(f"GaussianVar{i}", REAL) for i in range(f)]
    return DataStream.from_arrays(attrs, x), means, z


def drift_stream(n_per_phase: int, f: int, seed: int = 0
                 ) -> Tuple[DataStream, int]:
    """Two-phase stream with an abrupt mean shift (concept drift) halfway."""
    rng = np.random.default_rng(seed)
    mu1 = rng.uniform(-2, 2, f).astype(np.float32)
    mu2 = mu1 + 6.0
    x1 = mu1 + rng.standard_normal((n_per_phase, f)).astype(np.float32)
    x2 = mu2 + rng.standard_normal((n_per_phase, f)).astype(np.float32)
    attrs = [Attribute(f"GaussianVar{i}", REAL) for i in range(f)]
    x = np.concatenate([x1, x2])
    return DataStream.from_arrays(attrs, x), n_per_phase


def nb_stream(n: int, classes: int, f_cont: int, f_disc: int, card: int = 3,
              seed: int = 0) -> Tuple[DataStream, np.ndarray]:
    """Naive-Bayes data: class -> continuous + discrete children."""
    rng = np.random.default_rng(seed)
    y = rng.integers(0, classes, n)
    means = rng.uniform(-3, 3, (classes, f_cont)).astype(np.float32)
    xc = means[y] + 0.8 * rng.standard_normal((n, f_cont)).astype(np.float32)
    tables = rng.dirichlet(np.ones(card) * 0.5, size=(classes, f_disc))
    xd = np.stack(
        [[rng.choice(card, p=tables[y[i], j]) for j in range(f_disc)]
         for i in range(n)]
    ).astype(np.int32)
    attrs = ([Attribute(f"G{i}", REAL) for i in range(f_cont)]
             + [Attribute(f"D{i}", FINITE, card) for i in range(f_disc)]
             + [Attribute("Class", FINITE, classes)])
    xd_full = np.concatenate([xd, y[:, None].astype(np.int32)], axis=1)
    return DataStream.from_arrays(attrs, xc, xd_full), y


def regression_stream(n: int, d: int, seed: int = 0, noise: float = 0.5
                      ) -> Tuple[DataStream, np.ndarray]:
    """Bayesian-linear-regression data: y = w^T x + b + eps."""
    rng = np.random.default_rng(seed)
    w = rng.standard_normal(d).astype(np.float32)
    b = 0.7
    x = rng.standard_normal((n, d)).astype(np.float32)
    y = x @ w + b + noise * rng.standard_normal(n).astype(np.float32)
    attrs = ([Attribute(f"X{i}", REAL) for i in range(d)]
             + [Attribute("Y", REAL)])
    return (DataStream.from_arrays(attrs, np.concatenate([x, y[:, None]], 1)),
            np.concatenate([w, [b]]).astype(np.float32))


def fa_stream(n: int, f: int, l: int, seed: int = 0, noise: float = 0.3
              ) -> Tuple[DataStream, np.ndarray]:
    """Factor-analysis data: x = W h + mu + eps, h ~ N(0, I_l)."""
    rng = np.random.default_rng(seed)
    W = rng.standard_normal((f, l)).astype(np.float32)
    mu = rng.uniform(-1, 1, f).astype(np.float32)
    h = rng.standard_normal((n, l)).astype(np.float32)
    x = h @ W.T + mu + noise * rng.standard_normal((n, f)).astype(np.float32)
    attrs = [Attribute(f"X{i}", REAL) for i in range(f)]
    return DataStream.from_arrays(attrs, x), W


def hmm_sequences(s: int, t: int, states: int, f: int, seed: int = 0
                  ) -> Tuple[DynamicDataStream, np.ndarray, np.ndarray, np.ndarray]:
    """Gaussian-emission HMM sequences; returns stream + true params."""
    rng = np.random.default_rng(seed)
    trans = rng.dirichlet(np.ones(states) * 0.3, size=states)
    # make transitions sticky so states are identifiable
    trans = 0.2 * trans + 0.8 * np.eye(states)
    init = np.ones(states) / states
    means = (np.arange(states)[:, None] * 4.0
             + rng.uniform(-1, 1, (states, f))).astype(np.float32)
    xs = np.zeros((s, t, f), np.float32)
    zs = np.zeros((s, t), np.int64)
    for i in range(s):
        z = rng.choice(states, p=init)
        for j in range(t):
            zs[i, j] = z
            xs[i, j] = means[z] + 0.5 * rng.standard_normal(f)
            z = rng.choice(states, p=trans[z])
    attrs = [Attribute(f"G{i}", REAL) for i in range(f)]
    return DynamicDataStream(attrs, xs), trans.astype(np.float32), means, zs


def lds_sequences(s: int, t: int, dim_h: int, f: int, seed: int = 0
                  ) -> Tuple[DynamicDataStream, np.ndarray, np.ndarray]:
    """Linear dynamical system: h_t = A h_{t-1} + w, x_t = C h_t + v."""
    rng = np.random.default_rng(seed)
    # stable A
    A = rng.standard_normal((dim_h, dim_h)) * 0.3
    A = 0.9 * A / np.abs(np.linalg.eigvals(A)).max()  # spectral radius 0.9
    C = rng.standard_normal((f, dim_h)).astype(np.float32)
    xs = np.zeros((s, t, f), np.float32)
    for i in range(s):
        h = rng.standard_normal(dim_h)
        for j in range(t):
            h = A @ h + 0.3 * rng.standard_normal(dim_h)
            xs[i, j] = C @ h + 0.2 * rng.standard_normal(f)
    attrs = [Attribute(f"G{i}", REAL) for i in range(f)]
    return DynamicDataStream(attrs, xs), A.astype(np.float32), C


def hmm_stream(n_batches: int, s: int, t: int, states: int, f: int,
               switch_at: Optional[int] = None, shift: float = 6.0,
               seed: int = 0):
    """Stream of HMM sequence batches with a mid-stream regime switch.

    ``n_batches`` batches of ``s`` sequences x ``t`` steps from a sticky
    Gaussian-emission HMM; from batch ``switch_at`` on (default: halfway)
    every emission mean jumps by ``shift`` — the temporal analog of
    ``drift_stream``/``bn_stream(n_chunks=...)`` for the ``seq_stream_fit``
    drift tests.  Returns (batches, attrs, switch_at) where ``batches`` is
    a list of equal-shape ``DynamicDataStream``s (one per arriving batch).
    """
    if switch_at is None:
        switch_at = n_batches // 2
    rng = np.random.default_rng(seed)
    trans = rng.dirichlet(np.ones(states) * 0.3, size=states)
    trans = 0.2 * trans + 0.8 * np.eye(states)
    init = np.ones(states) / states
    means = (np.arange(states)[:, None] * 4.0
             + rng.uniform(-1, 1, (states, f))).astype(np.float32)
    attrs = [Attribute(f"G{i}", REAL) for i in range(f)]
    batches = []
    for b in range(n_batches):
        mu = means + (shift if b >= switch_at else 0.0)
        xs = np.zeros((s, t, f), np.float32)
        for i in range(s):
            z = rng.choice(states, p=init)
            for j in range(t):
                xs[i, j] = mu[z] + 0.5 * rng.standard_normal(f)
                z = rng.choice(states, p=trans[z])
        batches.append(DynamicDataStream(attrs, xs))
    return batches, attrs, switch_at


def slds_stream(n_batches: int, s: int, t: int, dim_h: int, f: int,
                switch_at: Optional[int] = None, seed: int = 0):
    """Stream of switching-LDS sequence batches with a mid-stream regime
    switch: every sequence alternates between two dynamics matrices (a slow
    rotation and its reverse) at a per-sequence midpoint, and from batch
    ``switch_at`` on the emission map is re-drawn (the stream-level drift).
    Returns (batches, attrs, A_true [2, dim_h, dim_h], switch_at)."""
    if switch_at is None:
        switch_at = n_batches // 2
    rng = np.random.default_rng(seed)
    th = 0.5
    rot = np.eye(dim_h)
    rot[:2, :2] = 0.95 * np.array([[np.cos(th), -np.sin(th)],
                                   [np.sin(th), np.cos(th)]])
    A_true = np.stack([rot, rot.T]).astype(np.float32)   # [2, L, L]
    C1 = rng.standard_normal((f, dim_h)).astype(np.float32)
    C2 = rng.standard_normal((f, dim_h)).astype(np.float32)
    attrs = [Attribute(f"G{i}", REAL) for i in range(f)]
    batches = []
    for b in range(n_batches):
        C = C2 if b >= switch_at else C1
        xs = np.zeros((s, t, f), np.float32)
        for i in range(s):
            h = rng.standard_normal(dim_h)
            for j in range(t):
                A = A_true[0] if j < t // 2 else A_true[1]
                h = A @ h + 0.1 * rng.standard_normal(dim_h)
                xs[i, j] = C @ h + 0.1 * rng.standard_normal(f)
        batches.append(DynamicDataStream(attrs, xs))
    return batches, attrs, A_true, switch_at


# -- ground-truth structures (structure-learning experiments) ------------------


def random_discrete_bn(n_vars: int, card: int = 3, max_parents: int = 2,
                       seed: int = 0, conc: float = 0.25,
                       tree: bool = False):
    """Random discrete Bayesian network with bounded fan-in.

    Node ``D{i}`` draws its parents uniformly from ``D{0..i-1}`` (at most
    ``max_parents``; exactly one when ``tree=True``).  CPD rows are built
    identifiable by construction: each parent's value shifts a chunk of
    the child's probability mass to a distinct mode (plus ``conc`` of
    Dirichlet noise), so every edge carries detectable marginal AND joint
    dependence — random Dirichlet tables routinely produce near-
    independent edges no score can recover.  Returns the
    ``BayesianNetwork`` (sample it with :func:`bn_stream`); ground truth
    for ``learn_structure`` tests and the BENCH_structure driver.
    """
    import jax.numpy as jnp

    from repro.core.dag import (BayesianNetwork, DAG, MultinomialCPD,
                                Variables)

    rng = np.random.default_rng(seed)
    vs = Variables()
    nodes = [vs.new_multinomial(f"D{i}", card) for i in range(n_vars)]
    dag = DAG(vs)
    cpds = {}
    for i, v in enumerate(nodes):
        if tree:
            n_pa = 1 if i > 0 else 0
        else:
            n_pa = int(rng.integers(0, min(max_parents, i) + 1))
        pa = sorted(rng.choice(i, size=n_pa, replace=False)) if n_pa else []
        for p in pa:
            dag.add_parent(v, nodes[p])
        q = card ** len(pa)
        noise = rng.dirichlet(np.ones(card), size=q)
        table = conc * noise
        if pa:
            # per-parent mode weights: first parent strongest, all > noise
            w = np.array([2.0 ** -k for k in range(len(pa))])
            w = w / w.sum() * (1.0 - conc)
            offset = rng.integers(0, card, size=len(pa))
            for j in range(q):
                digits = [(j // card ** (len(pa) - 1 - k)) % card
                          for k in range(len(pa))]
                for k, d in enumerate(digits):
                    table[j, (d + offset[k]) % card] += w[k]
        else:
            table += (1.0 - conc) * rng.dirichlet(np.full(card, 0.8))
        table = table / table.sum(-1, keepdims=True)
        cpds[v.name] = MultinomialCPD(jnp.asarray(
            table.astype(np.float32).reshape((card,) * len(pa) + (card,))))
    return BayesianNetwork(dag, cpds)


def clg_tree_bn(n_vars: int, seed: int = 0, beta_lo: float = 0.8,
                beta_hi: float = 1.4, noise: float = 0.4):
    """Random linear-Gaussian tree: ``G{i}`` regresses on one earlier node
    with |beta| in [beta_lo, beta_hi] — strong enough that pairwise
    Gaussian MI recovers the tree exactly from ample data."""
    import jax.numpy as jnp

    from repro.core.dag import BayesianNetwork, CLGCPD, DAG, Variables

    rng = np.random.default_rng(seed)
    vs = Variables()
    nodes = [vs.new_gaussian(f"G{i}") for i in range(n_vars)]
    dag = DAG(vs)
    cpds = {nodes[0].name: CLGCPD(jnp.asarray(float(rng.uniform(-1, 1))),
                                  jnp.zeros((0,)), jnp.asarray(1.0))}
    for i in range(1, n_vars):
        p = int(rng.integers(0, i))
        dag.add_parent(nodes[i], nodes[p])
        beta = float(rng.uniform(beta_lo, beta_hi) * rng.choice([-1.0, 1.0]))
        cpds[nodes[i].name] = CLGCPD(
            jnp.asarray(float(rng.uniform(-1, 1))), jnp.asarray([beta]),
            jnp.asarray(float(noise * (0.5 + rng.random()))))
    return BayesianNetwork(dag, cpds)


def bn_stream(bn, n: int, seed: int = 0, n_chunks: int = 1) -> DataStream:
    """Sample ``n`` instances from a ``BayesianNetwork`` into a
    ``DataStream`` (continuous variables -> REAL/xc columns, discrete ->
    FINITE/xd, both in registry order).  ``n_chunks > 1`` splits the rows
    into that many source chunks so the stream drives the streaming /
    drift-adaptation paths."""
    import jax

    asg = bn.sample(jax.random.PRNGKey(seed), n)
    attrs: List[Attribute] = []
    cc, dd = [], []
    for v in bn.dag.variables:
        if v.is_discrete:
            attrs.append(Attribute(v.name, FINITE, v.card))
            dd.append(np.asarray(asg[v.name], np.int32))
        else:
            attrs.append(Attribute(v.name, REAL))
            cc.append(np.asarray(asg[v.name], np.float32))
    xc = (np.stack(cc, 1) if cc else np.zeros((n, 0), np.float32))
    xd = (np.stack(dd, 1) if dd else np.zeros((n, 0), np.int32))
    if n_chunks <= 1:
        return DataStream.from_arrays(attrs, xc, xd)
    bounds = np.linspace(0, n, n_chunks + 1).astype(int)

    def src():
        for a, b in zip(bounds, bounds[1:]):
            yield xc[a:b], xd[a:b]

    return DataStream(attrs, src, n_instances=n)


def lda_corpus(n_docs: int, vocab: int, topics: int, doc_len: int = 80,
               seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Bag-of-words corpus from an LDA generative model.

    Returns (counts [n_docs, vocab], true_topics [topics, vocab])."""
    rng = np.random.default_rng(seed)
    beta = rng.dirichlet(np.ones(vocab) * 0.1, size=topics)
    counts = np.zeros((n_docs, vocab), np.float32)
    for d in range(n_docs):
        theta = rng.dirichlet(np.ones(topics) * 0.3)
        zs = rng.choice(topics, size=doc_len, p=theta)
        for z in zs:
            w = rng.choice(vocab, p=beta[z])
            counts[d, w] += 1
    return counts, beta.astype(np.float32)
