"""LM token pipeline for the assigned architectures.

Synthetic-but-structured corpora (no external data in this container):

* ``markov_corpus``  — order-2 Markov chain over the vocab with a Zipf
  marginal: enough structure that a 100M model's loss falls well below
  log(V) within a few hundred steps (the end-to-end example's check).
* ``drift_corpus``   — two Markov regimes concatenated (tests the streaming
  VB trainer's drift response).
* ``TokenStream``    — bounded-memory batch iterator yielding TrainBatch,
  sharded to the data mesh.
"""

from __future__ import annotations

from typing import Iterator, Optional, Tuple

import numpy as np
import jax.numpy as jnp

from repro.train.step import TrainBatch


def _markov_tables(vocab: int, branch: int, seed: int):
    rng = np.random.default_rng(seed)
    # each context maps to `branch` likely successors (sparse structure)
    succ = rng.integers(0, vocab, size=(vocab, branch))
    probs = rng.dirichlet(np.ones(branch) * 0.5, size=vocab)
    return succ, probs


def markov_sequence(n: int, vocab: int, seed: int = 0, branch: int = 8
                    ) -> np.ndarray:
    succ, probs = _markov_tables(vocab, branch, seed)
    rng = np.random.default_rng(seed + 1)
    out = np.empty(n, np.int32)
    s = rng.integers(0, vocab)
    for i in range(n):
        out[i] = s
        s = succ[s, rng.choice(probs.shape[1], p=probs[s])]
    return out


def markov_sequence_fast(n: int, vocab: int, seed: int = 0, branch: int = 8
                         ) -> np.ndarray:
    """Vectorized sampler (~100x the python loop) for large corpora."""
    succ, probs = _markov_tables(vocab, branch, seed)
    rng = np.random.default_rng(seed + 1)
    cum = probs.cumsum(1)
    u = rng.random(n)
    out = np.empty(n, np.int32)
    s = int(rng.integers(0, vocab))
    # chunked: state dependency is sequential, but the RNG draw is pre-made
    for i in range(n):
        out[i] = s
        k = np.searchsorted(cum[s], u[i])
        s = succ[s, min(k, branch - 1)]
    return out


class TokenStream:
    """Yields fixed-shape TrainBatch from one long token array."""

    def __init__(self, tokens: np.ndarray, batch: int, seq: int,
                 enc_stub: Optional[Tuple[int, int]] = None, seed: int = 0):
        self.tokens = tokens
        self.batch, self.seq = batch, seq
        self.enc_stub = enc_stub  # (enc_len, d_model) for audio archs
        self.rng = np.random.default_rng(seed)

    def batches(self, n_steps: int) -> Iterator[TrainBatch]:
        n = len(self.tokens) - self.seq - 1
        for _ in range(n_steps):
            starts = self.rng.integers(0, n, self.batch)
            toks = np.stack([self.tokens[s: s + self.seq] for s in starts])
            labs = np.stack([self.tokens[s + 1: s + self.seq + 1]
                             for s in starts])
            enc = None
            if self.enc_stub:
                el, d = self.enc_stub
                enc = self.rng.standard_normal(
                    (self.batch, el, d)).astype(np.float32)
            yield TrainBatch(tokens=jnp.asarray(toks),
                             labels=jnp.asarray(labs),
                             enc_input=None if enc is None else jnp.asarray(enc))


def drift_corpus(n_per_phase: int, vocab: int, seed: int = 0) -> np.ndarray:
    a = markov_sequence_fast(n_per_phase, vocab, seed=seed)
    b = markov_sequence_fast(n_per_phase, vocab, seed=seed + 777)
    return np.concatenate([a, b])
