"""DataStream — paper §3.1 (``eu.amidst.core.datastream``).

A ``DataStream`` presents data as a sequence of fixed-shape batches
``Batch(xc, xd, mask)`` without ever materializing more than one batch —
the paper's "process the data sequentially without having to load all
observations into main memory".  Static data sets, generator-backed streams
and concatenations all share the interface, so learning code is agnostic
(paper: "the code for learning a model is independent of the processing
environment").

For the distributed case (`dvmp`), :meth:`sharded_batches` pads the batch to
a multiple of the data-mesh size; the launcher places shards.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Iterator, List, NamedTuple, Optional, Sequence, Tuple

import numpy as np
import jax.numpy as jnp

REAL = "REAL"
FINITE = "FINITE_SET"


@dataclasses.dataclass(frozen=True)
class Attribute:
    name: str
    kind: str          # REAL | FINITE_SET
    card: int = 0      # for FINITE_SET

    def __str__(self) -> str:
        return f"{self.name} {self.kind}"


class Batch(NamedTuple):
    xc: jnp.ndarray    # [B, F]  continuous columns
    xd: jnp.ndarray    # [B, Fd] discrete columns (int32)
    mask: jnp.ndarray  # [B]     1.0 = real instance, 0.0 = padding


class DataStream:
    """A (possibly unbounded) stream of instances with fixed attributes."""

    def __init__(
        self,
        attributes: Sequence[Attribute],
        source: Callable[[], Iterator[Tuple[np.ndarray, np.ndarray]]],
        n_instances: Optional[int] = None,
        validate: bool = False,
    ) -> None:
        self.attributes = list(attributes)
        self._source = source
        self.n_instances = n_instances
        self.cont_idx = [i for i, a in enumerate(self.attributes) if a.kind == REAL]
        self.disc_idx = [i for i, a in enumerate(self.attributes) if a.kind == FINITE]
        # validate=True screens every chunk: schema violations (wrong column
        # count) raise; non-finite xc rows and out-of-range xd rows are
        # QUARANTINED (dropped + counted) before they reach a learner
        self.validate = validate
        self.quarantined = 0                       # rows dropped, total
        self.chunk_quarantine: List[int] = []      # rows dropped per chunk

    # -- constructors ---------------------------------------------------------

    @staticmethod
    def from_arrays(attributes: Sequence[Attribute], xc: np.ndarray,
                    xd: Optional[np.ndarray] = None,
                    validate: bool = False) -> "DataStream":
        xc = np.asarray(xc, np.float32)
        if xd is None:
            xd = np.zeros((xc.shape[0], 0), np.int32)
        xd = np.asarray(xd, np.int32)

        def src():
            yield xc, xd

        return DataStream(attributes, src, n_instances=xc.shape[0],
                          validate=validate)

    @staticmethod
    def concat(streams: Sequence["DataStream"]) -> "DataStream":
        if not streams:
            raise ValueError("concat of zero streams")
        # silently concatenating mismatched schemas would misalign columns
        # in every downstream batch — validate attribute-for-attribute
        for i, s in enumerate(streams[1:], start=1):
            if s.attributes != streams[0].attributes:
                raise ValueError(
                    f"concat: stream {i} attribute schema "
                    f"{[str(a) for a in s.attributes]} does not match "
                    f"stream 0 {[str(a) for a in streams[0].attributes]}")

        def src():
            for s in streams:
                yield from s._source()

        n = None
        if all(s.n_instances is not None for s in streams):
            n = sum(s.n_instances for s in streams)
        return DataStream(streams[0].attributes, src, n_instances=n)

    # -- iteration --------------------------------------------------------------

    def _validate_chunk(self, ci: int, xc: np.ndarray, xd: np.ndarray
                        ) -> Tuple[np.ndarray, np.ndarray, int]:
        """Schema-check one chunk; drop non-finite / out-of-range rows.

        A wrong column count is a programming error and raises; bad DATA
        (NaN/Inf in xc, out-of-range categories in xd) is quarantined
        row-wise — the return is ``(clean_xc, clean_xd, n_dropped)``."""
        xc = np.asarray(xc)
        xd = np.asarray(xd)
        F, Fd = len(self.cont_idx), len(self.disc_idx)
        if xc.ndim != 2 or xc.shape[1] != F:
            raise ValueError(f"chunk {ci}: xc shape {xc.shape} does not "
                             f"match schema ({F} REAL attributes)")
        if xd.ndim != 2 or xd.shape[1] != Fd:
            raise ValueError(f"chunk {ci}: xd shape {xd.shape} does not "
                             f"match schema ({Fd} FINITE_SET attributes)")
        ok = np.isfinite(xc).all(axis=1) if F else np.ones(len(xc), bool)
        for j, i in enumerate(self.disc_idx):
            card = self.attributes[i].card
            ok &= (xd[:, j] >= 0) & (xd[:, j] < card)
        dropped = int((~ok).sum())
        return xc[ok], xd[ok], dropped

    def _iter(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        """Every consumer path (chunks/batches/collect) routes through
        here so ``validate=`` screens them uniformly."""
        if not self.validate:
            yield from self._source()
            return
        from repro.obs import sink as obs
        for ci, (xc, xd) in enumerate(self._source()):
            xc, xd, dropped = self._validate_chunk(ci, xc, xd)
            self.quarantined += dropped
            self.chunk_quarantine.append(dropped)
            if dropped and obs.enabled():
                obs.emit("quarantine", t=ci, site="data", dropped=dropped)
                from repro.obs import agg
                agg.REGISTRY.counter("quarantine_total", site="data"
                                     ).inc(dropped)
            yield xc, xd

    def chunks(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        """The stream's native (xc, xd) chunks, as the source yields them —
        the batching ``Model.update_model`` routes through the streaming
        drivers.  One pass over the source; no re-batching or padding.
        With ``validate=True`` each chunk is screened first."""
        yield from self._iter()

    def batches(self, batch_size: int) -> Iterator[Batch]:
        """Fixed-shape batches; the ragged tail is zero-padded and masked."""
        buf_c: List[np.ndarray] = []
        buf_d: List[np.ndarray] = []
        have = 0
        F, Fd = len(self.cont_idx), len(self.disc_idx)
        for xc, xd in self._iter():
            buf_c.append(xc); buf_d.append(xd); have += xc.shape[0]
            while have >= batch_size:
                cc = np.concatenate(buf_c) if len(buf_c) > 1 else buf_c[0]
                dd = np.concatenate(buf_d) if len(buf_d) > 1 else buf_d[0]
                out_c, rest_c = cc[:batch_size], cc[batch_size:]
                out_d, rest_d = dd[:batch_size], dd[batch_size:]
                buf_c, buf_d, have = [rest_c], [rest_d], rest_c.shape[0]
                yield Batch(jnp.asarray(out_c), jnp.asarray(out_d),
                            jnp.ones(batch_size, jnp.float32))
        if have > 0:
            cc = np.concatenate(buf_c) if len(buf_c) > 1 else buf_c[0]
            dd = np.concatenate(buf_d) if len(buf_d) > 1 else buf_d[0]
            pad = batch_size - have
            out_c = np.concatenate([cc, np.zeros((pad, F), np.float32)])
            out_d = np.concatenate([dd, np.zeros((pad, Fd), np.int32)])
            mask = np.concatenate([np.ones(have, np.float32),
                                   np.zeros(pad, np.float32)])
            yield Batch(jnp.asarray(out_c), jnp.asarray(out_d), jnp.asarray(mask))

    def sharded_batches(self, batch_size: int, n_shards: int) -> Iterator[Batch]:
        """Batches whose leading dim divides the data-mesh size."""
        if batch_size % n_shards:
            batch_size = ((batch_size // n_shards) + 1) * n_shards
        yield from self.batches(batch_size)

    # -- whole-stream collection (small data only; used by batch VMP fit) ------

    def collect(self, limit: Optional[int] = None) -> Batch:
        cs, ds, n = [], [], 0
        for xc, xd in self._iter():
            cs.append(xc); ds.append(xd); n += xc.shape[0]
            if limit and n >= limit:
                break
        xc = np.concatenate(cs); xd = np.concatenate(ds)
        if limit:
            xc, xd = xc[:limit], xd[:limit]
        return Batch(jnp.asarray(xc), jnp.asarray(xd),
                     jnp.ones(xc.shape[0], jnp.float32))

    def __str__(self) -> str:
        return "\n".join(str(a) for a in self.attributes)


# -- dynamic (sequence) data — paper §3.1 dynamic streams ----------------------


class SequenceBatch(NamedTuple):
    """[B, T, ...] sequence data with SEQUENCE_ID/TIME_ID semantics."""

    xc: jnp.ndarray    # [B, T, F]
    xd: jnp.ndarray    # [B, T, Fd]
    mask: jnp.ndarray  # [B, T]


class DynamicDataStream:
    """Sequences of equal length T (ragged sequences are right-padded)."""

    def __init__(self, attributes: Sequence[Attribute], xc: np.ndarray,
                 xd: Optional[np.ndarray] = None,
                 mask: Optional[np.ndarray] = None) -> None:
        self.attributes = list(attributes)
        self.xc = np.asarray(xc, np.float32)           # [S, T, F]
        self.xd = (np.asarray(xd, np.int32) if xd is not None
                   else np.zeros(self.xc.shape[:2] + (0,), np.int32))
        self.mask = (np.asarray(mask, np.float32) if mask is not None
                     else np.ones(self.xc.shape[:2], np.float32))

    def batches(self, batch_size: int) -> Iterator[SequenceBatch]:
        S = self.xc.shape[0]
        for i in range(0, S, batch_size):
            sl = slice(i, i + batch_size)
            xc, xd, m = self.xc[sl], self.xd[sl], self.mask[sl]
            pad = batch_size - xc.shape[0]
            if pad:
                xc = np.concatenate([xc, np.zeros((pad,) + xc.shape[1:], xc.dtype)])
                xd = np.concatenate([xd, np.zeros((pad,) + xd.shape[1:], xd.dtype)])
                m = np.concatenate([m, np.zeros((pad,) + m.shape[1:], m.dtype)])
            yield SequenceBatch(jnp.asarray(xc), jnp.asarray(xd), jnp.asarray(m))

    def collect(self) -> SequenceBatch:
        return SequenceBatch(jnp.asarray(self.xc), jnp.asarray(self.xd),
                             jnp.asarray(self.mask))
