"""Data substrate — the paper's ``datastream`` module, JAX-side.

``stream``      bounded-memory DataStream over continuous+discrete columns
``synthetic``   generators for every experiment (GMM, drift, HMM, regression)
``io``          ARFF-style text and npz round-trip
``tokens``      LM token pipeline for the assigned architectures
"""
