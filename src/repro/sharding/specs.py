"""PartitionSpec trees for params / optimizer / decode states.

Logical layout (DESIGN.md §5), production mesh ('pod','data','model'):

  TRAIN  — FSDP('data') x TP('model'), pure DP over 'pod':
    d_model-indexed weight dims  -> 'data'   (ZeRO weight sharding)
    head/ff/expert/vocab dims    -> 'model'  (tensor parallel)
    optimizer moments inherit the param specs (ZeRO-1/3 for free).

  SERVE  — TP('model') only (bf16 weights fit); batch over ('pod','data');
    decode-mode attention weights replicated, KV cache SEQ-sharded over
    'model' (context parallel — see attention.attention_decode_ctx_parallel).

Specs are matched to leaves by parameter NAME (the last one/two path keys),
so the one rule table covers every architecture's tree shape.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig

PyTree = Any


def _rules(fsdp: Optional[str], model: str, stacked: bool, mode: str,
           cfg: ModelConfig):
    """name -> spec for the trailing (non-layer) dims."""
    # §Perf change D: decode attention WEIGHTS shard over 'model' (q-heads)
    # even though decode ACTIVATIONS are model-replicated for the
    # ctx-parallel KV path — GSPMD inserts tiny [B,1,H,D] activation
    # gathers/psums instead of every chip reading every attention weight.
    att_model = model
    table = {
        # embeddings
        "table": P(model, fsdp),
        "pos": P(fsdp, None),
        # norms
        "scale": P(None), "bias": P(None),
        # attention [d, H, hd] / [H, hd, d]
        # q heads sharded (G-major GQA fold keeps this TP-able); k/v head
        # counts are usually < mesh model size -> replicated over 'model'
        "wq": P(fsdp, att_model, None),
        "wk": P(fsdp, None, None),
        "wv": P(fsdp, None, None),
        "wo": P(att_model, None, fsdp),
        # dense mlp
        "w_gate": P(fsdp, model),
        "w_up": P(fsdp, model),
        "w_down": P(model, fsdp),
        "b_up": P(model), "b_down": P(None),
        # moe (EP layout [s, E_loc, d, ff_loc]); router replicated
        "router": P(None, None),
        "moe/w_gate": P(model, None, fsdp, None),
        "moe/w_up": P(model, None, fsdp, None),
        "moe/w_down": P(model, None, None, fsdp),
        # mamba2
        "w_z": P(fsdp, model), "w_x": P(fsdp, model),
        "w_B": P(fsdp, None), "w_C": P(fsdp, None),
        "w_dt": P(fsdp, model),
        "conv_x": P(None, model), "conv_b_x": P(model),
        "conv_bc": P(None, None), "conv_b_bc": P(None),
        "A_log": P(model), "D": P(model), "dt_bias": P(model),
        "norm_scale": P(model),
        "w_out": P(model, fsdp),
    }
    return table


def fix_spec(spec: P, shape: Tuple[int, ...],
             axis_sizes: Optional[dict]) -> P:
    """Drop axis names on dims they don't evenly divide (-> replicate).

    jax requires in_shardings to divide the dim exactly (e.g. granite's
    vocab 49155 cannot shard 16-ways) — such dims fall back to replicated,
    which is also what a production system does for ragged vocab tails.
    """
    if axis_sizes is None:
        return spec
    fixed = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * len(shape)):
        if ax is None:
            fixed.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        size = 1
        for a in axes:
            size *= axis_sizes.get(a, 1)
        fixed.append(ax if dim % size == 0 else None)
    return P(*fixed[: len(shape)])


def param_specs(params: PyTree, cfg: ModelConfig, mode: str = "train", *,
                data_axes: Tuple[str, ...] = ("data",),
                model_axis: str = "model",
                axis_sizes: Optional[dict] = None) -> PyTree:
    """Build the PartitionSpec tree matching ``params``.

    mode: 'train' (FSDP+TP) | 'serve' (TP) | 'decode' (TP, attn replicated).
    ``axis_sizes``: mesh axis sizes for divisibility fixing (see fix_spec).
    """
    if mode == "train_fsdp":
        # pure-FSDP strategy (§Perf change C): weights sharded over EVERY
        # mesh axis, no tensor parallelism — activation psums disappear in
        # favour of param all-gathers + grad reduce-scatters.
        fsdp = tuple(data_axes) + (model_axis,)
        model_axis = None
    else:
        fsdp = data_axes[-1] if mode == "train" else None
    rules = _rules(fsdp, model_axis, True, mode, cfg)

    def spec_for(path, leaf):
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        name = keys[-1]
        parent = keys[-2] if len(keys) > 1 else ""
        qual = f"{parent}/{name}"
        spec = rules.get(qual, rules.get(name))
        if spec is None:
            return P()  # replicate unknowns
        # layer-stacked leaves ([L, ...]) get a leading None
        base_dims = len(spec)
        if leaf.ndim == base_dims + 1:
            spec = P(*((None,) + tuple(spec)))
        elif leaf.ndim != base_dims:
            return P()  # shape mismatch (e.g. shared block unstacked): safe
        return fix_spec(spec, tuple(leaf.shape), axis_sizes)

    return jax.tree_util.tree_map_with_path(spec_for, params)


def train_state_specs(state, cfg: ModelConfig, *, data_axes=("data",),
                      model_axis="model", axis_sizes=None, mode="train"):
    """TrainState / VBTrainState specs: moments mirror the param specs."""
    pspec = param_specs(state.params if hasattr(state, "params")
                        else state.vb.mean, cfg, mode,
                        data_axes=data_axes, model_axis=model_axis,
                        axis_sizes=axis_sizes)
    if hasattr(state, "params"):   # AdamW TrainState
        return type(state)(
            params=pspec,
            opt=type(state.opt)(m=pspec, v=pspec, step=P()),
            step=P(),
        )
    # VBTrainState
    vb = state.vb
    return type(state)(
        vb=type(vb)(mean=pspec, fisher=pspec, prior_mean=pspec,
                    prior_prec=pspec, step=P()),
        step=P(),
    )


def decode_state_specs(state, cfg: ModelConfig, *, data_axes=("data",),
                       model_axis="model", axis_sizes=None):
    """DecodeState specs: KV caches [L, B, C, Hkv, D] — batch over data,
    cache SEQ over 'model' (context parallel); SSM states head-sharded."""
    def fx(spec, leaf):
        return fix_spec(spec, tuple(leaf.shape), axis_sizes)

    def kv_spec(cache):
        return type(cache)(
            k=fx(P(None, data_axes, model_axis, None, None), cache.k),
            v=fx(P(None, data_axes, model_axis, None, None), cache.v),
            length=P(None),
        )

    kv = kv_spec(state.kv) if state.kv is not None else None
    shared = kv_spec(state.shared_kv) if state.shared_kv is not None else None
    ssm = None
    if state.ssm is not None:
        ssm = type(state.ssm)(
            h=fx(P(None, data_axes, model_axis, None, None), state.ssm.h),
            conv_x=fx(P(None, data_axes, None, model_axis), state.ssm.conv_x),
            conv_bc=fx(P(None, data_axes, None, None), state.ssm.conv_bc),
        )
    enc_kv = None
    if state.enc_kv is not None:
        enc_kv = (fx(P(None, data_axes, None, None, None), state.enc_kv[0]),
                  fx(P(None, data_axes, None, None, None), state.enc_kv[1]))
    return type(state)(kv=kv, ssm=ssm, shared_kv=shared, enc_kv=enc_kv)
