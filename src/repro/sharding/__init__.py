"""PartitionSpec tables per (architecture x mode x mesh) — DESIGN.md §5."""

from repro.sharding.specs import (
    decode_state_specs,
    param_specs,
    train_state_specs,
)
