"""Checkpointing: flat-key npz round-trip for arbitrary pytrees.

Plays the role of the paper's model-persistence (HUGIN/ARFF export): the
neutral numpy container is the interop boundary (DESIGN.md §7.3).
"""

from __future__ import annotations

import os
from typing import Any, Dict, Tuple

import numpy as np
import jax
import jax.numpy as jnp

PyTree = Any

_SEP = "\x1f"  # unit separator: safe key joiner


def _flatten(tree: PyTree) -> Dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out[key] = np.asarray(leaf)
    return out


def save(path: str, tree: PyTree) -> None:
    tmp = path + ".tmp.npz"  # savez keeps the name when it ends with .npz
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(tmp, **_flatten(tree))
    os.replace(tmp, path)


def load(path: str, like: PyTree) -> PyTree:
    """Restore into the structure of ``like`` (shape/dtype-checked)."""
    with np.load(path) as data:
        flat, tdef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for p, leaf in flat:
            key = _SEP.join(str(getattr(q, "key", getattr(q, "idx", q)))
                            for q in p)
            arr = data[key]
            if arr.shape != tuple(leaf.shape):
                raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
            leaves.append(jnp.asarray(arr, leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves)
