"""Training substrate: optimizers, train step, trainer loop, checkpointing."""
