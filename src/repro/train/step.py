"""train_step / loss — the jit/pjit unit the launcher lowers.

``train_step``     AdamW step (the throughput baseline).
``vb_train_step``  streaming-VB (VON) step — the paper's technique as a
                   first-class training mode (--optimizer vb).
"""

from __future__ import annotations

from functools import partial
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.bayes import vb_optimizer as vb
from repro.configs.base import ModelConfig
from repro.nn import transformer as T
from repro.train import optimizer as opt

PyTree = Any


def lm_loss(logits: jnp.ndarray, labels: jnp.ndarray,
            mask: Optional[jnp.ndarray] = None,
            z_loss: float = 1e-4) -> jnp.ndarray:
    """Next-token cross entropy with z-loss; logits fp32 [B, S, V]."""
    logz = jax.nn.logsumexp(logits, -1)                      # [B, S]
    gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    nll = logz - gold
    zl = z_loss * logz ** 2
    per_tok = nll + zl
    if mask is None:
        return per_tok.mean()
    return (per_tok * mask).sum() / jnp.maximum(mask.sum(), 1.0)


class TrainBatch(NamedTuple):
    tokens: jnp.ndarray          # [B, S] int32
    labels: jnp.ndarray          # [B, S] int32 (shifted by the pipeline)
    enc_input: Optional[jnp.ndarray] = None  # audio/vlm stub embeddings


class TrainState(NamedTuple):
    params: PyTree
    opt: opt.AdamWState
    step: jnp.ndarray


def init_train_state(params: PyTree) -> TrainState:
    return TrainState(params=params, opt=opt.adamw_init(params),
                      step=jnp.zeros((), jnp.int32))


def loss_fn(params, batch: TrainBatch, cfg: ModelConfig, sh: T.Shardings,
            aux_weight: float = 0.01):
    out = T.forward(params, batch.tokens, cfg, sh, remat=True,
                    enc_input=batch.enc_input)
    loss = lm_loss(out.logits, batch.labels)
    return loss + aux_weight * out.moe_aux, (loss, out.moe_aux)


def train_step(state: TrainState, batch: TrainBatch, cfg: ModelConfig,
               sh: T.Shardings = T.NO_SHARD, *,
               lr_fn=opt.cosine_schedule(3e-4, 100, 10_000)
               ) -> Tuple[TrainState, dict]:
    (total, (loss, aux)), grads = jax.value_and_grad(
        loss_fn, has_aux=True)(state.params, batch, cfg, sh)
    params, ostate = opt.adamw_update(state.params, grads, state.opt,
                                      lr_fn=lr_fn)
    return (TrainState(params=params, opt=ostate, step=state.step + 1),
            {"loss": loss, "moe_aux": aux, "total": total})


# -- streaming-VB training mode (the paper's technique) -------------------------


class VBTrainState(NamedTuple):
    vb: vb.VBState
    step: jnp.ndarray


def init_vb_state(params: PyTree, prior_prec: float = 1.0) -> VBTrainState:
    return VBTrainState(vb=vb.vb_init(params, prior_prec=prior_prec),
                        step=jnp.zeros((), jnp.int32))


def vb_train_step(state: VBTrainState, batch: TrainBatch, cfg: ModelConfig,
                  sh: T.Shardings = T.NO_SHARD, *, n_total: float = 1e6,
                  lr: float = 0.1) -> Tuple[VBTrainState, dict]:
    """One VON step: grads of the NLL -> natural-gradient posterior update.

    The gradient all-reduce over the data axes IS the d-VMP message psum
    (DESIGN.md §2); XLA inserts it from the sharding of ``batch``.
    """
    (total, (loss, aux)), grads = jax.value_and_grad(
        loss_fn, has_aux=True)(state.vb.mean, batch, cfg, sh)
    new_vb = vb.vb_update(state.vb, grads, n_total=n_total, lr=lr)
    return (VBTrainState(vb=new_vb, step=state.step + 1),
            {"loss": loss, "moe_aux": aux, "total": total,
             "kl": vb.posterior_kl(new_vb, n_total)})


# -- serve step ------------------------------------------------------------------


def serve_step(params: PyTree, state: T.DecodeState, token: jnp.ndarray,
               cfg: ModelConfig, sh: T.Shardings = T.NO_SHARD):
    """ONE new token against the KV/SSM cache — the decode-shape unit."""
    return T.decode_step(params, state, token, cfg, sh)
