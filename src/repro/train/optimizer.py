"""Hand-rolled optimizers (no optax in this environment).

``adamw``            standard AdamW with cosine schedule + warmup.
``sgd_momentum``     baseline.

State is a pytree mirroring params; everything jit/pjit-friendly.  Under the
production mesh the (m, v) moments inherit the FSDP param sharding, giving
ZeRO-1/3 semantics for free.
"""

from __future__ import annotations

import math
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class AdamWState(NamedTuple):
    m: PyTree
    v: PyTree
    step: jnp.ndarray


def adamw_init(params: PyTree) -> AdamWState:
    z = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return AdamWState(m=z, v=jax.tree_util.tree_map(jnp.copy, z),
                      step=jnp.zeros((), jnp.int32))


def cosine_schedule(base_lr: float, warmup: int, total: int
                    ) -> Callable[[jnp.ndarray], jnp.ndarray]:
    def lr(step):
        w = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        return base_lr * w * 0.5 * (1 + jnp.cos(jnp.pi * prog))

    return lr


def adamw_update(params: PyTree, grads: PyTree, state: AdamWState, *,
                 lr_fn, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
                 clip_norm=1.0) -> Tuple[PyTree, AdamWState]:
    step = state.step + 1
    # global-norm clip (fp32)
    gnorm = jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree_util.tree_leaves(grads)))
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = lr_fn(step)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / (1 - b1 ** step)
        vhat = v / (1 - b2 ** step)
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state.m)
    flat_v = jax.tree_util.tree_leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(m=new_m, v=new_v, step=step)


class SGDState(NamedTuple):
    mom: PyTree
    step: jnp.ndarray


def sgd_init(params: PyTree) -> SGDState:
    return SGDState(
        mom=jax.tree_util.tree_map(
            lambda p: jnp.zeros_like(p, jnp.float32), params),
        step=jnp.zeros((), jnp.int32))


def sgd_update(params, grads, state: SGDState, *, lr=1e-2, momentum=0.9):
    def upd(p, g, m):
        m = momentum * m + g.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * m).astype(p.dtype), m

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    out = [upd(p, g, m) for p, g, m in zip(
        flat_p, jax.tree_util.tree_leaves(grads),
        jax.tree_util.tree_leaves(state.mom))]
    return (tdef.unflatten([o[0] for o in out]),
            SGDState(mom=tdef.unflatten([o[1] for o in out]),
                     step=state.step + 1))
