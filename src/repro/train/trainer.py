"""Trainer loop: checkpointing, eval, drift-aware streaming training.

Ties the substrate together the way the examples/launchers use it:
AdamW or streaming-VB steps, periodic eval + checkpoint, and — when the
drift monitor fires — Eq.-3 prior chaining with tempering (the NN analog of
core/streaming.stream_update's drift response).
"""

from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Callable, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.bayes import vb_optimizer as vb
from repro.bayes.drift import LossDriftMonitor
from repro.configs.base import ModelConfig
from repro.nn import transformer as T
from repro.train import checkpoint as ck
from repro.train import optimizer as opt
from repro.train import step as ts


@dataclasses.dataclass
class TrainerConfig:
    optimizer: str = "adamw"          # adamw | vb
    lr: float = 3e-4
    steps: int = 1000
    warmup: int = 100
    n_total: float = 1e6              # stream scale for VB
    ckpt_path: Optional[str] = None
    ckpt_every: int = 500
    eval_every: int = 100
    drift_threshold: float = 5.0
    drift_temper: float = 0.3         # prior forgetting on drift (Eq. 3)
    log_every: int = 25


class Trainer:
    def __init__(self, cfg: ModelConfig, params, tcfg: TrainerConfig,
                 sh: T.Shardings = T.NO_SHARD):
        self.cfg, self.tcfg, self.sh = cfg, tcfg, sh
        self.monitor = LossDriftMonitor.create(tcfg.drift_threshold)
        self.history: list = []
        self.n_drifts = 0
        if tcfg.optimizer == "adamw":
            self.state = ts.init_train_state(params)
            lr_fn = opt.cosine_schedule(tcfg.lr, tcfg.warmup, tcfg.steps)
            self._step = jax.jit(
                partial(ts.train_step, cfg=cfg, sh=sh, lr_fn=lr_fn))
        else:
            self.state = ts.init_vb_state(params)
            self._step = jax.jit(
                partial(ts.vb_train_step, cfg=cfg, sh=sh,
                        n_total=tcfg.n_total, lr=tcfg.lr))

    @property
    def params(self):
        return (self.state.params if self.tcfg.optimizer == "adamw"
                else self.state.vb.mean)

    def _on_drift(self):
        """Eq.-3 response: temper the chained prior so the model re-adapts
        (VB mode); AdamW mode just logs (no prior to chain)."""
        self.n_drifts += 1
        if self.tcfg.optimizer == "vb":
            new_vb = vb.chain_prior(self.state.vb, self.tcfg.n_total,
                                    temper=self.tcfg.drift_temper)
            self.state = self.state._replace(vb=new_vb)

    def fit(self, batches: Iterator, eval_fn: Optional[Callable] = None
            ) -> dict:
        t0 = time.time()
        tok_per_batch = None
        for i, batch in enumerate(batches):
            if tok_per_batch is None:
                tok_per_batch = int(np.prod(batch.tokens.shape))
            self.state, metrics = self._step(self.state, batch)
            loss = float(metrics["loss"])
            self.history.append(loss)
            self.monitor, drifted = self.monitor.observe(jnp.asarray(loss))
            if bool(drifted):
                self._on_drift()
            if self.tcfg.log_every and i % self.tcfg.log_every == 0:
                tps = tok_per_batch * (i + 1) / (time.time() - t0)
                obs.log(f"[trainer] step={i:5d} loss={loss:.4f} "
                        f"tok/s={tps:,.0f}"
                        + (" DRIFT" if bool(drifted) else ""),
                        component="trainer", step=i, loss=loss, tok_s=tps,
                        drifted=bool(drifted))
            if eval_fn and self.tcfg.eval_every \
                    and i and i % self.tcfg.eval_every == 0:
                eval_fn(self.params, i)
            if self.tcfg.ckpt_path and self.tcfg.ckpt_every \
                    and i and i % self.tcfg.ckpt_every == 0:
                ck.save(self.tcfg.ckpt_path, self.params)
        if self.tcfg.ckpt_path:
            ck.save(self.tcfg.ckpt_path, self.params)
        return {"final_loss": self.history[-1],
                "n_drifts": self.n_drifts,
                "steps": len(self.history)}
