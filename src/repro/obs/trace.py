"""Host-side span tracer: context-manager API, monotonic clocks,
parent/child nesting.

Spans measure HOST latency (queueing, trace/compile, dispatch+wait) —
the serving-tier quantities the ROADMAP's p50/p99 item needs.  They are
never entered inside a jitted function; device time is profiled via
``obs.profile`` (the ``jax.profiler`` hook) instead.

Below TRACE level, :func:`span` returns a shared null context — no
clock read, no allocation — so instrumented code paths cost one integer
compare when tracing is off.
"""

from __future__ import annotations

import contextlib
import itertools
import threading
import time
from typing import Any, Dict, Iterator, Optional

from repro.obs import sink

_ids = itertools.count(1)
_tls = threading.local()


def _stack() -> list:
    st = getattr(_tls, "stack", None)
    if st is None:
        st = _tls.stack = []
    return st


class Span:
    """One timed region.  ``dur_us`` is valid after the context exits;
    :meth:`add` attaches extra fields to the emitted event."""

    __slots__ = ("name", "span_id", "parent_id", "attrs", "_t0", "dur_us")

    def __init__(self, name: str, parent_id: Optional[int],
                 attrs: Dict[str, Any]):
        self.name = name
        self.span_id = next(_ids)
        self.parent_id = parent_id
        self.attrs = attrs
        self._t0 = 0
        self.dur_us = 0.0

    def add(self, **fields: Any) -> None:
        self.attrs.update(fields)


@contextlib.contextmanager
def _timed(name: str, attrs: Dict[str, Any]) -> Iterator[Span]:
    st = _stack()
    sp = Span(name, st[-1].span_id if st else None, attrs)
    st.append(sp)
    sp._t0 = time.perf_counter_ns()
    try:
        yield sp
    except BaseException as e:
        # A raising body must not look like a clean span: stamp the
        # exception type on the event and let it propagate.
        sp.attrs.setdefault("error", type(e).__name__)
        raise
    finally:
        sp.dur_us = (time.perf_counter_ns() - sp._t0) / 1e3
        st.pop()
        sink.emit("span", name=sp.name, dur_us=sp.dur_us,
                  span_id=sp.span_id, parent_id=sp.parent_id,
                  tid=threading.get_ident(), **sp.attrs)


class _NullSpan:
    __slots__ = ()
    span_id = None
    parent_id = None
    dur_us = 0.0

    def add(self, **fields: Any) -> None:
        pass


_NULL = _NullSpan()


@contextlib.contextmanager
def _null() -> Iterator[_NullSpan]:
    yield _NULL


def span(name: str, **attrs: Any):
    """Time a host-side region; emits a ``span`` event at TRACE level.

    Usage::

        with obs.span("serve.bucket", schema="X0,X1") as sp:
            ...
            sp.add(batch=8)

    Nesting records ``parent_id`` so a flush span owns its bucket spans.
    Returns a null context below TRACE level.
    """
    if sink.level() < sink.TRACE:
        return _null()
    return _timed(name, attrs)


def current_span() -> Optional[Span]:
    st = getattr(_tls, "stack", None)
    return st[-1] if st else None
