"""``jax.profiler`` integration hook.

Device-side time (kernel durations, HLO-op breakdown) is out of scope
for the host span tracer — this module bridges to the real profiler.
``benchmarks/run.py --profile DIR`` wraps each benchmark in
:func:`profile`; the resulting trace opens in TensorBoard / Perfetto.

jax is imported lazily so ``repro.obs`` stays importable before jax is
configured (see ``launch/dryrun.py``'s XLA_FLAGS ordering).
"""

from __future__ import annotations

import contextlib
from typing import Iterator, Optional

from repro.obs import sink


@contextlib.contextmanager
def profile(logdir: Optional[str]) -> Iterator[None]:
    """Capture a ``jax.profiler`` trace into ``logdir``.

    No-op when ``logdir`` is falsy, so call sites can pass the CLI flag
    straight through.  Emits a ``log`` event bracketing the capture when
    obs is enabled."""
    if not logdir:
        yield
        return
    import jax

    sink.emit("log", msg=f"profiler trace -> {logdir}", component="profile")
    jax.profiler.start_trace(logdir)
    try:
        yield
    finally:
        jax.profiler.stop_trace()
        sink.emit("log", msg=f"profiler trace written to {logdir}",
                  component="profile")
