"""JSONL telemetry sink, event schema, and the obs registry.

The measurement substrate every serving/perf PR reads from (ROADMAP:
"production serving tier ... p50/p99 latency" and "roofline gate" both
need a counter source).  Three pieces:

* **level knob** — ``REPRO_OBS=off|basic|trace`` (default ``off``).
  ``off`` is a zero-overhead no-op: every ``emit``/``count_kernel`` call
  is a single integer compare, spans return a cached null context and no
  file is ever opened.  ``basic`` emits structured events (logs, stream
  batch metrics, drift, serve buckets, kernel dispatch counts).
  ``trace`` additionally emits host-side latency spans (``obs.trace``).

* **JSONL sink** — every event is one JSON line appended to
  ``REPRO_OBS_PATH`` (default ``obs_events.jsonl``).  Base fields on every
  line: ``ts`` (unix seconds), ``seq`` (monotone per-process), ``run``
  (process run id), ``event`` (type).  Event types and their required
  fields are in :data:`EVENT_SCHEMA`; :func:`validate_obs_events` is the
  CI gate over an emitted file.

* **registry** — named estimator functions (:func:`register` /
  :func:`estimate`).  ``benchmarks/run.py`` registers the trip-count-aware
  HLO cost model (``benchmarks/hlo_analysis.py``) under ``"hlo_cost"`` so
  BENCH_* config blocks stamp analytical FLOP/byte estimates next to the
  measured inst/s, and each estimate is also recorded as a
  ``bench_estimate`` event.

Kernel-backend dispatch counters live here too (:func:`count_kernel`):
the suff-stats backends (``vmp._reduce_reg``/``_reduce_disc``) and the
``kernels/ops.py`` public wrappers bump a ``<kernel>:<backend>`` counter
at host-dispatch time.  Jitted callers dispatch once per TRACE (not per
device execution) — the counts answer "which backend did this program
take", not "how many times did the kernel run on device".
"""

from __future__ import annotations

import io
import json
import os
import sys
import threading
import time
import uuid
from typing import Any, Dict, Iterable, List, Optional, Tuple, Union

from repro.obs import agg as _agg

OFF, BASIC, TRACE = 0, 1, 2
_LEVEL_NAMES = {"off": OFF, "basic": BASIC, "trace": TRACE}

# Event schema: event type -> required extra fields (base fields ``ts``,
# ``seq``, ``run``, ``event`` are required on every line).  Extra fields
# beyond the required set are allowed everywhere.
EVENT_SCHEMA: Dict[str, Tuple[str, ...]] = {
    # human log line (mirrored to stderr by obs.log)
    "log": ("msg",),
    # one named scalar gauge/counter
    "metric": ("name", "value"),
    # per-batch streaming-VMP metrics (one per stream_fit/stream_update batch)
    "stream_batch": ("t", "elbo", "score", "ph", "drifted", "n_eff", "rho",
                     "sweeps"),
    # Page-Hinkley drift firing (subset of stream_batch rows where drifted)
    "drift": ("t", "ph", "score"),
    # non-finite batch (or poisoned input rows) skipped with the carried
    # posterior held — the streaming scans' health gate and the DataStream
    # ``validate=`` row filter both emit these
    "quarantine": ("t",),
    # host-side latency span (trace level only)
    "span": ("name", "dur_us", "span_id"),
    # PGMQueryEngine.flush summary
    "serve_flush": ("mode", "n_queries", "n_buckets"),
    # one evidence-schema bucket inside a flush
    "serve_bucket": ("mode", "schema", "batch", "queue_depth", "cache_hit",
                     "compile_us", "execute_us", "latency_us"),
    # junction-tree propagation plan (emitted once per compiled schema)
    "jt_plan": ("pipeline", "n_cliques", "levels", "batch"),
    # one fused temporal VB-EM fit (pgm_models.dynamic update_model)
    "temporal_fit": ("model", "sweeps", "elbo", "delta"),
    # temporal filter/predict program compiled for a serve bucket
    "temporal_plan": ("pipeline", "batch", "T", "S", "horizon"),
    # async micro-batch flush decision (size / timeout / deadline trigger)
    "serve_deadline": ("mode", "schema", "batch", "trigger", "wait_us",
                       "deadline_miss"),
    # hot model swap: new network version published without dropping traffic
    "serve_swap": ("old_version", "new_version", "warmed_plans", "drained",
                   "dur_us"),
    # load shedding: a submit over the bounded-queue capacity was rejected
    "serve_shed": ("mode", "queue_depth", "max_queue"),
    # transient plan-compile failure retried with backoff (serve/plan.py)
    "serve_retry": ("attempt", "error"),
    # worker-replica supervision: dead worker respawned, bucket requeued
    "serve_worker": ("worker", "action", "requeued"),
    # streaming-state snapshot written (resilience/checkpoint.py)
    "checkpoint": ("t", "path", "reason"),
    # kernel-backend dispatch counter snapshot
    "kernel_dispatch": ("counts",),
    # registry estimator output (e.g. analytical HLO FLOP/byte model)
    "bench_estimate": ("name", "estimate"),
    # per-replica health score snapshot (serve/queue.py supervisor)
    "serve_health": ("worker", "score", "ewma_ms", "flushes", "errors"),
    # rolling SLO snapshot per (mode, schema) — exact-rank quantiles from
    # the obs/agg.py serve_request_ms histogram, emitted once per flush
    "slo": ("mode", "schema", "count", "p50_ms", "p95_ms", "p99_ms",
            "miss_rate"),
}

_BASE_FIELDS = ("ts", "seq", "run", "event")


class _State:
    def __init__(self) -> None:
        self.level = _LEVEL_NAMES.get(
            os.environ.get("REPRO_OBS", "off").lower(), OFF)
        self.path = os.environ.get("REPRO_OBS_PATH", "obs_events.jsonl")
        self.run = uuid.uuid4().hex[:12]
        self.seq = 0
        self.fh: Optional[io.TextIOBase] = None
        self.lock = threading.Lock()
        self.kernel_counts: Dict[str, int] = {}
        self.registry: Dict[str, Any] = {}


_STATE = _State()


def level() -> int:
    """Current obs level (OFF/BASIC/TRACE)."""
    return _STATE.level


def enabled(min_level: int = BASIC) -> bool:
    return _STATE.level >= min_level


def configure(level: Optional[str] = None, path: Optional[str] = None,
              reset_counters: bool = False) -> Dict[str, str]:
    """Programmatic override of the env knobs (tests, drivers).

    Returns the PREVIOUS ``{"level", "path"}`` so callers can restore it.
    """
    prev = {"level": {v: k for k, v in _LEVEL_NAMES.items()}[_STATE.level],
            "path": _STATE.path}
    with _STATE.lock:
        if level is not None:
            if level not in _LEVEL_NAMES:
                raise ValueError(f"unknown obs level {level!r}; expected "
                                 f"{sorted(_LEVEL_NAMES)}")
            _STATE.level = _LEVEL_NAMES[level]
        if path is not None and path != _STATE.path:
            if _STATE.fh is not None:
                _STATE.fh.close()
                _STATE.fh = None
            _STATE.path = path
        if reset_counters:
            _STATE.kernel_counts.clear()
    if reset_counters:
        _agg.REGISTRY.reset()
    return prev


def _write(line: str) -> None:
    if _STATE.fh is None:
        _STATE.fh = open(_STATE.path, "a", buffering=1)
    _STATE.fh.write(line + "\n")


def emit(event: str, **fields: Any) -> None:
    """Append one event line to the JSONL sink (no-op when level is off)."""
    if _STATE.level < BASIC:
        return
    with _STATE.lock:
        _STATE.seq += 1
        rec = {"ts": time.time(), "seq": _STATE.seq, "run": _STATE.run,
               "event": event, **fields}
        _write(json.dumps(rec, default=_jsonable))
    return


def _jsonable(o: Any) -> Any:
    """Fallback encoder: numpy / jax scalars and arrays -> python."""
    if hasattr(o, "item") and getattr(o, "ndim", None) == 0:
        return o.item()
    if hasattr(o, "tolist"):
        return o.tolist()
    return str(o)


def log(msg: str, component: Optional[str] = None, **fields: Any) -> None:
    """Structured logger replacing the launchers' ad-hoc ``print()``s.

    The human-readable line always goes to stderr (launch drivers keep
    their console output regardless of the obs level); the structured
    ``log`` event is additionally appended to the JSONL sink when obs is
    enabled.
    """
    print(msg, file=sys.stderr, flush=True)
    if _STATE.level >= BASIC:
        emit("log", msg=msg, component=component, **fields)


# ---------------------------------------------------------------------------
# kernel-backend dispatch counters
# ---------------------------------------------------------------------------


def count_kernel(name: str) -> None:
    """Bump the host-dispatch counter for ``<kernel>:<backend>``.

    Called by the suff-stats backend dispatchers and the kernels/ops.py
    wrappers.  Single dict update when enabled, one integer compare when
    off.  Jitted callers hit this at trace time (once per compile)."""
    if _STATE.level < BASIC:
        return
    with _STATE.lock:
        _STATE.kernel_counts[name] = _STATE.kernel_counts.get(name, 0) + 1
    _agg.REGISTRY.counter("kernel_dispatch_total", kernel=name).inc()


def kernel_counts() -> Dict[str, int]:
    return dict(_STATE.kernel_counts)


def emit_kernel_counts(**extra: Any) -> None:
    """Snapshot the dispatch counters into a ``kernel_dispatch`` event."""
    if _STATE.level < BASIC or not _STATE.kernel_counts:
        return
    emit("kernel_dispatch", counts=dict(_STATE.kernel_counts), **extra)


# ---------------------------------------------------------------------------
# streaming metrics emission (host side, post-scan)
# ---------------------------------------------------------------------------


def emit_stream_events(info: Dict[str, Any]) -> None:
    """Emit per-batch ``stream_batch`` events (+ ``drift`` events for the
    batches whose Page-Hinkley test fired) from a ``stream_fit`` /
    ``stream_update`` info dict.  Host-side: called AFTER the scan, so the
    fused device program is untouched."""
    if _STATE.level < BASIC:
        return
    import numpy as np

    cols = {k: np.atleast_1d(np.asarray(info[k]))
            for k in ("elbo", "score", "ph", "drifted", "n_eff", "rho",
                      "sweeps", "quarantined") if k in info}
    T = max((v.shape[0] for v in cols.values()), default=0)
    n_drift = n_quar = 0
    for t in range(T):
        row = {k: v[t].item() for k, v in cols.items()}
        emit("stream_batch", t=t, **row)
        if row.get("drifted"):
            n_drift += 1
            emit("drift", t=t, ph=row.get("ph"), score=row.get("score"))
        if row.get("quarantined"):
            n_quar += 1
            emit("quarantine", t=t, site="stream", score=row.get("score"),
                 elbo=row.get("elbo"))
    if T:
        _agg.REGISTRY.counter("stream_batches_total").inc(T)
    if n_drift:
        _agg.REGISTRY.counter("drift_total", site="stream").inc(n_drift)
    if n_quar:
        _agg.REGISTRY.counter("quarantine_total", site="stream").inc(n_quar)


# ---------------------------------------------------------------------------
# registry — named estimators (analytical cost models, ...)
# ---------------------------------------------------------------------------


def register(name: str, fn: Any) -> None:
    """Register a named estimator callable in the obs registry."""
    _STATE.registry[name] = fn


def registered(name: str) -> bool:
    return name in _STATE.registry


def estimate(name: str, *args: Any, **kw: Any) -> Any:
    """Run a registered estimator; record its output as a
    ``bench_estimate`` event when obs is enabled.  Raises ``KeyError`` for
    an unregistered name."""
    fn = _STATE.registry[name]
    out = fn(*args, **kw)
    if _STATE.level >= BASIC:
        emit("bench_estimate", name=name, estimate=out)
    return out


# ---------------------------------------------------------------------------
# validation — the CI gate over an emitted JSONL file
# ---------------------------------------------------------------------------


def validate_obs_events(src: Union[str, Iterable[str]]) -> Dict[str, int]:
    """Validate a JSONL event stream against :data:`EVENT_SCHEMA`.

    ``src`` is a file path or an iterable of lines.  Raises ``ValueError``
    on the first malformed line (bad JSON, missing base field, unknown
    event type, missing required field, non-monotone ``seq`` within a
    run).  Returns ``{event_type: count}`` so callers can assert coverage.
    """
    if isinstance(src, str):
        with open(src) as fh:
            lines: List[str] = fh.readlines()
    else:
        lines = list(src)
    counts: Dict[str, int] = {}
    last_seq: Dict[str, int] = {}
    for i, line in enumerate(lines, 1):
        if not line.strip():
            continue
        try:
            rec = json.loads(line)
        except json.JSONDecodeError as e:
            raise ValueError(f"line {i}: invalid JSON ({e})") from e
        if not isinstance(rec, dict):
            raise ValueError(f"line {i}: event must be a JSON object")
        for f in _BASE_FIELDS:
            if f not in rec:
                raise ValueError(f"line {i}: missing base field {f!r}")
        if not isinstance(rec["ts"], (int, float)):
            raise ValueError(f"line {i}: ts must be a number")
        ev = rec["event"]
        if ev not in EVENT_SCHEMA:
            raise ValueError(f"line {i}: unknown event type {ev!r}")
        for f in EVENT_SCHEMA[ev]:
            if f not in rec:
                raise ValueError(
                    f"line {i}: event {ev!r} missing field {f!r}")
        run = rec["run"]
        if run in last_seq and rec["seq"] <= last_seq[run]:
            raise ValueError(
                f"line {i}: seq {rec['seq']} not monotone within run {run}")
        last_seq[run] = rec["seq"]
        counts[ev] = counts.get(ev, 0) + 1
    return counts
