"""Live metrics registry — thread-safe counters, gauges and mergeable
log-bucketed histograms with exact-rank quantile snapshots.

The PR 6 sink records raw *events*; this module is the aggregation tier
that can answer "what is p99 right now" in-process: hot paths record
into named instruments (one dict lookup + one lock per record), and any
thread can take a :meth:`MetricsRegistry.snapshot` — a plain-JSON view
that merges associatively across registries/processes
(:func:`merge_snapshots`) and exports to Prometheus text or feeds the
``slo`` events the serving queue emits per flush.

Three instrument kinds:

* :class:`Counter` — monotone float, ``inc(n)``.
* :class:`Gauge` — last-write-wins float with an update timestamp (the
  timestamp makes gauge merges associative: newest write wins).
* :class:`Histogram` — log-bucketed (geometric bucket edges
  ``lo * growth**i``), so nine decades of latency fit in ~150 sparse
  buckets with bounded relative error (``growth - 1`` per bucket).
  Quantiles are **exact-rank** over the recorded distribution: the
  bucket containing the rank-``floor(q*(count-1))`` observation is
  located by cumulative walk and its geometric midpoint returned
  (clipped to the exact observed min/max) — the same discipline as a
  production latency store, not a mean-based approximation.

Instruments are keyed by ``(name, sorted labels)``; the default
process-wide registry is :data:`REGISTRY`.  Everything here is pure
Python (no jax, no numpy) so ``repro.obs`` stays importable before jax
is configured, and record() sites stay cheap enough for serving hot
paths — callers gate on ``obs.enabled()`` so ``REPRO_OBS=off`` remains
one integer compare.
"""

from __future__ import annotations

import math
import threading
import time
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

LabelsT = Tuple[Tuple[str, str], ...]


def _labels_key(labels: Dict[str, Any]) -> LabelsT:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class Counter:
    """Monotone counter.  ``inc`` is thread-safe; ``value`` is a float."""

    __slots__ = ("name", "labels", "_lock", "_value")

    def __init__(self, name: str, labels: LabelsT = ()):
        self.name = name
        self.labels = labels
        self._lock = threading.Lock()
        self._value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError("counters only go up; use a Gauge")
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        return self._value

    def snapshot(self) -> Dict[str, Any]:
        return {"kind": "counter", "name": self.name,
                "labels": dict(self.labels), "value": self._value}


class Gauge:
    """Last-write-wins scalar.  Carries the wall-clock ``updated`` stamp
    so snapshot merges are associative (newest write wins)."""

    __slots__ = ("name", "labels", "_lock", "_value", "updated")

    def __init__(self, name: str, labels: LabelsT = ()):
        self.name = name
        self.labels = labels
        self._lock = threading.Lock()
        self._value = 0.0
        self.updated = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)
            self.updated = time.time()

    @property
    def value(self) -> float:
        return self._value

    def snapshot(self) -> Dict[str, Any]:
        return {"kind": "gauge", "name": self.name,
                "labels": dict(self.labels), "value": self._value,
                "updated": self.updated}


class Histogram:
    """Mergeable log-bucketed histogram with exact-rank quantiles.

    Bucket ``i`` covers ``[lo * growth**i, lo * growth**(i+1))``; values
    below ``lo`` land in a dedicated underflow bucket (represented by the
    exact observed min), values at/above ``hi`` in the overflow bucket
    (exact observed max).  Counts are kept sparse (dict), so an idle
    histogram costs a few hundred bytes.
    """

    __slots__ = ("name", "labels", "lo", "hi", "growth", "n_bins",
                 "_inv_log_growth", "_lock", "_counts", "count", "sum",
                 "min", "max")

    UNDER = -1  # underflow bin index

    def __init__(self, name: str, labels: LabelsT = (), *,
                 lo: float = 1e-3, hi: float = 1e7, growth: float = 1.15):
        if not (lo > 0 and hi > lo and growth > 1.0):
            raise ValueError("need 0 < lo < hi and growth > 1")
        self.name = name
        self.labels = labels
        self.lo = float(lo)
        self.hi = float(hi)
        self.growth = float(growth)
        self.n_bins = int(math.ceil(math.log(hi / lo) / math.log(growth)))
        self._inv_log_growth = 1.0 / math.log(growth)
        self._lock = threading.Lock()
        self._counts: Dict[int, int] = {}
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def _bin(self, v: float) -> int:
        if v < self.lo:
            return self.UNDER
        i = int(math.log(v / self.lo) * self._inv_log_growth)
        return min(i, self.n_bins)          # n_bins == overflow

    def edge(self, i: int) -> float:
        """Upper edge of bucket ``i`` (lower edge of bucket ``i+1``)."""
        return self.lo * self.growth ** (i + 1)

    def record(self, v: float) -> None:
        v = float(v)
        if v != v:                          # NaN: quarantine, don't poison
            return
        b = self._bin(v)
        with self._lock:
            self._counts[b] = self._counts.get(b, 0) + 1
            self.count += 1
            self.sum += v
            if v < self.min:
                self.min = v
            if v > self.max:
                self.max = v

    # -- quantiles ----------------------------------------------------------

    def quantile(self, q: float) -> float:
        with self._lock:
            return _quantile(self._counts, self.count, self.min, self.max,
                             self.lo, self.growth, self.n_bins, q)

    def quantiles(self, qs: Sequence[float]) -> List[float]:
        with self._lock:
            return [_quantile(self._counts, self.count, self.min, self.max,
                              self.lo, self.growth, self.n_bins, q)
                    for q in qs]

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            return {"kind": "histogram", "name": self.name,
                    "labels": dict(self.labels),
                    "lo": self.lo, "hi": self.hi, "growth": self.growth,
                    "n_bins": self.n_bins,
                    "counts": {str(k): v for k, v in self._counts.items()},
                    "count": self.count, "sum": self.sum,
                    "min": self.min if self.count else None,
                    "max": self.max if self.count else None}


def _quantile(counts: Dict[int, int], total: int, vmin: float, vmax: float,
              lo: float, growth: float, n_bins: int, q: float) -> float:
    """Exact-rank quantile over bucketed counts: locate the bucket holding
    the rank-``floor(q*(total-1))`` observation, return its geometric
    midpoint clipped to the observed [min, max]."""
    if total <= 0:
        return math.nan
    q = min(1.0, max(0.0, q))
    rank = int(q * (total - 1))
    seen = 0
    for b in sorted(counts):
        seen += counts[b]
        if seen > rank:
            if b == Histogram.UNDER:
                return vmin
            if b >= n_bins:
                return vmax
            mid = lo * growth ** (b + 0.5)
            return min(max(mid, vmin), vmax)
    return vmax


def quantile_from_snapshot(h: Dict[str, Any], q: float) -> float:
    """Exact-rank quantile over a histogram *snapshot* (post-merge view)."""
    if h.get("kind") != "histogram":
        raise ValueError("quantile_from_snapshot needs a histogram snapshot")
    counts = {int(k): v for k, v in h["counts"].items()}
    vmin = h["min"] if h["min"] is not None else math.nan
    vmax = h["max"] if h["max"] is not None else math.nan
    return _quantile(counts, h["count"], vmin, vmax, h["lo"], h["growth"],
                     h["n_bins"], q)


class MetricsRegistry:
    """Thread-safe get-or-create store of named, labeled instruments."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._metrics: Dict[Tuple[str, str, LabelsT], Any] = {}

    def _get(self, kind: str, cls, name: str, labels: Dict[str, Any],
             **kw: Any):
        key = (kind, name, _labels_key(labels))
        m = self._metrics.get(key)
        if m is None:
            with self._lock:
                m = self._metrics.get(key)
                if m is None:
                    m = self._metrics[key] = cls(name, key[2], **kw)
        return m

    def counter(self, name: str, **labels: Any) -> Counter:
        return self._get("counter", Counter, name, labels)

    def gauge(self, name: str, **labels: Any) -> Gauge:
        return self._get("gauge", Gauge, name, labels)

    def histogram(self, name: str, *, lo: float = 1e-3, hi: float = 1e7,
                  growth: float = 1.15, **labels: Any) -> Histogram:
        return self._get("histogram", Histogram, name, labels,
                         lo=lo, hi=hi, growth=growth)

    def snapshot(self) -> Dict[str, Any]:
        """Point-in-time plain-JSON view: ``{"metrics": [entry, ...]}``,
        each entry self-describing (kind/name/labels + state).  Snapshots
        merge associatively via :func:`merge_snapshots`."""
        with self._lock:
            metrics = list(self._metrics.values())
        return {"metrics": [m.snapshot() for m in metrics]}

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()


def _entry_key(e: Dict[str, Any]) -> Tuple[str, str, LabelsT]:
    return (e["kind"], e["name"], _labels_key(e["labels"]))


def _merge_entry(a: Dict[str, Any], b: Dict[str, Any]) -> Dict[str, Any]:
    if a["kind"] != b["kind"]:
        raise ValueError(f"cannot merge {a['kind']} with {b['kind']}")
    if a["kind"] == "counter":
        out = dict(a)
        out["value"] = a["value"] + b["value"]
        return out
    if a["kind"] == "gauge":
        return dict(a if a["updated"] >= b["updated"] else b)
    # histogram: bucket-wise sum; configs must agree for merge to be exact
    for f in ("lo", "hi", "growth", "n_bins"):
        if a[f] != b[f]:
            raise ValueError(f"histogram bucket configs differ on {f!r}")
    counts = dict(a["counts"])
    for k, v in b["counts"].items():
        counts[k] = counts.get(k, 0) + v
    mins = [m for m in (a["min"], b["min"]) if m is not None]
    maxs = [m for m in (a["max"], b["max"]) if m is not None]
    out = dict(a)
    out.update(counts=counts, count=a["count"] + b["count"],
               sum=a["sum"] + b["sum"],
               min=min(mins) if mins else None,
               max=max(maxs) if maxs else None)
    return out


def merge_snapshots(a: Dict[str, Any], b: Dict[str, Any]) -> Dict[str, Any]:
    """Merge two registry snapshots (associative and commutative up to
    entry order): counters add, gauges keep the newest write, histograms
    add bucket-wise.  The inputs are not mutated."""
    merged: Dict[Tuple[str, str, LabelsT], Dict[str, Any]] = {}
    order: List[Tuple[str, str, LabelsT]] = []
    for snap in (a, b):
        for e in snap["metrics"]:
            k = _entry_key(e)
            if k in merged:
                merged[k] = _merge_entry(merged[k], e)
            else:
                merged[k] = dict(e)
                order.append(k)
    return {"metrics": [merged[k] for k in sorted(order)]}


#: Default process-wide registry.  The sink's kernel-dispatch counters,
#: the streaming/resilience counters and the serving SLO histograms all
#: record here; ``obs.configure(reset_counters=True)`` clears it.
REGISTRY = MetricsRegistry()
