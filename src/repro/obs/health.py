"""Per-replica health scoring for the async serving tier.

The ROADMAP resilience next-notch: drain traffic away from a degraded
worker *before* it dies.  Each ``AsyncPGMServer`` worker gets a rolling
score in [0, 1] built from

* a flush-latency EWMA (``alpha``-smoothed, milliseconds),
* an error EWMA over flush outcomes (flush raised / engine error), and
* penalty events (request-timeout watchdog firings, quarantines) folded
  into the same error EWMA.

The score is *relative*: the fastest replica's EWMA defines "healthy"
latency, so a uniform slowdown (bigger batches, colder cache) degrades
nobody, while one replica stalling (sick accelerator, GC storm,
injected ``slow_flush``) drops only its own score.

``score_i = (ref / max(ewma_i, ref)) * max(0, 1 - err_ewma_i)`` with
``ref = min_j ewma_j``; replicas with fewer than ``min_flushes``
observations score a neutral 1.0 (unknown is healthy — a cold replica
must be allowed to warm up).

:meth:`HealthTracker.should_defer` is the dispatch hook: a worker whose
score fell below ``threshold`` × the best score — while at least one
healthier peer is available — backs off from claiming due buckets for a
grace period, biasing traffic toward healthy replicas without ever
stranding a ticket (a deferred bucket is still served by the degraded
worker once the grace expires, and deferral is disabled entirely during
drain/stop).

Pure Python and lock-cheap: one lock acquire per flush record, no jax,
no allocation on the hot path beyond EWMA arithmetic — callers gate on
``obs.enabled()`` only for *event emission*; the tracker itself is
always live so dispatch biasing works even with ``REPRO_OBS=off``
(scoring never changes device programs, only which worker pops a
bucket, so off-mode results stay bit-identical).
"""

from __future__ import annotations

import threading
from typing import Any, Dict, List


class _Replica:
    __slots__ = ("ewma_ms", "err_ewma", "flushes", "errors", "timeouts",
                 "penalties")

    def __init__(self) -> None:
        self.ewma_ms = 0.0
        self.err_ewma = 0.0
        self.flushes = 0
        self.errors = 0
        self.timeouts = 0
        self.penalties = 0


class HealthTracker:
    """Rolling per-replica health scores (see module docstring).

    Parameters
    ----------
    n_replicas:    number of workers tracked (index = worker index).
    alpha:         EWMA smoothing factor in (0, 1]; higher = faster
                   reaction to a stall, lower = smoother.
    threshold:     a replica is *degraded* when its score drops below
                   ``threshold * max(scores)``.
    min_flushes:   observations required before a replica can be scored
                   (cold replicas are neutral until then).
    """

    def __init__(self, n_replicas: int, *, alpha: float = 0.3,
                 threshold: float = 0.5, min_flushes: int = 3):
        if n_replicas < 1:
            raise ValueError("need at least one replica")
        if not (0.0 < alpha <= 1.0):
            raise ValueError("alpha must be in (0, 1]")
        self.alpha = float(alpha)
        self.threshold = float(threshold)
        self.min_flushes = int(min_flushes)
        self._lock = threading.Lock()
        self._replicas = [_Replica() for _ in range(n_replicas)]

    def __len__(self) -> int:
        return len(self._replicas)

    # -- recording ----------------------------------------------------------

    def record_flush(self, widx: int, latency_ms: float,
                     error: bool = False) -> None:
        """One completed (or failed) bucket flush on worker ``widx``."""
        a = self.alpha
        with self._lock:
            r = self._replicas[widx]
            if r.flushes == 0:
                r.ewma_ms = float(latency_ms)
            else:
                r.ewma_ms += a * (float(latency_ms) - r.ewma_ms)
            r.err_ewma += a * ((1.0 if error else 0.0) - r.err_ewma)
            r.flushes += 1
            if error:
                r.errors += 1

    def record_timeout(self, widx: int) -> None:
        """A request-timeout watchdog firing attributed to ``widx``
        (the worker holding the expired in-flight bucket)."""
        with self._lock:
            r = self._replicas[widx]
            r.timeouts += 1
            r.err_ewma += self.alpha * (1.0 - r.err_ewma)

    def record_penalty(self, widx: int, kind: str = "penalty") -> None:
        """Generic demerit (quarantined output, shed, retry) folded into
        the error EWMA at half weight."""
        with self._lock:
            r = self._replicas[widx]
            r.penalties += 1
            r.err_ewma += 0.5 * self.alpha * (1.0 - r.err_ewma)

    # -- scoring ------------------------------------------------------------

    def _scores_locked(self) -> List[float]:
        warm = [r for r in self._replicas if r.flushes >= self.min_flushes]
        if not warm:
            return [1.0] * len(self._replicas)
        ref = min(r.ewma_ms for r in warm)
        ref = max(ref, 1e-6)
        out = []
        for r in self._replicas:
            if r.flushes < self.min_flushes:
                out.append(1.0)
                continue
            lat = ref / max(r.ewma_ms, ref)
            err = max(0.0, 1.0 - r.err_ewma)
            out.append(lat * err)
        return out

    def scores(self) -> List[float]:
        with self._lock:
            return self._scores_locked()

    def score(self, widx: int) -> float:
        return self.scores()[widx]

    def should_defer(self, widx: int) -> bool:
        """True when worker ``widx`` is degraded AND a healthier peer
        exists to pick up the slack.  Never true for a lone replica or
        when every replica is equally sick (someone must serve)."""
        if len(self._replicas) < 2:
            return False
        with self._lock:
            s = self._scores_locked()
        mx = max(s)
        if mx <= 0.0 or s[widx] >= self.threshold * mx:
            return False
        return any(j != widx and sj >= self.threshold * mx
                   for j, sj in enumerate(s))

    # -- snapshots ----------------------------------------------------------

    def snapshots(self) -> List[Dict[str, Any]]:
        """Per-replica state dicts (score, ewma_ms, counters, degraded
        flag) — the payload of ``serve_health`` events and
        ``AsyncPGMServer.stats()["health"]``."""
        with self._lock:
            s = self._scores_locked()
            mx = max(s) if s else 1.0
            return [{"score": round(s[i], 6),
                     "ewma_ms": round(r.ewma_ms, 3),
                     "flushes": r.flushes,
                     "errors": r.errors,
                     "timeouts": r.timeouts,
                     "penalties": r.penalties,
                     "degraded": bool(s[i] < self.threshold * mx)}
                    for i, r in enumerate(self._replicas)]
