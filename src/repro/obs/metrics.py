"""Metrics pytrees — jit/scan-safe counters and gauges.

These are plain NamedTuples of arrays, so they ride through ``lax.scan``
carries/outputs, ``shard_map`` and donation like any other pytree: the
fused hot paths (``streaming._stream_fit_scan``, ``vmp.local_step``'s
chunked scan, the ``dvmp`` mesh programs) compute them IN-GRAPH and the
host decides after the fact whether to ship them to the sink
(``sink.emit_stream_events``).  Nothing here imports jax — the fields
are whatever arrays the caller puts in, which keeps ``repro.obs``
importable before jax is configured (``launch/dryrun.py`` sets XLA
flags pre-import).
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple


class StreamBatchMetrics(NamedTuple):
    """Per-batch gauges from one streaming-VMP step (scalars in
    ``stream_update``; ``[T]`` stacked columns out of ``stream_fit``)."""

    elbo: Any      # final ELBO of the batch fit
    score: Any     # per-instance ELBO (drift statistic input)
    ph: Any        # Page-Hinkley statistic after the batch
    drifted: Any   # bool: did the detector fire on this batch
    n_eff: Any     # effective instance count (mask sum)
    rho: Any       # prior tempering factor applied (1.0 = no temper)
    sweeps: Any    # VMP sweeps-to-convergence for the batch fit
    quarantined: Any  # bool: non-finite batch skipped, carried posterior held

    def as_info(self) -> Dict[str, Any]:
        """The dict view that ``stream_fit``/``stream_update`` return
        (the public info API predates this pytree and stays dict-shaped)."""
        return dict(self._asdict())


class TemporalFitMetrics(NamedTuple):
    """Per-sweep gauges carried through the fused temporal VB-EM scans
    (``pgm_models.dynamic``): each field is a ``[sweeps]`` column stacked
    out of the ``lax.scan`` over sweeps — the temporal analog of
    :class:`StreamBatchMetrics`."""

    elbo: Any      # ELBO (loglik lower bound) after each sweep
    delta: Any     # |ELBO - previous ELBO| per sweep (0 once converged)
    active: Any    # bool: was this sweep actually run (vs held post-tol)

    def as_info(self) -> Dict[str, Any]:
        return dict(self._asdict())


class LocalStepMetrics(NamedTuple):
    """Optional output of ``vmp.local_step(..., with_metrics=True)``."""

    chunk_n_eff: Any   # [n_chunks] effective instances reduced per chunk


class DvmpMetrics(NamedTuple):
    """Optional output of ``dvmp.dvmp_fit(..., with_metrics=True)``."""

    shard_n: Any   # [n_shards] per-device effective instance counts
    sweeps: Any    # scalar: sweeps-to-convergence of the distributed fit
