"""Exporters for the obs aggregation tier.

Two read-side formats:

* :func:`prometheus_text` — Prometheus text exposition (version 0.0.4)
  of a :meth:`~repro.obs.agg.MetricsRegistry.snapshot`: counters and
  gauges as single samples, histograms as cumulative ``_bucket{le=...}``
  series plus ``_sum``/``_count``, ready to drop behind any scrape
  endpoint or push to a textfile collector.

* :func:`chrome_trace` / :func:`write_chrome_trace` — Chrome-trace /
  Perfetto JSON (``{"traceEvents": [...]}``) built from the ``span``
  events in an obs JSONL stream.  Spans become complete ("X") events on
  one lane per emitting thread, so ``chrome://tracing`` or
  https://ui.perfetto.dev renders the serving queue's nested
  flush/bucket spans as a flame graph.

Both are pure read-side transforms: they never touch the sink or the
registry hot paths, so they add nothing to the ``REPRO_OBS=off`` cost.
"""

from __future__ import annotations

import json
from typing import Any, Dict, Iterable, List, Union

from repro.obs import agg

# Fields of a span JSONL record that are structural rather than
# user-attached; everything else lands in the trace event's ``args``.
_SPAN_FIELDS = ("ts", "seq", "run", "event", "name", "dur_us", "span_id",
                "parent_id", "tid")


def _sanitize_name(name: str) -> str:
    out = [c if (c.isalnum() or c in "_:") else "_" for c in name]
    if out and out[0].isdigit():
        out.insert(0, "_")
    return "".join(out)


def _escape_label(v: Any) -> str:
    return str(v).replace("\\", "\\\\").replace('"', '\\"').replace(
        "\n", "\\n")


def _labels_text(labels: Dict[str, Any], extra: str = "") -> str:
    parts = [f'{_sanitize_name(str(k))}="{_escape_label(v)}"'
             for k, v in sorted(labels.items())]
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


def _fmt(v: float) -> str:
    if v != v:
        return "NaN"
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def prometheus_text(snapshot: Dict[str, Any]) -> str:
    """Render a registry snapshot in Prometheus text exposition format.

    Histogram buckets are emitted cumulatively with ``le`` set to the
    log-bucket upper edges (only buckets that change the cumulative
    count, plus ``+Inf``), matching how a Prometheus-native histogram
    with custom bounds would scrape.
    """
    lines: List[str] = []
    typed: set = set()

    def _type(name: str, kind: str) -> None:
        if name not in typed:
            typed.add(name)
            lines.append(f"# TYPE {name} {kind}")

    for e in snapshot["metrics"]:
        name = _sanitize_name(e["name"])
        kind = e["kind"]
        if kind in ("counter", "gauge"):
            _type(name, kind)
            lines.append(f"{name}{_labels_text(e['labels'])} {_fmt(e['value'])}")
            continue
        if kind != "histogram":
            raise ValueError(f"unknown metric kind {kind!r}")
        _type(name, "histogram")
        counts = {int(k): v for k, v in e["counts"].items()}
        cum = 0
        for b in sorted(counts):
            cum += counts[b]
            if b >= e["n_bins"]:
                continue            # overflow is covered by +Inf
            le = e["hi"] if b == e["n_bins"] - 1 else e["lo"] * e["growth"] ** (b + 1)
            lt = _labels_text(e["labels"], 'le="%r"' % le)
            lines.append(f"{name}_bucket{lt} {cum}")
        inf = _labels_text(e["labels"], 'le="+Inf"')
        lines.append(f"{name}_bucket{inf} {e['count']}")
        lines.append(f"{name}_sum{_labels_text(e['labels'])} {_fmt(e['sum'])}")
        lines.append(f"{name}_count{_labels_text(e['labels'])} {e['count']}")
    return "\n".join(lines) + "\n"


def default_prometheus_text() -> str:
    """Prometheus exposition of the process-wide default registry."""
    return prometheus_text(agg.REGISTRY.snapshot())


# ---------------------------------------------------------------------------
# Chrome trace / Perfetto export of span events
# ---------------------------------------------------------------------------


def _iter_records(src: Union[str, Iterable[Any]]) -> Iterable[Dict[str, Any]]:
    if isinstance(src, str):
        with open(src) as fh:
            for line in fh:
                if line.strip():
                    yield json.loads(line)
        return
    for item in src:
        if isinstance(item, str):
            if item.strip():
                yield json.loads(item)
        else:
            yield item


def chrome_trace(src: Union[str, Iterable[Any]]) -> Dict[str, Any]:
    """Convert the ``span`` events of an obs JSONL stream to Chrome-trace
    JSON.

    ``src`` is a JSONL file path, an iterable of lines, or an iterable of
    already-parsed dicts; non-span events are skipped.  Each span becomes
    a complete ("X") event: ``ts`` is the span *start* in microseconds
    (the sink stamps wall-clock at span end, so start = ts*1e6 - dur_us),
    ``dur`` is ``dur_us``, the lane (``tid``) is the emitting thread and
    the process is the obs run id.  Span attrs plus ``span_id`` /
    ``parent_id`` ride along in ``args``.
    """
    events: List[Dict[str, Any]] = []
    pids: Dict[str, int] = {}
    for rec in _iter_records(src):
        if rec.get("event") != "span":
            continue
        run = rec.get("run", "?")
        pid = pids.get(run)
        if pid is None:
            pid = pids[run] = len(pids) + 1
            events.append({"name": "process_name", "ph": "M", "pid": pid,
                           "tid": 0, "args": {"name": f"obs run {run}"}})
        dur = float(rec.get("dur_us", 0.0))
        args = {k: v for k, v in rec.items() if k not in _SPAN_FIELDS}
        args["span_id"] = rec.get("span_id")
        if rec.get("parent_id") is not None:
            args["parent_id"] = rec["parent_id"]
        events.append({
            "name": rec.get("name", "span"),
            "ph": "X",
            "ts": rec["ts"] * 1e6 - dur,
            "dur": dur,
            "pid": pid,
            "tid": rec.get("tid", 0),
            "args": args,
        })
    events.sort(key=lambda e: (e["ph"] != "M", e.get("ts", 0.0)))
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(src: Union[str, Iterable[Any]], out_path: str
                       ) -> Dict[str, Any]:
    """Write :func:`chrome_trace` output to ``out_path``; returns it."""
    trace = chrome_trace(src)
    with open(out_path, "w") as fh:
        json.dump(trace, fh)
    return trace
