"""repro.obs — observability: metrics pytrees, span tracing, JSONL sink.

Layering rule: this package (and everything imported here) is jax-free,
so ``repro.obs`` can be imported before jax is configured —
``launch/dryrun.py`` must set ``XLA_FLAGS`` before the first jax import.
The two jax-adjacent pieces are opt-in imports: ``repro.obs.metrics``
holds the pytree definitions (itself jax-free; the arrays come from the
caller) and ``repro.obs.profile`` imports jax lazily inside the context
manager.

Quick start::

    REPRO_OBS=basic  python ...   # JSONL events -> $REPRO_OBS_PATH
    REPRO_OBS=trace  python ...   # + host latency spans

    from repro import obs
    with obs.span("my.region", tag="x") as sp:
        ...
    obs.emit("metric", name="elbo", value=-1.23)

See ``obs/sink.py`` for the event schema and README "Observability".
"""

from repro.obs.agg import (REGISTRY, MetricsRegistry, merge_snapshots,
                           quantile_from_snapshot)
from repro.obs.sink import (BASIC, EVENT_SCHEMA, OFF, TRACE, configure,
                            count_kernel, emit, emit_kernel_counts,
                            emit_stream_events, enabled, estimate,
                            kernel_counts, level, log, register, registered,
                            validate_obs_events)
from repro.obs.trace import current_span, span
from repro.obs.export import (chrome_trace, default_prometheus_text,
                              prometheus_text, write_chrome_trace)
from repro.obs.health import HealthTracker
from repro.obs.metrics import (DvmpMetrics, LocalStepMetrics,
                               StreamBatchMetrics, TemporalFitMetrics)

__all__ = [
    "OFF", "BASIC", "TRACE", "EVENT_SCHEMA",
    "configure", "enabled", "level",
    "emit", "log", "span", "current_span",
    "count_kernel", "kernel_counts", "emit_kernel_counts",
    "emit_stream_events",
    "register", "registered", "estimate",
    "validate_obs_events",
    "REGISTRY", "MetricsRegistry", "merge_snapshots",
    "quantile_from_snapshot",
    "prometheus_text", "default_prometheus_text",
    "chrome_trace", "write_chrome_trace",
    "HealthTracker",
    "StreamBatchMetrics", "TemporalFitMetrics", "LocalStepMetrics",
    "DvmpMetrics",
]
