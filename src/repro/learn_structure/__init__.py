"""Score-based structure learning from streaming sufficient statistics.

The AMIDST toolbox doesn't just parameterize hand-wired networks — via its
MOA/Weka links it *learns* structures (TAN classifiers and friends) from
data streams.  This subsystem reproduces that capability natively, built
on the same batched suff-stats kernels the VMP engine runs on:

* :mod:`scores` — decomposable Bayesian family scores (BDeu for discrete
  families, Normal-Gamma / MVNormalGamma evidence for CLG families) from
  batched counts: one ``family_counts`` kernel call scores every candidate
  family of bounded fan-in; plus :func:`scores.fit_cpds`, the conjugate
  materializer from structure to ``BayesianNetwork``.
* :mod:`chowliu` — batched pairwise (conditional) mutual information +
  maximum spanning tree: Chow-Liu trees and TAN classifiers.
* :mod:`search` — greedy add/remove/reverse hill-climbing with family-
  score caching and ``DAG.is_ancestor`` acyclicity guards.
* :mod:`stream_adapt` — the streaming loop: windowed suff-stats feed the
  scores online, Page-Hinkley drift on the batch log-likelihood triggers
  re-search, and the adapted network flows into ``infer_exact`` / serving
  unchanged.
"""

from repro.learn_structure.chowliu import chow_liu, predict_class, tan
from repro.learn_structure.metrics import skeleton_f1, undirected_edges
from repro.learn_structure.scores import (clg_family_scores,
                                          cpds_from_stats,
                                          disc_family_scores, fit_cpds,
                                          nig_evidence, structure_stats)
from repro.learn_structure.search import SearchResult, hill_climb
from repro.learn_structure.stream_adapt import AdaptiveStructure

__all__ = [
    "AdaptiveStructure",
    "SearchResult",
    "chow_liu",
    "clg_family_scores",
    "cpds_from_stats",
    "disc_family_scores",
    "fit_cpds",
    "hill_climb",
    "nig_evidence",
    "predict_class",
    "skeleton_f1",
    "structure_stats",
    "tan",
    "undirected_edges",
]
