"""Chow-Liu trees and TAN classifiers from batched pairwise statistics.

The Chow-Liu algorithm is the classic "structure learning as counting"
entry point: the maximum-likelihood tree over the variables is the maximum
spanning tree of the pairwise mutual-information graph, so the whole
learner is (1) every pairwise joint histogram in ONE ``family_counts``
call, (2) MI per pair, (3) a host-side MST, (4) conjugate CPD fitting.

Two variable classes:

* **discrete features** — MI from the pairwise joint counts; the TAN
  variant (Friedman et al. 1997) conditions on the class: the conditional
  MI ``I(Xi; Xj | Y)`` comes from the triple counts (again one kernel
  call), the MST over it becomes the class-augmenting tree, and the class
  is wired as a parent of every feature — the streaming TAN classifier the
  AMIDST toolbox learns through its MOA link.

* **continuous features** — Gaussian MI ``-0.5 log(1 - rho^2)`` from the
  (masked) correlation matrix; the resulting directed tree is a CLG
  network (each child regresses on its tree parent).

Both return plain ``(edges, BayesianNetwork)``; the network has conjugate
posterior-mean CPDs (``scores.fit_cpds``) and drops straight into
``infer_exact`` / ``PGMQueryEngine``.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax.numpy as jnp

from repro.core.dag import BayesianNetwork
from repro.data.stream import Attribute, Batch, FINITE
from repro.learn_structure import scores as S
from repro.learn_structure.scores import as_batch as _as_batch


def pairwise_mi_discrete(xd: jnp.ndarray, cards: Sequence[int], *,
                         mask: Optional[jnp.ndarray] = None,
                         cond: Optional[Tuple[int, int]] = None,
                         backend: str = "einsum") -> np.ndarray:
    """[Fd, Fd] (conditional) mutual information between discrete columns.

    ``cond=(col, card)`` computes ``I(Xi; Xj | X_col)`` instead — the TAN
    weight — by treating the conditioning column as a shared "parent" in
    the family code.  All pairs ride one ``family_counts`` call.
    """
    Fd = len(cards)
    pairs = [(i, j) for i in range(Fd) for j in range(i + 1, Fd)
             if cond is None or (i != cond[0] and j != cond[0])]
    if not pairs:
        return np.zeros((Fd, Fd))
    fams = [(i, (j,) if cond is None else (j, cond[0])) for i, j in pairs]
    strides, r, q, C = S.family_strides(fams, cards)
    counts = np.asarray(S.batched_family_counts(xd, strides, C, mask,
                                                backend=backend), np.float64)
    mi = np.zeros((Fd, Fd))
    for m, (i, j) in enumerate(pairs):
        ci, cj = cards[i], cards[j]
        nz = cond[1] if cond is not None else 1
        # code layout (child minor, first parent most significant):
        # cond is None:  x_i + ci * x_j            -> reshape [cj, ci]
        # cond = z:      x_i + ci * (x_z + cz*x_j) -> reshape [cj, cz, ci]
        tab = counts[m, : ci * cj * nz].reshape(cj, nz, ci)
        tot = tab.sum()
        if tot <= 0:
            continue
        p = tab / tot                                   # [cj, cz, ci]
        pz = p.sum((0, 2), keepdims=True)               # [1, cz, 1]
        p_iz = p.sum(0, keepdims=True)                  # [1, cz, ci]
        p_jz = p.sum(2, keepdims=True)                  # [cj, cz, 1]
        with np.errstate(divide="ignore", invalid="ignore"):
            ratio = np.where(p > 0, p * pz / np.maximum(p_iz * p_jz, 1e-300),
                             1.0)
            val = float((p * np.log(np.where(p > 0, ratio, 1.0))).sum())
        mi[i, j] = mi[j, i] = max(val, 0.0)
    return mi


def pairwise_mi_gaussian(xc: jnp.ndarray, *,
                         mask: Optional[jnp.ndarray] = None) -> np.ndarray:
    """[F, F] Gaussian mutual information ``-0.5 log(1 - rho^2)`` from the
    masked sample correlation matrix."""
    x = np.asarray(xc, np.float64)
    w = (np.ones(x.shape[0]) if mask is None
         else np.asarray(mask, np.float64))
    n = max(w.sum(), 1.0)
    mu = (w[:, None] * x).sum(0) / n
    xm = (x - mu) * np.sqrt(w)[:, None]
    cov = xm.T @ xm / n
    sd = np.sqrt(np.maximum(np.diag(cov), 1e-12))
    rho = cov / np.outer(sd, sd)
    rho2 = np.clip(rho * rho, 0.0, 1.0 - 1e-12)
    mi = -0.5 * np.log1p(-rho2)
    np.fill_diagonal(mi, 0.0)
    return mi


def max_spanning_tree(weights: np.ndarray) -> List[Tuple[int, int]]:
    """Prim's algorithm on a dense weight matrix; returns V-1 undirected
    edges of the maximum-weight spanning tree."""
    V = weights.shape[0]
    if V <= 1:
        return []
    in_tree = np.zeros(V, bool)
    in_tree[0] = True
    best, best_from = weights[0].astype(np.float64), np.zeros(V, np.int64)
    best[0] = -np.inf
    edges = []
    for _ in range(V - 1):
        v = int(np.argmax(np.where(in_tree, -np.inf, best)))
        edges.append((int(best_from[v]), v))
        in_tree[v] = True
        upd = weights[v] > best
        best = np.where(upd & ~in_tree, weights[v], best)
        best_from = np.where(upd & ~in_tree, v, best_from)
    return edges


def _direct_from_root(edges: Sequence[Tuple[int, int]], root: int
                      ) -> List[Tuple[int, int]]:
    """Orient undirected tree edges away from ``root`` -> (parent, child)."""
    adj: Dict[int, List[int]] = {}
    for a, b in edges:
        adj.setdefault(a, []).append(b)
        adj.setdefault(b, []).append(a)
    out, seen, stack = [], {root}, [root]
    while stack:
        u = stack.pop()
        for v in adj.get(u, []):
            if v not in seen:
                seen.add(v)
                out.append((u, v))
                stack.append(v)
    return out


def chow_liu(data, attributes: Sequence[Attribute], *, root: int = 0,
             ess: float = 1.0, backend: str = "einsum",
             **fit_kw) -> Tuple[List[Tuple[str, str]], BayesianNetwork]:
    """Chow-Liu tree over the stream's features (all-discrete or
    all-continuous).  Returns the directed (parent, child) name edges and
    the fitted ``BayesianNetwork``."""
    batch = _as_batch(data)
    kinds = {a.kind for a in attributes}
    if len(kinds) != 1:
        raise ValueError("chow_liu needs all-discrete or all-continuous "
                         f"features, got mixed kinds {sorted(kinds)}")
    if not 0 <= root < len(attributes):
        raise ValueError(f"root {root} out of range for "
                         f"{len(attributes)} attributes")
    names = [a.name for a in attributes]
    if kinds == {FINITE}:
        cards = [a.card for a in attributes]
        mi = pairwise_mi_discrete(batch.xd, cards, mask=batch.mask,
                                  backend=backend)
    else:
        mi = pairwise_mi_gaussian(batch.xc, mask=batch.mask)
    directed = _direct_from_root(max_spanning_tree(mi), root)
    parents = {n: [] for n in names}
    for u, v in directed:
        parents[names[v]].append(names[u])
    bn = S.fit_cpds(attributes, parents, batch, ess=ess, backend=backend,
                    **fit_kw)
    return [(names[u], names[v]) for u, v in directed], bn


def tan(data, attributes: Sequence[Attribute], class_name: str, *,
        root: int = 0, ess: float = 1.0, backend: str = "einsum",
        **fit_kw) -> Tuple[List[Tuple[str, str]], BayesianNetwork]:
    """Tree-augmented naive Bayes: class -> every feature, plus the maximum
    spanning tree of the class-conditional MI ``I(Xi; Xj | class)`` over
    the discrete features, rooted at feature ``root``.

    Continuous features ride along naive-Bayes style (class parent only);
    the augmenting tree spans the discrete features — the counting part is
    one triple-count ``family_counts`` call.
    """
    feats = [a for a in attributes if a.name != class_name]
    cls = next(a for a in attributes if a.name == class_name)
    if cls.kind != FINITE:
        raise ValueError(f"class attribute {class_name!r} must be FINITE")
    cards = [a.card for a in attributes if a.kind == FINITE]
    disc_feats = [a for a in feats if a.kind == FINITE]
    # xd columns: FINITE attributes in attribute order
    dcol = {a.name: i for i, a in
            enumerate(a for a in attributes if a.kind == FINITE)}
    batch = _as_batch(data)
    parents: Dict[str, List[str]] = {a.name: [] for a in attributes}
    for a in feats:
        parents[a.name].append(class_name)
    edges: List[Tuple[str, str]] = [(class_name, a.name) for a in feats]
    if len(disc_feats) >= 2:
        if not 0 <= root < len(disc_feats):
            raise ValueError(f"root {root} out of range for "
                             f"{len(disc_feats)} discrete features")
        mi = pairwise_mi_discrete(batch.xd, cards, mask=batch.mask,
                                  cond=(dcol[class_name], cls.card),
                                  backend=backend)
        cols = [dcol[a.name] for a in disc_feats]
        sub = mi[np.ix_(cols, cols)]
        for u, v in _direct_from_root(max_spanning_tree(sub), root):
            parents[disc_feats[v].name].append(disc_feats[u].name)
            edges.append((disc_feats[u].name, disc_feats[v].name))
    bn = S.fit_cpds(attributes, parents, batch, ess=ess, backend=backend,
                    **fit_kw)
    return edges, bn


def predict_class(bn: BayesianNetwork, class_name: str,
                  batch: Batch, attributes: Sequence[Attribute]
                  ) -> jnp.ndarray:
    """argmax_c p(class = c | features) under the learned network —
    evaluated in one vectorized log-prob sweep per class value."""
    var = bn.dag.variables.by_name(class_name)
    _, col = S.variables_of(attributes)
    N = batch.xc.shape[0]
    asg = {}
    for a in attributes:
        kind, c = col[a.name]
        asg[a.name] = batch.xc[:, c] if kind == "c" else batch.xd[:, c]
    lps = []
    for c in range(var.card):
        asg[class_name] = jnp.full(N, c, jnp.int32)
        lps.append(bn.log_prob(asg))
    return jnp.stack(lps, -1).argmax(-1)
