"""Greedy hill-climbing structure search with batched family scoring.

The classic score-based search (add / remove / reverse one edge, take the
best positive improvement, repeat) arranged so the device does the heavy
lifting: because the Bayesian scores decompose over families, an operator's
delta touches at most two families, and every family score is cached by
``(child, parent set)`` — one iteration evaluates ONLY the cache-miss
families of its whole candidate neighborhood, all in batched kernel calls
(``scores.disc_family_scores`` / ``scores.clg_family_scores``).  This is
the Fast-PGM observation: structure search is dominated by counting, and
counting batches.

Acyclicity is guarded by ``DAG.is_ancestor`` — the same incremental
ancestor walk ``add_parent`` uses, touching only the candidate's ancestor
set instead of re-running a whole-graph check per operator.  The CLG
restriction (no continuous parent of a discrete child) is enforced on the
operator set, so any reachable state is a valid CLG network.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

from repro.core.dag import BayesianNetwork, DAG
from repro.data.stream import Attribute, Batch
from repro.learn_structure import scores as S
from repro.learn_structure.scores import as_batch as _as_batch

FamilyKey = Tuple[str, FrozenSet[str]]


@dataclasses.dataclass
class SearchResult:
    parents: Dict[str, Tuple[str, ...]]   # child name -> parent names
    score: float                          # total decomposable score
    n_iters: int
    n_scored: int                         # families evaluated (cache misses)
    trace: List[Tuple[str, str, str, float]]  # (op, parent, child, delta)
    bn: Optional[BayesianNetwork] = None


class _Scorer:
    """Cache of family scores, filled by batched device calls."""

    def __init__(self, batch: Batch, attributes: Sequence[Attribute], *,
                 ess: float, kappa: float, a0: float, b0: float,
                 backend: str) -> None:
        self.batch = batch
        self.vs, self.col = S.variables_of(attributes)
        self.cards = [a.card for a in attributes if a.kind == S.FINITE]
        self.ess, self.kappa, self.a0, self.b0 = ess, kappa, a0, b0
        self.backend = backend
        self.cache: Dict[FamilyKey, float] = {}
        self.n_scored = 0

    def ensure(self, keys) -> None:
        """Score every cache-miss family, batched by child kind."""
        disc: List[Tuple[FamilyKey, S.DiscFamily]] = []
        cont: List[Tuple[FamilyKey, S.ContFamily]] = []
        for key in keys:
            if key in self.cache:
                continue
            child, pset = key
            pa = sorted(pset)
            if self.vs.by_name(child).is_discrete:
                disc.append((key, (self.col[child][1],
                                   tuple(self.col[p][1] for p in pa))))
            else:
                cpa = tuple(self.col[p][1] for p in pa
                            if self.col[p][0] == "c")
                dpa = tuple(self.col[p][1] for p in pa
                            if self.col[p][0] == "d")
                cont.append((key, (self.col[child][1], cpa, dpa)))
        if disc:
            got = S.disc_family_scores(
                self.batch.xd, [f for _, f in disc], self.cards,
                mask=self.batch.mask, ess=self.ess, backend=self.backend)
            for (key, _), sc in zip(disc, got):
                self.cache[key] = float(sc)
        if cont:
            got = S.clg_family_scores(
                self.batch.xc, self.batch.xd, [f for _, f in cont],
                self.cards, mask=self.batch.mask, kappa=self.kappa,
                a0=self.a0, b0=self.b0, backend=self.backend)
            for (key, _), sc in zip(cont, got):
                self.cache[key] = float(sc)
        self.n_scored += len(disc) + len(cont)

    def __getitem__(self, key: FamilyKey) -> float:
        return self.cache[key]


def hill_climb(data, attributes: Sequence[Attribute], *,
               max_parents: int = 2, ess: float = 1.0, kappa: float = 1.0,
               a0: float = 1.0, b0: float = 1.0, max_iters: int = 200,
               min_delta: float = 1e-4, backend: str = "einsum",
               init_parents: Optional[Dict[str, Sequence[str]]] = None,
               fit: bool = True) -> SearchResult:
    """Greedy add/remove/reverse hill-climbing over CLG structures.

    ``data`` is a ``Batch`` or ``DataStream`` (the window, in the streaming
    setting); ``init_parents`` warm-starts the search (e.g. the previous
    structure after a drift signal).  Returns the learned parent sets, the
    final score, and (``fit=True``) the conjugate-fitted
    ``BayesianNetwork`` ready for ``infer_exact`` / serving.
    """
    batch = _as_batch(data)
    scorer = _Scorer(batch, attributes, ess=ess, kappa=kappa, a0=a0, b0=b0,
                     backend=backend)
    vs = scorer.vs
    names = [v.name for v in vs]
    dag = DAG(vs)
    if init_parents:
        for child, pas in init_parents.items():
            for p in pas:
                dag.add_parent(vs.by_name(child), vs.by_name(p))

    def pa_set(n: str) -> FrozenSet[str]:
        return frozenset(p.name for p in dag.parents[n])

    def kind_ok(parent: str, child: str) -> bool:
        # CLG restriction: a discrete child takes only discrete parents
        return not (vs.by_name(child).is_discrete
                    and not vs.by_name(parent).is_discrete)

    scorer.ensure({(n, pa_set(n)) for n in names})
    total = sum(scorer[(n, pa_set(n))] for n in names)
    trace: List[Tuple[str, str, str, float]] = []

    it = 0
    for it in range(1, max_iters + 1):
        # -- enumerate the legal neighborhood --------------------------------
        cands: List[Tuple[str, str, str]] = []
        for v in names:
            pv = pa_set(v)
            for u in names:
                if u == v:
                    continue
                if u in pv:
                    cands.append(("remove", u, v))
                    # reverse u->v: the new edge v->u must be kind-legal,
                    # respect u's fan-in, and close no cycle through
                    # another u ~> v path
                    if (kind_ok(v, u)
                            and len(dag.parents[u]) < max_parents):
                        dag.remove_parent(vs.by_name(v), vs.by_name(u))
                        ok = not dag.is_ancestor(u, v)
                        dag.add_parent(vs.by_name(v), vs.by_name(u))
                        if ok:
                            cands.append(("reverse", u, v))
                elif (kind_ok(u, v) and len(pv) < max_parents
                        and not dag.is_ancestor(v, u)):
                    cands.append(("add", u, v))

        # -- batch-score the cache misses, pick the best delta ---------------
        needed = set()
        for op, u, v in cands:
            pv = pa_set(v)
            if op == "add":
                needed.add((v, pv | {u}))
            elif op == "remove":
                needed.add((v, pv - {u}))
            else:
                needed.add((v, pv - {u}))
                needed.add((u, pa_set(u) | {v}))
        scorer.ensure(needed)

        best, best_delta = None, min_delta
        for op, u, v in cands:
            pv = pa_set(v)
            if op == "add":
                delta = scorer[(v, pv | {u})] - scorer[(v, pv)]
            elif op == "remove":
                delta = scorer[(v, pv - {u})] - scorer[(v, pv)]
            else:
                pu = pa_set(u)
                delta = (scorer[(v, pv - {u})] - scorer[(v, pv)]
                         + scorer[(u, pu | {v})] - scorer[(u, pu)])
            if delta > best_delta:
                best, best_delta = (op, u, v), delta
        if best is None:
            break

        op, u, v = best
        if op == "add":
            dag.add_parent(vs.by_name(v), vs.by_name(u))
        elif op == "remove":
            dag.remove_parent(vs.by_name(v), vs.by_name(u))
        else:
            dag.remove_parent(vs.by_name(v), vs.by_name(u))
            dag.add_parent(vs.by_name(u), vs.by_name(v))
        total += best_delta
        trace.append((op, u, v, best_delta))

    parents = {n: tuple(p.name for p in dag.parents[n]) for n in names}
    bn = None
    if fit:
        bn = S.fit_cpds(attributes, {n: list(p) for n, p in parents.items()},
                        batch, ess=ess, kappa=kappa, a0=a0, b0=b0,
                        backend=backend)
    return SearchResult(parents=parents, score=total, n_iters=it,
                        n_scored=scorer.n_scored, trace=trace, bn=bn)
