"""Drift-triggered streaming structure adaptation.

The streaming half of the subsystem: an :class:`AdaptiveStructure` consumes
a stream batch-by-batch and keeps a bounded window of recent instances
whose sufficient statistics feed the scores ONLINE: each arriving chunk is
reduced once (``scores.structure_stats`` — one ``family_counts`` call plus
the per-continuous-child regression moments, O(batch)), the per-chunk
stats ride along the window, and the conjugate CPD refit after every batch
just sums the stored chunk stats (``scores.cpds_from_stats``) — no
instance is ever re-counted while the structure stands.

Drift: the mean per-instance log-likelihood of each *incoming* batch under
the *current* network runs through the same Page-Hinkley machinery
``core.streaming`` uses for parameter drift (``drift_init`` /
``drift_update``).  When the PH statistic crosses the threshold the old
window is evidence about a dead concept: the window shrinks to the
post-drift batches and the structure search re-runs (warm-started from the
current structure for the hill-climbing learner), so the *graph itself*
adapts to concept drift — the paper's Eq.-3 streaming story lifted from
parameters to structure.

The learned network is always a plain ``BayesianNetwork`` with conjugate-
fitted CPDs: every update leaves ``self.bn`` ready for ``infer_exact``,
``ImportanceSampling`` and ``serve.PGMQueryEngine``.
"""

from __future__ import annotations

import functools
import operator
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.streaming import drift_init, drift_update
from repro.data.stream import Attribute, Batch, DataStream
from repro.learn_structure import chowliu as CL
from repro.learn_structure import scores as S
from repro.learn_structure.search import hill_climb

LEARNERS = ("hillclimb", "chowliu", "tan")


class AdaptiveStructure:
    """Windowed structure learner with Page-Hinkley re-search triggering.

    learner          "hillclimb" (general CLG search), "chowliu" (tree) or
                     "tan" (class-augmented tree; needs ``class_name``)
    window           target instances kept as re-search evidence; eviction
                     is chunk-granular and never drops below this, so the
                     window holds [window, window + batch) instances
    drift_threshold  PH lambda on the mean batch log-likelihood
    relearn_every    also re-run the search every k batches (None = only
                     on drift — CPDs still refit every batch)
    """

    def __init__(self, attributes: Sequence[Attribute], *,
                 learner: str = "hillclimb",
                 class_name: Optional[str] = None,
                 window: int = 20_000, drift_threshold: float = 3.0,
                 delta: float = 0.05, relearn_every: Optional[int] = None,
                 ess: float = 1.0, kappa: float = 1.0, a0: float = 1.0,
                 b0: float = 1.0, backend: str = "einsum",
                 **learn_kw) -> None:
        if learner not in LEARNERS:
            raise ValueError(f"unknown learner {learner!r}; "
                             f"expected one of {LEARNERS}")
        if learner == "tan" and class_name is None:
            raise ValueError("learner='tan' needs class_name")
        self.attributes = list(attributes)
        self.learner = learner
        self.class_name = class_name
        self.window = window
        self.drift_threshold = drift_threshold
        self.delta = delta
        self.relearn_every = relearn_every
        self.backend = backend
        # conjugate hyperparameters: one set for the search scores, the
        # relearn fits AND the per-batch refit, so self.bn never flips
        # smoothing regime between relearn and refit batches
        self.fit_kw = dict(ess=ess, kappa=kappa, a0=a0, b0=b0)
        self.learn_kw = learn_kw
        _, self.col = S.variables_of(self.attributes)

        self._chunks: List[Tuple[np.ndarray, np.ndarray]] = []
        # per-chunk suff stats under the CURRENT structure (None until a
        # structure exists); the refit sums these instead of re-counting
        self._chunk_stats: List[Optional[Dict[str, object]]] = []
        self._n_window = 0
        self.drift = drift_init()
        self.bn = None
        self.parents: Dict[str, Tuple[str, ...]] = {}
        self.n_batches = 0
        self.n_drifts = 0
        self.n_relearn = 0

    # -- window plumbing -----------------------------------------------------

    def _chunk_batch(self, xc: np.ndarray, xd: np.ndarray) -> Batch:
        return Batch(jnp.asarray(xc), jnp.asarray(xd),
                     jnp.ones(xc.shape[0], jnp.float32))

    def _push(self, xc: np.ndarray, xd: np.ndarray, *,
              compute_stats: bool) -> None:
        self._chunks.append((xc, xd))
        self._chunk_stats.append(
            S.structure_stats(self.attributes, dict(self.parents),
                              self._chunk_batch(xc, xd),
                              backend=self.backend)
            if compute_stats else None)
        self._n_window += xc.shape[0]
        while self._chunks and self._n_window - self._chunks[0][0].shape[0] \
                >= self.window:
            old = self._chunks.pop(0)
            self._chunk_stats.pop(0)
            self._n_window -= old[0].shape[0]

    def _window_batch(self) -> Batch:
        xc = np.concatenate([c for c, _ in self._chunks])
        xd = np.concatenate([d for _, d in self._chunks])
        return self._chunk_batch(xc, xd)

    # -- scoring the incoming batch under the current network -----------------

    def _batch_score(self, xc: jnp.ndarray, xd: jnp.ndarray) -> float:
        asg = {}
        for name, (kind, c) in self.col.items():
            asg[name] = xc[:, c] if kind == "c" else xd[:, c]
        return float(jnp.mean(self.bn.log_prob(asg)))

    # -- learning ------------------------------------------------------------

    def _relearn(self, warm: bool) -> None:
        old = {k: frozenset(v) for k, v in self.parents.items()}
        batch = self._window_batch()
        kw = {**self.fit_kw, **self.learn_kw}
        if self.learner == "hillclimb":
            res = hill_climb(batch, self.attributes, backend=self.backend,
                             init_parents=(dict(self.parents)
                                           if warm and self.parents
                                           else None), **kw)
            self.parents, self.bn = res.parents, res.bn
        elif self.learner == "chowliu":
            edges, self.bn = CL.chow_liu(batch, self.attributes,
                                         backend=self.backend, **kw)
            self.parents = self._parents_of(edges)
        else:
            edges, self.bn = CL.tan(batch, self.attributes, self.class_name,
                                    backend=self.backend, **kw)
            self.parents = self._parents_of(edges)
        self.n_relearn += 1
        # re-reduce window chunks under the new family set — but when the
        # search kept the structure (scheduled relearn, no change), the
        # stored stats are still valid and only the chunks pushed without
        # stats (the one awaiting this relearn) need reducing
        changed = old != {k: frozenset(v) for k, v in self.parents.items()}
        self._chunk_stats = [
            st if st is not None and not changed else S.structure_stats(
                self.attributes, dict(self.parents),
                self._chunk_batch(xc, xd), backend=self.backend)
            for (xc, xd), st in zip(self._chunks, self._chunk_stats)]

    def _parents_of(self, edges) -> Dict[str, Tuple[str, ...]]:
        out: Dict[str, List[str]] = {a.name: [] for a in self.attributes}
        for u, v in edges:
            out[v].append(u)
        return {k: tuple(v) for k, v in out.items()}

    def _refit(self) -> None:
        """Conjugate CPD tracking at fixed structure: sum the stored
        per-chunk stats (small arrays, O(n_chunks)) — no re-counting."""
        stats = jax.tree_util.tree_map(
            lambda *leaves: functools.reduce(operator.add, leaves),
            *self._chunk_stats)
        self.bn = S.cpds_from_stats(self.attributes, dict(self.parents),
                                    stats, **self.fit_kw)

    # -- the streaming API ----------------------------------------------------

    def update(self, xc, xd=None, mask=None) -> Dict[str, float]:
        """Consume one arriving batch; returns
        ``{score, ph, drifted, relearned, n_window}``."""
        if isinstance(xc, Batch):
            batch = xc
            keep = np.asarray(batch.mask) > 0          # drop tail padding
            xc, xd = np.asarray(batch.xc)[keep], np.asarray(batch.xd)[keep]
        elif mask is not None:
            keep = np.asarray(mask) > 0
            xc, xd = np.asarray(xc)[keep], (np.asarray(xd)[keep]
                                            if xd is not None else None)
        xc = np.asarray(xc, np.float32)
        xd = (np.asarray(xd, np.int32) if xd is not None
              else np.zeros((xc.shape[0], 0), np.int32))
        self.n_batches += 1

        score, ph, drifted = 0.0, 0.0, False
        if self.bn is not None:
            score = self._batch_score(jnp.asarray(xc), jnp.asarray(xd))
            self.drift, ph_ = drift_update(self.drift, jnp.asarray(score),
                                           delta=self.delta)
            ph = float(ph_)
            drifted = ph > self.drift_threshold
        if drifted:
            # the pre-drift window describes the dead concept — restart the
            # evidence from this batch and re-search
            self.n_drifts += 1
            self.drift = drift_init()
            self._chunks, self._chunk_stats, self._n_window = [], [], 0

        # decide BEFORE pushing: a relearn re-reduces every window chunk
        # under the (possibly new) structure anyway, so the arriving chunk
        # is only reduced at push time when the structure will stand
        relearned = (self.bn is None or drifted
                     or bool(self.relearn_every
                             and self.n_batches % self.relearn_every == 0))
        self._push(xc, xd, compute_stats=not relearned)
        if relearned:
            self._relearn(warm=drifted)
        else:
            self._refit()           # conjugate CPD tracking, same structure
        return {"score": score, "ph": ph, "drifted": float(drifted),
                "relearned": float(relearned),
                "n_window": float(self._n_window)}

    def fit_stream(self, stream: DataStream, batch_size: int = 500
                   ) -> List[Dict[str, float]]:
        """Drive :meth:`update` over a whole ``DataStream``."""
        return [self.update(b) for b in stream.batches(batch_size)]

    def edges(self) -> set:
        return {(p, c) for c, ps in self.parents.items() for p in ps}
