"""Structure-recovery metrics shared by tests, benchmarks and CI smokes."""

from __future__ import annotations

from typing import Dict, Iterable, Set, Union

from repro.core.dag import BayesianNetwork

EdgeSource = Union[BayesianNetwork, Dict, Iterable]


def undirected_edges(structure: EdgeSource) -> Set[frozenset]:
    """The undirected skeleton of a structure given as a
    ``BayesianNetwork``, a ``{child: parent names}`` dict, or an iterable
    of (parent, child) pairs."""
    if isinstance(structure, BayesianNetwork):
        return {frozenset((p.name, c))
                for c, ps in structure.dag.parents.items() for p in ps}
    if isinstance(structure, dict):
        return {frozenset((p, c)) for c, ps in structure.items() for p in ps}
    return {frozenset(e) for e in structure}


def skeleton_f1(true_structure: EdgeSource, got_structure: EdgeSource
                ) -> float:
    """F1 between undirected skeletons — the recovery metric gated by
    ``validate_bench_structure`` and asserted in the tier-1 tests."""
    t, g = undirected_edges(true_structure), undirected_edges(got_structure)
    if not t and not g:
        return 1.0          # an edgeless graph, exactly recovered
    tp = len(t & g)
    prec = tp / max(len(g), 1)
    rec = tp / max(len(t), 1)
    return 2 * prec * rec / max(prec + rec, 1e-12)
