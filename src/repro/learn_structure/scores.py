"""Decomposable Bayesian family scores from sufficient statistics.

A score-based structure learner only ever asks one question: "how well does
family (child, parent set) explain the data?"  For conjugate models the
answer is the closed-form marginal likelihood of the family, computed from
the family's sufficient statistics alone — so scoring is a counting
problem, and counting is what the batched kernels are for:

* **Discrete child, discrete parents** — the BDeu score (Heckerman et al.):
  the Dirichlet-multinomial evidence with the equivalent-sample-size prior
  ``alpha_jk = ess / (q r)``.  Counts for ALL candidate families come from
  ONE ``family_counts`` kernel call (``backend="pallas"``; the einsum
  fallback is ``kernels.ref.family_counts_ref`` — same ``backend=``
  dispatch as the VMP suff-stats reductions).

* **Continuous child, continuous + discrete parents (CLG, Eq. 2)** — the
  Normal-Gamma / MVNormalGamma evidence: per discrete parent configuration
  the Bayesian linear regression of the child on ``[1, x_parents]`` under
  the conjugate NIG prior has closed-form log marginal likelihood
  (:func:`nig_evidence`).  The per-(family, configuration) regression
  moments come from the existing ``clg_suffstats`` kernel with the
  configuration one-hot as the responsibility matrix.

Both scores decompose over families, so hill-climbing deltas touch only the
families an operator changes.  Zero-padding candidate designs to a common
width is *exactly* evidence-invariant (the padded dimensions contribute
``log kappa - log kappa = 0`` to the determinant ratio and nothing to the
quadratic), so ragged candidate sets batch into one device call.

Column convention (matches ``data.stream.DataStream``): discrete variables
live in ``xd`` columns with cardinalities ``cards``; continuous variables
in ``xc`` columns.  :func:`fit_cpds` materializes a learned structure as a
``BayesianNetwork`` with conjugate posterior-mean CPDs — the object that
flows into ``infer_exact``, importance sampling and ``PGMQueryEngine``.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax.numpy as jnp
from jax.scipy.special import gammaln

from repro.core.dag import (BayesianNetwork, CLGCPD, DAG, MultinomialCPD,
                            Variable, Variables)
from repro.data.stream import Attribute, Batch, DataStream, FINITE, REAL

LOG2PI = float(np.log(2.0 * np.pi))

# family over xd columns: (child_col, parent_cols); parent order is
# irrelevant to the score, significant only for table axis layout
DiscFamily = Tuple[int, Tuple[int, ...]]
# family of a continuous child: (child_xc_col, cont_parent_xc_cols,
# disc_parent_xd_cols)
ContFamily = Tuple[int, Tuple[int, ...], Tuple[int, ...]]


def as_batch(data) -> Batch:
    """Coerce a learner's ``data`` argument (Batch or DataStream)."""
    return data.collect() if isinstance(data, DataStream) else data


# ---------------------------------------------------------------------------
# family config codes / counts
# ---------------------------------------------------------------------------


def family_strides(families: Sequence[DiscFamily], cards: Sequence[int]
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """Mixed-radix stride matrix for a batch of discrete families.

    Child minor, first parent most significant: the flat code of family
    ``(ch, (p1..pk))`` is ``x_ch + r*(x_pk + c_pk*(... x_p1))`` so
    ``counts.reshape(c_p1, .., c_pk, r)`` is the family's joint table.

    Returns (strides [M, Fd], r [M] child cards, q [M] parent-config
    counts, Cmax).
    """
    Fd = len(cards)
    M = len(families)
    strides = np.zeros((M, Fd), np.int32)
    r = np.zeros(M, np.int32)
    q = np.zeros(M, np.int32)
    for m, (ch, pa) in enumerate(families):
        strides[m, ch] = 1
        r[m] = cards[ch]
        s = int(cards[ch])
        for p in reversed(pa):
            strides[m, p] = s
            s *= int(cards[p])
        q[m] = s // int(cards[ch])
    Cmax = int((r * q).max()) if M else 1
    return strides, r, q, Cmax


def batched_family_counts(xd: jnp.ndarray, strides: np.ndarray, C: int,
                          mask: Optional[jnp.ndarray] = None, *,
                          backend: str = "einsum") -> jnp.ndarray:
    """Joint-config counts [M, C] for every family in one device call."""
    w = (jnp.ones(xd.shape[0], jnp.float32) if mask is None
         else mask.astype(jnp.float32))
    s = jnp.asarray(strides)
    if backend == "pallas":
        from repro.kernels import ops

        return ops.family_counts(xd, s, w, C)
    from repro.kernels import ref

    return ref.family_counts_ref(xd, s, w, C)


# ---------------------------------------------------------------------------
# BDeu (discrete families)
# ---------------------------------------------------------------------------


def bdeu_from_counts(counts: jnp.ndarray, r: np.ndarray, q: np.ndarray, *,
                     ess: float = 1.0) -> jnp.ndarray:
    """BDeu log score per family from flat joint counts.

    counts: [M, C] child-minor flat tables (padded configs exactly zero);
    r/q: per-family child cardinality and parent-config count.  Zero-count
    cells contribute ``lgamma(alpha) - lgamma(alpha) = 0`` so the padding
    needs no masking; only the child-card reshape forces bucketing by r.
    """
    M, C = counts.shape
    scores = jnp.zeros(M, jnp.float32)
    for rv in np.unique(r):
        sel = np.nonzero(r == rv)[0]
        rv = int(rv)
        Cb = int(-(-C // rv)) * rv                 # pad C to a multiple of r
        cb = counts[jnp.asarray(sel)]
        if Cb > C:
            cb = jnp.pad(cb, ((0, 0), (0, Cb - C)))
        n_ijk = cb.reshape(len(sel), Cb // rv, rv)           # [Mb, j, k]
        n_ij = n_ijk.sum(-1)                                 # [Mb, j]
        qb = jnp.asarray(q[sel].astype(np.float32))[:, None]
        a_j = ess / qb
        a_jk = ess / (qb * rv)
        s = ((gammaln(a_j) - gammaln(a_j + n_ij)).sum(-1)
             + (gammaln(a_jk[..., None] + n_ijk)
                - gammaln(a_jk[..., None])).sum((-1, -2)))
        scores = scores.at[jnp.asarray(sel)].set(s.astype(jnp.float32))
    return scores


def disc_family_scores(xd: jnp.ndarray, families: Sequence[DiscFamily],
                       cards: Sequence[int], *,
                       mask: Optional[jnp.ndarray] = None, ess: float = 1.0,
                       backend: str = "einsum") -> np.ndarray:
    """BDeu scores for all candidate discrete families in one device call."""
    if not families:
        return np.zeros(0, np.float64)
    strides, r, q, C = family_strides(families, cards)
    counts = batched_family_counts(xd, strides, C, mask, backend=backend)
    return np.asarray(bdeu_from_counts(counts, r, q, ess=ess), np.float64)


# ---------------------------------------------------------------------------
# NIG evidence (continuous CLG families)
# ---------------------------------------------------------------------------


def nig_evidence(sxx: jnp.ndarray, sxy: jnp.ndarray, syy: jnp.ndarray,
                 n: jnp.ndarray, *, kappa: float = 1.0, a0: float = 1.0,
                 b0: float = 1.0) -> jnp.ndarray:
    """Log marginal likelihood of Bayesian linear regression under the
    conjugate NIG prior ``m0 = 0, K0 = kappa I, Gamma(a0, b0)``.

    Batched over the leading axes of the regression moments (``sxx``
    [..., D, D]).  This is the continuous-family counterpart of BDeu: the
    evidence of the ``expfam.MVNormalGamma`` update.
    """
    D = sxx.shape[-1]
    K0 = kappa * jnp.eye(D, dtype=sxx.dtype)
    Kn = K0 + sxx
    mn = jnp.linalg.solve(Kn, sxy[..., None])[..., 0]
    an = a0 + 0.5 * n
    bn = b0 + 0.5 * (syy - jnp.einsum("...d,...de,...e->...", mn, Kn, mn))
    bn = jnp.maximum(bn, 1e-10)
    _, logdet_n = jnp.linalg.slogdet(Kn)
    logdet_0 = D * float(np.log(kappa))
    return (-0.5 * n * LOG2PI + 0.5 * (logdet_0 - logdet_n)
            + a0 * float(np.log(b0)) - an * jnp.log(bn)
            + gammaln(an) - gammaln(a0))


def _config_onehot(xd: jnp.ndarray, disc_pa: Tuple[int, ...],
                   cards: Sequence[int]) -> Tuple[jnp.ndarray, int]:
    """One-hot [N, q] of the joint configuration of ``disc_pa`` columns
    (first parent most significant — the fit_cpds reshape convention)."""
    N = xd.shape[0]
    if not disc_pa:
        return jnp.ones((N, 1), jnp.float32), 1
    code = jnp.zeros(N, jnp.int32)
    for p in disc_pa:
        code = code * int(cards[p]) + xd[:, p].astype(jnp.int32)
    q = int(np.prod([cards[p] for p in disc_pa]))
    cols = jnp.arange(q, dtype=jnp.int32)
    return (cols[None, :] == code[:, None]).astype(jnp.float32), q


def _reg_stats_group(xc: jnp.ndarray, xd: jnp.ndarray,
                     fams: Sequence[ContFamily], cards: Sequence[int],
                     mask: Optional[jnp.ndarray], backend: str):
    """Per-(family, config) regression moments for families sharing one
    discrete parent set: designs zero-padded to a common width, the config
    one-hot as responsibilities — one ``clg_suffstats`` call."""
    N = xc.shape[0]
    disc_pa = fams[0][2]
    r, _ = _config_onehot(xd, disc_pa, cards)
    if mask is not None:
        r = r * mask.astype(jnp.float32)[:, None]
    Dmax = 1 + max(len(f[1]) for f in fams)
    xc_h = np.asarray(xc, np.float32)          # host-side design assembly:
    d_h = np.zeros((N, len(fams), Dmax), np.float32)   # one transfer, not
    d_h[:, :, 0] = 1.0                                 # one .at[] per family
    for m, (_, cont_pa, _) in enumerate(fams):
        if cont_pa:
            d_h[:, m, 1:1 + len(cont_pa)] = xc_h[:, list(cont_pa)]
    d = jnp.asarray(d_h)
    y = xc[:, [f[0] for f in fams]]                        # [N, M]
    if backend == "pallas":
        from repro.kernels import clg_stats

        sxx, sxy, syy = clg_stats.clg_suffstats(d, y, r)
    else:
        from repro.kernels import ref

        sxx, sxy, syy = ref.clg_suffstats_ref(d, y, r)
    n = jnp.broadcast_to(r.sum(0)[None], syy.shape)        # [M, q]
    return sxx, sxy, syy, n


def clg_family_scores(xc: jnp.ndarray, xd: jnp.ndarray,
                      families: Sequence[ContFamily], cards: Sequence[int],
                      *, mask: Optional[jnp.ndarray] = None,
                      kappa: float = 1.0, a0: float = 1.0, b0: float = 1.0,
                      backend: str = "einsum") -> np.ndarray:
    """NIG-evidence scores for continuous CLG families.

    Families sharing a discrete parent set batch into one suff-stats kernel
    call (their configuration one-hot is shared); the per-configuration
    evidences sum into the family score.
    """
    scores = np.zeros(len(families), np.float64)
    groups: Dict[Tuple[int, ...], List[int]] = {}
    for m, (_, _, disc_pa) in enumerate(families):
        groups.setdefault(tuple(sorted(disc_pa)), []).append(m)
    for disc_pa, idxs in groups.items():
        fams = [(families[m][0], families[m][1], disc_pa) for m in idxs]
        sxx, sxy, syy, n = _reg_stats_group(xc, xd, fams, cards, mask,
                                            backend)
        ev = nig_evidence(sxx, sxy, syy, n, kappa=kappa, a0=a0, b0=b0)
        scores[np.asarray(idxs)] = np.asarray(ev.sum(-1), np.float64)
    return scores


# ---------------------------------------------------------------------------
# structure <-> stream plumbing
# ---------------------------------------------------------------------------


def variables_of(attributes: Sequence[Attribute]
                 ) -> Tuple[Variables, Dict[str, Tuple[str, int]]]:
    """Build the Variables registry of a stream's attributes plus the
    name -> ("c"|"d", column) map (DataStream column order: REAL columns
    into xc, FINITE columns into xd, each by attribute order)."""
    vs = Variables()
    col: Dict[str, Tuple[str, int]] = {}
    ci = di = 0
    for a in attributes:
        if a.kind == REAL:
            vs.new_gaussian(a.name)
            col[a.name] = ("c", ci)
            ci += 1
        elif a.kind == FINITE:
            vs.new_multinomial(a.name, a.card)
            col[a.name] = ("d", di)
            di += 1
        else:
            raise ValueError(f"unknown attribute kind {a.kind!r}")
    return vs, col


def structure_stats(attributes: Sequence[Attribute],
                    parents: Dict[str, Sequence[str]], batch: Batch, *,
                    backend: str = "einsum") -> Dict[str, object]:
    """Sufficient statistics of ``batch`` for every family of a fixed
    structure: ``{"disc": counts [Md, C] | None, "cont": {child name ->
    (sxx [q,D,D], sxy [q,D], syy [q], n [q])}}``.

    Stats are ADDITIVE in the instances (jnp arrays throughout), so a
    streaming window maintains them incrementally: add an arriving chunk's
    stats, subtract an evicted chunk's (``AdaptiveStructure``), and build
    CPDs from the running sum with :func:`cpds_from_stats` — per-batch
    cost O(batch), not O(window).
    """
    vs, col = variables_of(attributes)
    cards = [a.card for a in attributes if a.kind == FINITE]
    xd, xc, mask = batch.xd, batch.xc, batch.mask
    disc_fams: List[DiscFamily] = []
    for v in vs:
        if v.is_discrete:
            dpa = [col[p][1] for p in parents.get(v.name, ())]
            disc_fams.append((col[v.name][1], tuple(dpa)))
    disc = None
    if disc_fams:
        strides, _, _, C = family_strides(disc_fams, cards)
        disc = batched_family_counts(xd, strides, C, mask, backend=backend)
    cont: Dict[str, Tuple] = {}
    for v in vs:
        if v.is_discrete:
            continue
        pas = [vs.by_name(p) for p in parents.get(v.name, ())]
        dpa = tuple(col[p.name][1] for p in pas if p.is_discrete)
        cpa = tuple(col[p.name][1] for p in pas if not p.is_discrete)
        sxx, sxy, syy, n = _reg_stats_group(
            xc, xd, [(col[v.name][1], cpa, dpa)], cards, mask, backend)
        cont[v.name] = (sxx[0], sxy[0], syy[0], n[0])
    return {"disc": disc, "cont": cont}


def cpds_from_stats(attributes: Sequence[Attribute],
                    parents: Dict[str, Sequence[str]],
                    stats: Dict[str, object], *, ess: float = 1.0,
                    kappa: float = 1.0, a0: float = 1.0, b0: float = 1.0
                    ) -> BayesianNetwork:
    """Build the conjugate posterior-mean ``BayesianNetwork`` of a
    structure from :func:`structure_stats` output (possibly a running sum
    of per-chunk stats)."""
    vs, col = variables_of(attributes)
    cards = [a.card for a in attributes if a.kind == FINITE]
    dag = DAG(vs)
    for child, pas in parents.items():
        for p in pas:
            dag.add_parent(vs.by_name(child), vs.by_name(p))

    cpds: Dict[str, object] = {}
    disc_children = [v for v in vs if v.is_discrete]
    if disc_children:
        counts = np.asarray(stats["disc"])
        for m, v in enumerate(disc_children):
            dpa = [col[p.name][1] for p in dag.get_parents(v)]
            rv = cards[col[v.name][1]]
            pa_cards = [cards[p] for p in dpa]
            qv = int(np.prod(pa_cards)) if pa_cards else 1
            tab = counts[m, : rv * qv]
            tab = tab.reshape(*pa_cards, rv) + ess / (rv * qv)
            cpds[v.name] = MultinomialCPD(
                jnp.asarray(tab / tab.sum(-1, keepdims=True)))

    for v in vs:
        if v.is_discrete:
            continue
        pas = dag.get_parents(v)
        dpa = tuple(col[p.name][1] for p in pas if p.is_discrete)
        cpa = tuple(col[p.name][1] for p in pas if not p.is_discrete)
        sxx, sxy, syy, n = stats["cont"][v.name]
        K0 = kappa * jnp.eye(sxx.shape[-1])
        Kn = K0 + sxx                                        # [q, D, D]
        mn = jnp.linalg.solve(Kn, sxy[..., None])[..., 0]    # [q, D]
        an = a0 + 0.5 * n
        bn = b0 + 0.5 * (syy - jnp.einsum("qd,qde,qe->q", mn, Kn, mn))
        bn = jnp.maximum(bn, 1e-10)
        pa_cards = tuple(cards[p] for p in dpa)
        alpha = mn[:, 0].reshape(pa_cards)
        beta = mn[:, 1:].reshape(pa_cards + (len(cpa),))
        sigma2 = (bn / an).reshape(pa_cards)
        if not dpa:        # scalar-config CPDs drop the config axis
            alpha, beta, sigma2 = alpha[()], beta, sigma2[()]
            beta = beta.reshape(len(cpa))
        cpds[v.name] = CLGCPD(alpha=alpha, beta=beta, sigma2=sigma2)
    return BayesianNetwork(dag, cpds)


def fit_cpds(attributes: Sequence[Attribute],
             parents: Dict[str, Sequence[str]], batch: Batch, *,
             ess: float = 1.0, kappa: float = 1.0, a0: float = 1.0,
             b0: float = 1.0, backend: str = "einsum") -> BayesianNetwork:
    """Materialize a learned structure as a ``BayesianNetwork`` with
    conjugate posterior-mean CPDs fitted on ``batch``.

    ``parents`` maps child name -> parent names; discrete children take
    Dirichlet(ess/(q r))-smoothed tables, continuous children per-config
    NIG posterior means (weights ``m_n``, variance ``b_n / a_n`` — the
    same point estimate ``Model.to_bayesian_network`` exports).  The
    result flows straight into ``infer_exact`` / ``ImportanceSampling`` /
    ``PGMQueryEngine``.  (One-shot composition of :func:`structure_stats`
    + :func:`cpds_from_stats`; the streaming path keeps the stats and
    updates them incrementally instead.)
    """
    stats = structure_stats(attributes, parents, batch, backend=backend)
    return cpds_from_stats(attributes, parents, stats, ess=ess, kappa=kappa,
                           a0=a0, b0=b0)
