"""The modeling language: variables, DAGs and (conditional linear Gaussian)
Bayesian networks — paper §2.1 and Code Fragment 11.

Two levels:

* ``BayesianNetwork`` — a concrete CLG network (discrete multinomial nodes +
  continuous CLG nodes, Eq. 2).  Fully materialized parameters; supports joint
  log-density evaluation and ancestral sampling.  This is what inference
  (importance sampling, MAP, factored frontier) operates on, and what
  ``Model.get_model()`` returns after learning.

* ``PlateSpec`` — the Fig.-3 plate family the VMP learning engine compiles:
  global parameters theta, an optional per-instance discrete latent Z_i, an
  optional per-instance continuous latent vector H_i, and observed leaves that
  are CLG in (Z_i, H_i, observed parents).  Models in ``repro.pgm_models``
  build a PlateSpec in ``build_dag`` (the paper's ``buildDAG()``).

Structure (graphs, names) is static Python; parameters are jnp pytrees.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

DISCRETE = "multinomial"
CONTINUOUS = "gaussian"


@dataclasses.dataclass(frozen=True)
class Variable:
    name: str
    kind: str  # DISCRETE | CONTINUOUS
    card: int = 0  # cardinality for discrete vars

    @property
    def is_discrete(self) -> bool:
        return self.kind == DISCRETE


class Variables:
    """Variable registry — mirrors ``eu.amidst.core.variables.Variables``."""

    def __init__(self) -> None:
        self._vars: List[Variable] = []
        self._by_name: Dict[str, Variable] = {}

    def new_multinomial(self, name: str, card: int) -> Variable:
        return self._add(Variable(name, DISCRETE, card))

    def new_gaussian(self, name: str) -> Variable:
        return self._add(Variable(name, CONTINUOUS))

    def _add(self, v: Variable) -> Variable:
        if v.name in self._by_name:
            raise ValueError(f"duplicate variable {v.name!r}")
        self._vars.append(v)
        self._by_name[v.name] = v
        return v

    def by_name(self, name: str) -> Variable:
        return self._by_name[name]

    def __iter__(self):
        return iter(self._vars)

    def __len__(self) -> int:
        return len(self._vars)


class DAG:
    """Parent-set container over a ``Variables`` registry (Code Fragment 11)."""

    def __init__(self, variables: Variables) -> None:
        self.variables = variables
        self.parents: Dict[str, List[Variable]] = {v.name: [] for v in variables}

    def is_ancestor(self, anc: str, desc: str) -> bool:
        """True iff ``anc`` reaches ``desc`` along directed edges (reflexive:
        a variable is its own ancestor).  The incremental ancestor walk —
        touches only ``desc``'s ancestor set, not the whole graph — shared
        by :meth:`add_parent` and the structure-search operator guards
        (``learn_structure.search``: an add/reverse is acyclic iff the
        would-be child is not already an ancestor of the would-be parent).
        """
        stack, seen = [desc], set()
        while stack:
            u = stack.pop()
            if u == anc:
                return True
            if u in seen:
                continue
            seen.add(u)
            stack.extend(p.name for p in self.parents[u])
        return False

    def add_parent(self, child: Variable, parent: Variable) -> None:
        if parent.name == child.name:
            raise ValueError("self-loop")
        if any(p.name == parent.name for p in self.parents[child.name]):
            raise ValueError(
                f"duplicate edge {parent.name!r} -> {child.name!r}")
        # incremental acyclicity: the new edge closes a cycle iff the child
        # is already an ancestor of the parent.  Checked before mutation,
        # so a rejected edge leaves the DAG untouched.
        if self.is_ancestor(child.name, parent.name):
            raise ValueError(
                f"edge {parent.name!r} -> {child.name!r} creates a cycle")
        self.parents[child.name].append(parent)

    def remove_parent(self, child: Variable, parent: Variable) -> None:
        """Delete edge parent -> child (structure-search remove/reverse)."""
        pas = self.parents[child.name]
        for i, p in enumerate(pas):
            if p.name == parent.name:
                del pas[i]
                return
        raise ValueError(f"no edge {parent.name!r} -> {child.name!r}")

    def get_parents(self, v: Variable) -> List[Variable]:
        return self.parents[v.name]

    def topological_order(self) -> List[Variable]:
        # iterative DFS (parents before children, registry order breaking
        # ties — same order the old recursive visit produced): structure
        # search generates chains deeper than Python's recursion limit
        order: List[Variable] = []
        seen, mark = set(), set()
        for root in self.variables:
            if root.name in seen:
                continue
            mark.add(root.name)
            stack = [(root, iter(self.parents[root.name]))]
            while stack:
                v, it = stack[-1]
                for p in it:
                    if p.name in seen:
                        continue
                    if p.name in mark:
                        raise ValueError("cycle in DAG")
                    mark.add(p.name)
                    stack.append((p, iter(self.parents[p.name])))
                    break
                else:
                    stack.pop()
                    mark.discard(v.name)
                    seen.add(v.name)
                    order.append(v)
        return order


# ---------------------------------------------------------------------------
# Concrete CLG Bayesian network
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class MultinomialCPD:
    """p(X | discrete parents): table of shape parent_cards + [card]."""

    table: jnp.ndarray  # normalized along the last axis


@dataclasses.dataclass
class CLGCPD:
    """Eq. 2: N(z ; alpha(x_D) + beta(x_D)^T x_C, sigma2(x_D)).

    ``alpha``: [*parent_cards], ``beta``: [*parent_cards, C], ``sigma2``:
    [*parent_cards]; C = number of continuous parents (may be 0).
    """

    alpha: jnp.ndarray
    beta: jnp.ndarray
    sigma2: jnp.ndarray


class BayesianNetwork:
    """A CLG Bayesian network with materialized CPDs.

    ``assignments`` passed to :meth:`log_prob` map variable name -> value
    array; all value arrays share leading batch shape.
    """

    def __init__(self, dag: DAG, cpds: Dict[str, object]) -> None:
        self.dag = dag
        self.cpds = cpds
        self.order = dag.topological_order()
        for v in self.order:
            if v.name not in cpds:
                raise ValueError(f"missing CPD for {v.name}")
            parents = dag.get_parents(v)
            if v.is_discrete and any(not p.is_discrete for p in parents):
                raise ValueError(
                    f"CLG restriction: discrete node {v.name} with continuous parent"
                )

    # -- density ------------------------------------------------------------

    def log_prob(self, assignment: Dict[str, jnp.ndarray]) -> jnp.ndarray:
        total = 0.0
        for v in self.order:
            total = total + self._node_logp(v, assignment)
        return total

    def _node_logp(self, v: Variable, asg: Dict[str, jnp.ndarray]) -> jnp.ndarray:
        parents = self.dag.get_parents(v)
        dpa = [p for p in parents if p.is_discrete]
        cpa = [p for p in parents if not p.is_discrete]
        didx = tuple(asg[p.name].astype(jnp.int32) for p in dpa)
        cpd = self.cpds[v.name]
        if v.is_discrete:
            table = cpd.table[didx]  # [batch..., card] if dpa else [card]
            x = asg[v.name].astype(jnp.int32)
            if not dpa:
                return jnp.log(table[x])
            return jnp.log(jnp.take_along_axis(table, x[..., None], -1)[..., 0])
        alpha = cpd.alpha[didx]
        sigma2 = cpd.sigma2[didx]
        mean = alpha
        if cpa:
            beta = cpd.beta[didx]  # [..., C]
            xc = jnp.stack([asg[p.name] for p in cpa], -1)
            mean = mean + (beta * xc).sum(-1)
        z = asg[v.name]
        return -0.5 * (jnp.log(2 * jnp.pi * sigma2) + (z - mean) ** 2 / sigma2)

    # -- ancestral sampling ---------------------------------------------------

    def sample(self, key: jax.Array, n: int) -> Dict[str, jnp.ndarray]:
        asg: Dict[str, jnp.ndarray] = {}
        for v in self.order:
            key, sub = jax.random.split(key)
            parents = self.dag.get_parents(v)
            dpa = [p for p in parents if p.is_discrete]
            cpa = [p for p in parents if not p.is_discrete]
            didx = tuple(asg[p.name].astype(jnp.int32) for p in dpa)
            cpd = self.cpds[v.name]
            if v.is_discrete:
                table = cpd.table[didx] if dpa else jnp.broadcast_to(
                    cpd.table, (n,) + cpd.table.shape
                )
                asg[v.name] = jax.random.categorical(sub, jnp.log(table), axis=-1)
            else:
                alpha = cpd.alpha[didx] if dpa else jnp.broadcast_to(cpd.alpha, (n,))
                sigma2 = cpd.sigma2[didx] if dpa else jnp.broadcast_to(cpd.sigma2, (n,))
                mean = alpha
                if cpa:
                    beta = cpd.beta[didx] if dpa else jnp.broadcast_to(
                        cpd.beta, (n,) + cpd.beta.shape
                    )
                    xc = jnp.stack([asg[p.name] for p in cpa], -1)
                    mean = mean + (beta * xc).sum(-1)
                asg[v.name] = mean + jnp.sqrt(sigma2) * jax.random.normal(sub, (n,))
        return asg

    def __str__(self) -> str:  # paper Code Fragment 8 style print-out
        lines = ["Bayesian Network:"]
        for v in self.order:
            parents = self.dag.get_parents(v)
            pstr = ", ".join(p.name for p in parents)
            head = f"P({v.name}" + (f" | {pstr})" if parents else ")")
            cpd = self.cpds[v.name]
            if v.is_discrete:
                lines.append(f"{head} follows a Multinomial")
                lines.append(f"  {np.asarray(cpd.table)}")
            else:
                lines.append(f"{head} follows a Normal|Multinomial (CLG)")
                lines.append(
                    f"  alpha={np.asarray(cpd.alpha)} beta={np.asarray(cpd.beta)}"
                    f" sigma2={np.asarray(cpd.sigma2)}"
                )
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Plate family compiled by the VMP engine (paper Fig. 3)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PlateSpec:
    """Fig.-3 plate model, the class of structures the learning engine accepts.

    n_features        number of observed leaves X_i (continuous unless listed
                      in ``discrete_features`` with its cardinality)
    latent_card       cardinality of the per-instance discrete latent Z_i
                      (0 = no discrete latent; 1 behaves as "no mixture")
    latent_dim        dimension of the per-instance continuous latent H_i
                      (0 = none). H_i has a standard-normal prior and
                      linear-Gaussian children (FA/PPCA family).
    feature_parents   for each observed leaf, indices of *observed* continuous
                      features acting as CLG parents (Bayesian-regression
                      links); empty for plain mixture leaves.
    discrete_features map feature index -> cardinality for multinomial leaves
                      (Naive-Bayes style).
    """

    n_features: int
    latent_card: int = 0
    latent_dim: int = 0
    feature_parents: Tuple[Tuple[int, ...], ...] = ()
    discrete_features: Tuple[Tuple[int, int], ...] = ()

    def __post_init__(self):
        if self.feature_parents and len(self.feature_parents) != self.n_features:
            raise ValueError("feature_parents must list every feature")

    @property
    def discrete_map(self) -> Dict[int, int]:
        return dict(self.discrete_features)

    def parent_idx(self, i: int) -> Tuple[int, ...]:
        if not self.feature_parents:
            return ()
        return self.feature_parents[i]
