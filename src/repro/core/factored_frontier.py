"""Factored Frontier (Murphy & Weiss) — approximate inference in dynamic BNs.

Paper §2.2: "Versions of these methods for dynamic models are supported by
means of the Factored Frontier algorithm".

We implement FF for discrete 2-timeslice BNs with C parallel hidden chains
(factorial HMM structure) and per-chain discrete/Gaussian observations:

    belief b_t(x) ~= prod_c b_t^c(x_c)          (factored frontier assumption)
    predict:  b'^c = sum_{parents} T^c(x_c | pa) prod b^pa
    correct:  b^c  ∝ b'^c * l^c_t(x_c)

For a single chain (C=1) FF is EXACT filtering (the HMM forward algorithm) —
which is the correctness oracle in the tests.  The time recursion is a
``jax.lax.scan``; chains are vectorized.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class Factorial2TBN(NamedTuple):
    """C independent chains coupled only through the likelihood terms.

    init:  [C, S]        initial distribution per chain
    trans: [C, S, S]     p(x_t = j | x_{t-1} = i) per chain
    The observation model is supplied per step as log-likelihood tensors
    ll[t]: [C, S] (chain-factored likelihoods — the FF approximation point).
    """

    init: jnp.ndarray
    trans: jnp.ndarray


def factored_frontier_filter(
    model: Factorial2TBN, loglik: jnp.ndarray,
    mask: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """loglik: [T, C, S].  Returns (beliefs [T, C, S], loglik_lb [T]).

    ``mask`` ([T], optional) marks which steps carry evidence.  Padded
    steps (``mask[t] == 0``) HOLD the belief — no transition is applied
    and the loglik lower bound contribution is 0 — matching the
    ragged-sequence semantics of ``pgm_models.dynamic.forward_backward``.
    The padded frames' loglik values are never read (``where``-gated
    before use), so garbage/NaN padding cannot corrupt the marginals.
    """
    if mask is None:
        mask = jnp.ones(loglik.shape[0], dtype=loglik.dtype)

    def step(belief, inputs):
        ll_t, m_t = inputs
        ll_t = jnp.where(m_t > 0, ll_t, 0.0)
        # predict (per chain, independent transition)
        pred = jnp.einsum("cs,cst->ct", belief, model.trans)
        # correct
        post = pred * jnp.exp(ll_t - ll_t.max(-1, keepdims=True))
        norm = post.sum(-1, keepdims=True)
        post = post / jnp.maximum(norm, 1e-30)
        ll = (jnp.log(jnp.maximum(norm[..., 0], 1e-30))
              + ll_t.max(-1)).sum()
        post = jnp.where(m_t > 0, post, belief)
        ll = jnp.where(m_t > 0, ll, 0.0)
        return post, (post, ll)

    _, (beliefs, ll) = jax.lax.scan(step, model.init, (loglik, mask))
    return beliefs, ll


def factored_frontier_smooth(
    model: Factorial2TBN, loglik: jnp.ndarray,
    mask: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Factored gamma smoothing (forward-backward with the FF assumption).

    ``mask`` ([T], optional): padded steps hold both the filtered belief
    and the backward message (see :func:`factored_frontier_filter`)."""
    if mask is None:
        mask = jnp.ones(loglik.shape[0], dtype=loglik.dtype)
    beliefs, _ = factored_frontier_filter(model, loglik, mask)

    def bstep(bnext, inputs):
        ll_t, m_t = inputs
        ll_t = jnp.where(m_t > 0, ll_t, 0.0)
        # backward variable per chain
        msg = jnp.einsum("cst,ct->cs", model.trans,
                         bnext * jnp.exp(ll_t - ll_t.max(-1, keepdims=True)))
        msg = msg / jnp.maximum(msg.sum(-1, keepdims=True), 1e-30)
        msg = jnp.where(m_t > 0, msg, bnext)
        return msg, msg

    ones = jnp.ones_like(model.init)
    _, back = jax.lax.scan(
        bstep, ones, (loglik[1:][::-1], mask[1:][::-1])
    )
    back = jnp.concatenate([back[::-1], ones[None]], axis=0)
    gamma = beliefs * back
    return gamma / jnp.maximum(gamma.sum(-1, keepdims=True), 1e-30)


def predictive_posterior(
    model: Factorial2TBN, belief: jnp.ndarray, horizon: int
) -> jnp.ndarray:
    """paper Code Fragment 14: getPredictivePosterior(var, h) — roll the
    transition forward ``horizon`` steps with no evidence."""

    def step(b, _):
        b = jnp.einsum("cs,cst->ct", b, model.trans)
        return b, b

    _, out = jax.lax.scan(step, belief, None, length=horizon)
    return out[-1]


# -- convenience: exact HMM forward for the C=1 oracle -----------------------


def hmm_forward(init: jnp.ndarray, trans: jnp.ndarray,
                loglik: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Exact forward filtering. init [S], trans [S,S], loglik [T,S]."""
    model = Factorial2TBN(init=init[None], trans=trans[None])
    beliefs, ll = factored_frontier_filter(model, loglik[:, None, :])
    return beliefs[:, 0], ll
