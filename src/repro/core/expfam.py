"""Conjugate exponential-family algebra in natural-parameter form.

This is the quantitative substrate of the toolbox (paper §2.1/§2.2): every
distribution is represented by a parameter pytree, and Bayesian updating
(paper Eq. 3) is *addition of expected sufficient statistics to natural
parameters*.  VMP, d-VMP, SVI and streaming VB all reduce to this algebra,
which is why one engine serves every model in the zoo (paper Table 2).

Families provided (all vectorized — leading axes broadcast):
  * Dirichlet         — conjugate prior of Multinomial/Categorical
  * NormalGamma       — conjugate prior of a univariate Gaussian (mean+precision)
  * MVNormalGamma     — conjugate prior of a linear-Gaussian node (CLG, Eq. 2):
                        regression weights w and noise precision lambda
  * Gaussian utils    — moments/KL for local continuous latents (FA, LDS)

Everything is pure-functional jnp; no Python objects cross jit boundaries.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax.numpy as jnp
from jax.scipy.special import digamma, gammaln

LOG2PI = float(jnp.log(2.0 * jnp.pi))

# ---------------------------------------------------------------------------
# Dirichlet / Categorical
# ---------------------------------------------------------------------------


class Dirichlet(NamedTuple):
    """Dirichlet in 'pseudo-count' parameterization; natural param = alpha - 1."""

    alpha: jnp.ndarray  # [..., K]


def dirichlet_expected_logprob(d: Dirichlet) -> jnp.ndarray:
    """E[log pi_k] under Dirichlet(alpha)."""
    return digamma(d.alpha) - digamma(d.alpha.sum(-1, keepdims=True))


def dirichlet_mean(d: Dirichlet) -> jnp.ndarray:
    return d.alpha / d.alpha.sum(-1, keepdims=True)


def dirichlet_logZ(d: Dirichlet) -> jnp.ndarray:
    return gammaln(d.alpha).sum(-1) - gammaln(d.alpha.sum(-1))


def dirichlet_kl(q: Dirichlet, p: Dirichlet) -> jnp.ndarray:
    """KL(q || p) for Dirichlets, summed over the last axis."""
    elp = dirichlet_expected_logprob(q)
    return (
        -dirichlet_logZ(q)
        + dirichlet_logZ(p)
        + ((q.alpha - p.alpha) * elp).sum(-1)
    )


def dirichlet_update(prior: Dirichlet, counts: jnp.ndarray) -> Dirichlet:
    """Conjugate update: posterior alpha = prior alpha + expected counts."""
    return Dirichlet(prior.alpha + counts)


# ---------------------------------------------------------------------------
# Normal-Gamma / univariate Gaussian (unknown mean and precision)
# ---------------------------------------------------------------------------


class NormalGamma(NamedTuple):
    """p(mu, lam) = N(mu | mu0, (kappa lam)^-1) Gamma(lam | a, b). Broadcasts."""

    mu0: jnp.ndarray
    kappa: jnp.ndarray
    a: jnp.ndarray
    b: jnp.ndarray


class GaussSuffStats(NamedTuple):
    """Weighted sufficient statistics of scalar observations.

    n = sum_i w_i, sx = sum_i w_i x_i, sx2 = sum_i w_i x_i^2.
    This triplet is THE message that d-VMP psums across data shards.
    """

    n: jnp.ndarray
    sx: jnp.ndarray
    sx2: jnp.ndarray


def gauss_suffstats(x: jnp.ndarray, w: jnp.ndarray) -> GaussSuffStats:
    """x: [N, ...], w: [N, ...] responsibilities; reduces over axis 0."""
    return GaussSuffStats(
        n=w.sum(0), sx=(w * x).sum(0), sx2=(w * x * x).sum(0)
    )


def normalgamma_update(prior: NormalGamma, s: GaussSuffStats) -> NormalGamma:
    """Standard conjugate Normal-Gamma update from weighted suff stats."""
    n = s.n
    kappa_n = prior.kappa + n
    mu_n = (prior.kappa * prior.mu0 + s.sx) / kappa_n
    a_n = prior.a + 0.5 * n
    # scatter around the weighted mean, guarded for n == 0
    xbar = s.sx / jnp.maximum(n, 1e-12)
    scatter = s.sx2 - n * xbar * xbar
    b_n = prior.b + 0.5 * (
        scatter
        + prior.kappa * n * (xbar - prior.mu0) ** 2 / kappa_n
    )
    return NormalGamma(mu_n, kappa_n, a_n, b_n)


class GaussMoments(NamedTuple):
    """Expected natural statistics of the Gaussian under a NormalGamma posterior."""

    e_lam: jnp.ndarray      # E[lambda]
    e_loglam: jnp.ndarray   # E[log lambda]
    e_lammu: jnp.ndarray    # E[lambda mu]
    e_lammu2: jnp.ndarray   # E[lambda mu^2]


def normalgamma_moments(q: NormalGamma) -> GaussMoments:
    e_lam = q.a / q.b
    return GaussMoments(
        e_lam=e_lam,
        e_loglam=digamma(q.a) - jnp.log(q.b),
        e_lammu=e_lam * q.mu0,
        e_lammu2=1.0 / q.kappa + e_lam * q.mu0 * q.mu0,
    )


def gauss_expected_loglik(x: jnp.ndarray, m: GaussMoments) -> jnp.ndarray:
    """E_q[log N(x | mu, lambda^-1)] — the VMP message from a Gaussian child."""
    return 0.5 * (
        m.e_loglam - LOG2PI - m.e_lam * x * x + 2.0 * x * m.e_lammu - m.e_lammu2
    )


def gamma_kl(a_q, b_q, a_p, b_p) -> jnp.ndarray:
    return (
        (a_q - a_p) * digamma(a_q)
        - gammaln(a_q)
        + gammaln(a_p)
        + a_p * (jnp.log(b_q) - jnp.log(b_p))
        + a_q * (b_p - b_q) / b_q
    )


def normalgamma_kl(q: NormalGamma, p: NormalGamma) -> jnp.ndarray:
    """KL(q || p) between Normal-Gamma distributions (elementwise)."""
    e_lam = q.a / q.b
    # E_q[ log N(mu | p.mu0, (p.kappa lam)^-1) - log N(mu | q.mu0, (q.kappa lam)^-1) ]
    kl_mu = 0.5 * (
        jnp.log(q.kappa / p.kappa)
        + p.kappa / q.kappa
        - 1.0
        + p.kappa * e_lam * (q.mu0 - p.mu0) ** 2
    )
    return kl_mu + gamma_kl(q.a, q.b, p.a, p.b)


# ---------------------------------------------------------------------------
# Multivariate Normal-Gamma — Bayesian linear regression / CLG node (Eq. 2)
# ---------------------------------------------------------------------------


class MVNormalGamma(NamedTuple):
    """p(w, lam) = N(w | m, (lam K)^-1) Gamma(lam | a, b); w in R^D.

    This is the conjugate parameter family of the paper's CLG node
    p(z | x_C) = N(z ; w^T [x_C, 1], lam^-1): the per-discrete-configuration
    regression of Eq. 2 (alpha/beta absorbed into w via a bias feature).
    Batched over leading axes of m/K/a/b (e.g. one regression per discrete
    parent configuration and per mixture component).
    """

    m: jnp.ndarray  # [..., D]
    K: jnp.ndarray  # [..., D, D]  (precision scale)
    a: jnp.ndarray  # [...]
    b: jnp.ndarray  # [...]


class RegSuffStats(NamedTuple):
    """Weighted regression suff stats: the d-VMP message of a CLG node.

    ``sxx_hh`` is the lazy latent-block form used by the FA/PPCA plates:
    when set, ``sxx`` carries only the top [..., Do, D] block (observed rows;
    the observed-latent cross block sits in its last L columns) and
    ``sxx_hh`` holds the leaf-shared [K, L, L] latent-latent block ONCE
    instead of broadcast per leaf.  :func:`reg_dense` reassembles the full
    symmetric [..., D, D] matrix; every consumer of ``sxx`` densifies first.
    """

    sxx: jnp.ndarray  # [..., D, D] sum w x x^T  ([..., Do, D] when lazy)
    sxy: jnp.ndarray  # [..., D]    sum w x y
    syy: jnp.ndarray  # [...]       sum w y^2
    n: jnp.ndarray    # [...]       sum w
    sxx_hh: Optional[jnp.ndarray] = None  # [K, L, L] shared latent block


def reg_dense(s: RegSuffStats) -> RegSuffStats:
    """Expand the lazy latent-block form to the full [..., D, D] sxx."""
    if s.sxx_hh is None:
        return s
    D = s.sxx.shape[-1]
    Do = s.sxx.shape[-2]
    L = D - Do
    oh = s.sxx[..., :, Do:]                               # [..., Do, L]
    hh = jnp.broadcast_to(s.sxx_hh, s.sxx.shape[:-2] + (L, L))
    bot = jnp.concatenate([jnp.swapaxes(oh, -1, -2), hh], axis=-1)
    return RegSuffStats(jnp.concatenate([s.sxx, bot], axis=-2),
                        s.sxy, s.syy, s.n, None)


def reg_suffstats(x: jnp.ndarray, y: jnp.ndarray, w: jnp.ndarray) -> RegSuffStats:
    """x: [N, D] features, y: [N] target, w: [N, ...] responsibilities.

    Returns stats with trailing batch axes matching w's trailing axes.
    """
    # einsum handles the general [N, ...] weight layout
    sxx = jnp.einsum("nd,ne,n...->...de", x, x, w)
    sxy = jnp.einsum("nd,n,n...->...d", x, y, w)
    syy = jnp.einsum("n,n,n...->...", y, y, w)
    n = w.sum(0)
    return RegSuffStats(sxx, sxy, syy, n)


def mvnormalgamma_update(prior: MVNormalGamma, s: RegSuffStats) -> MVNormalGamma:
    s = reg_dense(s)                     # lazy latent block expands HERE, once
    K_n = prior.K + s.sxx
    km = jnp.einsum("...de,...e->...d", prior.K, prior.m)
    rhs = km + s.sxy
    m_n = jnp.linalg.solve(K_n, rhs[..., None])[..., 0]
    a_n = prior.a + 0.5 * s.n
    quad_prior = jnp.einsum("...d,...d->...", prior.m, km)
    quad_post = jnp.einsum(
        "...d,...de,...e->...", m_n, K_n, m_n
    )
    b_n = prior.b + 0.5 * (s.syy + quad_prior - quad_post)
    # numerical guard: b must stay positive
    b_n = jnp.maximum(b_n, 1e-10)
    return MVNormalGamma(m_n, K_n, a_n, b_n)


class RegMoments(NamedTuple):
    e_lam: jnp.ndarray      # [...]
    e_loglam: jnp.ndarray   # [...]
    e_lamw: jnp.ndarray     # [..., D]     E[lam w]
    e_lamww: jnp.ndarray    # [..., D, D]  E[lam w w^T]


def mvnormalgamma_moments(q: MVNormalGamma) -> RegMoments:
    e_lam = q.a / q.b
    K_inv = jnp.linalg.inv(q.K)
    return RegMoments(
        e_lam=e_lam,
        e_loglam=digamma(q.a) - jnp.log(q.b),
        e_lamw=e_lam[..., None] * q.m,
        e_lamww=K_inv + e_lam[..., None, None] * (q.m[..., :, None] * q.m[..., None, :]),
    )


def reg_expected_loglik(x: jnp.ndarray, y: jnp.ndarray, m: RegMoments) -> jnp.ndarray:
    """E_q[log N(y | w^T x, lam^-1)] for x: [N, D], y: [N]; broadcasts moments."""
    quad = jnp.einsum("nd,...de,ne->n...", x, m.e_lamww, x)
    lin = jnp.einsum("nd,...d->n...", x, m.e_lamw)
    y_ = y.reshape(y.shape + (1,) * (quad.ndim - 1))
    return 0.5 * (
        m.e_loglam - LOG2PI - m.e_lam * y_ * y_ + 2.0 * y_ * lin - quad
    )


def mvnormalgamma_kl(q: MVNormalGamma, p: MVNormalGamma) -> jnp.ndarray:
    """KL(q || p) (elementwise over batch axes)."""
    D = q.m.shape[-1]
    e_lam = q.a / q.b
    Kq_inv = jnp.linalg.inv(q.K)
    dm = q.m - p.m
    _, logdet_q = jnp.linalg.slogdet(q.K)
    _, logdet_p = jnp.linalg.slogdet(p.K)
    tr = jnp.einsum("...de,...ed->...", p.K, Kq_inv)
    quad = e_lam * jnp.einsum("...d,...de,...e->...", dm, p.K, dm)
    kl_w = 0.5 * (logdet_q - logdet_p + tr + quad - D)
    return kl_w + gamma_kl(q.a, q.b, p.a, p.b)


# ---------------------------------------------------------------------------
# Gaussian helpers for local continuous latents (FA / Kalman smoothing)
# ---------------------------------------------------------------------------


def gaussian_kl_standard(mean: jnp.ndarray, cov: jnp.ndarray) -> jnp.ndarray:
    """KL( N(mean, cov) || N(0, I) ) with cov: [..., D, D]."""
    D = mean.shape[-1]
    _, logdet = jnp.linalg.slogdet(cov)
    tr = jnp.trace(cov, axis1=-2, axis2=-1)
    return 0.5 * (tr + (mean * mean).sum(-1) - D - logdet)


def categorical_entropy(logp: jnp.ndarray) -> jnp.ndarray:
    """Entropy of categorical given normalized log-probs [..., K]."""
    p = jnp.exp(logp)
    return -(p * logp).sum(-1)
