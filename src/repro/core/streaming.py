"""Batch-streaming Bayesian learning — paper §2.3.

Implements:

* **Bayesian updating** (Eq. 3): the posterior after batch t-1 becomes the
  prior for batch t.  In natural-parameter space this is just carrying the
  accumulated suff-stats forward — constant memory per batch, never revisits
  old data.
* **Streaming Variational Bayes** (Broderick et al., 2013): each arriving
  batch is fitted with VMP sweeps against the chained prior.
* **Concept-drift detection** (Borchani et al., 2015 — "a novel probabilistic
  approach"): monitor the per-instance expected log-likelihood of each new
  batch under the current posterior with an exponential moving average +
  Page-Hinkley-style cumulative deviation test; on drift, the prior is
  *tempered* (forgetting factor) so the model re-adapts.

All of this works identically on one device or on the d-VMP mesh (pass
``mesh=``) — the paper's headline "same code multi-core or distributed".
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import vmp as V
from repro.core import dvmp
from repro.core.vmp import CompiledPlate, PlateParams


class DriftState(NamedTuple):
    """Page-Hinkley statistics on per-instance held-out log-likelihood."""

    mean: jnp.ndarray      # running mean of the score
    cum: jnp.ndarray       # cumulative deviation
    cum_min: jnp.ndarray   # running min of cum
    t: jnp.ndarray


def drift_init() -> DriftState:
    z = jnp.asarray(0.0)
    return DriftState(mean=z, cum=z, cum_min=z, t=jnp.asarray(0))


def drift_update(state: DriftState, score: jnp.ndarray, *,
                 delta: float = 0.05) -> Tuple[DriftState, jnp.ndarray]:
    """score = mean per-instance E_q[log p(x)] of the new batch BEFORE update.

    Returns (new_state, ph_statistic); caller compares against a threshold
    lambda (e.g. 5.0) to flag drift.
    """
    t = state.t + 1
    mean = state.mean + (score - state.mean) / t
    cum = state.cum + (mean - score - delta)  # drops in score push cum UP
    cum_min = jnp.minimum(state.cum_min, cum)
    ph = cum - cum_min
    return DriftState(mean=mean, cum=cum, cum_min=cum_min, t=t), ph


class StreamState(NamedTuple):
    prior: PlateParams     # chained prior  (Eq. 3 accumulation)
    post: PlateParams      # current posterior
    drift: DriftState
    n_seen: jnp.ndarray
    n_drifts: jnp.ndarray


def stream_init(prior: PlateParams, init: PlateParams) -> StreamState:
    return StreamState(prior=prior, post=init, drift=drift_init(),
                       n_seen=jnp.asarray(0.0), n_drifts=jnp.asarray(0))


def _temper(params: PlateParams, base: PlateParams, rho: float) -> PlateParams:
    """Forgetting: geometric interpolation toward the base prior in natural
    coordinates — the 'power prior' used on drift detection."""
    from repro.core import svi

    nat = svi.to_natural(params)
    nat0 = svi.to_natural(base)
    mixed = jax.tree_util.tree_map(
        lambda a, b: rho * a + (1.0 - rho) * b, nat, nat0
    )
    return svi.from_natural(mixed)


def stream_update(
    cp: CompiledPlate,
    base_prior: PlateParams,
    state: StreamState,
    xc: jnp.ndarray,
    xd: jnp.ndarray,
    *,
    sweeps: int = 20,
    tol: float = 1e-4,
    drift_threshold: float = 5.0,
    forget: float = 0.3,
    mesh=None,
    data_axes: Tuple[str, ...] = ("data",),
) -> Tuple[StreamState, dict]:
    """Process one arriving batch: score -> (maybe) drift -> Bayesian update.

    Eq. 3: p(theta | X_1..X_t) ∝ p(X_t | theta) p(theta | X_1..X_{t-1}):
    the fit below uses ``state.prior`` (yesterday's posterior) as the prior.
    """
    N = xc.shape[0]
    mask = jnp.ones(N)

    # --- score the incoming batch under the CURRENT posterior ---------------
    stats_pre, _ = V.local_step(cp, state.post, xc, xd, mask)
    score = stats_pre.local_elbo / N
    dstate, ph = drift_update(state.drift, score)
    drifted = ph > drift_threshold

    # on drift: temper the chained prior back toward the base prior
    prior = jax.tree_util.tree_map(
        lambda a, b: jnp.where(drifted, a, b),
        _temper(state.prior, base_prior, forget),
        state.prior,
    )
    # reset PH statistics after a drift signal
    dstate = jax.tree_util.tree_map(
        lambda r, k: jnp.where(drifted, r, k), drift_init(), dstate
    )

    # --- streaming VB: VMP sweeps against the chained prior ------------------
    if mesh is None:
        fit = V.vmp_fit(cp, prior, state.post, xc, xd, sweeps, tol)
        post, e = fit.post, fit.elbo
    else:
        post, e = state.post, jnp.asarray(-jnp.inf)
        for _ in range(sweeps):  # bounded sweeps; dvmp_fit also available
            post, e = dvmp.dvmp_one_sweep(
                cp, prior, post, xc, xd, mask, mesh, data_axes
            )

    new_state = StreamState(
        prior=post,  # Eq. 3: today's posterior is tomorrow's prior
        post=post,
        drift=dstate,
        n_seen=state.n_seen + N,
        n_drifts=state.n_drifts + drifted.astype(jnp.int32),
    )
    info = {"elbo": e, "score": score, "ph": ph, "drifted": drifted}
    return new_state, info
