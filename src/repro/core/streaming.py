"""Batch-streaming Bayesian learning — paper §2.3.

Implements:

* **Bayesian updating** (Eq. 3): the posterior after batch t-1 becomes the
  prior for batch t.  In natural-parameter space this is just carrying the
  accumulated suff-stats forward — constant memory per batch, never revisits
  old data.
* **Streaming Variational Bayes** (Broderick et al., 2013): each arriving
  batch is fitted with VMP sweeps against the chained prior.
* **Concept-drift detection** (Borchani et al., 2015 — "a novel probabilistic
  approach"): monitor the per-instance expected log-likelihood of each new
  batch under the current posterior with an exponential moving average +
  Page-Hinkley-style cumulative deviation test; on drift, the prior is
  *tempered* (forgetting factor) so the model re-adapts.

All of this works identically on one device or on the d-VMP mesh (pass
``mesh=``) — the paper's headline "same code multi-core or distributed".

Two drivers share one step body (:func:`_stream_step`):

* :func:`stream_update` — one host call per arriving batch (the online API);
* :func:`stream_fit` — T stacked batches in ONE jitted ``lax.scan`` with the
  drift test and prior tempering inside the scan body and the
  ``StreamState`` buffers donated, so the whole stream replay is a single
  resident device program (no per-batch host round-trip or dispatch).
"""

from __future__ import annotations

from functools import partial
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import vmp as V
from repro.core import dvmp
from repro.core.vmp import CompiledPlate, PlateParams
from repro.obs import sink as obs
from repro.obs.metrics import StreamBatchMetrics


class DriftState(NamedTuple):
    """Page-Hinkley statistics on per-instance held-out log-likelihood."""

    mean: jnp.ndarray      # running mean of the score
    cum: jnp.ndarray       # cumulative deviation
    cum_min: jnp.ndarray   # running min of cum
    t: jnp.ndarray


def drift_init() -> DriftState:
    z = jnp.asarray(0.0)
    return DriftState(mean=z, cum=z, cum_min=z, t=jnp.asarray(0))


def drift_update(state: DriftState, score: jnp.ndarray, *,
                 delta: float = 0.05) -> Tuple[DriftState, jnp.ndarray]:
    """score = mean per-instance E_q[log p(x)] of the new batch BEFORE update.

    Returns (new_state, ph_statistic); caller compares against a threshold
    lambda (e.g. 5.0) to flag drift.
    """
    t = state.t + 1
    mean = state.mean + (score - state.mean) / t
    cum = state.cum + (mean - score - delta)  # drops in score push cum UP
    cum_min = jnp.minimum(state.cum_min, cum)
    ph = cum - cum_min
    return DriftState(mean=mean, cum=cum, cum_min=cum_min, t=t), ph


def drift_gate(dstate: DriftState, score: jnp.ndarray, chained, tempered, *,
               drift_threshold: float):
    """Page-Hinkley test + prior selection, as pure traced ops.

    Runs :func:`drift_update` on ``score``, then where-selects between the
    ``chained`` prior (no drift) and the ``tempered`` prior (detector
    fired), resetting the PH statistics on a firing.  Generic over the
    prior pytree — shared by the static streaming path
    (:func:`_stream_step`, ``PlateParams``) and the temporal
    ``pgm_models.dynamic.seq_stream_fit`` scan (``HMMPosterior``).

    Returns ``(prior, new_dstate, ph, drifted)``.
    """
    dstate, ph = drift_update(dstate, score)
    drifted = ph > drift_threshold
    prior = jax.tree_util.tree_map(
        lambda a, b: jnp.where(drifted, a, b), tempered, chained
    )
    # reset PH statistics after a drift signal
    dstate = jax.tree_util.tree_map(
        lambda r, k: jnp.where(drifted, r, k), drift_init(), dstate
    )
    return prior, dstate, ph, drifted


class StreamState(NamedTuple):
    prior: PlateParams     # chained prior  (Eq. 3 accumulation)
    post: PlateParams      # current posterior
    drift: DriftState
    n_seen: jnp.ndarray
    n_drifts: jnp.ndarray
    n_quarantined: jnp.ndarray   # batches skipped by the non-finite gate


def stream_init(prior: PlateParams, init: PlateParams) -> StreamState:
    """Fresh stream state.  The global params are COPIED (they are tiny)
    so the state owns its buffers — :func:`stream_fit` donates them."""
    copy = lambda tree: jax.tree_util.tree_map(jnp.array, tree)
    return StreamState(prior=copy(prior), post=copy(init), drift=drift_init(),
                       n_seen=jnp.asarray(0.0), n_drifts=jnp.asarray(0),
                       n_quarantined=jnp.asarray(0))


def tree_finite(tree) -> jnp.ndarray:
    """Scalar bool: every inexact leaf of ``tree`` is fully finite.

    Pure traced ops (an ``all``-reduce per leaf), so the streaming scans
    run it in-body as the quarantine health flag at negligible cost next
    to the VMP sweeps.  Integer/bool leaves are finite by construction and
    skipped."""
    ok = jnp.asarray(True)
    for leaf in jax.tree_util.tree_leaves(tree):
        if jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.inexact):
            ok = jnp.logical_and(ok, jnp.all(jnp.isfinite(leaf)))
    return ok


def _temper(params: PlateParams, base: PlateParams, rho: float) -> PlateParams:
    """Forgetting: geometric interpolation toward the base prior in natural
    coordinates — the 'power prior' used on drift detection."""
    from repro.core import svi

    nat = svi.to_natural(params)
    nat0 = svi.to_natural(base)
    mixed = jax.tree_util.tree_map(
        lambda a, b: rho * a + (1.0 - rho) * b, nat, nat0
    )
    return svi.from_natural(mixed)


def _stream_step(
    cp: CompiledPlate,
    base_prior: PlateParams,
    state: StreamState,
    xc: jnp.ndarray,
    xd: jnp.ndarray,
    mask: jnp.ndarray,
    drift_threshold: float,
    forget: float,
    backend: str,
    chunk: Optional[int],
    fit_fn,
) -> Tuple[StreamState, Dict[str, jnp.ndarray]]:
    """score -> (maybe) drift -> Bayesian update, as pure traced ops.

    THE step body, shared by the per-batch :func:`stream_update` API and
    the :func:`stream_fit` scan — both drivers run exactly this math.
    ``fit_fn(prior, post) -> (post, elbo, sweeps)`` supplies the inner VMP
    fit (jitted ``vmp_fit``, traced ``fit_loop`` or d-VMP sweeps).

    The info output is a :class:`StreamBatchMetrics` pytree computed
    in-graph (ELBO, drift statistic + event mask, tempering rho, effective
    instance count, sweeps-to-convergence) — scan-safe telemetry at zero
    extra cost (every gauge is a byproduct of ops the step already runs).
    """
    n_eff = mask.sum()

    # --- score the incoming batch under the CURRENT posterior ---------------
    stats_pre, _ = V.local_step(cp, state.post, xc, xd, mask,
                                backend=backend, chunk=chunk)
    score = stats_pre.local_elbo / jnp.maximum(n_eff, 1.0)
    # on drift: temper the chained prior back toward the base prior
    prior, dstate, ph, drifted = drift_gate(
        state.drift, score, state.prior,
        _temper(state.prior, base_prior, forget),
        drift_threshold=drift_threshold,
    )

    # --- streaming VB: VMP sweeps against the chained prior ------------------
    post, e, fit_sweeps = fit_fn(prior, state.post)

    # --- non-finite quarantine ----------------------------------------------
    # A poisoned batch (NaN/Inf rows, or a fit that diverged) must not
    # corrupt every subsequent batch through the chained posterior.  Same
    # static-shape HOLD trick as the fused fits' convergence flag: the
    # update is computed unconditionally above, then the carried state is
    # where-selected wholesale — an unhealthy batch is SKIPPED (posterior,
    # chained prior and Page-Hinkley state all held bit-exactly) and only
    # counted.  The drift gate's score feeds the PH state, so it is held
    # too: one NaN score would otherwise poison the detector forever.
    healthy = jnp.logical_and(jnp.isfinite(score), jnp.isfinite(e))
    healthy = jnp.logical_and(healthy, tree_finite(post))
    drifted = jnp.logical_and(drifted, healthy)
    sel = lambda new, old: jax.tree_util.tree_map(
        lambda a, b: jnp.where(healthy, a, b), new, old)

    new_state = StreamState(
        prior=sel(post, state.prior),  # Eq. 3: posterior -> tomorrow's prior
        post=sel(post, state.post),
        drift=sel(dstate, state.drift),
        n_seen=state.n_seen + jnp.where(healthy, n_eff, 0.0),
        n_drifts=state.n_drifts + drifted.astype(jnp.int32),
        n_quarantined=state.n_quarantined
        + jnp.logical_not(healthy).astype(jnp.int32),
    )
    zero = jnp.asarray(0.0)
    metrics = StreamBatchMetrics(
        elbo=jnp.where(healthy, e, zero),
        score=jnp.where(healthy, score, zero),
        ph=jnp.where(healthy, ph, zero),
        drifted=drifted, n_eff=n_eff,
        rho=jnp.where(drifted, forget, 1.0), sweeps=fit_sweeps,
        quarantined=jnp.logical_not(healthy),
    )
    return new_state, metrics.as_info()


def stream_update(
    cp: CompiledPlate,
    base_prior: PlateParams,
    state: StreamState,
    xc: jnp.ndarray,
    xd: jnp.ndarray,
    *,
    sweeps: int = 20,
    tol: float = 1e-4,
    drift_threshold: float = 5.0,
    forget: float = 0.3,
    mesh=None,
    data_axes: Tuple[str, ...] = ("data",),
    backend: str = "einsum",
    chunk: Optional[int] = None,
    mask: Optional[jnp.ndarray] = None,
) -> Tuple[StreamState, dict]:
    """Process one arriving batch: score -> (maybe) drift -> Bayesian update.

    Eq. 3: p(theta | X_1..X_t) ∝ p(X_t | theta) p(theta | X_1..X_{t-1}):
    the fit below uses ``state.prior`` (yesterday's posterior) as the prior.

    One host call per batch with the drift logic dispatched eagerly — the
    online API.  For a resident replay of many batches use
    :func:`stream_fit` (same step body, one device program).
    """
    if mask is None:
        mask = jnp.ones(xc.shape[0])

    if mesh is None:
        def fit_fn(prior, post):
            fit = V.vmp_fit(cp, prior, post, xc, xd, sweeps, tol,
                            mask, backend, chunk)
            return fit.post, fit.elbo, fit.sweep
    else:
        def fit_fn(prior, post):
            e = jnp.asarray(-jnp.inf)
            for _ in range(sweeps):  # bounded sweeps; dvmp_fit also available
                post, e = dvmp.dvmp_one_sweep(
                    cp, prior, post, xc, xd, mask, mesh, data_axes,
                    backend, chunk
                )
            return post, e, jnp.asarray(sweeps)

    new_state, info = _stream_step(cp, base_prior, state, xc, xd, mask,
                                   drift_threshold, forget, backend, chunk,
                                   fit_fn)
    if obs.enabled():
        obs.emit_stream_events(info)
        obs.emit_kernel_counts(site="stream_update")
    return new_state, info


@partial(
    jax.jit,
    static_argnums=(0,),
    static_argnames=("sweeps", "tol", "drift_threshold", "forget",
                     "backend", "chunk"),
    donate_argnums=(2,),
)
def _stream_fit_scan(cp, base_prior, state, xcs, xds, masks, *, sweeps, tol,
                     drift_threshold, forget, backend, chunk):
    def step(carry: StreamState, inp):
        xc, xd, mask = inp

        def fit_fn(prior, post):
            fit = V.fit_loop(cp, prior, post, xc, xd, mask, sweeps, tol,
                             backend, chunk)
            return fit.post, fit.elbo, fit.sweep

        return _stream_step(cp, base_prior, carry, xc, xd, mask,
                            drift_threshold, forget, backend, chunk, fit_fn)

    return jax.lax.scan(step, state, (xcs, xds, masks))


def stream_fit(
    cp: CompiledPlate,
    base_prior: PlateParams,
    state: StreamState,
    xcs: jnp.ndarray,
    xds: jnp.ndarray,
    masks: Optional[jnp.ndarray] = None,
    *,
    sweeps: int = 20,
    tol: float = 1e-4,
    drift_threshold: float = 5.0,
    forget: float = 0.3,
    backend: str = "einsum",
    chunk: Optional[int] = None,
    window: Optional[int] = None,
) -> Tuple[StreamState, Dict[str, jnp.ndarray]]:
    """Replay T stacked batches in ONE jitted ``lax.scan``.

    xcs: [T, B, F]; xds: [T, B, Fd]; masks: [T, B] (None = all real).
    Equivalent to T calls of :func:`stream_update` (same step body), but the
    whole stream is a single resident device program: the drift test,
    tempering and the inner VMP sweep loop all live inside the scan body,
    and the ``StreamState`` buffers are donated so the posterior is updated
    in place batch-over-batch.

    ``window=w`` bounds DEVICE memory for long streams: the stacked batches
    stay on the host (pass numpy arrays) and the scan replays them one
    device-sliced window of w batches at a time — ceil(T/w) dispatches
    instead of T, with only O(w * B) of the stream resident on device.
    ``window=None`` keeps the whole stream in one scan (fastest, largest
    footprint).  The tail window may retrace once if ``T % w != 0``.

    Returns the final state and per-batch info arrays ``{"elbo", "score",
    "ph", "drifted", "n_eff", "rho", "sweeps", "quarantined"}`` each of
    leading dim T (the :class:`StreamBatchMetrics` columns; ``drifted`` is
    the per-batch drift-event mask, ``quarantined`` marks non-finite
    batches skipped with the carried posterior held).  When obs is enabled
    (``REPRO_OBS``) the same columns are emitted host-side as
    ``stream_batch``/``drift``/``quarantine`` JSONL events AFTER the scan
    returns — the fused device program is byte-identical at every obs
    level.
    """
    # state is donated, but its leaves routinely alias each other and the
    # other operands (stream_init reuses the prior's buffers for state.prior
    # and symmetry_broken shares all-but-m with it); XLA rejects donating an
    # aliased buffer, so copy exactly the aliased (small, global) leaves
    seen = {id(leaf) for tree in (base_prior, xcs, xds, masks)
            for leaf in jax.tree_util.tree_leaves(tree)}

    def unalias(leaf):
        if id(leaf) in seen:
            return jnp.array(leaf)
        seen.add(id(leaf))
        return leaf

    if window is not None and window < 1:
        raise ValueError(f"window must be >= 1, got {window}")
    state = jax.tree_util.tree_map(unalias, state)
    T = xcs.shape[0]
    if window is None or window >= T:
        if masks is None:
            masks = jnp.ones(xcs.shape[:2])
        state, info = _stream_fit_scan(cp, base_prior, state, xcs, xds,
                                       masks, sweeps=sweeps, tol=tol,
                                       drift_threshold=drift_threshold,
                                       forget=forget, backend=backend,
                                       chunk=chunk)
        if obs.enabled():
            obs.emit_stream_events(info)
            obs.emit_kernel_counts(site="stream_fit")
        return state, info
    infos = []
    for t0 in range(0, T, window):
        xc_w = jnp.asarray(xcs[t0:t0 + window])
        xd_w = jnp.asarray(xds[t0:t0 + window])
        m_w = (jnp.ones(xc_w.shape[:2]) if masks is None
               else jnp.asarray(masks[t0:t0 + window]))
        state, info = _stream_fit_scan(cp, base_prior, state, xc_w, xd_w,
                                       m_w, sweeps=sweeps, tol=tol,
                                       drift_threshold=drift_threshold,
                                       forget=forget, backend=backend,
                                       chunk=chunk)
        infos.append(info)
    info = {k: jnp.concatenate([i[k] for i in infos]) for k in infos[0]}
    if obs.enabled():
        obs.emit_stream_events(info)
        obs.emit_kernel_counts(site="stream_fit")
    return state, info
