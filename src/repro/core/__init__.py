"""Core module — the paper's learning/inference engine (paper Table 1 'core').

Submodules:
  expfam               conjugate exponential-family algebra
  dag                  modeling language (Variables/DAG/BayesianNetwork/PlateSpec)
  vmp                  variational message passing (single device)
  dvmp                 distributed VMP (shard_map + psum)
  svi                  stochastic variational inference
  streaming            Bayesian updating (Eq. 3), streaming VB, concept drift
  importance_sampling  parallel likelihood weighting for CLG networks
  factored_frontier    dynamic-BN filtering/smoothing (lax.scan)
  map_inference        scalable MAP / abductive inference
  compat               jax version shims (shard_map, make_mesh)

Exact inference (junction tree) lives in the sibling package
``repro.infer_exact`` — the paper's HUGIN link, replaced natively.
"""
