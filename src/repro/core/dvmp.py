"""d-VMP — distributed Variational Message Passing [Masegosa et al., 2016].

The paper's distributed scheme (Flink/Spark in the original) has one key
structural property: in the Fig.-3 plate family every *global* parameter node
receives, per VMP sweep, a message that is the SUM over data instances of
per-instance expected sufficient statistics, while *local* latent posteriors
(q(Z_i), q(H_i)) depend only on the instance's own data and the current
global posterior.  Hence:

    worker w:  stats_w = local_step(theta, data shard w)        (embarrassing)
    runtime :  stats   = all_reduce_sum(stats_w)                (one collective)
    driver  :  theta'  = conjugate_update(prior, stats)         (replicated)

On a TPU pod this is a `shard_map` over the data mesh axes with a single
`jax.lax.psum` of the suff-stat pytree per sweep — the Flink reduce becomes
an ICI all-reduce.  Local latents never leave their shard, which is what let
the paper scale to models with >1e9 (local-latent) nodes.

The sweep loop itself lives *inside* the shard_map body (a
``lax.while_loop``), so a full fit is ONE XLA program: sweeps are separated
by psums, not by host round-trips — strictly better than the paper's
per-iteration Flink superstep barrier.
"""

from __future__ import annotations

import functools
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.core.compat import shard_map

from repro.core import vmp as V
from repro.core.vmp import CompiledPlate, PlateParams, PlateStats, VMPState
from repro.obs.metrics import DvmpMetrics


def _psum_stats(stats: PlateStats, axes) -> PlateStats:
    return jax.tree_util.tree_map(lambda s: jax.lax.psum(s, axes), stats)


# ---------------------------------------------------------------------------
# Program caches.  Building a fresh ``shard_map`` + ``jax.jit`` wrapper per
# call forced a retrace (and on the streaming path, one retrace PER ARRIVING
# BATCH).  The wrappers are pure functions of (cp, mesh, data_axes) plus the
# python scalars closed over by the body, so we build each program once per
# key — ``CompiledPlate`` hashes by identity and ``Mesh`` is hashable; jax's
# own jit cache then handles shape/dtype variation.  ``lru_cache`` bounds
# retention for long-lived processes that build plates/meshes dynamically.
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=64)
def _fit_program(cp: CompiledPlate, mesh: Mesh, data_axes: Tuple[str, ...],
                 max_sweeps: int, tol: float, backend: str,
                 chunk: Optional[int], with_metrics: bool = False):
    dspec = P(data_axes)
    rep = P()

    @partial(
        shard_map, mesh=mesh,
        in_specs=(rep, rep, dspec, dspec, dspec),
        out_specs=(rep, rep) if with_metrics else rep,
        check_vma=False,
    )
    def fit_shard(prior_, init_, xc_, xd_, mask_):
        def sweep(state: VMPState) -> VMPState:
            stats, _ = V.local_step(cp, state.post, xc_, xd_, mask_,
                                    backend=backend, chunk=chunk)
            stats = _psum_stats(stats, data_axes)      # the d-VMP collective
            post = V.global_update(prior_, stats)
            e = V.elbo(cp, prior_, post, stats)
            return VMPState(post=post, elbo=e,
                            delta=jnp.abs(e - state.elbo), sweep=state.sweep + 1)

        def cond(state: VMPState):
            return jnp.logical_and(
                state.sweep < max_sweeps,
                state.delta > tol * (jnp.abs(state.elbo) + 1.0),
            )

        s0 = VMPState(post=init_, elbo=jnp.asarray(-jnp.inf),
                      delta=jnp.asarray(jnp.inf), sweep=jnp.asarray(0))
        st = jax.lax.while_loop(cond, sweep, sweep(s0))
        if not with_metrics:
            return st
        # per-shard effective instance counts, gathered across every data
        # axis in order — rides the same replicated out_spec as the state
        shard_n = mask_.sum()[None]
        for ax in data_axes:
            shard_n = jax.lax.all_gather(shard_n, ax).reshape(-1)
        return st, DvmpMetrics(shard_n=shard_n, sweeps=st.sweep)

    return jax.jit(fit_shard)


@functools.lru_cache(maxsize=64)
def _sweep_program(cp: CompiledPlate, mesh: Mesh, data_axes: Tuple[str, ...],
                   backend: str, chunk: Optional[int]):
    dspec = P(data_axes)
    rep = P()

    @partial(
        shard_map, mesh=mesh,
        in_specs=(rep, rep, dspec, dspec, dspec), out_specs=(rep, rep),
        check_vma=False,
    )
    def body(prior_, post_, xc_, xd_, mask_):
        stats, _ = V.local_step(cp, post_, xc_, xd_, mask_,
                                backend=backend, chunk=chunk)
        stats = _psum_stats(stats, data_axes)
        new = V.global_update(prior_, stats)
        return new, V.elbo(cp, prior_, new, stats)

    return jax.jit(body)


def dvmp_fit(
    cp: CompiledPlate,
    prior: PlateParams,
    init: PlateParams,
    xc: jnp.ndarray,
    xd: jnp.ndarray,
    mesh: Mesh,
    data_axes: Tuple[str, ...] = ("data",),
    max_sweeps: int = 100,
    tol: float = 1e-4,
    mask: Optional[jnp.ndarray] = None,
    backend: str = "einsum",
    chunk: Optional[int] = None,
    with_metrics: bool = False,
) -> VMPState:
    """Distributed VMP fit.

    xc: [N, F], xd: [N, Fd] — N must divide by the product of data-axis sizes;
    use ``mask`` (same leading dim) to pad ragged global batches.
    Global params are replicated; data is sharded over ``data_axes``.
    Result is numerically identical to single-device ``vmp_fit`` on the
    concatenated data (up to float reduction order) — tested.

    ``with_metrics=True`` (part of the program-cache key — a separate
    compiled program, the metric-free path is untouched) also returns a
    :class:`DvmpMetrics`: per-shard effective instance counts (all_gather
    of each shard's mask sum — the data-balance gauge) and
    sweeps-to-convergence.
    """
    if mask is None:
        mask = jnp.ones(xc.shape[0], xc.dtype)
    prog = _fit_program(cp, mesh, tuple(data_axes), max_sweeps, tol,
                        backend, chunk, with_metrics)
    return prog(prior, init, xc, xd, mask)


@functools.lru_cache(maxsize=64)
def _posterior_z_program(cp: CompiledPlate, mesh: Mesh,
                         data_axes: Tuple[str, ...], backend: str,
                         chunk: Optional[int]):
    dspec = P(data_axes)
    rep = P()

    @partial(
        shard_map, mesh=mesh,
        in_specs=(rep, dspec, dspec), out_specs=dspec,
        check_vma=False,
    )
    def body(post_, xc_, xd_):
        mask = jnp.ones(xc_.shape[0], xc_.dtype)
        _, r = V.local_step(cp, post_, xc_, xd_, mask,
                            backend=backend, chunk=chunk)
        return r

    return jax.jit(body)


def dvmp_posterior_z(
    cp: CompiledPlate,
    post: PlateParams,
    xc: jnp.ndarray,
    xd: jnp.ndarray,
    mesh: Mesh,
    data_axes: Tuple[str, ...] = ("data",),
    backend: str = "einsum",
    chunk: Optional[int] = None,
) -> jnp.ndarray:
    """Replica-sharded q(Z | x) — the serving-tier query collective.

    Independent queries need NO cross-device reduction (unlike the fit
    path's suff-stat psum): the global posterior is replicated, the query
    batch is split over ``data_axes``, each replica answers its shard with
    ``local_step`` and the sharded result is reassembled.  Row results are
    identical to single-device :func:`repro.core.vmp.posterior_z`.
    ``xc.shape[0]`` must divide by the product of data-axis sizes (the
    serving tier pads buckets to a power of two, which does).
    """
    prog = _posterior_z_program(cp, mesh, tuple(data_axes), backend, chunk)
    return prog(post, xc, xd)


def dvmp_one_sweep(
    cp: CompiledPlate,
    prior: PlateParams,
    post: PlateParams,
    xc: jnp.ndarray,
    xd: jnp.ndarray,
    mask: jnp.ndarray,
    mesh: Mesh,
    data_axes: Tuple[str, ...] = ("data",),
    backend: str = "einsum",
    chunk: Optional[int] = None,
) -> Tuple[PlateParams, jnp.ndarray]:
    """Single distributed sweep — the building block reused by streaming VB
    (one sweep per arriving batch) and by the SVI driver."""
    prog = _sweep_program(cp, mesh, tuple(data_axes), backend, chunk)
    return prog(prior, post, xc, xd, mask)
