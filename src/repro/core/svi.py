"""Stochastic Variational Inference (Hoffman et al., 2013) — paper §2.2.

SVI replaces the full-data global update with a natural-gradient step on the
global variational parameters, computed from a minibatch scaled to the full
data size:

    eta_{t+1} = (1 - rho_t) eta_t + rho_t ( eta_prior + (N/B) * stats_batch )

where eta are the NATURAL coordinates of the conjugate families.  For our
parameterizations the natural coordinates are

    Dirichlet      : alpha
    MVNormalGamma  : ( K, K m, a, b + 1/2 m^T K m )

(the coordinates in which the conjugate update is addition of suff stats).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import expfam as ef
from repro.core import vmp as V
from repro.core.vmp import CompiledPlate, PlateParams, PlateStats


class NatParams(NamedTuple):
    mix: jnp.ndarray       # alpha
    reg_K: jnp.ndarray
    reg_Km: jnp.ndarray
    reg_a: jnp.ndarray
    reg_bq: jnp.ndarray    # b + 1/2 m^T K m
    disc: jnp.ndarray      # alpha


def to_natural(p: PlateParams) -> NatParams:
    km = jnp.einsum("...de,...e->...d", p.reg.K, p.reg.m)
    quad = jnp.einsum("...d,...d->...", p.reg.m, km)
    return NatParams(
        mix=p.mix.alpha, reg_K=p.reg.K, reg_Km=km, reg_a=p.reg.a,
        reg_bq=p.reg.b + 0.5 * quad, disc=p.disc.alpha,
    )


def from_natural(n: NatParams) -> PlateParams:
    m = jnp.linalg.solve(n.reg_K, n.reg_Km[..., None])[..., 0]
    quad = jnp.einsum("...d,...d->...", m, n.reg_Km)
    b = jnp.maximum(n.reg_bq - 0.5 * quad, 1e-10)
    return PlateParams(
        mix=ef.Dirichlet(n.mix),
        reg=ef.MVNormalGamma(m=m, K=n.reg_K, a=n.reg_a, b=b),
        disc=ef.Dirichlet(n.disc),
    )


def stats_as_natural(stats: PlateStats) -> NatParams:
    """Suff stats expressed as a natural-coordinate increment."""
    reg = ef.reg_dense(stats.reg)        # expand the lazy latent block
    return NatParams(
        mix=stats.counts,
        reg_K=reg.sxx,
        reg_Km=reg.sxy,
        reg_a=0.5 * stats.reg.n,
        reg_bq=0.5 * stats.reg.syy,
        disc=stats.disc,
    )


class SVIState(NamedTuple):
    nat: NatParams
    step: jnp.ndarray


def svi_init(post: PlateParams) -> SVIState:
    return SVIState(nat=to_natural(post), step=jnp.asarray(0))


def svi_step(
    cp: CompiledPlate,
    prior: PlateParams,
    state: SVIState,
    xc: jnp.ndarray,
    xd: jnp.ndarray,
    n_total: float,
    *,
    tau: float = 1.0,
    kappa: float = 0.7,
    backend: str = "einsum",
    chunk: Optional[int] = None,
) -> SVIState:
    """One natural-gradient step on minibatch (xc, xd); Robbins-Monro rate
    rho_t = (t + tau)^-kappa, kappa in (0.5, 1].

    ``backend``/``chunk`` select the suff-stats reduction schedule of the
    E-step (see :func:`repro.core.vmp.local_step`).
    """
    B = xc.shape[0]
    post = from_natural(state.nat)
    stats, _ = V.local_step(cp, post, xc, xd, jnp.ones(B),
                            backend=backend, chunk=chunk)
    scale = n_total / B
    target = jax.tree_util.tree_map(
        lambda p, s: p + scale * s, to_natural(prior), stats_as_natural(stats)
    )
    rho = (state.step + tau) ** (-kappa)
    nat = jax.tree_util.tree_map(
        lambda cur, tgt: (1.0 - rho) * cur + rho * tgt, state.nat, target
    )
    return SVIState(nat=nat, step=state.step + 1)


def svi_posterior(state: SVIState) -> PlateParams:
    return from_natural(state.nat)
