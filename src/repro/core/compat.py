"""Version compatibility shims for jax APIs that moved between releases.

``shard_map`` graduated from ``jax.experimental.shard_map`` to the top-level
``jax`` namespace (and renamed ``check_rep`` -> ``check_vma``) in newer jax
releases.  The repo targets the modern spelling; this module maps it onto
whatever the installed jax provides so the import never breaks at collection
time again (see scripts/ci.sh).

Usage everywhere in the repo:

    from repro.core.compat import shard_map
"""

from __future__ import annotations

import inspect

try:  # jax >= 0.6: top-level export with check_vma kwarg
    from jax import shard_map as _shard_map
except ImportError:  # jax 0.4.x/0.5.x: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map

_ACCEPTS_VMA = "check_vma" in inspect.signature(_shard_map).parameters


def shard_map(f, **kwargs):
    """Drop-in ``jax.shard_map`` accepting the modern ``check_vma`` kwarg.

    Call sites use the decorator-with-kwargs form
    ``partial(shard_map, mesh=..., in_specs=..., out_specs=..., check_vma=...)``;
    on older jax the ``check_vma`` flag is translated to ``check_rep``.
    """
    if not _ACCEPTS_VMA and "check_vma" in kwargs:
        kwargs["check_rep"] = kwargs.pop("check_vma")
    return _shard_map(f, **kwargs)


def make_mesh(axis_shapes, axis_names, **kwargs):
    """``jax.make_mesh`` with every axis Auto, portable across jax versions.

    ``axis_types=`` / ``jax.sharding.AxisType`` only exist on newer jax;
    older releases treat every axis as Auto already, so there the kwarg is
    dropped.  On newer jax the Auto types are passed explicitly (shard_map
    requires non-Manual axes).
    """
    import jax

    if hasattr(jax.sharding, "AxisType"):
        kwargs.setdefault(
            "axis_types", (jax.sharding.AxisType.Auto,) * len(axis_names))
    else:
        kwargs.pop("axis_types", None)
    return jax.make_mesh(axis_shapes, axis_names, **kwargs)


__all__ = ["shard_map", "make_mesh"]
