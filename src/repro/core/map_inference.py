"""Scalable MAP / abductive inference — paper §2.2 / ref [18].

The paper's scheme is map-reduce: scatter many candidate assignments
(Monte-Carlo starts), hill-climb each locally, reduce with max.  TPU-native
version: candidates are a batch dimension (vmap), the hill-climb is a
``lax.while_loop`` of coordinate-ascent passes, and the reduce is a
``psum``-free ``lax.pmax``-style argmax — distributed over the mesh with
shard_map when provided.

Supported query: most probable joint configuration of the DISCRETE variables
of a CLG ``BayesianNetwork`` given (possibly continuous) evidence; continuous
non-evidence variables are marginalized approximately by clamping to their
conditional mean given the current discrete configuration (iterated).
"""

from __future__ import annotations

from functools import partial
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.core.compat import shard_map

from repro.core.dag import BayesianNetwork, Variable


def _complete_continuous(
    bn: BayesianNetwork, asg: Dict[str, jnp.ndarray], evidence: Dict[str, jnp.ndarray]
) -> Dict[str, jnp.ndarray]:
    """Set non-evidence continuous vars to their conditional mean (ancestral)."""
    out = dict(asg)
    for v in bn.order:
        if v.is_discrete or v.name in evidence:
            continue
        parents = bn.dag.get_parents(v)
        dpa = [p for p in parents if p.is_discrete]
        cpa = [p for p in parents if not p.is_discrete]
        didx = tuple(out[p.name].astype(jnp.int32) for p in dpa)
        cpd = bn.cpds[v.name]
        mean = cpd.alpha[didx] if dpa else jnp.broadcast_to(
            cpd.alpha, out[bn.order[0].name].shape)
        if cpa:
            beta = cpd.beta[didx] if dpa else cpd.beta
            xc = jnp.stack([out[p.name] for p in cpa], -1)
            mean = mean + (beta * xc).sum(-1)
        out[v.name] = mean
    return out


def map_inference(
    bn: BayesianNetwork,
    evidence: Dict[str, float],
    *,
    n_starts: int = 128,
    n_passes: int = 20,
    seed: int = 0,
    mesh: Optional[Mesh] = None,
    data_axes: Tuple[str, ...] = ("data",),
) -> Tuple[Dict[str, int], float]:
    """Returns (MAP assignment of discrete non-evidence vars, its log-prob)."""
    ev = {k: jnp.asarray(v) for k, v in evidence.items()}
    dvars: List[Variable] = [
        v for v in bn.order if v.is_discrete and v.name not in ev
    ]
    if not dvars:
        raise ValueError("no discrete query variables")
    cards = [v.card for v in dvars]

    def score(states: jnp.ndarray) -> jnp.ndarray:
        """states: [n, Q] int -> log p(states, evidence, cont@mean)."""
        n = states.shape[0]
        asg = {k: jnp.broadcast_to(v, (n,)) for k, v in ev.items()}
        for i, v in enumerate(dvars):
            asg[v.name] = states[:, i]
        asg = _complete_continuous(bn, asg, ev)
        return bn.log_prob(asg)

    def hill_climb(key: jax.Array, n_local: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
        keys = jax.random.split(key, len(dvars))
        init = jnp.stack(
            [jax.random.randint(keys[i], (n_local,), 0, c)
             for i, c in enumerate(cards)], axis=1)

        def one_pass(carry):
            states, best, it = carry
            for i, c in enumerate(cards):  # static unroll over query vars
                cand = jnp.stack([states.at[:, i].set(val) for val in range(c)])
                s = jax.vmap(score)(cand)          # [c, n]
                pick = s.argmax(0)
                states = states.at[:, i].set(pick)
            new_best = score(states)
            return states, new_best, it + 1

        def cond(carry):
            _, best, it = carry
            return it < n_passes

        states, best, _ = jax.lax.while_loop(
            cond, one_pass, (init, score(init), jnp.asarray(0)))
        return states, best

    if mesh is None:
        states, best = jax.jit(partial(hill_climb, n_local=n_starts))(
            jax.random.PRNGKey(seed))
    else:
        ndev = 1
        for a in data_axes:
            ndev *= mesh.shape[a]
        keys = jax.random.split(jax.random.PRNGKey(seed), ndev)

        @partial(shard_map, mesh=mesh, in_specs=P(data_axes),
                 out_specs=(P(data_axes), P(data_axes)), check_vma=False)
        def block(k):
            return hill_climb(k[0], max(n_starts // ndev, 1))

        states, best = jax.jit(block)(keys)

    idx = int(jnp.argmax(best))
    assignment = {v.name: int(states[idx, i]) for i, v in enumerate(dvars)}
    return assignment, float(best[idx])
