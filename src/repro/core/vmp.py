"""Variational Message Passing (Winn & Bishop 2005) — the learning engine.

The engine performs CAVI over the Fig.-3 plate family (``dag.PlateSpec``):

    theta  ~ conjugate priors                       (global, shared)
    Z_i    ~ Cat(pi)                                (per-instance discrete latent)
    H_i    ~ N(0, I_L)                              (per-instance cont. latent)
    X_if   ~ N( w_{f,Z_i}^T d_if , lam_{f,Z_i}^-1 ) (continuous leaves; CLG Eq. 2)
    X_id   ~ Cat( theta_{d,Z_i} )                   (discrete leaves)

where the design vector d_if = [1, observed parents of f, H_i (masked)].

One VMP *sweep* = local step (update q(Z), q(H), emit expected sufficient
statistics — the "messages to global parameter nodes") + global step
(conjugate natural-parameter update).  This file is single-device; dvmp.py
wraps the local step in shard_map and psums the messages, exactly the d-VMP
scheme [Masegosa et al., 2016].

All functions are jit-compatible; the sweep loop uses ``jax.lax.while_loop``.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import expfam as ef
from repro.core.dag import PlateSpec
from repro.obs import sink as obs_sink
from repro.obs.metrics import LocalStepMetrics


# ---------------------------------------------------------------------------
# Parameter / statistics pytrees
# ---------------------------------------------------------------------------


class PlateParams(NamedTuple):
    """Global variational posterior (and prior) over theta."""

    mix: ef.Dirichlet          # [K]        mixture weights (K=1 when no latent)
    reg: ef.MVNormalGamma      # [F, K, D]  one CLG regression per leaf/component
    disc: ef.Dirichlet         # [Fd, K, C] multinomial leaves (C = max card)


class PlateStats(NamedTuple):
    """Expected sufficient statistics — the d-VMP message pytree."""

    counts: jnp.ndarray        # [K]
    reg: ef.RegSuffStats       # [F, K, ...]
    disc: jnp.ndarray          # [Fd, K, C]
    n: jnp.ndarray             # scalar — #instances contributing
    local_elbo: jnp.ndarray    # scalar — sum of local ELBO terms


class PlateLayout(NamedTuple):
    """Static integer geometry derived from a PlateSpec (hashable, jit-static)."""

    F: int           # continuous leaves
    Fd: int          # discrete leaves
    K: int           # mixture components
    L: int           # continuous latent dim
    P: int           # max #observed parents
    D: int           # design dim = 1 + P + L
    C: int           # max discrete-leaf cardinality


def layout_of(spec: PlateSpec) -> PlateLayout:
    dm = spec.discrete_map
    F = spec.n_features - len(dm)
    Fd = len(dm)
    K = max(spec.latent_card, 1)
    L = spec.latent_dim
    P = max((len(spec.parent_idx(i)) for i in range(spec.n_features)), default=0)
    C = max(dm.values(), default=2)
    return PlateLayout(F=F, Fd=Fd, K=K, L=L, P=P, D=1 + P + L, C=C)


@dataclasses.dataclass(frozen=True, eq=False)  # eq=False: identity hash, jit-static
class CompiledPlate:
    """Static arrays derived from the spec (closed over by jitted fns).

    Continuous leaves are re-indexed 0..F-1 and discrete leaves 0..Fd-1; the
    data pipeline provides ``xc: [N, F]`` and ``xd: [N, Fd]`` accordingly.
    """

    spec: PlateSpec
    layout: PlateLayout
    parent_idx: jnp.ndarray    # [F, P] int — indices into xc columns
    parent_mask: jnp.ndarray   # [F, P]
    latent_mask: jnp.ndarray   # [F, L]
    card_mask: jnp.ndarray     # [Fd, C] — valid categories per discrete leaf


def compile_plate(
    spec: PlateSpec, latent_mask: Optional[jnp.ndarray] = None
) -> CompiledPlate:
    lay = layout_of(spec)
    dm = spec.discrete_map
    cont_ids = [i for i in range(spec.n_features) if i not in dm]
    cont_pos = {orig: new for new, orig in enumerate(cont_ids)}
    pidx = jnp.zeros((max(lay.F, 1), max(lay.P, 1)), jnp.int32)
    pmask = jnp.zeros((max(lay.F, 1), max(lay.P, 1)), jnp.float32)
    for new_f, orig_f in enumerate(cont_ids):
        for j, p in enumerate(spec.parent_idx(orig_f)):
            if p in dm:
                raise ValueError("observed parents must be continuous features")
            pidx = pidx.at[new_f, j].set(cont_pos[p])
            pmask = pmask.at[new_f, j].set(1.0)
    if latent_mask is None:
        lmask = jnp.ones((max(lay.F, 1), max(lay.L, 1)), jnp.float32)
    else:
        lmask = jnp.asarray(latent_mask, jnp.float32)
        lmask = lmask.reshape(max(lay.F, 1), max(lay.L, 1))
    cmask = jnp.zeros((max(lay.Fd, 1), lay.C), jnp.float32)
    for new_d, (orig, card) in enumerate(sorted(dm.items())):
        cmask = cmask.at[new_d, :card].set(1.0)
    return CompiledPlate(
        spec=spec, layout=lay, parent_idx=pidx, parent_mask=pmask,
        latent_mask=lmask, card_mask=cmask,
    )


def design_mask(cp: CompiledPlate) -> jnp.ndarray:
    """[F, D] — which design columns are live for each continuous leaf."""
    lay = cp.layout
    ones = jnp.ones((max(lay.F, 1), 1), jnp.float32)
    parts = [ones]
    if lay.P > 0:
        parts.append(cp.parent_mask[:, : lay.P])
    if lay.L > 0:
        parts.append(cp.latent_mask[:, : lay.L])
    return jnp.concatenate(parts, axis=1)


# ---------------------------------------------------------------------------
# Prior construction
# ---------------------------------------------------------------------------


def default_prior(cp: CompiledPlate, *, alpha0: float = 1.0, reg_scale: float = 1.0,
                  a0: float = 1.0, b0: float = 1.0) -> PlateParams:
    lay = cp.layout
    F, K, D, Fd, C = max(lay.F, 1), lay.K, lay.D, max(lay.Fd, 1), lay.C
    mix = ef.Dirichlet(jnp.full((K,), alpha0))
    eye = jnp.broadcast_to(jnp.eye(D) / reg_scale, (F, K, D, D))
    reg = ef.MVNormalGamma(
        m=jnp.zeros((F, K, D)),
        K=eye,
        a=jnp.full((F, K), a0),
        b=jnp.full((F, K), b0),
    )
    disc = ef.Dirichlet(
        jnp.full((Fd, K, C), alpha0) * cp.card_mask[:, None, :] + 1e-12
    )
    return PlateParams(mix=mix, reg=reg, disc=disc)


def symmetry_broken(prior: PlateParams, key: jax.Array, scale: float = 0.5
                    ) -> PlateParams:
    """Initial posterior: prior with jittered regression means (breaks the
    label symmetry that makes CAVI stall at the uniform fixed point)."""
    k1, k2 = jax.random.split(key)
    m = prior.reg.m + scale * jax.random.normal(k1, prior.reg.m.shape)
    disc = ef.Dirichlet(
        prior.disc.alpha * jnp.exp(0.1 * jax.random.normal(k2, prior.disc.alpha.shape))
    )
    return PlateParams(mix=prior.mix, reg=prior.reg._replace(m=m), disc=disc)


# ---------------------------------------------------------------------------
# Local step — compute q(Z), q(H) and emit expected sufficient statistics
# ---------------------------------------------------------------------------
#
# Two suff-stats backends share one math path:
#   backend="einsum"  — XLA einsum reductions (the reference; always exact);
#                       the leaf-shared latent-latent block is stored lazily
#                       as [K, L, L] (RegSuffStats.sxx_hh) and expanded once
#                       at the conjugate update
#   backend="pallas"  — kernels.clg_stats tiled-accumulation kernels; L > 0
#                       plates run the fused component-major
#                       clg_suffstats_latent kernel (design [obs, E[h|z=k]])
#                       (compiled on TPU, interpret fallback on CPU; oracles:
#                       kernels.ref.clg_suffstats_ref /
#                       clg_suffstats_latent_ref / clg_disc_counts_ref)
# and an instance-chunked driver (``chunk=``) scans the body over fixed-size
# instance blocks so the [N, F, K] intermediates (quad_oo, the sxx
# reductions) never materialize at full N; nothing [N, K, L, L]-shaped is
# formed on either backend.


BACKENDS = ("einsum", "pallas")


def default_backend() -> str:
    """'pallas' where the kernels compile natively (TPU or forced via
    REPRO_PALLAS_COMPILE=1), else 'einsum' — interpret-mode Pallas is
    correctness-grade only."""
    from repro.kernels import clg_stats

    return "einsum" if clg_stats._resolve_interpret(None) else "pallas"


def _observed_design(cp: CompiledPlate, xc: jnp.ndarray) -> jnp.ndarray:
    """[N, F, 1+P] observed part of the design vectors."""
    lay = cp.layout
    N = xc.shape[0]
    ones = jnp.ones((N, max(lay.F, 1), 1), xc.dtype)
    if lay.P == 0:
        return ones
    gathered = xc[:, cp.parent_idx]            # [N, F, P]
    return jnp.concatenate([ones, gathered * cp.parent_mask], axis=-1)


def _split_moments(cp: CompiledPlate, mom: ef.RegMoments):
    """Split regression moments into observed / latent blocks, applying masks."""
    lay = cp.layout
    Do = 1 + lay.P
    dmask = design_mask(cp)                                    # [F, D]
    mm = dmask[:, None, :, None] * dmask[:, None, None, :]     # [F,1,D,D]
    e_lamww = mom.e_lamww * mm
    e_lamw = mom.e_lamw * dmask[:, None, :]
    oo = e_lamww[..., :Do, :Do]
    oh = e_lamww[..., :Do, Do:]
    hh = e_lamww[..., Do:, Do:]
    wo = e_lamw[..., :Do]
    wh = e_lamw[..., Do:]
    return wo, wh, oo, oh, hh


def _latent_hh_shared(cp: CompiledPlate) -> bool:
    """True when every leaf sees the same latent dims (uniform latent mask):
    the latent-latent suff-stat block is then leaf-independent and the
    einsum backend stores it ONCE as a lazy [K, L, L] (``RegSuffStats.
    sxx_hh``) instead of broadcast per leaf.  Static: ``cp`` is concrete."""
    import numpy as np

    lm = np.asarray(cp.latent_mask)[:, : max(cp.layout.L, 1)]
    return bool((lm == lm[:1]).all())


def _reduce_reg(cp: CompiledPlate, obs: jnp.ndarray, y: jnp.ndarray,
                h_mean: jnp.ndarray, s_hh: jnp.ndarray, r: jnp.ndarray,
                backend: str):
    """Regression suff-stats reduction over instances.

    Returns ``(sxx, sxx_hh, sxy, syy)``; ``sxx_hh`` is None when ``sxx`` is
    the dense [F, K, D, D] matrix, or the lazy leaf-shared [K, L, L]
    latent-latent block (then ``sxx`` carries only the top [F, K, Do, D]
    observed rows — see :func:`repro.core.expfam.reg_dense`).

    ``backend="pallas"``: L == 0 routes through the k-independent
    ``clg_suffstats`` kernel; L > 0 routes the WHOLE reduction — observed,
    cross and latent blocks — through the fused component-major
    ``clg_suffstats_latent`` kernel (design [obs, E[h|z=k]] with the
    E[hh^T|z=k] covariance correction folded in), one pass over instances.
    ``backend="einsum"`` is the XLA reference; its latent-latent block is
    reduced once as [K, L, L] and never broadcast per leaf.
    """
    lay = cp.layout
    L = lay.L
    if L == 0:
        obs_sink.count_kernel(f"clg_suffstats:{backend}")
        if backend == "pallas":
            from repro.kernels import clg_stats

            sxx, sxy, syy = clg_stats.clg_suffstats(obs, y, r)
        else:
            sxx = jnp.einsum("nfa,nfb,nk->fkab", obs, obs, r)
            sxy = jnp.einsum("nfa,nf,nk->fka", obs, y, r)
            syy = jnp.einsum("nf,nf,nk->fk", y, y, r)
        return sxx, None, sxy, syy
    obs_sink.count_kernel(f"clg_suffstats_latent:{backend}")
    if backend == "pallas":
        from repro.kernels import clg_stats

        sxx, sxy, syy = clg_stats.clg_suffstats_latent(obs, h_mean, y, r,
                                                       s_hh)
        return sxx, None, sxy, syy
    sxx_oo = jnp.einsum("nfa,nfb,nk->fkab", obs, obs, r)
    sxy_o = jnp.einsum("nfa,nf,nk->fka", obs, y, r)
    syy = jnp.einsum("nf,nf,nk->fk", y, y, r)
    sxx_oh = jnp.einsum("nfa,nkl,nk->fkal", obs, h_mean, r)
    sxx_top = jnp.concatenate([sxx_oo, sxx_oh], axis=-1)     # [F,K,Do,D]
    sxx_hh = (jnp.einsum("nkl,nkm,nk->klm", h_mean, h_mean, r)
              + r.sum(0)[:, None, None] * s_hh)              # [K,L,L]
    sxy = jnp.concatenate(
        [sxy_o, jnp.einsum("nkl,nf,nk->fkl", h_mean, y, r)], axis=-1
    )
    if not _latent_hh_shared(cp):
        # per-leaf latent masks (CustomGlobalLocalModel): the hh block is
        # leaf-dependent after masking — fall back to the dense matrix
        hh = jnp.broadcast_to(sxx_hh[None],
                              (max(lay.F, 1),) + sxx_hh.shape)
        bot = jnp.concatenate([jnp.swapaxes(sxx_oh, -1, -2), hh], axis=-1)
        return jnp.concatenate([sxx_top, bot], axis=-2), None, sxy, syy
    return sxx_top, sxx_hh, sxy, syy


def _reduce_disc(cp: CompiledPlate, xd: jnp.ndarray, r: jnp.ndarray,
                 backend: str) -> jnp.ndarray:
    """Discrete-leaf one-hot count reduction -> [Fd, K, C]."""
    lay = cp.layout
    obs_sink.count_kernel(f"clg_disc_counts:{backend}")
    if backend == "pallas":
        from repro.kernels import clg_stats

        counts = clg_stats.clg_disc_counts(xd, r, lay.C)
    else:
        onehot = jax.nn.one_hot(xd.astype(jnp.int32), lay.C)  # [N, Fd, C]
        counts = jnp.einsum("nfc,nk->fkc", onehot, r)
    return counts * cp.card_mask[:, None, :]


def _local_step_body(cp: CompiledPlate, params: PlateParams, xc: jnp.ndarray,
                     xd: jnp.ndarray, mask: jnp.ndarray,
                     r_fixed: Optional[jnp.ndarray], backend: str,
                     ) -> Tuple[PlateStats, jnp.ndarray]:
    """Local step on one (chunk of a) batch — see :func:`local_step`."""
    lay = cp.layout
    N = xc.shape[0]
    K, L, Do = lay.K, lay.L, 1 + lay.P

    e_logpi = ef.dirichlet_expected_logprob(params.mix)        # [K]
    mom = ef.mvnormalgamma_moments(params.reg)                 # [F, K, ...]
    wo, wh, oo, oh, hh = _split_moments(cp, mom)
    if lay.F == 0:
        # pure-discrete model: keep regression block inert (stats = 0)
        xc = jnp.zeros((N, 1), xd.dtype if xd.size else jnp.float32)
    obs = _observed_design(cp, xc)                             # [N, F, Do]
    y = xc.astype(obs.dtype)                                   # [N, F]

    # --- quadratic pieces that do not involve H -----------------------------
    # quad_oo[n,f,k] = o^T E[lam w_o w_o^T] o
    quad_oo = jnp.einsum("nfa,fkab,nfb->nfk", obs, oo, obs)
    lin_o = jnp.einsum("nfa,fka->nfk", obs, wo)                # o^T E[lam w_o]

    if L > 0:
        # --- q(H_i | Z_i = k): Gaussian, shared across leaves ---------------
        A = jnp.eye(L) + hh.sum(0)                             # [K, L, L]
        S = jnp.linalg.inv(A)                                  # [K, L, L]
        # b[n,k,l] = sum_f ( y E[lam w_h] - E[lam w_h w_o^T] o )
        b = jnp.einsum("nf,fkl->nkl", y, wh) - jnp.einsum(
            "fkal,nfa->nkl", oh, obs
        )
        h_mean = jnp.einsum("klm,nkm->nkl", S, b)              # [N, K, L]
        # E[hh^T | z=k] = S_k + E[h]E[h]^T splits every quadratic into an
        # instance-independent [K] piece plus a mean-outer-product piece, so
        # nothing [N, K, L, L]-shaped is ever materialized.
        quad_h = (jnp.einsum("fklm,klm->fk", hh, S)[None]
                  + jnp.einsum("fklm,nkl,nkm->nfk", hh, h_mean, h_mean))
        cross = 2.0 * jnp.einsum("nfa,fkal,nkl->nfk", obs, oh, h_mean)
        lin_h = jnp.einsum("nf,fkl,nkl->nfk", y, wh, h_mean) * 2.0
        # KL(q(H|z=k) || N(0, I)): covariance terms depend only on k
        _, logdet_s = jnp.linalg.slogdet(S)                    # [K]
        tr_s = jnp.trace(S, axis1=-2, axis2=-1)                # [K]
        kl_h = 0.5 * ((h_mean * h_mean).sum(-1)
                      + (tr_s - L - logdet_s)[None])           # [N, K]
    else:
        quad_h = jnp.zeros((N, max(lay.F, 1), K))
        cross = jnp.zeros_like(quad_h)
        lin_h = jnp.zeros_like(quad_h)
        kl_h = jnp.zeros((N, K))
        h_mean = jnp.zeros((N, K, 1))
        S = jnp.zeros((K, 1, 1))

    # E_q[log N(y_f | w^T d, lam^-1)] per leaf/component
    ll = 0.5 * (
        mom.e_loglam[None]
        - ef.LOG2PI
        - mom.e_lam[None] * (y * y)[..., None]
        + 2.0 * lin_o * y[..., None]
        + lin_h
        - quad_oo
        - cross
        - quad_h
    )                                                          # [N, F, K]
    ll_cont = ll.sum(1) if lay.F > 0 else jnp.zeros((N, K))

    # discrete leaves
    if lay.Fd > 0:
        e_logtheta = ef.dirichlet_expected_logprob(params.disc)  # [Fd, K, C]
        ll_disc = jnp.take_along_axis(
            jnp.transpose(e_logtheta, (0, 2, 1))[None],          # [1, Fd, C, K]
            xd.astype(jnp.int32)[..., None, None],               # [N, Fd, 1, 1]
            axis=2,
        )[..., 0, :].sum(1)                                      # [N, K]
    else:
        ll_disc = jnp.zeros((N, K))

    logits = e_logpi[None] + ll_cont + ll_disc - kl_h            # [N, K]
    if r_fixed is None:
        logr = jax.nn.log_softmax(logits, axis=-1)
        r = jnp.exp(logr) * mask[:, None]
    else:
        logr = jnp.log(jnp.maximum(r_fixed, 1e-30))
        r = r_fixed * mask[:, None]

    # --- messages to global parameter nodes ---------------------------------
    counts = r.sum(0)                                            # [K]

    # expected design outer products per leaf (masked dims handled by moments;
    # stats are masked below so padded dims keep their prior)
    sxx, sxx_hh, sxy, syy = _reduce_reg(cp, obs, y, h_mean, S, r, backend)
    nw = jnp.broadcast_to(counts[None], syy.shape)

    dmask = design_mask(cp)
    live = 1.0 if lay.F > 0 else 0.0  # inert regression block for pure-discrete
    Do = sxx.shape[-2]                # = D dense, 1 + P lazy (static)
    sxx = (sxx * dmask[:, None, :Do, None] * dmask[:, None, None, :] * live)
    if sxx_hh is not None:
        # lazy leaf-shared latent block; mask row is uniform across leaves
        # (guaranteed by _latent_hh_shared in _reduce_reg)
        lmask = dmask[0, Do:]
        sxx_hh = sxx_hh * lmask[None, :, None] * lmask[None, None, :] * live
    sxy = sxy * dmask[:, None, :] * live
    reg_stats = ef.RegSuffStats(sxx=sxx, sxy=sxy, syy=syy * live, n=nw * live,
                                sxx_hh=sxx_hh)

    if lay.Fd > 0:
        disc_counts = _reduce_disc(cp, xd, r, backend)
    else:
        disc_counts = jnp.zeros((1, K, lay.C))

    # local ELBO: sum_n [ sum_k r (logits) + H(r) ] with masked instances 0
    ent = ef.categorical_entropy(logr) * mask
    local_elbo = (r * logits).sum() + ent.sum()

    stats = PlateStats(
        counts=counts, reg=reg_stats, disc=disc_counts,
        n=mask.sum(), local_elbo=local_elbo,
    )
    return stats, r


def local_step(cp: CompiledPlate, params: PlateParams, xc: jnp.ndarray,
               xd: jnp.ndarray, mask: jnp.ndarray,
               r_fixed: Optional[jnp.ndarray] = None, *,
               backend: str = "einsum", chunk: Optional[int] = None,
               with_metrics: bool = False,
               ) -> Tuple[PlateStats, jnp.ndarray]:
    """One local VMP step on a batch.

    xc: [N, F] continuous leaves; xd: [N, Fd] int discrete leaves;
    mask: [N] 1.0 for real instances (0.0 pads — streaming tail batches);
    r_fixed: [N, K] — clamp q(Z) (supervised models: observed class labels).

    backend: "einsum" (XLA reference) or "pallas" (tiled-accumulation
    kernels); chunk: when set, instances are processed in blocks of this
    size by a ``lax.scan`` whose carry is the suff-stat pytree, so no
    [N, F, K] / [N, K, L, L] intermediate ever materializes at full N.
    Both knobs only change the reduction schedule, not the math.

    Returns the suff-stat message pytree and the responsibilities r: [N, K];
    with ``with_metrics=True`` (a static flag — jitted callers key on it)
    additionally returns an :class:`LocalStepMetrics` pytree whose
    ``chunk_n_eff`` holds the per-chunk effective instance counts ([1] when
    unchunked) — in-graph observability of the reduction schedule.
    """
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; expected {BACKENDS}")
    N = xc.shape[0]
    if chunk is None or chunk >= N:
        stats, r = _local_step_body(cp, params, xc, xd, mask, r_fixed,
                                    backend)
        if with_metrics:
            return stats, r, LocalStepMetrics(chunk_n_eff=mask.sum()[None])
        return stats, r

    nchunks = -(-N // chunk)
    pad = nchunks * chunk - N
    if pad:
        xc = jnp.pad(xc, ((0, pad), (0, 0)))
        xd = jnp.pad(xd, ((0, pad), (0, 0)))
        mask = jnp.pad(mask, (0, pad))          # pads masked out -> stats 0
        if r_fixed is not None:
            r_fixed = jnp.pad(r_fixed, ((0, pad), (0, 0)))
    xcs = xc.reshape(nchunks, chunk, xc.shape[1])
    xds = xd.reshape(nchunks, chunk, xd.shape[1])
    ms = mask.reshape(nchunks, chunk)
    rfs = (None if r_fixed is None
           else r_fixed.reshape(nchunks, chunk, r_fixed.shape[1]))

    def body(acc, inp):
        if rfs is None:
            xc_c, xd_c, m_c = inp
            rf_c = None
        else:
            xc_c, xd_c, m_c, rf_c = inp
        stats_c, r_c = _local_step_body(cp, params, xc_c, xd_c, m_c, rf_c,
                                        backend)
        return jax.tree_util.tree_map(jnp.add, acc, stats_c), r_c

    # first chunk seeds the accumulator (no zero-pytree construction);
    # chunk < N here, so nchunks >= 2 and the scan always has work
    stats0, r0 = _local_step_body(cp, params, xcs[0], xds[0], ms[0],
                                  None if rfs is None else rfs[0], backend)
    xs = ((xcs[1:], xds[1:], ms[1:]) if rfs is None
          else (xcs[1:], xds[1:], ms[1:], rfs[1:]))
    stats, rs = jax.lax.scan(body, stats0, xs)
    r = jnp.concatenate([r0[None], rs], axis=0).reshape(nchunks * chunk, -1)
    if with_metrics:
        return stats, r[:N], LocalStepMetrics(chunk_n_eff=ms.sum(axis=1))
    return stats, r[:N]


# ---------------------------------------------------------------------------
# Global step — conjugate update, Bayesian updating Eq. (3)
# ---------------------------------------------------------------------------


def global_update(prior: PlateParams, stats: PlateStats) -> PlateParams:
    """posterior natural params = prior natural params + summed messages."""
    mix = ef.dirichlet_update(prior.mix, stats.counts)
    reg = ef.mvnormalgamma_update(prior.reg, stats.reg)
    disc = ef.Dirichlet(prior.disc.alpha + stats.disc)
    return PlateParams(mix=mix, reg=reg, disc=disc)


def global_kl(q: PlateParams, p: PlateParams, lay: PlateLayout) -> jnp.ndarray:
    kl = ef.dirichlet_kl(q.mix, p.mix)
    kl = kl + ef.mvnormalgamma_kl(q.reg, p.reg).sum()
    if lay.Fd > 0:
        # guard: padded categories have alpha ~ 0 in both q and p -> kl 0
        kl = kl + ef.dirichlet_kl(
            ef.Dirichlet(q.disc.alpha + 1e-12), ef.Dirichlet(p.disc.alpha + 1e-12)
        ).sum()
    return kl


def elbo(cp: CompiledPlate, prior: PlateParams, post: PlateParams,
         stats: PlateStats) -> jnp.ndarray:
    """ELBO of the current (q(theta), q(Z), q(H)) triple.

    Uses the standard CAVI identity: local terms were computed against the
    *current* q(theta); the global penalty is KL(q(theta) || p(theta)) minus
    the correction for re-scoring expected-suff-stat terms, which cancels at
    the CAVI fixed point; we report local_elbo - KL (a valid lower bound
    surrogate whose monotonicity we test).
    """
    return stats.local_elbo - global_kl(post, prior, cp.layout)


# ---------------------------------------------------------------------------
# Batch VMP fit — lax.while_loop sweeps to convergence
# ---------------------------------------------------------------------------


class VMPState(NamedTuple):
    post: PlateParams
    elbo: jnp.ndarray
    delta: jnp.ndarray
    sweep: jnp.ndarray


def fit_loop(cp: CompiledPlate, prior: PlateParams, init: PlateParams,
             xc: jnp.ndarray, xd: jnp.ndarray, mask: jnp.ndarray,
             max_sweeps: int, tol: float, backend: str = "einsum",
             chunk: Optional[int] = None) -> VMPState:
    """Trace-level VMP sweep loop (no jit) — embedded by :func:`vmp_fit`,
    ``dvmp`` shard bodies and the ``streaming.stream_fit`` scan."""

    def sweep(state: VMPState) -> VMPState:
        stats, _ = local_step(cp, state.post, xc, xd, mask,
                              backend=backend, chunk=chunk)
        post = global_update(prior, stats)
        e = elbo(cp, prior, post, stats)
        return VMPState(post=post, elbo=e,
                        delta=jnp.abs(e - state.elbo), sweep=state.sweep + 1)

    def cond(state: VMPState):
        return jnp.logical_and(
            state.sweep < max_sweeps,
            state.delta > tol * (jnp.abs(state.elbo) + 1.0),
        )

    state0 = VMPState(post=init, elbo=jnp.asarray(-jnp.inf),
                      delta=jnp.asarray(jnp.inf), sweep=jnp.asarray(0))
    # one unconditional sweep, then loop
    state1 = sweep(state0)
    return jax.lax.while_loop(cond, sweep, state1)


@partial(jax.jit, static_argnums=(0, 5, 6, 8, 9))
def vmp_fit(cp: CompiledPlate, prior: PlateParams, init: PlateParams,
            xc: jnp.ndarray, xd: jnp.ndarray,
            max_sweeps: int = 100, tol: float = 1e-4,
            mask: Optional[jnp.ndarray] = None, backend: str = "einsum",
            chunk: Optional[int] = None) -> VMPState:
    """Run VMP sweeps on one (device-local) data set until ELBO converges."""
    if mask is None:
        mask = jnp.ones(xc.shape[0])
    return fit_loop(cp, prior, init, xc, xd, mask, max_sweeps, tol,
                    backend, chunk)


# ---------------------------------------------------------------------------
# Posterior inference in the learnt model (paper §3.4, VMP as inference)
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnums=(0,), static_argnames=("backend", "chunk"))
def posterior_z(cp: CompiledPlate, params: PlateParams, xc: jnp.ndarray,
                xd: jnp.ndarray, *, backend: str = "einsum",
                chunk: Optional[int] = None) -> jnp.ndarray:
    """q(Z | x) for a batch — the paper's getPosterior(HiddenVar).

    Jitted (keyed on the plate + batch shape): repeated serve-path calls
    dispatch one compiled program instead of retracing ``local_step``.
    ``chunk`` bounds memory for very large query batches.
    """
    mask = jnp.ones(xc.shape[0])
    _, r = local_step(cp, params, xc, xd, mask, backend=backend, chunk=chunk)
    return r
