"""Parallel importance sampling in CLG networks — paper §2.2 / ref [19].

Likelihood weighting over a ``BayesianNetwork``: evidence nodes are clamped,
non-evidence nodes are sampled from their conditional given already-sampled
parents, and each particle carries weight prod_e p(e | parents).  The paper's
multi-core parallelism (Java 8 streams over sample blocks) becomes a single
``jax.vmap``-style batched sampler: all particles advance node-by-node in
lock-step, which is exactly the TPU-friendly layout.  A shard_map wrapper
distributes particle blocks across the mesh with one final psum.
"""

from __future__ import annotations

from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.core.compat import shard_map

from repro.core.dag import BayesianNetwork, Variable


def _sample_or_clamp(
    bn: BayesianNetwork,
    key: jax.Array,
    n: int,
    evidence: Dict[str, jnp.ndarray],
) -> Tuple[Dict[str, jnp.ndarray], jnp.ndarray]:
    """Batched likelihood weighting. Returns (particles, log_weights)."""
    asg: Dict[str, jnp.ndarray] = {}
    logw = jnp.zeros(n)
    for v in bn.order:
        key, sub = jax.random.split(key)
        parents = bn.dag.get_parents(v)
        dpa = [p for p in parents if p.is_discrete]
        cpa = [p for p in parents if not p.is_discrete]
        didx = tuple(asg[p.name].astype(jnp.int32) for p in dpa)
        cpd = bn.cpds[v.name]
        if v.name in evidence:
            val = jnp.broadcast_to(jnp.asarray(evidence[v.name]), (n,))
            asg[v.name] = val
            # weight by p(e | parents)
            logw = logw + bn._node_logp(v, asg)
            continue
        if v.is_discrete:
            table = cpd.table[didx] if dpa else jnp.broadcast_to(
                cpd.table, (n,) + cpd.table.shape)
            asg[v.name] = jax.random.categorical(sub, jnp.log(table), axis=-1)
        else:
            alpha = cpd.alpha[didx] if dpa else jnp.broadcast_to(cpd.alpha, (n,))
            sigma2 = cpd.sigma2[didx] if dpa else jnp.broadcast_to(cpd.sigma2, (n,))
            mean = alpha
            if cpa:
                beta = cpd.beta[didx] if dpa else jnp.broadcast_to(
                    cpd.beta, (n,) + cpd.beta.shape)
                xc = jnp.stack([asg[p.name] for p in cpa], -1)
                mean = mean + (beta * xc).sum(-1)
            asg[v.name] = mean + jnp.sqrt(sigma2) * jax.random.normal(sub, (n,))
    return asg, logw


class ImportanceSampling:
    """Paper §3.4 API: set model / evidence, run, query posteriors."""

    def __init__(self, n_samples: int = 10_000, seed: int = 0) -> None:
        self.n_samples = n_samples
        self.key = jax.random.PRNGKey(seed)
        self.bn: Optional[BayesianNetwork] = None
        self.evidence: Dict[str, jnp.ndarray] = {}
        self._particles = None
        self._logw = None

    def set_model(self, bn: BayesianNetwork) -> None:
        self.bn = bn

    def set_evidence(self, evidence: Dict[str, float]) -> None:
        self.evidence = {k: jnp.asarray(v) for k, v in evidence.items()}

    def run_inference(self, mesh: Optional[Mesh] = None,
                      data_axes: Tuple[str, ...] = ("data",)) -> None:
        self.key, sub = jax.random.split(self.key)
        if mesh is None:
            self._particles, self._logw = _sample_or_clamp(
                self.bn, sub, self.n_samples, self.evidence)
        else:
            ndev = 1
            for a in data_axes:
                ndev *= mesh.shape[a]
            keys = jax.random.split(sub, ndev)

            @partial(shard_map, mesh=mesh, in_specs=P(data_axes),
                     out_specs=(P(data_axes), P(data_axes)), check_vma=False)
            def sample_block(k):
                return _sample_or_clamp(
                    self.bn, k[0], self.n_samples // ndev, self.evidence)

            self._particles, self._logw = jax.jit(sample_block)(keys)

    # -- queries -------------------------------------------------------------

    def _weights(self) -> jnp.ndarray:
        return jax.nn.softmax(self._logw)

    def posterior_discrete(self, var: Variable) -> jnp.ndarray:
        """Normalized posterior table for a discrete variable."""
        w = self._weights()
        x = self._particles[var.name].astype(jnp.int32)
        return jnp.zeros(var.card).at[x].add(w)

    def posterior_mean_var(self, var: Variable) -> Tuple[jnp.ndarray, jnp.ndarray]:
        w = self._weights()
        x = self._particles[var.name]
        mean = (w * x).sum()
        return mean, (w * (x - mean) ** 2).sum()

    def effective_sample_size(self) -> jnp.ndarray:
        w = self._weights()
        return 1.0 / (w * w).sum()
