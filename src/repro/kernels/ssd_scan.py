"""Pallas TPU kernel for the Mamba2 SSD chunk pass.

TPU adaptation (DESIGN.md §6): one grid step processes one (batch*head,
chunk) tile entirely in VMEM — the intra-chunk quadratic term (two
[l, l] x [l, P/N] MXU matmuls), the chunk-state summary, and the
inter-chunk recurrence, whose running state [P, N] persists in VMEM
scratch across the sequentially-iterated chunk grid dimension.  This fuses
what the XLA path (nn.ssm.ssd_chunked) does in five einsums + a lax.scan,
eliminating the HBM round-trips of the intermediate [b,nc,l,l,H] decay
tensors — the kernel's working set is O(l^2 + l(P+N)) per step.

Grid: (B*H, n_chunks), chunk minor (sequential). Chunk length l and state
N are the TPU-aligned tile dims (l=chunk from the config, N=64/128).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hfin_ref, h_scr, *,
            l: int, nchunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, 0].astype(jnp.float32)       # [l, P]
    dt = dt_ref[0, 0].astype(jnp.float32)     # [l]
    A = a_ref[0]                              # scalar (>0; decay = exp(-A dt))
    B = b_ref[0, 0].astype(jnp.float32)       # [l, N]
    C = c_ref[0, 0].astype(jnp.float32)       # [l, N]

    dA = -A * dt                              # [l] negative log-decays
    cum = jnp.cumsum(dA)                      # [l]
    total = cum[-1]
    xd = x * dt[:, None]                      # [l, P]

    # intra-chunk: (C B^T ⊙ decay-mask) @ xd — two MXU matmuls
    scores = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # [l,l]
    ii = jax.lax.broadcasted_iota(jnp.int32, (l, l), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (l, l), 1)
    decay = jnp.where(ii >= jj, jnp.exp(cum[:, None] - cum[None, :]), 0.0)
    y = jax.lax.dot_general(scores * decay, xd, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)       # [l,P]

    # inter-chunk: contribution of the carried state
    h = h_scr[...]                            # [P, N]
    y = y + jnp.exp(cum)[:, None] * jax.lax.dot_general(
        C, h, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)

    # chunk state summary + recurrence
    w = jnp.exp(total - cum)[:, None] * B     # [l, N]
    state = jax.lax.dot_general(xd, w, (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)   # [P,N]
    h_scr[...] = h * jnp.exp(total) + state

    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == nchunks - 1)
    def _final():
        hfin_ref[0] = h_scr[...].astype(hfin_ref.dtype)


def ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
             B: jnp.ndarray, C: jnp.ndarray, chunk: int,
             interpret: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [b, S, H, P]; dt: [b, S, H]; A: [H]; B/C: [b, S, G, N].

    Returns (y [b, S, H, P], final state [b, H, P, N]). Matches
    ``nn.ssm.ssd_chunked`` (the oracle) — tested in interpret mode.
    """
    b, S, H, Pd = x.shape
    G, N = B.shape[2], B.shape[3]
    assert S % chunk == 0
    nc = S // chunk
    rep = H // G

    # head-major layouts: [b*H, nc, l, ...]
    xh = jnp.moveaxis(x, 2, 1).reshape(b * H, nc, chunk, Pd)
    dth = jnp.moveaxis(dt, 2, 1).reshape(b * H, nc, chunk)
    Ah = jnp.tile(A, b)                                     # [b*H]
    Bh = jnp.moveaxis(B, 2, 1).reshape(b * G, nc, chunk, N)
    Ch = jnp.moveaxis(C, 2, 1).reshape(b * G, nc, chunk, N)

    def x_map(bh, ci):
        return (bh, ci, 0, 0)

    def dt_map(bh, ci):
        return (bh, ci, 0)

    def a_map(bh, ci):
        return (bh,)

    def bc_map(bh, ci):
        bb = bh // H
        h = bh % H
        return (bb * G + h // rep, ci, 0, 0)

    def hfin_map(bh, ci):
        return (bh, 0, 0)

    y, hfin = pl.pallas_call(
        functools.partial(_kernel, l=chunk, nchunks=nc),
        grid=(b * H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, Pd), x_map),
            pl.BlockSpec((1, 1, chunk), dt_map),
            pl.BlockSpec((1,), a_map),
            pl.BlockSpec((1, 1, chunk, N), bc_map),
            pl.BlockSpec((1, 1, chunk, N), bc_map),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, Pd), x_map),
            pl.BlockSpec((1, Pd, N), hfin_map),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * H, nc, chunk, Pd), x.dtype),
            jax.ShapeDtypeStruct((b * H, Pd, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((Pd, N), jnp.float32)],
        interpret=interpret,
    )(xh, dth, Ah, Bh, Ch)
    y = jnp.moveaxis(y.reshape(b, H, S, Pd), 1, 2)
    return y, hfin.reshape(b, H, Pd, N)
