"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

from repro.nn.attention import attention_reference
from repro.nn.ssm import ssd_chunked


def flash_attention_ref(q, k, v, *, causal=True, window=None, scale=None):
    """Oracle for kernels.flash_attn.flash_attention."""
    return attention_reference(q, k, v, causal=causal, window=window,
                               scale=scale)


def ssd_scan_ref(x, dt, A, B, C, chunk):
    """Oracle for kernels.ssd_scan.ssd_scan (the XLA SSD path)."""
    return ssd_chunked(x, dt, A, B, C, chunk)


def clg_suffstats_ref(d: jnp.ndarray, y: jnp.ndarray, r: jnp.ndarray
                      ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Oracle for kernels.clg_stats.clg_suffstats."""
    sxx = jnp.einsum("nfd,nfe,nk->fkde", d, d, r)
    sxy = jnp.einsum("nfd,nf,nk->fkd", d, y, r)
    syy = jnp.einsum("nf,nf,nk->fk", y, y, r)
    return sxx, sxy, syy


def clg_suffstats_latent_ref(obs: jnp.ndarray, h_mean: jnp.ndarray,
                             y: jnp.ndarray, r: jnp.ndarray,
                             s_hh: jnp.ndarray
                             ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Oracle for kernels.clg_stats.clg_suffstats_latent: the three-einsum
    latent path over the component-major design d[n,f,k] = [obs, E[h|z=k]]
    with the E[hh^T|z=k] = S_k + E[h]E[h]^T covariance correction."""
    F = obs.shape[1]
    sxx_oo = jnp.einsum("nfa,nfb,nk->fkab", obs, obs, r)
    sxx_oh = jnp.einsum("nfa,nkl,nk->fkal", obs, h_mean, r)
    sxx_hh = (jnp.einsum("nkl,nkm,nk->klm", h_mean, h_mean, r)
              + r.sum(0)[:, None, None] * s_hh)               # [K, L, L]
    sxx_hh = jnp.broadcast_to(sxx_hh[None], (F,) + sxx_hh.shape)
    top = jnp.concatenate([sxx_oo, sxx_oh], axis=-1)
    bot = jnp.concatenate([jnp.swapaxes(sxx_oh, -1, -2), sxx_hh], axis=-1)
    sxx = jnp.concatenate([top, bot], axis=-2)
    sxy = jnp.concatenate(
        [jnp.einsum("nfa,nf,nk->fka", obs, y, r),
         jnp.einsum("nkl,nf,nk->fkl", h_mean, y, r)], axis=-1)
    syy = jnp.einsum("nf,nf,nk->fk", y, y, r)
    return sxx, sxy, syy


def clg_disc_counts_ref(xd: jnp.ndarray, r: jnp.ndarray, C: int) -> jnp.ndarray:
    """Oracle for kernels.clg_stats.clg_disc_counts."""
    import jax.nn

    onehot = jax.nn.one_hot(xd.astype(jnp.int32), C)       # [N, Fd, C]
    return jnp.einsum("nfc,nk->fkc", onehot, r)


def family_counts_ref(xd: jnp.ndarray, strides: jnp.ndarray, w: jnp.ndarray,
                      C: int) -> jnp.ndarray:
    """Oracle for kernels.family_counts.family_counts: the einsum fallback
    (mixed-radix code per (instance, family), then a weighted one-hot)."""
    import jax.nn

    codes = xd.astype(jnp.int32) @ strides.astype(jnp.int32).T     # [N, M]
    onehot = jax.nn.one_hot(codes, C)                              # [N, M, C]
    return jnp.einsum("nmc,n->mc", onehot, w)


def log_product_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Oracle for kernels.factor_ops.log_product."""
    return a + b[:, None, :]


def log_marginalize_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Oracle for kernels.factor_ops.log_marginalize."""
    import jax.scipy.special as jsp

    return jsp.logsumexp(x, axis=-1)


def evidence_select_ref(x: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """Oracle for kernels.factor_ops.evidence_select."""
    return jnp.take_along_axis(
        x, idx.astype(jnp.int32)[:, None, None], axis=-1)[..., 0]


def cg_weak_marg_ref(logw: jnp.ndarray, mu: jnp.ndarray, sigma: jnp.ndarray
                     ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Oracle for kernels.factor_ops.cg_weak_marg (moment-matched weak
    marginal): collapse the N mixture axis of ``logw [B,M,N]``,
    ``mu [B,M,N,n]``, ``sigma [B,M,N,n,n]`` to a single Gaussian per (B, M)
    preserving total mass and the first two moments.  -inf weights are
    inert; all-dead mixtures return (-inf, 0, I)."""
    import jax.scipy.special as jsp

    n = mu.shape[-1]
    lse = jsp.logsumexp(logw, axis=-1, keepdims=True)
    safe = jnp.where(jnp.isneginf(lse), 0.0, lse)
    w = jnp.where(jnp.isneginf(logw), 0.0, jnp.exp(logw - safe))
    mu_hat = (w[..., None] * mu).sum(-2)
    second = (w[..., None, None]
              * (sigma + mu[..., :, None] * mu[..., None, :])).sum(-3)
    sigma_hat = second - mu_hat[..., :, None] * mu_hat[..., None, :]
    logp = lse[..., 0]
    dead = jnp.isneginf(logp)
    mu_hat = jnp.where(dead[..., None], 0.0, mu_hat)
    sigma_hat = jnp.where(dead[..., None, None], jnp.eye(n), sigma_hat)
    return logp, mu_hat, sigma_hat
