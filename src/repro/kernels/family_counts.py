"""Pallas TPU kernel for batched family-count reduction (structure learning).

Score-based structure search (``repro.learn_structure``) is dominated by
counting: every candidate family (child, parent set) needs the joint-
configuration counts

    counts[m, c] = sum_n w[n] [ code(x[n], family m) == c ]

where ``code`` is the mixed-radix flattening of the family's (child,
parents) columns.  Because the radix weights are per-family constants, the
code of instance n under family m is a plain dot product

    code[n, m] = sum_f strides[m, f] * xd[n, f]

(``strides[m, f] = 0`` for columns outside the family), so ONE pass over
the instances scores every candidate family at once: grid (M,
n_instance_blocks) with the instance dim minor (sequential), the [bn, Fd] x
[Fd] code dot on the MXU and the [C] count accumulator in VMEM scratch —
the same tiling scheme as ``clg_stats.clg_disc_counts``.

Same compile/interpret policy as the other kernels
(``clg_stats._resolve_interpret``).  Oracle: ``repro.kernels.ref.
family_counts_ref``; jit'd wrapper: ``repro.kernels.ops.family_counts``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.clg_stats import _resolve_interpret


def _kernel(xd_ref, s_ref, w_ref, out_ref, acc_scr, *, nb: int, C: int):
    bi = pl.program_id(1)

    @pl.when(bi == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    xd = xd_ref[...].astype(jnp.float32)       # [bn, Fd]
    s = s_ref[...].astype(jnp.float32)         # [1, Fd]  (family m's strides)
    w = w_ref[...].astype(jnp.float32)         # [bn]
    # mixed-radix flat configuration code of every instance under family m:
    # integer-valued floats, exact well past any practical config count
    code = jax.lax.dot_general(
        xd, s, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)    # [bn, 1]
    cols = jax.lax.broadcasted_iota(jnp.float32, (xd.shape[0], C), 1)
    onehot = (cols == code).astype(jnp.float32)            # [bn, C]
    acc_scr[...] += (onehot * w[:, None]).sum(0)           # [C]

    @pl.when(bi == nb - 1)
    def _final():
        out_ref[0] = acc_scr[...]


def family_counts(xd: jnp.ndarray, strides: jnp.ndarray, w: jnp.ndarray,
                  C: int, *, block: int = 512,
                  interpret: Optional[bool] = None) -> jnp.ndarray:
    """xd: [N, Fd] int discrete columns; strides: [M, Fd] mixed-radix
    weights (0 outside the family); w: [N] instance weights/mask.

    Returns counts [M, C] — the weighted joint-configuration histogram of
    every candidate family in one pass over the instances.  Configurations
    beyond a family's true size (its code range is a prefix of [0, C)) stay
    exactly zero (oracle: kernels.ref.family_counts_ref).
    """
    interpret = _resolve_interpret(interpret)
    N, Fd = xd.shape
    M = strides.shape[0]
    block = min(block, N)
    nb = pl.cdiv(N, block)
    pad = nb * block - N
    if pad:
        # padded instances carry w = 0: their (valid) code 0 adds nothing
        xd = jnp.pad(xd, ((0, pad), (0, 0)))
        w = jnp.pad(w, (0, pad))

    return pl.pallas_call(
        functools.partial(_kernel, nb=nb, C=C),
        grid=(M, nb),
        in_specs=[
            pl.BlockSpec((block, Fd), lambda m, bi: (bi, 0)),
            pl.BlockSpec((1, Fd), lambda m, bi: (m, 0)),
            pl.BlockSpec((block,), lambda m, bi: (bi,)),
        ],
        out_specs=pl.BlockSpec((1, C), lambda m, bi: (m, 0)),
        out_shape=jax.ShapeDtypeStruct((M, C), jnp.float32),
        scratch_shapes=[pltpu.VMEM((C,), jnp.float32)],
        interpret=interpret,
    )(xd.astype(jnp.int32), strides.astype(jnp.int32), w)
