"""Pallas TPU kernel for the VMP E-step hot loop: CLG expected suff stats.

This is the paper's own compute kernel (DESIGN.md §6): for every continuous
leaf f and mixture component k, d-VMP reduces over (potentially millions
of) instances

    sxx[f,k] = sum_n r[n,k] d[n,f,:] d[n,f,:]^T      [D, D]
    sxy[f,k] = sum_n r[n,k] d[n,f,:] y[n,f]          [D]
    syy[f,k] = sum_n r[n,k] y[n,f]^2                 []

TPU mapping: grid (F, K, n_instance_blocks) with the instance dim minor
(sequential), accumulating the [D, D] tile in VMEM scratch; the inner
products are [D, bn] x [bn, D] MXU matmuls.  The per-shard result is the
psum payload of dvmp (one message pytree per sweep).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(d_ref, y_ref, r_ref, sxx_ref, sxy_ref, syy_ref,
            sxx_scr, sxy_scr, syy_scr, *, nb: int):
    bi = pl.program_id(2)

    @pl.when(bi == 0)
    def _init():
        sxx_scr[...] = jnp.zeros_like(sxx_scr)
        sxy_scr[...] = jnp.zeros_like(sxy_scr)
        syy_scr[...] = jnp.zeros_like(syy_scr)

    d = d_ref[0].astype(jnp.float32)          # [bn, D]
    y = y_ref[0].astype(jnp.float32)          # [bn]
    r = r_ref[0].astype(jnp.float32)          # [bn]  (component k's column)

    dw = d * r[:, None]                       # [bn, D]
    sxx_scr[...] += jax.lax.dot_general(
        dw, d, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)   # [D, D]
    sxy_scr[...] += (dw * y[:, None]).sum(0)  # [D]
    syy_scr[0] += (r * y * y).sum()

    @pl.when(bi == nb - 1)
    def _final():
        sxx_ref[0, 0] = sxx_scr[...]
        sxy_ref[0, 0] = sxy_scr[...]
        syy_ref[0, 0] = syy_scr[0]


def clg_suffstats(d: jnp.ndarray, y: jnp.ndarray, r: jnp.ndarray, *,
                  block: int = 512, interpret: bool = True
                  ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """d: [N, F, D] design vectors; y: [N, F]; r: [N, K] responsibilities.

    Returns (sxx [F, K, D, D], sxy [F, K, D], syy [F, K]) — the RegSuffStats
    triple of repro.core.expfam (oracle: kernels.ref.clg_suffstats_ref).
    """
    N, F, D = d.shape
    K = r.shape[1]
    block = min(block, N)
    nb = pl.cdiv(N, block)
    pad = nb * block - N
    if pad:
        d = jnp.pad(d, ((0, pad), (0, 0), (0, 0)))
        y = jnp.pad(y, ((0, pad), (0, 0)))
        r = jnp.pad(r, ((0, pad), (0, 0)))

    # feature-major layouts
    df = jnp.moveaxis(d, 1, 0)                # [F, N, D]
    yf = jnp.moveaxis(y, 1, 0)                # [F, N]
    rk = jnp.moveaxis(r, 1, 0)                # [K, N]

    sxx, sxy, syy = pl.pallas_call(
        functools.partial(_kernel, nb=nb),
        grid=(F, K, nb),
        in_specs=[
            pl.BlockSpec((1, block, D), lambda f, k, bi: (f, bi, 0)),
            pl.BlockSpec((1, block), lambda f, k, bi: (f, bi)),
            pl.BlockSpec((1, block), lambda f, k, bi: (k, bi)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, D, D), lambda f, k, bi: (f, k, 0, 0)),
            pl.BlockSpec((1, 1, D), lambda f, k, bi: (f, k, 0)),
            pl.BlockSpec((1, 1), lambda f, k, bi: (f, k)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((F, K, D, D), jnp.float32),
            jax.ShapeDtypeStruct((F, K, D), jnp.float32),
            jax.ShapeDtypeStruct((F, K), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((D, D), jnp.float32),
            pltpu.VMEM((D,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
        interpret=interpret,
    )(df, yf, rk)
    return sxx, sxy, syy
