"""Pallas TPU kernels for the VMP E-step hot loop: CLG expected suff stats.

This is the paper's own compute kernel (DESIGN.md §6): for every continuous
leaf f and mixture component k, d-VMP reduces over (potentially millions
of) instances

    sxx[f,k] = sum_n r[n,k] d[n,f,:] d[n,f,:]^T      [D, D]
    sxy[f,k] = sum_n r[n,k] d[n,f,:] y[n,f]          [D]
    syy[f,k] = sum_n r[n,k] y[n,f]^2                 []

and, for every discrete leaf and component, the one-hot count reduction

    disc[f,k,c] = sum_n r[n,k] [x[n,f] == c]         [C]

TPU mapping: grid (F, K, n_instance_blocks) with the instance dim minor
(sequential), accumulating the [D, D] tile in VMEM scratch; the inner
products are [D, bn] x [bn, D] MXU matmuls.  The per-shard result is the
psum payload of dvmp (one message pytree per sweep).

``interpret=None`` (the default) compiles the kernel natively when the
default jax backend is a TPU (or ``REPRO_PALLAS_COMPILE=1`` forces it) and
falls back to interpret mode on CPU — same policy as the factor-algebra
kernels behind ``repro.kernels.ops.INTERPRET``.

Oracles: ``repro.kernels.ref.{clg_suffstats_ref,clg_disc_counts_ref}``.
"""

from __future__ import annotations

import functools
import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _resolve_interpret(interpret: Optional[bool]) -> bool:
    """Compiled on TPU (or forced via REPRO_PALLAS_COMPILE=1); interpret
    elsewhere — CPU Pallas has no Mosaic lowering for these kernels.
    ``REPRO_PALLAS_INTERPRET=1`` forces interpret mode everywhere (wins
    over COMPILE): the CI parity leg runs the kernel suite once under each
    policy so the TPU-compiled path cannot silently diverge from the
    interpret semantics the CPU container tests."""
    if interpret is not None:
        return interpret
    if os.environ.get("REPRO_PALLAS_INTERPRET", "0") == "1":
        return True
    if os.environ.get("REPRO_PALLAS_COMPILE", "0") == "1":
        return False
    return jax.default_backend() != "tpu"


def _kernel(d_ref, y_ref, r_ref, sxx_ref, sxy_ref, syy_ref,
            sxx_scr, sxy_scr, syy_scr, *, nb: int):
    bi = pl.program_id(2)

    @pl.when(bi == 0)
    def _init():
        sxx_scr[...] = jnp.zeros_like(sxx_scr)
        sxy_scr[...] = jnp.zeros_like(sxy_scr)
        syy_scr[...] = jnp.zeros_like(syy_scr)

    d = d_ref[0].astype(jnp.float32)          # [bn, D]
    y = y_ref[0].astype(jnp.float32)          # [bn]
    r = r_ref[0].astype(jnp.float32)          # [bn]  (component k's column)

    dw = d * r[:, None]                       # [bn, D]
    sxx_scr[...] += jax.lax.dot_general(
        dw, d, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)   # [D, D]
    sxy_scr[...] += (dw * y[:, None]).sum(0)  # [D]
    syy_scr[0] += (r * y * y).sum()

    @pl.when(bi == nb - 1)
    def _final():
        sxx_ref[0, 0] = sxx_scr[...]
        sxy_ref[0, 0] = sxy_scr[...]
        syy_ref[0, 0] = syy_scr[0]


def clg_suffstats(d: jnp.ndarray, y: jnp.ndarray, r: jnp.ndarray, *,
                  block: int = 512, interpret: Optional[bool] = None
                  ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """d: [N, F, D] design vectors; y: [N, F]; r: [N, K] responsibilities.

    Returns (sxx [F, K, D, D], sxy [F, K, D], syy [F, K]) — the RegSuffStats
    triple of repro.core.expfam (oracle: kernels.ref.clg_suffstats_ref).
    """
    interpret = _resolve_interpret(interpret)
    N, F, D = d.shape
    K = r.shape[1]
    block = min(block, N)
    nb = pl.cdiv(N, block)
    pad = nb * block - N
    if pad:
        d = jnp.pad(d, ((0, pad), (0, 0), (0, 0)))
        y = jnp.pad(y, ((0, pad), (0, 0)))
        r = jnp.pad(r, ((0, pad), (0, 0)))

    # feature-major layouts
    df = jnp.moveaxis(d, 1, 0)                # [F, N, D]
    yf = jnp.moveaxis(y, 1, 0)                # [F, N]
    rk = jnp.moveaxis(r, 1, 0)                # [K, N]

    sxx, sxy, syy = pl.pallas_call(
        functools.partial(_kernel, nb=nb),
        grid=(F, K, nb),
        in_specs=[
            pl.BlockSpec((1, block, D), lambda f, k, bi: (f, bi, 0)),
            pl.BlockSpec((1, block), lambda f, k, bi: (f, bi)),
            pl.BlockSpec((1, block), lambda f, k, bi: (k, bi)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, D, D), lambda f, k, bi: (f, k, 0, 0)),
            pl.BlockSpec((1, 1, D), lambda f, k, bi: (f, k, 0)),
            pl.BlockSpec((1, 1), lambda f, k, bi: (f, k)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((F, K, D, D), jnp.float32),
            jax.ShapeDtypeStruct((F, K, D), jnp.float32),
            jax.ShapeDtypeStruct((F, K), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((D, D), jnp.float32),
            pltpu.VMEM((D,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
        interpret=interpret,
    )(df, yf, rk)
    return sxx, sxy, syy


def _latent_kernel(o_ref, hm_ref, y_ref, r_ref, shh_ref,
                   sxx_ref, sxy_ref, syy_ref,
                   sxx_scr, sxy_scr, syy_scr, rsum_scr, *,
                   nb: int, Do: int, L: int):
    bi = pl.program_id(2)

    @pl.when(bi == 0)
    def _init():
        sxx_scr[...] = jnp.zeros_like(sxx_scr)
        sxy_scr[...] = jnp.zeros_like(sxy_scr)
        syy_scr[...] = jnp.zeros_like(syy_scr)
        rsum_scr[...] = jnp.zeros_like(rsum_scr)

    o = o_ref[0].astype(jnp.float32)          # [bn, Do]  (leaf f's design)
    hm = hm_ref[0].astype(jnp.float32)        # [bn, L]   (component k's E[h])
    y = y_ref[0].astype(jnp.float32)          # [bn]
    r = r_ref[0].astype(jnp.float32)          # [bn]

    u = jnp.concatenate([o, hm], axis=1)      # [bn, D] component-major design
    uw = u * r[:, None]
    sxx_scr[...] += jax.lax.dot_general(
        uw, u, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)   # [D, D]
    sxy_scr[...] += (uw * y[:, None]).sum(0)  # [D]
    syy_scr[0] += (r * y * y).sum()
    rsum_scr[0] += r.sum()

    @pl.when(bi == nb - 1)
    def _final():
        # E[hh^T | z=k] = S_k + E[h]E[h]^T: the outer products above cover the
        # mean part; the instance-independent covariance enters as rsum * S_k
        # padded into the latent-latent block.
        D = Do + L
        corr = jnp.zeros((D, D), jnp.float32)
        corr = corr.at[Do:, Do:].set(shh_ref[0])
        sxx_ref[0, 0] = sxx_scr[...] + rsum_scr[0] * corr
        sxy_ref[0, 0] = sxy_scr[...]
        syy_ref[0, 0] = syy_scr[0]


def clg_suffstats_latent(obs: jnp.ndarray, h_mean: jnp.ndarray,
                         y: jnp.ndarray, r: jnp.ndarray, s_hh: jnp.ndarray, *,
                         block: int = 512, interpret: Optional[bool] = None
                         ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused latent-plate (FA/PPCA) suff-stats: component-major designs.

    obs: [N, F, Do] observed design vectors; h_mean: [N, K, L] per-component
    posterior means E[h | z=k]; y: [N, F]; r: [N, K]; s_hh: [K, L, L] the
    shared posterior covariance S_k of q(H | z=k) (so
    E[hh^T | z=k] = S_k + E[h]E[h]^T).

    Returns the FULL regression-moment triple over the concatenated design
    d[n,f,k] = [obs[n,f], E[h|z=k]] with the E[hh^T] covariance correction
    folded into the latent-latent block:

        sxx [F, K, D, D], sxy [F, K, D], syy [F, K],  D = Do + L

    One pass over instances; nothing [N, K, L, L]-shaped is ever formed
    (oracle: kernels.ref.clg_suffstats_latent_ref).
    """
    interpret = _resolve_interpret(interpret)
    N, F, Do = obs.shape
    K, L = h_mean.shape[1], h_mean.shape[2]
    D = Do + L
    block = min(block, N)
    nb = pl.cdiv(N, block)
    pad = nb * block - N
    if pad:
        obs = jnp.pad(obs, ((0, pad), (0, 0), (0, 0)))
        h_mean = jnp.pad(h_mean, ((0, pad), (0, 0), (0, 0)))
        y = jnp.pad(y, ((0, pad), (0, 0)))
        r = jnp.pad(r, ((0, pad), (0, 0)))  # r = 0 pads: contribute nothing

    of = jnp.moveaxis(obs, 1, 0)              # [F, N, Do]
    hk = jnp.moveaxis(h_mean, 1, 0)           # [K, N, L]
    yf = jnp.moveaxis(y, 1, 0)                # [F, N]
    rk = jnp.moveaxis(r, 1, 0)                # [K, N]
    shh = jnp.asarray(s_hh, jnp.float32)      # [K, L, L]

    sxx, sxy, syy = pl.pallas_call(
        functools.partial(_latent_kernel, nb=nb, Do=Do, L=L),
        grid=(F, K, nb),
        in_specs=[
            pl.BlockSpec((1, block, Do), lambda f, k, bi: (f, bi, 0)),
            pl.BlockSpec((1, block, L), lambda f, k, bi: (k, bi, 0)),
            pl.BlockSpec((1, block), lambda f, k, bi: (f, bi)),
            pl.BlockSpec((1, block), lambda f, k, bi: (k, bi)),
            pl.BlockSpec((1, L, L), lambda f, k, bi: (k, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, D, D), lambda f, k, bi: (f, k, 0, 0)),
            pl.BlockSpec((1, 1, D), lambda f, k, bi: (f, k, 0)),
            pl.BlockSpec((1, 1), lambda f, k, bi: (f, k)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((F, K, D, D), jnp.float32),
            jax.ShapeDtypeStruct((F, K, D), jnp.float32),
            jax.ShapeDtypeStruct((F, K), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((D, D), jnp.float32),
            pltpu.VMEM((D,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
        interpret=interpret,
    )(of, hk, yf, rk, shh)
    return sxx, sxy, syy


def _disc_kernel(x_ref, r_ref, out_ref, acc_scr, *, nb: int, C: int):
    bi = pl.program_id(2)

    @pl.when(bi == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[0]                              # [bn] int32
    r = r_ref[0].astype(jnp.float32)          # [bn]
    cols = jax.lax.broadcasted_iota(jnp.int32, (x.shape[0], C), 1)
    onehot = (cols == x[:, None]).astype(jnp.float32)      # [bn, C]
    acc_scr[...] += (onehot * r[:, None]).sum(0)           # [C]

    @pl.when(bi == nb - 1)
    def _final():
        out_ref[0, 0] = acc_scr[...]


def clg_disc_counts(xd: jnp.ndarray, r: jnp.ndarray, C: int, *,
                    block: int = 512, interpret: Optional[bool] = None
                    ) -> jnp.ndarray:
    """xd: [N, Fd] int discrete leaves; r: [N, K] responsibilities.

    Returns disc [Fd, K, C] — the weighted one-hot reduction
    ``sum_n r[n,k] onehot(xd[n,f], C)`` that completes the d-VMP message
    pytree (oracle: kernels.ref.clg_disc_counts_ref).  Same tiling scheme as
    :func:`clg_suffstats`: grid (Fd, K, n_blocks), instance dim sequential,
    [C] accumulator in VMEM scratch.
    """
    interpret = _resolve_interpret(interpret)
    N, Fd = xd.shape
    K = r.shape[1]
    block = min(block, N)
    nb = pl.cdiv(N, block)
    pad = nb * block - N
    if pad:
        # padded instances get category -1: matches no iota column -> 0 count
        xd = jnp.pad(xd, ((0, pad), (0, 0)), constant_values=-1)
        r = jnp.pad(r, ((0, pad), (0, 0)))

    xf = jnp.moveaxis(xd.astype(jnp.int32), 1, 0)          # [Fd, N]
    rk = jnp.moveaxis(r, 1, 0)                             # [K, N]

    return pl.pallas_call(
        functools.partial(_disc_kernel, nb=nb, C=C),
        grid=(Fd, K, nb),
        in_specs=[
            pl.BlockSpec((1, block), lambda f, k, bi: (f, bi)),
            pl.BlockSpec((1, block), lambda f, k, bi: (k, bi)),
        ],
        out_specs=pl.BlockSpec((1, 1, C), lambda f, k, bi: (f, k, 0)),
        out_shape=jax.ShapeDtypeStruct((Fd, K, C), jnp.float32),
        scratch_shapes=[pltpu.VMEM((C,), jnp.float32)],
        interpret=interpret,
    )(xf, rk)
