"""Jit'd public wrappers for the Pallas kernels.

``INTERPRET`` follows one policy for every kernel (clg_stats.
_resolve_interpret): compiled natively when the default jax backend is a
TPU or ``REPRO_PALLAS_COMPILE=1`` forces it, interpret mode (python
semantics of the same kernel body) elsewhere — e.g. this CPU container.

Every wrapper is counted (``obs.count_kernel``, host side, OUTSIDE the
jit boundary — the jitted program itself is unchanged): when obs is
enabled, each call bumps a ``<kernel>:<pallas|interpret>`` dispatch
counter, snapshotted into ``kernel_dispatch`` JSONL events by the
streaming and serving drivers.
"""

from __future__ import annotations

import functools
from functools import partial

import jax

from repro.kernels.clg_stats import (_resolve_interpret,
                                     clg_disc_counts as _clg_disc,
                                     clg_suffstats as _clg,
                                     clg_suffstats_latent as _clg_latent)
from repro.kernels.family_counts import family_counts as _famcounts
from repro.kernels.factor_ops import (cg_weak_marg as _cgweak,
                                      evidence_select as _evsel,
                                      log_marginalize as _logmarg,
                                      log_product as _logprod)
from repro.kernels.flash_attn import flash_attention as _flash
from repro.kernels.ssd_scan import ssd_scan as _ssd
from repro.obs import sink as obs_sink

INTERPRET = _resolve_interpret(None)
_MODE = "interpret" if INTERPRET else "pallas"


def _counted(kernel: str):
    """Host-side dispatch counter around a jitted kernel wrapper."""
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            obs_sink.count_kernel(f"{kernel}:{_MODE}")
            return fn(*args, **kwargs)
        return wrapper
    return deco


@_counted("flash_attention")
@partial(jax.jit, static_argnames=("causal", "window", "bq", "bk"))
def flash_attention(q, k, v, *, causal=True, window=None, bq=128, bk=128):
    return _flash(q, k, v, causal=causal, window=window, bq=bq, bk=bk,
                  interpret=INTERPRET)


@_counted("ssd_scan")
@partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x, dt, A, B, C, chunk=128):
    return _ssd(x, dt, A, B, C, chunk, interpret=INTERPRET)


@_counted("clg_suffstats")
@partial(jax.jit, static_argnames=("block",))
def clg_suffstats(d, y, r, *, block=512):
    return _clg(d, y, r, block=block, interpret=INTERPRET)


@_counted("clg_seq_suffstats")
@partial(jax.jit, static_argnames=("block",))
def clg_seq_suffstats(d, y, r, *, block=512):
    """Sequence-batch CLG suff-stats: flattens the ``[B, T]`` leading dims
    of ``d [B,T,F,D] / y [B,T,F] / r [B,T,K]`` into the kernel's instance
    axis and dispatches one ``clg_suffstats`` call — the temporal
    (``pgm_models.dynamic``) entry to the same pallas/interpret kernel the
    static plate uses.  Masking is the caller's job: zero ``r`` rows
    contribute nothing."""
    B, T = r.shape[0], r.shape[1]
    return _clg(d.reshape(B * T, *d.shape[2:]), y.reshape(B * T, *y.shape[2:]),
                r.reshape(B * T, r.shape[2]), block=block, interpret=INTERPRET)


@_counted("clg_suffstats_latent")
@partial(jax.jit, static_argnames=("block",))
def clg_suffstats_latent(obs, h_mean, y, r, s_hh, *, block=512):
    return _clg_latent(obs, h_mean, y, r, s_hh, block=block,
                       interpret=INTERPRET)


@_counted("clg_disc_counts")
@partial(jax.jit, static_argnames=("C", "block"))
def clg_disc_counts(xd, r, C, *, block=512):
    return _clg_disc(xd, r, C, block=block, interpret=INTERPRET)


@_counted("family_counts")
@partial(jax.jit, static_argnames=("C", "block"))
def family_counts(xd, strides, w, C, *, block=512):
    return _famcounts(xd, strides, w, C, block=block, interpret=INTERPRET)


@_counted("log_product")
@partial(jax.jit, static_argnames=("bm",))
def log_product(a, b, *, bm=256):
    return _logprod(a, b, bm=bm, interpret=INTERPRET)


@_counted("log_marginalize")
@partial(jax.jit, static_argnames=("bm", "bn"))
def log_marginalize(x, *, bm=256, bn=256):
    return _logmarg(x, bm=bm, bn=bn, interpret=INTERPRET)


@_counted("evidence_select")
@partial(jax.jit, static_argnames=("bm",))
def evidence_select(x, idx, *, bm=256):
    return _evsel(x, idx, bm=bm, interpret=INTERPRET)


@_counted("cg_weak_marg")
@partial(jax.jit, static_argnames=("bm",))
def cg_weak_marg(logw, mu, sigma, *, bm=64):
    return _cgweak(logw, mu, sigma, bm=bm, interpret=INTERPRET)
