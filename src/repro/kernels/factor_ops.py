"""Pallas TPU kernels for batched log-space factor algebra (infer_exact).

The factor algebra of ``repro.infer_exact.factors`` flattens every table
over a discrete scope ``(v_1..v_k)`` to ``[B, M, N]`` where ``B`` is the
evidence-batch axis (many query instances propagate in ONE device call),
``N`` the product of the cardinalities being acted on (marginalized /
shared with the sepset / indexed by evidence) and ``M`` the product of the
remaining axes:

    log_product(a [B,M,N], b [B,N])   -> [B,M,N]   factor product (log add)
    log_marginalize(x [B,M,N])        -> [B,M]     stable logsumexp over N
    evidence_select(x [B,M,N], i [B]) -> [B,M]     per-instance evidence slice

``log_product`` and ``log_marginalize`` back the two message-passing hot
loops (sepset absorption, marginalization onto a sepset).
``evidence_select`` backs ``factors.reduce_evidence`` — the shrink-style
evidence reduction of the algebra layer; the default engine path folds
evidence as indicator factors instead, keeping clique shapes static per
evidence schema.

``log_marginalize`` uses the flash-attention style running-max/rescale
accumulation over N tiles so arbitrarily wide factors stream through VMEM.
All three tolerate ``-inf`` entries (structural zeros from evidence
indicators) without producing NaNs.

Oracles: ``repro.kernels.ref.{log_product_ref,log_marginalize_ref,
evidence_select_ref}``.  Jit'd public wrappers: ``repro.kernels.ops``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


# ---------------------------------------------------------------------------
# log_product: a [B, M, N] + b [B, N] broadcast over M
# ---------------------------------------------------------------------------


def _product_kernel(a_ref, b_ref, o_ref):
    o_ref[0] = a_ref[0] + b_ref[0][None, :]


def log_product(a: jnp.ndarray, b: jnp.ndarray, *, bm: int = 256,
                interpret: bool = True) -> jnp.ndarray:
    """Log-space factor product of ``a`` with a sepset factor ``b``."""
    B, M, N = a.shape
    bm = min(bm, M)
    nm = pl.cdiv(M, bm)
    pad_m = nm * bm - M
    if pad_m:
        a = jnp.pad(a, ((0, 0), (0, pad_m), (0, 0)))
    out = pl.pallas_call(
        _product_kernel,
        grid=(B, nm),
        in_specs=[
            pl.BlockSpec((1, bm, N), lambda b_, mi: (b_, mi, 0)),
            pl.BlockSpec((1, N), lambda b_, mi: (b_, 0)),
        ],
        out_specs=pl.BlockSpec((1, bm, N), lambda b_, mi: (b_, mi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, nm * bm, N), jnp.float32),
        interpret=interpret,
    )(a.astype(jnp.float32), b.astype(jnp.float32))
    return out[:, :M]


# ---------------------------------------------------------------------------
# log_marginalize: stable streaming logsumexp over the last axis
# ---------------------------------------------------------------------------


def _marginalize_kernel(x_ref, o_ref, m_scr, s_scr, *, nn: int):
    ni = pl.program_id(2)

    @pl.when(ni == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        s_scr[...] = jnp.zeros_like(s_scr)

    x = x_ref[0].astype(jnp.float32)           # [bm, bn]
    m_prev = m_scr[...]                        # [bm]
    m_new = jnp.maximum(m_prev, x.max(-1))
    # safe center: where the running max is still -inf every exp() below is 0
    ms = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    corr = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - ms), 0.0)
    s_scr[...] = s_scr[...] * corr + jnp.exp(x - ms[:, None]).sum(-1)
    m_scr[...] = m_new

    @pl.when(ni == nn - 1)
    def _final():
        s = s_scr[...]
        ms_f = jnp.where(jnp.isfinite(m_scr[...]), m_scr[...], 0.0)
        o_ref[0] = jnp.where(s > 0.0, ms_f + jnp.log(jnp.maximum(s, 1e-37)),
                             NEG_INF)


def log_marginalize(x: jnp.ndarray, *, bm: int = 256, bn: int = 256,
                    interpret: bool = True) -> jnp.ndarray:
    """logsumexp over the last axis of ``x [B, M, N]`` -> ``[B, M]``."""
    B, M, N = x.shape
    bm, bn = min(bm, M), min(bn, N)
    nm, nn = pl.cdiv(M, bm), pl.cdiv(N, bn)
    pad_m, pad_n = nm * bm - M, nn * bn - N
    if pad_m or pad_n:
        x = jnp.pad(x, ((0, 0), (0, pad_m), (0, pad_n)),
                    constant_values=NEG_INF)
    out = pl.pallas_call(
        functools.partial(_marginalize_kernel, nn=nn),
        grid=(B, nm, nn),
        in_specs=[pl.BlockSpec((1, bm, bn), lambda b_, mi, ni: (b_, mi, ni))],
        out_specs=pl.BlockSpec((1, bm), lambda b_, mi, ni: (b_, mi)),
        out_shape=jax.ShapeDtypeStruct((B, nm * bm), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bm,), jnp.float32),
            pltpu.VMEM((bm,), jnp.float32),
        ],
        interpret=interpret,
    )(x.astype(jnp.float32))
    return out[:, :M]


# ---------------------------------------------------------------------------
# evidence_select: per-batch-instance gather along the last axis
# ---------------------------------------------------------------------------


def _select_kernel(x_ref, i_ref, o_ref):
    x = x_ref[0].astype(jnp.float32)           # [bm, N]
    idx = i_ref[0, 0]
    col = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    o_ref[0] = jnp.where(col == idx, x, NEG_INF).max(-1)


def evidence_select(x: jnp.ndarray, idx: jnp.ndarray, *, bm: int = 256,
                    interpret: bool = True) -> jnp.ndarray:
    """``x [B, M, N], idx [B] int`` -> ``[B, M]`` with ``out[b] = x[b,:,idx[b]]``.

    This is batched evidence reduction: each query instance clamps its own
    observed value, shrinking the factor by one axis in a single device call.
    """
    B, M, N = x.shape
    bm = min(bm, M)
    nm = pl.cdiv(M, bm)
    pad_m = nm * bm - M
    if pad_m:
        x = jnp.pad(x, ((0, 0), (0, pad_m), (0, 0)), constant_values=NEG_INF)
    out = pl.pallas_call(
        _select_kernel,
        grid=(B, nm),
        in_specs=[
            pl.BlockSpec((1, bm, N), lambda b_, mi: (b_, mi, 0)),
            pl.BlockSpec((1, 1), lambda b_, mi: (b_, 0)),
        ],
        out_specs=pl.BlockSpec((1, bm), lambda b_, mi: (b_, mi)),
        out_shape=jax.ShapeDtypeStruct((B, nm * bm), jnp.float32),
        interpret=interpret,
    )(x.astype(jnp.float32), idx.astype(jnp.int32).reshape(B, 1))
    return out[:, :M]


# ---------------------------------------------------------------------------
# cg_weak_marg: moment-matched weak marginal of a CG mixture
# ---------------------------------------------------------------------------


def _weak_marg_kernel(lw_ref, mu_ref, sg_ref, p_ref, mh_ref, sh_ref,
                      *, N: int, n: int):
    lw = lw_ref[0].astype(jnp.float32)              # [bm, N]
    bm = lw.shape[0]
    m = lw.max(-1)                                  # [bm]
    ms = jnp.where(jnp.isfinite(m), m, 0.0)
    w = jnp.where(jnp.isfinite(lw), jnp.exp(lw - ms[:, None]), 0.0)
    s = w.sum(-1)                                   # [bm]
    p_ref[0] = jnp.where(s > 0.0, ms + jnp.log(jnp.maximum(s, 1e-37)),
                         NEG_INF)
    wn = w / jnp.maximum(s, 1e-37)[:, None]         # [bm, N] normalized
    mu = mu_ref[0].astype(jnp.float32).reshape(bm, N, n)
    sg = sg_ref[0].astype(jnp.float32).reshape(bm, N, n, n)
    mu_hat = (wn[:, :, None] * mu).sum(1)           # [bm, n]
    second = (wn[:, :, None, None]
              * (sg + mu[:, :, :, None] * mu[:, :, None, :])).sum(1)
    sg_hat = second - mu_hat[:, :, None] * mu_hat[:, None, :]
    dead = (s <= 0.0)
    eye = jnp.eye(n, dtype=jnp.float32)
    mh_ref[0] = jnp.where(dead[:, None], 0.0, mu_hat)
    sh_ref[0] = jnp.where(dead[:, None, None], eye[None], sg_hat
                          ).reshape(bm, n * n)


def cg_weak_marg(logw: jnp.ndarray, mu: jnp.ndarray, sigma: jnp.ndarray,
                 *, bm: int = 64, interpret: bool = True
                 ) -> tuple:
    """Moment-matching weak marginal: collapse the mixture axis N.

    ``logw [B, M, N]``, ``mu [B, M, N, n]``, ``sigma [B, M, N, n, n]`` ->
    ``(logp [B, M], mu [B, M, n], sigma [B, M, n, n])`` where each (b, m)
    row becomes the single Gaussian matching the mixture's total mass,
    mean and covariance — the distribute-pass hot loop of the strong
    junction tree (Lauritzen 1992 weak marginals).  ``-inf`` weights
    (structural zeros from evidence indicators) are inert; fully dead rows
    yield ``(-inf, 0, I)``.  Oracle: ``repro.kernels.ref.cg_weak_marg_ref``.
    """
    B, M, N = logw.shape
    n = mu.shape[-1]
    bm = min(bm, M)
    nm = pl.cdiv(M, bm)
    pad_m = nm * bm - M
    if pad_m:
        logw = jnp.pad(logw, ((0, 0), (0, pad_m), (0, 0)),
                       constant_values=NEG_INF)
        mu = jnp.pad(mu, ((0, 0), (0, pad_m), (0, 0), (0, 0)))
        sigma = jnp.pad(sigma, ((0, 0), (0, pad_m), (0, 0), (0, 0), (0, 0)))
    mu2 = mu.reshape(B, nm * bm, N * n)
    sg2 = sigma.reshape(B, nm * bm, N * n * n)
    p, mh, sh = pl.pallas_call(
        functools.partial(_weak_marg_kernel, N=N, n=n),
        grid=(B, nm),
        in_specs=[
            pl.BlockSpec((1, bm, N), lambda b_, mi: (b_, mi, 0)),
            pl.BlockSpec((1, bm, N * n), lambda b_, mi: (b_, mi, 0)),
            pl.BlockSpec((1, bm, N * n * n), lambda b_, mi: (b_, mi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bm), lambda b_, mi: (b_, mi)),
            pl.BlockSpec((1, bm, n), lambda b_, mi: (b_, mi, 0)),
            pl.BlockSpec((1, bm, n * n), lambda b_, mi: (b_, mi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, nm * bm), jnp.float32),
            jax.ShapeDtypeStruct((B, nm * bm, n), jnp.float32),
            jax.ShapeDtypeStruct((B, nm * bm, n * n), jnp.float32),
        ],
        interpret=interpret,
    )(logw.astype(jnp.float32), mu2.astype(jnp.float32),
      sg2.astype(jnp.float32))
    return (p[:, :M], mh[:, :M].reshape(B, M, n),
            sh[:, :M].reshape(B, M, n, n))
