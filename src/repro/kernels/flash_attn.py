"""Pallas TPU flash-attention kernel (GQA, causal, sliding-window).

TPU adaptation (DESIGN.md §6): q/k/v blocks live in VMEM; the grid is
(batch*q_heads, q_blocks, kv_blocks) with the kv dimension iterated
sequentially (TPU grid semantics), so the streaming-softmax accumulators
(m, l, acc) persist in VMEM scratch across kv steps — the same recurrence
as ``nn.attention.attention_blockwise`` but with explicit tiling:

  * block shapes (BQ, D) / (BK, D) with BQ=BK=128 and D the head dim —
    the QK^T and PV matmuls are [128, D] x [D, 128] / [128, 128] x
    [128, D]: MXU-aligned for every assigned head_dim (64..256).
  * causal + sliding-window masking via iota comparison inside the block;
    fully-masked kv blocks are skipped with @pl.when (the TPU equivalent
    of the CUDA early-exit).

GQA is handled in the index maps: q head h reads kv head h % Hkv
(the framework's G-major fold), so K/V are never materialized per-q-head.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: Optional[int],
            bq: int, bk: int, nk: int, seq_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * bq
    k_start = ki * bk
    # skip kv blocks entirely above the causal diagonal / below the window
    run = jnp.asarray(True)
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + bq - 1)
    if window is not None:
        run = jnp.logical_and(run, k_start + bk - 1 > q_start - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # [bq, D]
        k = k_ref[0].astype(jnp.float32)                  # [bk, D]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # [bq,bk]
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        ok = kpos < seq_k
        if causal:
            ok = jnp.logical_and(ok, kpos <= qpos)
        if window is not None:
            ok = jnp.logical_and(ok, kpos > qpos - window)
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_scr[...]                               # [bq]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: Optional[int] = None,
                    scale: Optional[float] = None, bq: int = 128,
                    bk: int = 128, interpret: bool = True) -> jnp.ndarray:
    """q: [B, Sq, Hq, D]; k/v: [B, Sk, Hkv, D] -> [B, Sq, Hq, D].

    G-major GQA: q head h uses kv head h % Hkv (matches nn.attention).
    ``interpret=True`` runs the kernel body in python on CPU (this
    container); on TPU pass interpret=False.
    """
    B, Sq, Hq, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    scale = scale or 1.0 / math.sqrt(D)
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    nq = pl.cdiv(Sq, bq)
    nk = pl.cdiv(Sk, bk)
    # layout: heads major so blocks are [1, bq, D] contiguous per (b, h)
    qh = jnp.moveaxis(q, 2, 1).reshape(B * Hq, Sq, D)
    kh = jnp.moveaxis(k, 2, 1).reshape(B * Hkv, Sk, D)
    vh = jnp.moveaxis(v, 2, 1).reshape(B * Hkv, Sk, D)

    def q_map(bh, qi, ki):
        return (bh, qi, 0)

    def kv_map(bh, qi, ki):
        b = bh // Hq
        h = bh % Hq
        return (b * Hkv + h % Hkv, ki, 0)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, window=window,
                          bq=bq, bk=bk, nk=nk, seq_k=Sk),
        grid=(B * Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, D), q_map),
            pl.BlockSpec((1, bk, D), kv_map),
            pl.BlockSpec((1, bk, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, D), q_map),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(qh, kh, vh)
    return jnp.moveaxis(out.reshape(B, Hq, Sq, D), 1, 2)
