"""The plan API — "compile a plan" decoupled from "run a plan".

The serving tier's central abstraction (ROADMAP: "refactors that decouple
'compile a plan' from 'run a plan' in ``infer_exact/engine.py`` and
``serve/engine.py`` count toward this").  Three public names:

* :class:`PlanKey` — the identity of one compiled device program:
  ``(network_version, mode, schema, batch_shape, dtypes)``.  Everything
  shape- or model-affecting is in the key, so a key either resolves to a
  program that can serve the batch as-is or to nothing.  The
  ``network_version`` field is what makes hot model swap safe: a re-learnt
  network publishes under a new version, old-version plans simply stop
  hitting and age out of the LRU.

* :class:`CompiledPlan` — a compiled program plus its bookkeeping
  (compile wall time, run/hit counters).  ``plan.run(*args)`` dispatches;
  the plan never recompiles.

* :class:`PlanCache` — a bounded LRU from :class:`PlanKey` to
  :class:`CompiledPlan` with hit/miss/eviction counters.
  ``cache.get(key)`` returns the plan or ``None``; ``cache.get(key,
  build)`` compiles-and-inserts on miss (``build()`` returns the raw
  callable; the cache times it).  One cache instance is shared by every
  mode of a :class:`~repro.serve.engine.PGMQueryEngine` — exact-JT, vmp
  and temporal plans coexist, distinguished by ``PlanKey.mode``.

All methods are thread-safe: the async serving tier compiles from its
worker thread while a hot swap warms plans from another.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro import obs


@dataclasses.dataclass(frozen=True)
class PlanKey:
    """Identity of one compiled serving program.

    network_version  monotone int published by hot model swap; plans for
                     superseded versions never hit again
    mode             pipeline family: "jt-discrete" | "jt-strong" | "vmp"
                     | "temporal" | ...
    schema           the evidence schema (sorted observed-variable names;
                     value-carrying buckets encode values, e.g. "T16")
    batch_shape      device batch shape the program was compiled for
                     (leading dim is the pow2-padded capacity)
    dtypes           input dtypes, as strings
    """

    network_version: int
    mode: str
    schema: Tuple[str, ...]
    batch_shape: Tuple[int, ...]
    dtypes: Tuple[str, ...] = ()


class CompiledPlan:
    """A compiled program with run bookkeeping.  Built by
    :meth:`PlanCache.get`; ``run`` is the only mutating entry point."""

    __slots__ = ("key", "_fn", "compile_us", "hits", "runs", "created_s")

    def __init__(self, key: PlanKey, fn: Callable[..., Any],
                 compile_us: float = 0.0) -> None:
        self.key = key
        self._fn = fn
        self.compile_us = compile_us
        self.hits = 0          # cache hits (first get-after-compile is not one)
        self.runs = 0
        self.created_s = time.time()

    def run(self, *args: Any, **kw: Any) -> Any:
        """Dispatch the compiled program on a batch."""
        self.runs += 1
        return self._fn(*args, **kw)

    def __repr__(self) -> str:          # pragma: no cover - debugging aid
        return (f"CompiledPlan({self.key.mode}, v{self.key.network_version}, "
                f"schema={','.join(self.key.schema)}, "
                f"batch={self.key.batch_shape}, runs={self.runs})")


class PlanCache:
    """Bounded LRU of :class:`CompiledPlan` with hit/miss counters.

    ``max_plans`` bounds retention — long-lived servers seeing many
    (schema, batch) shapes or many network versions evict least-recently-
    used programs instead of growing without bound.
    """

    def __init__(self, max_plans: int = 128, *, compile_retries: int = 0,
                 retry_backoff_s: float = 0.05) -> None:
        if max_plans < 1:
            raise ValueError("max_plans must be >= 1")
        if compile_retries < 0:
            raise ValueError("compile_retries must be >= 0")
        self.max_plans = max_plans
        self.compile_retries = compile_retries
        self.retry_backoff_s = retry_backoff_s
        # fault injection / test seam: called with the PlanKey before each
        # build attempt; raising simulates a transient compile failure
        self.fault_hook: Optional[Callable[[PlanKey], None]] = None
        self._plans: "OrderedDict[PlanKey, CompiledPlan]" = OrderedDict()
        self._lock = threading.RLock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.retries = 0

    # -- core API ------------------------------------------------------------

    def peek(self, key: PlanKey) -> Optional[CompiledPlan]:
        """Look up without touching counters or LRU order."""
        with self._lock:
            return self._plans.get(key)

    def get(self, key: PlanKey,
            build: Optional[Callable[[], Callable[..., Any]]] = None
            ) -> Optional[CompiledPlan]:
        """Return the plan for ``key``; compile-and-insert on miss.

        A present key counts a hit (and refreshes LRU order).  An absent
        key counts a miss; with ``build`` the raw program is compiled
        (``build()`` — timed, the wall time lands in
        ``plan.compile_us``), wrapped and inserted, evicting the LRU entry
        when the cache is full.  Without ``build`` a miss returns None.
        """
        with self._lock:
            plan = self._plans.get(key)
            if plan is not None:
                self.hits += 1
                plan.hits += 1
                self._plans.move_to_end(key)
                return plan
            self.misses += 1
            if build is None:
                return None
        # compile OUTSIDE the lock: tracing/lowering can take seconds and
        # concurrent readers must not block on it.  A racing second build
        # of the same key loses and is discarded below.  Transient build
        # failures are retried with exponential backoff up to
        # ``compile_retries`` times; an exhausted budget re-raises and
        # leaves NO cache entry, so the next get() retries cleanly.
        attempt = 0
        while True:
            t0 = time.perf_counter_ns()
            try:
                if self.fault_hook is not None:
                    self.fault_hook(key)
                fn = build()
                break
            except Exception as e:
                attempt += 1
                if attempt > self.compile_retries:
                    raise
                with self._lock:
                    self.retries += 1
                if obs.enabled():
                    obs.emit("serve_retry", attempt=attempt,
                             error=type(e).__name__)
                time.sleep(self.retry_backoff_s * (2 ** (attempt - 1)))
        compile_us = (time.perf_counter_ns() - t0) / 1e3
        plan = CompiledPlan(key, fn, compile_us)
        with self._lock:
            won = self._plans.get(key)
            if won is not None:                 # lost the compile race
                return won
            self._plans[key] = plan
            while len(self._plans) > self.max_plans:
                self._plans.popitem(last=False)
                self.evictions += 1
            return plan

    # -- maintenance ---------------------------------------------------------

    def invalidate(self, network_version: Optional[int] = None) -> int:
        """Drop plans for one network version (or all).  Returns the
        number of plans dropped — the hot-swap drain path."""
        with self._lock:
            if network_version is None:
                n = len(self._plans)
                self._plans.clear()
                return n
            drop = [k for k in self._plans
                    if k.network_version == network_version]
            for k in drop:
                del self._plans[k]
            return len(drop)

    def keys(self) -> List[PlanKey]:
        with self._lock:
            return list(self._plans)

    def stats(self) -> Dict[str, float]:
        with self._lock:
            total = self.hits + self.misses
            return {"hits": self.hits, "misses": self.misses,
                    "evictions": self.evictions, "retries": self.retries,
                    "size": len(self._plans), "max_plans": self.max_plans,
                    "hit_rate": (self.hits / total) if total else 0.0}

    def __len__(self) -> int:
        with self._lock:
            return len(self._plans)

    def __contains__(self, key: PlanKey) -> bool:
        with self._lock:
            return key in self._plans
