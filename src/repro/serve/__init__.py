"""Serving tier: the plan/run API (``repro.serve.plan``), the schema-batched
query engines (``repro.serve.engine``) and the async deadline-aware
micro-batching server (``repro.serve.queue``).

The plan names are imported eagerly (they are dependency-free and
``infer_exact`` needs them); the engine/server classes load lazily because
``serve.engine`` pulls in the full ``repro.nn`` stack.
"""

from repro.serve.plan import CompiledPlan, PlanCache, PlanKey

__all__ = ["CompiledPlan", "PlanCache", "PlanKey", "DecodeEngine",
           "PGMQueryEngine", "AsyncPGMServer", "ServeTicket"]

_LAZY = {"DecodeEngine": "repro.serve.engine",
         "PGMQueryEngine": "repro.serve.engine",
         "AsyncPGMServer": "repro.serve.queue",
         "ServeTicket": "repro.serve.queue"}


def __getattr__(name):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(mod), name)
