"""Serving substrate: batched LM decode engine plus the schema-batched
exact-query path (``PGMQueryEngine`` over the infer_exact junction tree)."""
