"""Serving substrate: batched decode engine over the serve_step unit."""
