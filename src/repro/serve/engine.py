"""Batched request serving — the inference-side example driver.

A minimal continuous-batching engine: a fixed batch of request slots decodes
in lock-step (synchronized positions — the layout ``decode_32k``/
``long_500k`` lower); finished requests free their slot for queued prompts.
Slot refill uses teacher-forced prefill via repeated decode steps (simple,
cache-correct); a production system would run a separate prefill graph.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.nn import transformer as T


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class DecodeEngine:
    def __init__(self, params, cfg: ModelConfig, batch: int, capacity: int,
                 sh: T.Shardings = T.NO_SHARD, eos: Optional[int] = None,
                 greedy: bool = True, seed: int = 0):
        self.params, self.cfg, self.sh = params, cfg, sh
        self.batch, self.capacity = batch, capacity
        self.eos = eos
        self.greedy = greedy
        self.key = jax.random.PRNGKey(seed)
        self.state = T.init_decode_state(params, cfg, batch, capacity, sh)
        self.queue: List[Request] = []
        self.active: List[Optional[Request]] = [None] * batch
        self._step = jax.jit(
            lambda st, tok: T.decode_step(params, st, tok, cfg, sh))
        self._pending_prefill: List[List[int]] = [[] for _ in range(batch)]
        self._tok = np.zeros((batch, 1), np.int32)

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _fill_slots(self) -> None:
        for i in range(self.batch):
            if self.active[i] is None and self.queue:
                req = self.queue.pop(0)
                self.active[i] = req
                # prompt tokens are fed one per engine step (lock-step decode)
                self._pending_prefill[i] = list(req.prompt)
                self._tok[i, 0] = self._pending_prefill[i].pop(0) \
                    if self._pending_prefill[i] else 0

    def step(self) -> int:
        """One synchronized decode step for the whole batch.

        Returns the number of active requests."""
        self._fill_slots()
        if not any(self.active):
            return 0
        logits, self.state = self._step(self.state, jnp.asarray(self._tok))
        if self.greedy:
            nxt = np.asarray(logits[:, 0].argmax(-1), np.int32)
        else:
            self.key, sub = jax.random.split(self.key)
            nxt = np.asarray(
                jax.random.categorical(sub, logits[:, 0]), np.int32)
        for i, req in enumerate(self.active):
            if req is None:
                continue
            if self._pending_prefill[i]:
                # still teacher-forcing the prompt
                self._tok[i, 0] = self._pending_prefill[i].pop(0)
                continue
            tok = int(nxt[i])
            req.out.append(tok)
            self._tok[i, 0] = tok
            if (self.eos is not None and tok == self.eos) \
                    or len(req.out) >= req.max_new:
                req.done = True
                self.active[i] = None
        return sum(r is not None for r in self.active)

    def run(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if self.step() == 0 and not self.queue:
                break
