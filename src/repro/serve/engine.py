"""Batched request serving — the inference-side example driver.

Two serving surfaces:

* :class:`DecodeEngine` — LM continuous batching: a fixed batch of request
  slots decodes in lock-step (synchronized positions — the layout
  ``decode_32k``/``long_500k`` lower); finished requests free their slot for
  queued prompts.  Slot refill uses teacher-forced prefill via repeated
  decode steps (simple, cache-correct); a production system would run a
  separate prefill graph.

* :class:`PGMQueryEngine` — the probabilistic-query path.  Queries against a
  CLG ``BayesianNetwork`` queue up and, at ``flush()``, are grouped by
  evidence *schema* (the set of observed variable names).  Each group rides
  the leading batch axis of the junction-tree factor tables, so N exact
  queries sharing a schema cost ONE device call (``mode="exact"``, the
  infer_exact subsystem); ``mode="importance"`` serves the same API from
  the approximate sampler for throughput comparisons.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro import obs
from repro.configs.base import ModelConfig
from repro.data.stream import Batch
from repro.nn import transformer as T
from repro.serve.plan import PlanCache, PlanKey


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class DecodeEngine:
    def __init__(self, params, cfg: ModelConfig, batch: int, capacity: int,
                 sh: T.Shardings = T.NO_SHARD, eos: Optional[int] = None,
                 greedy: bool = True, seed: int = 0):
        self.params, self.cfg, self.sh = params, cfg, sh
        self.batch, self.capacity = batch, capacity
        self.eos = eos
        self.greedy = greedy
        self.key = jax.random.PRNGKey(seed)
        self.state = T.init_decode_state(params, cfg, batch, capacity, sh)
        self.queue: List[Request] = []
        self.active: List[Optional[Request]] = [None] * batch
        self._step = jax.jit(
            lambda st, tok: T.decode_step(params, st, tok, cfg, sh))
        self._pending_prefill: List[List[int]] = [[] for _ in range(batch)]
        self._tok = np.zeros((batch, 1), np.int32)

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _fill_slots(self) -> None:
        for i in range(self.batch):
            if self.active[i] is None and self.queue:
                req = self.queue.pop(0)
                self.active[i] = req
                # prompt tokens are fed one per engine step (lock-step decode)
                self._pending_prefill[i] = list(req.prompt)
                self._tok[i, 0] = self._pending_prefill[i].pop(0) \
                    if self._pending_prefill[i] else 0

    def step(self) -> int:
        """One synchronized decode step for the whole batch.

        Returns the number of active requests."""
        self._fill_slots()
        if not any(self.active):
            return 0
        logits, self.state = self._step(self.state, jnp.asarray(self._tok))
        if self.greedy:
            nxt = np.asarray(logits[:, 0].argmax(-1), np.int32)
        else:
            self.key, sub = jax.random.split(self.key)
            nxt = np.asarray(
                jax.random.categorical(sub, logits[:, 0]), np.int32)
        for i, req in enumerate(self.active):
            if req is None:
                continue
            if self._pending_prefill[i]:
                # still teacher-forcing the prompt
                self._tok[i, 0] = self._pending_prefill[i].pop(0)
                continue
            tok = int(nxt[i])
            req.out.append(tok)
            self._tok[i, 0] = tok
            if (self.eos is not None and tok == self.eos) \
                    or len(req.out) >= req.max_new:
                req.done = True
                self.active[i] = None
        return sum(r is not None for r in self.active)

    def run(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if self.step() == 0 and not self.queue:
                break


# ---------------------------------------------------------------------------
# Exact-query serving path (infer_exact junction tree)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PGMQuery:
    qid: int
    target: str                       # variable whose posterior is requested
    evidence: Dict[str, float]
    payload: Optional[np.ndarray] = None      # temporal mode: [T, F] sequence
    result: Optional[np.ndarray] = None       # posterior table over target
    log_evidence: Optional[float] = None      # exact mode only
    done: bool = False


class PGMQueryEngine:
    """Schema-batched posterior queries over a CLG Bayesian network.

    ``mode="exact"`` routes through :class:`JunctionTreeEngine` — queries
    with the same evidence schema propagate together in one batched device
    call.  ``mode="importance"`` answers each query with likelihood
    weighting (one sampler run per query) behind the same API.
    ``mode="vmp"`` serves q(Z | x) from a fitted plate model
    (``repro.pgm_models``) via the jitted, chunk-bounded
    ``vmp.posterior_z`` — N fully-observed queries sharing a schema cost
    one compiled dispatch; evidence must cover every feature ``X{i}``.
    ``mode="temporal"`` serves filtered / h-step predictive hidden-state
    posteriors from a fitted HMM-family model (``pgm_models.dynamic``):
    queries carry a ``[T, F]`` sequence payload, bucket by (T, horizon),
    and ride one compiled factored-frontier program per bucket shape
    (``dynamic._temporal_serve``, posterior passed as an argument so model
    updates are never served from stale compiled constants).
    """

    def __init__(self, bn, *, mode: str = "exact", n_samples: int = 10_000,
                 use_pallas: Optional[bool] = None, seed: int = 0,
                 plan_cache: Optional[PlanCache] = None,
                 network_version: int = 0, pad_pow2: bool = False,
                 mesh=None, data_axes: Tuple[str, ...] = ("data",)) -> None:
        from repro.infer_exact import JunctionTreeEngine

        if mode not in ("exact", "importance", "vmp", "temporal"):
            raise ValueError(f"unknown mode {mode!r}")
        if mode == "vmp":
            # ``bn`` is a plate Model with a discrete latent Z
            if not hasattr(bn, "cp") or bn.cp.layout.K <= 1:
                raise ValueError("mode='vmp' needs a plate Model with a "
                                 "discrete latent Z")
        if mode == "temporal" and not hasattr(bn, "filtered_posterior"):
            raise ValueError("mode='temporal' needs a fitted HMM-family "
                             "model (pgm_models.dynamic)")
        if mesh is not None and mode != "vmp":
            raise ValueError("mesh replica sharding is only wired for "
                             "mode='vmp' (the dvmp path)")
        self.bn = bn
        self.mode = mode
        self.n_samples = n_samples
        self.seed = seed
        self._use_pallas = use_pallas
        # pad exact-mode buckets to the next power of two (vmp/temporal
        # always do) so arbitrary batch sizes reuse a handful of compiled
        # plans.  Off by default: direct callers keep one-plan-per-size
        # compile accounting; the async serving tier turns it on.
        self.pad_pow2 = pad_pow2
        self.mesh = mesh
        self.data_axes = tuple(data_axes)
        # one PlanCache serves every mode; the serving tier passes a shared
        # instance so exact-JT / vmp / temporal plans share an LRU + counters
        self.plans = plan_cache if plan_cache is not None else PlanCache()
        self.network_version = network_version
        self._jt = (JunctionTreeEngine(bn, use_pallas=use_pallas,
                                       plan_cache=self.plans,
                                       network_version=network_version)
                    if mode == "exact" else None)
        self._queue: List[PGMQuery] = []
        self._next = 0

    # -- deprecated pre-plan-API cache views ---------------------------------

    @property
    def _vmp_caps(self) -> set:
        """Deprecated: compiled posterior_z batch capacities now live in
        ``self.plans`` as ``PlanKey(mode="vmp")`` entries."""
        warnings.warn("PGMQueryEngine._vmp_caps is deprecated; use "
                      "PGMQueryEngine.plans (repro.serve.plan.PlanCache)",
                      DeprecationWarning, stacklevel=2)
        return {k.batch_shape[0] for k in self.plans.keys()
                if k.mode == "vmp"
                and k.network_version == self.network_version}

    @property
    def _temporal_keys(self) -> set:
        """Deprecated: compiled (T, horizon, cap) buckets now live in
        ``self.plans`` as ``PlanKey(mode="temporal")`` entries."""
        warnings.warn("PGMQueryEngine._temporal_keys is deprecated; use "
                      "PGMQueryEngine.plans (repro.serve.plan.PlanCache)",
                      DeprecationWarning, stacklevel=2)
        return {(k.batch_shape[1], int(k.schema[1][1:]), k.batch_shape[0])
                for k in self.plans.keys() if k.mode == "temporal"
                and k.network_version == self.network_version}

    # -- model lifecycle -----------------------------------------------------

    def set_model(self, bn, *, network_version: Optional[int] = None) -> None:
        """Swap the served network/model in place (the hot-swap primitive).

        Bumps ``network_version`` (or sets it to the explicit one), so every
        plan compiled for the old model — whose CPDs are baked into the
        executable as compiled constants — stops hitting and ages out of
        the LRU.  Queued queries are answered by the NEW model on the next
        flush; the async tier drains old buckets first, then calls this.
        """
        self.bn = bn
        self.network_version = (self.network_version + 1
                                if network_version is None else network_version)
        if self._jt is not None:
            self._jt.set_model(bn, network_version=self.network_version)

    # -- query intake --------------------------------------------------------

    def _validate(self, target: str, evidence: Dict[str, float],
                  payload: Optional[np.ndarray] = None
                  ) -> Tuple[Dict[str, float], Optional[np.ndarray]]:
        """Reject malformed queries and normalize (evidence, payload).

        Raises at SUBMIT time: flush() empties the queue before dispatch,
        so a late error would drop queued work.  The async serving tier
        calls this from its own submit path for the same reason.
        """
        if self.mode == "vmp":
            if target != "Z":
                raise ValueError(f"mode='vmp' serves the latent Z, "
                                 f"got target {target!r}")
            names = {f"X{i}" for i in range(self.bn.spec.n_features)}
            missing = names - set(evidence)
            if missing:
                raise ValueError(f"mode='vmp' needs fully observed features; "
                                 f"missing {sorted(missing)}")
            return dict(evidence), None
        if self.mode == "temporal":
            if target not in ("filter", "predict"):
                raise ValueError(f"mode='temporal' serves 'filter' or "
                                 f"'predict', got target {target!r}")
            arr = np.asarray(payload, np.float32)
            if arr.ndim != 2:
                raise ValueError("mode='temporal' needs a [T, F] sequence "
                                 "payload")
            h = int(evidence.get("horizon", 1 if target == "predict" else 0))
            if target == "filter":
                h = 0
            # value-carrying schema: same-(T, horizon) queries batch together
            return {"T": float(arr.shape[0]), "h": float(h)}, arr
        return dict(evidence), None

    def bucket_key(self, evidence: Dict[str, float]) -> tuple:
        """The schema bucket for (normalized) evidence — queries sharing a
        key ride one device call.  Temporal buckets are value-carrying
        ((T, horizon), not just the evidence NAMES): sequence length
        selects the program."""
        return (tuple(f"{k}{int(v)}" for k, v in sorted(evidence.items()))
                if self.mode == "temporal" else tuple(sorted(evidence)))

    def submit(self, target: str, evidence: Dict[str, float],
               payload: Optional[np.ndarray] = None) -> PGMQuery:
        ev, arr = self._validate(target, evidence, payload)
        q = PGMQuery(self._next, target, ev, arr)
        self._next += 1
        self._queue.append(q)
        return q

    def flush(self) -> List[PGMQuery]:
        """Answer every queued query; one device call per evidence schema.

        When obs is enabled each schema bucket is measured — queue depth,
        batch size, compile-vs-execute split (from the junction tree's
        ``last_run``), cache hit/miss and wall latency — as a
        ``serve.bucket`` span plus a ``serve_bucket`` event, with a
        ``serve_flush`` summary and a kernel-dispatch snapshot at the end.
        Disabled (the default), this method runs the pre-obs code path with
        one integer compare per bucket added.
        """
        import time as _time

        done, queue = [], self._queue
        self._queue = []
        groups: Dict[tuple, List[PGMQuery]] = {}
        for q in queue:
            groups.setdefault(self.bucket_key(q.evidence), []).append(q)
        queue_depth = len(queue)
        with obs.span("serve.flush", mode=self.mode, n_queries=queue_depth,
                      n_buckets=len(groups)):
            for schema, qs in groups.items():
                t0 = _time.perf_counter_ns()
                with obs.span("serve.bucket", mode=self.mode,
                              schema=",".join(schema), batch=len(qs)):
                    if self.mode == "exact":
                        binfo = self._flush_exact(schema, qs)
                    elif self.mode == "vmp":
                        binfo = self._flush_vmp(schema, qs)
                    elif self.mode == "temporal":
                        binfo = self._flush_temporal(schema, qs)
                    else:
                        binfo = self._flush_importance(qs)
                if obs.enabled():
                    obs.emit("serve_bucket", mode=self.mode,
                             schema=",".join(schema), batch=len(qs),
                             queue_depth=queue_depth,
                             latency_us=(_time.perf_counter_ns() - t0) / 1e3,
                             **binfo)
                done.extend(qs)
        if obs.enabled():
            obs.emit("serve_flush", mode=self.mode, n_queries=queue_depth,
                     n_buckets=len(groups))
            obs.emit_kernel_counts(site="serve.flush")
        # SUBMISSION order, not bucket order: callers pair results with
        # requests positionally, and qid is the submission sequence number
        done.sort(key=lambda q: q.qid)
        return done

    def _flush_exact(self, schema: tuple, qs: List[PGMQuery]) -> dict:
        B = len(qs)
        cap = (1 << max(B - 1, 0).bit_length()) if self.pad_pow2 else B
        ev = {}
        for n in schema:
            col = jnp.asarray([q.evidence[n] for q in qs])
            if cap != B:
                # pad with copies of row 0: rows are independent through the
                # tree, so real rows stay bit-identical to the unpadded run
                col = jnp.concatenate(
                    [col, jnp.broadcast_to(col[:1], (cap - B,))])
            ev[n] = col
        self._jt.set_evidence(ev)
        self._jt.run_inference()
        logz = np.atleast_1d(np.asarray(self._jt.log_evidence()))
        for target in {q.target for q in qs}:
            var = self.bn.dag.variables.by_name(target)
            post = np.atleast_2d(
                np.asarray(self._jt.posterior_discrete(var)))
            for b, q in enumerate(qs):
                if q.target == target:
                    q.result = post[b if post.shape[0] > 1 else 0]
                    q.log_evidence = float(logz[b if logz.size > 1 else 0])
                    q.done = True
        lr = self._jt.last_run or {}
        return {"cache_hit": bool(lr.get("cache_hit", False)),
                "compile_us": lr.get("compile_us", 0.0),
                "execute_us": lr.get("execute_us", 0.0)}

    def _flush_vmp(self, schema: tuple, qs: List[PGMQuery]) -> dict:
        """q(Z | x) for a schema group in ONE jitted posterior_z dispatch.

        Queries were validated at submit time (full evidence, target Z).
        With a ``mesh``, the batch is data-sharded over the mesh replicas
        via the dvmp ``shard_map`` path — N independent queries split
        across devices, one collective-free program."""
        model = self.bn
        spec = model.spec
        dm = spec.discrete_map
        cont_ids = [i for i in range(spec.n_features) if i not in dm]
        B = len(qs)
        # pad to the next power of two so arbitrary group sizes reuse a
        # handful of compiled posterior_z programs instead of one per size
        cap = 1 << max(B - 1, 0).bit_length()
        if self.mesh is not None:
            # shard_map needs cap % n_devices == 0; pow2 caps divide any
            # pow2 device count once cap >= n_devices
            n_dev = 1
            for a in self.data_axes:
                n_dev *= self.mesh.shape[a]
            cap = max(cap, n_dev)
        xc = np.zeros((cap, len(cont_ids)), np.float32)
        xd = np.zeros((cap, len(dm)), np.int32)
        for b, q in enumerate(qs):
            xc[b] = [q.evidence[f"X{i}"] for i in cont_ids]
            xd[b] = [q.evidence[f"X{i}"] for i in sorted(dm)]
        key = PlanKey(self.network_version, "vmp", schema, (cap,))
        cache_hit = self.plans.peek(key) is not None

        def build():
            if self.mesh is None:
                # posterior read through self.bn at run time: model updates
                # between flushes are never served from a stale closure
                return lambda xc_, xd_: self.bn.posterior_z(
                    Batch(xc_, xd_, jnp.ones(xc_.shape[0], jnp.float32)))
            from repro.core import dvmp as _dvmp
            m, axes = self.mesh, self.data_axes
            return lambda xc_, xd_: _dvmp.dvmp_posterior_z(
                self.bn.cp, self.bn.posterior, xc_, xd_, m, axes,
                backend=self.bn.backend, chunk=self.bn.chunk)

        plan = self.plans.get(key, build)
        post = np.asarray(plan.run(jnp.asarray(xc), jnp.asarray(xd)))
        for b, q in enumerate(qs):
            q.result = post[b]
            q.done = True
        return {"cache_hit": cache_hit, "compile_us": 0.0, "execute_us": 0.0}

    def _flush_temporal(self, schema: tuple, qs: List[PGMQuery]) -> dict:
        """Filtered / predictive state posteriors for one (T, horizon) bucket.

        All sequences in the bucket share T, so they stack into a single
        ``[cap, T, F]`` batch (cap = next power of two, mirroring the vmp
        path) and run through ONE jitted factored-frontier program
        (``dynamic._temporal_serve``); padded rows carry a zero mask."""
        from repro.pgm_models import dynamic as _dyn

        model = self.bn
        h = int(qs[0].evidence.get("h", 0))
        B = len(qs)
        cap = 1 << max(B - 1, 0).bit_length()
        T = qs[0].payload.shape[0]
        F = qs[0].payload.shape[1]
        xs = np.zeros((cap, T, F), np.float32)
        mask = np.zeros((cap, T), np.float32)
        for b, q in enumerate(qs):
            xs[b] = q.payload
            mask[b] = 1.0
        key = PlanKey(self.network_version, "temporal", schema, (cap, T))
        cache_hit = self.plans.peek(key) is not None

        def build():
            # model state read through self.bn at run time (swap-safe)
            return lambda xc_, mask_: _dyn._temporal_serve(
                self.bn.posterior, self.bn._design(xc_),
                self.bn._emission_target(xc_), mask_, horizon=h)

        plan = self.plans.get(key, build)
        beliefs, last = plan.run(jnp.asarray(xs), jnp.asarray(mask))
        beliefs, last = np.asarray(beliefs), np.asarray(last)
        for b, q in enumerate(qs):
            q.result = beliefs[b] if q.target == "filter" else last[b]
            q.done = True
        if not cache_hit and obs.enabled():
            obs.emit("temporal_plan", pipeline="factored_frontier",
                     batch=cap, T=T, S=int(model.S), horizon=h)
        return {"cache_hit": cache_hit, "compile_us": 0.0, "execute_us": 0.0}

    def _flush_importance(self, qs: List[PGMQuery]) -> dict:
        from repro.core.importance_sampling import ImportanceSampling

        for q in qs:
            inf = ImportanceSampling(n_samples=self.n_samples,
                                     seed=self.seed + q.qid)
            inf.set_model(self.bn)
            inf.set_evidence(q.evidence)
            inf.run_inference()
            var = self.bn.dag.variables.by_name(q.target)
            q.result = np.asarray(inf.posterior_discrete(var))
            q.done = True
        return {"cache_hit": False, "compile_us": 0.0, "execute_us": 0.0}
