"""Async serving tier — deadline-aware micro-batching over PGMQueryEngine.

The layer a millions-of-users deployment needs on top of the schema-bucketed
batch engine (ROADMAP "production serving tier"):

* **Request queue + micro-batching** — :meth:`AsyncPGMServer.submit` returns
  immediately with a :class:`ServeTicket`; arriving queries coalesce into
  bucket-shaped device batches (same grouping as
  :meth:`PGMQueryEngine.bucket_key`) and flush on size-or-timeout, with
  per-request deadlines driving flush order: the due bucket with the
  earliest deadline always flushes first.

* **Replica sharding** — ``replicas=N`` runs N worker threads over N engine
  replicas (round-robin over buckets); all replicas share ONE
  :class:`~repro.serve.plan.PlanCache`, so a plan compiled by any replica
  serves all of them.  ``mesh=`` additionally data-shards each vmp bucket
  across the mesh devices via the ``dvmp`` ``shard_map`` path.

* **Hot model swap** — :meth:`swap_model` publishes a re-learnt network
  under ``network_version + 1``: new-version engines are built and their
  plans warmed in the background (serving continues), the engine list is
  switched atomically, queued-but-unflushed buckets drain through the OLD
  engines, and the old version's plans are invalidated.  No request is
  dropped; results issued before the switch come from the old network,
  after it from the new.

Flush decisions emit ``serve_deadline`` events and swaps emit
``serve_swap`` (schema-validated, ``repro.obs``); the per-bucket
``serve_bucket`` telemetry comes from the underlying engine unchanged.
"""

from __future__ import annotations

import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro import obs
from repro.serve.engine import PGMQueryEngine, PGMQuery
from repro.serve.plan import PlanCache


class ServeTicket:
    """Future-like handle for one submitted query.

    ``result(timeout)`` blocks until the micro-batch containing the query
    flushes; ``query`` then holds the answered :class:`PGMQuery`.
    """

    __slots__ = ("rid", "deadline_s", "submitted_s", "done_s", "query",
                 "error", "deadline_miss", "trigger", "_event")

    def __init__(self, rid: int, deadline_s: float, submitted_s: float):
        self.rid = rid
        self.deadline_s = deadline_s        # monotonic-clock deadline
        self.submitted_s = submitted_s
        self.done_s: Optional[float] = None
        self.query: Optional[PGMQuery] = None
        self.error: Optional[BaseException] = None
        self.deadline_miss = False
        self.trigger: Optional[str] = None  # what flushed the batch
        self._event = threading.Event()

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> np.ndarray:
        """Posterior table for the query (blocks until flushed)."""
        if not self._event.wait(timeout):
            raise TimeoutError(f"request {self.rid} not served "
                               f"within {timeout}s")
        if self.error is not None:
            raise self.error
        return self.query.result


class _Bucket:
    __slots__ = ("key", "items", "first_s", "min_deadline_s")

    def __init__(self, key: tuple, now: float):
        self.key = key
        # items hold the ORIGINAL (target, evidence, payload) so the engine
        # re-normalizes at flush time (e.g. temporal horizon extraction)
        self.items: List[Tuple[ServeTicket, str, Dict[str, float],
                               Optional[np.ndarray]]] = []
        self.first_s = now
        self.min_deadline_s = float("inf")


class AsyncPGMServer:
    """Deadline-aware async micro-batching server over PGMQueryEngine.

    Parameters
    ----------
    max_batch        size trigger: a bucket reaching this many queries
                     flushes immediately (the whole bucket flushes — the
                     pow2 padding downstream absorbs overshoot)
    max_delay_ms     timeout trigger: no query waits longer than this for
                     batch-mates, deadline permitting
    default_deadline_ms
                     per-request deadline when ``submit`` gives none; a
                     bucket flushes ``deadline_margin_ms`` before its
                     earliest deadline even if ``max_delay_ms`` has not
                     elapsed
    replicas         worker threads x engine replicas (shared plan cache)
    mesh, data_axes  vmp mode only: data-shard each bucket across the mesh
    """

    def __init__(self, bn, *, mode: str = "exact", max_batch: int = 32,
                 max_delay_ms: float = 5.0, default_deadline_ms: float = 50.0,
                 deadline_margin_ms: float = 1.0, replicas: int = 1,
                 use_pallas: Optional[bool] = None, mesh=None,
                 data_axes: Tuple[str, ...] = ("data",),
                 plan_cache: Optional[PlanCache] = None,
                 n_samples: int = 10_000, seed: int = 0) -> None:
        if replicas < 1:
            raise ValueError("replicas must be >= 1")
        self.mode = mode
        self.max_batch = max_batch
        self.max_delay_s = max_delay_ms / 1e3
        self.default_deadline_s = default_deadline_ms / 1e3
        self.margin_s = deadline_margin_ms / 1e3
        self._mk = dict(mode=mode, use_pallas=use_pallas, mesh=mesh,
                        data_axes=data_axes, n_samples=n_samples, seed=seed)
        self.plans = plan_cache if plan_cache is not None else PlanCache()
        self.network_version = 0
        self._engines = [self._make_engine(bn, 0) for _ in range(replicas)]
        self._cv = threading.Condition()
        self._buckets: Dict[tuple, _Bucket] = {}
        # one arrival sample per seen bucket — the swap warm-up workload
        self._samples: Dict[tuple, Tuple[str, Dict[str, float],
                                         Optional[np.ndarray]]] = {}
        self._next_rid = 0
        self._stop = False
        self.submitted = 0
        self.completed = 0
        self.deadline_misses = 0
        self.flushes: Dict[str, int] = {}
        self._workers = [
            threading.Thread(target=self._worker_loop, args=(i,), daemon=True,
                             name=f"serve-worker-{i}")
            for i in range(replicas)]
        for w in self._workers:
            w.start()

    def _make_engine(self, bn, version: int) -> PGMQueryEngine:
        eng = PGMQueryEngine(bn, plan_cache=self.plans,
                             network_version=version, pad_pow2=True,
                             **self._mk)
        # serializes this replica's submit+flush against the swap drain
        eng._serve_lock = threading.Lock()
        return eng

    # -- intake ---------------------------------------------------------------

    def submit(self, target: str, evidence: Dict[str, float],
               payload: Optional[np.ndarray] = None,
               deadline_ms: Optional[float] = None) -> ServeTicket:
        """Enqueue one query; returns immediately with a ticket."""
        eng = self._engines[0]
        ev, _ = eng._validate(target, evidence, payload)  # raise HERE, async
        key = eng.bucket_key(ev)
        now = time.monotonic()
        ddl = now + (self.default_deadline_s if deadline_ms is None
                     else deadline_ms / 1e3)
        with self._cv:
            if self._stop:
                raise RuntimeError("server is stopped")
            t = ServeTicket(self._next_rid, ddl, now)
            self._next_rid += 1
            b = self._buckets.get(key)
            if b is None:
                b = self._buckets[key] = _Bucket(key, now)
            b.items.append((t, target, dict(evidence),
                            None if payload is None else np.asarray(payload)))
            b.min_deadline_s = min(b.min_deadline_s, ddl)
            self._samples.setdefault(
                key, (target, dict(evidence),
                      None if payload is None else np.asarray(payload)))
            self.submitted += 1
            self._cv.notify_all()
        return t

    # -- flush scheduling -----------------------------------------------------

    def _due_time(self, b: _Bucket) -> float:
        return min(b.first_s + self.max_delay_s,
                   b.min_deadline_s - self.margin_s)

    def _pop_due_locked(self, now: float) -> Optional[Tuple[_Bucket, str]]:
        """Earliest-deadline due bucket (or None).  Caller holds _cv."""
        due = [b for b in self._buckets.values()
               if self._stop or len(b.items) >= self.max_batch
               or now >= self._due_time(b)]
        if not due:
            return None
        b = min(due, key=lambda b: b.min_deadline_s)
        del self._buckets[b.key]
        if len(b.items) >= self.max_batch:
            trigger = "size"
        elif self._stop:
            trigger = "drain"
        elif b.min_deadline_s - self.margin_s <= b.first_s + self.max_delay_s:
            trigger = "deadline"
        else:
            trigger = "timeout"
        return b, trigger

    def _worker_loop(self, widx: int) -> None:
        while True:
            with self._cv:
                while True:
                    if self._stop and not self._buckets:
                        return
                    now = time.monotonic()
                    item = self._pop_due_locked(now)
                    if item is not None:
                        engines = self._engines
                        break
                    nxt = min((self._due_time(b)
                               for b in self._buckets.values()),
                              default=None)
                    self._cv.wait(None if nxt is None
                                  else max(1e-4, nxt - now))
            bucket, trigger = item
            self._flush_bucket(engines[widx % len(engines)], bucket, trigger)

    def _flush_bucket(self, eng: PGMQueryEngine, bucket: _Bucket,
                      trigger: str) -> None:
        now = time.monotonic()
        wait_us = (now - bucket.first_s) * 1e6
        pairs: List[Tuple[ServeTicket, PGMQuery]] = []
        err: Optional[BaseException] = None
        try:
            with eng._serve_lock:
                for t, target, evidence, payload in bucket.items:
                    pairs.append((t, eng.submit(target, evidence, payload)))
                eng.flush()
        except BaseException as e:          # fail the tickets, never hang them
            err = e
        done_s = time.monotonic()
        miss = 0
        for t, q in pairs:
            t.query = q
            t.trigger = trigger
            t.error = err
            t.done_s = done_s
            if done_s > t.deadline_s:
                t.deadline_miss = True
                miss += 1
            t._event.set()
        if err is not None:                 # tickets created before the error
            for t, *_rest in bucket.items[len(pairs):]:
                t.error = err
                t.trigger = trigger
                t.done_s = done_s
                t._event.set()
        with self._cv:
            self.completed += len(bucket.items)
            self.deadline_misses += miss
            self.flushes[trigger] = self.flushes.get(trigger, 0) + 1
        if obs.enabled():
            obs.emit("serve_deadline", mode=self.mode,
                     schema=",".join(bucket.key), batch=len(bucket.items),
                     trigger=trigger, wait_us=wait_us, deadline_miss=miss)

    # -- hot model swap -------------------------------------------------------

    def swap_model(self, bn, *, warm: bool = True) -> Dict[str, Any]:
        """Publish ``bn`` as a new network version without dropping traffic.

        1. Build new-version engine replicas and (``warm=True``) compile
           their plans in the background by mirroring the OLD version's
           plan working set: for each old plan, the recorded sample
           request of its bucket is replayed at the plan's batch capacity
           — serving continues on the old engines throughout.
        2. Atomically switch the engine list: submissions from here on are
           answered by the new network.
        3. Drain queued-but-unflushed buckets through the OLD engines
           (deadline order), then invalidate the old version's plans.

        Returns a summary dict (also emitted as a ``serve_swap`` event).
        """
        t0 = time.perf_counter_ns()
        with self._cv:
            old_version = self.network_version
            samples = dict(self._samples)
            n_rep = len(self._engines)
        new_version = old_version + 1
        new_engines = [self._make_engine(bn, new_version)
                       for _ in range(n_rep)]
        warmed = 0
        if warm:
            eng = new_engines[0]   # shared plan cache: one replica warms all
            old_keys = [k for k in self.plans.keys()
                        if k.network_version == old_version]
            # bucket key == PlanKey.schema in every mode, so each old plan
            # maps back to its bucket's recorded sample request
            for k in old_keys:
                s = samples.get(k.schema)
                if s is None:
                    continue
                target, evidence, payload = s
                with eng._serve_lock:
                    for _ in range(k.batch_shape[0]):
                        eng.submit(target, evidence, payload)
                    eng.flush()
            warmed = sum(1 for k in self.plans.keys()
                         if k.network_version == new_version)
        with self._cv:
            old_engines, self._engines = self._engines, new_engines
            drained = list(self._buckets.values())
            self._buckets.clear()
            self.network_version = new_version
        n_drained = sum(len(b.items) for b in drained)
        for b in sorted(drained, key=lambda b: b.min_deadline_s):
            self._flush_bucket(old_engines[0], b, "drain")
        self.plans.invalidate(old_version)
        info = {"old_version": old_version, "new_version": new_version,
                "warmed_plans": warmed, "drained": n_drained,
                "dur_us": (time.perf_counter_ns() - t0) / 1e3}
        if obs.enabled():
            obs.emit("serve_swap", **info)
        return info

    # -- lifecycle ------------------------------------------------------------

    def stop(self) -> None:
        """Drain every queued bucket, then stop the workers."""
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        for w in self._workers:
            w.join()

    def stats(self) -> Dict[str, Any]:
        with self._cv:
            return {"submitted": self.submitted, "completed": self.completed,
                    "pending": self.submitted - self.completed,
                    "deadline_misses": self.deadline_misses,
                    "flushes": dict(self.flushes),
                    "network_version": self.network_version,
                    "replicas": len(self._engines),
                    "plans": self.plans.stats()}

    def __enter__(self) -> "AsyncPGMServer":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()
