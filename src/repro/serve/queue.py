"""Async serving tier — deadline-aware micro-batching over PGMQueryEngine.

The layer a millions-of-users deployment needs on top of the schema-bucketed
batch engine (ROADMAP "production serving tier"):

* **Request queue + micro-batching** — :meth:`AsyncPGMServer.submit` returns
  immediately with a :class:`ServeTicket`; arriving queries coalesce into
  bucket-shaped device batches (same grouping as
  :meth:`PGMQueryEngine.bucket_key`) and flush on size-or-timeout, with
  per-request deadlines driving flush order: the due bucket with the
  earliest deadline always flushes first.

* **Replica sharding** — ``replicas=N`` runs N worker threads over N engine
  replicas (round-robin over buckets); all replicas share ONE
  :class:`~repro.serve.plan.PlanCache`, so a plan compiled by any replica
  serves all of them.  ``mesh=`` additionally data-shards each vmp bucket
  across the mesh devices via the ``dvmp`` ``shard_map`` path.

* **Hot model swap** — :meth:`swap_model` publishes a re-learnt network
  under ``network_version + 1``: new-version engines are built and their
  plans warmed in the background (serving continues), the engine list is
  switched atomically, queued-but-unflushed buckets drain through the OLD
  engines, and the old version's plans are invalidated.  No request is
  dropped; results issued before the switch come from the old network,
  after it from the new.

* **Robustness** (``repro.resilience`` error vocabulary) — ``max_queue=``
  bounds the submit queue with load shedding (rejected tickets carry a
  :class:`~repro.resilience.errors.ShedError`), ``request_timeout_ms=``
  arms a watchdog that fails stuck requests with a
  :class:`~repro.resilience.errors.DeadlineError` instead of hanging the
  caller, and a supervisor thread detects dead worker replicas, requeues
  their in-flight bucket and respawns them — zero lost accepted tickets.

* **Replica health scoring** (``repro.obs.health``) — every flush feeds a
  per-worker :class:`~repro.obs.health.HealthTracker` (latency EWMA +
  error/timeout/crash demerits).  A worker whose score drops below
  ``health_threshold`` × the best replica's score defers claiming due
  buckets for ``health_penalty_ms``, so traffic drains toward healthy
  replicas *before* the sick one dies — without ever stranding a ticket
  (the grace expires, and deferral is off during drain/stop).  Scoring
  reads host wall-clocks only; it never changes device programs, so
  results stay bit-identical at every obs level.

Flush decisions emit ``serve_deadline`` events and swaps emit
``serve_swap`` (schema-validated, ``repro.obs``); sheds, respawns and
retries emit ``serve_shed``/``serve_worker``/``serve_retry``; the
per-bucket ``serve_bucket`` telemetry comes from the underlying engine
unchanged.  When obs is enabled, each flush additionally records
per-request end-to-end latency into the ``serve_request_ms{mode,schema}``
histogram of the default metrics registry (``repro.obs.agg``) and emits a
rolling ``slo`` event (exact-rank p50/p95/p99 + deadline-miss rate); the
supervisor periodically emits ``serve_health`` score snapshots.
"""

from __future__ import annotations

import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro import obs
from repro.obs import agg as _agg
from repro.obs.health import HealthTracker
from repro.resilience.errors import DeadlineError, ShedError
from repro.serve.engine import PGMQueryEngine, PGMQuery
from repro.serve.plan import PlanCache


class ServeTicket:
    """Future-like handle for one submitted query.

    ``result(timeout)`` blocks until the micro-batch containing the query
    flushes; ``query`` then holds the answered :class:`PGMQuery`.
    """

    __slots__ = ("rid", "deadline_s", "submitted_s", "done_s", "query",
                 "error", "deadline_miss", "trigger", "_event", "_lock")

    def __init__(self, rid: int, deadline_s: float, submitted_s: float):
        self.rid = rid
        self.deadline_s = deadline_s        # monotonic-clock deadline
        self.submitted_s = submitted_s
        self.done_s: Optional[float] = None
        self.query: Optional[PGMQuery] = None
        self.error: Optional[BaseException] = None
        self.deadline_miss = False
        self.trigger: Optional[str] = None  # what flushed the batch
        self._event = threading.Event()
        self._lock = threading.Lock()

    def done(self) -> bool:
        return self._event.is_set()

    def _finish(self, *, query: Optional[PGMQuery] = None,
                error: Optional[BaseException] = None,
                trigger: Optional[str] = None, deadline_miss: bool = False,
                done_s: Optional[float] = None) -> bool:
        """First completion wins — the flush path and the timeout watchdog
        can race to finish the same ticket; the loser is a no-op so a
        result already observed by the caller is never mutated."""
        with self._lock:
            if self._event.is_set():
                return False
            self.query = query
            self.error = error
            self.trigger = trigger
            self.deadline_miss = deadline_miss
            self.done_s = done_s
            self._event.set()
            return True

    def result(self, timeout: Optional[float] = None) -> np.ndarray:
        """Posterior table for the query (blocks until flushed)."""
        if not self._event.wait(timeout):
            raise TimeoutError(f"request {self.rid} not served "
                               f"within {timeout}s")
        if self.error is not None:
            raise self.error
        return self.query.result


class SwapHandle:
    """Returned by ``swap_model(block=False)``: readiness event + outcome.

    ``wait()`` blocks until the background swap publishes (returning the
    summary dict) or fails (re-raising the warm-compile error — in which
    case the OLD engines are still serving, untouched)."""

    __slots__ = ("ready", "info", "error")

    def __init__(self) -> None:
        self.ready = threading.Event()
        self.info: Optional[Dict[str, Any]] = None
        self.error: Optional[BaseException] = None

    def done(self) -> bool:
        return self.ready.is_set()

    def wait(self, timeout: Optional[float] = None) -> Dict[str, Any]:
        if not self.ready.wait(timeout):
            raise TimeoutError(f"model swap not ready within {timeout}s")
        if self.error is not None:
            raise self.error
        return self.info


class _Bucket:
    __slots__ = ("key", "items", "first_s", "min_deadline_s")

    def __init__(self, key: tuple, now: float):
        self.key = key
        # items hold the ORIGINAL (target, evidence, payload) so the engine
        # re-normalizes at flush time (e.g. temporal horizon extraction)
        self.items: List[Tuple[ServeTicket, str, Dict[str, float],
                               Optional[np.ndarray]]] = []
        self.first_s = now
        self.min_deadline_s = float("inf")


class AsyncPGMServer:
    """Deadline-aware async micro-batching server over PGMQueryEngine.

    Parameters
    ----------
    max_batch        size trigger: a bucket reaching this many queries
                     flushes immediately (the whole bucket flushes — the
                     pow2 padding downstream absorbs overshoot)
    max_delay_ms     timeout trigger: no query waits longer than this for
                     batch-mates, deadline permitting
    default_deadline_ms
                     per-request deadline when ``submit`` gives none; a
                     bucket flushes ``deadline_margin_ms`` before its
                     earliest deadline even if ``max_delay_ms`` has not
                     elapsed
    replicas         worker threads x engine replicas (shared plan cache)
    mesh, data_axes  vmp mode only: data-shard each bucket across the mesh
    max_queue        bound on pending (submitted - completed) requests:
                     a submit over capacity is SHED — its ticket returns
                     immediately carrying a ``ShedError`` (None = unbounded)
    request_timeout_ms
                     watchdog grace past the request deadline: a ticket
                     still unanswered ``deadline + timeout`` after submit
                     fails with ``DeadlineError`` instead of hanging its
                     caller behind a stuck flush (None = no watchdog)
    supervise        run the supervisor thread (worker liveness + request
                     timeouts); on by default
    health           track per-replica health scores and bias dispatch
                     away from degraded workers (on by default; a lone
                     replica never defers)
    health_alpha, health_threshold
                     EWMA smoothing / degraded cut-off for the
                     :class:`~repro.obs.health.HealthTracker`
    health_penalty_ms
                     how long a degraded worker holds back from claiming
                     a due bucket before serving it anyway (default:
                     2 x ``max_delay_ms``) — the bias window, not a drop
    """

    def __init__(self, bn, *, mode: str = "exact", max_batch: int = 32,
                 max_delay_ms: float = 5.0, default_deadline_ms: float = 50.0,
                 deadline_margin_ms: float = 1.0, replicas: int = 1,
                 use_pallas: Optional[bool] = None, mesh=None,
                 data_axes: Tuple[str, ...] = ("data",),
                 plan_cache: Optional[PlanCache] = None,
                 n_samples: int = 10_000, seed: int = 0,
                 max_queue: Optional[int] = None,
                 request_timeout_ms: Optional[float] = None,
                 supervise: bool = True,
                 supervise_interval_ms: float = 10.0,
                 health: bool = True, health_alpha: float = 0.3,
                 health_threshold: float = 0.5,
                 health_penalty_ms: Optional[float] = None) -> None:
        if replicas < 1:
            raise ValueError("replicas must be >= 1")
        if max_queue is not None and max_queue < 1:
            raise ValueError("max_queue must be >= 1 (or None)")
        self.mode = mode
        self.max_batch = max_batch
        self.max_delay_s = max_delay_ms / 1e3
        self.default_deadline_s = default_deadline_ms / 1e3
        self.margin_s = deadline_margin_ms / 1e3
        self.max_queue = max_queue
        self.request_timeout_s = (None if request_timeout_ms is None
                                  else request_timeout_ms / 1e3)
        self._mk = dict(mode=mode, use_pallas=use_pallas, mesh=mesh,
                        data_axes=data_axes, n_samples=n_samples, seed=seed)
        self.plans = plan_cache if plan_cache is not None else PlanCache()
        self.network_version = 0
        self._engines = [self._make_engine(bn, 0) for _ in range(replicas)]
        self._cv = threading.Condition()
        self._buckets: Dict[tuple, _Bucket] = {}
        # one arrival sample per seen bucket — the swap warm-up workload
        self._samples: Dict[tuple, Tuple[str, Dict[str, float],
                                         Optional[np.ndarray]]] = {}
        self._next_rid = 0
        self._stop = False
        self.submitted = 0
        self.completed = 0
        self.deadline_misses = 0
        self.shed = 0
        self.worker_restarts = 0
        self.flushes: Dict[str, int] = {}
        self.health = (HealthTracker(replicas, alpha=health_alpha,
                                     threshold=health_threshold)
                       if health else None)
        self._penalty_s = ((2.0 * max_delay_ms if health_penalty_ms is None
                            else health_penalty_ms) / 1e3)
        self._health_emit_s = 0.25
        self._health_last_emit = 0.0
        # fault-injection seam: called (widx, bucket) after a worker pops a
        # bucket and before it flushes; raising kills the worker mid-flight
        self._flush_hook = None
        # bucket each worker is currently flushing — the supervisor requeues
        # it if the worker dies before clearing its slot
        self._inflight: Dict[int, Optional[_Bucket]] = {
            i: None for i in range(replicas)}
        self._swap_lock = threading.Lock()
        self._workers = [
            threading.Thread(target=self._worker_loop, args=(i,), daemon=True,
                             name=f"serve-worker-{i}")
            for i in range(replicas)]
        for w in self._workers:
            w.start()
        self._sup_stop = threading.Event()
        self._sup_interval_s = supervise_interval_ms / 1e3
        self._supervisor: Optional[threading.Thread] = None
        if supervise:
            self._supervisor = threading.Thread(
                target=self._supervisor_loop, daemon=True,
                name="serve-supervisor")
            self._supervisor.start()

    def _make_engine(self, bn, version: int) -> PGMQueryEngine:
        eng = PGMQueryEngine(bn, plan_cache=self.plans,
                             network_version=version, pad_pow2=True,
                             **self._mk)
        # serializes this replica's submit+flush against the swap drain
        eng._serve_lock = threading.Lock()
        return eng

    # -- intake ---------------------------------------------------------------

    def submit(self, target: str, evidence: Dict[str, float],
               payload: Optional[np.ndarray] = None,
               deadline_ms: Optional[float] = None) -> ServeTicket:
        """Enqueue one query; returns immediately with a ticket.

        Over ``max_queue`` pending requests the submit is SHED: the
        returned ticket is already finished with a ``ShedError`` (the
        request was never accepted — retry after backoff is safe)."""
        eng = self._engines[0]
        ev, _ = eng._validate(target, evidence, payload)  # raise HERE, async
        key = eng.bucket_key(ev)
        now = time.monotonic()
        ddl = now + (self.default_deadline_s if deadline_ms is None
                     else deadline_ms / 1e3)
        depth = None
        with self._cv:
            if self._stop:
                raise RuntimeError("server is stopped")
            t = ServeTicket(self._next_rid, ddl, now)
            self._next_rid += 1
            if (self.max_queue is not None
                    and self.submitted - self.completed >= self.max_queue):
                depth = self.submitted - self.completed
                self.shed += 1
                t._finish(error=ShedError(
                    f"queue at capacity ({depth}/{self.max_queue} pending)"),
                    trigger="shed", done_s=now)
            else:
                self._enqueue_locked(t, key, target, evidence, payload,
                                     ddl, now)
        if depth is not None and obs.enabled():
            obs.emit("serve_shed", mode=self.mode, queue_depth=depth,
                     max_queue=self.max_queue)
            _agg.REGISTRY.counter("serve_shed_total", mode=self.mode).inc()
        return t

    def _enqueue_locked(self, t: ServeTicket, key: tuple, target: str,
                        evidence: Dict[str, float],
                        payload: Optional[np.ndarray], ddl: float,
                        now: float) -> None:
        b = self._buckets.get(key)
        if b is None:
            b = self._buckets[key] = _Bucket(key, now)
        b.items.append((t, target, dict(evidence),
                        None if payload is None else np.asarray(payload)))
        b.min_deadline_s = min(b.min_deadline_s, ddl)
        self._samples.setdefault(
            key, (target, dict(evidence),
                  None if payload is None else np.asarray(payload)))
        self.submitted += 1
        self._cv.notify_all()

    # -- flush scheduling -----------------------------------------------------

    def _due_time(self, b: _Bucket) -> float:
        return min(b.first_s + self.max_delay_s,
                   b.min_deadline_s - self.margin_s)

    def _pop_due_locked(self, now: float, defer: bool = False
                        ) -> Optional[Tuple[_Bucket, str]]:
        """Earliest-deadline due bucket (or None).  Caller holds _cv.

        ``defer=True`` (a degraded worker asking) only yields buckets that
        have been due for longer than the health penalty window — healthy
        workers get first claim, but nothing is ever stranded: past the
        grace the degraded worker serves the bucket itself."""
        grace = self._penalty_s if defer else 0.0
        due = [b for b in self._buckets.values()
               if self._stop
               or (not defer and len(b.items) >= self.max_batch)
               or now >= self._due_time(b) + grace]
        if not due:
            return None
        b = min(due, key=lambda b: b.min_deadline_s)
        del self._buckets[b.key]
        if len(b.items) >= self.max_batch:
            trigger = "size"
        elif self._stop:
            trigger = "drain"
        elif b.min_deadline_s - self.margin_s <= b.first_s + self.max_delay_s:
            trigger = "deadline"
        else:
            trigger = "timeout"
        return b, trigger

    def _worker_loop(self, widx: int) -> None:
        while True:
            with self._cv:
                while True:
                    if self._stop and not self._buckets:
                        return
                    now = time.monotonic()
                    defer = (self.health is not None and not self._stop
                             and self.health.should_defer(widx))
                    item = self._pop_due_locked(now, defer=defer)
                    if item is not None:
                        engines = self._engines
                        # registered BEFORE flush: if this thread dies the
                        # supervisor requeues the bucket from here
                        self._inflight[widx] = item[0]
                        break
                    grace = self._penalty_s if defer else 0.0
                    nxt = min((self._due_time(b)
                               for b in self._buckets.values()),
                              default=None)
                    self._cv.wait(None if nxt is None
                                  else max(1e-4, nxt + grace - now))
            bucket, trigger = item
            t0 = time.monotonic()
            hook = self._flush_hook
            if hook is not None:
                # fault injection: a raise here kills the worker with the
                # bucket still registered in-flight (supervised recovery)
                hook(widx, bucket)
            failed = self._flush_bucket(engines[widx % len(engines)], bucket,
                                        trigger)
            if self.health is not None:
                # t0 predates the flush hook, so an injected stall shows up
                # in this worker's latency EWMA exactly like a real one
                self.health.record_flush(
                    widx, (time.monotonic() - t0) * 1e3, error=failed)
            with self._cv:
                self._inflight[widx] = None

    def _flush_bucket(self, eng: PGMQueryEngine, bucket: _Bucket,
                      trigger: str) -> bool:
        """Flush one bucket; returns True when the engine flush failed
        (the tickets were failed, never hung — the flag feeds health)."""
        now = time.monotonic()
        wait_us = (now - bucket.first_s) * 1e6
        pairs: List[Tuple[ServeTicket, PGMQuery]] = []
        err: Optional[BaseException] = None
        try:
            with eng._serve_lock:
                for t, target, evidence, payload in bucket.items:
                    pairs.append((t, eng.submit(target, evidence, payload)))
                eng.flush()
        except BaseException as e:          # fail the tickets, never hang them
            err = e
        done_s = time.monotonic()
        miss = 0
        finished = 0
        lats_ms: List[float] = []
        for t, q in pairs:
            late = done_s > t.deadline_s
            if t._finish(query=q, error=err, trigger=trigger, done_s=done_s,
                         deadline_miss=late):
                finished += 1
                miss += late
                lats_ms.append((done_s - t.submitted_s) * 1e3)
            # else: the timeout watchdog already failed this ticket
        if err is not None:                 # tickets created before the error
            for t, *_rest in bucket.items[len(pairs):]:
                if t._finish(error=err, trigger=trigger, done_s=done_s,
                             deadline_miss=done_s > t.deadline_s):
                    finished += 1
        with self._cv:
            self.completed += finished
            self.deadline_misses += miss
            self.flushes[trigger] = self.flushes.get(trigger, 0) + 1
        if obs.enabled():
            schema = ",".join(bucket.key)
            obs.emit("serve_deadline", mode=self.mode, schema=schema,
                     batch=len(bucket.items), trigger=trigger,
                     wait_us=wait_us, deadline_miss=miss)
            if lats_ms:
                self._record_slo(schema, lats_ms, miss)
        return err is not None

    def _record_slo(self, schema: str, lats_ms: List[float],
                    miss: int) -> None:
        """Fold one flush's end-to-end request latencies into the
        ``serve_request_ms{mode,schema}`` histogram and emit a rolling
        ``slo`` snapshot (exact-rank quantiles over everything recorded
        so far for this mode/schema).  Only called when obs is enabled."""
        hist = _agg.REGISTRY.histogram("serve_request_ms", mode=self.mode,
                                       schema=schema)
        for ms in lats_ms:
            hist.record(ms)
        misses = _agg.REGISTRY.counter("serve_deadline_miss_total",
                                       mode=self.mode, schema=schema)
        if miss:
            misses.inc(miss)
        p50, p95, p99 = hist.quantiles((0.5, 0.95, 0.99))
        obs.emit("slo", mode=self.mode, schema=schema, count=hist.count,
                 p50_ms=p50, p95_ms=p95, p99_ms=p99,
                 miss_rate=misses.value / max(hist.count, 1))

    # -- supervision ----------------------------------------------------------

    def _check_workers_locked(self) -> List[Tuple[int, int, threading.Thread]]:
        """Detect dead worker threads: requeue each one's in-flight bucket
        (merging into any bucket that re-formed under the same key) and
        stage a replacement thread.  Caller holds ``_cv``; the staged
        threads must be started OUTSIDE the lock."""
        staged = []
        for widx, w in enumerate(self._workers):
            if w.is_alive():
                continue
            b = self._inflight.get(widx)
            if b is None and self._stop:
                continue                    # normal shutdown exit
            requeued = 0
            if b is not None:
                self._inflight[widx] = None
                live = self._buckets.get(b.key)
                if live is None:
                    self._buckets[b.key] = b
                else:
                    live.items.extend(b.items)
                    live.first_s = min(live.first_s, b.first_s)
                    live.min_deadline_s = min(live.min_deadline_s,
                                              b.min_deadline_s)
                requeued = len(b.items)
            nw = threading.Thread(target=self._worker_loop, args=(widx,),
                                  daemon=True, name=f"serve-worker-{widx}")
            self._workers[widx] = nw
            self.worker_restarts += 1
            staged.append((widx, requeued, nw))
        if staged:
            self._cv.notify_all()
        return staged

    def _expired_tickets_locked(self, now: float
                                ) -> List[Tuple[ServeTicket, Optional[int]]]:
        """Tickets past deadline + request timeout, queued or in-flight.
        In-flight tickets carry the index of the worker holding them (the
        timeout is that replica's demerit); queued ones carry None."""
        if self.request_timeout_s is None:
            return []
        cut = self.request_timeout_s
        out: List[Tuple[ServeTicket, Optional[int]]] = []
        for b in self._buckets.values():
            out += [(t, None) for t, *_ in b.items
                    if not t.done() and now > t.deadline_s + cut]
        for widx, b in self._inflight.items():
            if b is not None:
                out += [(t, widx) for t, *_ in b.items
                        if not t.done() and now > t.deadline_s + cut]
        return out

    def _supervise_once(self) -> None:
        now = time.monotonic()
        with self._cv:
            staged = self._check_workers_locked()
            expired = self._expired_tickets_locked(now)
        for widx, requeued, nw in staged:
            nw.start()
            if self.health is not None:
                self.health.record_penalty(widx, "crash")
            if obs.enabled():
                obs.emit("serve_worker", worker=widx, action="respawn",
                         requeued=requeued)
        timed_out = 0
        for t, widx in expired:
            if t._finish(error=DeadlineError(
                    f"request {t.rid} timed out "
                    f"({self.request_timeout_s * 1e3:.0f}ms past deadline)"),
                    trigger="watchdog", done_s=now, deadline_miss=True):
                timed_out += 1
                if widx is not None and self.health is not None:
                    self.health.record_timeout(widx)
        if timed_out:
            with self._cv:
                self.completed += timed_out
                self.deadline_misses += timed_out
        self._emit_health()

    def _emit_health(self, force: bool = False) -> None:
        """Emit one ``serve_health`` event per replica (rate-limited to
        one snapshot per ``_health_emit_s`` unless forced) and mirror the
        scores into the registry's ``replica_score`` gauges."""
        if self.health is None or not obs.enabled():
            return
        now = time.monotonic()
        if not force and now - self._health_last_emit < self._health_emit_s:
            return
        self._health_last_emit = now
        for w, snap in enumerate(self.health.snapshots()):
            obs.emit("serve_health", worker=w, **snap)
            _agg.REGISTRY.gauge("replica_score", worker=w).set(snap["score"])

    def _supervisor_loop(self) -> None:
        while not self._sup_stop.wait(self._sup_interval_s):
            self._supervise_once()

    # -- hot model swap -------------------------------------------------------

    def swap_model(self, bn, *, warm: bool = True, block: bool = True):
        """Publish ``bn`` as a new network version without dropping traffic.

        1. Build new-version engine replicas and (``warm=True``) compile
           their plans by mirroring the OLD version's plan working set:
           for each old plan, the recorded sample request of its bucket is
           replayed at the plan's batch capacity — serving continues on
           the old engines throughout.
        2. Atomically switch the engine list: submissions from here on are
           answered by the new network.
        3. Drain queued-but-unflushed buckets through the OLD engines
           (deadline order), then invalidate the old version's plans.

        ``block=True`` runs inline and returns the summary dict (also
        emitted as a ``serve_swap`` event).  ``block=False`` runs the
        whole sequence — including warm compilation — on a background
        thread and returns a :class:`SwapHandle` immediately; serving is
        never paused while the new version warms.

        A warm-compilation failure ABORTS the swap before the switch: the
        old engines keep serving untouched, the partially-warmed
        new-version plans are invalidated, and the error is re-raised
        (from this call when blocking, from ``handle.wait()`` otherwise).
        """
        handle = SwapHandle()

        def run() -> None:
            try:
                handle.info = self._do_swap(bn, warm)
            except BaseException as e:
                handle.error = e
            finally:
                handle.ready.set()

        if block:
            run()
            if handle.error is not None:
                raise handle.error
            return handle.info
        threading.Thread(target=run, daemon=True,
                         name="serve-swap").start()
        return handle

    def _do_swap(self, bn, warm: bool) -> Dict[str, Any]:
        t0 = time.perf_counter_ns()
        with self._swap_lock:               # concurrent swaps serialize
            with self._cv:
                old_version = self.network_version
                samples = dict(self._samples)
                n_rep = len(self._engines)
            new_version = old_version + 1
            try:
                new_engines = [self._make_engine(bn, new_version)
                               for _ in range(n_rep)]
                warmed = 0
                if warm:
                    # shared plan cache: one replica warms all
                    eng = new_engines[0]
                    old_keys = [k for k in self.plans.keys()
                                if k.network_version == old_version]
                    # bucket key == PlanKey.schema in every mode, so each
                    # old plan maps back to its bucket's sample request
                    for k in old_keys:
                        s = samples.get(k.schema)
                        if s is None:
                            continue
                        target, evidence, payload = s
                        with eng._serve_lock:
                            for _ in range(k.batch_shape[0]):
                                eng.submit(target, evidence, payload)
                            eng.flush()
                    warmed = sum(1 for k in self.plans.keys()
                                 if k.network_version == new_version)
            except BaseException:
                # abort: nothing switched — old engines serve on; drop any
                # half-warmed plans so the failed version leaves no residue
                self.plans.invalidate(new_version)
                raise
            with self._cv:
                old_engines, self._engines = self._engines, new_engines
                drained = list(self._buckets.values())
                self._buckets.clear()
                self.network_version = new_version
            n_drained = sum(len(b.items) for b in drained)
            for b in sorted(drained, key=lambda b: b.min_deadline_s):
                self._flush_bucket(old_engines[0], b, "drain")
            self.plans.invalidate(old_version)
        info = {"old_version": old_version, "new_version": new_version,
                "warmed_plans": warmed, "drained": n_drained,
                "dur_us": (time.perf_counter_ns() - t0) / 1e3}
        if obs.enabled():
            obs.emit("serve_swap", **info)
        return info

    # -- lifecycle ------------------------------------------------------------

    def stop(self) -> None:
        """Drain every queued bucket, then stop workers and supervisor."""
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        for w in list(self._workers):
            w.join()
        if self._supervisor is not None:
            # final pass: a worker that died holding a bucket is respawned
            # here, drains it (stop flushes everything), then exits
            self._supervise_once()
            self._sup_stop.set()
            self._supervisor.join()
        for w in list(self._workers):
            w.join()
        # final score snapshot so short runs always see serve_health events
        self._emit_health(force=True)

    def stats(self) -> Dict[str, Any]:
        health = (self.health.snapshots()
                  if self.health is not None else None)
        with self._cv:
            return {"submitted": self.submitted, "completed": self.completed,
                    "pending": self.submitted - self.completed,
                    "deadline_misses": self.deadline_misses,
                    "shed": self.shed,
                    "worker_restarts": self.worker_restarts,
                    "flushes": dict(self.flushes),
                    "network_version": self.network_version,
                    "replicas": len(self._engines),
                    "health": health,
                    "plans": self.plans.stats()}

    def __enter__(self) -> "AsyncPGMServer":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()
