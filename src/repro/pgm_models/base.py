"""Model — the paper's ``latentvariablemodels.staticmodels.Model`` analog.

Subclasses override :meth:`build_spec` (the paper's ``buildDAG()``) to return
a ``PlateSpec`` (+ optional latent mask).  ``update_model`` accepts a
``DataStream``, a ``Batch`` or raw arrays and performs either batch VMP,
distributed d-VMP (``mesh=``) or streaming Bayesian updating (repeated calls
— Eq. 3), mirroring Code Fragments 7/9/12.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import dvmp, expfam as ef, vmp
from repro.core.dag import (BayesianNetwork, CLGCPD, DAG, MultinomialCPD,
                            PlateSpec, Variables)
from repro.data.stream import Attribute, Batch, DataStream, FINITE, REAL


class Model:
    def __init__(self, attributes: Sequence[Attribute], *, seed: int = 0,
                 backend: Optional[str] = None, chunk: Optional[int] = None,
                 **prior_kwargs) -> None:
        self.attributes = list(attributes)
        spec, latent_mask = self.build_spec()
        self.spec = spec
        self.cp = vmp.compile_plate(spec, latent_mask)
        self.prior = vmp.default_prior(self.cp, **prior_kwargs)
        self.posterior = vmp.symmetry_broken(self.prior, jax.random.PRNGKey(seed))
        self._chained_prior = self.prior  # Eq. 3 accumulator
        self.n_seen = 0
        # suff-stats reduction schedule (vmp.local_step): backend None ->
        # pallas where the kernels compile natively, einsum elsewhere
        self.backend = backend if backend is not None else vmp.default_backend()
        self.chunk = chunk

    # -- to be overridden ------------------------------------------------------

    def build_spec(self) -> Tuple[PlateSpec, Optional[jnp.ndarray]]:
        raise NotImplementedError

    def supervised_r(self, batch: Batch) -> Optional[jnp.ndarray]:
        """Return fixed responsibilities [N, K] for supervised models."""
        return None

    # -- data plumbing ----------------------------------------------------------

    def _as_batch(self, data) -> Batch:
        if isinstance(data, Batch):
            return data
        if isinstance(data, DataStream):
            return data.collect()
        xc = jnp.asarray(data, jnp.float32)
        return Batch(xc, jnp.zeros((xc.shape[0], 0), jnp.int32),
                     jnp.ones(xc.shape[0], jnp.float32))

    # -- learning (paper Code Fragments 7, 9, 12) --------------------------------

    def update_model(self, data, *, sweeps: int = 100, tol: float = 1e-5,
                     mesh=None, data_axes: Tuple[str, ...] = ("data",),
                     stream_window: Optional[int] = None) -> float:
        """Fit/refine the posterior on ``data``.

        Repeated calls implement Bayesian updating (Eq. 3): the previous
        posterior becomes the prior for the new data.  Returns the ELBO.

        A multi-batch ``DataStream`` (a source yielding several chunks)
        routes through ``streaming``: equal-shape chunks are stacked and
        replayed by ``stream_fit`` in ONE jitted ``lax.scan`` (drift test +
        tempering resident on device); ragged chunk shapes fall back to the
        per-batch ``stream_update`` loop.  Single-chunk streams, raw arrays
        and ``Batch``es keep the one-shot VMP fit below.  The stacked
        replay is whole-stream-resident by default (the scan consumes
        [T, B, F] on device); ``stream_window=w`` keeps the stack on the
        host and replays device-sliced windows of w batches instead —
        bounded device memory for streams larger than memory.
        """
        if (mesh is None and isinstance(data, DataStream)
                and type(self).supervised_r is Model.supervised_r):
            chunks = [(jnp.asarray(xc, jnp.float32), jnp.asarray(xd))
                      for xc, xd in data.chunks()]
            if len(chunks) > 1:
                return self._update_model_stream(chunks, sweeps=sweeps,
                                                 tol=tol,
                                                 window=stream_window)
            if chunks:
                # single chunk: reuse it instead of re-running the source
                # (sources need not be restartable)
                xc, xd = chunks[0]
                data = Batch(xc, xd, jnp.ones(xc.shape[0], jnp.float32))
        batch = self._as_batch(data)
        prior = self._chained_prior
        r_fixed = self.supervised_r(batch)

        if r_fixed is not None:
            # conjugate closed form: one local step + global update
            stats, _ = vmp.local_step(
                self.cp, self.posterior, batch.xc, batch.xd, batch.mask,
                r_fixed, backend=self.backend, chunk=self.chunk
            )
            if mesh is not None:
                stats = jax.tree_util.tree_map(lambda s: s, stats)  # already global
            post = vmp.global_update(prior, stats)
            e = float(vmp.elbo(self.cp, prior, post, stats))
        elif mesh is None:
            st = vmp.vmp_fit(self.cp, prior, self.posterior,
                             batch.xc, batch.xd, sweeps, tol, batch.mask,
                             self.backend, self.chunk)
            post, e = st.post, float(st.elbo)
        else:
            st = dvmp.dvmp_fit(self.cp, prior, self.posterior, batch.xc,
                               batch.xd, mesh, data_axes, sweeps, tol,
                               mask=batch.mask, backend=self.backend,
                               chunk=self.chunk)
            post, e = st.post, float(st.elbo)

        self.posterior = post
        self._chained_prior = post      # Eq. 3: posterior -> next prior
        self.n_seen += int(batch.mask.sum())
        return e

    def _update_model_stream(self, chunks, *, sweeps: int, tol: float,
                             window: Optional[int] = None) -> float:
        """Streaming Bayesian updating over pre-chunked data (ROADMAP item:
        ``stream_fit`` underneath ``update_model``)."""
        import numpy as np

        from repro.core import streaming

        state = streaming.stream_init(self._chained_prior, self.posterior)
        stacked = len({(xc.shape, xd.shape) for xc, xd in chunks}) == 1
        if stacked:
            # windowed replay keeps the stack host-resident (numpy)
            stack = np.stack if window is not None else jnp.stack
            xcs = stack([xc for xc, _ in chunks])
            xds = stack([xd for _, xd in chunks])
            state, info = streaming.stream_fit(
                self.cp, self.prior, state, xcs, xds,
                sweeps=sweeps, tol=tol, backend=self.backend,
                chunk=self.chunk, window=window)
            e = float(info["elbo"][-1])
        else:
            for xc, xd in chunks:
                state, info = streaming.stream_update(
                    self.cp, self.prior, state, xc, xd,
                    sweeps=sweeps, tol=tol, backend=self.backend,
                    chunk=self.chunk)
            e = float(info["elbo"])
        self.posterior = state.post
        self._chained_prior = state.post
        self.n_seen += int(state.n_seen)
        return e

    # -- queries -----------------------------------------------------------------

    def posterior_z(self, data) -> jnp.ndarray:
        batch = self._as_batch(data)
        # vmp.posterior_z is jitted (keyed on the plate): per-query serve
        # calls dispatch one compiled program instead of retracing
        return vmp.posterior_z(self.cp, self.posterior, batch.xc, batch.xd,
                               backend=self.backend, chunk=self.chunk)

    def get_model(self) -> vmp.PlateParams:
        return self.posterior

    # -- exact inference (infer_exact junction tree — HUGIN-link replacement)

    def to_bayesian_network(self) -> BayesianNetwork:
        """Export the posterior-mean point estimate as a concrete CLG
        ``BayesianNetwork``.

        Node names: the latent is ``"Z"`` (present when ``latent_card > 1``);
        feature ``i`` of the spec is ``"X{i}"``.  Models with a continuous
        latent ``H`` (FA/PPCA family) are not expressible as a finite node
        set and raise ``NotImplementedError``.
        """
        lay = self.cp.layout
        if self.spec.latent_dim > 0:
            raise NotImplementedError(
                "continuous latent H has no finite-node BN export")
        spec, p = self.spec, self.posterior
        dm = spec.discrete_map
        vs = Variables()
        z = vs.new_multinomial("Z", lay.K) if lay.K > 1 else None
        feats = {}
        for i in range(spec.n_features):
            feats[i] = (vs.new_multinomial(f"X{i}", dm[i]) if i in dm
                        else vs.new_gaussian(f"X{i}"))
        dag = DAG(vs)
        cpds = {}
        if z is not None:
            cpds["Z"] = MultinomialCPD(ef.dirichlet_mean(p.mix))
        cont_ids = [i for i in range(spec.n_features) if i not in dm]
        sigma2 = p.reg.b / p.reg.a                       # [F, K] E-style var
        for f, orig in enumerate(cont_ids):
            v = feats[orig]
            if z is not None:
                dag.add_parent(v, z)
            pa = spec.parent_idx(orig)
            for pi in pa:
                dag.add_parent(v, feats[pi])
            m = p.reg.m[f]                               # [K, 1 + P]
            alpha, beta = m[:, 0], m[:, 1:1 + len(pa)]
            s2 = sigma2[f]
            if z is None:                                # no discrete parent
                alpha, beta, s2 = alpha[0], beta[0], s2[0]
            cpds[v.name] = CLGCPD(alpha=alpha, beta=beta, sigma2=s2)
        for new_d, (orig, card) in enumerate(sorted(dm.items())):
            v = feats[orig]
            if z is not None:
                dag.add_parent(v, z)
            alpha = p.disc.alpha[new_d, :, :card]        # [K, card]
            table = alpha / alpha.sum(-1, keepdims=True)
            cpds[v.name] = MultinomialCPD(table if z is not None
                                          else table[0])
        return BayesianNetwork(dag, cpds)

    def posterior_exact(self, data, *, use_pallas=None) -> jnp.ndarray:
        """Exact p(Z | x) via the native junction-tree engine.

        ``data`` is either an evidence dict (name -> scalar or [B] array,
        names as in :meth:`to_bayesian_network`) or anything
        :meth:`posterior_z` accepts — a Batch/DataStream/array whose rows
        become one batched propagation (a single device call).

        This is the correctness oracle for the approximate engines: for
        plate models with a single discrete latent it must agree with
        :meth:`posterior_z` up to VMP convergence.
        """
        from repro.infer_exact import JunctionTreeEngine

        if self.cp.layout.K <= 1:
            raise ValueError("model has no discrete latent to query")
        bn = self.to_bayesian_network()
        if isinstance(data, dict):
            evidence = data
        else:
            batch = self._as_batch(data)
            dm = self.spec.discrete_map
            cont_ids = [i for i in range(self.spec.n_features)
                        if i not in dm]
            evidence = {f"X{orig}": batch.xc[:, f]
                        for f, orig in enumerate(cont_ids)}
            for new_d, (orig, _) in enumerate(sorted(dm.items())):
                evidence[f"X{orig}"] = batch.xd[:, new_d]
        eng = JunctionTreeEngine(bn, use_pallas=use_pallas)
        eng.set_evidence(evidence)
        eng.run_inference()
        return eng.posterior_discrete(bn.dag.variables.by_name("Z"))

    # -- pretty print (paper Code Fragment 8) --------------------------------------

    def __str__(self) -> str:
        import numpy as np

        p = self.posterior
        lay = self.cp.layout
        lines = [f"{type(self).__name__} (Bayesian posterior):"]
        if lay.K > 1:
            w = np.asarray(p.mix.alpha / p.mix.alpha.sum())
            lines.append(f"P(Hidden) follows a Multinomial\n  {w}")
        for f in range(lay.F):
            mu = np.asarray(p.reg.m[f, :, 0])
            var = np.asarray(p.reg.b[f] / p.reg.a[f])
            lines.append(
                f"P(X{f} | ...) follows a Normal|Multinomial"
            )
            for k in range(lay.K):
                lines.append(f"  Normal [ mu = {mu[k]:.6f}, var = {var[k]:.6f} ]"
                             f" | {{Hidden = {k}}}")
        return "\n".join(lines)
