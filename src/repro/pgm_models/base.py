"""Model — the paper's ``latentvariablemodels.staticmodels.Model`` analog.

Subclasses override :meth:`build_spec` (the paper's ``buildDAG()``) to return
a ``PlateSpec`` (+ optional latent mask).  ``update_model`` accepts a
``DataStream``, a ``Batch`` or raw arrays and performs either batch VMP,
distributed d-VMP (``mesh=``) or streaming Bayesian updating (repeated calls
— Eq. 3), mirroring Code Fragments 7/9/12.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import dvmp, vmp
from repro.core.dag import PlateSpec
from repro.data.stream import Attribute, Batch, DataStream, FINITE, REAL


class Model:
    def __init__(self, attributes: Sequence[Attribute], *, seed: int = 0,
                 **prior_kwargs) -> None:
        self.attributes = list(attributes)
        spec, latent_mask = self.build_spec()
        self.spec = spec
        self.cp = vmp.compile_plate(spec, latent_mask)
        self.prior = vmp.default_prior(self.cp, **prior_kwargs)
        self.posterior = vmp.symmetry_broken(self.prior, jax.random.PRNGKey(seed))
        self._chained_prior = self.prior  # Eq. 3 accumulator
        self.n_seen = 0

    # -- to be overridden ------------------------------------------------------

    def build_spec(self) -> Tuple[PlateSpec, Optional[jnp.ndarray]]:
        raise NotImplementedError

    def supervised_r(self, batch: Batch) -> Optional[jnp.ndarray]:
        """Return fixed responsibilities [N, K] for supervised models."""
        return None

    # -- data plumbing ----------------------------------------------------------

    def _as_batch(self, data) -> Batch:
        if isinstance(data, Batch):
            return data
        if isinstance(data, DataStream):
            return data.collect()
        xc = jnp.asarray(data, jnp.float32)
        return Batch(xc, jnp.zeros((xc.shape[0], 0), jnp.int32),
                     jnp.ones(xc.shape[0], jnp.float32))

    # -- learning (paper Code Fragments 7, 9, 12) --------------------------------

    def update_model(self, data, *, sweeps: int = 100, tol: float = 1e-5,
                     mesh=None, data_axes: Tuple[str, ...] = ("data",)) -> float:
        """Fit/refine the posterior on ``data``.

        Repeated calls implement Bayesian updating (Eq. 3): the previous
        posterior becomes the prior for the new data.  Returns the ELBO.
        """
        batch = self._as_batch(data)
        prior = self._chained_prior
        r_fixed = self.supervised_r(batch)

        if r_fixed is not None:
            # conjugate closed form: one local step + global update
            stats, _ = vmp.local_step(
                self.cp, self.posterior, batch.xc, batch.xd, batch.mask, r_fixed
            )
            if mesh is not None:
                stats = jax.tree_util.tree_map(lambda s: s, stats)  # already global
            post = vmp.global_update(prior, stats)
            e = float(vmp.elbo(self.cp, prior, post, stats))
        elif mesh is None:
            st = vmp.vmp_fit(self.cp, prior, self.posterior,
                             batch.xc, batch.xd, sweeps, tol)
            post, e = st.post, float(st.elbo)
        else:
            st = dvmp.dvmp_fit(self.cp, prior, self.posterior, batch.xc,
                               batch.xd, mesh, data_axes, sweeps, tol,
                               mask=batch.mask)
            post, e = st.post, float(st.elbo)

        self.posterior = post
        self._chained_prior = post      # Eq. 3: posterior -> next prior
        self.n_seen += int(batch.mask.sum())
        return e

    # -- queries -----------------------------------------------------------------

    def posterior_z(self, data) -> jnp.ndarray:
        batch = self._as_batch(data)
        return vmp.posterior_z(self.cp, self.posterior, batch.xc, batch.xd)

    def get_model(self) -> vmp.PlateParams:
        return self.posterior

    # -- pretty print (paper Code Fragment 8) --------------------------------------

    def __str__(self) -> str:
        import numpy as np

        p = self.posterior
        lay = self.cp.layout
        lines = [f"{type(self).__name__} (Bayesian posterior):"]
        if lay.K > 1:
            w = np.asarray(p.mix.alpha / p.mix.alpha.sum())
            lines.append(f"P(Hidden) follows a Multinomial\n  {w}")
        for f in range(lay.F):
            mu = np.asarray(p.reg.m[f, :, 0])
            var = np.asarray(p.reg.b[f] / p.reg.a[f])
            lines.append(
                f"P(X{f} | ...) follows a Normal|Multinomial"
            )
            for k in range(lay.K):
                lines.append(f"  Normal [ mu = {mu[k]:.6f}, var = {var[k]:.6f} ]"
                             f" | {{Hidden = {k}}}")
        return "\n".join(lines)
