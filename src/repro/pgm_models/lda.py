"""Latent Dirichlet Allocation — paper module 'lda' ("allows text processing
by means of the latent Dirichlet allocation model").

Batch variational Bayes (Blei et al. 2003) over bag-of-words count matrices,
with the document E-step as a ``lax.scan``-free fixed-iteration vectorized
update (all documents in parallel — the multi-core parallelStream analog),
and an SVI path reusing the natural-gradient machinery for streams of
documents (Hoffman et al. 2013 — cited by the paper for SVI).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.scipy.special import digamma

from repro.core import expfam as ef


class LDA:
    def __init__(self, n_topics: int, vocab: int, *, alpha: float = 0.3,
                 eta: float = 0.1, seed: int = 0):
        self.T, self.V = n_topics, vocab
        self.alpha, self.eta = alpha, eta
        key = jax.random.PRNGKey(seed)
        # topic-word variational Dirichlet (global)
        self.lam = eta + jax.random.gamma(key, 100.0, (n_topics, vocab)) / 100.0
        self._step = 0

    # -- E-step: per-document mean-field, fully vectorized ----------------------

    @staticmethod
    @jax.jit
    def _doc_estep(lam: jnp.ndarray, counts: jnp.ndarray, alpha: float,
                   iters: int = 50):
        """counts: [D, V] -> (gamma [D, T], expected topic-word stats [T, V])."""
        D = counts.shape[0]
        T = lam.shape[0]
        e_logbeta = digamma(lam) - digamma(lam.sum(-1, keepdims=True))  # [T,V]
        gamma0 = jnp.full((D, T), alpha + counts.sum(-1, keepdims=True) / T)

        def body(_, gamma):
            e_logtheta = digamma(gamma) - digamma(gamma.sum(-1, keepdims=True))
            # phi[d, v, t] ∝ exp(e_logtheta[d,t] + e_logbeta[t,v])
            logphi = e_logtheta[:, None, :] + e_logbeta.T[None]      # [D,V,T]
            phi = jax.nn.softmax(logphi, axis=-1)
            return alpha + jnp.einsum("dv,dvt->dt", counts, phi)

        gamma = jax.lax.fori_loop(0, iters, body, gamma0)
        e_logtheta = digamma(gamma) - digamma(gamma.sum(-1, keepdims=True))
        logphi = e_logtheta[:, None, :] + e_logbeta.T[None]
        phi = jax.nn.softmax(logphi, axis=-1)
        stats = jnp.einsum("dv,dvt->tv", counts, phi)                 # [T,V]
        return gamma, stats

    # -- learning ---------------------------------------------------------------

    def update_model(self, counts: np.ndarray, *, sweeps: int = 30) -> float:
        """Batch VB. Repeated calls = Bayesian updating over document batches."""
        counts = jnp.asarray(counts, jnp.float32)
        for _ in range(sweeps):
            gamma, stats = self._doc_estep(self.lam, counts, self.alpha)
            self.lam = self.eta + stats  # conjugate global update
        self.gamma = gamma
        return float(self.perplexity_bound(counts))

    def svi_step(self, counts: np.ndarray, n_total: int, *, tau: float = 64.0,
                 kappa: float = 0.7) -> None:
        """One SVI natural-gradient step on a minibatch of documents."""
        counts = jnp.asarray(counts, jnp.float32)
        _, stats = self._doc_estep(self.lam, counts, self.alpha)
        rho = (self._step + tau) ** (-kappa)
        target = self.eta + (n_total / counts.shape[0]) * stats
        self.lam = (1 - rho) * self.lam + rho * target
        self._step += 1

    # -- queries ------------------------------------------------------------------

    def topics(self) -> np.ndarray:
        return np.asarray(self.lam / self.lam.sum(-1, keepdims=True))

    def doc_topics(self, counts) -> np.ndarray:
        gamma, _ = self._doc_estep(self.lam, jnp.asarray(counts, jnp.float32),
                                   self.alpha)
        return np.asarray(gamma / gamma.sum(-1, keepdims=True))

    def perplexity_bound(self, counts) -> jnp.ndarray:
        """Quick predictive bound: sum_d sum_v c_dv log sum_t theta beta."""
        gamma, _ = self._doc_estep(self.lam, counts, self.alpha)
        theta = gamma / gamma.sum(-1, keepdims=True)
        beta = self.lam / self.lam.sum(-1, keepdims=True)
        probs = theta @ beta                                   # [D, V]
        return (counts * jnp.log(jnp.maximum(probs, 1e-12))).sum()
