"""Dynamic latent-variable models — paper Table 2, right column.

All models operate on ``SequenceBatch`` data ([B, T, ...]) and are learnt by
variational Bayesian EM:

  * HMM family — E-step = masked forward-backward (``lax.scan``), vmapped
    over sequences; M-step = conjugate Dirichlet / Normal-Gamma /
    MVNormalGamma updates from expected counts.  AR-HMM and IO-HMM reuse the
    CLG emission (regression on the previous observation / exogenous input).
  * Kalman filter (LDS) — E-step = Kalman smoothing; M-step = Bayesian
    linear regression (MVNormalGamma) for transition and emission rows.
  * Switching LDS — structured mean field q(s)q(h): factored-frontier pass
    for the switch chain, Kalman smoothing under averaged dynamics, Bayesian
    regression M-step per switch state.

Streaming (Eq. 3) works exactly as in the static case: posteriors chain —
:func:`seq_stream_fit` replays stacked sequence batches in ONE jitted scan
with the Page-Hinkley drift gate (``core.streaming.drift_gate``) and prior
tempering in-body, mirroring ``streaming.stream_fit``.

**Fused sweep loops.**  Every ``update_model`` defaults to ``fused=True``:
the whole VB-EM sweep loop runs as one jitted donated-buffer ``lax.scan``
over sweeps, with the masked forward-backward / Kalman smoother vmapped
over the sequence batch INSIDE the scan body and a
:class:`~repro.obs.metrics.TemporalFitMetrics` pytree (per-sweep ELBO,
delta, active flag) carried out of the scan.  Convergence inside the scan
is a hold: once ``|e - last| < tol (|e| + 1)`` the posterior stops being
adopted, bit-matching the host loop that breaks.  ``fused=False`` keeps
the seed-style eager per-sweep loop (same step functions, one dispatch per
sweep) as the parity/benchmark reference.

**Program caching.**  The fused fits are MODULE-LEVEL jitted functions, so
jax's shape-keyed jit cache is the program cache: repeated ``update_model``
calls with the same ``(B, T, F, S, dtypes)`` reuse the compiled program
instead of retracing (the seed retraced per call via per-instance
closures).  :func:`trace_counts` exposes trace-time counters bumped inside
each fused body — a compile happens iff the counter moves, which is the
CI non-retrace assertion.

**Suff-stats backends.**  The HMM-family and fHMM M-steps accept
``backend="einsum" | "pallas"``; ``pallas`` routes the responsibility-
weighted regression stats through ``kernels.ops.clg_seq_suffstats`` (the
``clg_stats`` kernel with the ``[B, T]`` leading dims flattened), sharing
the static plate's kernel and its interpret/compile policy.
"""

from __future__ import annotations

from functools import partial
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import expfam as ef
from repro.core.factored_frontier import (Factorial2TBN,
                                          factored_frontier_filter,
                                          predictive_posterior)
from repro.data.stream import Attribute, DynamicDataStream, SequenceBatch, REAL
from repro.obs import sink as obs_sink
from repro.obs.metrics import StreamBatchMetrics, TemporalFitMetrics


# ---------------------------------------------------------------------------
# trace-time compile counters (the non-retrace CI assertion)
# ---------------------------------------------------------------------------

_TRACE_COUNTS: Dict[str, int] = {}


def _bump_trace(name: str) -> None:
    """Called INSIDE the jitted fused-fit bodies: runs once per trace
    (compile), never per cached execution — ``trace_counts()[name]``
    moving between two same-shape calls means the program was rebuilt."""
    _TRACE_COUNTS[name] = _TRACE_COUNTS.get(name, 0) + 1


def trace_counts() -> Dict[str, int]:
    """Snapshot of the fused-fit trace counters (per fused program name)."""
    return dict(_TRACE_COUNTS)


def _strong(tree):
    """Copy a pytree with weak types stripped (explicit-dtype ``jnp.array``).

    Two jobs at once for every fused-fit operand: (1) a weak-typed leaf
    (python-scalar initialised, e.g. ``jnp.asarray(0.3)``) and its
    strong-typed successor after one fit would key DIFFERENT compiled
    programs — the first refit would retrace; (2) the copy unaliases
    donated buffers (the chained prior IS the posterior after a fit, and
    XLA rejects donating an aliased or doubly-referenced buffer)."""
    return jax.tree_util.tree_map(
        lambda a: jnp.array(a, jnp.asarray(a).dtype), tree)


# ---------------------------------------------------------------------------
# masked forward-backward (shared by the HMM family)
# ---------------------------------------------------------------------------


def forward_backward(log_init: jnp.ndarray, log_trans: jnp.ndarray,
                     loglik: jnp.ndarray, mask: jnp.ndarray):
    """Single sequence. log_init [S], log_trans [S,S], loglik [T,S], mask [T].

    Returns (gamma [T,S], xi_sum [S,S], loglik_scalar).

    Padding semantics: masked steps HOLD the forward/backward state, their
    loglik values are never read (``where``-gated, so NaN/garbage padding
    is safe), and no transition is counted into or out of a padded step
    (``xi`` is masked by ``mask[t] * mask[t+1]``).  A LEFT-padded sequence
    seeds the recursion from ``log_init`` alone at its first observed step
    — the ``started`` flag below — rather than applying a spurious
    transition out of the padding."""
    S = log_init.shape[0]
    ll = jnp.where(mask[:, None] > 0, loglik, 0.0)   # NaN-safe padding

    def fstep(carry, inp):
        loga_prev, started = carry
        ll_t, m_t = inp
        trans_in = jax.nn.logsumexp(loga_prev[:, None] + log_trans, axis=0)
        # first observed step seeds from the initial distribution alone
        loga = jnp.where(started, trans_in, log_init) + ll_t
        loga = jnp.where(m_t > 0, loga, loga_prev)  # hold state over padding
        started = jnp.logical_or(started, m_t > 0)
        return (loga, started), loga

    _, logas = jax.lax.scan(
        fstep, (log_init, jnp.asarray(False)), (ll, mask))  # [T, S]
    logZ = jnp.where(mask.max() > 0, jax.nn.logsumexp(logas[-1]), 0.0)

    def bstep(carry, inp):
        logb_next = carry
        ll_t1, m_t1 = inp
        logb = jax.nn.logsumexp(
            log_trans + (ll_t1 + logb_next)[None, :], axis=1)
        logb = jnp.where(m_t1 > 0, logb, logb_next)
        return logb, logb

    logbT = jnp.zeros(S)
    _, logbs = jax.lax.scan(bstep, logbT, (ll[1:][::-1], mask[1:][::-1]))
    logbs = jnp.concatenate([logbs[::-1], logbT[None]], 0)  # [T, S]

    gamma = jax.nn.softmax(logas + logbs, axis=-1) * mask[:, None]

    # xi_t(i,j) ∝ a_t(i) T(i,j) l_{t+1}(j) b_{t+1}(j)
    logxi = (logas[:-1, :, None] + log_trans[None]
             + (ll[1:] + logbs[1:])[:, None, :])
    logxi = logxi - jax.nn.logsumexp(logxi, axis=(1, 2), keepdims=True)
    xi = jnp.exp(logxi) * (mask[1:] * mask[:-1])[:, None, None]
    return gamma, xi.sum(0), logZ


# ---------------------------------------------------------------------------
# HMM with (optionally regression-) Gaussian emissions
# ---------------------------------------------------------------------------


class HMMPosterior(NamedTuple):
    init: ef.Dirichlet        # [S]
    trans: ef.Dirichlet       # [S, S] rows
    emis: ef.MVNormalGamma    # [F, S, D] regression emission per feature/state


# -- class-agnostic step functions: every _HMMBase subclass reduces to a
#    (design d [B,T,F,D], target y [B,T,F]) pair, so ONE fused program per
#    shape serves the whole family ------------------------------------------


def _hmm_loglik(post: HMMPosterior, d: jnp.ndarray, y: jnp.ndarray
                ) -> jnp.ndarray:
    """[B, T, S] expected emission log-lik summed over features."""
    mom = ef.mvnormalgamma_moments(post.emis)     # [F, S, ...]
    quad = jnp.einsum("btfa,fsac,btfc->btfs", d, mom.e_lamww, d)
    lin = jnp.einsum("btfa,fsa->btfs", d, mom.e_lamw)
    ll = 0.5 * (
        mom.e_loglam[None, None] - ef.LOG2PI
        - mom.e_lam[None, None] * (y * y)[..., None]
        + 2.0 * y[..., None] * lin - quad
    )
    return ll.sum(2)


def _hmm_estep(post: HMMPosterior, d, y, mask):
    """Returns (gamma [B,T,S], xi [B,S,S], logZ [B])."""
    log_init = ef.dirichlet_expected_logprob(post.init)
    log_trans = ef.dirichlet_expected_logprob(post.trans)
    ll = _hmm_loglik(post, d, y)                  # [B, T, S]
    fb = jax.vmap(partial(forward_backward, log_init, log_trans))
    return fb(ll, mask)


def _hmm_mstep(prior: HMMPosterior, gamma, xi, d, y, mask,
               backend: str = "einsum") -> HMMPosterior:
    init = ef.Dirichlet(prior.init.alpha + gamma[:, 0].sum(0))
    trans = ef.Dirichlet(prior.trans.alpha + xi.sum(0))
    w = gamma * mask[..., None]                   # [B, T, S]
    if backend == "pallas":
        from repro.kernels import ops as kops
        sxx, sxy, syy = kops.clg_seq_suffstats(d, y, w)
    else:
        sxx = jnp.einsum("btfa,btfc,bts->fsac", d, d, w)
        sxy = jnp.einsum("btfa,btf,bts->fsa", d, y, w)
        syy = jnp.einsum("btf,btf,bts->fs", y, y, w)
    n = jnp.broadcast_to(w.sum((0, 1))[None], syy.shape)
    emis = ef.mvnormalgamma_update(
        prior.emis, ef.RegSuffStats(sxx, sxy, syy, n))
    return HMMPosterior(init=init, trans=trans, emis=emis)


def _hmm_fit_core(prior, post, d, y, mask, sweeps, tol, backend):
    """The sweep loop as a ``lax.scan`` with a convergence HOLD.

    Replicates the host loop exactly: the E/M step of the converging sweep
    is still adopted (the host ``break`` fires after the M-step), then the
    carry is held for the remaining scan steps.  Returns
    (post, last_elbo, TemporalFitMetrics with [sweeps] columns)."""

    def sweep(carry, _):
        post, last, done = carry
        gamma, xi, logZ = _hmm_estep(post, d, y, mask)
        e = logZ.sum()
        new_post = _hmm_mstep(prior, gamma, xi, d, y, mask, backend)
        conv = jnp.abs(e - last) < tol * (jnp.abs(e) + 1.0)
        active = jnp.logical_not(done)
        sel = lambda a, b: jnp.where(active, a, b)
        post = jax.tree_util.tree_map(sel, new_post, post)
        metrics = TemporalFitMetrics(
            elbo=jnp.where(active, e, last),
            delta=jnp.where(active, jnp.abs(e - last), 0.0),
            active=active,
        )
        last = jnp.where(active, jnp.where(conv, last, e), last)
        done = jnp.logical_or(done, conv)
        return (post, last, done), metrics

    carry0 = (post, -jnp.inf, jnp.asarray(False))
    (post, last, _), metrics = jax.lax.scan(
        sweep, carry0, None, length=sweeps)
    return post, last, metrics


@partial(jax.jit, static_argnames=("sweeps", "tol", "backend"),
         donate_argnums=(1,))
def _hmm_fit(prior, post, d, y, mask, *, sweeps, tol, backend):
    """One fused VB-EM fit for the whole HMM family.

    Module-level jit => the jit cache IS the program cache, keyed on the
    shapes/dtypes of (prior, post, d, y, mask) — i.e. (B, T, F, S, D,
    dtypes) — plus the static (sweeps, tol, backend).  ``post`` is donated
    (callers pass an unaliased copy)."""
    _bump_trace("hmm_fit")
    return _hmm_fit_core(prior, post, d, y, mask, sweeps, tol, backend)


def _hmm_filter_predict(post: HMMPosterior, d, y, mask, horizon: int):
    """Filtered beliefs + h-step predictive for a sequence batch.

    Returns (beliefs [B,T,S], last [B,S]) where ``last`` is the filtered
    distribution at the final step rolled ``horizon`` steps forward with no
    evidence (paper Code Fragment 14).  Pure function of the posterior —
    the serving layer jits it with the posterior as an ARGUMENT so model
    updates never serve stale compiled constants."""
    ll = _hmm_loglik(post, d, y)
    init = jax.nn.softmax(ef.dirichlet_expected_logprob(post.init))
    trans = jax.nn.softmax(ef.dirichlet_expected_logprob(post.trans), -1)
    model = Factorial2TBN(init=init[None], trans=trans[None])

    def one(seq_ll, seq_mask):
        beliefs, _ = factored_frontier_filter(
            model, seq_ll[:, None, :], seq_mask)
        return beliefs[:, 0]

    beliefs = jax.vmap(one)(ll, mask)
    last = beliefs[:, -1]
    if horizon > 0:
        last = jax.vmap(
            lambda b: predictive_posterior(model, b[None], horizon)[0])(last)
    return beliefs, last


@partial(jax.jit, static_argnames=("horizon",))
def _temporal_serve(post, d, y, mask, *, horizon):
    """The compiled temporal query program (``PGMQueryEngine``
    ``mode="temporal"``): one program per (B, T, F, S, horizon) bucket,
    cached by the module-level jit like the fused fits."""
    _bump_trace("temporal_serve")
    return _hmm_filter_predict(post, d, y, mask, horizon)


def _emit_fit_event(name: str, elbo, metrics: TemporalFitMetrics) -> None:
    if not obs_sink.enabled():
        return
    act = np.asarray(metrics.active)
    dl = np.asarray(metrics.delta)
    k = int(act.sum())
    obs_sink.emit("temporal_fit", model=name, sweeps=k, elbo=float(elbo),
                  delta=float(dl[max(k - 1, 0)]) if dl.size else 0.0)


class _HMMBase:
    """Shared machinery; subclasses define the emission design vector."""

    design_dim = 1  # bias only (plain Gaussian emission)

    def __init__(self, attributes, n_states: int = 2, *, seed: int = 0,
                 alpha0: float = 1.0, a0: float = 1.0, b0: float = 1.0):
        self.attributes = list(attributes)
        self.F = len([a for a in attributes if a.kind == REAL])
        self.S = n_states
        D = self.design_dim
        self.prior = HMMPosterior(
            init=ef.Dirichlet(jnp.full((self.S,), alpha0)),
            trans=ef.Dirichlet(jnp.full((self.S, self.S), alpha0)),
            emis=ef.MVNormalGamma(
                m=jnp.zeros((self.F, self.S, D)),
                K=jnp.broadcast_to(jnp.eye(D), (self.F, self.S, D, D)),
                a=jnp.full((self.F, self.S), a0),
                b=jnp.full((self.F, self.S), b0),
            ),
        )
        key = jax.random.PRNGKey(seed)
        m0 = self.prior.emis.m + jax.random.normal(
            key, self.prior.emis.m.shape)
        self.posterior = self.prior._replace(emis=self.prior.emis._replace(m=m0))
        self._chained_prior = self.prior

    # -- emission design: [B, T, F, D] / target: [B, T, F] -------------------

    def _design(self, xc: jnp.ndarray) -> jnp.ndarray:
        B, T, F = xc.shape
        return jnp.ones((B, T, F, 1), xc.dtype)

    def _emission_target(self, xc: jnp.ndarray) -> jnp.ndarray:
        return xc

    def _emission_loglik(self, post: HMMPosterior, xc: jnp.ndarray
                         ) -> jnp.ndarray:
        return _hmm_loglik(post, self._design(xc), self._emission_target(xc))

    def _estep(self, post: HMMPosterior, xc, mask):
        return _hmm_estep(post, self._design(xc),
                          self._emission_target(xc), mask)

    def _warm_start(self, xc: jnp.ndarray) -> None:
        """Data-driven symmetry breaking: bias term <- random observed
        frames (first fit only)."""
        if getattr(self, "_warm", False):
            return
        self._warm = True
        rng = np.random.default_rng(13)
        frames_all = xc[..., : self.F]   # emission columns (IOHMM: drops input)
        B, T, F = frames_all.shape
        picks = rng.integers(0, B * T, self.S)
        frames = np.asarray(frames_all.reshape(B * T, F))[picks]    # [S, F]
        m0 = np.array(self.posterior.emis.m)  # writable copy
        m0[:, :, 0] = frames.T
        self.posterior = self.posterior._replace(
            emis=self.posterior.emis._replace(m=jnp.asarray(m0)))

    # -- public API -----------------------------------------------------------

    def update_model(self, data, *, sweeps: int = 30, tol: float = 1e-5,
                     fused: bool = True, backend: str = "einsum") -> float:
        batch = data.collect() if isinstance(data, DynamicDataStream) else data
        xc, mask = batch.xc, batch.mask
        self._warm_start(xc)
        prior = self._chained_prior
        post = self.posterior
        d = self._design(xc)
        y = self._emission_target(xc)
        if fused:
            post, last, metrics = _hmm_fit(_strong(prior), _strong(post),
                                           d, y, mask,
                                           sweeps=sweeps, tol=tol,
                                           backend=backend)
            last = float(last)
        else:
            last, elbos, deltas = -np.inf, [], []
            for _ in range(sweeps):
                gamma, xi, logZ = _hmm_estep(post, d, y, mask)
                e = float(logZ.sum())
                post = _hmm_mstep(prior, gamma, xi, d, y, mask, backend)
                elbos.append(e)
                deltas.append(abs(e - last))
                if abs(e - last) < tol * (abs(e) + 1.0):
                    break
                last = e
            metrics = TemporalFitMetrics(
                elbo=np.asarray(elbos), delta=np.asarray(deltas),
                active=np.ones(len(elbos), bool))
        self.posterior = post
        self._chained_prior = post     # Eq. 3
        self.fit_metrics = metrics
        _emit_fit_event(type(self).__name__, last, metrics)
        return last

    def filtered_posterior(self, xc: jnp.ndarray, mask=None) -> jnp.ndarray:
        """[B, T, S] filtering distributions (Code Fragment 14 analog)."""
        if mask is None:
            mask = jnp.ones(xc.shape[:2])
        beliefs, _ = _hmm_filter_predict(
            self.posterior, self._design(xc), self._emission_target(xc),
            mask, 0)
        return beliefs

    def predictive(self, xc: jnp.ndarray, horizon: int,
                   mask=None) -> jnp.ndarray:
        """[B, S] state distribution ``horizon`` steps past the end of each
        sequence (getPredictivePosterior)."""
        if mask is None:
            mask = jnp.ones(xc.shape[:2])
        _, last = _hmm_filter_predict(
            self.posterior, self._design(xc), self._emission_target(xc),
            mask, horizon)
        return last

    def viterbi_states(self, xc) -> jnp.ndarray:
        g, _, _ = self._estep(self.posterior, xc, jnp.ones(xc.shape[:2]))
        return g.argmax(-1)

    def state_means(self) -> np.ndarray:
        """[S, F] emission means (bias term of the regression)."""
        return np.asarray(self.posterior.emis.m[:, :, 0]).T


class HiddenMarkovModel(_HMMBase):
    """Plain Gaussian-emission HMM."""


class AutoRegressiveHMM(_HMMBase):
    """Emission mean = w_s^T [1, x_{t-1,f}] (per feature) — AR(1) per state."""

    design_dim = 2

    def _design(self, xc):
        B, T, F = xc.shape
        prev = jnp.concatenate([jnp.zeros((B, 1, F), xc.dtype), xc[:, :-1]], 1)
        return jnp.stack([jnp.ones_like(prev), prev], -1)   # [B,T,F,2]


class InputOutputHMM(_HMMBase):
    """Emission mean = w_s^T [1, u_t] with exogenous input u (last column)."""

    design_dim = 2

    def __init__(self, attributes, n_states: int = 2, **kw):
        super().__init__(attributes, n_states, **kw)
        self.F = self.F - 1  # last REAL column is the input, not an emission
        # rebuild priors with the reduced F
        D = self.design_dim
        self.prior = self.prior._replace(emis=ef.MVNormalGamma(
            m=jnp.zeros((self.F, self.S, D)),
            K=jnp.broadcast_to(jnp.eye(D), (self.F, self.S, D, D)),
            a=jnp.full((self.F, self.S), kw.get("a0", 1.0)),
            b=jnp.full((self.F, self.S), kw.get("b0", 1.0)),
        ))
        key = jax.random.PRNGKey(kw.get("seed", 0))
        m0 = self.prior.emis.m + jax.random.normal(key, self.prior.emis.m.shape)
        self.posterior = self.prior._replace(
            emis=self.prior.emis._replace(m=m0))
        self._chained_prior = self.prior

    def _split(self, xc):
        return xc[..., :-1], xc[..., -1]

    def _emission_target(self, xc):
        return self._split(xc)[0]

    def _design(self, xc):
        y, u = self._split(xc)
        B, T, F = y.shape
        ones = jnp.ones((B, T, F, 1), xc.dtype)
        uu = jnp.broadcast_to(u[..., None, None], (B, T, F, 1))
        return jnp.concatenate([ones, uu], -1)


class DynamicNaiveBayes(_HMMBase):
    """Dynamic NB = HMM whose hidden class smooths over time; emissions are
    NB-style independent Gaussians — structurally our plain HMM (the paper's
    dynamic NB is exactly this 2TBN)."""


# ---------------------------------------------------------------------------
# sequence-batch streaming (Eq. 3 over SequenceBatch streams)
# ---------------------------------------------------------------------------


def _temper_hmm(params: HMMPosterior, base: HMMPosterior,
                rho: float) -> HMMPosterior:
    """Forgetting for the HMM posterior: geometric interpolation toward the
    base prior in natural-ish coordinates — Dirichlet alphas and the
    MVNormalGamma (K, K m, a, b) blocks are lerped, then the mean is
    recovered from the mixed precision (the temporal analog of
    ``streaming._temper``)."""
    lerp = lambda a, b: rho * a + (1.0 - rho) * b
    K = lerp(params.emis.K, base.emis.K)
    Km = lerp(jnp.einsum("...ac,...c->...a", params.emis.K, params.emis.m),
              jnp.einsum("...ac,...c->...a", base.emis.K, base.emis.m))
    m = jnp.linalg.solve(K, Km[..., None])[..., 0]
    emis = ef.MVNormalGamma(m=m, K=K, a=lerp(params.emis.a, base.emis.a),
                            b=lerp(params.emis.b, base.emis.b))
    return HMMPosterior(
        init=ef.Dirichlet(lerp(params.init.alpha, base.init.alpha)),
        trans=ef.Dirichlet(lerp(params.trans.alpha, base.trans.alpha)),
        emis=emis)


@partial(jax.jit,
         static_argnames=("sweeps", "tol", "drift_threshold", "forget",
                          "backend"),
         donate_argnums=(0,))
def _seq_stream_scan(state, base_prior, ds, ys, masks, *, sweeps, tol,
                     drift_threshold, forget, backend):
    from repro.core.streaming import drift_gate, tree_finite

    _bump_trace("seq_stream_fit")

    def step(carry, inp):
        d, y, mask = inp
        prior0, post0, dstate0, n_drifts, n_quar = carry
        n_eff = mask.sum()
        # score the batch under the CURRENT posterior (per-frame loglik)
        _, _, logZ = _hmm_estep(post0, d, y, mask)
        score = logZ.sum() / jnp.maximum(n_eff, 1.0)
        prior, dstate, ph, drifted = drift_gate(
            dstate0, score, prior0, _temper_hmm(prior0, base_prior, forget),
            drift_threshold=drift_threshold)
        post, last, fmetrics = _hmm_fit_core(
            prior, post0, d, y, mask, sweeps, tol, backend)
        # non-finite quarantine: a poisoned batch holds the carried
        # posterior/prior AND the PH state (a NaN score would corrupt the
        # detector) — same static-shape HOLD trick as the sweep scans.
        healthy = jnp.logical_and(jnp.isfinite(score), jnp.isfinite(last))
        healthy = jnp.logical_and(healthy, tree_finite(post))
        drifted = jnp.logical_and(drifted, healthy)
        sel = lambda new, old: jax.tree_util.tree_map(
            lambda a, b: jnp.where(healthy, a, b), new, old)
        zero = jnp.asarray(0.0)
        metrics = StreamBatchMetrics(
            elbo=jnp.where(healthy, last, zero),
            score=jnp.where(healthy, score, zero),
            ph=jnp.where(healthy, ph, zero),
            drifted=drifted, n_eff=n_eff,
            rho=jnp.where(drifted, forget, 1.0),
            sweeps=fmetrics.active.sum(),
            quarantined=jnp.logical_not(healthy),
        )
        carry = (sel(post, prior0),     # Eq. 3: posterior becomes the prior
                 sel(post, post0), sel(dstate, dstate0),
                 n_drifts + drifted.astype(jnp.int32),
                 n_quar + jnp.logical_not(healthy).astype(jnp.int32))
        return carry, metrics.as_info()

    (prior, post, dstate, n_drifts, n_quar), info = jax.lax.scan(
        step, state + (jnp.asarray(0, jnp.int32),
                       jnp.asarray(0, jnp.int32)), (ds, ys, masks))
    return (prior, post, dstate, n_drifts, n_quar), info


def seq_stream_fit(model, batches, *, sweeps: int = 10, tol: float = 1e-5,
                   drift_threshold: float = 5.0, forget: float = 0.3,
                   backend: str = "einsum"):
    """Replay a stream of ``SequenceBatch``es in ONE jitted ``lax.scan``.

    The temporal ``stream_fit``: per batch the scan body scores the
    incoming sequences under the current posterior, runs the Page-Hinkley
    drift gate (tempering the chained prior on a firing), fits with the
    fused sweep scan, and chains the posterior (Eq. 3).  ``model`` is any
    ``_HMMBase`` subclass; it is updated in place and the per-batch
    :class:`StreamBatchMetrics` columns are returned as an info dict (and
    emitted as ``stream_batch``/``drift`` JSONL events when obs is on).

    ``batches``: iterable of equal-shape ``SequenceBatch``es (e.g.
    ``DynamicDataStream.batches(B)``, which pads the tail batch).
    """
    batches = list(batches)
    if not batches:
        raise ValueError("seq_stream_fit needs at least one batch")
    model._warm_start(batches[0].xc)
    ds = jnp.stack([model._design(b.xc) for b in batches])
    ys = jnp.stack([model._emission_target(b.xc) for b in batches])
    masks = jnp.stack([b.mask for b in batches])
    from repro.core.streaming import drift_init
    state = _strong((model._chained_prior, model.posterior, drift_init()))
    (prior, post, _, n_drifts, n_quar), info = _seq_stream_scan(
        state, _strong(model.prior), ds, ys, masks, sweeps=sweeps, tol=tol,
        drift_threshold=drift_threshold, forget=forget, backend=backend)
    model.posterior = post
    model._chained_prior = post
    model.n_drifts = int(n_drifts)
    model.n_quarantined = int(n_quar)
    if obs_sink.enabled():
        obs_sink.emit_stream_events(info)
        obs_sink.emit_kernel_counts(site="seq_stream_fit")
    return info


# ---------------------------------------------------------------------------
# factorial HMM — chain-parallel structured VB
# ---------------------------------------------------------------------------


def _fhmm_sweep(means, log_trans, log_init, noise, gammas, xc, mask, backend):
    """One Jacobi sweep over ALL chains at once.

    Every chain's residual is computed from the PREVIOUS sweep's gammas and
    means (chain-batched einsum), the per-chain forward-backward runs as a
    nested vmap over (chains, sequences), and the M-step is one batched
    responsibility-weighted regression (einsum or the clg_stats kernel)."""
    B, T, F = xc.shape
    C, S = means.shape[0], means.shape[1]
    contrib = jnp.einsum("btcs,csf->btcf", gammas, means)
    resid = xc[:, :, None, :] - (contrib.sum(2, keepdims=True) - contrib)
    diff = resid[:, :, :, None, :] - means[None, None]       # [B,T,C,S,F]
    ll = (-(0.5 / noise) * (diff ** 2).sum(-1)
          - 0.5 * F * jnp.log(2 * jnp.pi * noise))           # [B,T,C,S]

    def fb_chain(li, lt, ll_c):
        return jax.vmap(partial(forward_backward, li, lt))(ll_c, mask)

    g, xi, logZ = jax.vmap(fb_chain, in_axes=(0, 0, 2))(
        log_init, log_trans, ll)          # [C,B,T,S], [C,B,S,S], [C,B]
    gammas_new = jnp.moveaxis(g, 0, 2)    # [B,T,C,S]
    w = gammas_new * mask[:, :, None, None]
    if backend == "pallas":
        from repro.kernels import ops as kops
        dsn = jnp.ones((B, T, F, 1), xc.dtype)
        _, sxy, _ = jax.vmap(kops.clg_seq_suffstats,
                             in_axes=(None, 2, 2))(dsn, resid, w)
        num = jnp.swapaxes(sxy[..., 0], 1, 2)                # [C,S,F]
    else:
        num = jnp.einsum("btcs,btcf->csf", w, resid)
    denom = jnp.maximum(w.sum((0, 1)), 1e-6)[..., None]      # [C,S,1]
    means_new = num / denom
    xs_sum = xi.sum(1)                                       # [C,S,S]
    log_trans_new = (
        jnp.log(jnp.maximum(xs_sum + 1.0, 1e-6))
        - jnp.log(jnp.maximum(xs_sum.sum(-1, keepdims=True) + S, 1e-6)))
    return means_new, log_trans_new, gammas_new, logZ.sum()


@partial(jax.jit, static_argnames=("sweeps", "tol", "backend"),
         donate_argnums=(0,))
def _fhmm_fit(params, log_init, noise, xc, mask, *, sweeps, tol, backend):
    _bump_trace("fhmm_fit")
    means, log_trans, gammas = params

    def sweep(carry, _):
        means, log_trans, gammas, last, done = carry
        m2, lt2, g2, e = _fhmm_sweep(means, log_trans, log_init, noise,
                                     gammas, xc, mask, backend)
        conv = jnp.abs(e - last) < tol * (jnp.abs(e) + 1.0)
        active = jnp.logical_not(done)
        sel = lambda a, b: jnp.where(active, a, b)
        means, log_trans, gammas = jax.tree_util.tree_map(
            sel, (m2, lt2, g2), (means, log_trans, gammas))
        metrics = TemporalFitMetrics(
            elbo=jnp.where(active, e, last),
            delta=jnp.where(active, jnp.abs(e - last), 0.0),
            active=active)
        last = jnp.where(active, jnp.where(conv, last, e), last)
        return (means, log_trans, gammas, last,
                jnp.logical_or(done, conv)), metrics

    carry0 = (means, log_trans, gammas, -jnp.inf, jnp.asarray(False))
    (means, log_trans, gammas, last, _), metrics = jax.lax.scan(
        sweep, carry0, None, length=sweeps)
    return means, log_trans, gammas, last, metrics


class FactorialHMMModel:
    """Factorial HMM: C independent chains, joint Gaussian emission.

    Learnt with the factored-frontier mean-field: each chain's E-step sees
    the residual of the other chains' expected contributions (standard VB
    for fHMM, Ghahramani & Jordan 1997).  Chain updates are JACOBI (all
    chains from the previous sweep's state), which is what lets the fused
    path batch every chain through one nested-vmap forward-backward."""

    def __init__(self, attributes, n_chains: int = 2, n_states: int = 2,
                 *, seed: int = 0):
        self.F = len([a for a in attributes if a.kind == REAL])
        self.C, self.S = n_chains, n_states
        key = jax.random.PRNGKey(seed)
        self.means = jax.random.normal(key, (self.C, self.S, self.F))
        self.log_trans = jnp.log(jnp.full((self.C, self.S, self.S), 1.0 / n_states))
        self.log_init = jnp.log(jnp.full((self.C, self.S), 1.0 / n_states))
        self.noise = jnp.asarray(1.0)

    def update_model(self, data, *, sweeps: int = 15, tol: float = 0.0,
                     fused: bool = True, backend: str = "einsum") -> float:
        batch = data.collect() if isinstance(data, DynamicDataStream) else data
        xc, mask = batch.xc, batch.mask            # [B,T,F], [B,T]
        B, T, F = xc.shape
        gammas = jnp.full((B, T, self.C, self.S), 1.0 / self.S)
        if fused:
            params = _strong((self.means, self.log_trans, gammas))
            means, log_trans, gammas, last, metrics = _fhmm_fit(
                params, _strong(self.log_init), _strong(self.noise),
                xc, mask, sweeps=sweeps, tol=tol, backend=backend)
            last = float(last)
        else:
            last, elbos, deltas = -np.inf, [], []
            means, log_trans = self.means, self.log_trans
            for _ in range(sweeps):
                means, log_trans, gammas, e = _fhmm_sweep(
                    means, log_trans, self.log_init, self.noise, gammas,
                    xc, mask, backend)
                e = float(e)
                elbos.append(e)
                deltas.append(abs(e - last))
                if abs(e - last) < tol * (abs(e) + 1.0):
                    break
                last = e
            metrics = TemporalFitMetrics(
                elbo=np.asarray(elbos), delta=np.asarray(deltas),
                active=np.ones(len(elbos), bool))
        self.means, self.log_trans, self.gammas = means, log_trans, gammas
        self.fit_metrics = metrics
        _emit_fit_event(type(self).__name__, last, metrics)
        return last


# ---------------------------------------------------------------------------
# Kalman filter (LDS) and switching LDS
# ---------------------------------------------------------------------------


def _kalman_smooth(A, C, q, r, xs, mask):
    """Masked Kalman smoother for one sequence.

    xs [T, F], mask [T] -> (means [T, L], covs [T, L, L], pair moments
    [T-1, L, L], loglik).  Masked steps run the time update only (predict,
    no correction, no loglik contribution); their observation values are
    never read."""
    L = A.shape[0]
    F = C.shape[0]
    Q = q * jnp.eye(L)
    R = r * jnp.eye(F)

    def fstep(carry, inp):
        x_t, m_t = inp
        m, P, ll = carry
        mp = A @ m
        Pp = A @ P @ A.T + Q
        S = C @ Pp @ C.T + R
        Sinv = jnp.linalg.inv(S)
        Kg = Pp @ C.T @ Sinv
        innov = jnp.where(m_t > 0, x_t, 0.0) - C @ mp
        m_new = jnp.where(m_t > 0, mp + Kg @ innov, mp)
        P_new = jnp.where(m_t > 0, (jnp.eye(L) - Kg @ C) @ Pp, Pp)
        _, logdet = jnp.linalg.slogdet(S)
        ll_new = ll - jnp.where(
            m_t > 0,
            0.5 * (logdet + innov @ Sinv @ innov + F * jnp.log(2 * jnp.pi)),
            0.0)
        return (m_new, P_new, ll_new), (m_new, P_new, mp, Pp)

    m0 = jnp.zeros(L)
    P0 = jnp.eye(L)
    (mT, PT, ll), (fm, fP, pm, pP) = jax.lax.scan(
        fstep, (m0, P0, 0.0), (xs, mask))

    def bstep(carry, inp):
        ms_next, Ps_next = carry
        fm_t, fP_t, pm_t1, pP_t1 = inp
        J = fP_t @ A.T @ jnp.linalg.inv(pP_t1)
        ms = fm_t + J @ (ms_next - pm_t1)
        Ps = fP_t + J @ (Ps_next - pP_t1) @ J.T
        pair = J @ Ps_next  # Cov(h_t, h_{t+1})
        return (ms, Ps), (ms, Ps, pair)

    (m1, P1), (sm, sP, pair) = jax.lax.scan(
        bstep, (fm[-1], fP[-1]),
        (fm[:-1], fP[:-1], pm[1:], pP[1:]), reverse=True)
    sm = jnp.concatenate([sm, fm[-1][None]], 0)
    sP = jnp.concatenate([sP, fP[-1][None]], 0)
    return sm, sP, pair, ll


def _kf_mstep(sm, sP, pair, xs, mask):
    """Masked LDS M-step (regressions + noise).  With an all-ones mask this
    is numerically identical to the seed's unweighted sums."""
    B, T, L = sm.shape
    F = xs.shape[-1]
    w = mask
    wl = mask[:, 1:] * mask[:, :-1]
    Ehh = sP + sm[..., :, None] * sm[..., None, :]            # [B,T,L,L]
    Ehh_lag = pair + sm[:, :-1, :, None] * sm[:, 1:, None, :]
    # transition regression: h_t on h_{t-1}
    Sxx = jnp.einsum("bt,btlm->lm", wl, Ehh[:, :-1]) + jnp.eye(L)
    Sxy = jnp.einsum("bt,btlm->lm", wl, Ehh_lag)              # [L, L] (t,t+1)
    A = jnp.linalg.solve(Sxx, Sxy).T
    # emission regression: x_t on h_t
    Hxx = jnp.einsum("bt,btlm->lm", w, Ehh) + jnp.eye(L)
    Hxy = jnp.einsum("bt,btl,btf->lf", w, sm, xs)
    C = jnp.linalg.solve(Hxx, Hxy).T
    # noise variances
    n = jnp.maximum(w.sum(), 1.0)
    nl = jnp.maximum(wl.sum(), 1.0)
    resid = xs - jnp.einsum("fl,btl->btf", C, sm)
    r = jnp.maximum(
        jnp.einsum("bt,btf->", w, resid ** 2) / (n * F)
        + jnp.einsum("fl,bt,btlm,fm->", C, w, sP, C) / (n * F), 1e-4)
    dyn = sm[:, 1:] - jnp.einsum("lm,btm->btl", A, sm[:, :-1])
    q = jnp.maximum(jnp.einsum("bt,btl->", wl, dyn ** 2) / (nl * L), 1e-4)
    return A, C, q, r


@partial(jax.jit, static_argnames=("sweeps", "tol"), donate_argnums=(0,))
def _kf_fit(params, xs, mask, *, sweeps, tol):
    _bump_trace("kf_fit")
    A, C, q, r = params
    B, T, F = xs.shape
    L = A.shape[0]

    def sweep(carry, _):
        A, C, q, r, sm_keep, last, done = carry
        sm, sP, pair, lls = jax.vmap(
            partial(_kalman_smooth, A, C, q, r))(xs, mask)
        e = lls.sum()
        A2, C2, q2, r2 = _kf_mstep(sm, sP, pair, xs, mask)
        conv = jnp.abs(e - last) < tol * (jnp.abs(e) + 1.0)
        active = jnp.logical_not(done)
        sel = lambda a, b: jnp.where(active, a, b)
        A, C, q, r, sm_keep = jax.tree_util.tree_map(
            sel, (A2, C2, q2, r2, sm), (A, C, q, r, sm_keep))
        metrics = TemporalFitMetrics(
            elbo=jnp.where(active, e, last),
            delta=jnp.where(active, jnp.abs(e - last), 0.0),
            active=active)
        last = jnp.where(active, jnp.where(conv, last, e), last)
        return (A, C, q, r, sm_keep, last,
                jnp.logical_or(done, conv)), metrics

    sm0 = jnp.zeros((B, T, L), xs.dtype)
    carry0 = (A, C, q, r, sm0, -jnp.inf, jnp.asarray(False))
    (A, C, q, r, sm, last, _), metrics = jax.lax.scan(
        sweep, carry0, None, length=sweeps)
    return A, C, q, r, sm, last, metrics


class KalmanFilter:
    """Linear dynamical system learnt by Bayesian EM (Code Fragment 10).

    h_t = A h_{t-1} + w,  x_t = C h_t + v; q(A_rows), q(C_rows) are
    MVNormalGamma; q(h_{1:T}) from Kalman smoothing at the posterior mean.
    """

    def __init__(self, attributes, n_hidden: int = 2, *, seed: int = 0):
        self.F = len([a for a in attributes if a.kind == REAL])
        self.L = n_hidden
        key1, key2 = jax.random.split(jax.random.PRNGKey(seed))
        L, F = self.L, self.F
        self.A = 0.5 * jnp.eye(L) + 0.01 * jax.random.normal(key1, (L, L))
        self.C = jax.random.normal(key2, (F, L))
        self.q = jnp.asarray(0.3)   # process noise var
        self.r = jnp.asarray(0.3)   # obs noise var
        # Bayesian accumulators (prior precision for A and C rows)
        self.KA = jnp.broadcast_to(jnp.eye(L), (L, L, L))
        self.KC = jnp.broadcast_to(jnp.eye(L), (F, L, L))

    def set_num_hidden(self, n: int) -> "KalmanFilter":
        self.__init__([Attribute(f"G{i}", REAL) for i in range(self.F)], n)
        return self

    def _smooth(self, xs: jnp.ndarray):
        """xs [T, F] -> means [T, L], covs [T, L, L], pair moments, loglik."""
        return _kalman_smooth(self.A, self.C, self.q, self.r, xs,
                              jnp.ones(xs.shape[0]))

    def update_model(self, data, *, sweeps: int = 25, tol: float = 0.0,
                     fused: bool = True) -> float:
        batch = data.collect() if isinstance(data, DynamicDataStream) else data
        xs, mask = batch.xc, batch.mask              # [B, T, F], [B, T]
        B, T, F = xs.shape
        L = self.L
        if not getattr(self, "_warm", False):
            # PCA warm start: C <- top-L principal axes, A <- lag-1 regression
            self._warm = True
            flat = np.asarray(xs.reshape(B * T, F))
            flat = flat - flat.mean(0)
            _, _, vt = np.linalg.svd(flat, full_matrices=False)
            C0 = vt[:L].T                            # [F, L]
            scores = flat @ C0                       # [B*T, L]
            sc = scores.reshape(B, T, L)
            xlag = sc[:, :-1].reshape(-1, L)
            xnext = sc[:, 1:].reshape(-1, L)
            A0 = np.linalg.lstsq(xlag, xnext, rcond=None)[0].T
            self.C = jnp.asarray(C0, jnp.float32)
            self.A = jnp.asarray(A0, jnp.float32)
        if fused:
            params = _strong((self.A, self.C, self.q, self.r))
            A, C, q, r, sm, last, metrics = _kf_fit(
                params, xs, mask, sweeps=sweeps, tol=tol)
            self.A, self.C, self.q, self.r = A, C, q, r
            last = float(last)
        else:
            last, elbos, deltas = -np.inf, [], []
            sm = None
            for _ in range(sweeps):
                sm, sP, pair, lls = jax.vmap(partial(
                    _kalman_smooth, self.A, self.C, self.q, self.r))(xs, mask)
                e = float(lls.sum())
                self.A, self.C, self.q, self.r = _kf_mstep(
                    sm, sP, pair, xs, mask)
                elbos.append(e)
                deltas.append(abs(e - last))
                if abs(e - last) < tol * (abs(e) + 1.0):
                    break
                last = e
            metrics = TemporalFitMetrics(
                elbo=np.asarray(elbos), delta=np.asarray(deltas),
                active=np.ones(len(elbos), bool))
        self.smoothed = sm
        self.fit_metrics = metrics
        _emit_fit_event(type(self).__name__, last, metrics)
        return last

    def get_model(self):
        return {"A": self.A, "C": self.C, "q": self.q, "r": self.r}

    def filtered_states(self, xs: jnp.ndarray) -> jnp.ndarray:
        masks = jnp.ones(xs.shape[:2])
        sm, _, _, _ = jax.vmap(partial(
            _kalman_smooth, self.A, self.C, self.q, self.r))(xs, masks)
        return sm


def _slds_sweep(A, C, q, r, log_trans, resp, xs, mask):
    """One structured-VB sweep: q(h) under switch-averaged dynamics, q(s)
    from innovation logliks via the masked factored-frontier filter, then
    a STATE-BATCHED M-step (one [S]-batched linear solve instead of the
    seed's per-state Python loop)."""
    B, T, F = xs.shape
    S, L = A.shape[0], A.shape[1]
    w_all = resp * mask[..., None]
    Abar = jnp.einsum("bts,slm->lm", w_all, A) / jnp.maximum(mask.sum(), 1.0)
    sm, sP, pair, lls = jax.vmap(
        partial(_kalman_smooth, Abar, C, q, r))(xs, mask)
    e = lls.sum()
    # q(s): innovation loglik per switch state
    pred = jnp.einsum("slm,btm->btsl", A, sm[:, :-1])
    innov = sm[:, 1:, None, :] - pred                 # [B,T-1,S,L]
    loglik = -0.5 * (innov ** 2).sum(-1) / q
    loglik = jnp.concatenate([jnp.zeros((B, 1, S), xs.dtype), loglik], axis=1)
    model = Factorial2TBN(init=jnp.full((1, S), 1.0 / S),
                          trans=jnp.exp(log_trans)[None])

    def one(seq_ll, seq_mask):
        beliefs, _ = factored_frontier_filter(
            model, seq_ll[:, None, :], seq_mask)
        return beliefs[:, 0]

    resp2 = jax.vmap(one)(loglik, mask)
    # M-step: per-switch-state transition regression, batched over S
    Ehh = sP + sm[..., :, None] * sm[..., None, :]
    Ehh_lag = pair + sm[:, :-1, :, None] * sm[:, 1:, None, :]
    wl = mask[:, 1:] * mask[:, :-1]
    ws = resp2[:, 1:] * wl[..., None]                 # [B,T-1,S]
    Sxx = jnp.einsum("bts,btlm->slm", ws, Ehh[:, :-1]) + jnp.eye(L)
    Sxy = jnp.einsum("bts,btlm->slm", ws, Ehh_lag)
    A2 = jnp.swapaxes(jnp.linalg.solve(Sxx, Sxy), -1, -2)
    # shared emission + noises (as in KalmanFilter)
    Hxx = jnp.einsum("bt,btlm->lm", mask, Ehh) + jnp.eye(L)
    Hxy = jnp.einsum("bt,btl,btf->lf", mask, sm, xs)
    C2 = jnp.linalg.solve(Hxx, Hxy).T
    n = jnp.maximum(mask.sum(), 1.0)
    nl = jnp.maximum(wl.sum(), 1.0)
    resid = xs - jnp.einsum("fl,btl->btf", C2, sm)
    r2 = jnp.maximum(jnp.einsum("bt,btf->", mask, resid ** 2) / (n * F), 1e-4)
    dyn = sm[:, 1:] - jnp.einsum(
        "bts,slm,btm->btl", resp2[:, 1:], A2, sm[:, :-1])
    q2 = jnp.maximum(jnp.einsum("bt,btl->", wl, dyn ** 2) / (nl * L), 1e-4)
    return A2, C2, q2, r2, resp2, sm, e


@partial(jax.jit, static_argnames=("sweeps", "tol"), donate_argnums=(0,))
def _slds_fit(params, log_trans, xs, mask, *, sweeps, tol):
    _bump_trace("slds_fit")
    A, C, q, r, resp = params
    B, T, _ = xs.shape
    L = A.shape[1]

    def sweep(carry, _):
        A, C, q, r, resp, sm_keep, last, done = carry
        A2, C2, q2, r2, resp2, sm, e = _slds_sweep(
            A, C, q, r, log_trans, resp, xs, mask)
        conv = jnp.abs(e - last) < tol * (jnp.abs(e) + 1.0)
        active = jnp.logical_not(done)
        sel = lambda a, b: jnp.where(active, a, b)
        A, C, q, r, resp, sm_keep = jax.tree_util.tree_map(
            sel, (A2, C2, q2, r2, resp2, sm), (A, C, q, r, resp, sm_keep))
        metrics = TemporalFitMetrics(
            elbo=jnp.where(active, e, last),
            delta=jnp.where(active, jnp.abs(e - last), 0.0),
            active=active)
        last = jnp.where(active, jnp.where(conv, last, e), last)
        return (A, C, q, r, resp, sm_keep, last,
                jnp.logical_or(done, conv)), metrics

    sm0 = jnp.zeros((B, T, L), xs.dtype)
    carry0 = (A, C, q, r, resp, sm0, -jnp.inf, jnp.asarray(False))
    (A, C, q, r, resp, sm, last, _), metrics = jax.lax.scan(
        sweep, carry0, None, length=sweeps)
    return A, C, q, r, resp, sm, last, metrics


class SwitchingLDS:
    """Switching LDS: discrete switch s_t selects the dynamics matrix A_s.

    Structured mean-field: q(s) (factored frontier over the switch chain,
    using expected innovation likelihoods) x q(h) (Kalman smoothing under
    switch-averaged dynamics); M-step = responsibility-weighted regressions.
    """

    def __init__(self, attributes, n_states: int = 2, n_hidden: int = 2,
                 *, seed: int = 0):
        self.F = len([a for a in attributes if a.kind == REAL])
        self.S, self.L = n_states, n_hidden
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        self.A = (0.5 * jnp.eye(self.L)[None]
                  + 0.3 * jax.random.normal(k1, (self.S, self.L, self.L)))
        self.C = jax.random.normal(k2, (self.F, self.L))
        self.q = jnp.asarray(0.3)
        self.r = jnp.asarray(0.3)
        self.log_trans = jnp.log(
            0.9 * jnp.eye(self.S) + 0.1 / self.S)

    def update_model(self, data, *, sweeps: int = 10, tol: float = 0.0,
                     fused: bool = True) -> float:
        batch = data.collect() if isinstance(data, DynamicDataStream) else data
        xs, mask = batch.xc, batch.mask
        B, T, F = xs.shape
        S = self.S
        resp = jnp.full((B, T, S), 1.0 / S)
        if fused:
            params = _strong((self.A, self.C, self.q, self.r, resp))
            A, C, q, r, resp, sm, last, metrics = _slds_fit(
                params, _strong(self.log_trans), xs, mask,
                sweeps=sweeps, tol=tol)
            self.A, self.C, self.q, self.r = A, C, q, r
            last = float(last)
        else:
            last, elbos, deltas = -np.inf, [], []
            for _ in range(sweeps):
                (self.A, self.C, self.q, self.r, resp, sm, e) = _slds_sweep(
                    self.A, self.C, self.q, self.r, self.log_trans, resp,
                    xs, mask)
                e = float(e)
                elbos.append(e)
                deltas.append(abs(e - last))
                if abs(e - last) < tol * (abs(e) + 1.0):
                    break
                last = e
            metrics = TemporalFitMetrics(
                elbo=np.asarray(elbos), delta=np.asarray(deltas),
                active=np.ones(len(elbos), bool))
        self.resp = resp
        self.smoothed = sm
        self.fit_metrics = metrics
        _emit_fit_event(type(self).__name__, last, metrics)
        return last
