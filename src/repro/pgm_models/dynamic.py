"""Dynamic latent-variable models — paper Table 2, right column.

All models operate on ``SequenceBatch`` data ([B, T, ...]) and are learnt by
variational Bayesian EM:

  * HMM family — E-step = masked forward-backward (``lax.scan``), vmapped
    over sequences; M-step = conjugate Dirichlet / Normal-Gamma /
    MVNormalGamma updates from expected counts.  AR-HMM and IO-HMM reuse the
    CLG emission (regression on the previous observation / exogenous input).
  * Kalman filter (LDS) — E-step = Kalman smoothing; M-step = Bayesian
    linear regression (MVNormalGamma) for transition and emission rows.
  * Switching LDS — structured mean field q(s)q(h): factored-frontier pass
    for the switch chain, Kalman smoothing under averaged dynamics, Bayesian
    regression M-step per switch state.

Streaming (Eq. 3) works exactly as in the static case: posteriors chain.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import expfam as ef
from repro.data.stream import Attribute, DynamicDataStream, SequenceBatch, REAL


# ---------------------------------------------------------------------------
# masked forward-backward (shared by the HMM family)
# ---------------------------------------------------------------------------


def forward_backward(log_init: jnp.ndarray, log_trans: jnp.ndarray,
                     loglik: jnp.ndarray, mask: jnp.ndarray):
    """Single sequence. log_init [S], log_trans [S,S], loglik [T,S], mask [T].

    Returns (gamma [T,S], xi_sum [S,S], loglik_scalar)."""
    S = log_init.shape[0]
    ll = loglik * mask[:, None]  # masked steps contribute nothing

    def fstep(carry, inp):
        loga_prev = carry
        ll_t, m_t = inp
        loga = jax.nn.logsumexp(
            loga_prev[:, None] + log_trans, axis=0) + ll_t
        loga = jnp.where(m_t > 0, loga, loga_prev)  # hold state over padding
        return loga, loga

    loga0 = log_init + ll[0]
    _, logas = jax.lax.scan(fstep, loga0, (ll[1:], mask[1:]))
    logas = jnp.concatenate([loga0[None], logas], 0)      # [T, S]
    logZ = jax.nn.logsumexp(logas[-1])

    def bstep(carry, inp):
        logb_next = carry
        ll_t1, m_t1 = inp
        logb = jax.nn.logsumexp(
            log_trans + (ll_t1 + logb_next)[None, :], axis=1)
        logb = jnp.where(m_t1 > 0, logb, logb_next)
        return logb, logb

    logbT = jnp.zeros(S)
    _, logbs = jax.lax.scan(bstep, logbT, (ll[1:][::-1], mask[1:][::-1]))
    logbs = jnp.concatenate([logbs[::-1], logbT[None]], 0)  # [T, S]

    gamma = jax.nn.softmax(logas + logbs, axis=-1) * mask[:, None]

    # xi_t(i,j) ∝ a_t(i) T(i,j) l_{t+1}(j) b_{t+1}(j)
    logxi = (logas[:-1, :, None] + log_trans[None]
             + (ll[1:] + logbs[1:])[:, None, :])
    logxi = logxi - jax.nn.logsumexp(logxi, axis=(1, 2), keepdims=True)
    xi = jnp.exp(logxi) * mask[1:, None, None]
    return gamma, xi.sum(0), logZ


# ---------------------------------------------------------------------------
# HMM with (optionally regression-) Gaussian emissions
# ---------------------------------------------------------------------------


class HMMPosterior(NamedTuple):
    init: ef.Dirichlet        # [S]
    trans: ef.Dirichlet       # [S, S] rows
    emis: ef.MVNormalGamma    # [F, S, D] regression emission per feature/state


class _HMMBase:
    """Shared machinery; subclasses define the emission design vector."""

    design_dim = 1  # bias only (plain Gaussian emission)

    def __init__(self, attributes, n_states: int = 2, *, seed: int = 0,
                 alpha0: float = 1.0, a0: float = 1.0, b0: float = 1.0):
        self.attributes = list(attributes)
        self.F = len([a for a in attributes if a.kind == REAL])
        self.S = n_states
        D = self.design_dim
        self.prior = HMMPosterior(
            init=ef.Dirichlet(jnp.full((self.S,), alpha0)),
            trans=ef.Dirichlet(jnp.full((self.S, self.S), alpha0)),
            emis=ef.MVNormalGamma(
                m=jnp.zeros((self.F, self.S, D)),
                K=jnp.broadcast_to(jnp.eye(D), (self.F, self.S, D, D)),
                a=jnp.full((self.F, self.S), a0),
                b=jnp.full((self.F, self.S), b0),
            ),
        )
        key = jax.random.PRNGKey(seed)
        m0 = self.prior.emis.m + jax.random.normal(
            key, self.prior.emis.m.shape)
        self.posterior = self.prior._replace(emis=self.prior.emis._replace(m=m0))
        self._chained_prior = self.prior

    # -- emission design: [B, T, F, D] --------------------------------------

    def _design(self, xc: jnp.ndarray) -> jnp.ndarray:
        B, T, F = xc.shape
        return jnp.ones((B, T, F, 1), xc.dtype)

    def _emission_loglik(self, post: HMMPosterior, xc: jnp.ndarray
                         ) -> jnp.ndarray:
        """[B, T, S] expected log-lik summed over features."""
        mom = ef.mvnormalgamma_moments(post.emis)     # [F, S, ...]
        d = self._design(xc)                          # [B, T, F, D]
        y = xc                                        # [B, T, F]
        quad = jnp.einsum("btfa,fsac,btfc->btfs", d, mom.e_lamww, d)
        lin = jnp.einsum("btfa,fsa->btfs", d, mom.e_lamw)
        ll = 0.5 * (
            mom.e_loglam[None, None] - ef.LOG2PI
            - mom.e_lam[None, None] * (y * y)[..., None]
            + 2.0 * y[..., None] * lin - quad
        )
        return ll.sum(2)

    def _estep(self, post: HMMPosterior, xc, mask):
        log_init = ef.dirichlet_expected_logprob(post.init)
        log_trans = ef.dirichlet_expected_logprob(post.trans)
        ll = self._emission_loglik(post, xc)          # [B, T, S]
        fb = jax.vmap(partial(forward_backward, log_init, log_trans))
        gamma, xi, logZ = fb(ll, mask)
        return gamma, xi, logZ

    def _mstep(self, prior: HMMPosterior, gamma, xi, xc, mask) -> HMMPosterior:
        init = ef.Dirichlet(prior.init.alpha + gamma[:, 0].sum(0))
        trans = ef.Dirichlet(prior.trans.alpha + xi.sum(0))
        d = self._design(xc)                          # [B, T, F, D]
        w = gamma * mask[..., None]                   # [B, T, S]
        sxx = jnp.einsum("btfa,btfc,bts->fsac", d, d, w)
        sxy = jnp.einsum("btfa,btf,bts->fsa", d, xc, w)
        syy = jnp.einsum("btf,btf,bts->fs", xc, xc, w)
        n = jnp.broadcast_to(w.sum((0, 1))[None], syy.shape)
        emis = ef.mvnormalgamma_update(
            prior.emis, ef.RegSuffStats(sxx, sxy, syy, n))
        return HMMPosterior(init=init, trans=trans, emis=emis)

    # -- public API -----------------------------------------------------------

    def update_model(self, data, *, sweeps: int = 30, tol: float = 1e-5) -> float:
        batch = data.collect() if isinstance(data, DynamicDataStream) else data
        xc, mask = batch.xc, batch.mask
        prior = self._chained_prior
        post = self.posterior
        if not getattr(self, "_warm", False):
            # data-driven symmetry breaking: bias term <- random observed frames
            self._warm = True
            rng = np.random.default_rng(13)
            obs = xc[..., : self.F]   # emission columns (IOHMM: drops input)
            B, T, F = obs.shape
            picks = rng.integers(0, B * T, self.S)
            frames = np.asarray(obs.reshape(B * T, F))[picks]    # [S, F]
            m0 = np.array(post.emis.m)  # writable copy
            m0[:, :, 0] = frames.T
            post = post._replace(emis=post.emis._replace(m=jnp.asarray(m0)))
        last = -np.inf
        for _ in range(sweeps):
            gamma, xi, logZ = self._estep(post, xc, mask)
            post = self._mstep(prior, gamma, xi, xc, mask)
            e = float(logZ.sum())
            if abs(e - last) < tol * (abs(e) + 1.0):
                break
            last = e
        self.posterior = post
        self._chained_prior = post     # Eq. 3
        return last

    def filtered_posterior(self, xc: jnp.ndarray, mask=None) -> jnp.ndarray:
        """[B, T, S] filtering distributions (Code Fragment 14 analog)."""
        from repro.core.factored_frontier import factored_frontier_filter, Factorial2TBN

        if mask is None:
            mask = jnp.ones(xc.shape[:2])
        post = self.posterior
        ll = self._emission_loglik(post, xc)
        init = jax.nn.softmax(ef.dirichlet_expected_logprob(post.init))
        trans = jax.nn.softmax(ef.dirichlet_expected_logprob(post.trans), -1)
        model = Factorial2TBN(init=init[None], trans=trans[None])

        def one(seq_ll):
            beliefs, _ = factored_frontier_filter(model, seq_ll[:, None, :])
            return beliefs[:, 0]

        return jax.vmap(one)(ll)

    def viterbi_states(self, xc) -> jnp.ndarray:
        g, _, _ = self._estep(self.posterior, xc, jnp.ones(xc.shape[:2]))
        return g.argmax(-1)

    def state_means(self) -> np.ndarray:
        """[S, F] emission means (bias term of the regression)."""
        return np.asarray(self.posterior.emis.m[:, :, 0]).T


class HiddenMarkovModel(_HMMBase):
    """Plain Gaussian-emission HMM."""


class AutoRegressiveHMM(_HMMBase):
    """Emission mean = w_s^T [1, x_{t-1,f}] (per feature) — AR(1) per state."""

    design_dim = 2

    def _design(self, xc):
        B, T, F = xc.shape
        prev = jnp.concatenate([jnp.zeros((B, 1, F), xc.dtype), xc[:, :-1]], 1)
        return jnp.stack([jnp.ones_like(prev), prev], -1)   # [B,T,F,2]


class InputOutputHMM(_HMMBase):
    """Emission mean = w_s^T [1, u_t] with exogenous input u (last column)."""

    design_dim = 2

    def __init__(self, attributes, n_states: int = 2, **kw):
        super().__init__(attributes, n_states, **kw)
        self.F = self.F - 1  # last REAL column is the input, not an emission
        # rebuild priors with the reduced F
        D = self.design_dim
        self.prior = self.prior._replace(emis=ef.MVNormalGamma(
            m=jnp.zeros((self.F, self.S, D)),
            K=jnp.broadcast_to(jnp.eye(D), (self.F, self.S, D, D)),
            a=jnp.full((self.F, self.S), kw.get("a0", 1.0)),
            b=jnp.full((self.F, self.S), kw.get("b0", 1.0)),
        ))
        key = jax.random.PRNGKey(kw.get("seed", 0))
        m0 = self.prior.emis.m + jax.random.normal(key, self.prior.emis.m.shape)
        self.posterior = self.prior._replace(
            emis=self.prior.emis._replace(m=m0))
        self._chained_prior = self.prior

    def _split(self, xc):
        return xc[..., :-1], xc[..., -1]

    def _design(self, xc):
        y, u = self._split(xc)
        B, T, F = y.shape
        ones = jnp.ones((B, T, F, 1), xc.dtype)
        uu = jnp.broadcast_to(u[..., None, None], (B, T, F, 1))
        return jnp.concatenate([ones, uu], -1)

    def _emission_loglik(self, post, xc):
        y, _ = self._split(xc)
        mom = ef.mvnormalgamma_moments(post.emis)
        d = self._design(xc)
        quad = jnp.einsum("btfa,fsac,btfc->btfs", d, mom.e_lamww, d)
        lin = jnp.einsum("btfa,fsa->btfs", d, mom.e_lamw)
        ll = 0.5 * (mom.e_loglam[None, None] - ef.LOG2PI
                    - mom.e_lam[None, None] * (y * y)[..., None]
                    + 2.0 * y[..., None] * lin - quad)
        return ll.sum(2)

    def _mstep(self, prior, gamma, xi, xc, mask):
        y, _ = self._split(xc)
        init = ef.Dirichlet(prior.init.alpha + gamma[:, 0].sum(0))
        trans = ef.Dirichlet(prior.trans.alpha + xi.sum(0))
        d = self._design(xc)
        w = gamma * mask[..., None]
        sxx = jnp.einsum("btfa,btfc,bts->fsac", d, d, w)
        sxy = jnp.einsum("btfa,btf,bts->fsa", d, y, w)
        syy = jnp.einsum("btf,btf,bts->fs", y, y, w)
        n = jnp.broadcast_to(w.sum((0, 1))[None], syy.shape)
        emis = ef.mvnormalgamma_update(
            prior.emis, ef.RegSuffStats(sxx, sxy, syy, n))
        return HMMPosterior(init=init, trans=trans, emis=emis)


class FactorialHMMModel:
    """Factorial HMM: C independent chains, joint Gaussian emission.

    Learnt with the factored-frontier mean-field: each chain's E-step sees
    the residual of the other chains' expected contributions (standard
    structured VB for fHMM, Ghahramani & Jordan 1997)."""

    def __init__(self, attributes, n_chains: int = 2, n_states: int = 2,
                 *, seed: int = 0):
        self.F = len([a for a in attributes if a.kind == REAL])
        self.C, self.S = n_chains, n_states
        key = jax.random.PRNGKey(seed)
        self.means = jax.random.normal(key, (self.C, self.S, self.F))
        self.log_trans = jnp.log(jnp.full((self.C, self.S, self.S), 1.0 / n_states))
        self.log_init = jnp.log(jnp.full((self.C, self.S), 1.0 / n_states))
        self.noise = jnp.asarray(1.0)

    def update_model(self, data, *, sweeps: int = 15) -> float:
        batch = data.collect() if isinstance(data, DynamicDataStream) else data
        xc, mask = batch.xc, batch.mask            # [B,T,F], [B,T]
        B, T, F = xc.shape
        gammas = jnp.full((B, T, self.C, self.S), 1.0 / self.S)
        ll_total = 0.0
        for _ in range(sweeps):
            # chain-wise E-step against residuals
            new_gammas = []
            for c in range(self.C):
                others = [cc for cc in range(self.C) if cc != c]
                resid = xc - sum(
                    jnp.einsum("bts,sf->btf", gammas[:, :, cc], self.means[cc])
                    for cc in others
                ) if others else xc
                ll = -(0.5 / self.noise) * (
                    (resid[..., None, :] - self.means[c]) ** 2
                ).sum(-1) - 0.5 * F * jnp.log(2 * jnp.pi * self.noise)
                fb = jax.vmap(partial(forward_backward, self.log_init[c],
                                      self.log_trans[c]))
                g, xi, logZ = fb(ll, mask)
                new_gammas.append(g)
                # M-step for chain c (responsibility-weighted residual means)
                w = (g * mask[..., None])
                denom = jnp.maximum(w.sum((0, 1)), 1e-6)[:, None]
                self.means = self.means.at[c].set(
                    jnp.einsum("bts,btf->sf", w, resid) / denom)
                self.log_trans = self.log_trans.at[c].set(
                    jnp.log(jnp.maximum(xi.sum(0) + 1.0, 1e-6))
                    - jnp.log(jnp.maximum(
                        xi.sum(0).sum(-1, keepdims=True) + self.S, 1e-6)))
                ll_total = float(logZ.sum())
            gammas = jnp.stack(new_gammas, 2)
        self.gammas = gammas
        return ll_total


class DynamicNaiveBayes(_HMMBase):
    """Dynamic NB = HMM whose hidden class smooths over time; emissions are
    NB-style independent Gaussians — structurally our plain HMM (the paper's
    dynamic NB is exactly this 2TBN)."""


# ---------------------------------------------------------------------------
# Kalman filter (LDS) and switching LDS
# ---------------------------------------------------------------------------


class KalmanFilter:
    """Linear dynamical system learnt by Bayesian EM (Code Fragment 10).

    h_t = A h_{t-1} + w,  x_t = C h_t + v; q(A_rows), q(C_rows) are
    MVNormalGamma; q(h_{1:T}) from Kalman smoothing at the posterior mean.
    """

    def __init__(self, attributes, n_hidden: int = 2, *, seed: int = 0):
        self.F = len([a for a in attributes if a.kind == REAL])
        self.L = n_hidden
        key1, key2 = jax.random.split(jax.random.PRNGKey(seed))
        L, F = self.L, self.F
        self.A = 0.5 * jnp.eye(L) + 0.01 * jax.random.normal(key1, (L, L))
        self.C = jax.random.normal(key2, (F, L))
        self.q = jnp.asarray(0.3)   # process noise var
        self.r = jnp.asarray(0.3)   # obs noise var
        # Bayesian accumulators (prior precision for A and C rows)
        self.KA = jnp.broadcast_to(jnp.eye(L), (L, L, L))
        self.KC = jnp.broadcast_to(jnp.eye(L), (F, L, L))

    def set_num_hidden(self, n: int) -> "KalmanFilter":
        self.__init__([Attribute(f"G{i}", REAL) for i in range(self.F)], n)
        return self

    # -- E-step: Kalman smoothing (scan) --------------------------------------

    def _smooth(self, xs: jnp.ndarray):
        """xs [T, F] -> means [T, L], covs [T, L, L], pair moments, loglik."""
        L, F = self.L, self.F
        A, C, q, r = self.A, self.C, self.q, self.r
        Q = q * jnp.eye(L)
        R = r * jnp.eye(F)

        def fstep(carry, x_t):
            m, P, ll = carry
            mp = A @ m
            Pp = A @ P @ A.T + Q
            S = C @ Pp @ C.T + R
            Sinv = jnp.linalg.inv(S)
            Kg = Pp @ C.T @ Sinv
            innov = x_t - C @ mp
            m_new = mp + Kg @ innov
            P_new = (jnp.eye(L) - Kg @ C) @ Pp
            _, logdet = jnp.linalg.slogdet(S)
            ll_new = ll - 0.5 * (logdet + innov @ Sinv @ innov
                                 + F * jnp.log(2 * jnp.pi))
            return (m_new, P_new, ll_new), (m_new, P_new, mp, Pp)

        m0 = jnp.zeros(L)
        P0 = jnp.eye(L)
        (mT, PT, ll), (fm, fP, pm, pP) = jax.lax.scan(
            fstep, (m0, P0, 0.0), xs)

        def bstep(carry, inp):
            ms_next, Ps_next = carry
            fm_t, fP_t, pm_t1, pP_t1 = inp
            J = fP_t @ A.T @ jnp.linalg.inv(pP_t1)
            ms = fm_t + J @ (ms_next - pm_t1)
            Ps = fP_t + J @ (Ps_next - pP_t1) @ J.T
            pair = J @ Ps_next  # Cov(h_t, h_{t+1})
            return (ms, Ps), (ms, Ps, pair)

        (m1, P1), (sm, sP, pair) = jax.lax.scan(
            bstep, (fm[-1], fP[-1]),
            (fm[:-1], fP[:-1], pm[1:], pP[1:]), reverse=True)
        sm = jnp.concatenate([sm, fm[-1][None]], 0)
        sP = jnp.concatenate([sP, fP[-1][None]], 0)
        return sm, sP, pair, ll

    def update_model(self, data, *, sweeps: int = 25) -> float:
        batch = data.collect() if isinstance(data, DynamicDataStream) else data
        xs = batch.xc                                # [B, T, F]
        B, T, F = xs.shape
        L = self.L
        if not getattr(self, "_warm", False):
            # PCA warm start: C <- top-L principal axes, A <- lag-1 regression
            self._warm = True
            flat = np.asarray(xs.reshape(B * T, F))
            flat = flat - flat.mean(0)
            _, _, vt = np.linalg.svd(flat, full_matrices=False)
            C0 = vt[:L].T                            # [F, L]
            scores = flat @ C0                       # [B*T, L]
            sc = scores.reshape(B, T, L)
            xlag = sc[:, :-1].reshape(-1, L)
            xnext = sc[:, 1:].reshape(-1, L)
            A0 = np.linalg.lstsq(xlag, xnext, rcond=None)[0].T
            self.C = jnp.asarray(C0, jnp.float32)
            self.A = jnp.asarray(A0, jnp.float32)
        ll = 0.0
        for _ in range(sweeps):
            sm, sP, pair, lls = jax.vmap(self._smooth)(xs)
            ll = float(lls.sum())
            # expected moments
            Ehh = sP + sm[..., :, None] * sm[..., None, :]       # [B,T,L,L]
            Ehh_lag = pair + sm[:, :-1, :, None] * sm[:, 1:, None, :]
            # transition regression: h_t on h_{t-1}
            Sxx = Ehh[:, :-1].sum((0, 1)) + jnp.eye(L)
            Sxy = Ehh_lag.sum((0, 1))                            # [L, L] (t,t+1)
            self.A = jnp.linalg.solve(Sxx, Sxy).T
            # emission regression: x_t on h_t
            Hxx = Ehh.sum((0, 1)) + jnp.eye(L)
            Hxy = jnp.einsum("btl,btf->lf", sm, xs)
            self.C = jnp.linalg.solve(Hxx, Hxy).T
            # noise variances
            resid = xs - jnp.einsum("fl,btl->btf", self.C, sm)
            self.r = jnp.maximum(
                (resid ** 2).mean() + jnp.einsum(
                    "fl,btlm,fm->", self.C, sP, self.C) / (B * T * F), 1e-4)
            dyn = sm[:, 1:] - jnp.einsum("lm,btm->btl", self.A, sm[:, :-1])
            self.q = jnp.maximum((dyn ** 2).mean(), 1e-4)
        self.smoothed = sm
        return ll

    def get_model(self):
        return {"A": self.A, "C": self.C, "q": self.q, "r": self.r}

    def filtered_states(self, xs: jnp.ndarray) -> jnp.ndarray:
        sm, _, _, _ = jax.vmap(self._smooth)(xs)
        return sm


class SwitchingLDS:
    """Switching LDS: discrete switch s_t selects the dynamics matrix A_s.

    Structured mean-field: q(s) (factored frontier over the switch chain,
    using expected innovation likelihoods) x q(h) (Kalman smoothing under
    switch-averaged dynamics); M-step = responsibility-weighted regressions.
    """

    def __init__(self, attributes, n_states: int = 2, n_hidden: int = 2,
                 *, seed: int = 0):
        self.F = len([a for a in attributes if a.kind == REAL])
        self.S, self.L = n_states, n_hidden
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        self.A = (0.5 * jnp.eye(self.L)[None]
                  + 0.3 * jax.random.normal(k1, (self.S, self.L, self.L)))
        self.C = jax.random.normal(k2, (self.F, self.L))
        self.q = jnp.asarray(0.3)
        self.r = jnp.asarray(0.3)
        self.log_trans = jnp.log(
            0.9 * jnp.eye(self.S) + 0.1 / self.S)
        self.base = KalmanFilter(
            [Attribute(f"G{i}", REAL) for i in range(self.F)], n_hidden)

    def update_model(self, data, *, sweeps: int = 10) -> float:
        from repro.core.factored_frontier import (
            Factorial2TBN, factored_frontier_filter)

        batch = data.collect() if isinstance(data, DynamicDataStream) else data
        xs = batch.xc
        B, T, F = xs.shape
        S, L = self.S, self.L
        resp = jnp.full((B, T, S), 1.0 / S)
        ll = 0.0
        for _ in range(sweeps):
            # q(h): smooth under switch-averaged A
            self.base.C = self.C
            self.base.q, self.base.r = self.q, self.r
            self.base.A = jnp.einsum(
                "bts,slm->lm", resp, self.A) / (B * T)
            sm, sP, pair, lls = jax.vmap(self.base._smooth)(xs)
            ll = float(lls.sum())
            # q(s): innovation loglik per switch state
            pred = jnp.einsum("slm,btm->btsl", self.A, sm[:, :-1])
            innov = sm[:, 1:, None, :] - pred                 # [B,T-1,S,L]
            loglik = -0.5 * (innov ** 2).sum(-1) / self.q
            loglik = jnp.concatenate(
                [jnp.zeros((B, 1, S)), loglik], axis=1)
            model = Factorial2TBN(
                init=jnp.full((1, S), 1.0 / S),
                trans=jnp.exp(self.log_trans)[None])

            def one(seq_ll):
                beliefs, _ = factored_frontier_filter(model, seq_ll[:, None, :])
                return beliefs[:, 0]

            resp = jax.vmap(one)(loglik)
            # M-step: per-switch-state transition regression
            Ehh = sP + sm[..., :, None] * sm[..., None, :]
            Ehh_lag = pair + sm[:, :-1, :, None] * sm[:, 1:, None, :]
            for s in range(S):
                w = resp[:, 1:, s]
                Sxx = jnp.einsum("bt,btlm->lm", w, Ehh[:, :-1]) + jnp.eye(L)
                Sxy = jnp.einsum("bt,btlm->lm", w, Ehh_lag)
                self.A = self.A.at[s].set(jnp.linalg.solve(Sxx, Sxy).T)
            # shared emission + noises (as in KalmanFilter)
            Hxx = Ehh.sum((0, 1)) + jnp.eye(L)
            Hxy = jnp.einsum("btl,btf->lf", sm, xs)
            self.C = jnp.linalg.solve(Hxx, Hxy).T
            resid = xs - jnp.einsum("fl,btl->btf", self.C, sm)
            self.r = jnp.maximum((resid ** 2).mean(), 1e-4)
            dyn = sm[:, 1:] - jnp.einsum(
                "bts,slm,btm->btl", resp[:, 1:], self.A, sm[:, :-1])
            self.q = jnp.maximum((dyn ** 2).mean(), 1e-4)
        self.resp = resp
        return ll
