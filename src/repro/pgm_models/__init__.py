"""latent-variable-models module — the paper's Table 2 model zoo.

Static models (``static.py``): Naive Bayes (+ classifier), Gaussian mixture,
multivariate Gaussian, Gaussian discriminant analysis, Bayesian linear
regression, factor analysis / PPCA, mixture of FA, and the paper's
Code-Fragment-11 custom model (global discrete + per-leaf local Gaussian).

Dynamic models (``dynamic.py``): HMM, factorial HMM, auto-regressive HMM,
input-output HMM, dynamic NB, Kalman filter (LDS), switching LDS.

Text (``lda.py``): latent Dirichlet allocation (paper module 'lda').

Every model follows the paper's API: ``Model(attributes)``,
``update_model(stream_or_batch)`` (works for initial learning AND Bayesian
updating, Eq. 3), ``get_model()``, ``posterior(...)``.
"""

from repro.pgm_models.base import Model
from repro.pgm_models.static import (
    BayesianLinearRegression,
    CustomGlobalLocalModel,
    FactorAnalysis,
    GaussianDiscriminantAnalysis,
    GaussianMixture,
    MixtureOfFA,
    MultivariateGaussian,
    NaiveBayes,
    NaiveBayesClassifier,
)
from repro.pgm_models.dynamic import (
    AutoRegressiveHMM,
    DynamicNaiveBayes,
    FactorialHMMModel,
    HiddenMarkovModel,
    InputOutputHMM,
    KalmanFilter,
    SwitchingLDS,
    seq_stream_fit,
)
from repro.pgm_models.lda import LDA
