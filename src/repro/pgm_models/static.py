"""Static latent-variable models — paper Table 2, left column."""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.dag import PlateSpec
from repro.data.stream import Attribute, Batch, FINITE, REAL
from repro.pgm_models.base import Model


def _split_attrs(attributes: Sequence[Attribute]):
    cont = [a for a in attributes if a.kind == REAL]
    disc = [a for a in attributes if a.kind == FINITE]
    return cont, disc


class GaussianMixture(Model):
    """Diagonal Gaussian mixture with a global discrete latent (CF 7)."""

    def __init__(self, attributes, n_states: int = 2, **kw):
        self.n_states = n_states
        super().__init__(attributes, **kw)

    def build_spec(self) -> Tuple[PlateSpec, Optional[jnp.ndarray]]:
        cont, disc = _split_attrs(self.attributes)
        if disc:
            raise ValueError("GaussianMixture expects continuous attributes")
        return PlateSpec(n_features=len(cont), latent_card=self.n_states), None


class MultivariateGaussian(Model):
    """Full-covariance Gaussian via the CLG chain rule:
    p(x) = prod_f N(x_f | w^T [1, x_<f]) — a dense upper-triangular CLG DAG."""

    def build_spec(self):
        cont, disc = _split_attrs(self.attributes)
        F = len(cont)
        parents = tuple(tuple(range(f)) for f in range(F))
        return PlateSpec(n_features=F, latent_card=0,
                         feature_parents=parents), None

    def joint_mean(self) -> np.ndarray:
        """Implied joint mean via ancestral substitution."""
        p = self.posterior
        lay = self.cp.layout
        mu = np.zeros(lay.F)
        for f in range(lay.F):
            w = np.asarray(p.reg.m[f, 0])
            mu[f] = w[0] + sum(w[1 + j] * mu[j] for j in range(f))
        return mu


class NaiveBayes(Model):
    """Unsupervised NB (latent class) over mixed continuous/discrete leaves."""

    def __init__(self, attributes, n_states: int = 2, **kw):
        self.n_states = n_states
        super().__init__(attributes, **kw)

    def build_spec(self):
        cont, disc = _split_attrs(self.attributes)
        dmap = []
        # discrete leaves are indexed AFTER continuous in (xc | xd) layout
        for j, a in enumerate(disc):
            dmap.append((len(cont) + j, a.card))
        return PlateSpec(n_features=len(cont) + len(disc),
                         latent_card=self.n_states,
                         discrete_features=tuple(dmap)), None


class NaiveBayesClassifier(NaiveBayes):
    """Supervised NB: last discrete attribute is the observed class."""

    def __init__(self, attributes, **kw):
        cont, disc = _split_attrs(attributes)
        if not disc:
            raise ValueError("needs a class attribute (FINITE_SET, last)")
        self.class_card = disc[-1].card
        # class column is consumed as the label -> not a leaf
        feats = [a for a in attributes if a is not disc[-1]]
        super().__init__(feats, n_states=self.class_card, **kw)

    def supervised_r(self, batch: Batch) -> Optional[jnp.ndarray]:
        # label column = LAST discrete column of the incoming batch
        y = batch.xd[:, -1]
        return jnp.eye(self.class_card)[y.astype(jnp.int32)]

    def _as_batch(self, data) -> Batch:
        b = super()._as_batch(data)
        # strip the label column from the leaf matrix (keep it for supervised_r)
        return b

    def update_model(self, data, **kw) -> float:
        b = super()._as_batch(data)
        r = self.supervised_r(b)
        stripped = Batch(b.xc, b.xd[:, :-1], b.mask)
        from repro.core import vmp

        stats, _ = vmp.local_step(self.cp, self.posterior, stripped.xc,
                                  stripped.xd, stripped.mask, r,
                                  backend=self.backend, chunk=self.chunk)
        post = vmp.global_update(self._chained_prior, stats)
        e = float(vmp.elbo(self.cp, self._chained_prior, post, stats))
        self.posterior = post
        self._chained_prior = post
        self.n_seen += int(b.mask.sum())
        return e

    def predict(self, data) -> jnp.ndarray:
        b = super()._as_batch(data)
        stripped = Batch(b.xc, b.xd[:, :-1] if b.xd.shape[1] else b.xd, b.mask)
        return self.posterior_z(stripped).argmax(-1)


class GaussianDiscriminantAnalysis(NaiveBayesClassifier):
    """GDA = supervised Gaussian class-conditionals; same machinery as the
    supervised NB with continuous leaves only (diagonal covariances)."""


class BayesianLinearRegression(Model):
    """Last REAL attribute regressed on all other REAL attributes."""

    def build_spec(self):
        cont, disc = _split_attrs(self.attributes)
        F = len(cont)
        parents = tuple(
            tuple(range(F - 1)) if f == F - 1 else () for f in range(F)
        )
        return PlateSpec(n_features=F, latent_card=0,
                         feature_parents=parents), None

    def coefficients(self) -> np.ndarray:
        """[bias, w_1..w_d] posterior mean of the regression weights."""
        m = np.asarray(self.posterior.reg.m[-1, 0])
        lay = self.cp.layout
        return m[: 1 + lay.P]

    def predict(self, x: jnp.ndarray) -> jnp.ndarray:
        w = jnp.asarray(self.coefficients())
        return w[0] + x @ w[1:]


class FactorAnalysis(Model):
    """x = W h + mu + eps with h ~ N(0, I_L) — PPCA when noise is tied."""

    def __init__(self, attributes, n_hidden: int = 2, **kw):
        self.n_hidden = n_hidden
        super().__init__(attributes, **kw)

    def build_spec(self):
        cont, _ = _split_attrs(self.attributes)
        return PlateSpec(n_features=len(cont), latent_card=0,
                         latent_dim=self.n_hidden), None

    def loading_matrix(self) -> np.ndarray:
        """[F, L] posterior-mean factor loadings."""
        lay = self.cp.layout
        return np.asarray(self.posterior.reg.m[:, 0, 1 + lay.P:])


class MixtureOfFA(Model):
    """Mixture of factor analysers: discrete latent selects the loading."""

    def __init__(self, attributes, n_states: int = 2, n_hidden: int = 2, **kw):
        self.n_states = n_states
        self.n_hidden = n_hidden
        super().__init__(attributes, **kw)

    def build_spec(self):
        cont, _ = _split_attrs(self.attributes)
        return PlateSpec(n_features=len(cont), latent_card=self.n_states,
                         latent_dim=self.n_hidden), None


class CustomGlobalLocalModel(Model):
    """The paper's Code-Fragment-11 custom model: a global multinomial hidden
    variable plus ONE local Gaussian hidden parent per observed leaf.

    Realized as latent_dim = F with a diagonal latent mask: leaf f sees only
    latent dimension f."""

    def __init__(self, attributes, n_states: int = 2, **kw):
        self.n_states = n_states
        super().__init__(attributes, **kw)

    def build_spec(self):
        cont, _ = _split_attrs(self.attributes)
        F = len(cont)
        mask = jnp.eye(F, dtype=jnp.float32)
        return PlateSpec(n_features=F, latent_card=self.n_states,
                         latent_dim=F), mask
