"""Model assembly: embeddings -> (scan over) blocks -> head, for all six
architecture families (dense / moe / ssm / hybrid / vlm / audio).

Layer stacking: homogeneous layer stacks are SCANNED (params stacked on a
leading [L] axis, ``jax.lax.scan`` over layers, ``jax.checkpoint`` per
layer) — constant-size HLO independent of depth, which is what keeps the
512-device dry-run compile tractable.  The zamba2 hybrid interleaves a
parameter-SHARED attention block every k layers (a python loop over scan
segments; the shared block's weights appear once).

Activation sharding: the model takes an optional ``Shardings`` carrying the
mesh + logical axes and drops ``with_sharding_constraint`` pins at the
block boundaries (batch over data axes; heads/ff over 'model').
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.nn import attention as attn
from repro.nn import layers as L
from repro.nn import moe as moe_lib
from repro.nn import ssm as ssm_lib

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True, eq=False)
class Shardings:
    """Mesh context for activation pins; None members disable pinning."""

    mesh: Optional[Mesh] = None
    data_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"
    shard_heads: bool = True   # False in decode mode (ctx-parallel KV instead)
    attn_seq_shard: bool = False  # True when Hq < model size (gemma): shard
                                  # attention over SEQUENCE instead of heads
    moe_ep: bool = True        # False under pure-FSDP training (experts are
                               # FSDP-gathered; dispatch is device-local)

    def pin(self, x: jnp.ndarray, spec: P) -> jnp.ndarray:
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))

    def act(self, x: jnp.ndarray) -> jnp.ndarray:
        """[B, S, d] residual-stream pin: batch over data, d replicated."""
        return self.pin(x, P(self.data_axes, None, None))

    def heads(self, x: jnp.ndarray) -> jnp.ndarray:
        """[B, S, H, D]: heads over model (train/prefill only)."""
        if not self.shard_heads:
            return self.pin(x, P(self.data_axes, None, None, None))
        if self.attn_seq_shard:   # context-parallel attention (small-H archs)
            return self.pin(x, P(self.data_axes, self.model_axis, None, None))
        return self.pin(x, P(self.data_axes, None, self.model_axis, None))

    def kv_heads(self, x: jnp.ndarray) -> jnp.ndarray:
        """K/V are head-REPLICATED over model (Hkv < mesh size is common);
        under seq-sharded attention they stay seq-replicated too (causal
        all-gather semantics handled by GSPMD)."""
        return self.pin(x, P(self.data_axes, None, None, None))


NO_SHARD = Shardings(mesh=None)


# ---------------------------------------------------------------------------
# attention block
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    d, hd = cfg.d_model, cfg.head_dim_
    ks = jax.random.split(key, 4)
    return {
        "wq": L.he_init(ks[0], (d, cfg.n_heads, hd), d, dtype),
        "wk": L.he_init(ks[1], (d, cfg.n_kv_heads, hd), d, dtype),
        "wv": L.he_init(ks[2], (d, cfg.n_kv_heads, hd), d, dtype),
        "wo": L.he_init(ks[3], (cfg.n_heads, hd, d), cfg.n_heads * hd, dtype),
    }


def _qkv(p: Params, x: jnp.ndarray, sh: Shardings):
    xb = x.astype(jnp.bfloat16)
    q = jnp.einsum("bsd,dhk->bshk", xb, p["wq"].astype(jnp.bfloat16))
    k = jnp.einsum("bsd,dhk->bshk", xb, p["wk"].astype(jnp.bfloat16))
    v = jnp.einsum("bsd,dhk->bshk", xb, p["wv"].astype(jnp.bfloat16))
    return sh.heads(q), sh.kv_heads(k), sh.kv_heads(v)


def attention_block(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                    sh: Shardings, *, causal: bool = True,
                    positions: Optional[jnp.ndarray] = None,
                    window: Optional[int] = None) -> jnp.ndarray:
    """Full-sequence attention (train/prefill). x: [B, S, d]."""
    B, S, _ = x.shape
    q, k, v = _qkv(p, x, sh)
    if cfg.rope_theta:
        pos = positions if positions is not None else jnp.arange(S)[None]
        q = L.apply_rope(q, pos, cfg.rope_theta)
        k = L.apply_rope(k, pos, cfg.rope_theta)
    o = attn.attention_blockwise(q, k, v, causal=causal, window=window)
    o = sh.heads(o)
    # bf16 output -> GSPMD all-reduces the TP partial sums in bf16 (2x
    # fewer link bytes than the default f32 accumulator; §Perf change A)
    out = jnp.einsum("bshk,hkd->bsd", o.astype(jnp.bfloat16),
                     p["wo"].astype(jnp.bfloat16),
                     preferred_element_type=jnp.bfloat16)
    return sh.act(out).astype(x.dtype)


def attention_block_decode(p: Params, x: jnp.ndarray, cache: attn.KVCache,
                           cfg: ModelConfig, sh: Shardings,
                           window: Optional[int] = None
                           ) -> Tuple[jnp.ndarray, attn.KVCache]:
    """One-token decode. x: [B, 1, d]."""
    q, k, v = _qkv(p, x, sh)
    if cfg.rope_theta:
        pos = cache.length[None, None]
        q = L.apply_rope(q, pos, cfg.rope_theta)
        k = L.apply_rope(k, pos, cfg.rope_theta)
    if sh.mesh is not None:
        # production path: cache seq dim sharded over 'model' (flash-decode /
        # context parallelism — DESIGN.md §5); q replicated over 'model'
        cache = attn.cache_update_ctx_parallel(
            cache, k.astype(cache.k.dtype), v.astype(cache.v.dtype),
            sh.mesh, model_axis=sh.model_axis, data_axes=sh.data_axes)
        o = attn.attention_decode_ctx_parallel(
            q, cache, sh.mesh, model_axis=sh.model_axis,
            data_axes=sh.data_axes, window=window)
    else:
        cache = attn.cache_update(cache, k.astype(cache.k.dtype),
                                  v.astype(cache.v.dtype))
        o = attn.attention_decode(q, cache, window=window)
    out = jnp.einsum("bshk,hkd->bsd", o.astype(jnp.bfloat16),
                     p["wo"].astype(jnp.bfloat16),
                     preferred_element_type=jnp.bfloat16)
    return sh.act(out).astype(x.dtype), cache


def cross_attention_block(p: Params, x: jnp.ndarray, enc_k: jnp.ndarray,
                          enc_v: jnp.ndarray, sh: Shardings) -> jnp.ndarray:
    """Decoder cross-attn against precomputed encoder K/V (whisper)."""
    xb = x.astype(jnp.bfloat16)
    q = jnp.einsum("bsd,dhk->bshk", xb, p["wq"].astype(jnp.bfloat16))
    o = attn.attention_blockwise(sh.heads(q), enc_k, enc_v, causal=False)
    out = jnp.einsum("bshk,hkd->bsd", o.astype(jnp.bfloat16),
                     p["wo"].astype(jnp.bfloat16),
                     preferred_element_type=jnp.bfloat16)
    return sh.act(out).astype(x.dtype)


def encoder_kv(p: Params, enc_out: jnp.ndarray, sh: Shardings):
    eb = enc_out.astype(jnp.bfloat16)
    k = jnp.einsum("bsd,dhk->bshk", eb, p["wk"].astype(jnp.bfloat16))
    v = jnp.einsum("bsd,dhk->bshk", eb, p["wv"].astype(jnp.bfloat16))
    return sh.kv_heads(k), sh.kv_heads(v)


# ---------------------------------------------------------------------------
# per-layer blocks
# ---------------------------------------------------------------------------


def init_dense_block(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.init_rmsnorm(cfg.d_model, dtype),
        "attn": init_attention(k1, cfg, dtype),
        "ln2": L.init_rmsnorm(cfg.d_model, dtype),
        "mlp": L.init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.mlp, dtype),
    }


def dense_block(p: Params, x, cfg: ModelConfig, sh: Shardings):
    h = attention_block(p["attn"], L.rmsnorm(p["ln1"], x, cfg.norm_eps),
                        cfg, sh, window=cfg.sliding_window)
    x = x + h
    m = L.mlp(p["mlp"], L.rmsnorm(p["ln2"], x, cfg.norm_eps), cfg.mlp)
    return sh.act(x + m)


def init_moe_block(key, cfg: ModelConfig, ep_shards: int,
                   dtype=jnp.float32) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.init_rmsnorm(cfg.d_model, dtype),
        "attn": init_attention(k1, cfg, dtype),
        "ln2": L.init_rmsnorm(cfg.d_model, dtype),
        "moe": moe_lib.init_moe(k2, cfg.d_model, cfg.d_ff, cfg.moe,
                                ep_shards, dtype),
    }


def moe_block(p: Params, x, cfg: ModelConfig, sh: Shardings):
    h = attention_block(p["attn"], L.rmsnorm(p["ln1"], x, cfg.norm_eps),
                        cfg, sh, window=cfg.sliding_window)
    x = x + h
    y, aux = moe_lib.apply_moe(
        p["moe"], L.rmsnorm(p["ln2"], x, cfg.norm_eps), cfg.moe,
        mesh=sh.mesh if sh.moe_ep else None,
        model_axis=sh.model_axis, data_axes=sh.data_axes)
    return sh.act(x + y.astype(x.dtype)), aux


def init_mamba_block(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    return {
        "ln": L.init_rmsnorm(cfg.d_model, dtype),
        "mamba": ssm_lib.init_mamba2(key, cfg.d_model, cfg.ssm, dtype),
    }


def mamba_block(p: Params, x, cfg: ModelConfig, sh: Shardings):
    h = ssm_lib.apply_mamba2(p["mamba"], L.rmsnorm(p["ln"], x, cfg.norm_eps),
                             cfg.d_model, cfg.ssm, cfg.norm_eps)
    return sh.act(x + h)


# ---------------------------------------------------------------------------
# whole-model init
# ---------------------------------------------------------------------------


def _stack_init(key, n: int, init_fn) -> Params:
    """Initialize n layers and stack leaves on a leading [n] axis."""
    keys = jax.random.split(key, n)
    layers = [init_fn(k) for k in keys]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *layers)


def init_model(key, cfg: ModelConfig, *, ep_shards: int = 1,
               dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 8)
    params: Params = {"embed": L.init_embedding(ks[0], cfg.vocab,
                                                cfg.d_model, dtype)}

    if cfg.arch_type in ("dense", "vlm"):
        params["blocks"] = _stack_init(
            ks[1], cfg.n_layers, lambda k: init_dense_block(k, cfg, dtype))
    elif cfg.arch_type == "moe":
        params["blocks"] = _stack_init(
            ks[1], cfg.n_layers,
            lambda k: init_moe_block(k, cfg, ep_shards, dtype))
    elif cfg.arch_type == "ssm":
        params["blocks"] = _stack_init(
            ks[1], cfg.n_layers, lambda k: init_mamba_block(k, cfg, dtype))
    elif cfg.arch_type == "hybrid":
        params["blocks"] = _stack_init(
            ks[1], cfg.n_layers, lambda k: init_mamba_block(k, cfg, dtype))
        shared = init_dense_block(ks[2], cfg, dtype)  # the SHARED attn block
        params["shared_attn"] = shared
    elif cfg.arch_type == "audio":
        enc = cfg.encoder
        params["enc_pos"] = L.init_pos_embedding(ks[3], enc.enc_len,
                                                 cfg.d_model, dtype)
        params["dec_pos"] = L.init_pos_embedding(ks[4], 1 << 16, cfg.d_model,
                                                 dtype)
        params["enc_blocks"] = _stack_init(
            ks[1], enc.n_layers, lambda k: init_dense_block(k, cfg, dtype))

        def dec_init(k):
            k1, k2 = jax.random.split(k)
            blk = init_dense_block(k1, cfg, dtype)
            blk["ln_x"] = L.init_rmsnorm(cfg.d_model, dtype)
            blk["xattn"] = init_attention(k2, cfg, dtype)
            return blk

        params["blocks"] = _stack_init(ks[2], cfg.n_layers, dec_init)
    else:
        raise ValueError(cfg.arch_type)

    params["final_norm"] = L.init_rmsnorm(cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = {
            "table": L.he_init(ks[5], (cfg.vocab, cfg.d_model), cfg.d_model,
                               dtype)}
    return params


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------


class ForwardOut(NamedTuple):
    logits: jnp.ndarray
    moe_aux: jnp.ndarray   # scalar: summed load-balance + z losses (0 if n/a)


def _scan_blocks(block_fn, stacked: Params, x, *, with_aux=False,
                 remat=True):
    fn = jax.checkpoint(block_fn) if remat else block_fn

    if with_aux:
        def body(carry, pl):
            y, aux = fn(pl, carry)
            return y, aux

        x, auxes = jax.lax.scan(body, x, stacked)
        lb = sum(jnp.sum(a) for a in
                 [auxes.load_balance, 0.001 * auxes.router_z])
        return x, lb

    def body(carry, pl):
        return fn(pl, carry), None

    x, _ = jax.lax.scan(body, x, stacked)
    return x, jnp.asarray(0.0, jnp.float32)


def forward(params: Params, tokens: jnp.ndarray, cfg: ModelConfig,
            sh: Shardings = NO_SHARD, *, remat: bool = True,
            enc_input: Optional[jnp.ndarray] = None) -> ForwardOut:
    """tokens: [B, S] int32. enc_input: [B, enc_len, d] (audio stub emb)."""
    x = L.embed(params["embed"], tokens)
    x = sh.act(x)
    aux = jnp.asarray(0.0, jnp.float32)

    if cfg.arch_type in ("dense", "vlm"):
        x, _ = _scan_blocks(lambda p, h: dense_block(p, h, cfg, sh),
                            params["blocks"], x, remat=remat)
    elif cfg.arch_type == "moe":
        x, aux = _scan_blocks(lambda p, h: moe_block(p, h, cfg, sh),
                              params["blocks"], x, with_aux=True, remat=remat)
    elif cfg.arch_type == "ssm":
        x, _ = _scan_blocks(lambda p, h: mamba_block(p, h, cfg, sh),
                            params["blocks"], x, remat=remat)
    elif cfg.arch_type == "hybrid":
        x = _hybrid_forward(params, x, cfg, sh, remat)
    elif cfg.arch_type == "audio":
        x = _audio_forward(params, x, cfg, sh, enc_input, remat)
    else:
        raise ValueError(cfg.arch_type)

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = L.unembed(head, x)
    vocab_axis = (sh.model_axis
                  if sh.model_axis not in sh.data_axes else None)
    return ForwardOut(logits=sh.pin(logits,
                                    P(sh.data_axes, None, vocab_axis)),
                      moe_aux=aux)


def _hybrid_forward(params, x, cfg: ModelConfig, sh: Shardings, remat: bool):
    """zamba2: mamba stack with a SHARED dense-attention block every k layers."""
    k = cfg.hybrid_attn_every
    Lz = cfg.n_layers
    blocks = params["blocks"]
    segs = Lz // k
    block_fn = (jax.checkpoint(lambda p, h: mamba_block(p, h, cfg, sh))
                if remat else (lambda p, h: mamba_block(p, h, cfg, sh)))
    shared_fn = (jax.checkpoint(
        lambda p, h: dense_block(p, h, cfg, sh)) if remat
        else (lambda p, h: dense_block(p, h, cfg, sh)))

    def seg_params(i0, n):
        return jax.tree_util.tree_map(lambda a: a[i0:i0 + n], blocks)

    done = 0
    for s in range(segs):
        xs = seg_params(done, k)
        x, _ = jax.lax.scan(lambda c, pl: (block_fn(pl, c), None), x, xs)
        done += k
        x = shared_fn(params["shared_attn"], x)   # SHARED weights each time
    if done < Lz:
        xs = seg_params(done, Lz - done)
        x, _ = jax.lax.scan(lambda c, pl: (block_fn(pl, c), None), x, xs)
    return x


def _audio_forward(params, x_dec, cfg: ModelConfig, sh: Shardings,
                   enc_input: jnp.ndarray, remat: bool):
    """whisper: bidirectional encoder over frame embeddings, causal decoder
    with cross-attention."""
    assert enc_input is not None, "audio arch needs enc_input embeddings"
    e = L.add_pos(params["enc_pos"], enc_input.astype(x_dec.dtype))
    e = sh.act(e)

    def enc_block(p, h):
        a = attention_block(p["attn"], L.rmsnorm(p["ln1"], h, cfg.norm_eps),
                            cfg, sh, causal=False)
        h = h + a
        m = L.mlp(p["mlp"], L.rmsnorm(p["ln2"], h, cfg.norm_eps), cfg.mlp)
        return sh.act(h + m)

    fn = jax.checkpoint(enc_block) if remat else enc_block
    e, _ = jax.lax.scan(lambda c, pl: (fn(pl, c), None),
                        e, params["enc_blocks"])

    x = L.add_pos(params["dec_pos"], x_dec)

    def dec_block(p, h):
        a = attention_block(p["attn"], L.rmsnorm(p["ln1"], h, cfg.norm_eps),
                            cfg, sh, causal=True)
        h = h + a
        ek, ev = encoder_kv(p["xattn"], e, sh)
        c = cross_attention_block(
            p["xattn"], L.rmsnorm(p["ln_x"], h, cfg.norm_eps), ek, ev, sh)
        h = h + c
        m = L.mlp(p["mlp"], L.rmsnorm(p["ln2"], h, cfg.norm_eps), cfg.mlp)
        return sh.act(h + m)

    fn = jax.checkpoint(dec_block) if remat else dec_block
    x, _ = jax.lax.scan(lambda c, pl: (fn(pl, c), None), x, params["blocks"])
    return x


# ---------------------------------------------------------------------------
# decode (serve_step): ONE new token against per-layer caches
# ---------------------------------------------------------------------------


class DecodeState(NamedTuple):
    """Per-layer recurrent state, leaves stacked on a leading [L] axis."""

    kv: Optional[attn.KVCache]            # attention caches [L, ...]
    ssm: Optional[ssm_lib.SSMState]       # mamba states [L, ...]
    shared_kv: Optional[attn.KVCache]     # zamba shared-block caches [segs,...]
    enc_kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]]  # whisper cross K/V [L,...]


def _stack_states(states):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *states)


def init_decode_state(params: Params, cfg: ModelConfig, batch: int,
                      capacity: int, sh: Shardings = NO_SHARD,
                      enc_input: Optional[jnp.ndarray] = None,
                      dtype=jnp.bfloat16) -> DecodeState:
    """capacity = KV budget (window size for SWA archs at long context)."""
    hd = cfg.head_dim_ if cfg.n_heads else 0
    kv = ssm = shared = enc_kv = None
    if cfg.arch_type in ("dense", "vlm", "moe", "audio"):
        kv = _stack_states([
            attn.init_kv_cache(batch, capacity, cfg.n_kv_heads, hd, dtype)
            for _ in range(cfg.n_layers)])
    if cfg.arch_type in ("ssm", "hybrid"):
        ssm = _stack_states([
            ssm_lib.init_ssm_state(batch, cfg.d_model, cfg.ssm, jnp.float32)
            for _ in range(cfg.n_layers)])
    if cfg.arch_type == "hybrid":
        segs = cfg.n_layers // cfg.hybrid_attn_every
        cap = min(capacity, cfg.sliding_window or capacity)
        shared = _stack_states([
            attn.init_kv_cache(batch, cap, cfg.n_kv_heads, hd, dtype)
            for _ in range(segs)])
    if cfg.arch_type == "audio":
        # run the encoder once; cache cross-attention K/V per decoder layer
        assert enc_input is not None
        e = L.add_pos(params["enc_pos"], enc_input.astype(jnp.bfloat16))

        def enc_block(p, h):
            a = attention_block(p["attn"], L.rmsnorm(p["ln1"], h, cfg.norm_eps),
                                cfg, sh, causal=False)
            h = h + a
            m = L.mlp(p["mlp"], L.rmsnorm(p["ln2"], h, cfg.norm_eps), cfg.mlp)
            return sh.act(h + m)

        e, _ = jax.lax.scan(lambda c, pl: (enc_block(pl, c), None),
                            sh.act(e), params["enc_blocks"])

        def one_layer_kv(pl):
            return encoder_kv(pl["xattn"], e, sh)

        enc_kv = jax.vmap(one_layer_kv)(params["blocks"])
    return DecodeState(kv=kv, ssm=ssm, shared_kv=shared, enc_kv=enc_kv)


def decode_step(params: Params, state: DecodeState, token: jnp.ndarray,
                cfg: ModelConfig, sh: Shardings = NO_SHARD) -> Tuple[jnp.ndarray, DecodeState]:
    """token: [B, 1] int32 -> (logits [B, 1, V], new state)."""
    x = L.embed(params["embed"], token)
    x = sh.act(x)
    window = cfg.sliding_window

    if cfg.arch_type in ("dense", "vlm", "moe"):
        is_moe = cfg.arch_type == "moe"

        def body(carry, inp):
            h = carry
            pl, cache = inp
            a, cache = attention_block_decode(
                pl["attn"], L.rmsnorm(pl["ln1"], h, cfg.norm_eps), cache,
                cfg, sh, window=window)
            h = h + a
            hn = L.rmsnorm(pl["ln2"], h, cfg.norm_eps)
            if is_moe:
                y, _ = moe_lib.apply_moe(pl["moe"], hn, cfg.moe, mesh=sh.mesh,
                                         model_axis=sh.model_axis,
                                         data_axes=sh.data_axes)
                h = h + y.astype(h.dtype)
            else:
                h = h + L.mlp(pl["mlp"], hn, cfg.mlp)
            return sh.act(h), cache

        x, kv = jax.lax.scan(body, x, (params["blocks"], state.kv))
        state = state._replace(kv=kv)

    elif cfg.arch_type == "ssm":
        def body(carry, inp):
            h = carry
            pl, st = inp
            y, st = ssm_lib.ssd_decode_step(
                pl["mamba"], L.rmsnorm(pl["ln"], h, cfg.norm_eps), st,
                cfg.d_model, cfg.ssm, cfg.norm_eps)
            return sh.act(h + y), st

        x, ssm = jax.lax.scan(body, x, (params["blocks"], state.ssm))
        state = state._replace(ssm=ssm)

    elif cfg.arch_type == "hybrid":
        k = cfg.hybrid_attn_every
        segs = cfg.n_layers // k

        def mamba_body(carry, inp):
            h = carry
            pl, st = inp
            y, st = ssm_lib.ssd_decode_step(
                pl["mamba"], L.rmsnorm(pl["ln"], h, cfg.norm_eps), st,
                cfg.d_model, cfg.ssm, cfg.norm_eps)
            return sh.act(h + y), st

        new_ssm, new_shared = [], []
        done = 0
        for s in range(segs):
            seg = jax.tree_util.tree_map(lambda a: a[done:done + k],
                                         (params["blocks"], state.ssm))
            x, st = jax.lax.scan(mamba_body, x, seg)
            new_ssm.append(st)
            done += k
            cache_s = jax.tree_util.tree_map(lambda a: a[s], state.shared_kv)
            pshared = params["shared_attn"]
            a, cache_s = attention_block_decode(
                pshared["attn"], L.rmsnorm(pshared["ln1"], x, cfg.norm_eps),
                cache_s, cfg, sh, window=window)
            x = x + a
            x = x + L.mlp(pshared["mlp"],
                          L.rmsnorm(pshared["ln2"], x, cfg.norm_eps), cfg.mlp)
            x = sh.act(x)
            new_shared.append(cache_s)
        if done < cfg.n_layers:
            seg = jax.tree_util.tree_map(lambda a: a[done:],
                                         (params["blocks"], state.ssm))
            x, st = jax.lax.scan(mamba_body, x, seg)
            new_ssm.append(st)
        state = state._replace(
            ssm=jax.tree_util.tree_map(
                lambda *xs: jnp.concatenate(xs), *new_ssm),
            shared_kv=_stack_states(new_shared))

    elif cfg.arch_type == "audio":
        x = L.add_pos(params["dec_pos"], x, 0)  # position 0 slice; decode pos
        enc_k, enc_v = state.enc_kv

        def body(carry, inp):
            h = carry
            pl, cache, ek, ev = inp
            a, cache = attention_block_decode(
                pl["attn"], L.rmsnorm(pl["ln1"], h, cfg.norm_eps), cache,
                cfg, sh)
            h = h + a
            c = cross_attention_block(
                pl["xattn"], L.rmsnorm(pl["ln_x"], h, cfg.norm_eps),
                ek, ev, sh)
            h = h + c
            h = h + L.mlp(pl["mlp"], L.rmsnorm(pl["ln2"], h, cfg.norm_eps),
                          cfg.mlp)
            return sh.act(h), cache

        x, kv = jax.lax.scan(body, x,
                             (params["blocks"], state.kv, enc_k, enc_v))
        state = state._replace(kv=kv)
    else:
        raise ValueError(cfg.arch_type)

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = L.unembed(head, x)
    return logits, state
