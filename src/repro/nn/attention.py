"""GQA/MQA attention with causal + sliding-window masking.

Three execution paths:

* ``attention_reference`` — O(S^2)-memory jnp oracle (tests, tiny shapes).
* ``attention_blockwise`` — lax.scan over KV blocks with a running-softmax
  accumulator (flash-attention recurrence in XLA).  This is what large
  shapes compile through: peak memory O(S * block) instead of O(S^2), which
  is what lets prefill_32k lower within HBM.  The Pallas kernel
  (``repro.kernels.flash_attn``) implements the same recurrence with
  explicit VMEM tiling for the TPU target; interpret-mode tests pin all
  three paths together.
* ``attention_decode`` — one query token against a KV cache (serve_step).

All paths take q:[B,S,Hq,D], k/v:[B,S,Hkv,D] and return [B,S,Hq,D];
GQA folds q-head groups onto kv heads via reshape (no materialized repeat).
"""

from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _fold_gqa(q: jnp.ndarray, n_kv: int) -> jnp.ndarray:
    """[B,S,Hq,D] -> [B,S,G,Hkv,D] with G = Hq // Hkv (G-MAJOR fold).

    G-major (q head h uses kv head h % Hkv) so that a contiguous 'model'
    sharding of the fused Hq dim lands on the G dim after the reshape —
    that keeps GQA tensor-parallel even when Hkv < mesh model size."""
    B, S, Hq, D = q.shape
    return q.reshape(B, S, Hq // n_kv, n_kv, D)


def _mask_bias(sq: int, sk: int, q_offset, causal: bool,
               window: Optional[int]) -> jnp.ndarray:
    """[sq, sk] additive mask; q position i is q_offset + i."""
    qpos = q_offset + jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    ok = jnp.ones((sq, sk), jnp.bool_)
    if causal:
        ok = ok & (kpos <= qpos)
    if window is not None:
        ok = ok & (kpos > qpos - window)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


# ---------------------------------------------------------------------------
# reference (quadratic memory)
# ---------------------------------------------------------------------------


def attention_reference(q, k, v, *, causal=True, window=None, q_offset=0,
                        scale=None):
    B, Sq, Hq, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    scale = scale or 1.0 / math.sqrt(D)
    qg = _fold_gqa(q, Hkv)                                  # [B,Sq,G,Hkv,D]
    logits = jnp.einsum("bqghd,bkhd->bghqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    logits = logits + _mask_bias(Sq, Sk, q_offset, causal, window)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bghqk,bkhd->bqghd", w, v.astype(jnp.float32))
    return out.reshape(B, Sq, Hq, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# blockwise (flash recurrence in XLA) — the production path
# ---------------------------------------------------------------------------


def attention_blockwise(q, k, v, *, causal=True, window=None, q_offset=0,
                        scale=None, kv_block: int = 1024):
    """Streaming-softmax attention: scan over KV blocks.

    Equivalent to the reference up to fp assoc.; peak memory O(Sq * kv_block).
    """
    B, Sq, Hq, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    scale = scale or 1.0 / math.sqrt(D)
    kv_block = min(kv_block, Sk)
    nblk = (Sk + kv_block - 1) // kv_block
    pad = nblk * kv_block - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))

    # operands stay bf16 (f32 upcasts of big K/V get hoisted out of the
    # scan by XLA and double HBM traffic — see EXPERIMENTS.md §Perf);
    # accumulation is f32 via preferred_element_type.
    qg = (_fold_gqa(q, Hkv) * jnp.asarray(scale, q.dtype))
    kb = k.reshape(B, nblk, kv_block, Hkv, D)
    vb = v.reshape(B, nblk, kv_block, Hkv, D)
    kb = jnp.moveaxis(kb, 1, 0)                             # [nblk,B,kb,Hkv,D]
    vb = jnp.moveaxis(vb, 1, 0)

    qpos = q_offset + jnp.arange(Sq)

    def step(carry, inp):
        m, l, acc = carry                                   # running max/sum/out
        kblk, vblk, blk_idx = inp
        kpos = blk_idx * kv_block + jnp.arange(kv_block)
        logits = jnp.einsum("bqghd,bkhd->bqghk", qg, kblk,
                            preferred_element_type=jnp.float32)
        ok = kpos[None, :] < Sk                             # mask padding
        if causal:
            ok = ok & (kpos[None, :] <= qpos[:, None])
        if window is not None:
            ok = ok & (kpos[None, :] > qpos[:, None] - window)
        logits = logits + jnp.where(ok, 0.0, NEG_INF)[None, :, None, None, :]
        m_new = jnp.maximum(m, logits.max(-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqghk,bkhd->bqghd", p.astype(vblk.dtype), vblk,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    G = Hq // Hkv
    m0 = jnp.full((B, Sq, G, Hkv), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, G, Hkv), jnp.float32)
    a0 = jnp.zeros((B, Sq, G, Hkv, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), (kb, vb, jnp.arange(nblk)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, Sq, Hq, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# decode: one token vs KV cache
# ---------------------------------------------------------------------------


class KVCache(NamedTuple):
    k: jnp.ndarray        # [B, C, Hkv, D]  (C = cache capacity; ring for SWA)
    v: jnp.ndarray        # [B, C, Hkv, D]
    length: jnp.ndarray   # [] int32 — tokens written so far (absolute)


def init_kv_cache(batch: int, capacity: int, n_kv: int, head_dim: int,
                  dtype=jnp.bfloat16) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, capacity, n_kv, head_dim), dtype),
        v=jnp.zeros((batch, capacity, n_kv, head_dim), dtype),
        length=jnp.zeros((), jnp.int32),
    )


def cache_update(cache: KVCache, k_new: jnp.ndarray, v_new: jnp.ndarray
                 ) -> KVCache:
    """Append one token (ring-buffer write: pos = length mod capacity)."""
    C = cache.k.shape[1]
    pos = jnp.mod(cache.length, C)
    k = jax.lax.dynamic_update_slice_in_dim(cache.k, k_new, pos, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache.v, v_new, pos, axis=1)
    return KVCache(k=k, v=v, length=cache.length + 1)


def attention_decode(q, cache: KVCache, *, window=None, scale=None):
    """q: [B, 1, Hq, D] vs ring-buffer cache. Returns [B, 1, Hq, D].

    Ring semantics: slot s holds absolute position p(s) = s + C*floor(...)
    — we reconstruct each slot's absolute position from ``length`` and mask
    slots that are empty or outside the sliding window.
    """
    B, _, Hq, D = q.shape
    C, Hkv = cache.k.shape[1], cache.k.shape[2]
    scale = scale or 1.0 / math.sqrt(D)
    qg = _fold_gqa(q, Hkv) * jnp.asarray(scale, q.dtype)    # [B,1,G,Hkv,D]
    logits = jnp.einsum("bqghd,bkhd->bqghk", qg.astype(cache.k.dtype),
                        cache.k, preferred_element_type=jnp.float32)
    # absolute position of each slot given length L (slots wrap mod C)
    L = cache.length                                        # tokens written
    slots = jnp.arange(C)
    wraps = (L - 1 - slots) // C                            # how many writes ago
    abs_pos = slots + wraps * C                             # latest abs pos in slot
    valid = (abs_pos >= 0) & (abs_pos < L)
    if window is not None:
        valid = valid & (abs_pos > L - 1 - window)
    logits = logits + jnp.where(valid, 0.0, NEG_INF)[None, None, None, None, :]
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bqghk,bkhd->bqghd", w.astype(cache.v.dtype), cache.v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, Hq, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# context-parallel decode: KV cache sharded over the 'model' axis (seq dim)
# ---------------------------------------------------------------------------
#
# For GQA models with few KV heads (glm4: 2) a 32k decode cache cannot shard
# over heads; the production layout shards the cache SEQUENCE over 'model'
# (flash-decode / context parallelism): every model shard scores q against
# its cache slice, then the partial softmax accumulators are combined with
# one pmax + two psums of [B, H, G]-sized scalars — collective bytes are
# tiny compared to the HBM reads the shard saved (DESIGN.md §5).


def _decode_partial(q, k, v, abs_pos, length, window, scale):
    """Local flash-decode accumulators. q: [B,1,Hq,D]; k/v: [B,C_loc,Hkv,D];
    abs_pos: [C_loc] absolute position each local slot holds (-1 = empty)."""
    B, _, Hq, D = q.shape
    Hkv = k.shape[2]
    qg = _fold_gqa(q, Hkv) * jnp.asarray(scale, q.dtype)
    logits = jnp.einsum("bqghd,bkhd->bqghk", qg.astype(k.dtype), k,
                        preferred_element_type=jnp.float32)
    valid = (abs_pos >= 0) & (abs_pos < length)
    if window is not None:
        valid = valid & (abs_pos > length - 1 - window)
    logits = logits + jnp.where(valid, 0.0, NEG_INF)[None, None, None, None, :]
    m = logits.max(-1)                                        # [B,1,G,Hkv]
    p = jnp.exp(logits - m[..., None])
    l = p.sum(-1)
    acc = jnp.einsum("bqghk,bkhd->bqghd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return m, l, acc


def attention_decode_ctx_parallel(q, cache: KVCache, mesh, *,
                                  model_axis="model", data_axes=("data",),
                                  window=None, scale=None):
    """Decode with the cache's seq dim sharded over ``model_axis``.

    q is replicated over 'model'; output is replicated over 'model'.
    """
    from functools import partial as _partial
    from jax.sharding import PartitionSpec as P
    from repro.core.compat import shard_map

    B, _, Hq, D = q.shape
    C = cache.k.shape[1]
    scale_ = scale or 1.0 / math.sqrt(D)
    s = mesh.shape[model_axis]
    C_loc = C // s
    ndata = 1
    for a in data_axes:
        ndata *= mesh.shape[a]
    dp = data_axes if B % ndata == 0 else ()   # tiny batches stay replicated

    @_partial(
        shard_map, mesh=mesh,
        in_specs=(P(dp, None, None, None),
                  P(dp, model_axis, None, None),
                  P(dp, model_axis, None, None),
                  P()),
        out_specs=P(dp, None, None, None),
        check_vma=False,
    )
    def body(q_, k_, v_, length):
        j = jax.lax.axis_index(model_axis)
        slots = j * C_loc + jnp.arange(C_loc)      # global slot ids
        wraps = (length - 1 - slots) // C
        abs_pos = slots + wraps * C
        m, l, acc = _decode_partial(q_, k_, v_, abs_pos, length, window,
                                    scale_)
        m_g = jax.lax.pmax(m, model_axis)
        corr = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * corr, model_axis)
        acc_g = jax.lax.psum(acc * corr[..., None], model_axis)
        out = acc_g / jnp.maximum(l_g, 1e-30)[..., None]
        B_loc = q_.shape[0]                        # local batch inside shmap
        return out.reshape(B_loc, 1, Hq, D)

    return body(q, cache.k, cache.v, cache.length).astype(q.dtype)


def cache_update_ctx_parallel(cache: KVCache, k_new, v_new, mesh, *,
                              model_axis="model", data_axes=("data",)):
    """Ring write when the cache seq dim is sharded: only the owning shard
    writes; everyone else passes its slice through."""
    from functools import partial as _partial
    from jax.sharding import PartitionSpec as P
    from repro.core.compat import shard_map

    C = cache.k.shape[1]
    s = mesh.shape[model_axis]
    C_loc = C // s
    B = cache.k.shape[0]
    ndata = 1
    for a in data_axes:
        ndata *= mesh.shape[a]
    dp = data_axes if B % ndata == 0 else ()

    @_partial(
        shard_map, mesh=mesh,
        in_specs=(P(dp, model_axis, None, None),
                  P(dp, model_axis, None, None),
                  P(dp, None, None, None),
                  P(dp, None, None, None),
                  P()),
        out_specs=(P(dp, model_axis, None, None),
                   P(dp, model_axis, None, None)),
        check_vma=False,
    )
    def body(k_, v_, kn, vn, length):
        j = jax.lax.axis_index(model_axis)
        pos = jnp.mod(length, C)
        owns = (pos >= j * C_loc) & (pos < (j + 1) * C_loc)
        local = jnp.clip(pos - j * C_loc, 0, C_loc - 1)
        k_w = jax.lax.dynamic_update_slice_in_dim(
            k_, kn.astype(k_.dtype), local, axis=1)
        v_w = jax.lax.dynamic_update_slice_in_dim(
            v_, vn.astype(v_.dtype), local, axis=1)
        return (jnp.where(owns, k_w, k_), jnp.where(owns, v_w, v_))

    k, v = body(cache.k, cache.v, k_new, v_new, cache.length)
    return KVCache(k=k, v=v, length=cache.length + 1)
