"""Mixture-of-Experts layer with explicit expert-parallel sharding.

Routing: top-k softmax gating with capacity-based dispatch (GShard-style,
drop on overflow) — index/scatter based, NEVER materializing a [T, E, C]
one-hot.  The d-VMP connection (DESIGN.md §4): router load-balance
statistics are *expected sufficient statistics* summed over the data axis —
the aux loss reduces them with the same psum pattern as the paper's global
parameter messages.

Expert parallelism (the shard_map island): activations between blocks are
sharded over the data axes and REPLICATED over 'model'; therefore each model
shard can locally gather the tokens routed to ITS experts — dispatch needs
no all-to-all at all, and the only collective is one psum over 'model' to
combine partial expert outputs (identical collective shape to the dense
tensor-parallel MLP).  This is the TPU-native reformulation of GPU EP
all-to-all, exploiting activation replication that megatron-style TP
already pays for.

Weight layout: EP-layout tensors [s, E_loc, d, ff_loc] where s = model-axis
size, created by ``ep_split`` at init:
  * E >= s  : E_loc = E // s, ff_loc = ff   (whole experts per shard)
  * E <  s  : E_loc = 1, ff_loc = ff*E // s (experts tensor-split over ff)
Storage sharding: P('model', None, 'data'|None, None) — the 'data' factor is
the FSDP axis for training.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.core.compat import shard_map

from repro.configs.base import MoEConfig
from repro.nn.layers import he_init

Params = Dict[str, jnp.ndarray]


def ep_split(w: jnp.ndarray, s: int) -> jnp.ndarray:
    """[E, d, ff] canonical -> EP layout [s, E_loc, d, ff_loc]."""
    E, d, ff = w.shape
    if E >= s:
        assert E % s == 0, (E, s)
        return w.reshape(s, E // s, d, ff)
    assert s % E == 0, (E, s)
    k = s // E
    w = w.reshape(E, d, k, ff // k)
    return jnp.transpose(w, (0, 2, 1, 3)).reshape(s, 1, d, ff // k)


def ep_split_down(w: jnp.ndarray, s: int) -> jnp.ndarray:
    """[E, ff, d] -> [s, E_loc, ff_loc, d]."""
    E, ff, d = w.shape
    if E >= s:
        return w.reshape(s, E // s, ff, d)
    k = s // E
    w = w.reshape(E, k, ff // k, d)
    return w.reshape(s, 1, ff // k, d)


def init_moe(key, d: int, ff: int, cfg: MoEConfig, ep_shards: int = 1,
             dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 4)
    E = cfg.n_experts
    return {
        "router": he_init(ks[0], (d, E), d, jnp.float32),  # router in fp32
        "w_gate": ep_split(he_init(ks[1], (E, d, ff), d, dtype), ep_shards),
        "w_up": ep_split(he_init(ks[2], (E, d, ff), d, dtype), ep_shards),
        "w_down": ep_split_down(
            he_init(ks[3], (E, ff, d), ff, dtype), ep_shards),
    }


class MoEAux(NamedTuple):
    load_balance: jnp.ndarray   # scalar aux loss (Switch-style)
    router_z: jnp.ndarray       # router z-loss
    expert_load: jnp.ndarray    # [E] fraction of tokens per expert


def _route(router_w: jnp.ndarray, x: jnp.ndarray, cfg: MoEConfig
           ) -> Tuple[jnp.ndarray, jnp.ndarray, MoEAux]:
    """x: [T, d] -> (gates [T, K], expert idx [T, K], aux)."""
    logits = x.astype(jnp.float32) @ router_w                # [T, E]
    probs = jax.nn.softmax(logits, -1)
    gate, idx = jax.lax.top_k(probs, cfg.top_k)              # [T, K]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    # Switch aux: E * sum_e (frac tokens to e) * (mean prob of e)
    T = x.shape[0]
    onehot_top1 = jax.nn.one_hot(idx[:, 0], cfg.n_experts)
    frac = onehot_top1.mean(0)
    lb = cfg.n_experts * (frac * probs.mean(0)).sum()
    zl = (jax.nn.logsumexp(logits, -1) ** 2).mean()
    return gate, idx, MoEAux(load_balance=lb, router_z=zl, expert_load=frac)


def _dispatch_compute(params: Params, x2: jnp.ndarray, cfg: MoEConfig,
                      shard_idx: jnp.ndarray, s: int) -> Tuple[jnp.ndarray, MoEAux]:
    """Local (per-shard) MoE computation on x2: [T, d].

    ``shard_idx``: this shard's index along the model axis (0 when s == 1).
    Returns the PARTIAL output (needs psum over 'model' when s > 1).
    """
    T, d = x2.shape
    E, K = cfg.n_experts, cfg.top_k
    wg, wu, wd = params["w_gate"][0], params["w_up"][0], params["w_down"][0]
    E_loc, _, ff_loc = wg.shape

    gate, idx, aux = _route(params["router"], x2, cfg)

    flat_e = idx.reshape(-1)                                  # [T*K]
    flat_g = gate.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), K)

    # position of each (token, k) within its expert's capacity buffer
    oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)           # [T*K, E]
    pos = (jnp.cumsum(oh, 0) - 1)[jnp.arange(T * K), flat_e]  # [T*K]
    cap = int(math.ceil(T * K * cfg.capacity_factor / E))
    cap = max(8, ((cap + 7) // 8) * 8)
    keep = (pos < cap)

    # map global expert id -> local slot on this shard (or drop)
    if E >= s:
        e0 = shard_idx * E_loc
        mine = (flat_e >= e0) & (flat_e < e0 + E_loc) & keep
        local_e = jnp.clip(flat_e - e0, 0, E_loc - 1)
    else:  # each expert split over s//E shards; every owning shard takes it
        owner = flat_e * (s // E)                              # first owner
        span = s // E
        mine = (shard_idx >= owner) & (shard_idx < owner + span) & keep
        local_e = jnp.zeros_like(flat_e)

    posc = jnp.clip(pos, 0, cap - 1)
    w = mine.astype(jnp.bfloat16)
    buf = jnp.zeros((E_loc, cap, d), jnp.bfloat16)
    buf = buf.at[local_e, posc].add(
        x2.astype(jnp.bfloat16)[flat_t] * w[:, None])

    h_g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg.astype(jnp.bfloat16)))
    h_u = jnp.einsum("ecd,edf->ecf", buf, wu.astype(jnp.bfloat16))
    out_buf = jnp.einsum("ecf,efd->ecd", h_g * h_u, wd.astype(jnp.bfloat16))

    y = jnp.zeros((T, d), jnp.float32)
    contrib = out_buf[local_e, posc] * (flat_g * mine).astype(jnp.float32)[:, None]
    y = y.at[flat_t].add(contrib.astype(jnp.float32))
    return y, aux


def apply_moe(params: Params, x: jnp.ndarray, cfg: MoEConfig,
              mesh: Optional[Mesh] = None, model_axis: str = "model",
              data_axes: Tuple[str, ...] = ("data",)) -> Tuple[jnp.ndarray, MoEAux]:
    """x: [B, S, d] -> (y [B, S, d], aux). shard_map EP when mesh given."""
    B, S, d = x.shape

    if mesh is None:
        y, aux = _dispatch_compute(params, x.reshape(B * S, d), cfg,
                                   jnp.asarray(0), 1)
        return y.reshape(B, S, d).astype(x.dtype), aux

    s = mesh.shape[model_axis]
    ndata = 1
    for a in data_axes:
        ndata *= mesh.shape[a]
    if B % ndata != 0:
        data_axes = ()   # tiny decode batches stay replicated over data

    @partial(
        shard_map, mesh=mesh,
        in_specs=(
            {"router": P(), "w_gate": P(model_axis), "w_up": P(model_axis),
             "w_down": P(model_axis)},
            P(data_axes, None, None),
        ),
        out_specs=(P(data_axes, None, None), P()),
        check_vma=False,
    )
    def body(pr, xl):
        Bl, Sl, _ = xl.shape
        sidx = jax.lax.axis_index(model_axis)
        y, aux = _dispatch_compute(pr, xl.reshape(Bl * Sl, d), cfg, sidx, s)
        # bf16 psum (§Perf change A): halves the EP combine link bytes
        y = jax.lax.psum(y.astype(jnp.bfloat16), model_axis)
        aux = jax.tree_util.tree_map(
            lambda a: jax.lax.pmean(a, data_axes + (model_axis,)), aux)
        return y.reshape(Bl, Sl, d), aux

    y, aux = body(params, x)
    return y.astype(x.dtype), aux
