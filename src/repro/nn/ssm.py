"""Mamba2 SSD (state-space duality) block — arXiv:2405.21060, TPU-adapted.

The SSD recurrence  h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t,
                    y_t = C_t^T h_t + D x_t
is computed in *chunked* form (the paper's SSD algorithm):

  * intra-chunk: quadratic "attention-like" term (C B^T ⊙ decay mask) @ x —
    dense [chunk x chunk] matmuls that map straight onto the MXU;
  * inter-chunk: per-chunk summarized states passed through a
    ``jax.lax.scan`` (sequential over S/chunk steps, parallel over batch,
    heads and state — this is the recurrent-scan sharding surface).

TPU-sharding note (a deliberate deviation from the reference CUDA impl):
the original fuses [z|x|B|C|dt] into ONE in_proj; we keep SEPARATE
projections so that head-indexed tensors (z, x, dt) can shard over the
'model' axis while the tiny B/C/dt group tensors stay replicated — the SSD
scan then runs with ZERO cross-chip communication; only w_out's contraction
psums (DESIGN.md §5/§7).

Decode: O(1) single-step state update (``ssd_decode_step``).
"""

from __future__ import annotations

import math
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.nn.layers import he_init, rmsnorm

Params = Dict[str, jnp.ndarray]


def init_mamba2(key, d_model: int, cfg: SSMConfig, dtype=jnp.float32) -> Params:
    d_in = cfg.expand * d_model
    H = d_in // cfg.head_dim
    G, N = cfg.n_groups, cfg.state_dim
    ks = jax.random.split(key, 8)
    return {
        "w_z": he_init(ks[0], (d_model, d_in), d_model, dtype),
        "w_x": he_init(ks[1], (d_model, d_in), d_model, dtype),
        "w_B": he_init(ks[2], (d_model, G * N), d_model, dtype),
        "w_C": he_init(ks[3], (d_model, G * N), d_model, dtype),
        "w_dt": he_init(ks[4], (d_model, H), d_model, dtype),
        "conv_x": he_init(ks[5], (cfg.conv_width, d_in), cfg.conv_width, dtype),
        "conv_b_x": jnp.zeros((d_in,), dtype),
        "conv_bc": he_init(ks[6], (cfg.conv_width, 2 * G * N), cfg.conv_width,
                           dtype),
        "conv_b_bc": jnp.zeros((2 * G * N,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(dtype),  # [H]
        "D": jnp.ones((H,), dtype),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((H,), 0.01))).astype(dtype),
        "norm_scale": jnp.ones((d_in,), dtype),
        "w_out": he_init(ks[7], (d_in, d_model), d_in, dtype),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv1d. x: [B, S, C]; w: [W, C]."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(
        xp[:, i: i + x.shape[1], :] * w[i][None, None, :] for i in range(W)
    )
    return jax.nn.silu(out + b[None, None, :])


class SSMState(NamedTuple):
    """Decode-time recurrent state."""

    h: jnp.ndarray           # [B, H, P, N]
    conv_x: jnp.ndarray      # [B, W-1, d_in] trailing x inputs
    conv_bc: jnp.ndarray     # [B, W-1, 2*G*N] trailing B/C inputs


def init_ssm_state(batch: int, d_model: int, cfg: SSMConfig,
                   dtype=jnp.float32) -> SSMState:
    d_in = cfg.expand * d_model
    H = d_in // cfg.head_dim
    return SSMState(
        h=jnp.zeros((batch, H, cfg.head_dim, cfg.state_dim), dtype),
        conv_x=jnp.zeros((batch, cfg.conv_width - 1, d_in), dtype),
        conv_bc=jnp.zeros((batch, cfg.conv_width - 1,
                           2 * cfg.n_groups * cfg.state_dim), dtype),
    )


def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                B: jnp.ndarray, C: jnp.ndarray, chunk: int,
                h0: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """SSD scan. x: [b, S, H, P]; dt: [b, S, H] (>0); A: [H] (>0, used as -A);
    B, C: [b, S, G, N]. Returns (y [b, S, H, P], final state [b, H, P, N])."""
    b, S, H, Pd = x.shape
    G, N = B.shape[2], B.shape[3]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    rep = H // G

    # fold dt into x (the "discretized" input) and compute log-decays
    dA = dt * (-A)[None, None, :]                  # [b, S, H] (negative)
    xd = x * dt[..., None]
    # chunk views
    xc = xd.reshape(b, nc, chunk, H, Pd)
    Bc = B.reshape(b, nc, chunk, G, N)
    Cc = C.reshape(b, nc, chunk, G, N)
    dAc = dA.reshape(b, nc, chunk, H)
    cum = jnp.cumsum(dAc, axis=2)                  # [b, nc, l, H]
    total = cum[:, :, -1]                          # [b, nc, H]

    # --- intra-chunk (quadratic, MXU-friendly) --------------------------------
    # decay(i<-j) = exp(cum_i - cum_j) for j <= i
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]     # [b,nc,i,j,H]
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    Lmat = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)
    # scores[i, j] = C_i . B_j  (per group) -> expand to heads
    scores = jnp.einsum("bnigd,bnjgd->bnijg", Cc, Bc)        # [b,nc,i,j,G]
    scores = jnp.repeat(scores, rep, axis=-1)                # [b,nc,i,j,H]
    y_intra = jnp.einsum("bnijh,bnijh,bnjhp->bnihp",
                         scores, Lmat, xc)

    # --- chunk state summaries --------------------------------------------------
    # state_n = sum_j exp(total - cum_j) B_j x_j^T
    decay_to_end = jnp.exp(total[:, :, None, :] - cum)       # [b,nc,l,H]
    Bh = jnp.repeat(Bc, rep, axis=3)                         # [b,nc,l,H,N]
    states = jnp.einsum("bnlh,bnlhe,bnlhp->bnhpe",
                        decay_to_end, Bh, xc)                # [b,nc,H,P,N]

    # --- inter-chunk scan --------------------------------------------------------
    chunk_decay = jnp.exp(total)                             # [b, nc, H]

    def step(h, inp):
        st, dec = inp                                        # [b,H,P,N], [b,H]
        h_prev = h
        h = h * dec[..., None, None] + st
        return h, h_prev

    init = h0 if h0 is not None else jnp.zeros((b, H, Pd, N), x.dtype)
    final, h_prevs = jax.lax.scan(
        step, init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                    # [b,nc,H,P,N]

    # --- inter-chunk contribution: C_i decay-from-start @ h_prev ------------------
    decay_from_start = jnp.exp(cum)                          # [b,nc,l,H]
    Ch = jnp.repeat(Cc, rep, axis=3)                         # [b,nc,l,H,N]
    y_inter = jnp.einsum("bnlh,bnlhe,bnhpe->bnlhp",
                         decay_from_start, Ch, h_prevs)

    y = (y_intra + y_inter).reshape(b, S, H, Pd)
    return y, final


def apply_mamba2(params: Params, x: jnp.ndarray, d_model: int,
                 cfg: SSMConfig, eps: float = 1e-5) -> jnp.ndarray:
    """Full Mamba2 block (prefill/train). x: [B, S, d_model]."""
    b, S, _ = x.shape
    d_in = cfg.expand * d_model
    H = d_in // cfg.head_dim
    G, N = cfg.n_groups, cfg.state_dim
    xb = x.astype(jnp.bfloat16)
    z = xb @ params["w_z"].astype(jnp.bfloat16)
    xs = xb @ params["w_x"].astype(jnp.bfloat16)
    BC = jnp.concatenate(
        [xb @ params["w_B"].astype(jnp.bfloat16),
         xb @ params["w_C"].astype(jnp.bfloat16)], -1)
    dt = xb @ params["w_dt"].astype(jnp.bfloat16)
    xs = _causal_conv(xs.astype(jnp.float32),
                      params["conv_x"].astype(jnp.float32),
                      params["conv_b_x"].astype(jnp.float32))
    BC = _causal_conv(BC.astype(jnp.float32),
                      params["conv_bc"].astype(jnp.float32),
                      params["conv_b_bc"].astype(jnp.float32))
    B, C = jnp.split(BC, 2, -1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    A = jnp.exp(params["A_log"].astype(jnp.float32))         # [H] > 0
    y, _ = ssd_chunked(
        xs.reshape(b, S, H, cfg.head_dim),
        dt, A,
        B.reshape(b, S, G, N), C.reshape(b, S, G, N),
        min(cfg.chunk, S),
    )
    y = y + params["D"].astype(jnp.float32)[None, None, :, None] \
        * xs.reshape(b, S, H, cfg.head_dim)
    y = y.reshape(b, S, d_in)
    # gated RMSNorm (mamba2 style), then output projection
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rmsnorm({"scale": params["norm_scale"]}, y, eps)
    return jnp.einsum("...i,io->...o", y.astype(jnp.bfloat16),
                      params["w_out"].astype(jnp.bfloat16),
                      preferred_element_type=jnp.bfloat16).astype(x.dtype)


def ssd_decode_step(params: Params, x: jnp.ndarray, state: SSMState,
                    d_model: int, cfg: SSMConfig, eps: float = 1e-5
                    ) -> Tuple[jnp.ndarray, SSMState]:
    """One-token decode. x: [B, 1, d_model] -> (y, new state)."""
    b = x.shape[0]
    d_in = cfg.expand * d_model
    H = d_in // cfg.head_dim
    G, N = cfg.n_groups, cfg.state_dim
    xb = x[:, 0].astype(jnp.bfloat16)
    z = xb @ params["w_z"].astype(jnp.bfloat16)
    xs = xb @ params["w_x"].astype(jnp.bfloat16)
    BC = jnp.concatenate(
        [xb @ params["w_B"].astype(jnp.bfloat16),
         xb @ params["w_C"].astype(jnp.bfloat16)], -1)
    dt = xb @ params["w_dt"].astype(jnp.bfloat16)

    # causal conv over ring buffers
    def conv1(hist_buf, new, w, bias):
        hist = jnp.concatenate(
            [hist_buf, new[:, None, :].astype(hist_buf.dtype)], 1)
        out = jax.nn.silu(
            (hist.astype(jnp.float32) * w.astype(jnp.float32)[None]).sum(1)
            + bias.astype(jnp.float32))
        return out, hist[:, 1:]

    xs, new_cx = conv1(state.conv_x, xs, params["conv_x"], params["conv_b_x"])
    BC, new_cbc = conv1(state.conv_bc, BC, params["conv_bc"],
                        params["conv_b_bc"])
    B, C = jnp.split(BC, 2, -1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # [B, H]
    A = jnp.exp(params["A_log"].astype(jnp.float32))
    xh = xs.reshape(b, H, cfg.head_dim)
    Bh = jnp.repeat(B.reshape(b, G, N), H // G, axis=1)      # [B, H, N]
    Ch = jnp.repeat(C.reshape(b, G, N), H // G, axis=1)
    decay = jnp.exp(dt * (-A)[None])                         # [B, H]
    h = state.h * decay[..., None, None] + jnp.einsum(
        "bh,bhp,bhn->bhpn", dt, xh, Bh)
    y = jnp.einsum("bhpn,bhn->bhp", h, Ch) \
        + params["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(b, d_in)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rmsnorm({"scale": params["norm_scale"]}, y, eps)
    out = (y.astype(jnp.bfloat16) @ params["w_out"].astype(jnp.bfloat16))
    return out[:, None, :].astype(x.dtype), SSMState(
        h=h, conv_x=new_cx, conv_bc=new_cbc)
