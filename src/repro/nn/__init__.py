"""Neural substrate for the assigned architectures (DESIGN.md §3).

Pure-functional JAX: parameters are nested dicts of jnp arrays created by
``init_*`` functions and consumed by ``apply``-style functions.  Sharding is
attached externally (``repro.sharding.specs``) as a matching PartitionSpec
tree — the module code is mesh-agnostic except for the explicit shard_map
island in ``moe.py`` (expert parallelism) — see DESIGN.md §5.
"""
