"""Common layers: norms, embeddings, RoPE, gated MLPs.

Dtype policy (applies framework-wide): parameters live in ``param_dtype``
(fp32 for training, bf16 for serving); matmuls run in bf16; normalization
statistics, softmax and residual accumulation run in fp32.
"""

from __future__ import annotations

import math
from typing import Dict, Optional

import jax
import jax.numpy as jnp

Params = Dict[str, jnp.ndarray]


def he_init(key, shape, fan_in, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype) * (1.0 / math.sqrt(fan_in))


# -- RMSNorm -------------------------------------------------------------------


def init_rmsnorm(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32)).astype(x.dtype)


# -- LayerNorm (whisper) ---------------------------------------------------------


def init_layernorm(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(x.dtype)


# -- Embedding -------------------------------------------------------------------


def init_embedding(key, vocab: int, d: int, dtype=jnp.float32) -> Params:
    return {"table": jax.random.normal(key, (vocab, d), dtype) * 0.02}


def embed(p: Params, ids: jnp.ndarray, compute_dtype=jnp.bfloat16) -> jnp.ndarray:
    return p["table"].astype(compute_dtype)[ids]


def unembed(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    """Logits in fp32 (loss numerics)."""
    return jnp.einsum("...d,vd->...v", x.astype(jnp.bfloat16),
                      p["table"].astype(jnp.bfloat16)).astype(jnp.float32)


# -- RoPE -------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float
               ) -> jnp.ndarray:
    """x: [..., S, H, D]; positions: [..., S] (broadcastable)."""
    D = x.shape[-1]
    freqs = rope_frequencies(D, theta)                     # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, D/2]
    cos = jnp.cos(angles)[..., None, :]                    # [..., S, 1, D/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# -- learned absolute positions (whisper) -------------------------------------------


def init_pos_embedding(key, max_len: int, d: int, dtype=jnp.float32) -> Params:
    return {"pos": jax.random.normal(key, (max_len, d), dtype) * 0.01}


def add_pos(p: Params, x: jnp.ndarray, offset=0) -> jnp.ndarray:
    S = x.shape[-2]
    pos = jax.lax.dynamic_slice_in_dim(p["pos"], offset, S, 0) \
        if isinstance(offset, int) and offset == 0 else \
        jax.lax.dynamic_slice_in_dim(p["pos"], offset, S, 0)
    return x + pos.astype(x.dtype)


# -- MLPs ---------------------------------------------------------------------------


def init_mlp(key, d: int, ff: int, kind: str, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    if kind in ("swiglu", "geglu"):
        return {
            "w_gate": he_init(k1, (d, ff), d, dtype),
            "w_up": he_init(k2, (d, ff), d, dtype),
            "w_down": he_init(k3, (ff, d), ff, dtype),
        }
    return {   # plain gelu (whisper)
        "w_up": he_init(k1, (d, ff), d, dtype),
        "b_up": jnp.zeros((ff,), dtype),
        "w_down": he_init(k2, (ff, d), ff, dtype),
        "b_down": jnp.zeros((d,), dtype),
    }


def mlp(p: Params, x: jnp.ndarray, kind: str) -> jnp.ndarray:
    xb = x.astype(jnp.bfloat16)
    if kind in ("swiglu", "geglu"):
        act = jax.nn.silu if kind == "swiglu" else \
            (lambda v: jax.nn.gelu(v, approximate=True))
        g = act(xb @ p["w_gate"].astype(jnp.bfloat16))
        u = xb @ p["w_up"].astype(jnp.bfloat16)
        # bf16 down-proj output -> bf16 TP all-reduce (§Perf change A)
        return jnp.einsum("...f,fd->...d", g * u,
                          p["w_down"].astype(jnp.bfloat16),
                          preferred_element_type=jnp.bfloat16
                          ).astype(x.dtype)
    h = jax.nn.gelu(xb @ p["w_up"].astype(jnp.bfloat16)
                    + p["b_up"].astype(jnp.bfloat16), approximate=True)
    return (h @ p["w_down"].astype(jnp.bfloat16)
            + p["b_down"].astype(jnp.bfloat16)).astype(x.dtype)
