"""chameleon-34b [vlm] — early-fusion, VQ image tokens. [arXiv:2405.09818]

Early fusion IS token-level: image patches arrive as VQ codebook ids inside
the 65536 vocab; the VQ codec itself is the stubbed modality frontend
(DESIGN.md carve-out). The backbone below is the full 34B decoder.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", arch_type="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22016, vocab=65536, mlp="swiglu",
    source="arXiv:2405.09818",
)
