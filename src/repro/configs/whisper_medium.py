"""whisper-medium [audio] — enc-dec, conv frontend STUB. [arXiv:2212.04356]

input_specs() provides precomputed mel/conv frame embeddings [B, 1500, 1024]
(DESIGN.md carve-out); encoder is bidirectional, decoder causal + cross-attn.
"""
from repro.configs.base import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", arch_type="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=51865, mlp="gelu", rope_theta=0.0,  # learned abs pos
    encoder=EncoderConfig(n_layers=24, enc_len=1500),
    source="arXiv:2212.04356",
)
