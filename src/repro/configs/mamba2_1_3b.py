"""mamba2-1.3b [ssm] — SSD (state-space duality), attn-free. [arXiv:2405.21060]"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", arch_type="ssm",
    n_layers=48, d_model=2048, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=50280, mlp="none",
    ssm=SSMConfig(state_dim=128, head_dim=64, n_groups=1, expand=2, chunk=128),
    source="arXiv:2405.21060",
)
