"""mixtral-8x7b [moe] — 8 experts top-2, SWA. [arXiv:2401.04088]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", arch_type="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=32000, mlp="swiglu", sliding_window=4096,
    moe=MoEConfig(n_experts=8, top_k=2, capacity_factor=1.25),
    source="arXiv:2401.04088",
)
