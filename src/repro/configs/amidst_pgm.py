"""The paper's own PGM workloads as selectable configs.

These mirror the models used in the AMIDST/d-VMP evaluations: large
Gaussian-mixture / NB-with-latent plates whose LOCAL node count
(instances x latent+leaf nodes) reaches the >1e9 scale of [11].
"""

from __future__ import annotations

import dataclasses
from typing import Dict

from repro.core.dag import PlateSpec


@dataclasses.dataclass(frozen=True)
class PGMWorkload:
    name: str
    spec: PlateSpec
    description: str

    def nodes_per_instance(self) -> int:
        """Local graph nodes per instance (latents + leaves)."""
        n = self.spec.n_features
        if self.spec.latent_card:
            n += 1
        n += self.spec.latent_dim
        return n


PGM_WORKLOADS: Dict[str, PGMWorkload] = {
    "gmm_large": PGMWorkload(
        name="gmm_large",
        spec=PlateSpec(n_features=10, latent_card=4),
        description="10-feature 4-component GMM: 11 local nodes/instance; "
                    "1e8 instances = 1.1e9 nodes (the d-VMP scale claim)",
    ),
    "nb_mixed": PGMWorkload(
        name="nb_mixed",
        spec=PlateSpec(n_features=12, latent_card=3,
                       discrete_features=((10, 4), (11, 4))),
        description="mixed continuous/discrete NB with latent class "
                    "(financial-sector style, paper refs [1,2])",
    ),
    "fa_plate": PGMWorkload(
        name="fa_plate",
        spec=PlateSpec(n_features=16, latent_card=0, latent_dim=4),
        description="factor-analysis plate: 4 local continuous latents",
    ),
}
