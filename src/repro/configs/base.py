"""ModelConfig — the selectable-architecture config system.

One ``ModelConfig`` instance per assigned architecture lives in
``repro/configs/<id>.py``; ``repro.configs.get_config(name)`` resolves them,
and every config supports ``.reduced()`` for CPU smoke tests (2 layers,
d_model <= 512, <= 4 experts — per the assignment contract).

Input shapes (the 4 assigned): ``INPUT_SHAPES`` below.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int = 2
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128      # N
    head_dim: int = 64        # P
    n_groups: int = 1         # B/C groups
    expand: int = 2           # d_inner = expand * d_model
    conv_width: int = 4
    chunk: int = 128          # SSD chunk length


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Audio/VLM frontends are STUBS: input_specs() provides precomputed
    frame/patch embeddings of shape [B, enc_len, d_model]."""

    n_layers: int
    enc_len: int              # e.g. 1500 mel frames for whisper


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str            # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0         # 0 -> d_model // n_heads
    mlp: str = "swiglu"       # swiglu | geglu | gelu
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    encoder: Optional[EncoderConfig] = None
    # hybrid (zamba2-style): attention block shared + inserted every k blocks
    hybrid_attn_every: int = 0
    source: str = ""          # citation

    @property
    def head_dim_(self) -> int:
        if self.n_heads == 0:  # attention-free (pure SSM)
            return 0
        return self.head_dim or self.d_model // self.n_heads

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k decode (see DESIGN.md §decode coverage)."""
        return self.arch_type in ("ssm", "hybrid") or self.sliding_window is not None

    @property
    def is_encdec(self) -> bool:
        return self.encoder is not None and self.arch_type == "audio"

    def n_params(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, ff, L, V = self.d_model, self.d_ff, self.n_layers, self.vocab
        hd = self.head_dim_
        emb = V * d * (1 if self.tie_embeddings else 2)
        attn = d * hd * self.n_heads + 2 * d * hd * self.n_kv_heads \
            + hd * self.n_heads * d
        if self.moe:
            mlp = 3 * d * ff * self.moe.n_experts + d * self.moe.n_experts
        elif self.mlp in ("swiglu", "geglu"):
            mlp = 3 * d * ff
        else:
            mlp = 2 * d * ff
        if self.arch_type == "ssm":
            s = self.ssm
            d_in = s.expand * d
            nh = d_in // s.head_dim
            blk = d * (2 * d_in + 2 * s.n_groups * s.state_dim + nh) \
                + d_in * d + s.conv_width * (d_in + 2 * s.n_groups * s.state_dim)
            return emb + L * (blk + 2 * d)
        if self.arch_type == "hybrid":
            s = self.ssm
            d_in = s.expand * d
            nh = d_in // s.head_dim
            mamba_blk = d * (2 * d_in + 2 * s.n_groups * s.state_dim + nh) \
                + d_in * d
            # the attention+MLP block is parameter-SHARED (zamba2): counted once
            return emb + L * (mamba_blk + 2 * d) + attn + mlp + 2 * d
        enc = 0
        if self.encoder:
            enc = self.encoder.n_layers * (2 * attn + mlp + 4 * d)
        return emb + L * (attn + mlp + 2 * d) + enc

    def n_active_params(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if not self.moe:
            return self.n_params()
        d, ff, L = self.d_model, self.d_ff, self.n_layers
        total = self.n_params()
        dense_share = total - L * 3 * d * ff * self.moe.n_experts
        return dense_share + L * 3 * d * ff * self.moe.top_k

    def reduced(self) -> "ModelConfig":
        """CPU smoke-test variant: 2 layers, d_model<=512, <=4 experts."""
        d = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        hd = min(self.head_dim_, 64)
        repl = dict(
            n_layers=2,
            d_model=d,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=hd,
            d_ff=min(self.d_ff, 512),
            vocab=min(self.vocab, 512),
            sliding_window=64 if self.sliding_window else None,
        )
        if self.moe:
            repl["moe"] = MoEConfig(
                n_experts=min(self.moe.n_experts, 4), top_k=self.moe.top_k,
                capacity_factor=self.moe.capacity_factor)
        if self.ssm:
            repl["ssm"] = SSMConfig(
                state_dim=min(self.ssm.state_dim, 32),
                head_dim=32, n_groups=1, expand=2, conv_width=4, chunk=32)
        if self.encoder:
            repl["encoder"] = EncoderConfig(n_layers=2, enc_len=64)
        if self.hybrid_attn_every:
            repl["hybrid_attn_every"] = 2
        return dataclasses.replace(self, **repl)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    def reduced(self) -> "InputShape":
        return InputShape(self.name, min(self.seq_len, 128),
                          min(self.global_batch, 4), self.kind)


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
