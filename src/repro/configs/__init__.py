"""Architecture registry — ``get_config(name)`` / ``--arch <id>``.

Ten assigned architectures (public-pool, citations in each file) plus the
paper's own PGM workload configs (``amidst_pgm``).
"""

from __future__ import annotations

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig

_REGISTRY = {}


def _register(mod_name: str, attr: str = "CONFIG"):
    import importlib

    def load():
        m = importlib.import_module(f"repro.configs.{mod_name}")
        return getattr(m, attr)

    return load


_LOADERS = {
    "granite-3-2b": _register("granite_3_2b"),
    "chameleon-34b": _register("chameleon_34b"),
    "glm4-9b": _register("glm4_9b"),
    "gemma-2b": _register("gemma_2b"),
    "h2o-danube-1.8b": _register("h2o_danube_1_8b"),
    "zamba2-1.2b": _register("zamba2_1_2b"),
    "mamba2-1.3b": _register("mamba2_1_3b"),
    "phi3.5-moe-42b-a6.6b": _register("phi35_moe"),
    "mixtral-8x7b": _register("mixtral_8x7b"),
    "whisper-medium": _register("whisper_medium"),
}

ARCH_IDS = list(_LOADERS)


def get_config(name: str) -> ModelConfig:
    if name not in _LOADERS:
        raise KeyError(f"unknown arch {name!r}; available: {ARCH_IDS}")
    if name not in _REGISTRY:
        _REGISTRY[name] = _LOADERS[name]()
    return _REGISTRY[name]
