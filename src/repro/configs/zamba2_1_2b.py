"""zamba2-1.2b [hybrid] — Mamba2 + SHARED attention blocks. [arXiv:2411.15242]

38 Mamba2 blocks; a single parameter-shared attention+MLP block is invoked
every ``hybrid_attn_every`` layers (Zamba's weight-shared global block).
kv=32 (MHA in the shared block).
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", arch_type="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32000, mlp="geglu",
    ssm=SSMConfig(state_dim=64, head_dim=64, n_groups=1, expand=2, chunk=128),
    hybrid_attn_every=6, sliding_window=4096,  # shared block uses SWA at 500k
    source="arXiv:2411.15242",
)
