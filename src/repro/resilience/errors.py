"""Typed failure vocabulary shared by the serving tier and fault injection.

Kept dependency-free (no jax, no repro imports) so ``repro.serve`` can
raise these without creating an import cycle, and callers can catch a
specific failure mode instead of string-matching RuntimeError.
"""

from __future__ import annotations


class ResilienceError(RuntimeError):
    """Base class for every resilience-layer failure."""


class ShedError(ResilienceError):
    """Submit rejected: the server's bounded queue is at capacity.

    The request was never accepted — retrying after backoff is safe and
    the intended client response."""


class DeadlineError(ResilienceError):
    """Request abandoned: deadline + request timeout elapsed before its
    micro-batch flush completed.  The caller gets this error instead of
    blocking forever on a stuck flush."""


class TransientCompileError(ResilienceError):
    """A plan build failed transiently (retryable).  Raised by the
    fault injector to exercise :class:`~repro.serve.plan.PlanCache`'s
    retry-with-backoff path."""


class WorkerCrashError(ResilienceError):
    """Injected worker-thread death (fault injection only): the worker's
    thread exits mid-flight and supervision must requeue its bucket."""
