"""Fault tolerance for streaming & serving — the layer the paper outsources.

AMIDST leans on Flink/Spark precisely because those runtimes supply fault
tolerance the toolbox itself lacks; a self-hosted jax_pallas deployment has
to carry its own.  Three concerns, one package:

* **Non-finite quarantine** — the streaming scan bodies
  (``core.streaming._stream_step``, ``pgm_models.dynamic._seq_stream_scan``)
  gate every Bayesian update on a jit-safe health flag: a batch whose
  E-step produces non-finite ELBO/posteriors is skipped with the carried
  posterior held bit-exactly, counted, and surfaced as an obs
  ``quarantine`` event — instead of poisoning every subsequent batch
  through the chained prior (Eq. 3).

* **Posterior checkpoint/restore** (:mod:`repro.resilience.checkpoint`) —
  periodic snapshots of the full streaming state; resume-mid-stream is
  bit-identical to the uninterrupted run.

* **Fault injection** (:mod:`repro.resilience.faultinject`) — seeded,
  deterministic injectors (NaN batches, worker crash, compile failure,
  slow flush) that drive the chaos tests and the CI chaos leg.

The serving tier's robustness knobs (bounded queue with shedding,
per-request timeout, worker supervision, compile retry) live in
``repro.serve`` but speak this package's typed error vocabulary
(:mod:`repro.resilience.errors`).
"""

from repro.resilience.errors import (  # noqa: F401
    DeadlineError,
    ResilienceError,
    ShedError,
    TransientCompileError,
    WorkerCrashError,
)
from repro.resilience.checkpoint import (  # noqa: F401
    CheckpointManager,
    checkpointed_stream_fit,
    load,
    resume_stream_fit,
    save,
)
from repro.resilience.faultinject import FaultInjector  # noqa: F401
