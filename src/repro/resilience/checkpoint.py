"""Posterior checkpoint/restore for the streaming path.

Generalizes the dormant ``train.checkpoint`` flat-key npz round-trip with
a JSON metadata block (batch counter, network version, reason) and a
retention-managed directory of snapshots, then wires it into
``core.streaming.stream_fit`` as :func:`checkpointed_stream_fit` /
:func:`resume_stream_fit`.

The resume guarantee is **bit-identical**: the fused scan body is one
compiled program whose per-step math does not depend on the trip count,
and the checkpoint holds the full carried :class:`~repro.core.streaming.
StreamState` (posterior pytree, chained prior, Page-Hinkley drift state,
counters) — so replaying batches ``t..T`` from a snapshot taken at ``t``
produces exactly the arrays the uninterrupted ``0..T`` run would have
(asserted by ``tests/test_resilience.py``).
"""

from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.obs import sink as obs
from repro.train.checkpoint import _flatten, load as _load_tree

PyTree = Any

_META_KEY = "__meta__"          # reserved npz key: JSON metadata as uint8
_CKPT_RE = re.compile(r"^ckpt_(\d{8})\.npz$")


def save(path: str, tree: PyTree, meta: Optional[Dict[str, Any]] = None
         ) -> None:
    """Atomic flat-key npz snapshot of ``tree`` plus a JSON ``meta`` block.

    Same wire format as ``train.checkpoint.save`` with one reserved key
    (``__meta__``) — files written by the old saver load fine (empty
    meta)."""
    flat = _flatten(tree)
    if _META_KEY in flat:       # a pytree key colliding with the reserved one
        raise ValueError(f"tree flattens onto reserved key {_META_KEY!r}")
    flat[_META_KEY] = np.frombuffer(
        json.dumps(meta or {}).encode("utf-8"), dtype=np.uint8)
    tmp = path + ".tmp.npz"     # savez keeps the name when it ends with .npz
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(tmp, **flat)
    os.replace(tmp, path)


def load(path: str, like: PyTree) -> Tuple[PyTree, Dict[str, Any]]:
    """Restore ``(tree, meta)``; the tree lands in the structure of
    ``like`` (shape/dtype-checked by ``train.checkpoint.load``)."""
    tree = _load_tree(path, like)
    with np.load(path) as data:
        meta = (json.loads(bytes(data[_META_KEY]).decode("utf-8"))
                if _META_KEY in data else {})
    return tree, meta


class CheckpointManager:
    """Retention-managed directory of streaming-state snapshots.

    Parameters
    ----------
    directory   where ``ckpt_{t:08d}.npz`` files live
    every       periodic policy: snapshot each time ``t`` advances by this
                many batches (0 disables the periodic trigger)
    on_drift    also snapshot when the caller reports a drift firing —
                drift points are exactly where the posterior lurches, so
                they are the states worth keeping
    keep        retention: prune to the newest ``keep`` snapshots
    network_version
                stamped into each snapshot's meta so serving-tier restores
                can refuse a stale structure
    """

    def __init__(self, directory: str, *, every: int = 0,
                 on_drift: bool = False, keep: int = 3,
                 network_version: int = 0) -> None:
        if keep < 1:
            raise ValueError("keep must be >= 1")
        self.directory = directory
        self.every = int(every)
        self.on_drift = bool(on_drift)
        self.keep = int(keep)
        self.network_version = int(network_version)
        self._last_t: Optional[int] = None
        os.makedirs(directory, exist_ok=True)

    # -- write side -----------------------------------------------------------

    def path_for(self, t: int) -> str:
        return os.path.join(self.directory, f"ckpt_{t:08d}.npz")

    def save(self, t: int, state: PyTree, *, reason: str = "periodic",
             extra: Optional[Dict[str, Any]] = None) -> str:
        """Unconditionally snapshot ``state`` after batch ``t``."""
        path = self.path_for(t)
        meta = {"t": int(t), "reason": reason, "format": 1,
                "network_version": self.network_version}
        if extra:
            meta.update(extra)
        save(path, state, meta)
        self._last_t = int(t)
        self._prune()
        if obs.enabled():
            obs.emit("checkpoint", t=int(t), path=path, reason=reason)
            from repro.obs import agg
            agg.REGISTRY.counter("checkpoint_total", reason=reason).inc()
        return path

    def maybe_save(self, t: int, state: PyTree, *,
                   drifted: bool = False) -> Optional[str]:
        """Apply the periodic / on-drift policy; returns the path written
        (or None when neither trigger fires)."""
        if drifted and self.on_drift:
            return self.save(t, state, reason="drift")
        if self.every > 0 and (self._last_t is None
                               or t - self._last_t >= self.every):
            return self.save(t, state, reason="periodic")
        return None

    def _prune(self) -> None:
        paths = self.paths()
        for p in paths[:-self.keep]:
            os.remove(p)

    # -- read side ------------------------------------------------------------

    def paths(self) -> List[str]:
        """Snapshot paths, oldest first."""
        out = []
        for name in os.listdir(self.directory):
            if _CKPT_RE.match(name):
                out.append(os.path.join(self.directory, name))
        return sorted(out)

    def latest(self) -> Optional[str]:
        paths = self.paths()
        return paths[-1] if paths else None

    def restore(self, like: PyTree
                ) -> Optional[Tuple[PyTree, Dict[str, Any]]]:
        """Load the newest snapshot into the structure of ``like``.
        Returns ``(state, meta)`` or None when the directory is empty."""
        path = self.latest()
        if path is None:
            return None
        return load(path, like)


# -- stream_fit integration ----------------------------------------------------


def checkpointed_stream_fit(cp, base_prior, state, xcs, xds, masks=None, *,
                            manager: CheckpointManager, start: int = 0,
                            **stream_kw):
    """``stream_fit`` with checkpoints: replay batches ``start..T`` in
    segments of ``manager.every`` batches, snapshotting the full carried
    state after each segment (and, with ``manager.on_drift``, after a
    segment containing a drift firing).

    The segmented replay is bit-identical to one unsegmented scan — the
    scan body is the same compiled per-step program either way and the
    carry crosses the segment boundary exactly — so checkpointing costs
    only the host round-trip + npz write per segment, never accuracy.
    Returns ``(state, info)`` like ``stream_fit``.
    """
    from repro.core import streaming

    T = xcs.shape[0]
    if not 0 <= start <= T:
        raise ValueError(f"start {start} outside [0, {T}]")
    every = manager.every if manager.every > 0 else T - start
    infos = []
    t = start
    while t < T:
        hi = min(t + every, T)
        m = None if masks is None else masks[t:hi]
        state, info = streaming.stream_fit(
            cp, base_prior, state, xcs[t:hi], xds[t:hi], m, **stream_kw)
        infos.append(info)
        t = hi
        drifted = bool(np.asarray(info["drifted"]).any())
        if (drifted and manager.on_drift) or manager.every > 0 or t == T:
            manager.save(t, state,
                         reason="drift" if drifted and manager.on_drift
                         else "periodic")
    if not infos:
        return state, {}
    info = {k: np.concatenate([np.asarray(i[k]) for i in infos])
            for k in infos[0]}
    return state, info


def resume_stream_fit(cp, base_prior, like_state, xcs, xds, masks=None, *,
                      manager: CheckpointManager, **stream_kw):
    """Crash recovery: restore the newest snapshot (falling back to
    ``like_state`` at t=0 when none exists) and continue the replay from
    the recorded batch counter.  Returns ``(state, info)`` covering only
    the batches actually replayed."""
    restored = manager.restore(like_state)
    if restored is None:
        state, start = like_state, 0
    else:
        state, meta = restored
        start = int(meta.get("t", 0))
        if meta.get("network_version",
                    manager.network_version) != manager.network_version:
            raise ValueError(
                f"checkpoint network_version {meta.get('network_version')} "
                f"!= manager's {manager.network_version}")
    return checkpointed_stream_fit(cp, base_prior, state, xcs, xds, masks,
                                   manager=manager, start=start, **stream_kw)
