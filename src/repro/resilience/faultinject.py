"""Deterministic fault injection — the chaos harness behind the tests.

Every injector is seeded and reproducible: a chaos test that fails replays
bit-for-bit.  Four fault classes, matching the failure modes the
resilience layer defends against:

* :meth:`FaultInjector.poison_nan` — NaN-poison a seeded subset of
  stacked stream batches (exercises the non-finite quarantine gate);
* :meth:`FaultInjector.crash_worker` — kill one ``AsyncPGMServer`` worker
  thread mid-flight via the server's ``_flush_hook`` (exercises
  supervision: bucket requeue + replica respawn);
* :meth:`FaultInjector.fail_compiles` — make the next N plan builds raise
  :class:`~repro.resilience.errors.TransientCompileError` via
  ``PlanCache.fault_hook`` (exercises retry-with-backoff, and swap abort
  when N exceeds the retry budget);
* :meth:`FaultInjector.slow_flush` — stall the next N flushes (exercises
  the per-request timeout watchdog).

Hooks compose: arming several injectors on one server chains them, so a
single run can see NaN batches + a crash + a compile failure (the CI
chaos leg does exactly this).
"""

from __future__ import annotations

import time
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.resilience.errors import TransientCompileError, WorkerCrashError


class FaultInjector:
    """Seeded injector factory.  ``log`` records every armed fault as
    ``(kind, detail)`` so tests/benches can report what was injected."""

    def __init__(self, seed: int = 0) -> None:
        self.rng = np.random.default_rng(seed)
        self.log: list = []

    # -- data faults ----------------------------------------------------------

    def poison_nan(self, xcs, rate: float,
                   ) -> Tuple[np.ndarray, np.ndarray]:
        """NaN-poison a seeded fraction of the stacked batches ``[T, B, F]``.

        Whole batches are poisoned (every row NaN) so the quarantine
        outcome is deterministic; returns ``(poisoned_copy, indices)``.
        ``rate > 0`` always poisons at least one batch."""
        xcs = np.array(xcs, dtype=np.asarray(xcs).dtype)
        T = xcs.shape[0]
        n = 0 if rate <= 0 else max(1, int(round(rate * T)))
        idx = np.sort(self.rng.choice(T, size=min(n, T), replace=False))
        xcs[idx] = np.nan
        self.log.append(("nan_batches", [int(i) for i in idx]))
        return xcs, idx

    # -- serving faults -------------------------------------------------------

    @staticmethod
    def _chain_flush_hook(server, fn) -> None:
        prev = getattr(server, "_flush_hook", None)

        def hook(widx: int, bucket) -> None:
            if prev is not None:
                prev(widx, bucket)
            fn(widx, bucket)

        server._flush_hook = hook

    def crash_worker(self, server, widx: Optional[int] = None
                     ) -> Dict[str, Any]:
        """Arm a one-shot crash: the next bucket pop kills that worker's
        thread (the bucket stays registered in-flight, so the supervisor
        must requeue it and respawn the replica).  ``widx`` pins the crash
        to one replica; None (default) fires on whichever worker pops
        first — with several replicas a pinned worker may never win a
        bucket race, so None is what a multi-replica chaos run wants."""
        box = {"armed": True, "fired": False}

        def fn(w: int, bucket) -> None:
            if box["armed"] and (widx is None or w == widx):
                box["armed"] = False
                box["fired"] = True
                raise WorkerCrashError(f"injected crash in worker {w}")

        self._chain_flush_hook(server, fn)
        self.log.append(("worker_crash", widx))
        return box

    def slow_flush(self, server, delay_s: float, n: int = 1,
                   widx: Optional[int] = None) -> Dict[str, Any]:
        """Arm ``n`` stalled flushes of ``delay_s`` each (the stuck-flush
        scenario the request-timeout watchdog converts into a
        :class:`~repro.resilience.errors.DeadlineError`).  ``widx`` pins
        the stalls to one replica — the degraded-replica scenario the
        health scorer must detect and route around; None (default) stalls
        whichever worker pops next."""
        box = {"left": n, "fired": 0}

        def fn(w: int, bucket) -> None:
            if box["left"] > 0 and (widx is None or w == widx):
                box["left"] -= 1
                box["fired"] += 1
                time.sleep(delay_s)

        self._chain_flush_hook(server, fn)
        self.log.append(("slow_flush", (delay_s, n, widx)))
        return box

    def fail_compiles(self, cache, n: int = 1) -> Dict[str, Any]:
        """Arm the next ``n`` plan builds on ``cache`` to raise
        :class:`TransientCompileError` before compiling.  With
        ``n <= cache.compile_retries`` the request still succeeds after
        backoff; beyond the budget the build error propagates (and an
        in-progress hot swap aborts, leaving old engines serving)."""
        box = {"left": n}

        def hook(key) -> None:
            if box["left"] > 0:
                box["left"] -= 1
                raise TransientCompileError(
                    f"injected compile failure for {key.mode} plan")

        cache.fault_hook = hook
        self.log.append(("compile_failures", n))
        return box

    @staticmethod
    def disarm(server=None, cache=None) -> None:
        """Remove every armed hook from a server and/or cache."""
        if server is not None:
            server._flush_hook = None
        if cache is not None:
            cache.fault_hook = None
