"""The paper's technique applied to NN training (DESIGN.md §4).

``vb_optimizer``   streaming variational Bayes over network weights:
                   Gaussian mean-field posterior, natural-gradient (VON)
                   updates, Eq.-3 prior chaining, d-VMP-style data-axis
                   reduction of expected sufficient statistics.
``drift``          streaming concept-drift monitor on the training loss.
"""
