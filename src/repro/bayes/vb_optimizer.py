"""Streaming Variational Bayes optimizer for neural networks.

This is the faithful transfer of the paper's learning engine to the
(non-conjugate) NN setting: maintain a mean-field Gaussian variational
posterior q(w) = N(m, diag(1/p)) over every weight and update it with
NATURAL-GRADIENT steps (Variational Online Newton / VON — Khan et al. 2018,
the standard VMP generalization for non-conjugate likelihoods):

    p_t = (1 - rho) p_{t-1} + rho (N * ghat^2 + p_prior)       (precision)
    m_t = m_{t-1} - alpha * (N * ghat + p_prior (m - m_prior)) / p_t

where ghat is the minibatch gradient of the NLL and N the stream scale.
The two statistics (sum of gradients, sum of squared gradients) are exactly
the "messages to the global parameter node": under pjit they are reduced
over the data axes by the SAME all-reduce pattern as d-VMP's psum
(DESIGN.md §2 mapping table).

Streaming / Eq. 3: ``chain_prior`` turns the current posterior into the next
prior — the Bayesian updating recursion, giving drift-robust continual
learning without replay.  ``sample_params`` draws a posterior weight sample
for Bayesian predictions (Thompson-style decoding).
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class VBState(NamedTuple):
    mean: PyTree        # m — also the params used in the forward pass
    fisher: PyTree      # s — EMA of squared per-sample gradients (no bias corr)
    prior_mean: PyTree  # chained prior (Eq. 3)
    prior_prec: PyTree
    step: jnp.ndarray


def vb_init(params: PyTree, *, prior_prec: float = 1.0) -> VBState:
    pm = jax.tree_util.tree_map(
        lambda p: jnp.asarray(p, jnp.float32), params)
    pp = jax.tree_util.tree_map(
        lambda p: jnp.full(p.shape, prior_prec, jnp.float32), params)
    return VBState(mean=pm,
                   fisher=jax.tree_util.tree_map(jnp.zeros_like, pm),
                   prior_mean=jax.tree_util.tree_map(jnp.copy, pm),
                   prior_prec=pp, step=jnp.zeros((), jnp.int32))


def vb_update(state: VBState, grads: PyTree, *, n_total: float,
              lr: float = 0.1, rho: float = 0.05, damping: float = 0.1,
              clip_norm: float = 1.0) -> VBState:
    """One VON natural-gradient step from minibatch MEAN gradients.

    Per-sample coordinates (divide the Bayesian objective by N):
        s_t  = EMA_rho(ghat^2), bias-corrected            (Fisher proxy)
        m_t  = m - lr (ghat + (p0/N)(m - m0)) / (s_hat + p0/N + damping)
    Posterior precision (for KL/sampling): p = N (s_hat + damping) + p0.
    ``damping`` is VON's external curvature jitter (Khan et al. 2018) —
    without it the diagonal Newton step 1/g explodes where g -> 0.
    """
    step = state.step + 1
    gnorm = jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree_util.tree_leaves(grads)))
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
    bias = 1.0 - (1.0 - rho) ** step

    def upd(m, s, g, m0, p0):
        g = g.astype(jnp.float32) * scale
        s_new = (1 - rho) * s + rho * g * g
        s_hat = s_new / bias
        lam0 = p0 / n_total
        denom = s_hat + lam0 + damping
        m_new = m - lr * (g + lam0 * (m - m0)) / denom
        return m_new, s_new

    flat_m, tdef = jax.tree_util.tree_flatten(state.mean)
    out = [upd(m, s, g, m0, p0) for m, s, g, m0, p0 in zip(
        flat_m,
        jax.tree_util.tree_leaves(state.fisher),
        jax.tree_util.tree_leaves(grads),
        jax.tree_util.tree_leaves(state.prior_mean),
        jax.tree_util.tree_leaves(state.prior_prec))]
    return VBState(
        mean=tdef.unflatten([o[0] for o in out]),
        fisher=tdef.unflatten([o[1] for o in out]),
        prior_mean=state.prior_mean, prior_prec=state.prior_prec, step=step)


def posterior_prec(state: VBState, n_total: float,
                   damping: float = 0.1) -> PyTree:
    """p = N (s_hat + damping) + p0 — the implied posterior precision."""
    bias = 1.0 - 0.95 ** jnp.maximum(state.step, 1)
    return jax.tree_util.tree_map(
        lambda s, p0: n_total * (s / bias + damping) + p0,
        state.fisher, state.prior_prec)


def chain_prior(state: VBState, n_total: float, *,
                temper: float = 1.0) -> VBState:
    """Eq. 3: posterior -> prior for the next data block.

    ``temper`` < 1 applies the forgetting factor used on drift detection
    (power prior), exactly mirroring core/streaming.py."""
    post_p = posterior_prec(state, n_total)
    new_pp = jax.tree_util.tree_map(lambda p: temper * p, post_p)
    return state._replace(
        prior_mean=jax.tree_util.tree_map(jnp.copy, state.mean),
        prior_prec=new_pp)


def sample_params(state: VBState, key: jax.Array, n_total: float) -> PyTree:
    """Draw w ~ q(w) for Bayesian prediction / uncertainty estimates."""
    leaves, tdef = jax.tree_util.tree_flatten(state.mean)
    keys = jax.random.split(key, len(leaves))
    precs = jax.tree_util.tree_leaves(posterior_prec(state, n_total))
    out = [m + jax.random.normal(k, m.shape) / jnp.sqrt(jnp.maximum(p, 1e-8))
           for m, p, k in zip(leaves, precs, keys)]
    return tdef.unflatten(out)


def posterior_kl(state: VBState, n_total: float) -> jnp.ndarray:
    """KL(q || chained prior) — the global penalty term of the stream ELBO."""
    def kl(m, p, m0, p0):
        return 0.5 * jnp.sum(
            p0 / p - 1.0 + jnp.log(p / p0) + p0 * (m - m0) ** 2)

    return sum(map(
        kl,
        jax.tree_util.tree_leaves(state.mean),
        jax.tree_util.tree_leaves(posterior_prec(state, n_total)),
        jax.tree_util.tree_leaves(state.prior_mean),
        jax.tree_util.tree_leaves(state.prior_prec)))
