"""Streaming drift monitor for NN training — reuses the Page-Hinkley
machinery of ``repro.core.streaming`` on the per-token loss signal."""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax.numpy as jnp

from repro.core.streaming import DriftState, drift_init, drift_update


class LossDriftMonitor(NamedTuple):
    state: DriftState
    threshold: float

    @staticmethod
    def create(threshold: float = 5.0) -> "LossDriftMonitor":
        return LossDriftMonitor(state=drift_init(), threshold=threshold)

    def observe(self, loss: jnp.ndarray) -> Tuple["LossDriftMonitor", jnp.ndarray]:
        """Feed a batch mean loss; returns (new monitor, drifted?)."""
        # score = negative loss (higher is better, matching ELBO convention)
        st, ph = drift_update(self.state, -loss)
        return LossDriftMonitor(state=st, threshold=self.threshold), \
            ph > self.threshold
