"""Serve a small model with batched requests (continuous batching).

Run: PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import numpy as np

from repro.configs import get_config
from repro.nn import transformer as T
from repro.serve.engine import DecodeEngine, Request

cfg = get_config("h2o-danube-1.8b").reduced()   # SWA arch: ring-buffer cache
params = T.init_model(jax.random.PRNGKey(0), cfg)
engine = DecodeEngine(params, cfg, batch=4, capacity=128)

rng = np.random.default_rng(0)
requests = [
    Request(rid=i, prompt=rng.integers(0, cfg.vocab, 8).tolist(), max_new=24)
    for i in range(12)
]
for r in requests:
    engine.submit(r)

t0 = time.time()
engine.run()
dt = time.time() - t0
tok = sum(len(r.out) for r in requests)
print(f"served {len(requests)} requests / {tok} tokens "
      f"in {dt:.1f}s ({tok / dt:.0f} tok/s, batch=4, SWA ring cache)")
for r in requests[:3]:
    print(f"  req {r.rid}: prompt={r.prompt[:4]}... -> out={r.out[:8]}...")
