"""Streaming with concept drift — paper §2.3.

A two-phase stream (abrupt mean shift) processed by streaming VB with the
probabilistic drift detector; on detection the prior is tempered and the
model re-adapts.  Also shows the SAME machinery applied to NN training
(bayes.drift.LossDriftMonitor).

Run: PYTHONPATH=src python examples/streaming_drift.py
"""

import jax
import numpy as np

from repro.core import streaming, vmp
from repro.core.dag import PlateSpec
from repro.data.synthetic import drift_stream

stream, n_phase = drift_stream(n_per_phase=2500, f=4, seed=0)
spec = PlateSpec(n_features=4, latent_card=1)
cp = vmp.compile_plate(spec)
prior = vmp.default_prior(cp)
state = streaming.stream_init(
    prior, vmp.symmetry_broken(prior, jax.random.PRNGKey(0)))

print("batch |   score   |  PH stat | drift | model mean[0]")
for i, b in enumerate(stream.batches(250)):
    state, info = streaming.stream_update(cp, prior, state, b.xc, b.xd,
                                          drift_threshold=3.0)
    mean0 = float(state.post.reg.m[0, 0, 0])
    flag = " DRIFT" if bool(info["drifted"]) else ""
    print(f"{i:5d} | {float(info['score']):9.3f} | {float(info['ph']):8.3f} |"
          f" {flag:6s}| {mean0:+.2f}")
print(f"\ntotal drifts detected: {int(state.n_drifts)} "
      f"(true change point: batch {n_phase // 250})")

# -- same stream, ONE device program: the resident stream_fit scan driver ----
import jax.numpy as jnp  # noqa: E402

batches = list(drift_stream(n_per_phase=2500, f=4, seed=0)[0].batches(250))
state2, infos = streaming.stream_fit(
    cp, prior, streaming.stream_init(
        prior, vmp.symmetry_broken(prior, jax.random.PRNGKey(0))),
    jnp.stack([b.xc for b in batches]),
    jnp.stack([b.xd for b in batches]),
    jnp.stack([b.mask for b in batches]),
    drift_threshold=3.0)
print(f"stream_fit (single lax.scan): drifts={int(state2.n_drifts)}, "
      f"flags match loop: "
      f"{int(state2.n_drifts) == int(state.n_drifts)}, "
      f"final mean[0]={float(state2.post.reg.m[0, 0, 0]):+.2f}")
