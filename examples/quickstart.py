"""Quickstart — the paper's Code Fragments 7/9/13 in this framework.

Learn a Gaussian mixture from a data stream, update it with new batches
(Bayesian updating, Eq. 3), and query a posterior given evidence.

Run: PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax.numpy as jnp

from repro.data.synthetic import gmm_stream
from repro.pgm_models import GaussianMixture

# --- Code Fragment 7: learn a predefined static model from data -------------
stream, true_means, _ = gmm_stream(n=3000, k=2, f=10, seed=0)
model = GaussianMixture(stream.attributes, n_states=2)
model.update_model(stream)          # scalable VMP learning
print(model)                        # Code Fragment 8 style print-out

# --- Code Fragment 9: update the model as new data arrives ------------------
for i in range(3):
    new_stream, _, _ = gmm_stream(n=500, k=2, f=10, seed=10 + i)
    elbo = model.update_model(new_stream)
    print(f"[update {i}] elbo={elbo:.1f} (n_seen={model.n_seen})")

# --- Code Fragment 13: inference — P(Hidden | evidence) ---------------------
evidence = np.zeros((1, 10), np.float32)
evidence[0, :] = np.asarray(true_means[0])      # a point near component 0
evidence[0, 8:] = [8.0, -1.0]                   # CF 13's GaussianVar8/9 values
posterior = model.posterior_z(jnp.asarray(evidence))
print("P(HiddenVar | GaussianVar8=8.0, GaussianVar9=-1.0) =",
      np.asarray(posterior[0]))
