"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

Uses the granite-3-2b family at a ~100M reduced size (8 layers, d=512) on a
synthetic Markov corpus; compares AdamW with the paper-derived streaming-VB
(VON) optimizer on the same stream.

Run: PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""

import argparse
import dataclasses
import time
from functools import partial

import jax
import numpy as np

from repro.configs import get_config
from repro.data.tokens import TokenStream, markov_sequence_fast
from repro.nn import transformer as T
from repro.train import optimizer as opt
from repro.train import step as ts

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=256)
args = ap.parse_args()

# ~100M params: 8 layers x d512 x ff2048, vocab 8192
cfg = dataclasses.replace(
    get_config("granite-3-2b"), n_layers=8, d_model=512, n_heads=8,
    n_kv_heads=4, head_dim=64, d_ff=2048, vocab=8192)
print(f"arch={cfg.name}-100m  params~{cfg.n_params() / 1e6:.0f}M")

corpus = markov_sequence_fast(400_000, cfg.vocab, seed=0)
params = T.init_model(jax.random.PRNGKey(0), cfg)

for name, init_fn, step_fn in [
    ("adamw", ts.init_train_state,
     partial(ts.train_step, cfg=cfg,
             lr_fn=opt.cosine_schedule(3e-4, 20, args.steps))),
    ("streaming-vb", ts.init_vb_state,
     partial(ts.vb_train_step, cfg=cfg, n_total=4e5, lr=0.05)),
]:
    state = init_fn(params)
    jstep = jax.jit(step_fn)
    stream = TokenStream(corpus, args.batch, args.seq, seed=1)
    t0, losses = time.time(), []
    for i, b in enumerate(stream.batches(args.steps)):
        state, m = jstep(state, b)
        losses.append(float(m["loss"]))
        if i % 25 == 0:
            print(f"[{name}] step {i:4d} loss {losses[-1]:.4f}")
    tps = args.steps * args.batch * args.seq / (time.time() - t0)
    print(f"[{name}] final loss {losses[-1]:.4f} "
          f"(log V = {np.log(cfg.vocab):.2f}) {tps:,.0f} tok/s\n")
