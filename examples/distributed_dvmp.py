"""d-VMP on a simulated 8-device mesh — the paper's distributed learning.

XLA_FLAGS must be set BEFORE jax import (done below), so run this file
directly: PYTHONPATH=src python examples/distributed_dvmp.py
"""

import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import jax
import numpy as np

from repro.core import dvmp, vmp
from repro.core.dag import PlateSpec
from repro.data.synthetic import gmm_stream

stream, means, _ = gmm_stream(n=8000, k=3, f=6, seed=0)
batch = stream.collect()
spec = PlateSpec(n_features=6, latent_card=3)
cp = vmp.compile_plate(spec)
prior = vmp.default_prior(cp)
init = vmp.symmetry_broken(prior, jax.random.PRNGKey(0))

mesh = jax.make_mesh((8,), ("data",),
                     axis_types=(jax.sharding.AxisType.Auto,))
print(f"devices: {len(jax.devices())}; data shards: 8")

st = dvmp.dvmp_fit(cp, prior, init, batch.xc, batch.xd, mesh,
                   ("data",), max_sweeps=100, tol=1e-6)
print(f"d-VMP converged: sweeps={int(st.sweep)} elbo={float(st.elbo):.1f}")

st1 = vmp.vmp_fit(cp, prior, init, batch.xc, batch.xd, 100, 1e-6)
print(f"single-device    : sweeps={int(st1.sweep)} elbo={float(st1.elbo):.1f}")
print("max |mean difference| =",
      float(np.abs(np.asarray(st.post.reg.m - st1.post.reg.m)).max()))
