"""End-to-end behaviour of the whole system (paper-level claims)."""

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.bayes.drift import LossDriftMonitor
from repro.configs import get_config
from repro.data.tokens import TokenStream, drift_corpus, markov_sequence_fast
from repro.nn import transformer as T
from repro.train import optimizer as opt
from repro.train import step as ts


def test_e2e_training_reduces_loss_below_unigram():
    """Train a small LM for ~60 steps; loss must fall well below log(V)."""
    cfg = get_config("granite-3-2b").reduced()
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    state = ts.init_train_state(params)
    toks = markov_sequence_fast(30_000, cfg.vocab, seed=3)
    stream = TokenStream(toks, batch=8, seq=64)
    lr_fn = opt.cosine_schedule(1.5e-3, 10, 200)
    jstep = jax.jit(partial(ts.train_step, cfg=cfg, lr_fn=lr_fn))
    losses = []
    for b in stream.batches(60):
        state, m = jstep(state, b)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5
    assert losses[-1] < np.log(cfg.vocab) - 0.3


def test_vb_optimizer_learns_and_tracks_uncertainty():
    """The paper's technique as NN trainer: loss falls AND the posterior
    concentrates (per-weight precision grows) as data accumulates."""
    from repro.bayes import vb_optimizer as vb

    cfg = get_config("granite-3-2b").reduced()
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    state = ts.init_vb_state(params)
    toks = markov_sequence_fast(30_000, cfg.vocab, seed=4)
    stream = TokenStream(toks, batch=8, seq=64)
    jstep = jax.jit(partial(ts.vb_train_step, cfg=cfg, n_total=3e4, lr=0.05))
    losses = []
    for b in stream.batches(50):
        state, m = jstep(state, b)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5
    prec = vb.posterior_prec(state.vb, 3e4)
    mean_prec = float(sum(jnp.sum(p) for p in jax.tree_util.tree_leaves(prec))
                      / sum(p.size for p in jax.tree_util.tree_leaves(prec)))
    assert mean_prec > 1.0   # concentrated beyond the unit prior


def test_drift_monitor_fires_on_distribution_shift():
    cfg = get_config("granite-3-2b").reduced()
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    state = ts.init_train_state(params)
    corpus = drift_corpus(20_000, cfg.vocab, seed=5)
    lr_fn = opt.cosine_schedule(1.5e-3, 5, 400)
    jstep = jax.jit(partial(ts.train_step, cfg=cfg, lr_fn=lr_fn))
    monitor = LossDriftMonitor.create(threshold=2.0)
    fired_at = None
    n_steps = 60
    for i in range(n_steps):
        # phase 1 for the first 40 steps, phase 2 afterwards
        half = 0 if i < 40 else 20_000
        stream = TokenStream(corpus[half:half + 20_000], batch=8, seq=64,
                             seed=i)
        b = next(iter(stream.batches(1)))
        state, m = jstep(state, b)
        monitor, drifted = monitor.observe(m["loss"])
        if bool(drifted) and fired_at is None:
            fired_at = i
    assert fired_at is not None and fired_at >= 40, fired_at


def test_streaming_pgm_and_nn_share_drift_machinery():
    """Both stacks use the same Page-Hinkley statistics (one engine)."""
    from repro.core.streaming import drift_init, drift_update

    st = drift_init()
    # stable scores -> no drift
    for _ in range(20):
        st, ph = drift_update(st, jnp.asarray(-1.0))
    assert float(ph) < 1.0
    # collapse in score -> drift statistic rises
    for _ in range(10):
        st, ph = drift_update(st, jnp.asarray(-8.0))
    assert float(ph) > 3.0
