"""The obs aggregation tier: metrics registry (counters / gauges /
log-bucketed histograms with exact-rank quantiles), snapshot merging,
Prometheus + Chrome-trace exporters, replica health scoring, and the
degraded-replica dispatch bias in ``AsyncPGMServer`` — plus the span
error-stamping regression test and the off-vs-trace bit-identity of the
new serving paths."""

import contextlib
import json
import math
import time

import numpy as np
import pytest

from repro.data import synthetic as syn
from repro.obs import agg, export, sink
from repro.obs.health import HealthTracker
from repro.resilience.faultinject import FaultInjector
from repro.serve.queue import AsyncPGMServer


@contextlib.contextmanager
def _obs_to(tmp_path, level="basic"):
    path = str(tmp_path / "events.jsonl")
    prev = sink.configure(level=level, path=path, reset_counters=True)
    try:
        yield path
    finally:
        sink.configure(level=prev["level"], path=prev["path"],
                       reset_counters=True)


def _events(path):
    with open(path) as fh:
        return [json.loads(l) for l in fh if l.strip()]


# ---------------------------------------------------------------------------
# histogram quantiles
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dist", ["lognormal", "uniform", "exponential"])
def test_histogram_quantiles_match_numpy_percentile(dist):
    rng = np.random.default_rng(0)
    draws = {"lognormal": lambda: rng.lognormal(1.0, 1.0, 5000),
             "uniform": lambda: rng.uniform(0.01, 50.0, 5000),
             "exponential": lambda: rng.exponential(3.0, 5000)}[dist]()
    h = agg.Histogram("h")
    for v in draws:
        h.record(v)
    for q in (0.1, 0.25, 0.5, 0.9, 0.95, 0.99):
        got = h.quantile(q)
        want = float(np.percentile(draws, 100 * q))
        # exact-rank within one log bucket: relative error bounded by the
        # bucket width (growth - 1), with slack for rank-vs-interpolation
        assert abs(got - want) / want < h.growth - 1.0 + 0.02, \
            f"q={q}: {got} vs numpy {want}"


def test_histogram_edges_nan_and_empty():
    h = agg.Histogram("h", lo=1.0, hi=16.0, growth=2.0)
    assert h.n_bins == 4
    h.record(float("nan"))                     # ignored, never poisons
    assert h.count == 0
    assert math.isnan(h.quantile(0.5))
    h.record(0.25)                             # underflow -> exact min
    h.record(100.0)                            # overflow -> exact max
    assert h.count == 2
    assert h.quantile(0.0) == 0.25
    assert h.quantile(1.0) == 100.0


def test_counter_and_gauge():
    reg = agg.MetricsRegistry()
    c = reg.counter("reqs_total", mode="exact")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    assert reg.counter("reqs_total", mode="exact") is c   # same instrument
    with pytest.raises(ValueError):
        c.inc(-1)
    g = reg.gauge("score", worker=0)
    g.set(0.75)
    assert g.value == 0.75 and g.updated > 0


# ---------------------------------------------------------------------------
# snapshot merge
# ---------------------------------------------------------------------------


def _reg_with(seed, n=200):
    rng = np.random.default_rng(seed)
    reg = agg.MetricsRegistry()
    reg.counter("c_total", leg=str(seed % 2)).inc(seed + 1)
    g = reg.gauge("g")
    g.set(float(seed))
    h = reg.histogram("lat_ms")
    for v in rng.lognormal(0.5, 1.0, n):
        h.record(v)
    return reg


def test_snapshot_merge_associativity_and_counts():
    a, b, c = (_reg_with(s).snapshot() for s in (1, 2, 3))
    left = agg.merge_snapshots(agg.merge_snapshots(a, b), c)
    right = agg.merge_snapshots(a, agg.merge_snapshots(b, c))
    assert left == right
    hist = [e for e in left["metrics"] if e["kind"] == "histogram"][0]
    assert hist["count"] == 600
    # merged quantile equals the quantile over the pooled draws
    pooled = np.concatenate([np.random.default_rng(s).lognormal(0.5, 1.0, 200)
                             for s in (1, 2, 3)])
    got = agg.quantile_from_snapshot(hist, 0.5)
    want = float(np.percentile(pooled, 50))
    assert abs(got - want) / want < hist["growth"] - 1.0 + 0.02
    # counters added; the gauge kept the newest write (seed 3 set last)
    csum = sum(e["value"] for e in left["metrics"] if e["kind"] == "counter")
    assert csum == (1 + 1) + (2 + 1) + (3 + 1)
    gauge = [e for e in left["metrics"] if e["kind"] == "gauge"][0]
    assert gauge["value"] == 3.0


def test_merge_rejects_mismatched_bucket_configs():
    r1, r2 = agg.MetricsRegistry(), agg.MetricsRegistry()
    r1.histogram("h", growth=1.15).record(1.0)
    r2.histogram("h", growth=2.0).record(1.0)
    with pytest.raises(ValueError, match="bucket configs differ"):
        agg.merge_snapshots(r1.snapshot(), r2.snapshot())


# ---------------------------------------------------------------------------
# exporters (golden outputs)
# ---------------------------------------------------------------------------


def test_prometheus_text_golden():
    reg = agg.MetricsRegistry()
    reg.counter("kernel_dispatch_total", kernel="k:einsum").inc(2)
    reg.gauge("replica_score", worker=0).set(0.5)
    h = reg.histogram("lat_ms", lo=1.0, hi=16.0, growth=2.0, route="a")
    for v in (1.5, 3.0, 20.0):
        h.record(v)
    assert export.prometheus_text(reg.snapshot()) == (
        '# TYPE kernel_dispatch_total counter\n'
        'kernel_dispatch_total{kernel="k:einsum"} 2\n'
        '# TYPE replica_score gauge\n'
        'replica_score{worker="0"} 0.5\n'
        '# TYPE lat_ms histogram\n'
        'lat_ms_bucket{route="a",le="2.0"} 1\n'
        'lat_ms_bucket{route="a",le="4.0"} 2\n'
        'lat_ms_bucket{route="a",le="+Inf"} 3\n'
        'lat_ms_sum{route="a"} 24.5\n'
        'lat_ms_count{route="a"} 3\n')


def test_chrome_trace_golden():
    spans = [
        {"ts": 100.0001, "seq": 2, "run": "r1", "event": "span",
         "name": "serve.flush", "dur_us": 100.0, "span_id": 1,
         "parent_id": None, "tid": 7},
        {"ts": 100.00005, "seq": 1, "run": "r1", "event": "span",
         "name": "serve.bucket", "dur_us": 50.0, "span_id": 2,
         "parent_id": 1, "tid": 7, "batch": 4},
        {"ts": 100.0, "seq": 3, "run": "r1", "event": "metric",
         "name": "x", "value": 1},                       # skipped
    ]
    tr = export.chrome_trace(spans)
    assert tr == {"traceEvents": [
        {"name": "process_name", "ph": "M", "pid": 1, "tid": 0,
         "args": {"name": "obs run r1"}},
        {"name": "serve.flush", "ph": "X", "ts": 100.0001 * 1e6 - 100.0,
         "dur": 100.0, "pid": 1, "tid": 7, "args": {"span_id": 1}},
        {"name": "serve.bucket", "ph": "X", "ts": 100.00005 * 1e6 - 50.0,
         "dur": 50.0, "pid": 1, "tid": 7,
         "args": {"batch": 4, "span_id": 2, "parent_id": 1}},
    ], "displayTimeUnit": "ms"}


def test_write_chrome_trace_roundtrip(tmp_path):
    out = str(tmp_path / "trace.json")
    spans = [{"ts": 1.0, "seq": 1, "run": "r", "event": "span", "name": "a",
              "dur_us": 2.0, "span_id": 1, "parent_id": None, "tid": 0}]
    export.write_chrome_trace([json.dumps(s) for s in spans], out)
    with open(out) as fh:
        assert len(json.load(fh)["traceEvents"]) == 2   # metadata + span


# ---------------------------------------------------------------------------
# span error stamping (regression: a raising body must not look clean)
# ---------------------------------------------------------------------------


def test_span_error_stamped_and_reraised(tmp_path):
    from repro import obs

    with _obs_to(tmp_path, level="trace") as path:
        with pytest.raises(KeyError):
            with obs.span("boom.region", tag="x"):
                raise KeyError("inner failure")
        spans = [e for e in _events(path) if e["event"] == "span"]
    assert len(spans) == 1
    assert spans[0]["name"] == "boom.region"
    assert spans[0]["error"] == "KeyError"
    assert spans[0]["tag"] == "x"
    assert spans[0]["dur_us"] >= 0


def test_configure_reset_clears_default_registry(tmp_path):
    agg.REGISTRY.counter("leftover_total").inc()
    with _obs_to(tmp_path):
        assert agg.REGISTRY.snapshot() == {"metrics": []}


# ---------------------------------------------------------------------------
# health tracker (unit)
# ---------------------------------------------------------------------------


def test_health_tracker_scoring_and_defer():
    tr = HealthTracker(2, alpha=0.5, threshold=0.5, min_flushes=3)
    assert tr.scores() == [1.0, 1.0]
    assert not tr.should_defer(0)              # cold replicas never defer
    for _ in range(5):
        tr.record_flush(0, 100.0)              # slow replica
        tr.record_flush(1, 1.0)                # healthy replica
    s = tr.scores()
    assert s[1] == 1.0 and s[0] < 0.05
    assert tr.should_defer(0) and not tr.should_defer(1)
    snaps = tr.snapshots()
    assert snaps[0]["degraded"] and not snaps[1]["degraded"]
    assert snaps[0]["flushes"] == 5
    # errors sink the score even at equal latency
    tr2 = HealthTracker(2, alpha=0.5, threshold=0.5, min_flushes=1)
    for _ in range(4):
        tr2.record_flush(0, 1.0, error=True)
        tr2.record_flush(1, 1.0)
    assert tr2.should_defer(0)
    assert tr2.snapshots()[0]["errors"] == 4


def test_health_lone_replica_and_uniform_sickness_never_defer():
    lone = HealthTracker(1)
    for _ in range(5):
        lone.record_flush(0, 500.0, error=True)
    assert not lone.should_defer(0)
    both = HealthTracker(2, min_flushes=1)
    for _ in range(5):
        both.record_flush(0, 500.0, error=True)
        both.record_flush(1, 500.0, error=True)
    assert not both.should_defer(0) and not both.should_defer(1)


# ---------------------------------------------------------------------------
# serving integration: degraded replica drains, SLO events, exports
# ---------------------------------------------------------------------------


def _discrete_bn(seed=0):
    return syn.random_discrete_bn(5, card=2, max_parents=2, seed=seed)


def _q(bn, i=0):
    names = [v.name for v in bn.order]
    return names[-1], {names[0]: float(i % 2)}


def test_slow_flush_drops_health_score_and_biases_dispatch(tmp_path):
    bn = _discrete_bn()
    inj = FaultInjector()
    with _obs_to(tmp_path, level="trace") as path:
        srv = AsyncPGMServer(bn, mode="exact", max_batch=8, max_delay_ms=5,
                             default_deadline_ms=60_000, replicas=2,
                             supervise_interval_ms=5)
        srv.submit(*_q(bn)).result(timeout=120)          # warm the plan
        # n is effectively unbounded so the stall cannot run dry before the
        # degraded state is observed on a slow/contended machine
        inj.slow_flush(srv, delay_s=0.08, n=1000, widx=0)
        # phase 1: trickle queries until the stalls have degraded worker 0
        # (adaptive — how fast it racks up flushes depends on scheduling)
        tickets = []
        deadline = time.monotonic() + 30.0
        i = 0
        while time.monotonic() < deadline:
            tickets.append(srv.submit(*_q(bn, i)))
            i += 1
            time.sleep(0.006)
            if srv.health.snapshots()[0]["degraded"]:
                break
        assert srv.health.snapshots()[0]["degraded"], \
            "slow replica never marked degraded"
        # phase 2: more traffic — dispatch must now bias toward worker 1
        for j in range(30):
            tickets.append(srv.submit(*_q(bn, j)))
            time.sleep(0.006)
        # snapshot BEFORE stop(): the drain deliberately disables deferral
        # (never strand a ticket), so the sick replica may catch up on fast
        # flushes during the drain and partially recover its score
        h = srv.health.snapshots()
        srv.stop()
        st = srv.stats()
        # zero lost tickets: every submit resolved with a result
        assert st["pending"] == 0
        for t in tickets:
            assert t.done() and t.error is None
            assert t.result() is not None
        # the stalled replica's score collapsed and it flushed measurably
        # fewer buckets than its healthy peer
        assert h[0]["degraded"] and not h[1]["degraded"]
        assert h[0]["score"] < 0.5 * h[1]["score"]
        assert h[0]["flushes"] < h[1]["flushes"]
        # JSONL: serve_health + slo events present and schema-valid
        counts = sink.validate_obs_events(path)
        assert counts.get("serve_health", 0) >= 2
        assert counts.get("slo", 0) >= 1
        slo = [e for e in _events(path) if e["event"] == "slo"][-1]
        assert slo["p50_ms"] <= slo["p95_ms"] <= slo["p99_ms"]
        assert 0.0 <= slo["miss_rate"] <= 1.0
        # the run exports: Prometheus snapshot + Chrome trace both render
        text = export.prometheus_text(agg.REGISTRY.snapshot())
        assert "serve_request_ms_bucket" in text
        assert "replica_score" in text
        trace = export.write_chrome_trace(path, str(tmp_path / "trace.json"))
        xs = [e for e in trace["traceEvents"] if e["ph"] == "X"]
        assert xs and all(e["dur"] >= 0 for e in xs)


def test_serve_with_health_off_vs_trace_bit_identical(tmp_path):
    bn = _discrete_bn()

    def run():
        srv = AsyncPGMServer(bn, mode="exact", max_batch=4, max_delay_ms=2,
                             default_deadline_ms=60_000, replicas=2)
        tickets = [srv.submit(*_q(bn, i)) for i in range(12)]
        out = [np.asarray(t.result(timeout=120)) for t in tickets]
        srv.stop()
        return out

    prev = sink.configure(level="off", reset_counters=True)
    try:
        base = run()
        with _obs_to(tmp_path, level="trace"):
            traced = run()
    finally:
        sink.configure(level=prev["level"], path=prev["path"],
                       reset_counters=True)
    for a, b in zip(base, traced):
        assert np.array_equal(a, b)            # bit-identical, not allclose


def test_serve_off_level_emits_no_events_or_metrics(tmp_path):
    bn = _discrete_bn()
    path = str(tmp_path / "off.jsonl")
    prev = sink.configure(level="off", path=path, reset_counters=True)
    try:
        srv = AsyncPGMServer(bn, mode="exact", max_batch=4, max_delay_ms=2,
                             default_deadline_ms=60_000, replicas=2)
        [t.result(timeout=120) for t in
         [srv.submit(*_q(bn, i)) for i in range(8)]]
        srv.stop()
        assert not (tmp_path / "off.jsonl").exists()
        # no SLO instrument was ever created with obs off
        names = {e["name"] for e in agg.REGISTRY.snapshot()["metrics"]}
        assert "serve_request_ms" not in names
    finally:
        sink.configure(level=prev["level"], path=prev["path"],
                       reset_counters=True)
