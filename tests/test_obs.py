"""Observability: JSONL sink + schema validation, span tracing, streaming
metrics (drift events on a concept switch), serve-path telemetry, kernel
dispatch counters — and the zero-overhead guarantee that ``REPRO_OBS=off``
leaves every numeric output bit-identical and emits nothing."""

import contextlib
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core import streaming, vmp
from repro.core.dag import PlateSpec
from repro.data import synthetic as syn
from repro.data.stream import DataStream


@contextlib.contextmanager
def _obs_to(tmp_path, level="trace"):
    """Route obs events to a temp JSONL file at ``level``; restore the
    previous config on exit (the CI leg runs pytest under REPRO_OBS=trace,
    so tests must not assume the ambient level)."""
    path = str(tmp_path / "events.jsonl")
    prev = obs.configure(level=level, path=path, reset_counters=True)
    try:
        yield path
    finally:
        obs.configure(level=prev["level"], path=prev["path"],
                      reset_counters=True)


def _events(path):
    with open(path) as fh:
        return [json.loads(l) for l in fh if l.strip()]


def _gmm_setup(n=1000, batch=250, seed=7):
    stream, _, _ = syn.gmm_stream(n, 2, 3, seed=seed)
    spec = PlateSpec(n_features=3, latent_card=2)
    cp = vmp.compile_plate(spec)
    prior = vmp.default_prior(cp)
    init = vmp.symmetry_broken(prior, jax.random.PRNGKey(0))
    batches = list(stream.batches(batch))
    xcs = jnp.stack([b.xc for b in batches])
    xds = jnp.stack([b.xd for b in batches])
    masks = jnp.stack([b.mask for b in batches])
    return cp, prior, init, xcs, xds, masks


# ---------------------------------------------------------------------------
# stream_fit: off is a bit-identical no-op; trace emits schema-valid events
# ---------------------------------------------------------------------------


def test_stream_fit_off_bit_identical_and_trace_emits(tmp_path):
    cp, prior, init, xcs, xds, masks = _gmm_setup()

    with _obs_to(tmp_path, level="off") as path_off:
        s_off = streaming.stream_init(prior, init)
        s_off, info_off = streaming.stream_fit(cp, prior, s_off,
                                               xcs, xds, masks)
        assert not (tmp_path / "events.jsonl").exists(), \
            "REPRO_OBS=off must never open the sink"

    with _obs_to(tmp_path, level="trace") as path:
        s_on = streaming.stream_init(prior, init)
        s_on, info_on = streaming.stream_fit(cp, prior, s_on,
                                             xcs, xds, masks)
        counts = obs.validate_obs_events(path)

    # same device program either way -> bit-identical outputs
    assert np.array_equal(np.asarray(s_off.post.reg.m),
                          np.asarray(s_on.post.reg.m))
    for k in info_off:
        assert np.array_equal(np.asarray(info_off[k]),
                              np.asarray(info_on[k])), k

    T = xcs.shape[0]
    assert counts["stream_batch"] == T
    evs = [e for e in _events(path) if e["event"] == "stream_batch"]
    assert [e["t"] for e in evs] == list(range(T))
    np.testing.assert_allclose([e["elbo"] for e in evs],
                               np.asarray(info_on["elbo"]), rtol=1e-6)
    # in-graph gauges made it out: sweeps-to-convergence and n_eff
    assert all(1 <= e["sweeps"] <= 20 for e in evs)
    assert sum(e["n_eff"] for e in evs) == 1000.0


def test_stream_fit_info_has_metric_columns():
    """The info dict carries every StreamBatchMetrics column with leading
    dim T (the per-batch drift-event mask is part of the fit result)."""
    cp, prior, init, xcs, xds, masks = _gmm_setup(n=500, batch=250)
    state = streaming.stream_init(prior, init)
    _, info = streaming.stream_fit(cp, prior, state, xcs, xds, masks)
    for k in ("elbo", "score", "ph", "drifted", "n_eff", "rho", "sweeps"):
        assert k in info and np.asarray(info[k]).shape[0] == xcs.shape[0], k
    assert not np.asarray(info["drifted"]).any()      # stationary stream
    assert (np.asarray(info["rho"]) == 1.0).all()     # no tempering


# ---------------------------------------------------------------------------
# drift events fire on the bn_stream concept switch (satellite a)
# ---------------------------------------------------------------------------


def test_drift_events_fire_on_bn_stream_concept_switch(tmp_path):
    """Generator switches mid-stream (two different CLG trees); the PH
    test fires after the switch and the firing batches surface both in
    the per-batch ``drifted`` mask and as ``drift`` JSONL events."""
    bn_a = syn.clg_tree_bn(3, seed=0)
    bn_b = syn.clg_tree_bn(3, seed=11, beta_lo=2.0, beta_hi=3.0)
    stream = DataStream.concat([syn.bn_stream(bn_a, 1500, seed=1),
                                syn.bn_stream(bn_b, 1500, seed=2)])
    spec = PlateSpec(n_features=3, latent_card=1)
    cp = vmp.compile_plate(spec)
    prior = vmp.default_prior(cp)
    init = vmp.symmetry_broken(prior, jax.random.PRNGKey(0))
    batches = list(stream.batches(250))
    xcs = jnp.stack([b.xc for b in batches])
    xds = jnp.stack([b.xd for b in batches])
    masks = jnp.stack([b.mask for b in batches])

    with _obs_to(tmp_path, level="basic") as path:
        state = streaming.stream_init(prior, init)
        state, info = streaming.stream_fit(cp, prior, state, xcs, xds, masks,
                                           drift_threshold=3.0)
        counts = obs.validate_obs_events(path)

    flags = np.asarray(info["drifted"])
    switch_at = 1500 // 250
    assert flags.any(), "drift never fired on the concept switch"
    assert not flags[:switch_at].any(), "drift fired before the switch"
    assert int(state.n_drifts) == int(flags.sum())

    drift_evs = [e for e in _events(path) if e["event"] == "drift"]
    assert counts["drift"] == int(flags.sum())
    assert [e["t"] for e in drift_evs] == list(np.flatnonzero(flags))
    assert all(e["ph"] > 3.0 for e in drift_evs)


# ---------------------------------------------------------------------------
# PGMQueryEngine telemetry (satellite d)
# ---------------------------------------------------------------------------


def _exact_engine():
    from repro.serve.engine import PGMQueryEngine

    bn = syn.random_discrete_bn(4, card=3, seed=0, tree=True)
    return PGMQueryEngine(bn, mode="exact")


def test_serve_exact_telemetry(tmp_path):
    with _obs_to(tmp_path, level="trace") as path:
        eng = _exact_engine()
        eng.submit("D0", {"D2": 1, "D3": 2})
        eng.submit("D0", {"D2": 0, "D3": 0})
        eng.submit("D0", {"D3": 1})                 # second schema bucket
        done = eng.flush()
        # same schema at the same batch size -> the AOT executable is
        # reused (the cache key is (schema, batch, dtypes))
        eng.submit("D0", {"D2": 2, "D3": 1})
        eng.submit("D0", {"D2": 1, "D3": 0})
        eng.flush()
        counts = obs.validate_obs_events(path)
        evs = _events(path)

    assert len(done) == 3 and all(q.done for q in done)
    assert counts["serve_flush"] == 2
    assert counts["serve_bucket"] == 3
    assert counts["jt_plan"] == 2          # one per compiled (schema, batch)

    buckets = [e for e in evs if e["event"] == "serve_bucket"]
    by_schema = {}
    for b in buckets:
        by_schema.setdefault(b["schema"], []).append(b)
    assert by_schema["D2,D3"][0]["batch"] == 2
    assert by_schema["D2,D3"][0]["cache_hit"] is False
    assert by_schema["D2,D3"][0]["compile_us"] > 0
    assert by_schema["D2,D3"][1]["cache_hit"] is True   # AOT cache reused
    assert by_schema["D2,D3"][1]["compile_us"] == 0
    assert all(b["latency_us"] > 0 and b["execute_us"] >= 0 for b in buckets)
    assert {b["queue_depth"] for b in buckets} == {3, 2}

    # span nesting: flush spans are roots, bucket/compile/execute have parents
    spans = {e["span_id"]: e for e in evs if e["event"] == "span"}
    names = [s["name"] for s in spans.values()]
    for n in ("serve.flush", "serve.bucket", "jt.compile", "jt.execute"):
        assert n in names, n
    for s in spans.values():
        if s["name"] == "serve.flush":
            assert s["parent_id"] is None
        elif s["name"] == "serve.bucket":
            assert spans[s["parent_id"]]["name"] == "serve.flush"
        else:   # jt.compile / jt.execute nest under their bucket
            assert spans[s["parent_id"]]["name"] == "serve.bucket"


def test_serve_off_no_events_and_identical_posteriors(tmp_path):
    queries = [("D0", {"D2": 1, "D3": 2}), ("D0", {"D2": 0, "D3": 0})]

    with _obs_to(tmp_path, level="off"):
        eng = _exact_engine()
        qs_off = [eng.submit(t, e) for t, e in queries]
        eng.flush()
        assert not (tmp_path / "events.jsonl").exists()

    with _obs_to(tmp_path, level="trace") as path:
        eng = _exact_engine()
        qs_on = [eng.submit(t, e) for t, e in queries]
        eng.flush()
        assert obs.validate_obs_events(path)["serve_bucket"] == 1

    for a, b in zip(qs_off, qs_on):
        assert np.array_equal(a.result, b.result)
        assert a.log_evidence == b.log_evidence


def test_serve_vmp_mode_telemetry(tmp_path):
    from repro.pgm_models import GaussianMixture
    from repro.serve.engine import PGMQueryEngine

    s, _, _ = syn.gmm_stream(600, 3, 4, seed=1)
    m = GaussianMixture(s.attributes, n_states=3)
    m.update_model(s)
    batch = s.collect()

    with _obs_to(tmp_path, level="trace") as path:
        eng = PGMQueryEngine(m, mode="vmp")
        for b in range(3):
            eng.submit("Z", {f"X{i}": float(batch.xc[b, i])
                             for i in range(4)})
        eng.flush()
        for b in range(3, 6):                       # same padded capacity
            eng.submit("Z", {f"X{i}": float(batch.xc[b, i])
                             for i in range(4)})
        done = eng.flush()
        obs.validate_obs_events(path)
        evs = _events(path)

    assert all(q.done for q in done)
    buckets = [e for e in evs if e["event"] == "serve_bucket"]
    assert len(buckets) == 2 and all(b["mode"] == "vmp" for b in buckets)
    assert buckets[0]["cache_hit"] is False
    assert buckets[1]["cache_hit"] is True     # posterior_z capacity reused
    np.testing.assert_allclose(
        np.stack([q.result for q in done]),
        np.asarray(m.posterior_z(batch))[3:6], atol=1e-5)


# ---------------------------------------------------------------------------
# kernel dispatch counters
# ---------------------------------------------------------------------------


def test_kernel_dispatch_counts(tmp_path):
    from repro.kernels import ops

    with _obs_to(tmp_path, level="basic") as path:
        assert obs.kernel_counts() == {}
        x = jnp.zeros((2, 4, 8))
        ops.log_marginalize(x)
        ops.log_marginalize(x)                 # host-side: counted per call
        ops.log_product(x, jnp.zeros((2, 8)))
        kc = obs.kernel_counts()
        obs.emit_kernel_counts(site="test")
        counts = obs.validate_obs_events(path)
        evs = _events(path)

    (lm_key,) = [k for k in kc if k.startswith("log_marginalize:")]
    (lp_key,) = [k for k in kc if k.startswith("log_product:")]
    assert kc[lm_key] == 2 and kc[lp_key] == 1
    assert counts["kernel_dispatch"] == 1
    ev = [e for e in evs if e["event"] == "kernel_dispatch"][0]
    assert ev["counts"] == kc and ev["site"] == "test"


def test_kernel_counters_off_cost_nothing(tmp_path):
    from repro.kernels import ops

    with _obs_to(tmp_path, level="off"):
        ops.log_marginalize(jnp.zeros((2, 4, 8)))
        assert obs.kernel_counts() == {}
        obs.emit_kernel_counts()               # no counts, no file
        assert not (tmp_path / "events.jsonl").exists()


# ---------------------------------------------------------------------------
# with_metrics: local_step chunk gauges and the dvmp mesh path
# ---------------------------------------------------------------------------


def test_local_step_with_metrics_chunked():
    spec = PlateSpec(n_features=3, latent_card=2)
    cp = vmp.compile_plate(spec)
    post = vmp.symmetry_broken(vmp.default_prior(cp), jax.random.PRNGKey(2))
    xc = jax.random.normal(jax.random.PRNGKey(3), (300, 3))
    xd = jnp.zeros((300, 0), jnp.int32)
    mask = jnp.concatenate([jnp.ones(260), jnp.zeros(40)])

    s0, r0 = vmp.local_step(cp, post, xc, xd, mask)
    s1, r1, m1 = vmp.local_step(cp, post, xc, xd, mask, with_metrics=True)
    assert m1.chunk_n_eff.shape == (1,)
    assert float(m1.chunk_n_eff.sum()) == 260.0

    s2, r2, m2 = vmp.local_step(cp, post, xc, xd, mask, chunk=128,
                                with_metrics=True)
    assert m2.chunk_n_eff.shape == (3,)        # ceil(300/128) chunks
    assert float(m2.chunk_n_eff.sum()) == 260.0
    np.testing.assert_allclose(np.asarray(r0), np.asarray(r2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(s0.local_elbo),
                               np.asarray(s2.local_elbo), rtol=1e-5)


def test_dvmp_fit_with_metrics_single_device_mesh():
    from repro.core import dvmp
    from repro.core.compat import make_mesh

    spec = PlateSpec(n_features=3, latent_card=2)
    cp = vmp.compile_plate(spec)
    prior = vmp.default_prior(cp)
    init = vmp.symmetry_broken(prior, jax.random.PRNGKey(0))
    xc = jax.random.normal(jax.random.PRNGKey(3), (128, 3))
    xd = jnp.zeros((128, 0), jnp.int32)
    mesh = make_mesh((1,), ("data",))

    ref = dvmp.dvmp_fit(cp, prior, init, xc, xd, mesh, max_sweeps=10)
    st, metrics = dvmp.dvmp_fit(cp, prior, init, xc, xd, mesh,
                                max_sweeps=10, with_metrics=True)
    assert metrics.shard_n.shape == (1,)       # one shard on a 1-device mesh
    assert float(metrics.shard_n.sum()) == 128.0
    assert int(metrics.sweeps) == int(st.sweep) >= 1
    # the metric-free program is untouched (separate cache key)
    np.testing.assert_allclose(np.asarray(ref.post.reg.m),
                               np.asarray(st.post.reg.m), atol=1e-6)


# ---------------------------------------------------------------------------
# sink mechanics: spans below TRACE, validator rejects malformed streams
# ---------------------------------------------------------------------------


def test_span_null_below_trace(tmp_path):
    with _obs_to(tmp_path, level="basic") as path:
        with obs.span("should.not.emit") as sp:
            assert sp.span_id is None
            sp.add(extra=1)                    # no-op, not an error
        obs.emit("metric", name="x", value=1.0)
        counts = obs.validate_obs_events(path)
    assert "span" not in counts and counts["metric"] == 1


def _line(**kw):
    base = {"ts": 1.0, "seq": kw.pop("seq", 1), "run": "r1",
            "event": "metric", "name": "x", "value": 0}
    base.update(kw)
    return json.dumps(base)


def test_validate_obs_events_rejects_malformed():
    ok = [_line(seq=1), _line(seq=2)]
    assert obs.validate_obs_events(ok) == {"metric": 2}

    with pytest.raises(ValueError, match="invalid JSON"):
        obs.validate_obs_events(["{not json"])
    with pytest.raises(ValueError, match="unknown event"):
        obs.validate_obs_events([_line(event="nope")])
    with pytest.raises(ValueError, match="missing base field"):
        obs.validate_obs_events(['{"ts": 1.0, "seq": 1, "event": "log"}'])
    with pytest.raises(ValueError, match="missing field"):
        obs.validate_obs_events(
            ['{"ts": 1.0, "seq": 1, "run": "r", "event": "drift", "t": 0}'])
    with pytest.raises(ValueError, match="not monotone"):
        obs.validate_obs_events([_line(seq=2), _line(seq=2)])
    # independent runs keep independent seq counters
    assert obs.validate_obs_events(
        [_line(seq=5), _line(seq=3, run="r2")]) == {"metric": 2}


def test_configure_restores_previous():
    prev = obs.configure(level="basic")
    try:
        assert obs.enabled() and not obs.enabled(obs.TRACE)
        with pytest.raises(ValueError, match="unknown obs level"):
            obs.configure(level="loud")
    finally:
        obs.configure(level=prev["level"], path=prev["path"])
