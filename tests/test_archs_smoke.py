"""Assigned-architecture smoke tests (deliverable f).

Each architecture instantiates its REDUCED variant (2 layers, d_model<=512,
<=4 experts) and runs one forward + one train step + a few decode steps on
CPU, asserting output shapes and no NaNs.
"""

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.nn import transformer as T
from repro.train import optimizer as opt
from repro.train import step as ts


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


def _batch(cfg, key, B=2, S=64):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    enc = None
    if cfg.is_encdec:
        enc = jax.random.normal(key, (B, cfg.encoder.enc_len, cfg.d_model))
    return ts.TrainBatch(tokens=toks, labels=jnp.roll(toks, -1, 1),
                         enc_input=enc)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_config_contract(arch):
    cfg = get_config(arch).reduced()
    assert cfg.n_layers == 2
    assert cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch, key):
    cfg = get_config(arch).reduced()
    params = T.init_model(key, cfg)
    batch = _batch(cfg, key)
    out = T.forward(params, batch.tokens, cfg, enc_input=batch.enc_input)
    assert out.logits.shape == (2, 64, cfg.vocab)
    assert bool(jnp.isfinite(out.logits).all())
    if cfg.moe:
        assert float(out.moe_aux) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_decreases_nothing_nan(arch, key):
    cfg = get_config(arch).reduced()
    params = T.init_model(key, cfg)
    state = ts.init_train_state(params)
    batch = _batch(cfg, key)
    lr_fn = opt.cosine_schedule(1e-3, 2, 20)
    jstep = jax.jit(partial(ts.train_step, cfg=cfg, lr_fn=lr_fn))
    l0 = None
    for i in range(3):
        state, m = jstep(state, batch)
        loss = float(m["loss"])
        assert np.isfinite(loss)
        l0 = loss if l0 is None else l0
    assert loss < l0  # same batch thrice must reduce loss


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_steps(arch, key):
    cfg = get_config(arch).reduced()
    params = T.init_model(key, cfg)
    B = 2
    enc = (jax.random.normal(key, (B, cfg.encoder.enc_len, cfg.d_model))
           if cfg.is_encdec else None)
    state = T.init_decode_state(params, cfg, B, capacity=32, enc_input=enc)
    tok = jnp.zeros((B, 1), jnp.int32)
    for _ in range(4):
        logits, state = T.decode_step(params, state, tok, cfg)
        assert logits.shape == (B, 1, cfg.vocab)
        assert bool(jnp.isfinite(logits).all())
        tok = logits.argmax(-1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ["granite-3-2b", "mamba2-1.3b",
                                  "h2o-danube-1.8b"])
def test_decode_consistent_with_prefill(arch, key):
    """Greedy decode continuation must match teacher-forced forward argmax."""
    cfg = get_config(arch).reduced()
    params = T.init_model(key, cfg)
    B, S = 1, 16
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    fwd = T.forward(params, toks, cfg, remat=False)
    fwd_next = np.asarray(fwd.logits.argmax(-1))          # [B, S]
    state = T.init_decode_state(params, cfg, B, capacity=64)
    preds = []
    for t in range(S):
        logits, state = T.decode_step(params, state, toks[:, t:t + 1], cfg)
        preds.append(int(logits[0, 0].argmax()))
    match = (np.asarray(preds) == fwd_next[0]).mean()
    assert match > 0.85, (preds, fwd_next[0].tolist())


def test_vb_train_step_all_family_kinds(key):
    for arch in ["granite-3-2b", "mixtral-8x7b", "mamba2-1.3b"]:
        cfg = get_config(arch).reduced()
        params = T.init_model(key, cfg)
        state = ts.init_vb_state(params)
        batch = _batch(cfg, key)
        jstep = jax.jit(partial(ts.vb_train_step, cfg=cfg, n_total=1e4))
        for _ in range(2):
            state, m = jstep(state, batch)
        assert np.isfinite(float(m["loss"]))
        assert np.isfinite(float(m["kl"]))


def test_param_counts_sane():
    """Config-level param counts in the right ballpark per model card."""
    expect = {
        "granite-3-2b": (2.2e9, 3.6e9),
        "chameleon-34b": (30e9, 39e9),
        "glm4-9b": (8e9, 11e9),
        "gemma-2b": (2.0e9, 3.3e9),
        "h2o-danube-1.8b": (1.5e9, 2.1e9),
        "mamba2-1.3b": (1.0e9, 1.6e9),
        "phi3.5-moe-42b-a6.6b": (39e9, 45e9),
        "mixtral-8x7b": (43e9, 50e9),
        "whisper-medium": (0.6e9, 1.0e9),
        "zamba2-1.2b": (1.0e9, 1.7e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).n_params()
        assert lo <= n <= hi, (arch, n)
    # MoE active < total
    for arch in ["phi3.5-moe-42b-a6.6b", "mixtral-8x7b"]:
        cfg = get_config(arch)
        assert cfg.n_active_params() < 0.45 * cfg.n_params()
