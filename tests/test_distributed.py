"""Distributed correctness: d-VMP shard invariance, sharded train/decode.

These spawn subprocesses with XLA_FLAGS=--xla_force_host_platform_device_count=8
so the main pytest process stays single-device.
"""

import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n: int = 8, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_dvmp_matches_single_device_vmp():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.dag import PlateSpec
        from repro.core import vmp, dvmp
        key = jax.random.PRNGKey(0)
        k1,k2,k3 = jax.random.split(key,3)
        N = 800
        z = jax.random.bernoulli(k1, 0.4, (N,)).astype(int)
        mus = jnp.array([[ 3., -2.],[-3., 2.]])
        x = mus[z] + 0.7*jax.random.normal(k2,(N,2))
        xd = jnp.zeros((N,0), jnp.int32)
        spec = PlateSpec(n_features=2, latent_card=2)
        cp = vmp.compile_plate(spec)
        prior = vmp.default_prior(cp); init = vmp.symmetry_broken(prior, k3)
        st = vmp.vmp_fit(cp, prior, init, x, xd, 50, 1e-6)
        from repro.core.compat import make_mesh
        mesh = make_mesh((8,), ("data",))
        st2 = dvmp.dvmp_fit(cp, prior, init, x, xd, mesh, ("data",), 50, 1e-6)
        assert np.allclose(st.post.reg.m, st2.post.reg.m, atol=1e-3), "means differ"
        assert abs(float(st.elbo - st2.elbo)) < 1.0, (st.elbo, st2.elbo)
        print("DVMP_OK")
    """)
    assert "DVMP_OK" in out


def test_sharded_train_step_matches_single_device():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from repro.configs import get_config
        from repro.nn import transformer as T
        from repro.train import step as ts
        from repro.train import optimizer as opt
        cfg = get_config("granite-3-2b").reduced()
        key = jax.random.PRNGKey(0)
        params = T.init_model(key, cfg)
        toks = jax.random.randint(key, (8, 64), 0, cfg.vocab)
        batch = ts.TrainBatch(tokens=toks, labels=jnp.roll(toks, -1, 1))
        lr_fn = opt.cosine_schedule(1e-3, 10, 100)
        s0 = ts.init_train_state(params)
        _, m0 = jax.jit(partial(ts.train_step, cfg=cfg, lr_fn=lr_fn))(s0, batch)
        from repro.core.compat import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        sh = T.Shardings(mesh=mesh, data_axes=("data",), model_axis="model")
        s1 = ts.init_train_state(params)
        _, m1 = jax.jit(partial(ts.train_step, cfg=cfg, sh=sh, lr_fn=lr_fn))(s1, batch)
        a, b = float(m0["loss"]), float(m1["loss"])
        assert abs(a - b) < 5e-2, (a, b)
        print("TRAIN_SHARD_OK", a, b)
    """)
    assert "TRAIN_SHARD_OK" in out


def test_ctx_parallel_decode_matches_single_device():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.nn import transformer as T
        cfg = get_config("glm4-9b").reduced()
        key = jax.random.PRNGKey(0)
        params = T.init_model(key, cfg)
        B, cap = 8, 64
        st0 = T.init_decode_state(params, cfg, B, cap)
        from repro.core.compat import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        sh = T.Shardings(mesh=mesh, data_axes=("data",), model_axis="model",
                         shard_heads=False)
        st1 = T.init_decode_state(params, cfg, B, cap)
        tok = jnp.zeros((B,1), jnp.int32)
        t0, t1 = tok, tok
        for i in range(6):
            l0, st0 = T.decode_step(params, st0, t0, cfg)
            l1, st1 = T.decode_step(params, st1, t1, cfg, sh)
            t0 = l0.argmax(-1).astype(jnp.int32)
            t1 = l1.argmax(-1).astype(jnp.int32)
            assert (t0 == t1).all(), (i, t0, t1)
            np.testing.assert_allclose(np.asarray(l0), np.asarray(l1),
                                       atol=0.2, rtol=0.05)
        print("DECODE_SHARD_OK")
    """)
    assert "DECODE_SHARD_OK" in out


def test_moe_ep_matches_dense_local():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import MoEConfig
        from repro.nn import moe as M
        cfg = MoEConfig(n_experts=4, top_k=2, capacity_factor=8.0)  # no drops
        key = jax.random.PRNGKey(0)
        d, ff = 32, 64
        x = jax.random.normal(key, (2, 16, d))
        # local (1 shard)
        p1 = M.init_moe(key, d, ff, cfg, ep_shards=1)
        y1, aux1 = M.apply_moe(p1, x, cfg, mesh=None)
        # EP over 4 model shards (same canonical weights, re-laid-out)
        from repro.core.compat import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        p4 = M.init_moe(key, d, ff, cfg, ep_shards=4)
        y4, aux4 = M.apply_moe(p4, x, cfg, mesh=mesh)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y4),
                                   atol=2e-2, rtol=2e-2)
        # expert_load is LINEAR in tokens -> exact under the data-shard pmean;
        # load_balance is a product of means (slightly estimator-dependent)
        np.testing.assert_allclose(np.asarray(aux1.expert_load),
                                   np.asarray(aux4.expert_load), atol=1e-5)
        assert abs(float(aux1.load_balance) - float(aux4.load_balance)) < 0.3
        print("MOE_EP_OK")
    """)
    assert "MOE_EP_OK" in out
