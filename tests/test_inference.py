"""Inference algorithms: importance sampling, factored frontier, MAP."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dag import (BayesianNetwork, CLGCPD, DAG, MultinomialCPD,
                            Variables)
from repro.core.factored_frontier import (Factorial2TBN,
                                          factored_frontier_filter,
                                          factored_frontier_smooth,
                                          hmm_forward, predictive_posterior)
from repro.core.importance_sampling import ImportanceSampling
from repro.core.map_inference import map_inference


@pytest.fixture(scope="module")
def clg_net():
    vs = Variables()
    Z = vs.new_multinomial("Z", 2)
    X1 = vs.new_gaussian("X1")
    X2 = vs.new_gaussian("X2")
    dag = DAG(vs)
    dag.add_parent(X1, Z)
    dag.add_parent(X2, Z)
    cpds = {
        "Z": MultinomialCPD(jnp.array([0.3, 0.7])),
        "X1": CLGCPD(alpha=jnp.array([0.0, 4.0]), beta=jnp.zeros((2, 0)),
                     sigma2=jnp.array([1.0, 1.0])),
        "X2": CLGCPD(alpha=jnp.array([-2.0, 2.0]), beta=jnp.zeros((2, 0)),
                     sigma2=jnp.array([1.0, 1.0])),
    }
    return BayesianNetwork(dag, cpds), Z


def test_importance_sampling_matches_exact(clg_net):
    bn, Z = clg_net
    inf = ImportanceSampling(n_samples=100_000, seed=1)
    inf.set_model(bn)
    inf.set_evidence({"X1": 3.0, "X2": 1.0})
    inf.run_inference()
    post = np.asarray(inf.posterior_discrete(Z))

    def norm_pdf(x, m):
        return np.exp(-0.5 * (x - m) ** 2) / np.sqrt(2 * np.pi)

    l0 = 0.3 * norm_pdf(3, 0) * norm_pdf(1, -2)
    l1 = 0.7 * norm_pdf(3, 4) * norm_pdf(1, 2)
    exact = np.array([l0, l1]) / (l0 + l1)
    np.testing.assert_allclose(post, exact, atol=0.01)
    assert float(inf.effective_sample_size()) > 1000


def test_importance_sampling_evidence_on_root(clg_net):
    """Evidence on a root node: the root is clamped, every particle gets
    the same p(e) weight (uniform -> ESS == n), and children sample from
    the clamped conditional."""
    bn, Z = clg_net
    inf = ImportanceSampling(n_samples=20_000, seed=2)
    inf.set_model(bn)
    inf.set_evidence({"Z": 1})
    inf.run_inference()
    # uniform weights: likelihood weighting on a root contributes the same
    # prior factor to every particle
    assert float(inf.effective_sample_size()) == pytest.approx(20_000,
                                                               rel=1e-4)
    post = np.asarray(inf.posterior_discrete(Z))
    np.testing.assert_allclose(post, [0.0, 1.0], atol=1e-3)
    assert post[0] == 0.0          # the clamped value takes ALL the mass
    m, v = inf.posterior_mean_var(bn.dag.variables.by_name("X1"))
    assert float(m) == pytest.approx(4.0, abs=0.05)
    assert float(v) == pytest.approx(1.0, abs=0.05)


def test_importance_sampling_empty_evidence_prior(clg_net):
    """No evidence = pure prior sampling: uniform weights, posterior ==
    prior marginals."""
    bn, Z = clg_net
    inf = ImportanceSampling(n_samples=50_000, seed=3)
    inf.set_model(bn)
    inf.set_evidence({})
    inf.run_inference()
    assert float(inf.effective_sample_size()) == pytest.approx(50_000,
                                                               rel=1e-4)
    post = np.asarray(inf.posterior_discrete(Z))
    np.testing.assert_allclose(post, [0.3, 0.7], atol=0.01)
    # X2 marginal: mixture mean 0.3*(-2) + 0.7*2 = 0.8
    m, v = inf.posterior_mean_var(bn.dag.variables.by_name("X2"))
    assert float(m) == pytest.approx(0.8, abs=0.05)
    # mixture variance: 1 + E[mu^2] - E[mu]^2 = 1 + (0.3*4 + 0.7*4) - 0.64
    assert float(v) == pytest.approx(1.0 + 4.0 - 0.64, abs=0.1)


def test_bn_sampling_consistency(clg_net):
    bn, Z = clg_net
    asg = bn.sample(jax.random.PRNGKey(0), 50_000)
    assert float((asg["Z"] == 1).mean()) == pytest.approx(0.7, abs=0.02)
    x1_mean_given_z1 = float(asg["X1"][asg["Z"] == 1].mean())
    assert x1_mean_given_z1 == pytest.approx(4.0, abs=0.05)


def test_factored_frontier_exact_for_single_chain():
    key = jax.random.PRNGKey(4)
    T, S = 40, 3
    trans = jax.nn.softmax(jax.random.normal(key, (S, S)) * 2, -1)
    init = jnp.ones(S) / S
    ll = jax.random.normal(key, (T, S))
    bel, _ = hmm_forward(init, trans, ll)
    a = init * jnp.exp(ll[0]); a = a / a.sum()
    for t in range(1, T):
        a = (a @ trans) * jnp.exp(ll[t]); a = a / a.sum()
    np.testing.assert_allclose(np.asarray(bel[-1]), np.asarray(a), atol=1e-5)


def test_factored_frontier_smoothing_and_prediction():
    key = jax.random.PRNGKey(5)
    model = Factorial2TBN(
        init=jnp.array([[0.9, 0.1], [0.5, 0.5]]),
        trans=jnp.stack([jnp.array([[0.9, 0.1], [0.1, 0.9]]),
                         jnp.array([[0.5, 0.5], [0.5, 0.5]])]))
    ll = jax.random.normal(key, (20, 2, 2))
    gamma = factored_frontier_smooth(model, ll)
    assert gamma.shape == (20, 2, 2)
    np.testing.assert_allclose(np.asarray(gamma.sum(-1)), 1.0, atol=1e-5)
    beliefs, _ = factored_frontier_filter(model, ll)
    pred = predictive_posterior(model, beliefs[-1], horizon=50)
    # chain 1 is uniform-mixing: long-horizon prediction -> stationary 0.5
    np.testing.assert_allclose(np.asarray(pred[1]), [0.5, 0.5], atol=1e-3)


def test_map_inference_finds_mode():
    vs = Variables()
    Z = vs.new_multinomial("Z", 2)
    W = vs.new_multinomial("W", 3)
    X1 = vs.new_gaussian("X1")
    dag = DAG(vs)
    dag.add_parent(X1, Z)
    dag.add_parent(W, Z)
    cpds = {
        "Z": MultinomialCPD(jnp.array([0.3, 0.7])),
        "W": MultinomialCPD(jnp.array([[0.8, 0.1, 0.1], [0.1, 0.1, 0.8]])),
        "X1": CLGCPD(alpha=jnp.array([0.0, 4.0]), beta=jnp.zeros((2, 0)),
                     sigma2=jnp.array([1.0, 1.0])),
    }
    bn = BayesianNetwork(dag, cpds)
    asg, lp = map_inference(bn, {"X1": 3.8}, n_starts=16, n_passes=4)
    assert asg == {"Z": 1, "W": 2}
    assert np.isfinite(lp)
