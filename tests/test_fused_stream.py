"""Fused streaming hot path: suff-stats backend parity, chunked local step,
stream_fit scan driver vs the per-batch loop, dvmp program caching."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import expfam as ef
from repro.core import streaming, vmp
from repro.core.dag import PlateSpec
from repro.data.synthetic import drift_stream, gmm_stream, nb_stream


def _mixed_setup(n=600, seed=0):
    """Mixed CLG + discrete plate with a masked tail (padded instances)."""
    spec = PlateSpec(n_features=5, latent_card=3,
                     discrete_features=((3, 3), (4, 2)))
    cp = vmp.compile_plate(spec)
    prior = vmp.default_prior(cp)
    post = vmp.symmetry_broken(prior, jax.random.PRNGKey(seed))
    xc = jax.random.normal(jax.random.PRNGKey(seed + 1), (n, 3))
    xd = jax.random.randint(jax.random.PRNGKey(seed + 2), (n, 2), 0, 2)
    mask = jnp.concatenate([jnp.ones(n - n // 8), jnp.zeros(n // 8)])
    return cp, prior, post, xc, xd, mask


def _assert_stats_close(a, b, label, atol=5e-4, rtol=1e-4):
    # densify: the einsum backend stores the latent-latent block lazily as
    # [K, L, L] while the fused pallas kernel emits the full matrix
    ra, rb = ef.reg_dense(a.reg), ef.reg_dense(b.reg)
    for la, lb, name in [
        (a.counts, b.counts, "counts"), (ra.sxx, rb.sxx, "sxx"),
        (ra.sxy, rb.sxy, "sxy"), (ra.syy, rb.syy, "syy"),
        (a.disc, b.disc, "disc"), (a.n, b.n, "n"),
        (a.local_elbo, b.local_elbo, "local_elbo"),
    ]:
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   atol=atol, rtol=rtol,
                                   err_msg=f"{label}: {name}")


@pytest.mark.parametrize("backend", ["einsum", "pallas"])
@pytest.mark.parametrize("chunk", [None, 256, 100])  # 100 -> ragged last chunk
def test_local_step_backend_parity_mixed_plate(backend, chunk):
    """Fused/chunked backends match the reference einsum path on mixed
    CLG+discrete plates including padded/masked tail instances."""
    cp, prior, post, xc, xd, mask = _mixed_setup()
    ref_stats, ref_r = vmp.local_step(cp, post, xc, xd, mask)
    stats, r = vmp.local_step(cp, post, xc, xd, mask,
                              backend=backend, chunk=chunk)
    _assert_stats_close(ref_stats, stats, f"{backend}/{chunk}")
    np.testing.assert_allclose(np.asarray(ref_r), np.asarray(r), atol=1e-5)


@pytest.mark.parametrize("backend", ["einsum", "pallas"])
@pytest.mark.parametrize("L,latent_card", [(1, 0), (2, 3), (8, 2)])
def test_local_step_parity_latent_dim(backend, L, latent_card):
    """FA/PPCA plates (L > 0): the fused component-major kernel and the
    lazy-latent-block einsum path match the unchunked reference under
    chunked accumulation, across latent dims and with padded/masked tails
    (300 % 128 != 0 also exercises the kernel's instance padding)."""
    spec = PlateSpec(n_features=4, latent_card=latent_card, latent_dim=L)
    cp = vmp.compile_plate(spec)
    prior = vmp.default_prior(cp)
    post = vmp.symmetry_broken(prior, jax.random.PRNGKey(3))
    xc = jax.random.normal(jax.random.PRNGKey(4), (300, 4))
    xd = jnp.zeros((300, 0), jnp.int32)
    mask = jnp.concatenate([jnp.ones(260), jnp.zeros(40)])
    ref_stats, ref_r = vmp.local_step(cp, post, xc, xd, mask)
    stats, r = vmp.local_step(cp, post, xc, xd, mask,
                              backend=backend, chunk=128)
    _assert_stats_close(ref_stats, stats, f"{backend}/L{L}")
    np.testing.assert_allclose(np.asarray(ref_r), np.asarray(r), atol=1e-5)


def test_local_step_latent_lazy_vs_fused_forms():
    """The einsum backend stores the leaf-shared latent-latent block ONCE
    ([K, L, L], no per-leaf broadcast); the fused pallas kernel emits the
    dense matrix; reg_dense reconciles them exactly."""
    spec = PlateSpec(n_features=5, latent_card=3, latent_dim=4)
    cp = vmp.compile_plate(spec)
    prior = vmp.default_prior(cp)
    post = vmp.symmetry_broken(prior, jax.random.PRNGKey(0))
    xc = jax.random.normal(jax.random.PRNGKey(1), (200, 5))
    xd = jnp.zeros((200, 0), jnp.int32)
    se, _ = vmp.local_step(cp, post, xc, xd, jnp.ones(200))
    sp, _ = vmp.local_step(cp, post, xc, xd, jnp.ones(200),
                           backend="pallas")
    lay = cp.layout
    assert se.reg.sxx_hh is not None
    assert se.reg.sxx_hh.shape == (lay.K, lay.L, lay.L)
    assert se.reg.sxx.shape == (lay.F, lay.K, 1 + lay.P, lay.D)
    assert sp.reg.sxx_hh is None
    assert sp.reg.sxx.shape == (lay.F, lay.K, lay.D, lay.D)
    dense = ef.reg_dense(se.reg)
    assert dense.sxx.shape == sp.reg.sxx.shape
    # the dense matrix is symmetric and its hh block is leaf-shared
    np.testing.assert_allclose(np.asarray(dense.sxx),
                               np.asarray(np.swapaxes(dense.sxx, -1, -2)),
                               atol=1e-6)
    # both feed the same conjugate update
    pe = vmp.global_update(prior, se)
    pp = vmp.global_update(prior, sp)
    np.testing.assert_allclose(np.asarray(pe.reg.m), np.asarray(pp.reg.m),
                               atol=1e-4)


def test_local_step_latent_nonuniform_mask_falls_back_dense():
    """Per-leaf latent masks (CustomGlobalLocalModel) keep the dense,
    leaf-dependent hh block on every backend — and they still agree."""
    spec = PlateSpec(n_features=3, latent_card=2, latent_dim=3)
    cp = vmp.compile_plate(spec, jnp.eye(3))
    post = vmp.symmetry_broken(vmp.default_prior(cp), jax.random.PRNGKey(2))
    xc = jax.random.normal(jax.random.PRNGKey(5), (150, 3))
    xd = jnp.zeros((150, 0), jnp.int32)
    se, _ = vmp.local_step(cp, post, xc, xd, jnp.ones(150))
    sp, _ = vmp.local_step(cp, post, xc, xd, jnp.ones(150),
                           backend="pallas")
    assert se.reg.sxx_hh is None and sp.reg.sxx_hh is None
    _assert_stats_close(se, sp, "nonuniform-mask")


def test_local_step_chunked_r_fixed():
    """Supervised path (clamped q(Z)) survives the chunked scan."""
    cp, prior, post, xc, xd, mask = _mixed_setup()
    rf = jax.nn.one_hot(
        jax.random.randint(jax.random.PRNGKey(9), (xc.shape[0],), 0, 3), 3)
    ref_stats, ref_r = vmp.local_step(cp, post, xc, xd, mask, rf)
    stats, r = vmp.local_step(cp, post, xc, xd, mask, rf,
                              backend="pallas", chunk=128)
    _assert_stats_close(ref_stats, stats, "r_fixed")
    np.testing.assert_allclose(np.asarray(ref_r), np.asarray(r), atol=1e-6)


def test_vmp_fit_backend_invariance():
    """Full fits agree across backends/chunking (same fixed point)."""
    stream, means, _ = gmm_stream(800, 2, 3, seed=5)
    full = stream.collect()
    spec = PlateSpec(n_features=3, latent_card=2)
    cp = vmp.compile_plate(spec)
    prior = vmp.default_prior(cp)
    init = vmp.symmetry_broken(prior, jax.random.PRNGKey(0))
    ref = vmp.vmp_fit(cp, prior, init, full.xc, full.xd, 60, 1e-6)
    st = vmp.vmp_fit(cp, prior, init, full.xc, full.xd, 60, 1e-6,
                     None, "pallas", 256)
    np.testing.assert_allclose(np.asarray(ref.post.reg.m),
                               np.asarray(st.post.reg.m), atol=1e-3)


# ---------------------------------------------------------------------------
# stream_fit scan driver vs the per-batch stream_update loop
# ---------------------------------------------------------------------------


def _stacked(batches):
    return (jnp.stack([b.xc for b in batches]),
            jnp.stack([b.xd for b in batches]),
            jnp.stack([b.mask for b in batches]))


def test_stream_fit_matches_loop_with_padded_tail():
    """Scan replay == per-batch loop on a stationary stream whose last
    batch is zero-padded and masked."""
    stream, _, _ = gmm_stream(1100, 2, 3, seed=7)   # 1100 % 250 != 0
    spec = PlateSpec(n_features=3, latent_card=2)
    cp = vmp.compile_plate(spec)
    prior = vmp.default_prior(cp)
    init = vmp.symmetry_broken(prior, jax.random.PRNGKey(0))
    batches = list(stream.batches(250))
    assert float(batches[-1].mask.sum()) < 250  # really exercises the pad

    ss = streaming.stream_init(prior, init)
    elbos = []
    for b in batches:
        ss, info = streaming.stream_update(cp, prior, ss, b.xc, b.xd,
                                           mask=b.mask)
        elbos.append(float(info["elbo"]))

    sf = streaming.stream_init(prior, init)
    sf, infos = streaming.stream_fit(cp, prior, sf, *_stacked(batches))

    np.testing.assert_allclose(np.asarray(ss.post.reg.m),
                               np.asarray(sf.post.reg.m),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(elbos), np.asarray(infos["elbo"]),
                               rtol=1e-4)
    assert float(ss.n_seen) == float(sf.n_seen) == 1100.0
    assert int(ss.n_drifts) == int(sf.n_drifts)


def test_stream_fit_drift_flags_match_loop():
    """Drift detection (flags, PH stats, n_drifts) is identical between the
    scan driver and the per-batch loop, and the model re-adapts."""
    stream, _ = drift_stream(1500, 3, seed=8)
    spec = PlateSpec(n_features=3, latent_card=1)
    cp = vmp.compile_plate(spec)
    prior = vmp.default_prior(cp)
    init = vmp.symmetry_broken(prior, jax.random.PRNGKey(0))
    batches = list(stream.batches(250))

    ss = streaming.stream_init(prior, init)
    loop_flags = []
    for b in batches:
        ss, info = streaming.stream_update(cp, prior, ss, b.xc, b.xd,
                                           drift_threshold=3.0)
        loop_flags.append(bool(info["drifted"]))

    sf = streaming.stream_init(prior, init)
    sf, infos = streaming.stream_fit(cp, prior, sf, *_stacked(batches),
                                     drift_threshold=3.0)
    scan_flags = [bool(d) for d in np.asarray(infos["drifted"])]

    assert loop_flags == scan_flags
    assert any(loop_flags), "drift never fired"
    assert int(ss.n_drifts) == int(sf.n_drifts) == sum(loop_flags)
    np.testing.assert_allclose(np.asarray(ss.post.reg.m),
                               np.asarray(sf.post.reg.m),
                               rtol=1e-4, atol=1e-4)
    # re-adapted to the +6 shifted phase
    assert (np.asarray(sf.post.reg.m[:, 0, 0]) > 2.0).all()


def test_stream_fit_pallas_backend_mixed_plate():
    """The fused backend drives the whole scan on a CLG+discrete stream."""
    stream, _ = nb_stream(240, 2, 2, 1, seed=3)
    batch = stream.collect()
    spec = PlateSpec(n_features=4, latent_card=2,
                     discrete_features=((2, 3), (3, 2)))
    cp = vmp.compile_plate(spec)
    prior = vmp.default_prior(cp)
    init = vmp.symmetry_broken(prior, jax.random.PRNGKey(1))
    xcs = batch.xc.reshape(4, 60, 2)
    xds = batch.xd.reshape(4, 60, 2)

    ref, _ = streaming.stream_fit(cp, prior,
                                  streaming.stream_init(prior, init),
                                  xcs, xds, sweeps=3)
    got, infos = streaming.stream_fit(cp, prior,
                                      streaming.stream_init(prior, init),
                                      xcs, xds, sweeps=3,
                                      backend="pallas", chunk=32)
    np.testing.assert_allclose(np.asarray(ref.post.reg.m),
                               np.asarray(got.post.reg.m),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(ref.post.disc.alpha),
                               np.asarray(got.post.disc.alpha),
                               rtol=1e-4, atol=1e-4)
    assert np.isfinite(np.asarray(infos["elbo"])).all()


def test_stream_fit_latent_plate_pallas_backend():
    """FA/PPCA plates (L > 0) ride the same donated single-scan streaming
    program as mixtures, on the fused kernel backend."""
    spec = PlateSpec(n_features=4, latent_card=2, latent_dim=2)
    cp = vmp.compile_plate(spec)
    prior = vmp.default_prior(cp)
    init = vmp.symmetry_broken(prior, jax.random.PRNGKey(1))
    xc = jax.random.normal(jax.random.PRNGKey(2), (240, 4))
    xcs = xc.reshape(4, 60, 4)
    xds = jnp.zeros((4, 60, 0), jnp.int32)

    ref, _ = streaming.stream_fit(cp, prior,
                                  streaming.stream_init(prior, init),
                                  xcs, xds, sweeps=3)
    got, infos = streaming.stream_fit(cp, prior,
                                      streaming.stream_init(prior, init),
                                      xcs, xds, sweeps=3,
                                      backend="pallas", chunk=32)
    np.testing.assert_allclose(np.asarray(ref.post.reg.m),
                               np.asarray(got.post.reg.m),
                               rtol=1e-4, atol=1e-4)
    assert np.isfinite(np.asarray(infos["elbo"])).all()


def test_dvmp_latent_plate_matches_single_device():
    """d-VMP psums the lazy latent-block message pytree correctly: the
    mesh fit equals the single-device fit on an FA-mixture plate."""
    from repro.core import dvmp
    from repro.core.compat import make_mesh

    spec = PlateSpec(n_features=3, latent_card=2, latent_dim=2)
    cp = vmp.compile_plate(spec)
    prior = vmp.default_prior(cp)
    init = vmp.symmetry_broken(prior, jax.random.PRNGKey(0))
    xc = jax.random.normal(jax.random.PRNGKey(3), (128, 3))
    xd = jnp.zeros((128, 0), jnp.int32)
    mesh = make_mesh((1,), ("data",))
    single = vmp.vmp_fit(cp, prior, init, xc, xd, 10, 0.0)
    dist = dvmp.dvmp_fit(cp, prior, init, xc, xd, mesh, ("data",), 10, 0.0)
    np.testing.assert_allclose(np.asarray(single.post.reg.m),
                               np.asarray(dist.post.reg.m),
                               rtol=1e-4, atol=1e-4)


def test_stream_fit_windowed_matches_full_scan():
    """window= replays the stream in device-sliced windows (host-resident
    stack) and matches the single full scan exactly, ragged tail included."""
    stream, _, _ = gmm_stream(1100, 2, 3, seed=7)
    spec = PlateSpec(n_features=3, latent_card=2)
    cp = vmp.compile_plate(spec)
    prior = vmp.default_prior(cp)
    init = vmp.symmetry_broken(prior, jax.random.PRNGKey(0))
    batches = list(stream.batches(250))
    xcs, xds, masks = _stacked(batches)
    xcs_h, xds_h, masks_h = (np.asarray(xcs), np.asarray(xds),
                             np.asarray(masks))

    ref, iref = streaming.stream_fit(cp, prior,
                                     streaming.stream_init(prior, init),
                                     xcs, xds, masks)
    win, iwin = streaming.stream_fit(cp, prior,
                                     streaming.stream_init(prior, init),
                                     xcs_h, xds_h, masks_h, window=2)
    np.testing.assert_allclose(np.asarray(ref.post.reg.m),
                               np.asarray(win.post.reg.m),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(iref["elbo"]),
                               np.asarray(iwin["elbo"]), rtol=1e-5)
    assert iwin["elbo"].shape[0] == len(batches)
    assert float(ref.n_seen) == float(win.n_seen) == 1100.0


def test_stream_fit_donation_keeps_inputs_alive():
    """stream_init copies the globals, so the caller's prior/init (and a
    second replay from the same arrays) survive buffer donation."""
    stream, _, _ = gmm_stream(400, 2, 3, seed=2)
    spec = PlateSpec(n_features=3, latent_card=2)
    cp = vmp.compile_plate(spec)
    prior = vmp.default_prior(cp)
    init = vmp.symmetry_broken(prior, jax.random.PRNGKey(0))
    batches = list(stream.batches(100))
    xcs, xds, masks = _stacked(batches)
    s1, _ = streaming.stream_fit(cp, prior,
                                 streaming.stream_init(prior, init),
                                 xcs, xds, masks)
    s2, _ = streaming.stream_fit(cp, prior,
                                 streaming.stream_init(prior, init),
                                 xcs, xds, masks)
    np.testing.assert_allclose(np.asarray(s1.post.reg.m),
                               np.asarray(s2.post.reg.m))
    assert np.isfinite(float(prior.mix.alpha.sum()))


# ---------------------------------------------------------------------------
# dvmp program caching (the per-batch retrace bug)
# ---------------------------------------------------------------------------


def test_dvmp_programs_are_cached():
    from repro.core import dvmp
    from repro.core.compat import make_mesh

    stream, _, _ = gmm_stream(64, 2, 3, seed=1)
    full = stream.collect()
    spec = PlateSpec(n_features=3, latent_card=2)
    cp = vmp.compile_plate(spec)
    prior = vmp.default_prior(cp)
    init = vmp.symmetry_broken(prior, jax.random.PRNGKey(0))
    mesh = make_mesh((1,), ("data",))
    mask = jnp.ones(64)

    dvmp._sweep_program.cache_clear()
    dvmp._fit_program.cache_clear()
    post, e = dvmp.dvmp_one_sweep(cp, prior, init, full.xc, full.xd, mask,
                                  mesh, ("data",))
    for _ in range(3):
        post, e = dvmp.dvmp_one_sweep(cp, prior, post, full.xc, full.xd,
                                      mask, mesh, ("data",))
    info = dvmp._sweep_program.cache_info()
    assert info.currsize == 1, "one program per (cp, mesh, axes)"
    assert info.hits == 3

    for _ in range(2):
        dvmp.dvmp_fit(cp, prior, init, full.xc, full.xd, mesh, ("data",),
                      10, 1e-4)
    assert dvmp._fit_program.cache_info().currsize == 1
    assert np.isfinite(float(e))


def test_posterior_z_is_jitted_and_correct():
    stream, _, labels = gmm_stream(900, 2, 3, seed=6)
    full = stream.collect()
    spec = PlateSpec(n_features=3, latent_card=2)
    cp = vmp.compile_plate(spec)
    prior = vmp.default_prior(cp)
    init = vmp.symmetry_broken(prior, jax.random.PRNGKey(0))
    st = vmp.vmp_fit(cp, prior, init, full.xc, full.xd, 80, 1e-6)
    r = vmp.posterior_z(cp, st.post, full.xc, full.xd)
    r_chunked = vmp.posterior_z(cp, st.post, full.xc, full.xd,
                                backend="pallas", chunk=256)
    np.testing.assert_allclose(np.asarray(r), np.asarray(r_chunked),
                               atol=1e-5)
    acc = max(float((np.asarray(r).argmax(1) == labels).mean()),
              float((np.asarray(r).argmax(1) != labels).mean()))
    assert acc > 0.95
