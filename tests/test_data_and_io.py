"""Data pipeline + checkpoint tests."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.stream import Attribute, Batch, DataStream, REAL
from repro.data.tokens import TokenStream, drift_corpus, markov_sequence_fast
from repro.train import checkpoint as ck
from repro.train.step import TrainBatch


def test_datastream_batching_and_padding():
    attrs = [Attribute("a", REAL), Attribute("b", REAL)]
    x = np.arange(20, dtype=np.float32).reshape(10, 2)
    s = DataStream.from_arrays(attrs, x)
    batches = list(s.batches(4))
    assert len(batches) == 3
    assert all(b.xc.shape == (4, 2) for b in batches)
    assert float(batches[-1].mask.sum()) == 2.0   # 10 = 4+4+2
    # content preserved in order
    rec = np.concatenate([np.asarray(b.xc[b.mask > 0]) for b in batches])
    np.testing.assert_array_equal(rec, x)


def test_datastream_concat_and_collect():
    attrs = [Attribute("a", REAL)]
    s1 = DataStream.from_arrays(attrs, np.ones((5, 1), np.float32))
    s2 = DataStream.from_arrays(attrs, 2 * np.ones((7, 1), np.float32))
    s = DataStream.concat([s1, s2])
    full = s.collect()
    assert full.xc.shape == (12, 1)
    assert float(full.xc.sum()) == 5 + 14


def test_token_stream_shapes_and_labels():
    toks = markov_sequence_fast(5000, 100, seed=1)
    assert toks.min() >= 0 and toks.max() < 100
    ts = TokenStream(toks, batch=4, seq=32)
    for b in ts.batches(3):
        assert b.tokens.shape == (4, 32)
        # labels are the next-token shift
        np.testing.assert_array_equal(np.asarray(b.labels[:, :-1]),
                                      np.asarray(b.tokens[:, 1:]))


def test_markov_corpus_is_learnable_structure():
    """Markov corpus has much lower conditional entropy than uniform."""
    toks = markov_sequence_fast(20000, 50, seed=2)
    joint = np.zeros((50, 50))
    for a, b in zip(toks[:-1], toks[1:]):
        joint[a, b] += 1
    cond = joint / np.maximum(joint.sum(1, keepdims=True), 1)
    ent = -(cond * np.log(np.maximum(cond, 1e-12))).sum(1)
    w = joint.sum(1) / joint.sum()
    assert (w * ent).sum() < 0.7 * np.log(50)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": {"b": jnp.arange(6).reshape(2, 3).astype(jnp.float32)},
            "c": [jnp.ones(4), jnp.zeros((2, 2), jnp.int32)]}
    path = str(tmp_path / "ck.npz")
    ck.save(path, tree)
    loaded = ck.load(path, tree)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    path = str(tmp_path / "ck.npz")
    ck.save(path, {"w": jnp.ones((2, 2))})
    with pytest.raises(ValueError):
        ck.load(path, {"w": jnp.ones((3, 2))})


def test_drift_corpus_has_two_regimes():
    c = drift_corpus(3000, 64, seed=3)
    assert len(c) == 6000
    # transition tables of the two halves differ
    def table(t):
        j = np.zeros((64, 64))
        for a, b in zip(t[:-1], t[1:]):
            j[a, b] += 1
        return j / max(j.sum(), 1)
    d = np.abs(table(c[:3000]) - table(c[3000:])).sum()
    assert d > 0.5
