"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps.

The factor-algebra and CG kernels run through ``repro.kernels.ops`` so they
follow the session's interpret policy: scripts/ci.sh runs this file once
with ``REPRO_PALLAS_INTERPRET=1`` and once under the default policy, so a
TPU runner exercises the compiled path against the same oracles the CPU
container checks in interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.clg_stats import (clg_disc_counts, clg_suffstats,
                                     clg_suffstats_latent)
from repro.kernels.family_counts import family_counts
from repro.kernels.flash_attn import flash_attention
from repro.kernels.ssd_scan import ssd_scan

KEYS = jax.random.split(jax.random.PRNGKey(0), 8)


@pytest.mark.parametrize("B,S,Hq,Hkv,D", [
    (1, 128, 4, 4, 64),     # MHA
    (2, 256, 4, 2, 64),     # GQA
    (1, 128, 4, 1, 128),    # MQA
    (1, 192, 2, 2, 256),    # gemma-style head_dim, ragged seq/block
])
@pytest.mark.parametrize("window", [None, 64])
def test_flash_attention_sweep(B, S, Hq, Hkv, D, window):
    q = jax.random.normal(KEYS[0], (B, S, Hq, D), jnp.float32)
    k = jax.random.normal(KEYS[1], (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(KEYS[2], (B, S, Hkv, D), jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=window, bq=64, bk=64)
    exp = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 3e-5), (jnp.bfloat16, 0.05)])
def test_flash_attention_dtypes(dtype, tol):
    B, S, Hq, Hkv, D = 1, 128, 2, 1, 64
    q = jax.random.normal(KEYS[0], (B, S, Hq, D)).astype(dtype)
    k = jax.random.normal(KEYS[1], (B, S, Hkv, D)).astype(dtype)
    v = jax.random.normal(KEYS[2], (B, S, Hkv, D)).astype(dtype)
    out = flash_attention(q, k, v, bq=64, bk=64)
    exp = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_noncausal():
    B, S, Hq, Hkv, D = 1, 128, 2, 2, 64
    q = jax.random.normal(KEYS[0], (B, S, Hq, D))
    k = jax.random.normal(KEYS[1], (B, S, Hkv, D))
    v = jax.random.normal(KEYS[2], (B, S, Hkv, D))
    out = flash_attention(q, k, v, causal=False, bq=64, bk=64)
    exp = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5)


@pytest.mark.parametrize("b,S,H,P,G,N,chunk", [
    (2, 128, 4, 32, 1, 64, 32),
    (1, 256, 2, 64, 2, 32, 64),
    (1, 128, 8, 64, 1, 128, 128),   # mamba2-1.3b tile shape
])
def test_ssd_scan_sweep(b, S, H, P, G, N, chunk):
    x = jax.random.normal(KEYS[3], (b, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(KEYS[4], (b, S, H)))
    A = jnp.exp(jax.random.normal(KEYS[5], (H,)) * 0.3)
    B = jax.random.normal(KEYS[6], (b, S, G, N))
    C = jax.random.normal(KEYS[7], (b, S, G, N))
    y, h = ssd_scan(x, dt, A, B, C, chunk)
    y_ref, h_ref = ref.ssd_scan_ref(x, dt, A, B, C, chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               atol=2e-4, rtol=2e-4)


def test_ssd_scan_chunk_invariance():
    """Different chunk sizes = same math (the SSD identity)."""
    b, S, H, P, G, N = 1, 128, 2, 16, 1, 32
    x = jax.random.normal(KEYS[3], (b, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(KEYS[4], (b, S, H)))
    A = jnp.exp(jax.random.normal(KEYS[5], (H,)) * 0.3)
    B = jax.random.normal(KEYS[6], (b, S, G, N))
    C = jax.random.normal(KEYS[7], (b, S, G, N))
    y32, _ = ssd_scan(x, dt, A, B, C, 32)
    y128, _ = ssd_scan(x, dt, A, B, C, 128)
    np.testing.assert_allclose(np.asarray(y32), np.asarray(y128),
                               atol=3e-4, rtol=3e-4)


@pytest.mark.parametrize("N,F,D,K,block", [
    (1000, 3, 4, 2, 256),
    (513, 1, 2, 5, 128),     # ragged N vs block
    (256, 2, 8, 16, 64),     # K = 16 components
])
def test_clg_suffstats_sweep(N, F, D, K, block):
    d = jax.random.normal(KEYS[0], (N, F, D))
    y = jax.random.normal(KEYS[1], (N, F))
    r = jax.nn.softmax(jax.random.normal(KEYS[2], (N, K)), -1)
    sxx, sxy, syy = clg_suffstats(d, y, r, block=block)
    rxx, rxy, ryy = ref.clg_suffstats_ref(d, y, r)
    np.testing.assert_allclose(np.asarray(sxx), np.asarray(rxx),
                               atol=1e-3, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(sxy), np.asarray(rxy),
                               atol=1e-3, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(syy), np.asarray(ryy),
                               atol=1e-3, rtol=1e-4)


@pytest.mark.parametrize("N,Fd,C,K,block", [
    (1000, 2, 3, 2, 256),
    (513, 1, 5, 4, 128),     # ragged N vs block
    (128, 3, 2, 7, 64),
])
def test_clg_disc_counts_sweep(N, Fd, C, K, block):
    """The one-hot count reduction that completes the message pytree."""
    xd = jax.random.randint(KEYS[0], (N, Fd), 0, C)
    r = jax.nn.softmax(jax.random.normal(KEYS[1], (N, K)), -1)
    out = clg_disc_counts(xd, r, C, block=block)
    exp = ref.clg_disc_counts_ref(xd, r, C)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=1e-4, rtol=1e-5)
    # column sums recover the responsibilities' mass per leaf
    np.testing.assert_allclose(np.asarray(out.sum(-1)),
                               np.tile(np.asarray(r.sum(0)), (Fd, 1)),
                               atol=1e-3)


@pytest.mark.parametrize("N,F,Do,K,L,block", [
    (600, 3, 2, 2, 1, 256),
    (513, 2, 1, 3, 2, 128),    # ragged N vs block; FA-style Do = 1
    (256, 1, 3, 4, 8, 64),     # wide latent block (L = 8)
])
def test_clg_suffstats_latent_sweep(N, F, Do, K, L, block):
    """The fused component-major latent kernel vs its three-einsum oracle
    (observed, cross and E[hh^T]-corrected latent blocks in one pass)."""
    obs = jax.random.normal(KEYS[0], (N, F, Do))
    hm = jax.random.normal(KEYS[1], (N, K, L))
    y = jax.random.normal(KEYS[2], (N, F))
    r = jax.nn.softmax(jax.random.normal(KEYS[3], (N, K)), -1)
    a = jax.random.normal(KEYS[4], (K, L, L)) * 0.3
    shh = a @ jnp.swapaxes(a, -1, -2) + jnp.eye(L)   # SPD covariance
    sxx, sxy, syy = clg_suffstats_latent(obs, hm, y, r, shh, block=block)
    rxx, rxy, ryy = ref.clg_suffstats_latent_ref(obs, hm, y, r, shh)
    np.testing.assert_allclose(np.asarray(sxx), np.asarray(rxx),
                               atol=1e-3, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(sxy), np.asarray(rxy),
                               atol=1e-3, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(syy), np.asarray(ryy),
                               atol=1e-3, rtol=1e-4)
    # block structure: the latent-latent block is leaf-independent
    hh = np.asarray(sxx)[..., Do:, Do:]
    np.testing.assert_allclose(hh, np.broadcast_to(hh[:1], hh.shape),
                               atol=1e-4)
    # symmetric output
    np.testing.assert_allclose(np.asarray(sxx),
                               np.asarray(jnp.swapaxes(sxx, -1, -2)),
                               atol=1e-4)


def test_clg_suffstats_latent_masked_instances():
    """r = 0 rows (padded/masked instances) contribute nothing, including
    to the rsum * S_k covariance correction."""
    N, F, Do, K, L = 200, 2, 2, 3, 2
    obs = jax.random.normal(KEYS[0], (N, F, Do))
    hm = jax.random.normal(KEYS[1], (N, K, L))
    y = jax.random.normal(KEYS[2], (N, F))
    r = jax.nn.softmax(jax.random.normal(KEYS[3], (N, K)), -1)
    r = r * (jnp.arange(N) < 150)[:, None]
    shh = jnp.broadcast_to(jnp.eye(L), (K, L, L)) * 0.7
    full = clg_suffstats_latent(obs, hm, y, r, shh, block=64)
    trunc = clg_suffstats_latent(obs[:150], hm[:150], y[:150], r[:150],
                                 shh, block=64)
    for a, b in zip(full, trunc):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-5)


def test_clg_suffstats_latent_via_ops_policy():
    """The jit'd ops wrapper follows the session interpret policy (the CI
    parity legs run this file under both policies)."""
    N, F, Do, K, L = 130, 2, 1, 2, 2
    obs = jax.random.normal(KEYS[5], (N, F, Do))
    hm = jax.random.normal(KEYS[6], (N, K, L))
    y = jax.random.normal(KEYS[7], (N, F))
    r = jax.nn.softmax(jax.random.normal(KEYS[0], (N, K)), -1)
    shh = jnp.broadcast_to(jnp.eye(L), (K, L, L))
    got = ops.clg_suffstats_latent(obs, hm, y, r, shh, block=64)
    exp = ref.clg_suffstats_latent_ref(obs, hm, y, r, shh)
    for g, e in zip(got, exp):
        np.testing.assert_allclose(np.asarray(g), np.asarray(e),
                                   atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("N,Fd,block", [
    (1000, 4, 256),
    (513, 2, 128),      # ragged N vs block
    (100, 6, 64),
])
def test_family_counts_sweep(N, Fd, block):
    """The structure-learning count reduction: mixed-radix family codes +
    weighted one-hot histogram, one pass over instances."""
    cards = [int(c) for c in
             np.asarray(jax.random.randint(KEYS[0], (Fd,), 2, 5))]
    cols = [jax.random.randint(jax.random.fold_in(KEYS[1], f), (N,), 0, c)
            for f, c in enumerate(cards)]
    xd = jnp.stack(cols, 1).astype(jnp.int32)
    # candidate families: each var with its two successors as parents
    fams = [(f, tuple((f + 1 + j) % Fd for j in range(min(2, Fd - 1))))
            for f in range(Fd)]
    strides = np.zeros((len(fams), Fd), np.int32)
    sizes = []
    for m, (ch, pa) in enumerate(fams):
        strides[m, ch] = 1
        s = cards[ch]
        for p in reversed(pa):
            strides[m, p] = s
            s *= cards[p]
        sizes.append(s)
    C = max(sizes)
    w = jax.random.uniform(KEYS[2], (N,))
    got = family_counts(xd, jnp.asarray(strides), w, C, block=block)
    exp = ref.family_counts_ref(xd, jnp.asarray(strides), w, C)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               atol=1e-3, rtol=1e-5)
    # every family's histogram carries the full instance mass, and padded
    # configurations beyond its true size stay exactly zero
    np.testing.assert_allclose(np.asarray(got.sum(-1)),
                               float(w.sum()), rtol=1e-5)
    for m, s in enumerate(sizes):
        assert np.asarray(got)[m, s:].max(initial=0.0) == 0.0


def test_family_counts_via_ops_policy():
    """The jit'd ops wrapper follows the session interpret policy (the CI
    parity legs run this file under both policies)."""
    xd = jax.random.randint(KEYS[3], (300, 3), 0, 3).astype(jnp.int32)
    strides = jnp.asarray([[1, 3, 9], [0, 1, 3], [1, 0, 0]], jnp.int32)
    w = jnp.ones(300)
    got = ops.family_counts(xd, strides, w, 27)
    exp = ref.family_counts_ref(xd, strides, w, 27)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), atol=1e-4)


def test_clg_kernel_feeds_conjugate_update():
    """Kernel output slots directly into the expfam conjugate update."""
    from repro.core import expfam as ef

    N, F, D, K = 400, 2, 3, 2
    d = jax.random.normal(KEYS[0], (N, F, D))
    y = jax.random.normal(KEYS[1], (N, F))
    r = jax.nn.softmax(jax.random.normal(KEYS[2], (N, K)), -1)
    sxx, sxy, syy = clg_suffstats(d, y, r, block=128)
    n = jnp.broadcast_to(r.sum(0)[None], syy.shape)
    prior = ef.MVNormalGamma(
        m=jnp.zeros((F, K, D)),
        K=jnp.broadcast_to(jnp.eye(D), (F, K, D, D)),
        a=jnp.ones((F, K)), b=jnp.ones((F, K)))
    post = ef.mvnormalgamma_update(
        prior, ef.RegSuffStats(sxx, sxy, syy, n))
    assert bool(jnp.isfinite(post.m).all())
    assert bool((post.b > 0).all())


# -- batched factor algebra (infer_exact hot loops) ---------------------------


def _factor_table(key, shape, p_neg_inf=0.25):
    """Random log table with structural zeros (evidence indicators)."""
    x = jax.random.normal(key, shape)
    mask = jax.random.bernoulli(jax.random.fold_in(key, 1), p_neg_inf, shape)
    return jnp.where(mask, -jnp.inf, x)


@pytest.mark.parametrize("B,M,N", [
    (1, 8, 8),
    (4, 300, 13),      # ragged M, prime N
    (2, 64, 700),      # N wider than one tile -> streaming accumulation
    (3, 1, 1),
])
def test_factor_log_product(B, M, N):
    a = _factor_table(KEYS[3], (B, M, N))
    b = jax.random.normal(KEYS[4], (B, N))
    out = ops.log_product(a, b, bm=64)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.log_product_ref(a, b)),
                               atol=1e-6)


@pytest.mark.parametrize("B,M,N", [
    (1, 8, 8),
    (4, 300, 13),
    (2, 64, 700),
    (3, 1, 1),
])
def test_factor_log_marginalize(B, M, N):
    x = _factor_table(KEYS[5], (B, M, N))
    out = ops.log_marginalize(x, bm=64, bn=64)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.log_marginalize_ref(x)),
                               atol=1e-5)


def test_factor_log_marginalize_all_neg_inf():
    """Fully impossible rows must stay -inf, not NaN."""
    x = jnp.full((2, 4, 300), -jnp.inf)
    out = np.asarray(ops.log_marginalize(x, bn=64))
    assert np.all(np.isneginf(out))


@pytest.mark.parametrize("B,M,N", [(1, 8, 8), (4, 300, 13), (2, 64, 700)])
def test_factor_evidence_select(B, M, N):
    x = _factor_table(KEYS[6], (B, M, N))
    idx = jax.random.randint(KEYS[7], (B,), 0, N)
    out = ops.evidence_select(x, idx, bm=64)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.evidence_select_ref(x, idx)),
                               atol=1e-6)


# -- cg_weak_marg: the strong junction tree's moment-matching hot loop --------


@pytest.mark.parametrize("B,M,N,n", [
    (1, 4, 3, 1),
    (3, 130, 6, 2),     # ragged M vs block
    (2, 8, 12, 3),
])
def test_cg_weak_marg_matches_ref(B, M, N, n):
    lw = _factor_table(KEYS[0], (B, M, N))
    mu = jax.random.normal(KEYS[1], (B, M, N, n))
    a = jax.random.normal(KEYS[2], (B, M, N, n, n))
    sigma = a @ jnp.swapaxes(a, -1, -2) + 0.5 * jnp.eye(n)
    got = ops.cg_weak_marg(lw, mu, sigma, bm=64)
    exp = ref.cg_weak_marg_ref(lw, mu, sigma)
    for g, e in zip(got, exp):
        np.testing.assert_allclose(np.asarray(g), np.asarray(e),
                                   atol=1e-5, rtol=1e-5)


def test_cg_weak_marg_dead_rows():
    """All -inf mixtures collapse to (-inf, 0, I) — no NaNs."""
    B, M, N, n = 2, 5, 4, 2
    lw = jnp.full((B, M, N), -jnp.inf)
    mu = jax.random.normal(KEYS[3], (B, M, N, n))
    sigma = jnp.broadcast_to(jnp.eye(n), (B, M, N, n, n))
    p, mh, sh = ops.cg_weak_marg(lw, mu, sigma)
    assert np.all(np.isneginf(np.asarray(p)))
    np.testing.assert_allclose(np.asarray(mh), 0.0)
    np.testing.assert_allclose(np.asarray(sh),
                               np.broadcast_to(np.eye(n), (B, M, n, n)))


def test_cg_weak_marg_preserves_moments():
    """The weak marginal keeps the mixture's exact mean and covariance."""
    B, M, N, n = 1, 1, 5, 2
    lw = jnp.log(jax.nn.softmax(jax.random.normal(KEYS[4], (B, M, N))))
    mu = jax.random.normal(KEYS[5], (B, M, N, n))
    a = jax.random.normal(KEYS[6], (B, M, N, n, n)) * 0.3
    sigma = a @ jnp.swapaxes(a, -1, -2) + jnp.eye(n)
    p, mh, sh = ops.cg_weak_marg(lw, mu, sigma)
    w = np.exp(np.asarray(lw))[0, 0]
    mu_np = np.asarray(mu)[0, 0]
    mix_mean = (w[:, None] * mu_np).sum(0)
    mix_cov = (w[:, None, None] * (np.asarray(sigma)[0, 0]
               + mu_np[:, :, None] * mu_np[:, None, :])).sum(0) \
        - mix_mean[:, None] * mix_mean[None, :]
    np.testing.assert_allclose(float(p[0, 0]), np.log(w.sum()), atol=1e-6)
    np.testing.assert_allclose(np.asarray(mh)[0, 0], mix_mean, atol=1e-5)
    np.testing.assert_allclose(np.asarray(sh)[0, 0], mix_cov, atol=1e-5)
