"""Model zoo (paper Table 2): parameter recovery on synthetic data."""

import itertools

import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import synthetic as syn
from repro.pgm_models import (AutoRegressiveHMM, BayesianLinearRegression,
                              CustomGlobalLocalModel, DynamicNaiveBayes,
                              FactorAnalysis, FactorialHMMModel,
                              GaussianMixture, HiddenMarkovModel,
                              InputOutputHMM, KalmanFilter, LDA, MixtureOfFA,
                              MultivariateGaussian, NaiveBayesClassifier,
                              SwitchingLDS)


def test_gaussian_mixture_recovery():
    s, means, _ = syn.gmm_stream(2000, 3, 4, seed=1)
    m = GaussianMixture(s.attributes, n_states=3, seed=0)
    e = m.update_model(s)
    learnt = np.sort(np.asarray(m.posterior.reg.m[:, :, 0]).T, axis=0)
    np.testing.assert_allclose(learnt, np.sort(means, 0), atol=0.3)
    assert np.isfinite(e)


def test_gmm_bayesian_updating_streams():
    """Code Fragment 9: repeated update_model calls refine the posterior."""
    s, means, _ = syn.gmm_stream(2000, 3, 4, seed=1)   # well-separated
    m = GaussianMixture(s.attributes, n_states=3, seed=0)
    for b in s.batches(500):
        m.update_model(b)
    learnt = np.sort(np.asarray(m.posterior.reg.m[:, :, 0]).T, axis=0)
    np.testing.assert_allclose(learnt, np.sort(means, 0), atol=0.35)


def test_naive_bayes_classifier():
    s, y = syn.nb_stream(1500, 3, 2, 2, seed=2)
    clf = NaiveBayesClassifier(s.attributes)
    clf.update_model(s)
    acc = float((np.asarray(clf.predict(s)) == y).mean())
    assert acc > 0.75, acc


def test_bayesian_linear_regression():
    s, w_true = syn.regression_stream(2000, 4, seed=3)
    blr = BayesianLinearRegression(s.attributes)
    blr.update_model(s)
    co = blr.coefficients()          # [bias, w...]
    np.testing.assert_allclose(co[0], w_true[-1], atol=0.1)
    np.testing.assert_allclose(co[1:], w_true[:-1], atol=0.1)


def test_factor_analysis_subspace():
    s, W = syn.fa_stream(3000, 6, 2, seed=4)
    fa = FactorAnalysis(s.attributes, n_hidden=2)
    fa.update_model(s)
    L = fa.loading_matrix()
    u1, _, _ = np.linalg.svd(W, full_matrices=False)
    u2, _, _ = np.linalg.svd(L, full_matrices=False)
    assert np.linalg.svd(u1.T @ u2)[1].min() > 0.95


def test_multivariate_gaussian_mean():
    s, means, _ = syn.gmm_stream(1500, 1, 4, seed=5)
    mg = MultivariateGaussian(s.attributes)
    mg.update_model(s)
    np.testing.assert_allclose(mg.joint_mean(), means[0], atol=0.15)


def test_custom_model_cf11_runs():
    s, _, _ = syn.gmm_stream(1000, 2, 3, seed=6)
    cm = CustomGlobalLocalModel(s.attributes, n_states=2)
    e = cm.update_model(s)
    assert np.isfinite(e)


def test_mixture_of_fa_runs():
    s, _ = syn.fa_stream(1500, 5, 2, seed=7)
    m = MixtureOfFA(s.attributes, n_states=2, n_hidden=2)
    assert np.isfinite(m.update_model(s, sweeps=40))


def test_hmm_state_recovery():
    ds, trans, means, zs = syn.hmm_sequences(20, 60, 3, 2, seed=6)
    hm = HiddenMarkovModel(ds.attributes, n_states=3, seed=1)
    hm.update_model(ds)
    learnt = np.sort(hm.state_means()[:, 0])
    np.testing.assert_allclose(learnt, np.sort(means[:, 0]), atol=0.4)
    vit = hm.viterbi_states(ds.collect().xc)
    acc = max((np.asarray(vit) == np.array(p)[zs].reshape(vit.shape)).mean()
              for p in itertools.permutations(range(3)))
    assert acc > 0.9, acc


def test_hmm_filtered_and_transitions():
    ds, trans, means, zs = syn.hmm_sequences(15, 50, 2, 2, seed=9)
    hm = HiddenMarkovModel(ds.attributes, n_states=2, seed=1)
    hm.update_model(ds)
    tl = np.asarray(hm.posterior.trans.alpha)
    tl = tl / tl.sum(-1, keepdims=True)
    assert np.diag(tl).min() > 0.5   # sticky transitions recovered
    filt = hm.filtered_posterior(ds.collect().xc)
    np.testing.assert_allclose(np.asarray(filt.sum(-1)), 1.0, atol=1e-4)


def test_kalman_filter_dynamics():
    ds, A, C = syn.lds_sequences(10, 80, 2, 3, seed=7)
    kf = KalmanFilter(ds.attributes, n_hidden=2)
    kf.update_model(ds, sweeps=15)
    radius = np.abs(np.linalg.eigvals(np.asarray(kf.A))).max()
    assert 0.6 < radius < 1.05, radius
    xs = ds.collect().xc
    sm = kf.filtered_states(xs)
    pred = jnp.einsum("fl,btl->btf", kf.C,
                      jnp.einsum("lm,btm->btl", kf.A, sm[:, :-1]))
    err = float(((pred - xs[:, 1:]) ** 2).mean())
    naive = float(((xs[:, 1:] - xs[:, :-1]) ** 2).mean())
    assert err < 0.5 * naive, (err, naive)


def test_hmm_variants_train():
    ds, *_ = syn.hmm_sequences(10, 40, 2, 2, seed=8)
    for cls in (AutoRegressiveHMM, InputOutputHMM, DynamicNaiveBayes):
        m = cls(ds.attributes, n_states=2, seed=1)
        ll1 = m.update_model(ds, sweeps=3)
        ll2 = m.update_model(ds, sweeps=10)
        assert np.isfinite(ll2)


def test_factorial_hmm_and_slds_run():
    ds, *_ = syn.hmm_sequences(8, 40, 2, 2, seed=9)
    fh = FactorialHMMModel(ds.attributes, n_chains=2, n_states=2)
    assert np.isfinite(fh.update_model(ds, sweeps=4))
    ds2, _, _ = syn.lds_sequences(6, 50, 2, 3, seed=10)
    sl = SwitchingLDS(ds2.attributes, n_states=2, n_hidden=2)
    assert np.isfinite(sl.update_model(ds2, sweeps=4))


def test_lda_topic_recovery():
    counts, beta = syn.lda_corpus(300, 50, 4, doc_len=150, seed=8)
    lda = LDA(4, 50, seed=0)
    lda.update_model(counts, sweeps=30)
    top = lda.topics()
    score = max(sum(float(top[p[t]] @ beta[t]) for t in range(4))
                for p in itertools.permutations(range(4)))
    perfect = sum(float(beta[t] @ beta[t]) for t in range(4))
    # random topics score ~ 4/vocab ~ 0.08; require >= 75% of perfect
    assert score > 0.75 * perfect, (score, perfect)
    # doc-topic posteriors normalized
    dt = lda.doc_topics(counts[:10])
    np.testing.assert_allclose(dt.sum(-1), 1.0, atol=1e-4)


def test_lda_svi_stream():
    counts, beta = syn.lda_corpus(200, 40, 3, seed=9)
    lda = LDA(3, 40, seed=0)
    for i in range(0, 200, 20):
        lda.svi_step(counts[i:i + 20], n_total=200)
    b1 = float(lda.perplexity_bound(jnp.asarray(counts[:50])))
    assert np.isfinite(b1)
